"""`stage_tree` / `modeled_stage_time` edge cases: empty source directories,
deeply nested trees, zero-byte transfers, and the n_streams guard."""

import pytest

from repro.core import FSClient, GlobalFS, dom_efs, dom_lustre, modeled_stage_time
from repro.core.staging import stage, stage_tree


@pytest.fixture
def gfs(tmp_path):
    fs = GlobalFS(str(tmp_path / "lustre"))
    yield fs
    fs.teardown()


@pytest.fixture
def efs(tmp_path):
    fs = GlobalFS(str(tmp_path / "burst"))     # any DataManager works as dst
    yield fs
    fs.teardown()


def test_stage_tree_empty_source_dir_is_noop(gfs, efs):
    FSClient(gfs).makedirs("/proj/empty")
    rep = stage_tree(gfs, efs, "/proj/empty", "/in",
                     src_model=dom_lustre(), dst_model=dom_efs())
    assert rep.files == 0
    assert rep.bytes == 0
    assert rep.modeled_time_s == 0.0           # no setup ramp for zero bytes
    assert not FSClient(efs).exists("/in")     # nothing was created


def test_stage_tree_deeply_nested(gfs, efs):
    c = FSClient(gfs)
    depth = 12
    path = "/proj"
    for d in range(depth):
        path += f"/lvl{d}"
    c.makedirs(path)
    c.write_file(f"{path}/leaf.bin", b"x" * 1024)
    c.write_file("/proj/lvl0/shallow.bin", b"y" * 256)
    rep = stage_tree(gfs, efs, "/proj", "/dst")
    assert rep.files == 2
    assert rep.bytes == 1024 + 256
    dst = FSClient(efs)
    nested = "/dst" + path[len("/proj"):] + "/leaf.bin"
    assert dst.read_file(nested) == b"x" * 1024
    assert dst.read_file("/dst/lvl0/shallow.bin") == b"y" * 256


def test_stage_empty_pair_list(gfs, efs):
    rep = stage(gfs, efs, [], src_model=dom_lustre(), dst_model=dom_efs())
    assert rep.files == 0 and rep.bytes == 0 and rep.modeled_time_s == 0.0


def test_modeled_stage_time_zero_bytes_is_zero():
    assert modeled_stage_time(0, dom_lustre(), dom_efs()) == 0.0
    assert modeled_stage_time(-5.0, dom_lustre(), dom_efs()) == 0.0
    assert modeled_stage_time(0, None, None) == 0.0


def test_modeled_stage_time_n_streams_zero_guard():
    """n_streams <= 0 must not divide by zero; it clamps to one stream."""
    t0 = modeled_stage_time(1e9, dom_lustre(), dom_efs(), n_streams=0)
    t1 = modeled_stage_time(1e9, dom_lustre(), dom_efs(), n_streams=1)
    tneg = modeled_stage_time(1e9, dom_lustre(), dom_efs(), n_streams=-3)
    assert t0 == t1 == tneg
    assert t0 > 0


def test_modeled_stage_time_monotone_in_bytes():
    times = [
        modeled_stage_time(nb, dom_lustre(), dom_efs())
        for nb in (1e6, 1e9, 1e12)
    ]
    assert times == sorted(times)
    assert all(t > 0 for t in times)


def test_modeled_stage_time_one_sided_models():
    """Missing src or dst model degrades to the other side's path alone."""
    both = modeled_stage_time(1e10, dom_lustre(), dom_efs())
    read_only = modeled_stage_time(1e10, dom_lustre(), None)
    write_only = modeled_stage_time(1e10, None, dom_efs())
    assert both == pytest.approx(max(read_only, write_only))
