"""Property tests for the pool subsystem's acceptance invariants:

* the capacity ledger is never oversubscribed;
* a node is never in two live pools;
* last-lease release (or TTL expiry) is the only path to pool teardown;
* evicted datasets are re-staged (a miss), never served stale.

Driven by hypothesis-generated operation sequences; the same invariants are
also soaked deterministically in test_pool.py for hypothesis-less installs.
"""

import pytest

pytest.importorskip("hypothesis")
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core import AllocationError, Scheduler, dom_cluster
from repro.pool import DatasetRef, PoolManager, PoolState

GB = 1e9

DATASETS = [DatasetRef(f"d{i}", (5 + 10 * (i % 7)) * GB) for i in range(10)]

# one operation = (kind, a, b) with kind-specific interpretation
ops_strategy = st.lists(
    st.tuples(
        st.sampled_from(["create", "acquire", "release", "retire", "reap"]),
        st.integers(0, 9),
        st.integers(0, 9),
    ),
    max_size=60,
)


@settings(max_examples=100, deadline=None)
@given(ops_strategy)
def test_pool_invariants_under_random_ops(ops):
    mgr = PoolManager(Scheduler(dom_cluster()), ttl_s=200.0)
    live_leases = []
    staged_resident: set[str] = set()     # names completed at least once
    now = 0.0
    teardowns_observed = 0

    for kind, a, b in ops:
        now += 1.0 + a
        if kind == "create":
            try:
                mgr.create_pool(nodes=1 + b % 2,
                                cap_bytes=(60 + 80 * (a % 3)) * GB, now=now)
            except AllocationError:
                pass                       # inventory exhausted: fine
        elif kind == "acquire":
            refs = DATASETS[a % len(DATASETS):][: 1 + b % 3]
            lease = mgr.try_acquire(f"job-{a}-{b}", refs,
                                    scratch_bytes=float(b) * GB, now=now)
            if lease is not None:
                live_leases.append(lease)
        elif kind == "release" and live_leases:
            lease = live_leases.pop(a % len(live_leases))
            if b % 2:                      # stage-in completed before release
                mgr.on_stage_in_complete(lease, now)
            torn = mgr.release(lease, now)
            if torn:
                teardowns_observed += 1
        elif kind == "retire" and mgr.active_pools:
            pool = mgr.active_pools[a % len(mgr.active_pools)]
            if pool.n_leases == 0:
                assert mgr.retire(pool, now) is True    # drained: immediate
                teardowns_observed += 1
            else:
                assert mgr.retire(pool, now) is False   # draining, NOT torn down
                assert pool.state is PoolState.DRAINING
        elif kind == "reap":
            teardowns_observed += len(mgr.reap_idle(now))

        # ledger never oversubscribed + node-disjointness + catalog sync
        mgr.check_invariants()
        # teardown discipline: every RETIRED pool got there through one of
        # the counted paths (retire-drained, last-lease release, TTL reap)
        n_retired = sum(p.state is PoolState.RETIRED for p in mgr.pools)
        assert n_retired == teardowns_observed == mgr.stats.pools_retired
        # retired pools hold nothing
        for p in mgr.pools:
            if p.state is PoolState.RETIRED:
                assert p.n_leases == 0 and p.used_bytes == 0.0

    # drain everything: inventory must be conserved
    for lease in live_leases:
        mgr.release(lease, now + 1)
        mgr.check_invariants()
    free_c, free_s = mgr.scheduler.free_counts()
    held = sum(len(p.allocation.storage_nodes) for p in mgr.live_pools)
    assert free_s + held == 4 and free_c == 8


@settings(max_examples=60, deadline=None)
@given(st.lists(st.integers(0, 9), min_size=1, max_size=20))
def test_eviction_means_restage_never_stale(refs):
    """Whatever the reference string, a dataset reported as a hit is RESIDENT
    in the catalog at grant time, and an evicted dataset's next reference is
    a miss that re-stages it."""
    mgr = PoolManager(Scheduler(dom_cluster()))
    mgr.create_pool(nodes=1, cap_bytes=90 * GB, now=0.0)
    evicted_since_touch: set[str] = set()
    now = 0.0
    for i, r in enumerate(refs):
        now += 1.0
        d = DATASETS[r]
        before = mgr.evictor.evictions
        lease = mgr.try_acquire(f"j{i}", [d], now=now)
        if lease is None:
            continue
        if d.name in evicted_since_touch:
            # invariant: evicted data is never served from the pool
            assert lease.misses == 1 and d in lease.missing
            evicted_since_touch.discard(d.name)
        if lease.hits:
            assert mgr.catalog.resident(lease.pool_id, d.name)
        mgr.on_stage_in_complete(lease, now)
        mgr.release(lease, now)
        if mgr.evictor.evictions > before:
            # something was pushed out; track names no longer resident
            for other in DATASETS:
                if not mgr.catalog.resident(lease.pool_id, other.name):
                    evicted_since_touch.add(other.name)
            evicted_since_touch.discard(d.name)   # just (re)staged
        mgr.check_invariants()
