"""Runtime: sharding rules, fault tolerance, restart planning."""

import time

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.runtime import HeartbeatMonitor, plan_restart
from repro.runtime.costs import hlo_collective_bytes, jaxpr_costs
from repro.runtime.sharding import _sanitize, param_spec


class FakeMesh:
    """Shape-only stand-in (sharding rules only read mesh.shape/axis_names)."""

    def __init__(self, shape: dict):
        self.shape = shape
        self.axis_names = tuple(shape)


MESH = FakeMesh({"data": 16, "model": 16})
MESH3 = FakeMesh({"pod": 2, "data": 16, "model": 16})


class TestParamRules:
    def test_ffn_megatron_pattern(self):
        assert param_spec(MESH, "layers/mlp/gate/w", (32, 4096, 16384)) == \
            P(None, None, "model")
        assert param_spec(MESH, "layers/mlp/down/w", (32, 16384, 4096)) == \
            P(None, "model", None)

    def test_vocab_sharding_with_fallback(self):
        assert param_spec(MESH, "embed/w", (152064, 5120)) == P("model", None)
        # odd vocab: model doesn't divide dim0 -> dropped
        assert param_spec(MESH, "embed/w", (92553, 2048)) == P(None, None)

    def test_moe_expert_parallel(self):
        assert param_spec(MESH, "layers/moe/gate", (48, 128, 2048, 768)) == \
            P(None, "model", None, None)

    def test_stacked_dims_padded(self):
        # gemma3 local layers have two leading stack dims
        assert param_spec(MESH, "local_layers/attn/wq/w", (8, 5, 3840, 4096)) == \
            P(None, None, None, "model")

    def test_norms_replicated(self):
        assert param_spec(MESH, "layers/ln1/scale", (32, 4096)) == P()

    def test_sanitize_composite_dp_prefix(self):
        # batch 32 divides (2*16) -> full composite kept
        assert _sanitize(MESH3, (("pod", "data"), None), (32, 128)) == \
            P(("pod", "data"), None)
        # batch 16 only divides data after dropping "pod"... prefix ("pod",)
        # divides 16? 16 % 2 == 0 -> ("pod",) chosen first from prefixes
        spec = _sanitize(MESH3, (("pod", "data"), None), (8, 128))
        assert spec in (P(("pod",), None), P("pod", None))

    def test_sanitize_no_axis_reuse(self):
        spec = _sanitize(MESH, ("model", "model"), (32, 32))
        assert spec == P("model", None)


class TestFault:
    def test_dead_node_detection(self):
        mon = HeartbeatMonitor(["n0", "n1"], timeout_s=10)
        now = time.monotonic()
        mon.beat("n0", now=now + 100)
        assert mon.dead_nodes(now=now + 100) == ["n1"]

    def test_straggler_detection(self):
        mon = HeartbeatMonitor([f"n{i}" for i in range(8)])
        for i in range(8):
            for _ in range(10):
                mon.beat(f"n{i}", step_time_s=1.0 if i else 5.0)
        assert mon.stragglers() == ["n0"]

    def test_no_straggler_when_uniform(self):
        mon = HeartbeatMonitor([f"n{i}" for i in range(4)])
        for i in range(4):
            for t in (1.0, 1.1, 0.9, 1.0, 1.05):
                mon.beat(f"n{i}", step_time_s=t)
        assert mon.stragglers() == []

    def test_restart_plan_shrinks_data_axis(self):
        plan = plan_restart(alive_chips=240, model_parallel=16,
                            committed_steps=[100, 200])
        assert plan.mesh_shape == (15, 16)
        assert plan.restore_step == 200

    def test_restart_plan_multipod(self):
        plan = plan_restart(alive_chips=512, model_parallel=16,
                            committed_steps=[5], pods=2)
        assert plan.mesh_shape == (2, 16, 16)

    def test_restart_plan_too_few_chips(self):
        with pytest.raises(RuntimeError):
            plan_restart(alive_chips=8, model_parallel=16, committed_steps=[])


class TestCosts:
    def test_jaxpr_dot_flops(self):
        import jax.numpy as jnp

        def f(a, b):
            return a @ b

        jx = jax.make_jaxpr(f)(jnp.zeros((64, 32)), jnp.zeros((32, 16)))
        c = jaxpr_costs(jx)
        assert c["flops"] == 2 * 64 * 32 * 16

    def test_jaxpr_scan_multiplies(self):
        import jax.numpy as jnp

        def f(x, ws):
            def body(c, w):
                return c @ w, None
            out, _ = jax.lax.scan(body, x, ws)
            return out

        jx = jax.make_jaxpr(f)(jnp.zeros((8, 8)), jnp.zeros((10, 8, 8)))
        c = jaxpr_costs(jx)
        assert c["flops"] == 10 * 2 * 8 * 8 * 8

    def test_hlo_collective_parser_trip_counts(self):
        hlo = """
%body (p: (s32[], f32[4])) -> (s32[], f32[4]) {
  %ar = f32[4]{0} all-reduce(%x), channel_id=1
}
%cond (p: (s32[], f32[4])) -> pred[] {
}
ENTRY %main (p0: f32[4]) -> f32[4] {
  %w = (s32[], f32[4]) while(%t), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"7"}}
  %ag = f32[8]{0} all-gather(%y), channel_id=2
}
"""
        c = hlo_collective_bytes(hlo)
        assert c["all-reduce"] == 7 * 16
        assert c["all-gather"] == 32
        assert c["count"] == 8
