"""PR 10: pilot-style many-task execution (two-level scheduling).

Four pillars:

* unit coverage for the in-pilot `TaskScheduler` — wave packing with
  head-blocking, batch pricing, quantum coalescing, task-level fault
  retries, and checkpoint-committed interrupts;
* orchestrator integration — `submit_pilot` pays exactly ONE negotiation
  and ONE pooled session per pilot however many tasks run inside, report
  and live counters agree, and the chaos path degrades a RUNNING pilot
  in place (slots shrink, tasks requeue) instead of killing it;
* the checkpoint-residency satellite — a pooled resume whose checkpoint
  is still RESIDENT in its pool skips the global-FS restore read, with
  the re-staged bytes pinned exactly;
* determinism regressions — 500 pilots / 50k tasks replay bit-for-bit
  through the legacy and indexed dispatchers, and a pilots-off campaign
  (the PR 4 / PR 9 shape) is untouched by the refactor.
"""

import dataclasses
import random

import pytest

from repro.chaos import NodeFaultModel
from repro.core import dom_cluster, synthetic_cluster
from repro.orchestrator import (
    BackfillPolicy,
    JobState,
    Orchestrator,
    PilotSpec,
    TaskSpec,
    WorkflowSpec,
    summarize,
)
from repro.pilot import TaskScheduler
from repro.pool import DatasetRef
from repro.provision import LifetimeClass, ProvisioningService, StorageSpec
from repro.runtime import FaultInjector, FaultSpec

GB = 1e9


# -- TaskScheduler units ------------------------------------------------------

def test_spec_validation():
    with pytest.raises(ValueError):
        TaskSpec("", run_time_s=1.0)
    with pytest.raises(ValueError):
        TaskSpec("t", run_time_s=-1.0)
    with pytest.raises(ValueError):
        TaskSpec("t", run_time_s=1.0, cores=0.0)
    with pytest.raises(ValueError):
        TaskSpec("t", run_time_s=1.0, checkpoint_every_s=0.0)
    with pytest.raises(ValueError):
        PilotSpec("p", n_compute=0)
    with pytest.raises(ValueError):
        PilotSpec("p", n_compute=1, slots_per_node=0)
    with pytest.raises(ValueError):
        TaskScheduler(slots=0)


def test_pack_fills_slots_and_head_blocks():
    ts = TaskScheduler(slots=4, slots_per_node=4)
    ts.submit(TaskSpec("small", run_time_s=10.0, cores=0.25), n=2)  # 1 slot each
    ts.submit(TaskSpec("big", run_time_s=10.0, cores=1.0))          # 4 slots
    ts.submit(TaskSpec("tail", run_time_s=10.0, cores=0.25))
    # the two smalls fit; "big" needs all 4 slots and blocks the tail
    assert ts.pack(0.0) == 2
    assert ts.busy_slots == 2 and ts.n_queued == 2
    assert ts.pack(0.0) == 0                       # head-blocked, no starvation
    ts.advance(10.0)
    assert ts.pack(10.0) == 1                      # big runs alone
    assert ts.busy_slots == 4
    ts.advance(20.0)
    assert ts.pack(20.0) == 1                      # then the tail
    ts.advance(30.0)
    assert ts.drained
    assert ts.stats.done == 4 and ts.stats.waves == 3


def test_task_needing_more_slots_than_pilot_rejected():
    ts = TaskScheduler(slots=2, slots_per_node=2)
    with pytest.raises(ValueError, match="needs"):
        ts.submit(TaskSpec("huge", run_time_s=1.0, cores=2.0))


def test_wave_io_priced_once_as_aggregate():
    calls = []

    def price(nbytes):
        calls.append(nbytes)
        return 1.0

    ts = TaskScheduler(slots=8)
    ts.price_in = price
    ts.submit(TaskSpec("t", run_time_s=10.0, cores=1.0, stage_in_bytes=GB), n=8)
    assert ts.pack(0.0) == 8
    assert calls == [8 * GB]                       # one call for the whole wave
    # all eight ends coalesce into one batch at 11.0 (1s wave I/O + 10s run)
    assert ts.next_wake() == pytest.approx(11.0)
    completed, failed, requeued = ts.advance(11.0)
    assert (completed, failed, requeued) == (8, 0, 0)


def test_quantum_rounds_heterogeneous_ends_onto_grid():
    ts = TaskScheduler(slots=4, quantum_s=5.0)
    ts.submit(TaskSpec("a", run_time_s=3.0, cores=1.0))
    ts.submit(TaskSpec("b", run_time_s=4.2, cores=1.0))
    ts.submit(TaskSpec("c", run_time_s=9.9, cores=1.0))
    ts.pack(0.0)
    assert ts.next_wake() == pytest.approx(5.0)
    assert ts.advance(5.0)[0] == 2                 # a and b land on one grid point
    assert ts.advance(10.0)[0] == 1


def test_task_faults_retry_then_fail():
    ts = TaskScheduler(slots=1, trip=lambda name: True)
    ts.submit(TaskSpec("doomed", run_time_s=5.0, cores=1.0, max_retries=2))
    t = 0.0
    for _ in range(3):                             # attempts 0, 1, 2 all trip
        assert ts.pack(t) == 1
        t = ts.next_wake()
        ts.advance(t)
    assert ts.drained
    assert ts.stats.failed == 1 and ts.stats.retries == 2
    assert ts.pending_run_s == 0.0                 # aggregates fully unwound


def test_faulted_task_resumes_from_last_checkpoint():
    trips = iter([True, False])
    ts = TaskScheduler(slots=1, trip=lambda name: next(trips))
    ts.submit(TaskSpec("ckpt", run_time_s=30.0, cores=1.0, checkpoint_every_s=10.0))
    ts.pack(0.0)
    ts.advance(30.0)                               # trips; 20s committed
    rec = ts._queue[0]
    assert rec.committed_run_s == pytest.approx(20.0)
    ts.pack(30.0)
    assert ts.next_wake() == pytest.approx(40.0)   # only the last 10s replays
    ts.advance(40.0)
    assert ts.stats.done == 1 and ts.stats.resumes == 1
    assert ts.stats.run_s_saved == pytest.approx(20.0)


def test_interrupt_commits_checkpoint_progress_without_retry_cost():
    ts = TaskScheduler(slots=2)
    ts.submit(TaskSpec("t", run_time_s=50.0, cores=1.0, checkpoint_every_s=10.0,
                       max_retries=0), n=2)
    ts.pack(0.0)
    assert ts.interrupt(25.0) == 2                 # mid-run sweep
    assert ts.busy_slots == 0 and ts.n_queued == 2
    assert all(r.committed_run_s == pytest.approx(20.0) for r in ts._queue)
    ts.pack(25.0)
    assert ts.next_wake() == pytest.approx(55.0)   # 30s remain, not 50
    ts.advance(55.0)
    assert ts.drained and ts.stats.failed == 0     # no max_retries consumed
    assert ts.stats.interrupts == 1


def test_lost_slots_shrink_but_never_deadlock():
    ts = TaskScheduler(slots=4)
    ts.set_lost_slots(99)
    assert ts.effective_slots == 1                 # floor of one slot
    ts.submit(TaskSpec("t", run_time_s=1.0, cores=1.0), n=3)
    assert ts.pack(0.0) == 1                       # drains one at a time
    ts.set_lost_slots(0)
    assert ts.effective_slots == 4


# -- orchestrator integration -------------------------------------------------

def _pilot_orch(recorder=None, **kw):
    orch = Orchestrator(dom_cluster(), recorder=recorder, **kw)
    orch.enable_pools(ttl_s=None).create_pool(nodes=2)
    return orch


def test_pilot_pays_one_negotiation_and_one_session_for_many_tasks():
    from repro.obs import TraceRecorder

    rec = TraceRecorder()
    orch = _pilot_orch(recorder=rec)
    spec = PilotSpec("p0", n_compute=2, slots_per_node=4,
                     datasets=(DatasetRef("train", 20 * GB),),
                     stage_in_bytes=GB, stage_out_bytes=GB)
    task = TaskSpec("t", run_time_s=10.0, cores=0.25,
                    stage_in_bytes=0.1 * GB, stage_out_bytes=0.01 * GB)
    job = orch.submit_pilot(spec, tasks=((task, 200),))
    orch.engine.run()
    assert job.state is JobState.DONE
    assert job.pilot.stats.done == 200
    # the acquisition amortizes: one negotiation, one session, 200 tasks
    assert rec.counts["negotiation.scored"] == 1
    assert rec.counts["sessions.opened.ephemeralfs"] == 1
    assert rec.counts["pilot.started"] == 1
    assert rec.counts["pilot.tasks_done"] == 200
    # tasks packed beyond the slot pool: 200 tasks through 8 slots
    assert job.pilot.tasks.base_slots == 8
    assert rec.counts["pilot.batches"] < 200 / 2   # coalesced, not per-task
    # the pilot rides the ordinary lifecycle: full phase history
    states = [s for s, _ in job.history]
    assert states == [
        JobState.QUEUED, JobState.ALLOCATED, JobState.PROVISIONING,
        JobState.STAGING_IN, JobState.RUNNING, JobState.STAGING_OUT,
        JobState.TEARDOWN, JobState.DONE,
    ]


def test_report_and_live_counters_agree_on_task_totals():
    orch = _pilot_orch()
    task = TaskSpec("t", run_time_s=5.0, cores=0.5)
    jobs = [
        orch.submit_pilot(PilotSpec(f"p{i}", n_compute=1, slots_per_node=4),
                          tasks=((task, 40),))
        for i in range(3)
    ]
    orch.engine.run()
    live = orch.live_report()
    assert live.n_pilots == 3
    assert live.tasks_submitted == live.tasks_done == 120
    rep = summarize(jobs, n_storage_nodes=4, pools=orch.pools)
    assert rep.n_pilots == 3
    assert rep.tasks_done == 120 and rep.tasks_failed == 0
    assert rep.tasks_submitted == orch.counters.tasks_submitted


def test_empty_pilot_completes_immediately():
    orch = _pilot_orch()
    job = orch.submit_pilot(PilotSpec("empty", n_compute=1))
    orch.engine.run()
    assert job.state is JobState.DONE
    assert job.pilot.stats.submitted == 0


def test_late_submission_packs_into_running_pilot():
    orch = _pilot_orch()
    spec = PilotSpec("late", n_compute=1, slots_per_node=2, open_ended=False)
    job = orch.submit_pilot(
        spec, tasks=((TaskSpec("warm", run_time_s=50.0, cores=0.5), 2),))
    orch.engine.at(10.0, lambda: job.pilot.submit(
        TaskSpec("late", run_time_s=5.0, cores=0.5), 2))
    orch.engine.run()
    assert job.state is JobState.DONE
    assert job.pilot.stats.done == 4


def test_task_faults_consume_task_phase_not_run_phase():
    faults = FaultInjector(FaultSpec(task_fail_p=0.3, seed=3))
    orch = _pilot_orch(faults=faults)
    task = TaskSpec("t", run_time_s=10.0, cores=0.25, max_retries=3,
                    checkpoint_every_s=4.0)
    job = orch.submit_pilot(PilotSpec("p", n_compute=2, slots_per_node=4),
                            tasks=((task, 50),))
    orch.engine.run()
    assert job.state is JobState.DONE
    assert job.attempt == 0                        # global scheduler untouched
    st = job.pilot.stats
    assert st.done == 50 and st.retries > 0
    assert st.resumes == st.retries                # every retry resumed warm
    assert st.run_s_saved > 0
    assert all(phase == "task" for _n, phase in faults.trips)


# -- chaos: degrade in place --------------------------------------------------

def _chaos_pilot(schedule, mttr_s=300.0, pool_nodes=3, extra_pool=False):
    from repro.obs import TraceRecorder

    rec = TraceRecorder()
    orch = Orchestrator(synthetic_cluster(8, 4), recorder=rec)
    mgr = orch.enable_pools(ttl_s=None)
    mgr.create_pool(nodes=pool_nodes)
    if extra_pool:
        mgr.create_pool(nodes=2)
    orch.enable_chaos(NodeFaultModel(
        [n.node_id for n in orch.scheduler.cluster.storage_nodes],
        mttr_s=mttr_s, schedule=schedule,
    ))
    task = TaskSpec("t", run_time_s=30.0, cores=0.25, checkpoint_every_s=10.0)
    job = orch.submit_pilot(
        PilotSpec("p", n_compute=2, slots_per_node=4,
                  datasets=(DatasetRef("d", 10 * GB),)),
        tasks=((task, 64),))
    orch.engine.run()
    return job, rec, orch


def test_node_loss_degrades_running_pilot_in_place():
    job, rec, orch = _chaos_pilot(((50.0, "sn00000"),))
    assert job.state is JobState.DONE
    assert job.attempt == 0                        # never requeued globally
    assert rec.counts["chaos.degraded"] == 1
    assert rec.counts["pilot.resized"] == 2        # shrink + repair widen
    resized = [e for e in rec.events if e[0] == "pilot_resized"]
    shrink, widen = resized
    assert shrink[3]["cause"] == "sn00000" and shrink[3]["n_slots"] < 8
    assert widen[3]["cause"] == "repair" and widen[3]["n_slots"] == 8
    st = job.pilot.stats
    assert st.interrupts >= 1 and st.resumes > 0   # residents requeued warm
    assert st.run_s_saved > 0
    assert not orch.scheduler.down_storage_nodes


def test_pool_collapse_requeues_pilot_through_global_path():
    # the pilot's 2-node pool loses BOTH nodes and collapses (< 2
    # survivors: no degraded mode): the attempt fails and the retry leases
    # the second pool through the ordinary global path, backlog intact
    job, rec, orch = _chaos_pilot(((50.0, "sn00000"), (50.0, "sn00001")),
                                  pool_nodes=2, extra_pool=True)
    assert job.state is JobState.DONE
    assert job.attempt >= 1                        # global requeue this time
    assert job.pilot.stats.done == 64              # backlog survived suspend
    assert job.pilot.stats.interrupts >= 1


# -- checkpoint residency (PR 5 satellite) ------------------------------------

def test_pooled_resume_skips_restore_read_when_checkpoint_resident():
    # seed 1: exactly one run fault -> one resume through the pool
    faults = FaultInjector(FaultSpec(run_fail_p=0.6, seed=1))
    orch = Orchestrator(dom_cluster(), faults=faults)
    orch.enable_pools(ttl_s=None).create_pool(nodes=2)
    job = orch.submit(WorkflowSpec(
        "j", 1, use_pool=True, datasets=(DatasetRef("d", 5 * GB),),
        run_time_s=100.0, checkpoint_every_s=10.0, checkpoint_bytes=2 * GB,
        max_retries=6))
    orch.engine.run()
    assert job.state is JobState.DONE and job.attempt == 1
    assert job.checkpoint_pool_id == job.pool_id
    # the resume re-leased the checkpoint's own pool: the 5 GB dataset was
    # a warm hit AND the 2 GB restore read never touched the global FS —
    # total staged bytes stay pinned at the first attempt's dataset miss
    assert job.staged_in_bytes == pytest.approx(5 * GB)
    assert job.stage_in_saved_bytes == pytest.approx(7 * GB)


def test_restore_read_paid_when_landing_on_a_different_pool():
    svc = ProvisioningService(dom_cluster())
    svc.ensure_pools(ttl_s=None)
    pool = svc.pool_manager.create_pool(nodes=2)
    spec = StorageSpec("resume", lifetime=LifetimeClass.POOLED,
                       managers=("ephemeralfs",))
    cold = svc.try_open_session(spec, n_compute=1, now=0.0,
                                restore_bytes=2 * GB, restore_pool_id=None)
    assert cold.stage_in_bytes == pytest.approx(2 * GB)   # global-FS read
    assert cold.saved_bytes == 0.0
    cold.release(0.5)
    warm = svc.try_open_session(spec, n_compute=1, now=1.0,
                                restore_bytes=2 * GB,
                                restore_pool_id=pool.pool_id)
    assert warm.stage_in_bytes == 0.0                     # resident: skipped
    assert warm.saved_bytes == pytest.approx(2 * GB)
    warm.release(1.5)
    # a stale pool id (pool retired, id never reused) pays the full read
    stale = svc.try_open_session(spec, n_compute=1, now=2.0,
                                 restore_bytes=2 * GB,
                                 restore_pool_id=pool.pool_id + 999)
    assert stale.stage_in_bytes == pytest.approx(2 * GB)
    stale.release(2.5)


def test_node_loss_invalidates_checkpoint_residency():
    faults = FaultInjector(FaultSpec(run_fail_p=0.6, seed=1))
    orch = Orchestrator(synthetic_cluster(8, 4), faults=faults)
    orch.enable_pools(ttl_s=None).create_pool(nodes=2)
    orch.enable_chaos(NodeFaultModel(
        [n.node_id for n in orch.scheduler.cluster.storage_nodes],
        mttr_s=5000.0, schedule=((30.0, "sn00000"),),
    ))
    job = orch.submit(WorkflowSpec(
        "j", 1, use_pool=True, run_time_s=100.0,
        checkpoint_every_s=10.0, checkpoint_bytes=2 * GB, max_retries=8))
    orch.engine.run()
    assert job.state is JobState.DONE
    # the blast hit the checkpoint's pool mid-run: residency was cleared,
    # so whatever resumes happened re-read their restore bytes
    assert job.checkpoint_pool_id is None or job.staged_in_bytes > 0


# -- determinism --------------------------------------------------------------

def _mixed_pilot_specs(seed, n_pilots, tasks_per_pilot):
    rng = random.Random(seed)
    ds = [DatasetRef(f"d{k}", (6.0 + 2.0 * k) * GB) for k in range(3)]
    out = []
    for i in range(n_pilots):
        pspec = PilotSpec(
            f"pilot{i:03d}", n_compute=rng.randint(1, 3),
            slots_per_node=rng.choice((2, 4, 8)),
            datasets=(ds[rng.randint(0, 2)],),
            stage_in_bytes=rng.uniform(0, 2) * GB,
            completion_quantum_s=rng.choice((0.0, 5.0)),
        )
        task = TaskSpec(
            f"t{i:03d}", run_time_s=rng.uniform(5, 40),
            cores=rng.choice((0.125, 0.25, 0.5)),
            stage_in_bytes=rng.uniform(0, 0.2) * GB,
            checkpoint_every_s=rng.choice((None, 5.0)),
        )
        out.append((pspec, task, tasks_per_pilot))
    return out


def _pilot_fingerprint(incremental, *, seed=11, n_pilots=500,
                       tasks_per_pilot=100, chaos=False):
    orch = Orchestrator(synthetic_cluster(16, 6), policy=BackfillPolicy(),
                        incremental=incremental,
                        faults=FaultInjector(FaultSpec(task_fail_p=0.02,
                                                       seed=7)))
    orch.enable_pools(ttl_s=None).create_pool(nodes=3, cap_bytes=200 * GB)
    if chaos:
        orch.enable_chaos(NodeFaultModel(
            [n.node_id for n in orch.scheduler.cluster.storage_nodes],
            mttf_s=6000.0, mttr_s=400.0, horizon_s=2000.0, seed=9,
        ))
    jobs = [
        orch.submit_pilot(pspec, tasks=((task, n),), at=i * 1.0)
        for i, (pspec, task, n) in enumerate(
            _mixed_pilot_specs(seed, n_pilots, tasks_per_pilot))
    ]
    orch.engine.run()
    assert all(j.state is JobState.DONE for j in jobs)
    # a task may exhaust its retries under task_fail_p; every task must
    # still reach a terminal state
    assert sum(j.pilot.stats.terminal for j in jobs) == n_pilots * tasks_per_pilot
    return [
        (j.spec.name, tuple(j.history), tuple(j.alloc_history), j.attempt,
         dataclasses.astuple(j.pilot.stats))
        for j in jobs
    ]


@pytest.mark.slow
def test_50k_tasks_bit_identical_legacy_vs_indexed():
    """500 pilots x 100 tasks: histories, granted nodes, attempts, and the
    full per-pilot task statistics replay identically through the legacy
    and indexed dispatchers, and run-to-run."""
    legacy = _pilot_fingerprint(False)
    indexed = _pilot_fingerprint(True)
    again = _pilot_fingerprint(True)
    assert legacy == indexed
    assert indexed == again


def test_pilot_campaign_deterministic_under_chaos():
    legacy = _pilot_fingerprint(False, n_pilots=60, tasks_per_pilot=40,
                                chaos=True)
    indexed = _pilot_fingerprint(True, n_pilots=60, tasks_per_pilot=40,
                                 chaos=True)
    assert legacy == indexed


def _plain_fingerprint(incremental, seed=13, n_jobs=200):
    """A pilots-off campaign in the PR 4 / PR 9 shape: the pilot refactor
    must leave it bit-for-bit untouched."""
    rng = random.Random(seed)
    orch = Orchestrator(synthetic_cluster(16, 6), policy=BackfillPolicy(),
                        incremental=incremental)
    orch.enable_pools(ttl_s=None).create_pool(nodes=2, cap_bytes=80 * GB)
    orch.enable_chaos(NodeFaultModel(
        [n.node_id for n in orch.scheduler.cluster.storage_nodes],
        mttf_s=4000.0, mttr_s=350.0, horizon_s=1200.0, seed=9,
    ))
    ds = [DatasetRef(f"d{k}", (8.0 + 3.0 * k) * GB) for k in range(3)]
    specs = []
    for i in range(n_jobs):
        if rng.random() < 0.4:
            specs.append(WorkflowSpec(
                f"j{i:03d}", rng.randint(1, 3), use_pool=True,
                datasets=(ds[rng.randint(0, 2)],),
                run_time_s=rng.uniform(10, 60), max_retries=6))
        else:
            specs.append(WorkflowSpec(
                f"j{i:03d}", rng.randint(1, 4),
                run_time_s=rng.uniform(10, 60), max_retries=6))
    jobs = orch.run_campaign(specs,
                             submit_times=[i * 1.5 for i in range(n_jobs)])
    assert all(j.state is JobState.DONE for j in jobs)
    return [(j.spec.name, tuple(j.history), tuple(j.alloc_history), j.attempt)
            for j in jobs]


def test_pilots_off_replay_is_bit_for_bit_unchanged():
    assert _plain_fingerprint(False) == _plain_fingerprint(True)


# -- obs ----------------------------------------------------------------------

def test_doctor_flags_underpacked_pilot():
    from repro.obs import TraceRecorder, diagnose

    rec = TraceRecorder()
    orch = _pilot_orch(recorder=rec)
    # 32 slots, a trickle of staggered 1-slot tasks: occupancy ~3%
    job = orch.submit_pilot(
        PilotSpec("lazy", n_compute=4, slots_per_node=8),
        tasks=tuple((TaskSpec(f"drip{i}", run_time_s=10.0 + i, cores=0.125), 1)
                    for i in range(6)))
    orch.engine.run()
    assert job.state is JobState.DONE
    advisories = diagnose(rec)
    adv = next((a for a in advisories if a.code == "pilot_underpacked"), None)
    assert adv is not None
    assert adv.evidence["worst_pilot"] == "lazy"
    assert adv.evidence["worst_mean_occupancy"] < 0.5


def test_well_packed_pilot_not_flagged():
    from repro.obs import TraceRecorder, diagnose

    rec = TraceRecorder()
    orch = _pilot_orch(recorder=rec)
    job = orch.submit_pilot(
        PilotSpec("busy", n_compute=1, slots_per_node=4),
        tasks=((TaskSpec("t", run_time_s=10.0, cores=0.25), 100),))
    orch.engine.run()
    assert job.state is JobState.DONE
    assert not any(a.code == "pilot_underpacked" for a in diagnose(rec))


def test_pilot_occupancy_series_recorded():
    from repro.obs import MetricsHub, TraceRecorder

    hub = MetricsHub()
    rec = TraceRecorder(metrics=hub)
    orch = _pilot_orch(recorder=rec)
    orch.submit_pilot(PilotSpec("p", n_compute=1, slots_per_node=4),
                      tasks=((TaskSpec("t", run_time_s=10.0, cores=0.25), 60),))
    orch.engine.run()
    series = hub.series["pilot_occupancy/p"]
    assert len(series.items()) > 0
    assert all(0.0 <= v <= 1.0 for _t, v in series.items())


def test_open_ended_pilot_makes_no_release_promise():
    # an open-ended pilot must never enter the EASY projection ledger
    # (late submissions would break the promise); a closed pilot does
    seen = {}

    def check(orch, job):
        def probe():
            if job.allocation is not None:
                seen[job.spec.name] = orch.scheduler.projected_release_of(
                    job.allocation)
        orch.engine.at(5.0, probe)

    for open_ended in (False, True):
        orch = _pilot_orch()
        job = orch.submit_pilot(
            PilotSpec("open" if open_ended else "closed", n_compute=1,
                      open_ended=open_ended),
            tasks=((TaskSpec("t", run_time_s=10.0, cores=1.0), 4),))
        check(orch, job)
        orch.engine.run()
        assert job.state is JobState.DONE
    assert seen["closed"] is not None
    assert seen["open"] is None
