"""Fault-tolerant scheduling layer: checkpoint-aware requeue, preemption,
EASY reservations — plus the satellites that ride along (virtual-clock
heartbeats, O(1) live counters, the data-aware fraction cache, and the
``_pool_wait_n`` drift guard).
"""

import random

import pytest

from repro.core import Scheduler, StorageRequest, dom_cluster, synthetic_cluster
from repro.core.scheduler import JobRequest
from repro.orchestrator import (
    BackfillPolicy,
    DataAwarePolicy,
    EasyBackfillPolicy,
    FIFOPolicy,
    JobState,
    Orchestrator,
    PreemptionPolicy,
    WorkflowSpec,
    storage_node_utilization,
    summarize,
)
from repro.pool import DatasetRef
from repro.provision import StorageSpec
from repro.runtime import FaultInjector, FaultSpec, HeartbeatMonitor

GB = 1e9


class ScriptedFaults(FaultInjector):
    """Trips exactly the (job, phase, attempt) triples it is given —
    deterministic regardless of event ordering, unlike the seeded
    coin-flipper."""

    def __init__(self, script):
        super().__init__()
        self._script = dict(script)     # (name, phase) -> times to trip

    def trip(self, job_name, phase):
        left = self._script.get((job_name, phase), 0)
        if left > 0:
            self._script[(job_name, phase)] = left - 1
            self.trips.append((job_name, phase))
            return True
        return False


def _ckpt_spec(name, *, every, run_s=100.0, ckpt_bytes=0.0, nodes=2,
               stage_in=20 * GB, retries=2):
    return WorkflowSpec(
        name,
        2,
        storage_spec=StorageSpec(
            name, nodes=nodes, managers=("ephemeralfs",), stage_in_bytes=stage_in
        ),
        run_time_s=run_s,
        max_retries=retries,
        checkpoint_every_s=every,
        checkpoint_bytes=ckpt_bytes,
    )


def _phase_time(job, state_value, which=0):
    times = [t for s, t in job.history if s.value == state_value]
    return times[which]


# -- checkpoint-aware requeue -------------------------------------------------
def test_spec_validation():
    with pytest.raises(ValueError, match="checkpoint_every_s"):
        WorkflowSpec("x", 1, checkpoint_every_s=0.0)
    with pytest.raises(ValueError, match="checkpoint_bytes"):
        WorkflowSpec("x", 1, checkpoint_bytes=1.0)
    assert WorkflowSpec("x", 1, checkpoint_every_s=5.0).fault_tolerant
    assert not WorkflowSpec("x", 1).fault_tolerant


def test_resume_pays_only_remaining_run_time():
    """A run fault with committed checkpoints replays only the uncommitted
    tail; without checkpointing the whole run replays."""
    makespans = {}
    for every in (None, 25.0):
        orch = Orchestrator(
            synthetic_cluster(8, 4), faults=ScriptedFaults({("j", "run"): 1})
        )
        spec = _ckpt_spec("j", every=every)
        job = orch.run_campaign([spec])[0]
        assert job.state is JobState.DONE
        makespans[every] = orch.engine.now
        if every is None:
            assert job.committed_run_s == 0.0
            assert job.run_s_saved == 0.0
        else:
            # fault hit at end-of-run: 3 commits at 25/50/75 s were durable
            assert job.committed_run_s == pytest.approx(75.0)
            assert job.checkpoints_committed >= 3
            assert job.run_s_saved == pytest.approx(75.0)
            assert job.resume_attempts == 1
    assert makespans[25.0] < makespans[None]


def test_resume_skips_stage_in_on_warm_nodes():
    """The retry lands on the same storage nodes (nothing else competes),
    so the staged inputs are still there: zero re-staged bytes."""
    orch = Orchestrator(
        synthetic_cluster(8, 4), faults=ScriptedFaults({("j", "run"): 1})
    )
    job = orch.run_campaign([_ckpt_spec("j", every=25.0)])[0]
    assert job.state is JobState.DONE
    first = {nid for nid, *_ in [ids for ids, _, _ in job.alloc_history]}
    assert job.alloc_history[0][1] == job.alloc_history[1][1], first
    # 20 GB staged once; the resume's stage-in was a warm skip
    assert job.staged_in_bytes == pytest.approx(20 * GB)
    assert job.stage_in_saved_bytes == pytest.approx(20 * GB)


def test_cold_resume_restages_and_pays_restore():
    """When the resume cannot land on the staged nodes, the inputs replay
    and the checkpoint is read back from the global FS."""
    orch = Orchestrator(
        synthetic_cluster(8, 4), faults=ScriptedFaults({("j", "run"): 1})
    )
    # filler pins sn00000/1 so j stages on sn00002/3; the sniper (queued
    # ahead of j's requeue) grabs those the moment the fault frees them,
    # forcing j's resume onto different (cold) storage nodes once the
    # filler drains
    def _block(name, run_s):
        return WorkflowSpec(
            name, 1,
            storage_spec=StorageSpec(name, nodes=2, managers=("ephemeralfs",)),
            run_time_s=run_s,
        )

    filler = orch.submit(_block("filler", 400.0))
    j = orch.submit(_ckpt_spec("j", every=25.0, ckpt_bytes=4 * GB))
    orch.submit(_block("sniper", 1000.0), at=10.0)
    orch.engine.run()
    assert filler.alloc_history[0][1] == ("sn00000", "sn00001")
    assert j.state is JobState.DONE
    assert j.alloc_history[0][1] != j.alloc_history[1][1]
    # inputs staged twice + one 4 GB checkpoint restore
    assert j.staged_in_bytes == pytest.approx(2 * 20 * GB + 4 * GB)
    assert j.stage_in_saved_bytes == 0.0
    assert j.run_s_saved == pytest.approx(75.0)


def test_checkpoint_write_cost_stretches_running_phase():
    """Each commit charges the modeled write against the session bandwidth:
    the RUNNING wall time is remaining + n_commits * write cost."""
    orch = Orchestrator(synthetic_cluster(8, 4))
    job = orch.run_campaign([_ckpt_spec("j", every=25.0, ckpt_bytes=8 * GB)])[0]
    assert job.state is JobState.DONE
    t_run = _phase_time(job, "running")
    t_out = _phase_time(job, "staging_out")
    run_wall = t_out - t_run
    assert run_wall > 100.0
    assert job.checkpoints_committed == 3
    # 3 equal commits stretch the phase by exactly 3 write costs
    cost = (run_wall - 100.0) / 3
    assert cost > 0
    # and a free-write spec spends exactly run_time_s
    orch2 = Orchestrator(synthetic_cluster(8, 4))
    job2 = orch2.run_campaign([_ckpt_spec("k", every=25.0)])[0]
    assert (
        _phase_time(job2, "staging_out") - _phase_time(job2, "running")
        == pytest.approx(100.0)
    )


def test_pooled_resume_reattaches_warm():
    """Pool-backed resume: the catalog still holds the datasets, so the
    retry's lease is a pure cache hit."""
    orch = Orchestrator(
        dom_cluster(), faults=ScriptedFaults({("p", "run"): 1})
    )
    orch.enable_pools(ttl_s=None)
    orch.pools.create_pool(nodes=2)
    ds = DatasetRef("d", 10 * GB)
    spec = WorkflowSpec(
        "p", 1, use_pool=True, datasets=(ds,), run_time_s=60.0,
        checkpoint_every_s=20.0,
    )
    job = orch.run_campaign([spec])[0]
    assert job.state is JobState.DONE
    assert job.dataset_hits == 1 and job.dataset_misses == 1
    assert job.stage_in_saved_bytes == pytest.approx(10 * GB)
    assert job.run_s_saved == pytest.approx(40.0)


def test_exhausted_retries_still_fail():
    orch = Orchestrator(
        synthetic_cluster(4, 2), faults=ScriptedFaults({("j", "run"): 3})
    )
    job = orch.run_campaign([_ckpt_spec("j", every=25.0, retries=2)])[0]
    assert job.state is JobState.FAILED
    assert job.attempt == 3


# -- preemption ---------------------------------------------------------------
def test_preempt_manual_checkpoint_and_release():
    orch = Orchestrator(synthetic_cluster(4, 2))
    job = orch.submit(
        WorkflowSpec("v", 4, run_time_s=500.0, checkpoint_every_s=100.0)
    )
    orch.engine.run(until=250.0)
    assert job.state is JobState.RUNNING
    assert orch.preempt(job)
    # preempt at t=250: committed the elapsed progress, not just the cadence
    assert job.committed_run_s == pytest.approx(250.0, abs=1.0)
    assert job.preemptions == 1
    # nothing else wants the nodes, so the resume re-dispatched immediately
    # and pays only the remaining 250 s
    orch.engine.run()
    assert job.state is JobState.DONE
    assert job.attempt == 0          # an eviction is not a fault
    assert job.run_s_saved == pytest.approx(250.0, abs=1.0)
    assert orch.engine.now == pytest.approx(500.0, abs=2.0)
    # a second preempt on a non-RUNNING job is refused
    assert not orch.preempt(job)


def test_preempt_without_checkpointing_loses_progress():
    orch = Orchestrator(synthetic_cluster(4, 2))
    job = orch.submit(WorkflowSpec("v", 4, run_time_s=100.0))
    orch.engine.run(until=60.0)
    assert orch.preempt(job)
    orch.engine.run()
    assert job.state is JobState.DONE
    assert job.committed_run_s == 0.0
    # the resumed attempt replayed the full run
    assert orch.engine.now >= 60.0 + 100.0


def test_high_priority_arrival_preempts_lowest_priority_victim():
    orch = Orchestrator(
        synthetic_cluster(8, 2), preemption=PreemptionPolicy(), policy=FIFOPolicy()
    )
    lo = orch.submit(
        WorkflowSpec("lo", 4, run_time_s=500.0, checkpoint_every_s=50.0, priority=0)
    )
    mid = orch.submit(
        WorkflowSpec("mid", 4, run_time_s=500.0, checkpoint_every_s=50.0, priority=3)
    )
    hi = orch.submit(WorkflowSpec("hi", 4, run_time_s=10.0, priority=5), at=100.0)
    orch.engine.run()
    assert all(j.state is JobState.DONE for j in (lo, mid, hi))
    assert lo.preemptions == 1 and mid.preemptions == 0
    assert _phase_time(hi, "allocated") == pytest.approx(100.0)


def test_preemption_protects_most_progress_on_ties():
    orch = Orchestrator(
        synthetic_cluster(8, 2), preemption=PreemptionPolicy(), policy=FIFOPolicy()
    )
    old = orch.submit(
        WorkflowSpec("old", 4, run_time_s=500.0, checkpoint_every_s=50.0)
    )
    young = orch.submit(
        WorkflowSpec("young", 4, run_time_s=500.0, checkpoint_every_s=50.0),
        at=300.0,
    )
    hi = orch.submit(WorkflowSpec("hi", 4, run_time_s=10.0, priority=1), at=400.0)
    orch.engine.run()
    assert hi.state is JobState.DONE
    assert young.preemptions == 1 and old.preemptions == 0


def test_no_pointless_preemption_when_demand_cannot_be_covered():
    orch = Orchestrator(
        synthetic_cluster(4, 2), preemption=PreemptionPolicy(), policy=FIFOPolicy()
    )
    v = orch.submit(WorkflowSpec("v", 2, run_time_s=100.0, checkpoint_every_s=10.0))
    # wants 8 compute: even releasing everything cannot satisfy it
    big = orch.submit(WorkflowSpec("big", 8, run_time_s=10.0, priority=9), at=10.0)
    orch.engine.run()
    assert v.preemptions == 0
    assert big.state is JobState.FAILED      # infeasible, fails fast at arrival
    assert v.state is JobState.DONE


def test_preempt_victim_pays_final_checkpoint_write():
    orch = Orchestrator(synthetic_cluster(4, 2))
    job = orch.submit(
        WorkflowSpec(
            "v", 4, run_time_s=500.0,
            storage=StorageRequest(nodes=1),
            checkpoint_every_s=100.0, checkpoint_bytes=8 * GB,
        )
    )
    orch.engine.run(until=150.0)
    t0 = orch.engine.now
    assert orch.preempt(job)
    assert job.state is JobState.RUNNING      # draining the final write
    orch.engine.run()
    requeued_at = [t for s, t in job.history if s.value == "queued"][1]
    assert requeued_at > t0                   # the write took modeled time
    assert job.state is JobState.DONE


# -- EASY reservations --------------------------------------------------------
def _easy_campaign(policy):
    orch = Orchestrator(synthetic_cluster(8, 4), policy=policy)
    running = orch.submit(
        WorkflowSpec(
            "running", 1,
            storage_spec=StorageSpec("running", nodes=3, managers=("ephemeralfs",)),
            run_time_s=100.0,
        )
    )
    wide = orch.submit(
        WorkflowSpec(
            "wide", 1,
            storage_spec=StorageSpec("wide", nodes=4, managers=("ephemeralfs",)),
            run_time_s=10.0,
        ),
        at=1.0,
    )
    smalls = [
        orch.submit(
            WorkflowSpec(
                f"s{i}", 1,
                storage_spec=StorageSpec(f"s{i}", nodes=1, managers=("ephemeralfs",)),
                run_time_s=400.0,
            ),
            at=2.0 + i,
        )
        for i in range(3)
    ]
    orch.engine.run()
    assert all(j.done for j in [running, wide, *smalls])
    return orch, running, wide, smalls


@pytest.mark.parametrize("incremental", [True, False])
def test_easy_head_never_delayed_by_backfill(incremental):
    """The wide head-of-queue job starts the moment the running job's nodes
    free — long small jobs cannot starve it (they do under plain backfill)."""
    policy = EasyBackfillPolicy()
    orch = Orchestrator(synthetic_cluster(8, 4), policy=policy,
                        incremental=incremental)
    running = orch.submit(
        WorkflowSpec(
            "running", 1,
            storage_spec=StorageSpec("running", nodes=3, managers=("ephemeralfs",)),
            run_time_s=100.0,
        )
    )
    wide = orch.submit(
        WorkflowSpec(
            "wide", 1,
            storage_spec=StorageSpec("wide", nodes=4, managers=("ephemeralfs",)),
            run_time_s=10.0,
        ),
        at=1.0,
    )
    smalls = [
        orch.submit(
            WorkflowSpec(
                f"s{i}", 1,
                storage_spec=StorageSpec(f"s{i}", nodes=1, managers=("ephemeralfs",)),
                run_time_s=400.0,
            ),
            at=2.0 + i,
        )
        for i in range(3)
    ]
    orch.engine.run()
    release_t = [t for s, t in running.history if s.value == "done"][0]
    wide_start = _phase_time(wide, "allocated")
    assert wide_start == pytest.approx(release_t)
    # and the reservation actually admitted no delaying backfill: every
    # small job started only after the wide head was served
    for s in smalls:
        assert _phase_time(s, "allocated") >= wide_start


def test_plain_backfill_starves_the_wide_head():
    """The contrast case: without reservations the 400 s small jobs jump
    the 4-node head and push its start out by hundreds of seconds."""
    _, running, wide, _ = _easy_campaign(BackfillPolicy())
    release_t = [t for s, t in running.history if s.value == "done"][0]
    assert _phase_time(wide, "allocated") > release_t + 300.0


def test_easy_backfills_jobs_that_finish_before_the_reservation():
    """A small job whose modeled completion lands before the reserved start
    is admitted — EASY keeps utilization, not just fairness."""
    orch = Orchestrator(synthetic_cluster(8, 4), policy=EasyBackfillPolicy())
    running = orch.submit(
        WorkflowSpec(
            "running", 1,
            storage_spec=StorageSpec("running", nodes=3, managers=("ephemeralfs",)),
            run_time_s=500.0,
        )
    )
    wide = orch.submit(
        WorkflowSpec(
            "wide", 1,
            storage_spec=StorageSpec("wide", nodes=4, managers=("ephemeralfs",)),
            run_time_s=10.0,
        ),
        at=1.0,
    )
    quick = orch.submit(
        WorkflowSpec(
            "quick", 1,
            storage_spec=StorageSpec("quick", nodes=1, managers=("ephemeralfs",)),
            run_time_s=5.0,
        ),
        at=2.0,
    )
    orch.engine.run()
    release_t = [t for s, t in running.history if s.value == "done"][0]
    assert _phase_time(quick, "allocated") == pytest.approx(2.0)  # backfilled
    assert _phase_time(wide, "allocated") == pytest.approx(release_t)


def test_easy_refuses_backfill_when_reservation_unprovable():
    """Head nodes held by a pool (no release projection): nothing may
    backfill, because no no-delay proof exists."""
    orch = Orchestrator(dom_cluster(), policy=EasyBackfillPolicy())
    orch.enable_pools(ttl_s=None)
    orch.pools.create_pool(nodes=3)       # dom has 4 storage nodes; 1 left
    wide = orch.submit(
        WorkflowSpec(
            "wide", 1,
            storage_spec=StorageSpec("wide", nodes=2, managers=("ephemeralfs",)),
            run_time_s=10.0,
        )
    )
    small = orch.submit(
        WorkflowSpec(
            "small", 1,
            storage_spec=StorageSpec("small", nodes=1, managers=("ephemeralfs",)),
            run_time_s=5.0,
        ),
        at=1.0,
    )
    orch.engine.run(until=50.0)
    assert wide.state is JobState.QUEUED
    assert small.state is JobState.QUEUED     # refused: would not be provable
    assert orch.reservation is not None and orch.reservation.start_at is None


def test_scheduler_reservation_ledger():
    sched = Scheduler(synthetic_cluster(4, 4))
    a = sched.submit(JobRequest("a", 1, storage=StorageRequest(nodes=3)))
    sched.note_projected_release(a, 50.0)
    assert sched.projected_release_of(a) == 50.0
    assert sched.projected_free_at(49.0) == (0, 0)
    assert sched.projected_free_at(50.0) == (1, 3)
    # 1 storage node free now; 3 more at t=50
    assert sched.earliest_fit(0, 1, now=0.0) == 0.0
    assert sched.earliest_fit(0, 4, now=0.0) == 50.0
    assert sched.earliest_fit(5, 0, now=0.0) is None    # only 4 compute exist
    b = sched.submit(JobRequest("b", 1, storage=StorageRequest(nodes=1)))
    # b has no projection: demands needing its node are unprovable
    assert sched.earliest_fit(0, 4, now=0.0) is None
    sched.release(b)
    assert sched.earliest_fit(0, 4, now=0.0) == 50.0
    sched.release(a)
    assert sched.projected_release_of(a) is None
    assert sched.earliest_fit(0, 4, now=60.0) == 60.0


# -- heartbeat clock (satellite) ----------------------------------------------
def test_heartbeat_monitor_injectable_clock():
    t = [0.0]
    mon = HeartbeatMonitor(["n0", "n1"], timeout_s=10.0, clock=lambda: t[0])
    assert mon.dead_nodes() == []
    t[0] = 5.0
    mon.beat("n0")
    t[0] = 12.0
    assert mon.dead_nodes() == ["n1"]      # n1's birth stamp aged out
    t[0] = 20.0
    assert set(mon.dead_nodes()) == {"n0", "n1"}


def test_orchestrator_heartbeat_monitor_uses_virtual_clock():
    orch = Orchestrator(synthetic_cluster(4, 2))
    mon = orch.heartbeat_monitor(timeout_s=30.0)
    assert set(mon.nodes) == {
        n.node_id for n in orch.scheduler.cluster.compute_nodes
    }
    orch.submit(WorkflowSpec("j", 1, run_time_s=100.0))
    orch.engine.run(until=20.0)
    assert mon.dead_nodes() == []          # virtual 20 s < 30 s timeout
    orch.engine.run(until=40.0)
    assert len(mon.dead_nodes()) == 4      # virtual clock crossed the timeout
    # beats taken mid-campaign are stamped with virtual time
    mon2 = orch.heartbeat_monitor(nodes=["x"], timeout_s=30.0)
    assert mon2.nodes["x"].last_beat == orch.engine.now


def test_default_heartbeat_clock_is_wallclock():
    mon = HeartbeatMonitor(["n0"], timeout_s=1e6)
    assert mon.nodes["n0"].last_beat > 0
    assert mon.dead_nodes() == []


# -- O(1) live counters (satellite) -------------------------------------------
def _counter_campaign(seed):
    rng = random.Random(seed)
    orch = Orchestrator(
        dom_cluster(),
        faults=FaultInjector(FaultSpec(stage_in_fail_p=0.1, run_fail_p=0.1, seed=seed)),
        preemption=PreemptionPolicy(),
    )
    orch.enable_pools(ttl_s=400.0)
    orch.pools.create_pool(nodes=1, cap_bytes=50 * GB)
    specs = []
    for i in range(40):
        name = f"j{i:02d}"
        r = rng.random()
        if r < 0.3:
            specs.append(
                WorkflowSpec(
                    name, rng.randint(1, 3), use_pool=True,
                    datasets=(DatasetRef(f"d{i % 4}", 8 * GB),),
                    run_time_s=rng.uniform(5, 60),
                    checkpoint_every_s=10.0 if r < 0.15 else None,
                )
            )
        elif r < 0.7:
            specs.append(
                WorkflowSpec(
                    name, rng.randint(1, 4),
                    storage_spec=StorageSpec(
                        name, nodes=rng.randint(1, 2), managers=("ephemeralfs",),
                        stage_in_bytes=rng.uniform(1, 20) * GB,
                    ),
                    run_time_s=rng.uniform(5, 60),
                    checkpoint_every_s=15.0 if r < 0.5 else None,
                    checkpoint_bytes=2 * GB if r < 0.5 else 0.0,
                    priority=rng.randint(0, 3),
                )
            )
        else:
            specs.append(
                WorkflowSpec(name, rng.randint(1, 6), run_time_s=rng.uniform(5, 60),
                             priority=rng.randint(0, 5))
            )
    return orch, specs


def _assert_counters_match_batch(orch, now):
    jobs = orch.jobs
    if not jobs:
        return
    live = orch.live_report(now)
    rep = summarize(jobs, n_storage_nodes=4, now=now)
    assert live.n_jobs == rep.n_jobs
    assert live.n_done == rep.n_done
    assert live.n_failed == rep.n_failed
    # batch retries = extra QUEUED entries = fault requeues + preemptions
    assert live.retries + live.preemptions == rep.total_retries
    assert live.preemptions == rep.preemptions
    assert live.resumes == rep.resumes
    assert live.run_s_saved == pytest.approx(rep.run_s_saved)
    assert live.staged_in_bytes == pytest.approx(rep.staged_in_bytes)
    assert live.staged_out_bytes == pytest.approx(rep.staged_out_bytes)
    assert live.stage_in_bytes_saved == pytest.approx(rep.stage_in_bytes_saved)
    assert live.makespan_s == pytest.approx(rep.makespan_s)
    assert live.storage_node_utilization == pytest.approx(
        storage_node_utilization(jobs, 4, rep.makespan_s, now)
    )


def test_live_counters_match_batch_metrics_mid_flight_and_final():
    for seed in (0, 1, 2):
        orch, specs = _counter_campaign(seed)
        for spec in specs:
            orch.submit(spec, at=float(specs.index(spec)))
        for t in (10.0, 45.0, 120.0, 300.0):
            orch.engine.run(until=t)
            _assert_counters_match_batch(orch, orch.engine.now)
        orch.engine.run()
        assert all(j.done for j in orch.jobs)
        _assert_counters_match_batch(orch, orch.engine.now)


# -- data-aware fraction cache (satellite) ------------------------------------
def test_data_aware_fraction_cache_invalidates_on_epoch():
    orch = Orchestrator(dom_cluster())
    orch.enable_pools(ttl_s=None)
    orch.pools.create_pool(nodes=2)
    policy = DataAwarePolicy(orch.provision)
    calls = []
    real = orch.provision.resident_fraction
    orch.provision.resident_fraction = lambda ds: (calls.append(ds), real(ds))[1]

    ds = (DatasetRef("d", 10 * GB),)
    f0 = policy.resident_fraction(ds)
    f1 = policy.resident_fraction(ds)
    assert f0 == f1 == 0.0
    assert len(calls) == 1                  # second lookup served from cache

    job = orch.submit(
        WorkflowSpec("p", 1, use_pool=True, datasets=ds, run_time_s=10.0)
    )
    orch.engine.run()
    assert job.done
    f2 = policy.resident_fraction(ds)
    assert f2 == 1.0                        # epoch moved: recomputed, now warm
    assert len(calls) == 2
    assert policy.resident_fraction(ds) == 1.0 and len(calls) == 2


def test_data_aware_order_matches_uncached_ranking():
    """The cache must be invisible to ranking: a fresh policy (no cache
    state) and a used one produce identical sort keys."""
    orch = Orchestrator(dom_cluster())
    orch.enable_pools(ttl_s=None)
    orch.pools.create_pool(nodes=2)
    warm = DatasetRef("warm", 5 * GB)
    done = orch.run_campaign(
        [WorkflowSpec("w", 1, use_pool=True, datasets=(warm,), run_time_s=5.0)]
    )
    assert all(j.done for j in done)
    used = DataAwarePolicy(orch.provision)
    jobs = [
        orch._make_job(
            WorkflowSpec(f"q{i}", 1, use_pool=True,
                         datasets=(warm,) if i % 2 else (DatasetRef("cold", GB),)),
            None,
        )
        for i in range(4)
    ]
    keys_used = [used.sort_key(j, orch.scheduler, 0.0) for j in jobs]
    keys_used2 = [used.sort_key(j, orch.scheduler, 0.0) for j in jobs]
    fresh = DataAwarePolicy(orch.provision)
    keys_fresh = [fresh.sort_key(j, orch.scheduler, 0.0) for j in jobs]
    assert keys_used == keys_used2 == keys_fresh


# -- _pool_wait_n drift guard (satellite property test) -----------------------
def _pool_wait_scan(orch):
    return sum(orch._pool_waiting(j) for j in orch.jobs)


def _drift_campaign(seed):
    rng = random.Random(seed)
    orch = Orchestrator(
        dom_cluster(),
        faults=FaultInjector(
            FaultSpec(stage_in_fail_p=0.15, run_fail_p=0.15, seed=seed)
        ),
        preemption=PreemptionPolicy(),
    )
    orch.enable_pools(ttl_s=rng.choice([None, 200.0]))
    orch.pools.create_pool(nodes=1, cap_bytes=40 * GB)
    specs, times = [], []
    for i in range(30):
        name = f"j{i:02d}"
        if rng.random() < 0.5:
            specs.append(
                WorkflowSpec(
                    name, rng.randint(1, 3), use_pool=True,
                    datasets=(DatasetRef(f"d{i % 3}", 6 * GB),),
                    stage_in_bytes=rng.uniform(0, 4) * GB,
                    run_time_s=rng.uniform(5, 50),
                    max_retries=rng.randint(0, 2),
                    checkpoint_every_s=rng.choice([None, 10.0]),
                )
            )
        else:
            specs.append(
                WorkflowSpec(
                    name, rng.randint(1, 4), run_time_s=rng.uniform(5, 50),
                    max_retries=rng.randint(0, 1),
                    priority=rng.randint(0, 4),
                    checkpoint_every_s=rng.choice([None, 15.0]),
                )
            )
        times.append(rng.uniform(0, 60))
    return orch, specs, times


def _drift_trace(seed):
    orch, specs, times = _drift_campaign(seed)
    for spec, t in zip(specs, times):
        orch.submit(spec, at=t)
    checkpoints = sorted({round(t) + k * 17.0 for t in times[:6] for k in range(3)})
    for t in checkpoints:
        orch.engine.run(until=t)
        assert orch._pool_wait_n == _pool_wait_scan(orch), (
            f"seed {seed}: drift at t={t}"
        )
    orch.engine.run()
    assert all(j.done for j in orch.jobs)
    assert orch._pool_wait_n == _pool_wait_scan(orch) == 0


def test_pool_wait_counter_never_drifts_seeded():
    """Retry-to-FAILED, preempt-resume, and lease re-attach paths all
    mutate the incremental counter; at arbitrary instants it must equal a
    from-scratch scan over every job."""
    for seed in range(8):
        _drift_trace(seed)


def test_pool_wait_counter_never_drifts_hypothesis():
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import strategies as st

    @hyp.settings(max_examples=25, deadline=None)
    @hyp.given(st.integers(min_value=0, max_value=10_000))
    def check(seed):
        _drift_trace(seed)

    check()
