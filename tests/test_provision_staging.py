"""Provisioner lifecycle, staging, data pipeline."""

import numpy as np
import pytest

from repro.core import (
    FSClient,
    GlobalFS,
    JobRequest,
    Provisioner,
    Scheduler,
    StorageRequest,
    dom_cluster,
    dom_lustre,
    stage_tree,
)
from repro.data import DatasetSpec, Loader, stage_in, write_corpus


@pytest.fixture
def deployment(tmp_path):
    cluster = dom_cluster()
    sched = Scheduler(cluster)
    alloc = sched.submit(JobRequest("t", 4, storage=StorageRequest(nodes=2)))
    prov = Provisioner(cluster)
    dep = prov.deploy(prov.plan_for(alloc), str(tmp_path / "efs"))
    yield dep
    dep.teardown()
    sched.release(alloc)


def test_deploy_layout_matches_paper(deployment):
    """1 metadata + 2 storage disks per node; mgmt+mon on first node."""
    kinds = {}
    for s in deployment.fs.services():
        kinds.setdefault(s.kind, []).append(s)
    assert len(kinds["metadata"]) == 2
    assert len(kinds["storage"]) == 4
    assert len(kinds["management"]) == 1
    assert len(kinds["monitor"]) == 1
    assert kinds["management"][0].node_id == deployment.plan.storage_nodes[0].node_id


def test_deploy_time_modeled(deployment):
    assert deployment.deploy_time_s == pytest.approx(5.37, abs=0.05)


def test_warm_redeploy_faster(tmp_path):
    cluster = dom_cluster()
    prov = Provisioner(cluster)
    sched = Scheduler(cluster)
    alloc = sched.submit(JobRequest("t", 1, storage=StorageRequest(nodes=2)))
    plan = prov.plan_for(alloc, runtime="docker")
    d1 = prov.deploy(plan, str(tmp_path / "x"))
    t_fresh = d1.deploy_time_s
    # stop services but keep the tree, then re-deploy over it (paper §IV-B1:
    # 1.2 s warm vs 4.6 s fresh)
    d1.release(keep_tree=True)
    d2 = prov.deploy(plan, str(tmp_path / "x"))
    assert d2.deploy_time_s < t_fresh
    d2.teardown()


def test_base_dir_collision_raises(tmp_path):
    """Two live deployments must never share a base_dir (they would silently
    serve each other's data as a warm tree)."""
    from repro.core import FSError

    cluster = dom_cluster()
    prov = Provisioner(cluster)
    sched = Scheduler(cluster)
    alloc = sched.submit(JobRequest("t", 1, storage=StorageRequest(nodes=2)))
    plan = prov.plan_for(alloc, runtime="docker")
    d1 = prov.deploy(plan, str(tmp_path / "x"))
    with pytest.raises(FSError, match="already in use"):
        prov.deploy(plan, str(tmp_path / "x"))
    d1.teardown()
    # teardown releases ownership: the dir is claimable (and cold) again
    d3 = prov.deploy(plan, str(tmp_path / "x"))
    assert d3.deploy_time_s == pytest.approx(t_fresh_docker(plan), abs=0.05)
    d3.teardown()
    sched.release(alloc)


def t_fresh_docker(plan):
    from repro.core import predict_deploy_time

    return predict_deploy_time(plan.targets_per_node, runtime="docker", fresh=True)


def test_render_service_config(deployment):
    cfg = deployment.plan.render_service_config()
    assert len(cfg["meta"]) == 2 and len(cfg["storage"]) == 4
    assert cfg["mgmtd"]["node"] == deployment.plan.storage_nodes[0].node_id
    assert all(m["xattr"] for m in cfg["meta"])


def test_mount_and_io(deployment):
    c = deployment.mount("rank0")
    c.makedirs("/out/run1")
    c.write_file("/out/run1/result.bin", b"payload")
    assert c.read_file("/out/run1/result.bin") == b"payload"
    assert c.stats.bytes_written == 7


def test_stage_tree_roundtrip(deployment, tmp_path):
    gfs = GlobalFS(str(tmp_path / "lustre"))
    c = FSClient(gfs)
    c.makedirs("/proj/input/sub")
    c.write_file("/proj/input/a.bin", b"A" * 3000)
    c.write_file("/proj/input/sub/b.bin", b"B" * 500)
    rep = stage_tree(gfs, deployment.fs, "/proj/input", "/in",
                     src_model=dom_lustre(), dst_model=deployment.model)
    assert rep.files == 2 and rep.bytes == 3500
    assert rep.modeled_time_s > 0
    bc = deployment.mount()
    assert bc.read_file("/in/a.bin") == b"A" * 3000
    assert bc.read_file("/in/sub/b.bin") == b"B" * 500
    gfs.teardown()


def test_loader_fs_equals_generator(deployment, tmp_path):
    gfs = GlobalFS(str(tmp_path / "lustre2"))
    spec = DatasetSpec(seed=11, vocab=997, n_tokens=1 << 14, shard_tokens=1 << 12)
    write_corpus(gfs, "/ds", spec)
    stage_in(gfs, deployment.fs, "/ds", "/data")
    via_fs = Loader(spec, batch=8, seq=32, fs=deployment.fs, root="/data")
    via_gen = Loader(spec, batch=8, seq=32)
    for step in (0, 3, 17):
        a, b = via_fs.batch_at(step), via_gen.batch_at(step)
        assert np.array_equal(a["tokens"], b["tokens"])
        assert np.array_equal(a["labels"], b["labels"])
    # next-token alignment
    a = via_fs.batch_at(0)
    assert np.array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])
    gfs.teardown()
