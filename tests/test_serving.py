"""Serving subsystem: batching accounting, replica lifecycle, staged-once
weights, autoscaler hysteresis, and the bit-identical replay regression."""

import pytest

from repro.core import dom_cluster
from repro.obs import (
    AlertEngine,
    AlertRule,
    MetricsHub,
    SLOSpec,
    SLOTracker,
    TraceRecorder,
    diagnose,
)
from repro.orchestrator import burst_arrivals, diurnal_arrivals
from repro.serving import (
    Autoscaler,
    AutoscalerConfig,
    BatchEngine,
    LengthDist,
    ModelProfile,
    Request,
    ReplicaState,
    ServingCampaign,
    ServingPerf,
    synthesize_requests,
)

GB = 1e9


# -- workload -----------------------------------------------------------------

def test_synthesize_requests_seeded_and_validated():
    times = [0.0, 1.0, 2.5]
    a = synthesize_requests(times, seed=4)
    b = synthesize_requests(times, seed=4)
    assert [(r.prompt_tokens, r.gen_tokens) for r in a] == [
        (r.prompt_tokens, r.gen_tokens) for r in b
    ]
    assert all(r.prompt_tokens >= 1 and r.gen_tokens >= 1 for r in a)
    with pytest.raises(ValueError):
        synthesize_requests([1.0, 0.5], seed=0)   # non-monotone
    with pytest.raises(ValueError):
        LengthDist(mean=0.0)


def test_length_dist_constant_when_sigma_zero():
    import random

    d = LengthDist(mean=100.0, sigma=0.0)
    assert d.sample(random.Random(0)) == 100


# -- batching -----------------------------------------------------------------

def test_batch_engine_token_accounting_exact():
    """Hand-computed two-request scenario: prefill priority, decode step
    cost scaling with occupancy, TTFT/TPOT derivation."""
    perf = ServingPerf(
        prefill_tok_per_s=1000.0, prefill_overhead_s=0.1,
        decode_base_s=0.01, decode_per_slot_s=0.005,
    )
    b = BatchEngine(2, perf)
    r1 = Request(0, 0.0, prompt_tokens=100, gen_tokens=3)
    r2 = Request(1, 0.0, prompt_tokens=200, gen_tokens=1)

    dt = b.begin_prefill(r1, 1.0)
    assert dt == pytest.approx(0.2)               # 0.1 + 100/1000
    assert b.finish_prefill(r1, 1.2) is None      # takes slot 0
    assert r1.t_first_token == 1.2 and r1.generated == 1
    assert b.n_active == 1 and b.slots[0] is r1

    # one-token request completes at prefill end, never takes a slot
    b.begin_prefill(r2, 1.2)
    done = b.finish_prefill(r2, 1.5)
    assert done is r2 and r2.t_done == 1.5 and b.n_active == 1

    # decode: step cost reflects one active slot
    assert b.decode_step_s() == pytest.approx(0.015)
    assert b.advance_decode(1.515) == []          # token 2 of 3
    done = b.advance_decode(1.530)                # token 3 of 3
    assert done == [r1] and r1.t_done == 1.530
    assert b.n_active == 0 and b.has_free_slot()

    assert r1.ttft_s == pytest.approx(1.2)
    assert r1.tpot_s == pytest.approx((1.530 - 1.2) / 2)
    assert r2.tpot_s is None
    assert b.tokens_generated == 4                # 3 + 1
    assert b.tokens_prefilled == 300
    assert b.mean_occupancy == pytest.approx(1.0)


def test_batch_engine_slot_reuse_is_deterministic():
    b = BatchEngine(3, ServingPerf())
    reqs = [Request(i, 0.0, prompt_tokens=10, gen_tokens=2) for i in range(3)]
    for i, r in enumerate(reqs):
        b.begin_prefill(r, float(i))
        b.finish_prefill(r, float(i) + 0.1)
    assert [b.slots[i].rid for i in range(3)] == [0, 1, 2]
    b.advance_decode(5.0)                          # all complete, slots free
    assert b._free == [2, 1, 0]                    # lowest slot next again


# -- campaign fixtures --------------------------------------------------------

def make_requests(n_diurnal=600, n_burst=240):
    times = sorted(
        diurnal_arrivals(n_diurnal, base_rate=0.5, peak_rate=2.0,
                         period_s=1_200.0, seed=3)
        + burst_arrivals(n_burst, base_rate=0.05, burst_rate=6.0,
                         burst_t0=400.0, burst_t1=520.0, seed=4)
    )
    return synthesize_requests(times, seed=5)


def make_obs():
    hub = MetricsHub()
    slos = SLOTracker(
        hub,
        [SLOSpec(name="queue-delay", series="serving/queue_delay_s",
                 op="<=", target=2.0, objective=0.85,
                 burn_windows=(120.0, 600.0))],
    )
    alerts = AlertEngine(
        hub,
        [AlertRule(name="queue-delay-burn", kind="burn", slo="queue-delay",
                   op=">=", target=3.0, window_s=120.0, severity="critical")],
        slos=slos,
    )
    rec = TraceRecorder(metrics=hub, sample_every_s=10.0, alerts=alerts)
    return hub, alerts, rec


def make_autoscaler(alerts, rec, **overrides):
    kw = dict(rule="queue-delay-burn", min_replicas=1, max_replicas=4,
              control_every_s=15.0, scale_up_cooldown_s=60.0, idle_ttl_s=90.0)
    kw.update(overrides)
    return Autoscaler(alerts, AutoscalerConfig(**kw), recorder=rec)


MODEL = ModelProfile("qwen3-14b-sim", weight_bytes=28 * GB, n_slots=8)


def run_traced_campaign(requests=None):
    hub, alerts, rec = make_obs()
    camp = ServingCampaign(
        dom_cluster(), MODEL, requests if requests is not None else make_requests(),
        initial_replicas=1, autoscaler=make_autoscaler(alerts, rec),
        recorder=rec,
    )
    report = camp.run()
    return camp, report, hub, alerts, rec


# -- replica set + staged-once invariant --------------------------------------

def test_weights_staged_exactly_once():
    camp, report, hub, alerts, rec = run_traced_campaign()
    attaches = [e for e in rec.events if e[0] == "lease_attached"]
    misses = [e for e in attaches if e[3]["misses"] > 0]
    # the loader lease is the only attach that staged anything
    assert len(misses) == 1 and misses[0][2] == "serving-weights"
    # every replica attach was a pure catalog hit
    replica_attaches = [e for e in attaches if e[2].startswith("serving-r")]
    assert replica_attaches and all(
        e[3]["misses"] == 0 and e[3]["hits"] == 1 for e in replica_attaches
    )
    pm = camp.service.pool_manager
    assert pm.stats.bytes_staged == MODEL.weight_bytes
    assert pm.stats.dataset_misses == 1
    # weight bytes each replica did NOT re-stage are credited as saved
    assert pm.stats.bytes_saved == MODEL.weight_bytes * len(replica_attaches)


def test_campaign_serves_everything_and_scales_both_ways():
    camp, report, hub, alerts, rec = run_traced_campaign()
    assert report.n_completed == report.n_requests
    assert report.scale_ups >= 1 and report.scale_downs >= 1
    assert report.peak_replicas >= 2
    assert report.n_replicas_final == 1
    # replica-seconds: more than a single always-on replica, less than a
    # peak-sized fleet held the whole time
    assert report.replica_seconds > report.makespan_s * 0.9
    assert report.replica_seconds < report.makespan_s * report.peak_replicas
    # incident lifecycle: fired during/after the burst, then resolved
    inc = alerts.incidents_for("queue-delay-burn")
    assert inc and inc[0].t_fired >= 400.0 and not inc[0].open


def test_replica_lifecycle_states_traced():
    camp, report, hub, alerts, rec = run_traced_campaign()
    for r in camp.rset.replicas:
        if r.state is ReplicaState.STOPPED:
            assert r.stopped_at is not None and r.session.lease is None
        states = [e[3]["state"] for e in rec.events
                  if e[0] == "replica" and e[2] == r.name]
        assert states[0] == "starting"
        if "stopped" in states:
            assert states.index("starting") < states.index("active") < \
                states.index("draining") < states.index("stopped")
    # cold start was priced: attach + page-in, no deploy
    r0 = camp.rset.replicas[0]
    assert r0.cold_start_s > 0
    assert r0.cold_start_s < camp.rset.weight_stage_s


def test_serving_trace_is_diagnosable_and_ranged():
    camp, report, hub, alerts, rec = run_traced_campaign()
    advisories = diagnose(rec)
    assert any(a.code == "serving_queue_bound" for a in advisories)
    t0, t1 = rec.t_range()
    assert 0.0 <= t0 < t1            # event-timestamp fallback, no spans


# -- determinism regression ---------------------------------------------------

def test_1k_request_campaign_replays_bit_identical():
    """The ISSUE 8 regression: a ~1k-request diurnal+burst campaign with
    autoscaler + recorder + alerts attached replays bit-identically —
    completion order, scale events, and the final hub snapshot."""
    reqs = make_requests(n_diurnal=700, n_burst=300)

    def run():
        fresh = [Request(r.rid, r.t_submit, r.prompt_tokens, r.gen_tokens)
                 for r in reqs]
        return run_traced_campaign(fresh)

    c1, rep1, hub1, a1, rec1 = run()
    c2, rep2, hub2, a2, rec2 = run()
    assert rep1.n_completed == 1000
    assert c1.completion_order == c2.completion_order
    assert c1.rset.scale_events == c2.rset.scale_events
    assert [d for d in c1.autoscaler.decisions] == \
        [d for d in c2.autoscaler.decisions]
    assert hub1.snapshot() == hub2.snapshot()
    assert rec1.events == rec2.events
    assert rep1 == rep2


# -- autoscaler hysteresis (scripted alert sequences) -------------------------

class ScriptedAlerts:
    """Fake AlertEngine: returns a scripted sequence of states for one
    rule (duck-typed — no hub/evaluate, so the autoscaler just polls)."""

    def __init__(self, states):
        self.states = list(states)
        self.i = 0

    def state(self, rule):
        s = self.states[min(self.i, len(self.states) - 1)]
        self.i += 1
        return s


class FakeReplica:
    def __init__(self, rid, idle_since=None):
        self.rid = rid
        self.name = f"fake-r{rid:02d}"
        self.idle_since = idle_since


class FakeReplicaSet:
    """Narrow ReplicaSet interface the autoscaler drives: n_live,
    scale_up / scale_down, idle_replicas."""

    def __init__(self, n_live=1, deny_ups=False):
        self.n_live = n_live
        self.deny_ups = deny_ups
        self.ups = []
        self.downs = []
        self._idle = []

    def scale_up(self, now, reason=""):
        if self.deny_ups:
            return None
        self.n_live += 1
        r = FakeReplica(len(self.ups))
        self.ups.append(now)
        return r

    def scale_down(self, r, now, reason=""):
        self.n_live -= 1
        self.downs.append((now, r.rid))
        self._idle = [x for x in self._idle if x is not r]

    def set_idle(self, *replicas):
        self._idle = list(replicas)

    def idle_replicas(self, now, ttl_s):
        return [r for r in self._idle
                if r.idle_since is not None and now - r.idle_since >= ttl_s]


def drive(asc, rset, ticks, every=15.0):
    for i in range(ticks):
        asc._rset = rset
        asc.decide(i * every)


def test_scale_up_cooldown_suppresses_rapid_ups():
    # alert FIRING on every one of 8 ticks, 15 s apart, cooldown 60 s:
    # ups land at t=0 and t=60 only
    alerts = ScriptedAlerts(["firing"] * 8)
    asc = Autoscaler(alerts, AutoscalerConfig(
        rule="r", min_replicas=1, max_replicas=8,
        control_every_s=15.0, scale_up_cooldown_s=60.0, idle_ttl_s=30.0))
    rset = FakeReplicaSet(n_live=1)
    drive(asc, rset, 8)
    assert rset.ups == [0.0, 60.0]
    assert asc.scale_ups == 2


def test_scale_up_stops_at_max_replicas():
    alerts = ScriptedAlerts(["firing"] * 10)
    asc = Autoscaler(alerts, AutoscalerConfig(
        rule="r", min_replicas=1, max_replicas=2,
        control_every_s=15.0, scale_up_cooldown_s=0.0, idle_ttl_s=30.0))
    rset = FakeReplicaSet(n_live=1)
    drive(asc, rset, 10)
    assert rset.n_live == 2 and len(rset.ups) == 1
    assert any(d.reason == "at max_replicas" for d in asc.decisions)


def test_denied_scale_up_is_counted_not_fatal():
    alerts = ScriptedAlerts(["firing"] * 3)
    asc = Autoscaler(alerts, AutoscalerConfig(
        rule="r", min_replicas=1, max_replicas=4,
        control_every_s=15.0, scale_up_cooldown_s=0.0, idle_ttl_s=30.0))
    rset = FakeReplicaSet(n_live=1, deny_ups=True)
    drive(asc, rset, 3)
    assert asc.denied_ups == 3 and asc.scale_ups == 0


def test_scale_down_waits_for_idle_ttl_and_steps_one_per_tick():
    # alert quiet throughout; three idle replicas above min, TTL 30 s
    alerts = ScriptedAlerts(["inactive"] * 10)
    asc = Autoscaler(alerts, AutoscalerConfig(
        rule="r", min_replicas=1, max_replicas=8,
        control_every_s=15.0, scale_up_cooldown_s=0.0, idle_ttl_s=30.0))
    rset = FakeReplicaSet(n_live=4)
    idlers = [FakeReplica(i, idle_since=0.0) for i in range(3)]
    rset.set_idle(*idlers)
    drive(asc, rset, 10)
    # nothing drains before TTL (ticks at 0 and 15): first down at t=30,
    # then one per tick, and never below min_replicas
    assert rset.downs == [(30.0, 0), (45.0, 1), (60.0, 2)]
    assert rset.n_live == 1
    assert asc.scale_downs == 3


def test_flapping_alert_does_not_thrash():
    # FIRING / quiet alternating every tick; cooldown 60 s, TTL 90 s: ups
    # are rate-limited to cooldown spacing (0, 60, 120 — not every firing
    # tick), and the quiet half-ticks never drain anything because no
    # replica has been idle past the TTL
    alerts = ScriptedAlerts(["firing", "inactive"] * 5)
    asc = Autoscaler(alerts, AutoscalerConfig(
        rule="r", min_replicas=1, max_replicas=4,
        control_every_s=15.0, scale_up_cooldown_s=60.0, idle_ttl_s=90.0))
    rset = FakeReplicaSet(n_live=1)
    drive(asc, rset, 10)
    assert rset.ups == [0.0, 60.0, 120.0]
    assert all(b - a >= 60.0 for a, b in zip(rset.ups, rset.ups[1:]))
    assert rset.downs == []            # idle TTL never cleared


def test_autoscaler_config_validation():
    with pytest.raises(ValueError):
        AutoscalerConfig(rule="r", min_replicas=3, max_replicas=2)
    with pytest.raises(ValueError):
        AutoscalerConfig(rule="r", min_replicas=0)
    with pytest.raises(ValueError):
        AutoscalerConfig(rule="r", control_every_s=0.0)
