"""Paper-claims validation (C1..C9, DESIGN.md §1) against the perf model."""

import pytest

from repro.core import (
    Workload,
    ault_efs,
    dom_efs,
    dom_lustre,
    hacc_workload,
    predict_deploy_time,
    predict_mdtest,
    predict_read,
    predict_write,
)

MiB = 1 << 20
GB = 1e9


def _w(sp_mb, pattern="shared", n=288):
    return Workload(n_procs=n, size_per_proc=sp_mb * MiB, pattern=pattern)


class TestC1SharedWrite:
    def test_comparable_to_lustre_beyond_32mb(self):
        """Fig 2: both ~6 GB/s from 32 MB/proc."""
        for sp in (32, 64, 256):
            b = predict_write(_w(sp), dom_efs(2)).bandwidth
            l = predict_write(_w(sp), dom_lustre()).bandwidth
            assert 5 * GB < b < 7.5 * GB, sp
            assert 5 * GB < l < 6.5 * GB, sp

    def test_lustre_wins_small_sizes(self):
        b = predict_write(_w(1), dom_efs(2)).bandwidth
        l = predict_write(_w(1), dom_lustre()).bandwidth
        assert l > b


class TestC2ReadCollapse:
    def test_read_2x_lustre_when_cached(self):
        for sp in (16, 64, 256):
            b = predict_read(_w(sp), dom_efs(2)).bandwidth
            l = predict_read(_w(sp), dom_lustre()).bandwidth
            assert b / l > 1.7, sp

    def test_even_more_at_4mb(self):
        b = predict_read(_w(4), dom_efs(2)).bandwidth
        l = predict_read(_w(4), dom_lustre()).bandwidth
        assert b / l > 2.5

    def test_collapse_at_512mb(self):
        """Per-server working set 73.72 GB > 64 GB DRAM -> dramatic drop."""
        ok = predict_read(_w(256), dom_efs(2))
        bad = predict_read(_w(512), dom_efs(2))
        assert ok.cache_resident and not bad.cache_resident
        assert bad.bandwidth < 0.4 * ok.bandwidth
        assert bad.bound == "cache-thrash"

    def test_collapse_boundary_math(self):
        """0.5 x 8 x 36 x S_p >= 73.72 GB at S_p = 512 MB (paper §IV-A2)."""
        per_node = 288 * 512 * MiB / 2
        assert per_node == pytest.approx(73.72e9, rel=0.05)


class TestC3C4FilePerProcess:
    def test_fpp_peak_near_raw(self):
        """11.96 GB/s ~ 93% of 4 x 3.2 raw: 'maximum of its capability'."""
        r = predict_write(_w(64, "fpp"), dom_efs(2))
        assert r.peak_bandwidth == pytest.approx(11.96 * GB, rel=0.02)
        assert r.peak_bandwidth / 12.8e9 > 0.9

    def test_fpp_1p7x_shared(self):
        fpp = predict_write(_w(64, "fpp"), dom_efs(2)).peak_bandwidth
        sh = predict_write(_w(64), dom_efs(2)).peak_bandwidth
        assert fpp / sh == pytest.approx(1.7, rel=0.05)


class TestC5Scaling:
    def test_shared_write_logarithmic(self):
        """1->2 nodes ~3x; 2->4 only ~+30% (Fig 4)."""
        b1 = predict_write(_w(256), dom_efs(1)).peak_bandwidth
        b2 = predict_write(_w(256), dom_efs(2)).peak_bandwidth
        b4 = predict_write(_w(256), dom_efs(4)).peak_bandwidth
        assert b2 / b1 == pytest.approx(3.0, rel=0.1)
        assert b4 / b2 == pytest.approx(1.3, rel=0.1)

    def test_fpp_scales_linearly(self):
        b1 = predict_write(_w(64, "fpp"), dom_efs(1)).peak_bandwidth
        b4 = predict_write(_w(64, "fpp"), dom_efs(4)).peak_bandwidth
        assert b4 / b1 == pytest.approx(4.0, rel=0.05)


class TestC6Mdtest:
    def test_lustre_file_creation_3p5x(self):
        e = predict_mdtest(dom_efs(2))
        l = predict_mdtest(dom_lustre())
        ratio = l[("file", "creation")] / e[("file", "creation")]
        assert ratio == pytest.approx(3.5, rel=0.05)

    def test_beegfs_dir_stat_anomaly(self):
        """Client-cache-served dir stat: 5.3M op/s >> everything else."""
        e = predict_mdtest(dom_efs(2))
        assert e[("dir", "stat")] > 1e6
        assert e[("dir", "stat")] > 20 * predict_mdtest(dom_lustre())[("dir", "stat")]

    def test_md_rate_scales_with_targets(self):
        e2 = predict_mdtest(dom_efs(2))
        e4 = predict_mdtest(dom_efs(4))
        assert e4[("file", "creation")] == pytest.approx(
            2 * e2[("file", "creation")], rel=0.01)


class TestC7HaccIO:
    def test_beegfs_peaks(self):
        w = hacc_workload(288, 4_000_000)  # ~43.8 GB total
        wr = predict_write(w, dom_efs(2))
        rd = predict_read(w, dom_efs(2))
        assert wr.bandwidth == pytest.approx(5.3 * GB, rel=0.05)
        assert rd.bandwidth == pytest.approx(9.1 * GB, rel=0.05)

    def test_lustre_collapses_on_unaligned(self):
        w = hacc_workload(288, 4_000_000)
        assert predict_write(w, dom_lustre()).bandwidth < 1.0 * GB
        assert predict_read(w, dom_lustre()).bandwidth < 0.4 * GB


class TestC8DeployTime:
    def test_dom(self):
        assert predict_deploy_time(3, runtime="shifter") == pytest.approx(5.37, abs=0.05)

    def test_ault_fresh_and_warm(self):
        assert predict_deploy_time(8, runtime="docker") == pytest.approx(4.6, abs=0.05)
        assert predict_deploy_time(8, runtime="docker", fresh=False) == pytest.approx(1.2, abs=0.05)


class TestC9Ault:
    def test_fpp_peaks(self):
        """Fig 7: 13.70 GB/s write, 20.36 GB/s read, file-per-process."""
        w = Workload(n_procs=22, size_per_proc=512 * MiB, pattern="fpp")
        wr = predict_write(w, ault_efs())
        rd = predict_read(w, ault_efs())
        assert wr.peak_bandwidth == pytest.approx(13.70 * GB, rel=0.02)
        assert rd.peak_bandwidth == pytest.approx(20.36 * GB, rel=0.02)
