"""End-to-end behaviour tests: the paper's workflow driving a real training
job — allocate, provision, stage-in, train, checkpoint to burst, drain,
crash, re-provision, restore, continue. Plus failure-path coverage."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.configs import get_smoke
from repro.core import (
    FSError,
    GlobalFS,
    JobRequest,
    Provisioner,
    Scheduler,
    StorageRequest,
    dom_cluster,
)
from repro.data import DatasetSpec, Loader, stage_in, write_corpus
from repro.models import build_model
from repro.optim import AdamWConfig
from repro.runtime import (
    RuntimeConfig,
    TrainState,
    make_train_state,
    make_train_step,
    plan_restart,
)

ARCH = "granite-moe-1b-a400m"   # MoE exercises the widest code path
BATCH, SEQ, N_STEPS = 4, 32, 8


def _setup(tmp_path, job="e2e"):
    cluster = dom_cluster()
    sched = Scheduler(cluster)
    alloc = sched.submit(JobRequest(job, 4, storage=StorageRequest(nodes=2)))
    prov = Provisioner(cluster)
    dep = prov.deploy(prov.plan_for(alloc), str(tmp_path / f"burst-{job}"))
    return cluster, sched, alloc, prov, dep


def test_full_job_lifecycle(tmp_path):
    cfg = get_smoke(ARCH)
    model = build_model(cfg)
    rt = RuntimeConfig(remat=None, zero1=False, opt=AdamWConfig(lr=3e-3))

    cluster, sched, alloc, prov, dep = _setup(tmp_path)
    gfs = GlobalFS(str(tmp_path / "lustre"))

    # stage-in
    spec = DatasetSpec(seed=3, vocab=cfg.vocab_size, n_tokens=1 << 14,
                       shard_tokens=1 << 12)
    write_corpus(gfs, "/ds", spec)
    rep = stage_in(gfs, dep.fs, "/ds", "/data")
    assert rep.bytes == (1 << 14) * 4

    loader = Loader(spec, batch=BATCH, seq=SEQ, fs=dep.fs, root="/data")
    mgr = CheckpointManager(dep.fs, global_fs=gfs)
    state = make_train_state(model, jax.random.PRNGKey(0), rt)
    step_fn = jax.jit(make_train_step(model, rt))

    # alternate two loader batches so a same-batch loss comparison is valid
    losses = []
    for step in range(N_STEPS):
        b = {k: jnp.asarray(v) for k, v in loader.batch_at(step % 2).items()}
        state, m = step_fn(state, b)
        losses.append(float(m["loss"]))
        if (step + 1) % 4 == 0:
            mgr.save(step + 1, {"params": state.params, "opt": state.opt})
    assert all(np.isfinite(l) for l in losses)
    assert losses[-2] < losses[0]   # batch-0 loss, revisited later
    assert mgr.steps() == [4, 8]

    # drain newest to global FS, then the job 'crashes': teardown deletes data
    mgr.drain_to_global(8)
    dep.teardown()
    sched.release(alloc)
    with pytest.raises(FSError):
        dep.fs.stat("/ckpt")

    # restart: new allocation, restore from the global FS copy
    _, sched2, alloc2, _, dep2 = _setup(tmp_path, job="e2e-restart")
    gmgr = CheckpointManager(gfs, root="/persist/ckpt")
    like = {"params": state.params, "opt": state.opt}
    restored, rstep = gmgr.restore(like)
    assert rstep == 8
    state2 = TrainState(restored["params"], restored["opt"], ())

    # exact state equality -> bitwise-identical continuation
    for a, b in zip(jax.tree.leaves(restored["params"]),
                    jax.tree.leaves(state.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # continue training; loss stays sane
    loader2 = Loader(spec, batch=BATCH, seq=SEQ)
    for step in range(rstep, rstep + 3):
        b = {k: jnp.asarray(v) for k, v in loader2.batch_at(step).items()}
        state2, m = step_fn(state2, b)
        assert np.isfinite(float(m["loss"]))

    dep2.teardown()
    sched2.release(alloc2)
    gfs.teardown()


def test_storage_node_failure_recovery(tmp_path):
    """Mirror-mode deployment survives a storage-node kill mid-job; restart
    plan shrinks the mesh and picks the last committed step."""
    cluster = dom_cluster()
    sched = Scheduler(cluster)
    alloc = sched.submit(JobRequest("ft", 2, storage=StorageRequest(nodes=2)))
    prov = Provisioner(cluster)
    dep = prov.deploy(prov.plan_for(alloc, mirror=True), str(tmp_path / "ft"))

    mgr = CheckpointManager(dep.fs)
    t = {"w": jnp.arange(16.0).reshape(4, 4)}
    mgr.save(10, t)
    dep.fs.kill_node(alloc.storage_nodes[1].node_id)
    assert dep.fs.degraded()

    # data is still fully readable through mirrors
    restored, step = mgr.restore(t)
    assert step == 10
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(t["w"]))

    # saving on the degraded FS keeps working
    mgr.save(20, t)
    assert mgr.steps() == [10, 20]

    plan = plan_restart(alive_chips=240, model_parallel=16,
                        committed_steps=mgr.steps(),
                        dropped_nodes=(alloc.storage_nodes[1].node_id,))
    assert plan.mesh_shape == (15, 16)
    assert plan.restore_step == 20
    dep.teardown()
    sched.release(alloc)


def test_capability_sized_storage_for_checkpoint_budget(tmp_path):
    """size_for_checkpoint -> scheduler -> provision: the paper's §V
    capability sizing wired end-to-end."""
    from repro.core import size_for_checkpoint
    from repro.core.resources import GB

    cluster = dom_cluster()
    sched = Scheduler(cluster)
    req = size_for_checkpoint(
        state_bytes=100 * GB, stall_budget_s=10.0, cluster=cluster)
    n = sched.resolve_storage_nodes(req)
    assert n == 2   # 10 GB/s needs two DataWarp nodes (6.4 GB/s each)
    alloc = sched.submit(JobRequest("sz", 1, storage=req))
    assert len(alloc.storage_nodes) == 2
    sched.release(alloc)


def test_train_driver_main(tmp_path, monkeypatch):
    """The launch/train.py driver runs end-to-end (tiny settings)."""
    monkeypatch.chdir(tmp_path)
    from repro.launch.train import main
    res = main(["--arch", "granite-moe-1b-a400m", "--steps", "6",
                "--batch", "2", "--seq", "32", "--ckpt-every", "3"])
    assert res["improved"]
    assert len(res["steps"]) >= 1
