"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from tests.conftest import JAX_DRIFT_REASON, jax_api_drifted

pytestmark = pytest.mark.skipif(jax_api_drifted(), reason=JAX_DRIFT_REASON)

from repro.kernels import ops, ref  # noqa: E402

jax.config.update("jax_enable_x64", False)


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


def _tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-5


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,S,H,K,hd,T,window",
    [
        (2, 128, 4, 2, 64, 128, None),
        (1, 256, 8, 8, 32, 256, None),     # MHA
        (2, 128, 4, 1, 64, 128, None),     # MQA
        (1, 128, 6, 2, 128, 128, 64),      # sliding window
        (1, 64, 2, 2, 16, 64, 16),
    ],
)
def test_flash_attention_sweep(B, S, H, K, hd, T, window, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = _rand(ks[0], (B, S, H, hd), dtype)
    k = _rand(ks[1], (B, T, K, hd), dtype)
    v = _rand(ks[2], (B, T, K, hd), dtype)
    out = ops.flash_attention(q, k, v, causal=True, window=window,
                              block_q=64, block_k=64)
    want = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        atol=_tol(dtype), rtol=_tol(dtype),
    )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("kv_len", [1, 37, 100, 512])
@pytest.mark.parametrize("window", [None, 64])
def test_decode_attention_sweep(kv_len, window, dtype):
    B, H, K, hd, T = 2, 8, 4, 64, 512
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = _rand(ks[0], (B, 1, H, hd), dtype)
    k = _rand(ks[1], (B, T, K, hd), dtype)
    v = _rand(ks[2], (B, T, K, hd), dtype)
    out = ops.decode_attention(q, k, v, kv_len=kv_len, window=window, block_k=128)
    want = ref.decode_attention_ref(q, k, v, kv_len=kv_len, window=window)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        atol=_tol(dtype), rtol=_tol(dtype),
    )


@pytest.mark.parametrize("B,nc,Q,H,N,P", [
    (2, 4, 32, 8, 16, 16),
    (1, 2, 64, 4, 64, 64),
    (1, 1, 128, 2, 32, 64),
])
def test_ssd_intra_chunk_sweep(B, nc, Q, H, N, P):
    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    la = -jnp.abs(jax.random.normal(ks[0], (B, nc, Q, H))) * 0.1
    C = jax.random.normal(ks[1], (B, nc, Q, N))
    Bm = jax.random.normal(ks[2], (B, nc, Q, N))
    x = jax.random.normal(ks[3], (B, nc, Q, H, P))
    y, st, tot = ops.ssd_intra_chunk(la, C, Bm, x)
    yr, str_, totr = ref.ssd_intra_chunk_ref(la, C, Bm, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(str_), atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(tot), np.asarray(totr), atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(4, 64, 256), (2, 33, 128), (1, 1, 512)])
def test_rmsnorm_sweep(shape, dtype):
    ks = jax.random.split(jax.random.PRNGKey(3), 2)
    x = _rand(ks[0], shape, dtype)
    s = _rand(ks[1], shape[-1:], dtype)
    out = ops.rmsnorm(x, s)
    want = ref.rmsnorm_ref(x, s)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        atol=_tol(dtype), rtol=_tol(dtype),
    )


def test_flash_attention_grads_flow():
    """The kernel sits on the fwd path only in serving; training uses the
    blockwise jnp path — but interpret-mode kernels must still be jittable
    inside larger graphs."""
    q = jnp.ones((1, 64, 2, 32))
    k = jnp.ones((1, 64, 2, 32))
    v = jnp.ones((1, 64, 2, 32))

    @jax.jit
    def f(q):
        return ops.flash_attention(q, k, v, block_q=32, block_k=32).sum()

    assert jnp.isfinite(f(q))
