"""PR 4 regression suite: indexed ledgers, incremental dispatch, caching.

Three pillars:

* a hypothesis property test driving the `Scheduler`'s indexed free-pool
  ledger against a naive dict-of-free-nodes model under random
  grant/release interleavings — node choice, free sets, sizing
  resolutions, and the weakest-free aggregates must stay bit-for-bit
  equal;
* determinism regressions replaying identical seeded campaigns through
  the legacy (sort-everything) dispatcher and the indexed one, across
  FIFO / backfill / storage-aware / data-aware policies, with faults,
  pools, retries, and Poisson arrivals — `JobRecord.history`, granted
  node ids, attempt counts, and failure phases must match exactly;
* unit coverage for the new machinery: `SimEngine.at_many`, the
  configurable `max_events` backstop, negotiation caching epochs, and
  pool-reap coalescing.
"""

import math
import random

import pytest

from repro.core import (
    AllocationError,
    JobRequest,
    Scheduler,
    StorageRequest,
    dom_cluster,
    synthetic_cluster,
    tpu_pod_cluster,
)
from repro.core.resources import (
    ARIES,
    ClusterSpec,
    ComputeNode,
    Disk,
    DiskSpec,
    StorageNode,
)
from repro.orchestrator import (
    BackfillPolicy,
    DataAwarePolicy,
    FIFOPolicy,
    Orchestrator,
    SimEngine,
    StorageAwarePolicy,
    WorkflowSpec,
)
from repro.orchestrator.arrivals import poisson_arrivals
from repro.pool import DatasetRef
from repro.provision import LifetimeClass, ProvisioningService, StorageSpec
from repro.runtime import FaultInjector, FaultSpec

GB = 1e9
TB = 1e12


# -- naive model for the indexed ledger --------------------------------------
class NaiveScheduler:
    """The pre-index semantics, literally: dict free pools, full sorts and
    min-scans per operation. The property test holds the real scheduler to
    bit-for-bit equality with this."""

    def __init__(self, cluster, policy):
        self.cluster = cluster
        self.policy = policy
        self.free_compute = {n.node_id: n for n in cluster.compute_nodes}
        self.free_storage = {n.node_id: n for n in cluster.storage_nodes}

    def resolve(self, req, assume_empty=False):
        if req.nodes is not None:
            return req.nodes
        if assume_empty or not self.free_storage:
            candidates = self.cluster.storage_nodes
        else:
            candidates = tuple(self.free_storage.values())
        if req.capacity_bytes is not None:
            weakest = min(candidates, key=self.policy.node_capacity_bytes)
            return self.policy.nodes_for_capacity(weakest, req.capacity_bytes)
        weakest = min(candidates, key=self.policy.node_capability_bw)
        return self.policy.nodes_for_capability(weakest, req.capability_bw)

    def grant(self, n_compute, n_storage):
        compute = [self.free_compute.pop(k) for k in sorted(self.free_compute)[:n_compute]]
        storage = [self.free_storage.pop(k) for k in sorted(self.free_storage)[:n_storage]]
        return compute, storage

    def release(self, compute, storage):
        for n in compute:
            self.free_compute[n.node_id] = n
        for n in storage:
            self.free_storage[n.node_id] = n

    def weakest_free(self):
        if not self.free_storage:
            return (None, None)
        nodes = tuple(self.free_storage.values())
        return (
            min(self.policy.node_capacity_bytes(n) for n in nodes),
            min(self.policy.node_capability_bw(n) for n in nodes),
        )


def _heterogeneous_cluster(seed: int, n_storage: int) -> ClusterSpec:
    rng = random.Random(seed)
    nodes = []
    for i in range(n_storage):
        nid = f"s{i:03d}"
        spec = DiskSpec(
            f"d{i}",
            capacity_bytes=rng.choice([2, 4, 6, 10]) * TB,
            read_bw=rng.choice([2, 4, 6]) * GB,
            write_bw=rng.choice([1, 2, 3]) * GB,
        )
        disks = tuple(Disk(nid, d, spec) for d in range(rng.randint(1, 3)))
        nodes.append(StorageNode(nid, disks))
    return ClusterSpec(
        name="hetero-prop",
        compute_nodes=tuple(ComputeNode(f"c{i:03d}") for i in range(8)),
        storage_nodes=tuple(nodes),
        interconnect=ARIES,
    )


def _random_request(rng) -> StorageRequest:
    kind = rng.randrange(3)
    if kind == 0:
        return StorageRequest(nodes=rng.randint(1, 3))
    if kind == 1:
        return StorageRequest(capacity_bytes=rng.uniform(1, 40) * TB)
    return StorageRequest(capability_bw=rng.uniform(1, 20) * GB)


def _ledger_trace(seed: int, n_ops: int = 120) -> None:
    rng = random.Random(seed)
    cluster = _heterogeneous_cluster(seed, n_storage=rng.randint(2, 9))
    sched = Scheduler(cluster)
    model = NaiveScheduler(cluster, sched.policy)
    live = []          # (Allocation, model compute, model storage)
    for _ in range(n_ops):
        assert set(sched._free_compute) == set(model.free_compute)
        assert set(sched._free_storage) == set(model.free_storage)
        assert (sched.free_min_capacity(), sched.free_min_bandwidth()) == (
            model.weakest_free()
        )
        req = _random_request(rng)
        assert sched.resolve_storage_nodes(req, assume_empty=True) == model.resolve(
            req, assume_empty=True
        )
        assert sched.resolve_storage_nodes(req) == model.resolve(req)
        if live and (rng.random() < 0.45 or rng.random() < 0.1 * len(live)):
            alloc, mc, ms = live.pop(rng.randrange(len(live)))
            sched.release(alloc)
            model.release(mc, ms)
            continue
        job = JobRequest(f"job{_}", rng.randint(0, 3), storage=req)
        try:
            alloc = sched.submit(job)
        except AllocationError:
            # the model must agree it cannot fit
            n_storage = model.resolve(req)
            assert (
                job.n_compute > len(model.free_compute)
                or n_storage > len(model.free_storage)
            )
            continue
        mc, ms = model.grant(job.n_compute, model.resolve(req))
        assert [n.node_id for n in alloc.compute_nodes] == [n.node_id for n in mc]
        assert [n.node_id for n in alloc.storage_nodes] == [n.node_id for n in ms]
        live.append((alloc, mc, ms))


def test_indexed_ledger_matches_naive_model_seeded():
    for seed in range(12):
        _ledger_trace(seed)


def test_indexed_ledger_matches_naive_model_hypothesis():
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import strategies as st

    @hyp.settings(max_examples=40, deadline=None)
    @hyp.given(st.integers(min_value=0, max_value=10_000))
    def check(seed):
        _ledger_trace(seed, n_ops=60)

    check()


# -- determinism regressions: legacy vs indexed dispatch ---------------------
def _mixed_specs(seed: int, n: int) -> list:
    rng = random.Random(seed)
    specs = []
    for i in range(n):
        name = f"job{i:03d}"
        r = rng.random()
        if r < 0.25:
            # <= 2 storage nodes: dom keeps 4 and the campaign pool pins
            # one, so even FIFO's blocked head can always eventually run
            storage = StorageSpec(
                name, nodes=rng.randint(1, 2), managers=("ephemeralfs",),
                stage_in_bytes=rng.uniform(1, 40) * GB,
                stage_out_bytes=rng.uniform(0, 10) * GB,
            )
            spec = WorkflowSpec(name, rng.randint(1, 6), storage_spec=storage,
                                run_time_s=rng.uniform(5, 120), max_retries=2)
        elif r < 0.45:
            storage = StorageSpec(
                name, capacity_bytes=rng.choice([5, 12, 20]) * TB,
                managers=("ephemeralfs",), stage_in_bytes=8 * GB,
            )
            spec = WorkflowSpec(name, rng.randint(1, 4), storage_spec=storage,
                                run_time_s=rng.uniform(5, 60))
        elif r < 0.6:
            storage = StorageSpec(
                name, bandwidth=rng.choice([4, 9]) * GB,
                managers=("ephemeralfs",), stage_in_bytes=2 * GB,
            )
            spec = WorkflowSpec(name, rng.randint(1, 4), storage_spec=storage,
                                run_time_s=rng.uniform(5, 60))
        elif r < 0.75:
            ds = DatasetRef(f"d{rng.randint(0, 5)}", (5 + 3 * rng.randint(0, 4)) * GB)
            spec = WorkflowSpec(name, rng.randint(1, 3), use_pool=True,
                                datasets=(ds,), stage_in_bytes=rng.uniform(0, 5) * GB,
                                run_time_s=rng.uniform(5, 60))
        elif r < 0.9:
            spec = WorkflowSpec(name, rng.randint(1, 8), run_time_s=rng.uniform(5, 60))
        else:
            storage = StorageSpec(
                name, capacity_bytes=2 * TB, managers=("globalfs", "ephemeralfs"),
                stage_in_bytes=1 * GB,
            )
            spec = WorkflowSpec(name, rng.randint(1, 4), storage_spec=storage,
                                run_time_s=rng.uniform(5, 60))
        specs.append(spec)
    return specs


def _campaign_fingerprint(policy_name: str, incremental: bool, seed: int,
                          n_jobs: int, cluster_fn, *, recorder=None,
                          out=None):
    """``recorder``/``out`` let tests/test_obs.py replay the same campaign
    with tracing on and compare histories + engine event counts."""
    orch = Orchestrator(
        cluster_fn(),
        faults=FaultInjector(
            FaultSpec(stage_in_fail_p=0.08, run_fail_p=0.05, seed=seed)
        ),
        incremental=incremental,
        recorder=recorder,
    )
    mgr = orch.enable_pools(ttl_s=500.0)
    mgr.create_pool(nodes=1, cap_bytes=60 * GB)
    if policy_name == "fifo":
        orch.policy = FIFOPolicy()
    elif policy_name == "backfill":
        orch.policy = BackfillPolicy()
    elif policy_name == "storage-aware":
        orch.policy = StorageAwarePolicy(aging_s=200.0)
    else:
        orch.policy = DataAwarePolicy(orch.provision, aging_s=200.0)
    specs = _mixed_specs(seed, n_jobs)
    times = poisson_arrivals(1.0, len(specs), seed=seed)
    jobs = orch.run_campaign(specs, submit_times=list(times))
    assert all(j.done for j in jobs)
    if out is not None:
        out["events_processed"] = orch.engine.events_processed
    return [
        (
            j.spec.name,
            tuple(j.history),              # (state, virtual time) pairs
            tuple(j.alloc_history),        # granted node ids + pool per attempt
            j.attempt,
            j.failure_phase,
        )
        for j in jobs
    ]


@pytest.mark.parametrize(
    "policy_name", ["fifo", "backfill", "storage-aware", "data-aware"]
)
def test_indexed_dispatch_is_bit_identical_to_legacy(policy_name):
    """The tentpole's determinism guarantee: 500 seeded jobs (faults,
    retries, pools, Poisson arrivals) produce identical histories and
    allocation node-ids through both dispatchers."""
    legacy = _campaign_fingerprint(policy_name, False, 42, 500, dom_cluster)
    indexed = _campaign_fingerprint(policy_name, True, 42, 500, dom_cluster)
    assert legacy == indexed


def test_indexed_dispatch_matches_legacy_on_larger_cluster():
    for policy_name in ("backfill", "data-aware"):
        legacy = _campaign_fingerprint(
            policy_name, False, 7, 200, lambda: tpu_pod_cluster(24, 8)
        )
        indexed = _campaign_fingerprint(
            policy_name, True, 7, 200, lambda: tpu_pod_cluster(24, 8)
        )
        assert legacy == indexed


def test_allocations_hand_out_lowest_node_ids_first():
    orch = Orchestrator(synthetic_cluster(8, 4))
    job = orch.submit(
        WorkflowSpec(
            "j", 3,
            storage_spec=StorageSpec("j", nodes=2, managers=("ephemeralfs",)),
        )
    )
    orch.engine.run()
    compute_ids, storage_ids, pool_id = job.alloc_history[0]
    assert compute_ids == ("cn00000", "cn00001", "cn00002")
    assert storage_ids == ("sn00000", "sn00001")
    assert pool_id is None


# -- engine: at_many + configurable backstop ---------------------------------
def test_at_many_matches_sequential_at():
    fired_a, fired_b = [], []
    eng_a, eng_b = SimEngine(), SimEngine()
    events = [(5.0, "x"), (1.0, "y"), (5.0, "z"), (2.0, "w")]
    for t, tag in events:
        eng_a.at(t, (lambda g: lambda: fired_a.append(g))(tag))
    eng_b.at_many(
        (t, (lambda g: lambda: fired_b.append(g))(tag)) for t, tag in events
    )
    eng_a.run()
    eng_b.run()
    assert fired_a == fired_b == ["y", "w", "x", "z"]


def test_at_many_rejects_past_times():
    eng = SimEngine(start=10.0)
    with pytest.raises(ValueError):
        eng.at_many([(11.0, lambda: None), (9.0, lambda: None)])


def test_run_max_events_none_disables_backstop():
    eng = SimEngine()
    count = [0]

    def tick():
        count[0] += 1
        if count[0] < 2_000:
            eng.after(1.0, tick)

    eng.after(1.0, tick)
    eng.run(max_events=None)
    assert count[0] == 2_000


def test_run_campaign_max_events_scales_with_jobs():
    """A campaign bigger than the engine's fixed 1M default must not trip
    the backstop; an explicit tiny cap still does."""
    orch = Orchestrator(synthetic_cluster(4, 2))
    specs = [WorkflowSpec(f"j{i}", 1, run_time_s=1.0) for i in range(40)]
    with pytest.raises(RuntimeError):
        Orchestrator(synthetic_cluster(4, 2)).run_campaign(
            list(specs), max_events=10
        )
    jobs = orch.run_campaign(specs)
    assert all(j.done for j in jobs)


# -- negotiation caching -----------------------------------------------------
def test_negotiation_cache_hits_for_repeated_spec_shapes():
    svc = ProvisioningService(dom_cluster())
    offers = [
        svc.negotiate(
            StorageSpec(f"job{i}", nodes=2, managers=("ephemeralfs",))
        )
        for i in range(50)
    ]
    assert len({o.backend for o in offers}) == 1
    assert svc.stats.negotiations == 50
    assert svc.stats.negotiations_cached == 49
    assert all(o == offers[0] for o in offers)


def test_negotiation_cache_failures_reraise_with_caller_name():
    from repro.provision import NegotiationError

    svc = ProvisioningService(dom_cluster())
    bad = dict(nodes=100, managers=("ephemeralfs",))
    with pytest.raises(NegotiationError, match="alpha"):
        svc.negotiate(StorageSpec("alpha", **bad))
    with pytest.raises(NegotiationError, match="beta"):
        svc.negotiate(StorageSpec("beta", **bad))
    assert svc.stats.negotiations_cached == 1
    assert svc.stats.failed_negotiations == 2


def test_pooled_offers_invalidate_on_pool_state_change():
    svc = ProvisioningService(dom_cluster())
    pools = svc.ensure_pools()
    spec = StorageSpec(
        "pooled", lifetime=LifetimeClass.POOLED, managers=("ephemeralfs",),
        datasets=(DatasetRef("d", 10 * GB),),
    )
    from repro.provision import NegotiationError

    with pytest.raises(NegotiationError):
        svc.negotiate(spec)          # no active pool yet
    pools.create_pool(nodes=2)
    offer = svc.negotiate(spec)      # epoch moved: re-scored, now feasible
    assert offer.backend == "ephemeralfs"
    # stable pool state: the identical shape is now a cache hit
    before = svc.stats.negotiations_cached
    svc.negotiate(StorageSpec(
        "pooled2", lifetime=LifetimeClass.POOLED, managers=("ephemeralfs",),
        datasets=(DatasetRef("d", 10 * GB),),
    ))
    assert svc.stats.negotiations_cached == before + 1


def test_ephemeral_offers_cached_across_free_pool_churn():
    """EPHEMERAL offers are sized against the whole inventory, so granting
    and releasing nodes must not invalidate them."""
    svc = ProvisioningService(dom_cluster())
    spec = StorageSpec("a", capacity_bytes=10 * TB, managers=("ephemeralfs",))
    svc.negotiate(spec)
    session = svc.open_session(
        StorageSpec("hold", nodes=2, managers=("ephemeralfs",))
    )
    svc.negotiate(StorageSpec("b", capacity_bytes=10 * TB, managers=("ephemeralfs",)))
    session.release()
    svc.negotiate(StorageSpec("c", capacity_bytes=10 * TB, managers=("ephemeralfs",)))
    assert svc.stats.negotiations_cached == 2


# -- pool-reap counter + coalescing ------------------------------------------
def test_reap_counter_tracks_pool_waiting_jobs():
    orch = Orchestrator(dom_cluster())
    mgr = orch.enable_pools(ttl_s=50.0)
    mgr.create_pool(nodes=2)
    ds = DatasetRef("d", 5 * GB)
    specs = [
        WorkflowSpec(f"p{i}", 1, use_pool=True, datasets=(ds,), run_time_s=10.0)
        for i in range(4)
    ]
    assert orch._pool_wait_n == 0
    for s in specs:
        orch.submit(s)
    orch.engine.run()
    assert orch._pool_wait_n == 0                 # every pooled job ran
    # TTL elapsed with nothing waiting: the pool must have been reaped
    assert not mgr.active_pools
    assert mgr.stats.pools_retired == 1


def test_reap_events_coalesce_per_fire_time():
    orch = Orchestrator(dom_cluster())
    mgr = orch.enable_pools(ttl_s=100.0)
    mgr.create_pool(nodes=2)
    ds = DatasetRef("d", 5 * GB)
    # both leases release at the same virtual instant -> one pending reap
    specs = [
        WorkflowSpec(f"p{i}", 1, use_pool=True, datasets=(ds,), run_time_s=10.0)
        for i in range(2)
    ]
    for s in specs:
        orch.submit(s)
    orch.engine.run(until=30.0)
    assert all(j.done for j in orch.jobs)
    assert len(orch._reap_times) == len(set(orch._reap_times))
    assert len(orch._reap_times) <= 1
    orch.engine.run()
    assert not mgr.active_pools


def test_reap_holds_while_pool_job_still_queued():
    """A future-arrival pooled job must keep the TTL reaper from tearing
    the pool down (the old O(jobs) scan, now a counter)."""
    orch = Orchestrator(dom_cluster())
    mgr = orch.enable_pools(ttl_s=20.0)
    mgr.create_pool(nodes=2)
    ds = DatasetRef("d", 5 * GB)
    first = orch.submit(WorkflowSpec("now", 1, use_pool=True, datasets=(ds,),
                                     run_time_s=5.0))
    late = orch.submit(
        WorkflowSpec("late", 1, use_pool=True, datasets=(ds,), run_time_s=5.0),
        at=200.0,
    )
    orch.engine.run()
    assert first.done and late.done
    assert late.state.value == "done"
    assert late.dataset_hits == 1     # pool survived to serve the late job


def test_custom_fault_injector_subclass_is_always_consulted():
    """The fault-free hot-path bypass must apply only to the stock
    injector: a subclass overriding trip() fires even with a
    zero-probability spec."""

    class ScriptedFaults(FaultInjector):
        def trip(self, job_name, phase):
            return phase == "run" and job_name == "victim"

    orch = Orchestrator(dom_cluster(), faults=ScriptedFaults())
    victim = orch.submit(WorkflowSpec("victim", 1, run_time_s=5.0, max_retries=0))
    bystander = orch.submit(WorkflowSpec("ok", 1, run_time_s=5.0))
    orch.engine.run()
    assert victim.state.value == "failed" and victim.failure_phase == "run"
    assert bystander.state.value == "done"


# -- dispatch equivalence under custom (non-incremental) policies ------------
def test_custom_policy_falls_back_to_legacy_dispatch():
    class ReversePolicy(FIFOPolicy):
        incremental = False

        def order(self, queue, scheduler, now):
            return list(reversed(queue))

    orch = Orchestrator(dom_cluster(), policy=ReversePolicy())
    assert orch._dq is None           # legacy path selected automatically
    specs = [WorkflowSpec(f"j{i}", 2, run_time_s=5.0) for i in range(6)]
    jobs = orch.run_campaign(specs)
    assert all(j.done for j in jobs)


def test_forcing_incremental_with_legacy_policy_raises():
    class Custom(FIFOPolicy):
        incremental = False

    with pytest.raises(ValueError):
        Orchestrator(dom_cluster(), policy=Custom(), incremental=True)


def test_scheduler_epoch_bumps_on_grant_and_release():
    sched = Scheduler(dom_cluster())
    e0 = sched.epoch
    alloc = sched.submit(JobRequest("j", 2, storage=StorageRequest(nodes=1)))
    assert sched.epoch == e0 + 1
    sched.release(alloc)
    assert sched.epoch == e0 + 2


def test_stock_sizing_fast_path_matches_policy_arithmetic():
    sched = Scheduler(synthetic_cluster(4, 6))
    node = sched.cluster.storage_nodes[0]
    for cap in (1 * TB, 5 * TB, 23 * TB):
        expect = max(1, math.ceil(cap / sched.policy.node_capacity_bytes(node)))
        assert sched.resolve_storage_nodes(StorageRequest(capacity_bytes=cap)) == expect
