"""Scheduler invariants: no double allocation, release restores, sizing."""

import pytest

pytest.importorskip("hypothesis")
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core import (
    AllocationError,
    JobRequest,
    Scheduler,
    SizingPolicy,
    StorageRequest,
    dom_cluster,
    size_for_checkpoint,
)
from repro.core.resources import GB, TB


def test_basic_allocate_release():
    s = Scheduler(dom_cluster())
    a = s.submit(JobRequest("j1", 4, storage=StorageRequest(nodes=2)))
    assert len(a.compute_nodes) == 4 and len(a.storage_nodes) == 2
    assert s.free_counts() == (4, 2)
    s.release(a)
    assert s.free_counts() == (8, 4)
    with pytest.raises(AllocationError):
        s.release(a)  # double release


def test_exhaustion():
    s = Scheduler(dom_cluster())
    s.submit(JobRequest("j1", 8))
    with pytest.raises(AllocationError):
        s.submit(JobRequest("j2", 1))


def test_storage_requires_constraint():
    s = Scheduler(dom_cluster())
    with pytest.raises(AllocationError):
        s.submit(JobRequest("j", 1, storage=StorageRequest(nodes=1), constraint="mc"))


def test_capacity_sizing():
    """2 storage disks/node x 5.9 TB: 20 TB needs 2 nodes."""
    s = Scheduler(dom_cluster())
    n = s.resolve_storage_nodes(StorageRequest(capacity_bytes=20 * TB))
    assert n == 2


def test_capability_sizing():
    """Paper's capability notion (§V): 2 x 3.2 GB/s per node."""
    s = Scheduler(dom_cluster())
    assert s.resolve_storage_nodes(StorageRequest(capability_bw=6 * GB)) == 1
    assert s.resolve_storage_nodes(StorageRequest(capability_bw=12.8 * GB)) == 2
    assert s.resolve_storage_nodes(StorageRequest(capability_bw=13 * GB)) == 3


def test_checkpoint_sizing_helper():
    req = size_for_checkpoint(64 * GB, stall_budget_s=10, cluster=dom_cluster())
    s = Scheduler(dom_cluster())
    assert s.resolve_storage_nodes(req) == 1  # 6.4 GB/s within one node


def test_storage_request_validation():
    with pytest.raises(ValueError):
        StorageRequest()
    with pytest.raises(ValueError):
        StorageRequest(nodes=1, capacity_bytes=1.0)


@settings(max_examples=100, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 4), st.integers(0, 2)), max_size=12))
def test_property_no_double_allocation(jobs):
    """Random submit/release sequences never hand a node to two live jobs and
    always conserve inventory."""
    s = Scheduler(dom_cluster())
    live = []
    for n_c, n_s in jobs:
        try:
            a = s.submit(JobRequest(
                "j", n_c,
                storage=StorageRequest(nodes=n_s) if n_s else None,
            ))
            live.append(a)
        except AllocationError:
            if live:
                s.release(live.pop(0))
        # invariant: live allocations are disjoint
        seen = set()
        for al in s.live_allocations:
            ids = {n.node_id for n in al.compute_nodes + al.storage_nodes}
            assert not ids & seen
            seen |= ids
        free_c, free_s = s.free_counts()
        used_c = sum(len(a.compute_nodes) for a in s.live_allocations)
        used_s = sum(len(a.storage_nodes) for a in s.live_allocations)
        assert free_c + used_c == 8
        assert free_s + used_s == 4
