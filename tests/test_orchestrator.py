"""Orchestrator subsystem: engine, lifecycle, policies, metrics, campaigns."""

import time

import pytest

from repro.core import AllocationError, StorageRequest, dom_cluster
from repro.core.perfmodel import predict_deploy_time
from repro.orchestrator import (
    BackfillPolicy,
    FIFOPolicy,
    JobState,
    Orchestrator,
    SimEngine,
    StorageAwarePolicy,
    WorkflowSpec,
    format_report,
    job_breakdown,
    summarize,
)
from repro.runtime import FaultInjector, FaultSpec

GB = 1e9


# -- engine ------------------------------------------------------------------
def test_engine_orders_events():
    eng = SimEngine()
    fired = []
    eng.after(5.0, lambda: fired.append("b"))
    eng.after(1.0, lambda: fired.append("a"))
    eng.at(5.0, lambda: fired.append("c"))      # same time: insertion order
    assert eng.run() == 5.0
    assert fired == ["a", "b", "c"]


def test_engine_nested_scheduling_and_until():
    eng = SimEngine()
    fired = []

    def first():
        fired.append(eng.now)
        eng.after(10.0, lambda: fired.append(eng.now))

    eng.after(2.0, first)
    assert eng.run(until=5.0) == 5.0
    assert fired == [2.0]
    assert eng.run() == 12.0
    assert fired == [2.0, 12.0]


def test_engine_rejects_past_and_detects_loops():
    eng = SimEngine()
    eng.after(1.0, lambda: None)
    eng.run()
    with pytest.raises(ValueError):
        eng.at(0.5, lambda: None)

    def reschedule():
        eng.after(1.0, reschedule)

    eng.after(1.0, reschedule)
    with pytest.raises(RuntimeError):
        eng.run(max_events=100)


# -- single-job lifecycle ----------------------------------------------------
def test_single_job_walks_all_states():
    orch = Orchestrator(dom_cluster())
    job = orch.submit(
        WorkflowSpec("j", 4, StorageRequest(nodes=2),
                     stage_in_bytes=10 * GB, stage_out_bytes=5 * GB,
                     run_time_s=100.0)
    )
    orch.engine.run()
    states = [s for s, _ in job.history]
    assert states == [
        JobState.QUEUED, JobState.ALLOCATED, JobState.PROVISIONING,
        JobState.STAGING_IN, JobState.RUNNING, JobState.STAGING_OUT,
        JobState.TEARDOWN, JobState.DONE,
    ]
    times = [t for _, t in job.history]
    assert times == sorted(times)
    # provisioning advanced the clock by the C8 model (Dom, 3 targets/node)
    b = job_breakdown(job)
    assert b.phase_s[JobState.PROVISIONING] == pytest.approx(
        predict_deploy_time(3, runtime="shifter"), abs=1e-9
    )
    assert b.phase_s[JobState.RUNNING] == pytest.approx(100.0)
    assert b.phase_s[JobState.STAGING_IN] > 0
    assert b.phase_s[JobState.STAGING_OUT] > 0
    # nodes fully returned
    assert orch.scheduler.free_counts() == (8, 4)
    assert job.staged_in_bytes == 10 * GB and job.staged_out_bytes == 5 * GB


def test_job_without_storage_skips_staging():
    orch = Orchestrator(dom_cluster())
    job = orch.submit(WorkflowSpec("compute-only", 2, run_time_s=50.0))
    orch.engine.run()
    assert job.state is JobState.DONE
    b = job_breakdown(job)
    assert b.phase_s[JobState.PROVISIONING] == 0.0
    assert b.phase_s[JobState.STAGING_IN] == 0.0
    assert b.phase_s[JobState.RUNNING] == pytest.approx(50.0)


def test_infeasible_job_fails_fast_without_raising():
    orch = Orchestrator(dom_cluster())
    job = orch.submit(WorkflowSpec("too-big", 100, StorageRequest(nodes=2)))
    orch.engine.run()
    assert job.state is JobState.FAILED
    assert job.failure_phase == "infeasible"
    assert not orch.queue


# -- queueing (the fail-on-busy behavior is gone) ----------------------------
def test_busy_cluster_queues_instead_of_failing():
    orch = Orchestrator(dom_cluster())
    a = orch.submit(WorkflowSpec("a", 8, StorageRequest(nodes=4), run_time_s=100.0))
    b = orch.submit(WorkflowSpec("b", 8, StorageRequest(nodes=4), run_time_s=10.0))
    orch.engine.run()
    assert a.state is JobState.DONE and b.state is JobState.DONE
    # b could only start after a released everything
    b_alloc = next(t for s, t in b.history if s is JobState.ALLOCATED)
    a_done = next(t for s, t in a.history if s is JobState.DONE)
    assert b_alloc >= a_done


def test_fifo_head_of_line_blocks_but_backfill_overtakes():
    def specs():
        # both wide jobs need the whole storage pool; tiny is compute-only,
        # so under FIFO it still waits behind the blocked head
        return [
            WorkflowSpec("wide", 4, StorageRequest(nodes=4), run_time_s=100.0),
            WorkflowSpec("wide2", 4, StorageRequest(nodes=4), run_time_s=100.0),
            WorkflowSpec("tiny", 1, run_time_s=1.0),
        ]

    fifo = Orchestrator(dom_cluster(), policy=FIFOPolicy())
    fifo_jobs = fifo.run_campaign(specs())
    bf = Orchestrator(dom_cluster(), policy=BackfillPolicy())
    bf_jobs = bf.run_campaign(specs())

    def done_time(jobs, name):
        j = next(x for x in jobs if x.spec.name == name)
        return next(t for s, t in j.history if s is JobState.DONE)

    # FIFO: tiny waits behind both wide jobs; backfill: tiny slips through
    assert done_time(bf_jobs, "tiny") < done_time(fifo_jobs, "tiny")
    assert all(j.state is JobState.DONE for j in fifo_jobs + bf_jobs)


def test_storage_aware_prefers_small_storage_demand():
    orch = Orchestrator(dom_cluster(), policy=StorageAwarePolicy(aging_s=1e6))
    blocker = orch.submit(WorkflowSpec("blocker", 1, StorageRequest(nodes=4),
                                       run_time_s=10.0))
    # arrival order is big-then-small; storage-aware starts small first and
    # big (which needs the whole pool) must wait for small to drain
    big = orch.submit(WorkflowSpec("big", 1, StorageRequest(nodes=4), run_time_s=10.0))
    small = orch.submit(WorkflowSpec("small", 1, StorageRequest(nodes=1), run_time_s=10.0))
    orch.engine.run()
    assert all(j.state is JobState.DONE for j in (blocker, big, small))
    alloc = {
        j.spec.name: next(t for s, t in j.history if s is JobState.ALLOCATED)
        for j in (big, small)
    }
    assert alloc["small"] < alloc["big"]


# -- faults & retries --------------------------------------------------------
def test_fault_requeues_then_succeeds():
    faults = FaultInjector(FaultSpec(run_fail_p=0.5, seed=2))
    orch = Orchestrator(dom_cluster(), faults=faults)
    job = orch.submit(WorkflowSpec("f", 1, StorageRequest(nodes=1), max_retries=20))
    orch.engine.run()
    assert job.state is JobState.DONE
    if faults.trips:                           # retried at least once
        assert job.attempt == len(faults.trips)
        assert [s for s, _ in job.history].count(JobState.QUEUED) == job.attempt + 1


def test_fault_exhausts_retries_to_failed_and_releases_nodes():
    faults = FaultInjector(FaultSpec(run_fail_p=1.0, seed=3))
    orch = Orchestrator(dom_cluster(), faults=faults)
    job = orch.submit(WorkflowSpec("f", 2, StorageRequest(nodes=2), max_retries=1))
    orch.engine.run()
    assert job.state is JobState.FAILED
    assert job.attempt == 2                     # initial + 1 retry
    assert job.failure_phase == "run"
    assert orch.scheduler.free_counts() == (8, 4)
    # each attempt held (and returned) its storage nodes
    assert len(job.storage_intervals) == 2
    assert all(n == 2 for _, _, n in job.storage_intervals)


def test_retry_after_provision_fault_redeploys_fresh():
    """A provisioning fault means no tree ever landed: the retry pays the
    fresh deploy again, not the warm one."""
    faults = FaultInjector(FaultSpec(provision_fail_p=1.0, seed=4))
    orch = Orchestrator(dom_cluster(), faults=faults)
    job = orch.submit(WorkflowSpec("p", 1, StorageRequest(nodes=1), max_retries=1))
    orch.engine.run()
    assert job.state is JobState.FAILED
    prov_spans = [
        t1 - t0
        for (s0, t0), (_, t1) in zip(job.history, job.history[1:])
        if s0 is JobState.PROVISIONING
    ]
    assert len(prov_spans) == 2
    fresh = predict_deploy_time(3, fresh=True)
    assert all(d == pytest.approx(fresh) for d in prov_spans)


def test_retry_on_different_nodes_redeploys_fresh():
    """If another job grabbed the faulted job's nodes, the retry lands on a
    different (cold) node and must deploy fresh."""
    faults = FaultInjector(FaultSpec(run_fail_p=0.5, seed=6))
    orch = Orchestrator(dom_cluster(), policy=BackfillPolicy(), faults=faults)
    jobs = orch.run_campaign(
        [
            WorkflowSpec(f"j{i}", 1, StorageRequest(nodes=1),
                         run_time_s=10.0, max_retries=10)
            for i in range(12)
        ]
    )
    assert all(j.state is JobState.DONE for j in jobs)
    fresh = predict_deploy_time(3, fresh=True)
    warm = predict_deploy_time(3, fresh=False)
    for job in jobs:
        spans = [
            t1 - t0
            for (s0, t0), (_, t1) in zip(job.history, job.history[1:])
            if s0 is JobState.PROVISIONING
        ]
        # first deploy of any job is always fresh; later ones are warm only
        # on nodes it already deployed to
        assert spans[0] == pytest.approx(fresh)
        for d in spans[1:]:
            assert d == pytest.approx(fresh) or d == pytest.approx(warm)


def test_midcampaign_utilization_counts_open_allocations():
    orch = Orchestrator(dom_cluster())
    orch.submit(WorkflowSpec("long", 2, StorageRequest(nodes=4), run_time_s=1000.0))
    orch.engine.run(until=500.0)
    rep = summarize(orch.jobs, n_storage_nodes=4, now=orch.engine.now)
    assert rep.n_done == 0
    assert rep.storage_node_utilization > 0.9      # all 4 nodes busy so far


def test_total_retries_exact_for_exhausted_job():
    faults = FaultInjector(FaultSpec(run_fail_p=1.0, seed=8))
    orch = Orchestrator(dom_cluster(), faults=faults)
    jobs = orch.run_campaign(
        [WorkflowSpec("doomed", 1, StorageRequest(nodes=1), max_retries=0)]
    )
    rep = summarize(jobs, n_storage_nodes=4)
    assert rep.n_failed == 1
    assert rep.total_retries == 0                  # one attempt, zero retries
    assert rep.breakdowns[0].attempts == 1


def test_retry_redeploys_warm():
    faults = FaultInjector(FaultSpec(stage_in_fail_p=1.0, seed=5))
    orch = Orchestrator(dom_cluster(), faults=faults)
    job = orch.submit(WorkflowSpec("w", 1, StorageRequest(nodes=1),
                                   stage_in_bytes=GB, max_retries=1))
    orch.engine.run()
    prov_spans = []
    for (s0, t0), (_, t1) in zip(job.history, job.history[1:]):
        if s0 is JobState.PROVISIONING:
            prov_spans.append(t1 - t0)
    assert len(prov_spans) == 2
    assert prov_spans[0] == pytest.approx(predict_deploy_time(3, fresh=True))
    assert prov_spans[1] == pytest.approx(predict_deploy_time(3, fresh=False))
    assert prov_spans[1] < prov_spans[0]


# -- acceptance campaign -----------------------------------------------------
@pytest.mark.parametrize("policy_cls", [FIFOPolicy, BackfillPolicy, StorageAwarePolicy])
def test_campaign_100plus_jobs_oversubscribed(policy_cls):
    """>=100 jobs demanding far more storage than the 4 free nodes: no
    AllocationError escapes, everything queues and finishes, metrics report
    the breakdowns, and the event engine keeps wallclock tiny."""
    cluster = dom_cluster()
    faults = FaultInjector(
        FaultSpec(provision_fail_p=0.02, stage_in_fail_p=0.02, run_fail_p=0.01, seed=11)
    )
    orch = Orchestrator(cluster, policy=policy_cls(), faults=faults)
    specs = [
        WorkflowSpec(
            name=f"job{i:03d}",
            n_compute=1 + i % 4,
            storage=StorageRequest(nodes=1 + i % 3),
            stage_in_bytes=(4 + 12 * (i % 5)) * GB,
            stage_out_bytes=(1 + 3 * (i % 3)) * GB,
            run_time_s=20.0 + 10.0 * (i % 6),
            max_retries=5,
        )
        for i in range(120)
    ]
    t0 = time.perf_counter()
    jobs = orch.run_campaign(specs)
    wallclock = time.perf_counter() - t0

    assert len(jobs) == 120
    assert all(j.state is JobState.DONE for j in jobs)
    assert not orch.queue
    assert orch.scheduler.free_counts() == (8, 4)

    rep = summarize(jobs, n_storage_nodes=len(cluster.storage_nodes))
    assert rep.n_done == 120 and rep.n_failed == 0
    # oversubscription showed up as real queueing and real virtual time
    assert rep.max_queue_wait_s > 0
    assert rep.makespan_s > 1000.0
    assert 0.0 < rep.storage_node_utilization <= 1.0
    # >= because a job that trips after a successful stage-in re-stages on retry
    assert rep.staged_in_bytes >= sum(s.stage_in_bytes for s in specs)
    # per-job breakdowns cover the whole pipeline
    for b in rep.breakdowns:
        assert b.phase_s[JobState.RUNNING] > 0
        assert b.total_s >= b.phase_s[JobState.RUNNING]
    # the virtual campaign must simulate fast
    assert wallclock < 5.0
    assert "storage-node utilization" in format_report(rep)


def test_campaign_metrics_consistency():
    orch = Orchestrator(dom_cluster(), policy=BackfillPolicy())
    jobs = orch.run_campaign(
        [
            WorkflowSpec(f"j{i}", 2, StorageRequest(nodes=2),
                         stage_in_bytes=GB, run_time_s=10.0)
            for i in range(8)
        ]
    )
    rep = summarize(jobs, n_storage_nodes=4)
    for b in rep.breakdowns:
        assert b.total_s == pytest.approx(sum(b.phase_s.values()), rel=1e-9)
    # two 2-node jobs fit at once; utilization reflects overlap, not serial sum
    assert rep.storage_node_utilization <= 1.0


def test_try_submit_never_escapes_allocation_error_when_feasible():
    orch = Orchestrator(dom_cluster())
    # saturate, then submit a feasible job: must queue, not raise
    orch.submit(WorkflowSpec("sat", 8, StorageRequest(nodes=4), run_time_s=5.0))
    job = orch.submit(WorkflowSpec("q", 8, StorageRequest(nodes=4), run_time_s=5.0))
    orch.engine.run()
    assert job.state is JobState.DONE


def test_spec_validation():
    with pytest.raises(ValueError):
        WorkflowSpec("bad", 1, stage_in_bytes=GB)          # staging w/o storage
    with pytest.raises(ValueError):
        WorkflowSpec("bad", 1, run_time_s=-1.0)
    with pytest.raises(ValueError):
        WorkflowSpec("bad", 1, max_retries=-1)


# -- metrics edge cases (PR 6 satellites) -------------------------------------
def test_summarize_empty_campaign_raises():
    with pytest.raises(ValueError, match="no jobs"):
        summarize([], n_storage_nodes=4)


def test_breakdown_and_summarize_with_running_job_at_horizon():
    orch = Orchestrator(dom_cluster())
    job = orch.submit(
        WorkflowSpec("longrun", 2, StorageRequest(nodes=2), run_time_s=500.0)
    )
    orch.engine.run(until=100.0)
    now = orch.engine.now
    assert job.state is JobState.RUNNING
    b = job_breakdown(job, now)
    # the open RUNNING phase is charged up to the poll instant
    assert b.phase_s[JobState.RUNNING] > 0
    assert b.total_s == pytest.approx(now - job.submit_time)
    assert b.total_s == pytest.approx(sum(b.phase_s.values()), rel=1e-9)
    rep = summarize([job], n_storage_nodes=4, now=now)
    assert rep.n_done == 0 and rep.n_failed == 0
    assert rep.makespan_s == pytest.approx(now - job.submit_time)
    assert rep.storage_node_utilization > 0     # open allocation counts busy
    # without now= the open phase is simply not charged — no crash
    b0 = job_breakdown(job)
    assert b0.phase_s[JobState.RUNNING] == 0.0
    orch.engine.run()
    assert job.state is JobState.DONE


def test_format_report_top_n_zero_lists_no_jobs():
    orch = Orchestrator(dom_cluster())
    jobs = orch.run_campaign(
        [WorkflowSpec(f"j{i}", 1, StorageRequest(nodes=1), run_time_s=5.0)
         for i in range(3)]
    )
    rep = summarize(jobs, n_storage_nodes=4)
    text = format_report(rep, top_n=0)
    assert "slowest 0 jobs:" in text
    assert text.splitlines()[-1] == "slowest 0 jobs:"     # nothing after it
    assert "j0" not in text
