"""Arrival processes: seeded Poisson generation, trace replay, and per-job
submit times flowing through Orchestrator campaigns."""

import pytest

from repro.core import StorageRequest, dom_cluster
from repro.orchestrator import (
    JobState,
    Orchestrator,
    WorkflowSpec,
    burst_arrivals,
    diurnal_arrivals,
    exponential_interarrivals,
    mean_interarrival,
    poisson_arrivals,
    replay_trace,
)

GB = 1e9


def test_poisson_is_seeded_and_monotone():
    a = poisson_arrivals(0.5, 50, seed=7)
    b = poisson_arrivals(0.5, 50, seed=7)
    c = poisson_arrivals(0.5, 50, seed=8)
    assert a == b                      # deterministic for a seed
    assert a != c                      # and the seed matters
    assert len(a) == 50
    assert all(t >= 0 for t in a)
    assert a == sorted(a)


def test_poisson_mean_matches_rate():
    rate = 0.25
    times = poisson_arrivals(rate, 4000, seed=3)
    assert mean_interarrival(times) == pytest.approx(1 / rate, rel=0.1)


def test_interarrivals_validation():
    with pytest.raises(ValueError):
        exponential_interarrivals(0.0, 5)
    with pytest.raises(ValueError):
        exponential_interarrivals(1.0, -1)
    with pytest.raises(ValueError):
        poisson_arrivals(1.0, 5, start=-1.0)
    assert exponential_interarrivals(1.0, 0) == []


def test_replay_trace_sorts_shifts_and_validates():
    assert replay_trace([5.0, 1.0, 3.0]) == [1.0, 3.0, 5.0]
    assert replay_trace([1.0, 2.0], start=10.0) == [11.0, 12.0]
    assert replay_trace([]) == []
    with pytest.raises(ValueError):
        replay_trace([-0.5, 1.0])


def test_diurnal_is_seeded_and_monotone():
    kw = dict(base_rate=0.5, peak_rate=2.0, period_s=1200.0)
    a = diurnal_arrivals(200, seed=7, **kw)
    b = diurnal_arrivals(200, seed=7, **kw)
    c = diurnal_arrivals(200, seed=8, **kw)
    assert a == b                      # same seed -> identical times
    assert a != c
    assert len(a) == 200
    assert a == sorted(a) and a[0] >= 0


def test_diurnal_mean_rate_matches_profile():
    """Empirical rate over the generated span tracks the analytic mean of
    the sinusoidal profile over the same span (within sampling tolerance)."""
    import math

    base, peak, period = 0.5, 2.0, 2000.0
    times = diurnal_arrivals(
        4000, base_rate=base, peak_rate=peak, period_s=period, seed=3
    )
    span = times[-1]
    # integral of base + (peak-base)*(1 - cos(2*pi*t/period))/2 over [0, span]
    amp = (peak - base) / 2.0
    expected = (base + amp) * span - amp * (period / (2 * math.pi)) * math.sin(
        2 * math.pi * span / period
    )
    assert len(times) == pytest.approx(expected, rel=0.1)


def test_diurnal_peaks_mid_period():
    """Arrivals bunch at mid-period (the rate crest), thin at the edges."""
    period = 1000.0
    times = diurnal_arrivals(
        3000, base_rate=0.2, peak_rate=4.0, period_s=period, seed=9
    )
    in_first = [t % period for t in times]
    crest = sum(1 for t in in_first if period / 4 <= t < 3 * period / 4)
    trough = len(in_first) - crest
    assert crest > 2 * trough


def test_burst_is_seeded_and_concentrated():
    kw = dict(base_rate=0.1, burst_rate=5.0, burst_t0=100.0, burst_t1=200.0)
    a = burst_arrivals(300, seed=5, **kw)
    assert a == burst_arrivals(300, seed=5, **kw)
    assert a == sorted(a)
    in_burst = [t for t in a if 100.0 <= t < 200.0]
    # the draw stops at n arrivals, mid-burst: nearly everything after the
    # slow 0.1/s lead-in lands inside the window
    assert len(in_burst) > 0.7 * len(a)
    # in-window empirical rate (over the span actually observed) tracks
    # burst_rate, not base_rate
    observed_span = in_burst[-1] - 100.0
    assert len(in_burst) / observed_span == pytest.approx(5.0, rel=0.15)


def test_profile_arrivals_validation():
    with pytest.raises(ValueError):
        diurnal_arrivals(10, base_rate=2.0, peak_rate=1.0)    # peak < base
    with pytest.raises(ValueError):
        diurnal_arrivals(10, base_rate=0.5, peak_rate=1.0, period_s=0.0)
    with pytest.raises(ValueError):
        burst_arrivals(10, base_rate=1.0, burst_rate=2.0,
                       burst_t0=50.0, burst_t1=50.0)          # empty window
    with pytest.raises(ValueError):
        burst_arrivals(10, base_rate=0.0, burst_rate=2.0,
                       burst_t0=0.0, burst_t1=10.0)
    assert diurnal_arrivals(0, base_rate=0.5, peak_rate=1.0) == []


def test_campaign_honors_submit_times():
    orch = Orchestrator(dom_cluster())
    times = [0.0, 100.0, 250.0]
    specs = [
        WorkflowSpec(f"j{i}", 1, StorageRequest(nodes=1), run_time_s=5.0)
        for i in range(3)
    ]
    jobs = orch.run_campaign(specs, submit_times=times)
    assert all(j.state is JobState.DONE for j in jobs)
    for job, t in zip(jobs, times):
        assert job.submit_time == t
        queued_at = next(tt for s, tt in job.history if s is JobState.QUEUED)
        assert queued_at == t
    # nothing queued: each job starts at its own arrival
    assert all(
        next(tt for s, tt in j.history if s is JobState.ALLOCATED) == j.submit_time
        for j in jobs
    )


def test_submit_times_length_mismatch_raises():
    orch = Orchestrator(dom_cluster())
    with pytest.raises(ValueError):
        orch.run_campaign(
            [WorkflowSpec("j", 1, run_time_s=1.0)], submit_times=[0.0, 1.0]
        )


def test_poisson_campaign_spreads_queueing():
    """The same workload arriving as a Poisson stream waits less than the
    batch-at-zero burst (the whole point of modeling arrivals)."""
    def specs():
        return [
            WorkflowSpec(f"j{i}", 2, StorageRequest(nodes=2), run_time_s=30.0)
            for i in range(40)
        ]

    burst = Orchestrator(dom_cluster())
    burst_jobs = burst.run_campaign(specs())
    spread = Orchestrator(dom_cluster())
    spread_jobs = spread.run_campaign(
        specs(), submit_times=poisson_arrivals(0.02, 40, seed=5)
    )
    assert all(j.state is JobState.DONE for j in burst_jobs + spread_jobs)

    def mean_wait(jobs):
        waits = []
        for j in jobs:
            q = next(t for s, t in j.history if s is JobState.QUEUED)
            a = next(t for s, t in j.history if s is JobState.ALLOCATED)
            waits.append(a - q)
        return sum(waits) / len(waits)

    assert mean_wait(spread_jobs) < mean_wait(burst_jobs)
