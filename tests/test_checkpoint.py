"""Checkpoint manager: sharded save/restore, two-phase commit, drain, GC."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.core import EphemeralFS, FSError, GlobalFS, dom_cluster


@pytest.fixture
def burst(tmp_path):
    fs = EphemeralFS(dom_cluster().storage_nodes[:2], str(tmp_path / "b"))
    yield fs
    fs.teardown()


def _tree(x=0.0):
    return {
        "params": {"w": jnp.full((8, 4), 1.0 + x), "b": jnp.zeros((4,))},
        "opt": {"m": jnp.full((8, 4), 0.5 * x), "step": jnp.int32(int(x))},
    }


def test_save_restore_equality(burst):
    mgr = CheckpointManager(burst)
    t = _tree(3.0)
    mgr.save(100, t)
    restored, step = mgr.restore(_tree())
    assert step == 100
    for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(t)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_committed_wins(burst):
    mgr = CheckpointManager(burst)
    mgr.save(1, _tree(1.0))
    mgr.save(2, _tree(2.0))
    restored, step = mgr.restore(_tree())
    assert step == 2
    assert float(restored["params"]["w"][0, 0]) == 3.0


def test_uncommitted_checkpoint_ignored(burst):
    """Simulate a crash between data write and COMMIT: the step must be
    invisible to restore (two-phase commit)."""
    mgr = CheckpointManager(burst)
    mgr.save(1, _tree(1.0))
    mgr.save(2, _tree(2.0))
    burst.unlink(f"{mgr.root}/step-{2:08d}/COMMIT")   # 'crash' before commit
    assert mgr.steps() == [1]
    _, step = mgr.restore(_tree())
    assert step == 1


def test_gc_keeps_last_k(burst):
    mgr = CheckpointManager(burst, keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree(float(s)))
    assert mgr.steps() == [3, 4]


def test_restore_specific_step(burst):
    mgr = CheckpointManager(burst, keep=5)
    mgr.save(1, _tree(1.0))
    mgr.save(2, _tree(2.0))
    restored, step = mgr.restore(_tree(), step=1)
    assert step == 1 and float(restored["params"]["w"][0, 0]) == 2.0
    with pytest.raises(FSError):
        mgr.restore(_tree(), step=99)


def test_no_checkpoints_raises(burst):
    mgr = CheckpointManager(burst)
    with pytest.raises(FSError):
        mgr.restore(_tree())


def test_drain_to_global(burst, tmp_path):
    gfs = GlobalFS(str(tmp_path / "g"))
    mgr = CheckpointManager(burst, global_fs=gfs)
    man = mgr.save(7, _tree(7.0))
    rep = mgr.drain_to_global(7)
    assert rep["bytes"] >= man["total_bytes"]
    # restore from the DRAINED copy via a fresh manager on the global fs
    mgr2 = CheckpointManager(gfs, root="/persist/ckpt")
    restored, step = mgr2.restore(_tree())
    assert step == 7 and float(restored["params"]["w"][0, 0]) == 8.0
    gfs.teardown()


def test_file_per_shard_layout(burst):
    """The paper's C3 finding drives the layout: one object per leaf, not a
    single shared file."""
    mgr = CheckpointManager(burst)
    mgr.save(1, _tree())
    files = burst.readdir(f"{mgr.root}/step-{1:08d}")
    npys = [f for f in files if f.endswith(".npy")]
    assert len(npys) == 4  # one per leaf
