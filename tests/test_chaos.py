"""PR 9 chaos suite: fault model, backoff, blast radius, healing, replay.

Five pillars:

* unit coverage for the ``repro.chaos`` package — `NodeFaultModel`
  determinism/validation, `RetryPolicy` delay sequences, `drive_retries`
  cadence over a `SimEngine`, and the duck-typed blast-radius resolver;
* the scheduler's failure domain — free nodes park immediately, nodes
  inside live allocations park on release, repairs restore the free
  pool, and the availability gauge tracks both;
* degradation semantics — a mirrored session survives one loss at
  halved effective bandwidth; everything else refuses to degrade;
* pool self-healing — node loss invalidates residency and shrinks the
  ledger only by what surviving hardware can't cover; backfill and
  repair each restore exactly the deducted share, never both;
* determinism regressions — a 500-job campaign under random MTTF draws
  plus scripted kills replays bit-identically through the legacy and
  indexed dispatchers (and run-to-run with tracing on), and an armed
  but empty fault model perturbs nothing.
"""

import random

import pytest

from repro.chaos import (
    NodeEvent,
    NodeFaultModel,
    RetryPolicy,
    drive_retries,
    resolve_blast_radius,
)
from repro.core import (
    AllocationError,
    JobRequest,
    Scheduler,
    StorageRequest,
    dom_cluster,
    synthetic_cluster,
)
from repro.orchestrator import (
    BackfillPolicy,
    JobState,
    Orchestrator,
    SimEngine,
    WorkflowSpec,
)
from repro.pool import DatasetRef
from repro.provision import (
    Placement,
    ProvisioningService,
    SessionError,
    StorageSpec,
)
from repro.runtime import FaultInjector, FaultSpec, HeartbeatMonitor

GB = 1e9


# -- NodeFaultModel -----------------------------------------------------------

def test_fault_model_events_deterministic_and_sorted():
    nodes = [f"sn{i:05d}" for i in range(5)]
    kw = dict(mttf_s=500.0, mttr_s=120.0, horizon_s=2000.0, seed=7,
              schedule=((100.0, "sn00002"),))
    a = NodeFaultModel(nodes, **kw).events()
    b = NodeFaultModel(list(reversed(nodes)), **kw).events()
    assert a and a == b
    keys = [(e.t, e.node_id, 0 if e.kind == "up" else 1) for e in a]
    assert keys == sorted(keys)
    # every down is followed by its node's up exactly mttr later
    downs = [(e.t, e.node_id) for e in a if e.kind == "down"]
    ups = {(e.t, e.node_id) for e in a if e.kind == "up"}
    assert all((t + 120.0, nid) in ups for t, nid in downs)


def test_fault_model_per_node_streams_independent():
    """Adding a node to the domain never perturbs another node's draws."""
    kw = dict(mttf_s=400.0, mttr_s=100.0, horizon_s=3000.0, seed=3)
    small = NodeFaultModel(["a", "b"], **kw).events()
    big = NodeFaultModel(["a", "b", "c"], **kw).events()
    assert [e for e in small if e.node_id == "a"] == [
        e for e in big if e.node_id == "a"
    ]


def test_fault_model_validation():
    with pytest.raises(ValueError, match="unknown node"):
        NodeFaultModel(["a"], schedule=((1.0, "b"),))
    with pytest.raises(ValueError, match="negative time"):
        NodeFaultModel(["a"], schedule=((-1.0, "a"),))
    with pytest.raises(ValueError, match="horizon_s"):
        NodeFaultModel(["a"], mttf_s=100.0)
    with pytest.raises(ValueError, match="mttr_s"):
        NodeFaultModel(["a"], mttr_s=0.0)
    with pytest.raises(ValueError, match="kind"):
        NodeEvent(1.0, "a", "sideways")


def test_fault_model_any_faults_gates_chaos_off():
    assert not NodeFaultModel(["a", "b"]).any_faults
    assert NodeFaultModel(["a"], schedule=((1.0, "a"),)).any_faults
    assert NodeFaultModel(["a"], mttf_s=10.0, horizon_s=1.0).any_faults


# -- RetryPolicy + drive_retries ---------------------------------------------

def test_retry_delays_deterministic_and_bounded():
    p = RetryPolicy(max_attempts=5, base_s=10.0, factor=2.0,
                    max_delay_s=60.0, jitter=0.1, seed=4)
    d = p.delays("pool1:sn00003")
    assert d == p.delays("pool1:sn00003")
    assert d != p.delays("pool1:sn00004")
    assert len(d) == 5
    for i, w in enumerate(d):
        base = min(10.0 * 2.0**i, 60.0)
        assert base <= w <= base * 1.1


def test_retry_deadline_truncates_sequence():
    p = RetryPolicy(max_attempts=6, base_s=10.0, factor=2.0,
                    max_delay_s=300.0, jitter=0.0, deadline_s=35.0)
    assert p.delays("k") == (10.0, 20.0)       # 10+20=30 <= 35; +40 > 35
    tight = RetryPolicy(base_s=10.0, jitter=0.0, deadline_s=5.0)
    assert tight.delays("k") == ()


def test_retry_policy_validation():
    with pytest.raises(ValueError):
        RetryPolicy(max_attempts=0)
    with pytest.raises(ValueError):
        RetryPolicy(factor=0.5)
    with pytest.raises(ValueError):
        RetryPolicy(base_s=10.0, max_delay_s=5.0)
    with pytest.raises(ValueError):
        RetryPolicy(jitter=2.0)


def test_drive_retries_cadence_and_success_stop():
    eng = SimEngine()
    p = RetryPolicy(max_attempts=4, base_s=10.0, factor=2.0,
                    max_delay_s=100.0, jitter=0.0)
    calls = []

    def attempt():
        calls.append(eng.now)
        return len(calls) >= 2          # second try succeeds

    drive_retries(eng, p, "k", attempt)
    eng.run()
    # first attempt itself waits delays[0]: a failure was just observed now
    assert calls == [10.0, 30.0]


def test_drive_retries_gives_up_after_exhaustion():
    eng = SimEngine()
    p = RetryPolicy(max_attempts=3, base_s=10.0, factor=2.0,
                    max_delay_s=100.0, jitter=0.0)
    calls, gave = [], []
    drive_retries(eng, p, "k", lambda: (calls.append(eng.now), False)[1],
                  give_up=lambda: gave.append(eng.now))
    eng.run()
    assert calls == [10.0, 30.0, 70.0]
    assert gave == [70.0]


# -- blast radius -------------------------------------------------------------

class _Node:
    def __init__(self, nid):
        self.node_id = nid


class _Alloc:
    def __init__(self, *nids):
        self.storage_nodes = tuple(_Node(n) for n in nids)


class _Lease:
    def __init__(self, pool_id):
        self.pool_id = pool_id


class _Pool:
    def __init__(self, pool_id, *nids, leases=()):
        self.pool_id = pool_id
        self.storage_node_ids = set(nids)
        self.leases = {i: lease for i, lease in enumerate(leases)}


class _Session:
    def __init__(self, allocation=None, pool=None, lease=None):
        self.allocation = allocation
        self.pool = pool
        self.lease = lease


class _Replica:
    def __init__(self, session):
        self.session = session


def test_blast_radius_fans_out_over_sessions_pools_replicas():
    lease = _Lease(pool_id=1)
    hit_pool = _Pool(1, "sn0", "sn1", leases=(lease,))
    other_pool = _Pool(2, "sn2")
    direct = _Session(allocation=_Alloc("sn0", "sn3"))
    via_lease = _Session(lease=_Lease(pool_id=1))
    unrelated = _Session(allocation=_Alloc("sn4"))
    r_hit = _Replica(_Session(lease=_Lease(pool_id=1)))
    r_safe = _Replica(_Session(lease=_Lease(pool_id=2)))

    br = resolve_blast_radius(
        "sn0",
        sessions=[direct, via_lease, unrelated],
        pools=[hit_pool, other_pool],
        replicas=[r_hit, r_safe],
    )
    assert br.sessions == (direct, via_lease)
    assert br.pools == (hit_pool,)
    assert br.leases == (lease,)
    assert br.replicas == (r_hit,)
    assert not br.empty
    assert resolve_blast_radius("sn9", sessions=[direct], pools=[hit_pool]).empty


# -- scheduler failure domain -------------------------------------------------

def test_scheduler_parks_free_node_and_repairs_it():
    s = Scheduler(synthetic_cluster(4, 3))
    assert s.healthy_capacity_fraction == 1.0
    assert s.mark_node_down("sn00002") is True       # free: parked now
    assert s.free_counts()[1] == 2
    assert s.down_storage_nodes == frozenset({"sn00002"})
    assert s.healthy_capacity_fraction == pytest.approx(2 / 3)
    with pytest.raises(AllocationError):
        s.submit(JobRequest("j", 1, storage=StorageRequest(nodes=3)))
    assert s.mark_node_up("sn00002") is True
    assert s.healthy_capacity_fraction == 1.0
    a = s.submit(JobRequest("j", 1, storage=StorageRequest(nodes=3)))
    s.release(a)


def test_scheduler_parks_allocated_node_on_release():
    s = Scheduler(synthetic_cluster(4, 3))
    a = s.submit(JobRequest("j", 1, storage=StorageRequest(nodes=2)))
    held = a.storage_nodes[0].node_id
    assert s.mark_node_down(held) is False            # pending until release
    assert held in s.down_storage_nodes
    assert s.healthy_capacity_fraction == pytest.approx(2 / 3)
    s.release(a)
    assert s.free_counts()[1] == 2                    # parked, not freed
    assert s.mark_node_up(held) is True
    assert s.free_counts()[1] == 3


def test_scheduler_repair_before_release_unflags():
    s = Scheduler(synthetic_cluster(4, 3))
    a = s.submit(JobRequest("j", 1, storage=StorageRequest(nodes=2)))
    held = a.storage_nodes[0].node_id
    s.mark_node_down(held)
    assert s.mark_node_up(held) is False              # unflagged, still held
    s.release(a)
    assert s.free_counts()[1] == 3                    # freed normally


def test_scheduler_down_validation_and_idempotence():
    s = Scheduler(synthetic_cluster(4, 3))
    with pytest.raises(AllocationError):
        s.mark_node_down("sn99999")
    assert s.mark_node_down("sn00000") is True
    assert s.mark_node_down("sn00000") is True        # idempotent
    assert s.mark_node_up("sn00000") is True
    assert s.mark_node_up("sn00000") is False         # not down: no-op


# -- degradation semantics ----------------------------------------------------

def test_mirrored_session_degrades_to_half_bandwidth():
    svc = ProvisioningService(dom_cluster())
    s = svc.open_session(
        StorageSpec("m", nodes=2, managers=("ephemeralfs",),
                    placement=Placement(mirror=True), stage_in_bytes=20 * GB)
    )
    assert s.redundancy == "mirror"
    assert s.can_degrade
    healthy = s.stage_in_time_s
    s.degrade()
    assert s.degraded
    assert s.stage_in_time_s == pytest.approx(2.0 * healthy)
    assert s.checkpoint_write_s(1 * GB) > 0
    assert not s.can_degrade                          # second loss is fatal
    with pytest.raises(SessionError, match="no redundancy left"):
        s.degrade()
    s.release()


def test_unmirrored_session_cannot_degrade():
    svc = ProvisioningService(dom_cluster())
    s = svc.open_session(
        StorageSpec("p", nodes=2, managers=("ephemeralfs",))
    )
    assert s.redundancy == "none"
    assert not s.can_degrade
    with pytest.raises(SessionError):
        s.degrade()
    s.release()


# -- pool self-healing --------------------------------------------------------

def _pool_orch(n_storage=4):
    orch = Orchestrator(synthetic_cluster(4, n_storage))
    return orch, orch.enable_pools(ttl_s=None)


def test_pool_quota_below_hardware_loses_nothing_but_degrades():
    orch, mgr = _pool_orch()
    pool = mgr.create_pool(nodes=2, cap_bytes=100 * GB)
    dead = sorted(pool.storage_node_ids)[0]
    mgr.on_node_down(pool, dead)
    assert pool.degraded
    assert pool.dead_node_capacity == {dead: 0.0}     # survivor covers quota
    assert pool.capacity_bytes == 100 * GB
    assert dead not in pool.storage_node_ids
    assert mgr.affected_pools(dead) == ()             # no longer backing it


def test_pool_loss_above_surviving_hardware_shrinks_ledger():
    orch, mgr = _pool_orch()
    cap = orch.scheduler.policy.node_capacity_bytes
    pool = mgr.create_pool(nodes=2)                   # ledger = full hardware
    nodes = pool.allocation.storage_nodes
    full = pool.capacity_bytes
    dead = nodes[0].node_id
    mgr.on_node_down(pool, dead)
    survivor_hw = sum(cap(n) for n in nodes[1:])
    assert pool.capacity_bytes == pytest.approx(survivor_hw)
    assert pool.dead_node_capacity[dead] == pytest.approx(full - survivor_hw)
    mgr.on_node_repair(dead)
    assert pool.capacity_bytes == pytest.approx(full)
    assert not pool.degraded


def test_pool_backfill_replaces_dead_node_and_repair_keeps_spare():
    orch, mgr = _pool_orch()
    pool = mgr.create_pool(nodes=2, cap_bytes=100 * GB)
    dead = sorted(pool.storage_node_ids)[0]
    mgr.on_node_down(pool, dead)
    orch.scheduler.mark_node_down(dead)               # the chaos engine's order
    assert mgr.backfill(pool) is True
    assert dead in pool.replaced_node_ids
    assert len(pool.extra_allocations) == 1
    assert not pool.degraded
    assert pool.capacity_bytes == 100 * GB
    # the chassis repairing later must not double-restore the share
    orch.scheduler.mark_node_up(dead)
    mgr.on_node_repair(dead)
    assert pool.capacity_bytes == 100 * GB
    assert len(pool.extra_allocations) == 1


def test_pool_backfill_without_free_nodes_waits_for_repair():
    orch, mgr = _pool_orch(n_storage=2)
    pool = mgr.create_pool(nodes=2, cap_bytes=100 * GB)
    dead = sorted(pool.storage_node_ids)[0]
    orch.scheduler.mark_node_down(dead)
    mgr.on_node_down(pool, dead)
    assert mgr.backfill(pool) is False                # cluster has no spare
    assert pool.degraded
    mgr.on_node_repair(dead)
    assert not pool.degraded
    assert pool.capacity_bytes == 100 * GB


# -- fault.py satellites ------------------------------------------------------

def test_fault_injector_trip_rejects_unknown_phase():
    inj = FaultInjector(FaultSpec(run_fail_p=1.0, seed=1))
    assert inj.trip("j", "run") is True
    with pytest.raises(ValueError, match="valid phases are"):
        inj.trip("j", "bogus")


def test_heartbeat_revive_resets_state():
    t = [0.0]
    mon = HeartbeatMonitor(["n0", "n1"], timeout_s=10.0, clock=lambda: t[0])
    mon.beat("n0", step_time_s=5.0)
    t[0] = 50.0
    assert sorted(mon.dead_nodes()) == ["n0", "n1"]
    mon.revive("n0")
    assert mon.nodes["n0"].alive
    assert mon.nodes["n0"].step_times == []           # stale latencies dropped
    assert mon.dead_nodes() == ["n1"]


def test_stragglers_exclude_timed_out_nodes():
    t = [0.0]
    nodes = [f"n{i}" for i in range(4)] + ["slow"]
    mon = HeartbeatMonitor(nodes, timeout_s=10.0, clock=lambda: t[0])
    for _ in range(6):
        for n in nodes:
            mon.beat(n, step_time_s=50.0 if n == "slow" else 1.0)
    t[0] = 5.0
    assert mon.stragglers(now=5.0) == ["slow"]        # alive and slow: flagged
    for n in nodes:
        if n != "slow":
            mon.beat(n, now=95.0)
    # "slow" stopped beating: it is dead, not a straggler, and its samples
    # must not drag the fleet median
    assert mon.stragglers(now=100.0) == []
    assert mon.dead_nodes(100.0) == ["slow"]


# -- orchestrator integration -------------------------------------------------

def test_enable_chaos_rejects_unknown_nodes():
    orch = Orchestrator(synthetic_cluster(4, 2))
    model = NodeFaultModel(["sn00000", "ghost"], schedule=((1.0, "sn00000"),))
    with pytest.raises(ValueError, match="unknown storage nodes"):
        orch.enable_chaos(model)


def _mini_campaign(*, mirror, chaos=True):
    from repro.obs import TraceRecorder

    rec = TraceRecorder()
    orch = Orchestrator(synthetic_cluster(8, 4), policy=BackfillPolicy(),
                        recorder=rec)
    if chaos:
        orch.enable_chaos(NodeFaultModel(
            [n.node_id for n in orch.scheduler.cluster.storage_nodes],
            mttr_s=300.0, schedule=((60.0, "sn00000"),),
        ))
    specs = [
        WorkflowSpec(
            f"j{i}", 1 + i % 2,
            storage_spec=StorageSpec(
                f"j{i}", nodes=2, managers=("ephemeralfs",),
                placement=Placement(mirror=mirror),
                stage_in_bytes=10 * GB, stage_out_bytes=1 * GB,
            ),
            run_time_s=100.0, max_retries=4,
        )
        for i in range(6)
    ]
    jobs = orch.run_campaign(specs, submit_times=[i * 1.0 for i in range(6)])
    return jobs, rec, orch


def test_kill_degrades_mirrored_jobs_in_place():
    jobs, rec, orch = _mini_campaign(mirror=True)
    assert all(j.state is JobState.DONE for j in jobs)
    assert rec.counts.get("chaos.node_downs", 0) == 1
    assert rec.counts.get("chaos.node_repairs", 0) == 1
    assert rec.counts.get("chaos.degraded", 0) >= 1
    assert rec.counts.get("fault.requeued", 0) == 0   # nobody restarted
    assert orch.scheduler.healthy_capacity_fraction == 1.0
    assert not orch.scheduler.down_storage_nodes


def test_kill_requeues_unmirrored_jobs():
    jobs, rec, orch = _mini_campaign(mirror=False)
    assert all(j.state is JobState.DONE for j in jobs)
    assert rec.counts.get("chaos.degraded", 0) == 0
    assert rec.counts.get("fault.requeued", 0) >= 1   # the loss restarts them
    assert orch.scheduler.healthy_capacity_fraction == 1.0


# -- determinism regressions --------------------------------------------------

def _chaos_specs(seed, n):
    rng = random.Random(seed)
    ds = [DatasetRef(f"d{k}", (8.0 + 3.0 * k) * GB) for k in range(3)]
    specs = []
    for i in range(n):
        name = f"job{i:03d}"
        r = rng.random()
        if r < 0.35:
            storage = StorageSpec(
                name, nodes=2, managers=("ephemeralfs",),
                placement=Placement(mirror=True),
                stage_in_bytes=rng.uniform(4, 16) * GB,
                stage_out_bytes=rng.uniform(0, 4) * GB,
            )
            spec = WorkflowSpec(name, rng.randint(1, 4), storage_spec=storage,
                                run_time_s=rng.uniform(20, 90), max_retries=6)
        elif r < 0.55:
            storage = StorageSpec(
                name, nodes=1, managers=("ephemeralfs",),
                stage_in_bytes=rng.uniform(2, 10) * GB,
            )
            spec = WorkflowSpec(name, rng.randint(1, 3), storage_spec=storage,
                                run_time_s=rng.uniform(10, 60), max_retries=6)
        elif r < 0.75:
            spec = WorkflowSpec(
                name, rng.randint(1, 3), use_pool=True,
                datasets=(ds[rng.randint(0, 2)],),
                stage_in_bytes=rng.uniform(0, 4) * GB,
                run_time_s=rng.uniform(10, 60), max_retries=6,
            )
        else:
            spec = WorkflowSpec(name, rng.randint(1, 6),
                                run_time_s=rng.uniform(10, 60))
        specs.append(spec)
    return specs


def _chaos_fingerprint(incremental, seed=13, n_jobs=500, recorder=None):
    orch = Orchestrator(synthetic_cluster(16, 6), policy=BackfillPolicy(),
                        incremental=incremental, recorder=recorder)
    mgr = orch.enable_pools(ttl_s=None)
    mgr.create_pool(nodes=2, cap_bytes=80 * GB)
    node_ids = [n.node_id for n in orch.scheduler.cluster.storage_nodes]
    orch.enable_chaos(
        NodeFaultModel(node_ids, mttf_s=4000.0, mttr_s=350.0,
                       horizon_s=1200.0, seed=9,
                       schedule=((150.0, "sn00001"),)),
        retry=RetryPolicy(base_s=20.0, seed=2),
    )
    jobs = orch.run_campaign(
        _chaos_specs(seed, n_jobs),
        submit_times=[i * 1.5 for i in range(n_jobs)],
    )
    assert all(j.state is JobState.DONE for j in jobs)
    return [
        (j.spec.name, tuple(j.history), tuple(j.alloc_history), j.attempt,
         j.failure_phase)
        for j in jobs
    ]


def test_chaos_campaign_bit_identical_legacy_vs_indexed():
    """The PR 4 determinism contract extends under chaos: 500 seeded jobs
    with random MTTF outages, a scripted kill, mirrored degradation, pool
    self-healing, and retry backoff replay identically through both
    dispatchers — and run-to-run with tracing on."""
    from repro.obs import TraceRecorder

    legacy = _chaos_fingerprint(False)
    rec_a, rec_b = TraceRecorder(), TraceRecorder()
    indexed = _chaos_fingerprint(True, recorder=rec_a)
    again = _chaos_fingerprint(True, recorder=rec_b)
    assert legacy == indexed
    assert indexed == again
    assert rec_a.events == rec_b.events
    assert rec_a.counts.get("chaos.node_downs", 0) >= 1


def test_empty_fault_model_is_chaos_off():
    """An armed model that can never fire schedules nothing: job histories
    match a campaign that never called enable_chaos at all."""
    def run(arm_empty):
        orch = Orchestrator(synthetic_cluster(8, 4), policy=BackfillPolicy())
        orch.enable_pools(ttl_s=None).create_pool(nodes=1, cap_bytes=60 * GB)
        if arm_empty:
            orch.enable_chaos(NodeFaultModel(
                [n.node_id for n in orch.scheduler.cluster.storage_nodes]
            ))
        jobs = orch.run_campaign(
            _chaos_specs(5, 100),
            submit_times=[i * 2.0 for i in range(100)],
        )
        return [(j.spec.name, tuple(j.history), j.attempt) for j in jobs]

    assert run(False) == run(True)
