"""EphemeralFS functional behaviour: roundtrips, namespace, failure modes."""

import os

import pytest

pytest.importorskip("hypothesis")
import hypothesis.strategies as st
from hypothesis import HealthCheck, given, settings

from repro.core import EphemeralFS, FSError, dom_cluster
from repro.core.ephemeralfs import CacheSim


@pytest.fixture
def fs(tmp_path):
    nodes = dom_cluster().storage_nodes[:2]
    f = EphemeralFS(nodes, str(tmp_path / "efs"), stripe_size=1024)
    yield f
    if not f._torn_down:
        f.teardown()


def test_roundtrip_across_stripes(fs):
    fs.mkdir("/d")
    fs.create("/d/f")
    data = bytes(range(256)) * 20  # 5120 B -> 5 chunks over 4 targets
    fs.write("/d/f", 0, data)
    assert fs.read("/d/f", 0, len(data)) == data
    assert fs.stat("/d/f").size == len(data)
    # offset read
    assert fs.read("/d/f", 1000, 200) == data[1000:1200]


def test_offset_write_and_sparse(fs):
    fs.create("/f")
    fs.write("/f", 5000, b"xyz")
    assert fs.stat("/f").size == 5003
    out = fs.read("/f", 4998, 5)
    assert out == b"\x00\x00xyz"


def test_namespace_ops(fs):
    fs.mkdir("/a")
    fs.mkdir("/a/b")
    fs.create("/a/b/f1")
    fs.create("/a/b/f2")
    assert fs.readdir("/a/b") == ["f1", "f2"]
    with pytest.raises(FSError):
        fs.rmdir("/a/b")  # not empty
    fs.unlink("/a/b/f1")
    fs.unlink("/a/b/f2")
    fs.rmdir("/a/b")
    assert fs.readdir("/a") == []


def test_errors(fs):
    with pytest.raises(FSError):
        fs.stat("/missing")
    with pytest.raises(FSError):
        fs.create("/nodir/f")
    fs.create("/f")
    with pytest.raises(FSError):
        fs.create("/f")  # exists
    with pytest.raises(FSError):
        fs.read("/", 0, 1)  # directory


def test_chunks_distributed_over_targets(fs):
    fs.create("/big")
    fs.write("/big", 0, b"a" * 4096)  # 4 chunks
    used = [s for s in fs.storage_services if s.bytes_written > 0]
    assert len(used) == 4  # round-robin over all 4 storage targets


def test_kill_node_without_mirror_fails_io(fs):
    fs.create("/f")
    fs.write("/f", 0, b"a" * 4096)
    fs.kill_node(fs.storage_nodes[1].node_id)
    assert not fs.healthy()
    with pytest.raises(FSError):
        fs.read("/f", 0, 4096)


def test_mirror_survives_node_loss(tmp_path):
    nodes = dom_cluster().storage_nodes[:2]
    fs = EphemeralFS(nodes, str(tmp_path / "m"), stripe_size=512, mirror=True)
    fs.create("/f")
    data = os.urandom(4096)
    fs.write("/f", 0, data)
    fs.kill_node(nodes[1].node_id)
    assert fs.read("/f", 0, len(data)) == data  # served from mirrors
    assert fs.degraded()
    fs.write("/f", 4096, data)  # writes keep working degraded
    assert fs.read("/f", 4096, len(data)) == data
    fs.teardown()


def test_teardown_deletes_data(fs):
    fs.create("/f")
    fs.write("/f", 0, b"secret")
    base = fs.base_dir
    fs.teardown()
    assert not os.path.exists(base)
    with pytest.raises(FSError):
        fs.stat("/f")


def test_metadata_sharded_over_services(fs):
    """Namespace spreads by parent-directory hash (BeeGFS dirent locality:
    one directory's entries stay on one service; different directories land
    on different services)."""
    for i in range(16):
        fs.mkdir(f"/dir{i}")
        fs.create(f"/dir{i}/f")
    owners = {s.service_id for s in fs.md_services if s.inodes}
    assert len(owners) == 2
    # all entries of one directory co-located
    for i in range(16):
        holding = [s for s in fs.md_services if f"/dir{i}/f" in s.inodes]
        assert len(holding) == 1


def test_monitor_collects(fs):
    fs.create("/f")
    fs.write("/f", 0, b"d" * 2048)
    fs.read("/f", 0, 2048)
    stats = fs.monitor.collect(fs)
    assert sum(v["bytes_written"] for v in stats["storage"].values()) == 2048
    assert sum(v["bytes_read"] for v in stats["storage"].values()) == 2048


# -- CacheSim: the C2 mechanism ------------------------------------------------
def test_cachesim_lru_sequential_readback_thrashes():
    """Working set > capacity + LRU + sequential read-back => ~0 hit rate
    (the paper's Fig. 2 read collapse mechanism)."""
    c = CacheSim(capacity_bytes=10 * 100)
    for i in range(20):  # write 20 chunks of 100B; cache holds 10
        c.touch(f"chunk{i}", 100, is_read=False)
    for i in range(20):  # read back in write order
        c.touch(f"chunk{i}", 100, is_read=True)
    assert c.hit_rate() == 0.0
    assert c.evictions > 0


def test_cachesim_fits_all_hits():
    c = CacheSim(capacity_bytes=100 * 100)
    for i in range(20):
        c.touch(f"chunk{i}", 100, is_read=False)
    for i in range(20):
        c.touch(f"chunk{i}", 100, is_read=True)
    assert c.hit_rate() == 1.0


@settings(max_examples=30, deadline=None,
          suppress_health_check=[HealthCheck.function_scoped_fixture])
@given(data=st.binary(min_size=1, max_size=5000),
       offset=st.integers(0, 3000))
def test_property_write_read_roundtrip(fs, data, offset):
    path = "/prop"
    if not fs.exists(path):
        fs.create(path)
    fs.write(path, offset, data)
    assert fs.read(path, offset, len(data)) == data
