import pytest

# Single source of truth for the jax API drift detection lives in
# repro.compat so runnable examples (examples/serve_decode.py) can reuse
# it; tests import it from here as before.
from repro.compat import JAX_DRIFT_REASON, jax_api_drifted  # noqa: F401


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running tests")
