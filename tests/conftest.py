import pytest

# The kernel/model/distributed suites track jax+pallas APIs that have
# drifted on some container jax versions (pre-existing at seed; see
# ROADMAP "Kernel/model tests"). They are skipped — not failed — when the
# APIs they exercise are absent, so tier-1 `pytest -x -q` fails only on
# real regressions in the storage/orchestration layers.
JAX_DRIFT_REASON = (
    "jax/pallas API drift on this container's jax (pre-existing at seed): "
    "jax.sharding.AxisType and/or pallas CompilerParams are missing"
)


def jax_api_drifted() -> bool:
    try:
        import jax
        from jax.experimental.pallas import tpu as pltpu
    except Exception:
        return True
    return not (
        hasattr(jax.sharding, "AxisType") and hasattr(pltpu, "CompilerParams")
    )


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running tests")
