"""EphemeralKV — the paper's §VII generality claim (second data-manager type
on the same provisioning substrate) — plus async checkpoint drain."""

import os

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
import hypothesis.strategies as st
from hypothesis import HealthCheck, given, settings

from repro.checkpoint import CheckpointManager
from repro.core import EphemeralFS, EphemeralKV, FSError, GlobalFS, dom_cluster


@pytest.fixture
def kv(tmp_path):
    store = EphemeralKV(dom_cluster().storage_nodes[:2], str(tmp_path / "kv"))
    yield store
    if not store._torn_down:
        store.teardown()


def test_put_get_delete(kv):
    kv.put("a", b"1")
    kv.put("b", b"22")
    assert kv.get("a") == b"1"
    assert kv.get("b") == b"22"
    assert kv.get("missing") is None
    assert kv.delete("a")
    assert kv.get("a") is None
    assert not kv.delete("a")


def test_overwrite_returns_latest(kv):
    kv.put("k", b"v1")
    kv.put("k", b"v2" * 100)
    assert kv.get("k") == b"v2" * 100


def test_keys_partitioned_across_shards(kv):
    for i in range(64):
        kv.put(f"key-{i}", bytes([i]))
    used = [s for s in kv.shards if s.index]
    assert len(used) == 4  # 2 nodes x 2 shards
    assert kv.scan() == {f"key-{i}".encode() for i in range(64)}


def test_kill_node_without_replica_fails(kv):
    kv.put("x", b"v")
    kv.kill_node(kv.shards[0].node_id)
    assert not kv.healthy()
    with pytest.raises(FSError):
        for i in range(32):
            kv.get(f"probe{i}")   # some key lands on the dead node


def test_replicated_survives_node_loss(tmp_path):
    kv = EphemeralKV(dom_cluster().storage_nodes[:2], str(tmp_path / "kvr"),
                     replicate=True)
    data = {f"k{i}": os.urandom(64) for i in range(64)}
    for k, v in data.items():
        kv.put(k, v)
    kv.kill_node(kv.shards[0].node_id)
    for k, v in data.items():
        assert kv.get(k) == v     # every key still served via replicas
    kv.teardown()


def test_teardown_deletes_everything(kv):
    kv.put("secret", b"data")
    base = kv.base_dir
    kv.teardown()
    assert not os.path.exists(base)
    with pytest.raises(FSError):
        kv.get("secret")


@settings(max_examples=50, deadline=None,
          suppress_health_check=[HealthCheck.function_scoped_fixture])
@given(items=st.dictionaries(st.binary(min_size=1, max_size=32),
                             st.binary(max_size=256), max_size=24))
def test_property_kv_semantics(kv, items):
    for k, v in items.items():
        kv.put(k, v)
    for k, v in items.items():
        assert kv.get(k) == v


def test_async_drain(tmp_path):
    burst = EphemeralFS(dom_cluster().storage_nodes[:2], str(tmp_path / "b"))
    gfs = GlobalFS(str(tmp_path / "g"))
    mgr = CheckpointManager(burst, global_fs=gfs)
    t = {"w": jnp.arange(12.0)}
    mgr.save(5, t)
    th = mgr.drain_async(5)
    mgr.wait_drains()
    assert not th.is_alive()
    g = CheckpointManager(gfs, root="/persist/ckpt")
    restored, step = g.restore(t)
    assert step == 5
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(t["w"]))
    burst.teardown()
    gfs.teardown()
