"""Perf-variant switches keep numerics: moe2d, bf16bwd, dp_decode, padheads.

These are the §Perf hillclimb levers — each must be a pure performance
transform (same math), so we assert output equality vs the baseline path.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.hints import flag, mesh_hint
from repro.models import build_model
from repro.models.layers import rmsnorm, rmsnorm_bf16bwd


def test_flag_context():
    assert not flag("moe2d")
    with mesh_hint(None, ("moe2d",)):
        assert flag("moe2d")
        assert not flag("other")
    assert not flag("moe2d")


def test_moe2d_same_loss_and_grads():
    cfg = get_smoke("qwen3-moe-30b-a3b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0, cfg.vocab_size),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (2, 64), 0, cfg.vocab_size),
    }

    def loss(p):
        return model.loss(p, batch)[0]

    l0, g0 = jax.value_and_grad(loss)(params)
    with mesh_hint(None, ("moe2d",)):
        l1, g1 = jax.value_and_grad(loss)(params)
    assert float(l0) == pytest.approx(float(l1), abs=1e-6)
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_bf16bwd_norm_matches_autodiff():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 32, 64), jnp.bfloat16)
    s = jnp.ones((64,), jnp.bfloat16)

    def f_ref(s_, x_):
        return (rmsnorm({"scale": s_}, x_).astype(jnp.float32) ** 2).sum()

    def f_cus(s_, x_):
        return (rmsnorm_bf16bwd(s_, x_).astype(jnp.float32) ** 2).sum()

    gr = jax.grad(f_ref, argnums=(0, 1))(s, x)
    gc = jax.grad(f_cus, argnums=(0, 1))(s, x)
    for a, b in zip(gr, gc):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            atol=5e-2, rtol=5e-2,  # bf16 cotangent quantization
        )
    # cotangent dtype is pinned to the input dtype
    dx = jax.grad(lambda x_: f_cus(s, x_))(x)
    assert dx.dtype == jnp.bfloat16


def test_padheads_equivalence_with_zero_wo_rows():
    """Padding q-heads (GROUP-ALIGNED for GQA) with zero wo rows is an exact
    no-op on outputs: original group-g head i lands at padded slot
    g*G' + i; pad slots contribute nothing through zero wo rows."""
    cfg = get_smoke("phi4-mini-3.8b")   # 4 q heads, 2 kv heads in smoke
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = {"tokens": jnp.arange(64, dtype=jnp.int32)[None, :] % cfg.vocab_size}
    logits, _ = model.prefill(params, batch, 70)

    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    H_pad = H + K  # one pad head per kv group
    G, Gp = H // K, H_pad // K
    cfg_p = dataclasses.replace(cfg, n_heads=H_pad)
    model_p = build_model(cfg_p)
    params_p = model_p.init(jax.random.PRNGKey(0))

    a = params["layers"]["attn"]
    b = params_p["layers"]["attn"]
    wq = jnp.zeros_like(b["wq"]["w"])
    wo = jnp.zeros_like(b["wo"]["w"])
    for h in range(H):
        g, i = divmod(h, G)
        dst = g * Gp + i
        wq = wq.at[..., dst * hd:(dst + 1) * hd].set(
            a["wq"]["w"][..., h * hd:(h + 1) * hd])
        wo = wo.at[..., dst * hd:(dst + 1) * hd, :].set(
            a["wo"]["w"][..., h * hd:(h + 1) * hd, :])
    params_p["layers"]["attn"] = {
        **b, "wq": {"w": wq}, "wo": {"w": wo},
        "wk": a["wk"], "wv": a["wv"],
    }
    for k in ("ln1", "ln2", "mlp"):
        params_p["layers"][k] = params["layers"][k]
    for k in ("embed", "final_norm", "unembed"):
        if k in params:
            params_p[k] = params[k]
    logits_p, _ = model_p.prefill(params_p, batch, 70)
    np.testing.assert_allclose(
        np.asarray(logits), np.asarray(logits_p), atol=2e-5, rtol=2e-5)


def test_runtime_flags_reach_trace(tmp_path):
    """RuntimeConfig.flags flow into the traced step via mesh_hint."""
    from repro.runtime import RuntimeConfig
    rt = RuntimeConfig(flags=("moe2d",))
    assert "moe2d" in rt.flags
