"""Scheduler edge cases the orchestrator relies on (no hypothesis needed):
double-release, free-pool restoration under interleaving, sizing round-trips,
request validation, and the non-raising try-allocate path."""

import pytest

from repro.core import (
    AllocationError,
    JobRequest,
    Scheduler,
    SizingPolicy,
    StorageRequest,
    dom_cluster,
)
from repro.core.resources import GB, TB


def test_double_release_raises():
    s = Scheduler(dom_cluster())
    a = s.submit(JobRequest("j", 2, storage=StorageRequest(nodes=1)))
    s.release(a)
    with pytest.raises(AllocationError):
        s.release(a)
    assert s.free_counts() == (8, 4)


def test_interleaved_submit_release_restores_pool():
    s = Scheduler(dom_cluster())
    a = s.submit(JobRequest("a", 3, storage=StorageRequest(nodes=2)))
    b = s.submit(JobRequest("b", 2, storage=StorageRequest(nodes=1)))
    s.release(a)
    c = s.submit(JobRequest("c", 5, storage=StorageRequest(nodes=3)))
    s.release(b)
    s.release(c)
    assert s.free_counts() == (8, 4)
    # no node ended up in two live allocations along the way
    assert s.live_allocations == ()
    # the full pool is allocatable again
    d = s.submit(JobRequest("d", 8, storage=StorageRequest(nodes=4)))
    assert len(d.compute_nodes) == 8 and len(d.storage_nodes) == 4


def test_capability_sizing_round_trip():
    """capability -> node count -> that many nodes actually deliver it."""
    cluster = dom_cluster()
    s = Scheduler(cluster)
    policy = SizingPolicy()
    for bw in (1 * GB, 6.4 * GB, 10 * GB, 19.2 * GB):
        req = StorageRequest(capability_bw=bw)
        n = s.resolve_storage_nodes(req)
        node = cluster.storage_nodes[0]
        per_node = sum(
            d.spec.write_bw for d in node.disks[: policy.storage_disks_per_node]
        )
        assert n * per_node >= bw                  # delivered >= requested
        if n > 1:
            assert (n - 1) * per_node < bw         # and n is minimal


def test_capacity_sizing_round_trip():
    cluster = dom_cluster()
    s = Scheduler(cluster)
    per_node = 2 * 5.9 * TB                        # 2 storage disks per node
    for cap in (1 * TB, 11.8 * TB, 12 * TB, 40 * TB):
        n = s.resolve_storage_nodes(StorageRequest(capacity_bytes=cap))
        assert n * per_node >= cap
        if n > 1:
            assert (n - 1) * per_node < cap


def test_zero_and_negative_storage_requests_rejected():
    with pytest.raises(ValueError):
        StorageRequest(nodes=0)
    with pytest.raises(ValueError):
        StorageRequest(nodes=-2)
    with pytest.raises(ValueError):
        StorageRequest(capacity_bytes=0.0)
    with pytest.raises(ValueError):
        StorageRequest(capability_bw=-1.0)
    with pytest.raises(ValueError):
        JobRequest("j", -1)


def test_try_submit_busy_vs_infeasible():
    s = Scheduler(dom_cluster())
    held = s.submit(JobRequest("hold", 8, storage=StorageRequest(nodes=4)))
    # busy: feasible on an empty cluster -> None, not an exception
    assert s.try_submit(JobRequest("q", 4, storage=StorageRequest(nodes=2))) is None
    # infeasible: bigger than the cluster -> raises even while busy
    with pytest.raises(AllocationError):
        s.try_submit(JobRequest("huge", 9))
    with pytest.raises(AllocationError):
        s.try_submit(JobRequest("huge-storage", 1, storage=StorageRequest(nodes=5)))
    s.release(held)
    granted = s.try_submit(JobRequest("q", 4, storage=StorageRequest(nodes=2)))
    assert granted is not None
    assert len(granted.compute_nodes) == 4 and len(granted.storage_nodes) == 2


def test_can_allocate_and_feasible():
    s = Scheduler(dom_cluster())
    req = JobRequest("j", 4, storage=StorageRequest(nodes=2))
    assert s.feasible(req) and s.can_allocate(req)
    a = s.submit(JobRequest("hog", 6, storage=StorageRequest(nodes=3)))
    assert s.feasible(req) and not s.can_allocate(req)
    s.release(a)
    assert s.can_allocate(req)
    # malformed (storage without constraint) raises from demand()
    with pytest.raises(AllocationError):
        s.demand(JobRequest("bad", 1, storage=StorageRequest(nodes=1), constraint="mc"))


def _hetero_cluster():
    """Two big storage nodes (2x10 TB disks) listed FIRST, one small node
    (2x2 TB): the old prototype sizing (``storage_nodes[0]``) measured only
    the big node."""
    from repro.core import ClusterSpec, ComputeNode
    from repro.core.resources import ARIES, Disk, DiskSpec, StorageNode

    big = DiskSpec("big-nvme", 10 * TB, read_bw=6 * GB, write_bw=4 * GB)
    small = DiskSpec("small-nvme", 2 * TB, read_bw=3 * GB, write_bw=1 * GB)

    def node(nid, spec):
        return StorageNode(nid, tuple(Disk(nid, d, spec) for d in range(2)))

    return ClusterSpec(
        name="hetero",
        compute_nodes=(ComputeNode("c0"),),
        storage_nodes=(node("big0", big), node("big1", big), node("small0", small)),
        interconnect=ARIES,
    )


def test_heterogeneous_capacity_sizing_never_underprovisions():
    """Regression: sizing from the node-0 prototype requested 1 node for
    8 TB (big node holds 20 TB) — but the allocator is free to grant the
    4 TB small node. Min-across-nodes sizing guarantees any granted subset
    delivers the requested capacity."""
    s = Scheduler(_hetero_cluster())
    req = StorageRequest(capacity_bytes=8 * TB)
    n = s.resolve_storage_nodes(req)
    assert n == 2                                  # min per-node is 4 TB
    a = s.submit(JobRequest("j", 0, storage=req))
    granted = sum(
        s.policy.node_capacity_bytes(node) for node in a.storage_nodes
    )
    assert granted >= 8 * TB
    s.release(a)


def test_heterogeneous_capability_sizing_uses_min_bandwidth():
    s = Scheduler(_hetero_cluster())
    # min per-node write bw is the small node's 2x1 GB/s
    assert s.resolve_storage_nodes(StorageRequest(capability_bw=4 * GB)) == 2
    assert s.resolve_storage_nodes(StorageRequest(capability_bw=2 * GB)) == 1


def test_heterogeneous_sizing_follows_free_pool():
    """Once the small node is busy, the free pool is homogeneous-big and the
    same request resolves to fewer nodes; feasibility keeps using the
    conservative empty-cluster (all-nodes) sizing throughout."""
    s = Scheduler(_hetero_cluster())
    req = StorageRequest(capacity_bytes=8 * TB)
    assert s.resolve_storage_nodes(req) == 2       # min over {big,big,small}
    # occupy the two big nodes (allocator picks lowest ids: big0, big1)
    held = s.submit(JobRequest("big-eater", 0, storage=StorageRequest(nodes=2)))
    assert {n.node_id for n in held.storage_nodes} == {"big0", "big1"}
    # only the 4 TB small node is free: the same request now needs 2 of it
    assert s.resolve_storage_nodes(req) == 2
    smaller = StorageRequest(capacity_bytes=3 * TB)
    assert s.resolve_storage_nodes(smaller) == 1   # still fits one small node
    # empty-cluster feasibility is unchanged by occupancy
    assert s.demand(JobRequest("q", 0, storage=req), assume_empty=True)[1] == 2
    s.release(held)
    assert s.resolve_storage_nodes(smaller) == 1   # big nodes back: 1 suffices


def test_provisioner_explicit_zero_md_disks_not_replaced_by_default(tmp_path):
    """The falsy-zero fix: md_disks_per_node=0 must survive plan_for."""
    from repro.core import Provisioner

    cluster = dom_cluster()
    s = Scheduler(cluster)
    alloc = s.submit(JobRequest("j", 1, storage=StorageRequest(nodes=2)))
    prov = Provisioner(cluster)
    plan = prov.plan_for(alloc, md_disks_per_node=0, storage_disks_per_node=3)
    assert plan.md_disks_per_node == 0
    assert plan.storage_disks_per_node == 3
    assert plan.targets_per_node == 3
    s.release(alloc)
