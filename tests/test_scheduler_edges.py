"""Scheduler edge cases the orchestrator relies on (no hypothesis needed):
double-release, free-pool restoration under interleaving, sizing round-trips,
request validation, and the non-raising try-allocate path."""

import pytest

from repro.core import (
    AllocationError,
    JobRequest,
    Scheduler,
    SizingPolicy,
    StorageRequest,
    dom_cluster,
)
from repro.core.resources import GB, TB


def test_double_release_raises():
    s = Scheduler(dom_cluster())
    a = s.submit(JobRequest("j", 2, storage=StorageRequest(nodes=1)))
    s.release(a)
    with pytest.raises(AllocationError):
        s.release(a)
    assert s.free_counts() == (8, 4)


def test_interleaved_submit_release_restores_pool():
    s = Scheduler(dom_cluster())
    a = s.submit(JobRequest("a", 3, storage=StorageRequest(nodes=2)))
    b = s.submit(JobRequest("b", 2, storage=StorageRequest(nodes=1)))
    s.release(a)
    c = s.submit(JobRequest("c", 5, storage=StorageRequest(nodes=3)))
    s.release(b)
    s.release(c)
    assert s.free_counts() == (8, 4)
    # no node ended up in two live allocations along the way
    assert s.live_allocations == ()
    # the full pool is allocatable again
    d = s.submit(JobRequest("d", 8, storage=StorageRequest(nodes=4)))
    assert len(d.compute_nodes) == 8 and len(d.storage_nodes) == 4


def test_capability_sizing_round_trip():
    """capability -> node count -> that many nodes actually deliver it."""
    cluster = dom_cluster()
    s = Scheduler(cluster)
    policy = SizingPolicy()
    for bw in (1 * GB, 6.4 * GB, 10 * GB, 19.2 * GB):
        req = StorageRequest(capability_bw=bw)
        n = s.resolve_storage_nodes(req)
        node = cluster.storage_nodes[0]
        per_node = sum(
            d.spec.write_bw for d in node.disks[: policy.storage_disks_per_node]
        )
        assert n * per_node >= bw                  # delivered >= requested
        if n > 1:
            assert (n - 1) * per_node < bw         # and n is minimal


def test_capacity_sizing_round_trip():
    cluster = dom_cluster()
    s = Scheduler(cluster)
    per_node = 2 * 5.9 * TB                        # 2 storage disks per node
    for cap in (1 * TB, 11.8 * TB, 12 * TB, 40 * TB):
        n = s.resolve_storage_nodes(StorageRequest(capacity_bytes=cap))
        assert n * per_node >= cap
        if n > 1:
            assert (n - 1) * per_node < cap


def test_zero_and_negative_storage_requests_rejected():
    with pytest.raises(ValueError):
        StorageRequest(nodes=0)
    with pytest.raises(ValueError):
        StorageRequest(nodes=-2)
    with pytest.raises(ValueError):
        StorageRequest(capacity_bytes=0.0)
    with pytest.raises(ValueError):
        StorageRequest(capability_bw=-1.0)
    with pytest.raises(ValueError):
        JobRequest("j", -1)


def test_try_submit_busy_vs_infeasible():
    s = Scheduler(dom_cluster())
    held = s.submit(JobRequest("hold", 8, storage=StorageRequest(nodes=4)))
    # busy: feasible on an empty cluster -> None, not an exception
    assert s.try_submit(JobRequest("q", 4, storage=StorageRequest(nodes=2))) is None
    # infeasible: bigger than the cluster -> raises even while busy
    with pytest.raises(AllocationError):
        s.try_submit(JobRequest("huge", 9))
    with pytest.raises(AllocationError):
        s.try_submit(JobRequest("huge-storage", 1, storage=StorageRequest(nodes=5)))
    s.release(held)
    granted = s.try_submit(JobRequest("q", 4, storage=StorageRequest(nodes=2)))
    assert granted is not None
    assert len(granted.compute_nodes) == 4 and len(granted.storage_nodes) == 2


def test_can_allocate_and_feasible():
    s = Scheduler(dom_cluster())
    req = JobRequest("j", 4, storage=StorageRequest(nodes=2))
    assert s.feasible(req) and s.can_allocate(req)
    a = s.submit(JobRequest("hog", 6, storage=StorageRequest(nodes=3)))
    assert s.feasible(req) and not s.can_allocate(req)
    s.release(a)
    assert s.can_allocate(req)
    # malformed (storage without constraint) raises from demand()
    with pytest.raises(AllocationError):
        s.demand(JobRequest("bad", 1, storage=StorageRequest(nodes=1), constraint="mc"))


def test_provisioner_explicit_zero_md_disks_not_replaced_by_default(tmp_path):
    """The falsy-zero fix: md_disks_per_node=0 must survive plan_for."""
    from repro.core import Provisioner

    cluster = dom_cluster()
    s = Scheduler(cluster)
    alloc = s.submit(JobRequest("j", 1, storage=StorageRequest(nodes=2)))
    prov = Provisioner(cluster)
    plan = prov.plan_for(alloc, md_disks_per_node=0, storage_disks_per_node=3)
    assert plan.md_disks_per_node == 0
    assert plan.storage_disks_per_node == 3
    assert plan.targets_per_node == 3
    s.release(alloc)
