"""Optimizer numerics, schedules, gradient compression."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.optim import AdamWConfig


def _params():
    return {"w": jnp.ones((4, 4), jnp.bfloat16), "b": jnp.zeros((4,), jnp.bfloat16)}


def test_adamw_first_step_matches_closed_form():
    """With bias correction, step 1 update is lr * g/(|g| + eps) + wd term."""
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, grad_clip=1e9)
    p = {"w": jnp.ones((2,), jnp.float32)}
    st = optim.init(p)
    g = {"w": jnp.array([0.5, -2.0])}
    newp, st2, stats = optim.update(g, st, p, cfg)
    expect = 1.0 - 0.1 * np.sign(np.array([0.5, -2.0]))
    np.testing.assert_allclose(np.asarray(newp["w"]), expect, rtol=1e-4)
    assert int(st2.step) == 1


def test_grad_clipping():
    cfg = AdamWConfig(lr=0.0, grad_clip=1.0)
    p = {"w": jnp.zeros((3,))}
    g = {"w": jnp.full((3,), 100.0)}
    _, _, stats = optim.update(g, optim.init(p), p, cfg)
    assert float(stats["grad_norm"]) == pytest.approx(np.sqrt(3) * 100, rel=1e-5)


def test_weight_decay_pulls_to_zero():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.5, grad_clip=1e9)
    p = {"w": jnp.full((2,), 2.0)}
    g = {"w": jnp.zeros((2,))}
    newp, *_ = optim.update(g, optim.init(p), p, cfg)
    assert float(newp["w"][0]) < 2.0


def test_master_weights_fp32_params_bf16():
    p = _params()
    st = optim.init(p)
    assert st.master["w"].dtype == jnp.float32
    g = jax.tree.map(lambda x: jnp.ones_like(x, jnp.float32), p)
    newp, st2, _ = optim.update(g, st, p, AdamWConfig())
    assert newp["w"].dtype == jnp.bfloat16
    assert st2.master["w"].dtype == jnp.float32


def test_warmup_cosine_shape():
    s = optim.warmup_cosine
    assert float(s(0, warmup=10, total=100)) == 0.0
    assert float(s(10, warmup=10, total=100)) == pytest.approx(1.0)
    assert float(s(100, warmup=10, total=100)) == pytest.approx(0.1, abs=1e-6)
    mid = float(s(55, warmup=10, total=100))
    assert 0.1 < mid < 1.0


def test_compression_roundtrip_small_error():
    g = {"w": jnp.linspace(-1, 1, 256).reshape(16, 16)}
    ef = optim.ef_init(g)
    out, ef2, ratio = optim.compress_grads(g, ef)
    err = float(jnp.max(jnp.abs(out["w"] - g["w"])))
    assert err <= 1.0 / 127 + 1e-6
    assert ratio == pytest.approx(0.25, abs=0.01)  # int8 vs f32


def test_error_feedback_unbiased_over_time():
    """Mean compressed gradient converges to the true mean (residual carries
    the rounding error forward)."""
    true_g = {"w": jnp.full((8,), 0.003)}
    ef = optim.ef_init(true_g)
    acc = jnp.zeros((8,))
    n = 50
    for _ in range(n):
        out, ef, _ = optim.compress_grads(true_g, ef)
        acc = acc + out["w"]
    np.testing.assert_allclose(np.asarray(acc / n), 0.003, rtol=0.02)
