"""Unified StorageSession API: spec validation, negotiation, sessions."""

import pytest

from repro.core import AllocationError, FSError, dom_cluster
from repro.pool import DatasetRef
from repro.provision import (
    BackendRegistry,
    EphemeralFSBackend,
    LifetimeClass,
    NegotiationError,
    ProvisioningService,
    QoS,
    SessionError,
    StorageSpec,
)

GB = 1e9
TB = 1e12


@pytest.fixture
def svc():
    return ProvisioningService(dom_cluster())


# -- spec validation ----------------------------------------------------------

def test_spec_exclusive_sizing():
    with pytest.raises(ValueError, match="at most one"):
        StorageSpec("s", nodes=1, capacity_bytes=1 * TB)
    with pytest.raises(ValueError, match="POOLED"):
        StorageSpec("s", nodes=1, lifetime=LifetimeClass.POOLED)
    with pytest.raises(ValueError, match="PERSISTENT"):
        StorageSpec("s", lifetime=LifetimeClass.PERSISTENT)
    with pytest.raises(ValueError):
        StorageSpec("")
    with pytest.raises(ValueError):
        StorageSpec("s", nodes=1, qos=QoS(min_bandwidth=-1.0))


def test_spec_dataset_validation():
    with pytest.raises(ValueError, match="DatasetRef"):
        StorageSpec("s", nodes=1, datasets=("d",))
    d = DatasetRef("d", GB)
    with pytest.raises(ValueError, match="duplicate"):
        StorageSpec("s", nodes=1, datasets=(d, d))


# -- negotiation --------------------------------------------------------------

def test_negotiate_prefers_first_feasible_manager(svc):
    offer = svc.negotiate(StorageSpec("j", nodes=2, managers=("ephemeralfs", "globalfs")))
    assert offer.backend == "ephemeralfs"
    assert offer.n_storage_nodes == 2
    assert offer.provision_time_s > 0


def test_negotiate_falls_back_in_preference_order(svc):
    # node-sized specs are impossible on the always-on global FS, so the
    # ordered fallback is the only feasible candidate
    offer = svc.negotiate(StorageSpec("j", nodes=1, managers=("globalfs", "ephemeralfs")))
    assert offer.backend == "ephemeralfs"
    assert any(r.backend == "globalfs" for r in offer.rejections)
    reason = next(r for r in offer.rejections if r.backend == "globalfs").reason
    assert "dedicated" in reason


def test_negotiate_no_backend_structured_reasons(svc):
    # 100 TB exceeds dom's 4 DataWarp nodes AND a bandwidth floor no backend
    # delivers -> every candidate must explain itself
    spec = StorageSpec(
        "hopeless",
        capacity_bytes=100 * TB,
        qos=QoS(min_bandwidth=1e15),
        managers=("ephemeralfs", "globalfs", "kvstore"),
    )
    with pytest.raises(NegotiationError) as ei:
        svc.negotiate(spec)
    err = ei.value
    assert err.spec_name == "hopeless"
    assert {r.backend for r in err.rejections} == {"ephemeralfs", "globalfs", "kvstore"}
    assert err.reason_for("kvstore") is not None
    assert "no backend can serve" in str(err)


def test_negotiate_qos_bandwidth_infeasible(svc):
    # 2 nodes deliver 2 x 6.4 GB/s; a 100 GB/s floor cannot be met
    with pytest.raises(NegotiationError) as ei:
        svc.negotiate(
            StorageSpec("q", nodes=2, managers=("ephemeralfs",),
                        qos=QoS(min_bandwidth=100 * GB))
        )
    assert "QoS floor" in ei.value.reason_for("ephemeralfs")
    # sized by bandwidth instead, the same floor is satisfiable
    offer = svc.negotiate(
        StorageSpec("q2", bandwidth=12 * GB, managers=("ephemeralfs",),
                    qos=QoS(min_bandwidth=12 * GB))
    )
    assert offer.n_storage_nodes == 2


def test_negotiate_qos_provision_latency(svc):
    with pytest.raises(NegotiationError) as ei:
        svc.negotiate(
            StorageSpec("fast", nodes=1, managers=("ephemeralfs",),
                        qos=QoS(max_provision_s=1.0))
        )
    assert "ceiling" in ei.value.reason_for("ephemeralfs")
    # the zero-deploy global FS satisfies the same latency ceiling
    offer = svc.negotiate(
        StorageSpec("fast2", capacity_bytes=1 * TB,
                    managers=("ephemeralfs", "globalfs"),
                    qos=QoS(max_provision_s=1.0))
    )
    assert offer.backend == "globalfs"


def test_negotiate_kv_access_routes_to_kvstore(svc):
    offer = svc.negotiate(StorageSpec("kv", nodes=1, access="kv"))
    assert offer.backend == "kvstore"
    # posix spec never lands on the KV store
    with pytest.raises(NegotiationError):
        svc.negotiate(StorageSpec("p", nodes=1, access="posix", managers=("kvstore",)))


def test_negotiate_unknown_manager_rejected(svc):
    with pytest.raises(NegotiationError) as ei:
        svc.negotiate(StorageSpec("x", nodes=1, managers=("hdf5-cloud",)))
    assert "not registered" in ei.value.reason_for("hdf5-cloud")


def test_null_backend_needs_explicit_request(svc):
    # never wins an open negotiation...
    offer = svc.negotiate(StorageSpec("open", nodes=1))
    assert offer.backend != "null"
    # ...but serves anything when named
    assert svc.negotiate(StorageSpec("dry", nodes=1, managers=("null",))).backend == "null"


def test_registry_rejects_duplicates():
    reg = BackendRegistry([EphemeralFSBackend()])
    with pytest.raises(ValueError):
        reg.register(EphemeralFSBackend())


# -- sessions: lifecycle + release-on-exception -------------------------------

def test_session_lifecycle_releases_nodes(svc):
    spec = StorageSpec("job", nodes=2, managers=("ephemeralfs",))
    with svc.open_session(spec, n_compute=3) as sess:
        assert svc.scheduler.free_counts() == (5, 2)
        assert sess.backend == "ephemeralfs"
        assert len(sess.storage_nodes) == 2
        assert sess.provision_time_s == pytest.approx(5.37, abs=0.05)
        assert sess.stage_in_time_s == 0.0       # nothing to stage
    assert sess.released
    assert svc.scheduler.free_counts() == (8, 4)
    sess.release()                               # idempotent
    assert svc.scheduler.free_counts() == (8, 4)


def test_session_exit_releases_on_exception(svc):
    spec = StorageSpec("boom", nodes=2, managers=("ephemeralfs",))
    with pytest.raises(RuntimeError, match="mid-session fault"):
        with svc.open_session(spec, n_compute=1):
            assert svc.scheduler.free_counts() == (7, 2)
            raise RuntimeError("mid-session fault")
    assert svc.scheduler.free_counts() == (8, 4)   # no leaked allocation


def test_pooled_session_exit_releases_lease_on_exception(svc):
    d = DatasetRef("d", 10 * GB)
    svc.ensure_pools()
    pool_sess = svc.open_session(
        StorageSpec("pool", nodes=2, lifetime=LifetimeClass.PERSISTENT)
    )
    assert svc.scheduler.free_counts() == (8, 2)
    with pytest.raises(RuntimeError):
        with svc.open_session(
            StorageSpec("leaser", lifetime=LifetimeClass.POOLED, datasets=(d,),
                        stage_in_bytes=1 * GB)
        ) as sess:
            assert sess.lease is not None
            assert pool_sess.pool.n_leases == 1
            raise RuntimeError("fault while leased")
    assert pool_sess.pool.n_leases == 0            # lease drained, pool alive
    assert svc.scheduler.free_counts() == (8, 2)   # pool still pins its nodes
    # retire through the session handle -> nodes return to the scheduler
    assert pool_sess.retire() is True
    assert svc.scheduler.free_counts() == (8, 4)


def test_pooled_spec_without_pools_is_negotiation_error(svc):
    d = DatasetRef("d", GB)
    with pytest.raises(NegotiationError) as ei:
        svc.negotiate(StorageSpec("l", lifetime=LifetimeClass.POOLED, datasets=(d,)))
    assert "pool" in ei.value.reason_for("ephemeralfs")


def test_pooled_cache_hit_halves_stage_plan(svc):
    d = DatasetRef("shared", 20 * GB)
    svc.ensure_pools()
    svc.open_session(StorageSpec("p", nodes=2, lifetime=LifetimeClass.PERSISTENT))
    s1 = svc.open_session(
        StorageSpec("first", lifetime=LifetimeClass.POOLED, datasets=(d,))
    )
    assert s1.stage_in_bytes == 20 * GB and s1.saved_bytes == 0.0
    s1.mark_staged()
    s1.release()
    s2 = svc.open_session(
        StorageSpec("second", lifetime=LifetimeClass.POOLED, datasets=(d,))
    )
    assert s2.stage_in_bytes == 0.0 and s2.saved_bytes == 20 * GB
    s2.release()


def test_globalfs_session_zero_cost_datasets(svc):
    d = DatasetRef("already-there", 30 * GB)
    spec = StorageSpec("g", managers=("globalfs",), datasets=(d,),
                       stage_in_bytes=2 * GB)
    with svc.open_session(spec) as sess:
        assert sess.provision_time_s == 0.0
        assert sess.stage_in_bytes == 2 * GB       # private traffic only
        assert sess.saved_bytes == 30 * GB         # datasets never move
        assert len(sess.storage_nodes) == 0
    assert svc.scheduler.free_counts() == (8, 4)


def test_open_session_busy_raises_try_open_returns_none(svc):
    spec = StorageSpec("big", nodes=4, managers=("ephemeralfs",))
    hold = svc.open_session(spec)
    again = StorageSpec("big2", nodes=1, managers=("ephemeralfs",))
    assert svc.try_open_session(again) is None
    with pytest.raises(AllocationError, match="cannot grant now"):
        svc.open_session(again)
    hold.release()
    assert svc.open_session(again).backend == "ephemeralfs"


def test_materialized_session_roundtrip(svc, tmp_path):
    spec = StorageSpec("io", nodes=2, managers=("ephemeralfs",))
    with svc.open_session(spec, materialize=True, base_dir=str(tmp_path / "efs")) as sess:
        c = sess.mount("rank0")
        c.makedirs("/out")
        c.write_file("/out/a.bin", b"payload")
        assert c.read_file("/out/a.bin") == b"payload"
    assert svc.scheduler.free_counts() == (8, 4)


def test_materialized_kv_session(svc, tmp_path):
    spec = StorageSpec("cache", nodes=1, access="kv", managers=("kvstore",))
    with svc.open_session(spec, materialize=True, base_dir=str(tmp_path / "kv")) as sess:
        kv = sess.mount()
        kv.put(b"k", b"v")
        assert kv.get(b"k") == b"v"
    assert svc.scheduler.free_counts() == (8, 4)


def test_modeled_session_mount_raises(svc):
    with svc.open_session(StorageSpec("m", nodes=1, managers=("ephemeralfs",))) as sess:
        with pytest.raises(SessionError, match="materialize"):
            sess.mount()


def test_service_stats_track_backends(svc):
    svc.open_session(StorageSpec("a", nodes=1, managers=("ephemeralfs",))).release()
    svc.open_session(StorageSpec("b", managers=("globalfs",))).release()
    with pytest.raises(NegotiationError):
        svc.negotiate(StorageSpec("c", nodes=99, managers=("ephemeralfs",)))
    assert svc.stats.sessions_opened == {"ephemeralfs": 1, "globalfs": 1}
    assert svc.stats.sessions_released == 2
    assert svc.stats.failed_negotiations == 1
    assert svc.stats.negotiations >= 3
    assert svc.stats.negotiation_wall_s > 0


def test_pool_base_dir_collision(svc):
    pools = svc.ensure_pools()
    pools.create_pool(nodes=1, name="a", base_dir="/trees/shared")
    with pytest.raises(FSError, match="already in use"):
        pools.create_pool(nodes=1, name="b", base_dir="/trees/shared")
    # the failed create must not leak its scheduler allocation
    assert svc.scheduler.free_counts() == (8, 3)
    # retiring the owner frees the tree for reuse
    pools.retire(pools.pools[0])
    pools.create_pool(nodes=1, name="c", base_dir="/trees/shared")
    assert svc.scheduler.free_counts() == (8, 3)


# -- regressions from review --------------------------------------------------

def test_materialize_collision_does_not_leak_nodes(svc, tmp_path):
    base = str(tmp_path / "shared")
    spec1 = StorageSpec("one", nodes=2, managers=("ephemeralfs",))
    spec2 = StorageSpec("two", nodes=2, managers=("ephemeralfs",))
    s1 = svc.open_session(spec1, materialize=True, base_dir=base)
    with pytest.raises(FSError, match="already in use"):
        svc.open_session(spec2, materialize=True, base_dir=base)
    # the failed open released its grant; only s1 still holds nodes
    assert svc.scheduler.free_counts() == (8, 2)
    s1.release()
    assert svc.scheduler.free_counts() == (8, 4)


def test_persistent_session_reattaches_by_name(svc):
    spec = StorageSpec("mkpool", nodes=2, lifetime=LifetimeClass.PERSISTENT)
    s1 = svc.open_session(spec)
    s2 = svc.open_session(spec)          # idempotent: same pool, no collision
    assert s2.pool is s1.pool
    assert s2.provision_time_s == 0.0    # already provisioned
    assert svc.scheduler.free_counts() == (8, 2)
    s1.retire()
    assert svc.scheduler.free_counts() == (8, 4)


def test_retried_persistent_job_survives_campaign():
    from repro.orchestrator import JobState, Orchestrator, WorkflowSpec

    class OneProvisionFault:
        """Trips exactly the first provision phase, then stays quiet."""

        def __init__(self):
            self.tripped = False

        def trip(self, job_name, phase):
            if phase == "provision" and not self.tripped:
                self.tripped = True
                return True
            return False

    orch = Orchestrator(dom_cluster(), faults=OneProvisionFault())
    spec = WorkflowSpec(
        "mk", 1, max_retries=2,
        storage_spec=StorageSpec("mk", nodes=2, lifetime=LifetimeClass.PERSISTENT),
    )
    jobs = orch.run_campaign([spec])     # must not raise FSError
    assert jobs[0].state is JobState.DONE
    assert jobs[0].attempt == 1          # one fault, one successful retry
    assert len(orch.pools.live_pools) == 1   # pool persisted, not duplicated


def test_ensure_pools_refuses_to_orphan_live_pools(svc):
    svc.open_session(StorageSpec("p", nodes=2, lifetime=LifetimeClass.PERSISTENT))
    with pytest.raises(ValueError, match="live"):
        svc.ensure_pools(ttl_s=100.0)
    assert len(svc.pool_manager.live_pools) == 1   # untouched


def test_failed_deploy_releases_tree_claim(svc, tmp_path):
    """A deploy that raises must not leave the base_dir claimed forever."""
    import pytest as _pytest

    spec = StorageSpec("claim", nodes=2, managers=("ephemeralfs",))
    target = tmp_path / "efs"
    target.write_text("a file, not a dir")    # EphemeralFS mkdir will fail
    with _pytest.raises(Exception):
        svc.open_session(spec, materialize=True, base_dir=str(target))
    assert svc.provisioner.tree_owner(str(target)) is None
    assert svc.scheduler.free_counts() == (8, 4)


def test_persistent_reattach_rejects_sizing_mismatch(svc):
    svc.open_session(StorageSpec("cache", nodes=2, lifetime=LifetimeClass.PERSISTENT))
    with pytest.raises(AllocationError, match="spans 2 nodes"):
        svc.open_session(StorageSpec("cache", nodes=1, lifetime=LifetimeClass.PERSISTENT))


def test_workflowspec_rejects_mixed_legacy_and_spec_fields():
    from repro.orchestrator import WorkflowSpec

    with pytest.raises(ValueError, match="storage_spec replaces"):
        WorkflowSpec(
            "j", 1,
            storage_spec=StorageSpec("j", nodes=1, managers=("ephemeralfs",)),
            stage_in_bytes=8 * GB,
        )


def test_enable_pools_no_args_returns_existing_manager():
    from repro.orchestrator import Orchestrator

    orch = Orchestrator(dom_cluster())
    mgr = orch.enable_pools(ttl_s=None)
    orch.provision.open_session(
        StorageSpec("p", nodes=2, lifetime=LifetimeClass.PERSISTENT)
    )
    assert orch.enable_pools() is mgr      # fetch idiom, not reconfiguration


def test_persistent_session_co_allocates_compute(svc):
    spec = StorageSpec("p", nodes=2, lifetime=LifetimeClass.PERSISTENT)
    sess = svc.open_session(spec, n_compute=8)
    assert sess.allocation is not None
    assert svc.scheduler.free_counts() == (0, 2)   # 8 compute + pool's 2 storage
    sess.release()                                 # compute back, pool persists
    assert svc.scheduler.free_counts() == (8, 2)
    # a busy compute pool is a clean None, not a half-created pool
    hold = svc.open_session(StorageSpec("h", nodes=1, managers=("ephemeralfs",)),
                            n_compute=8)
    assert svc.try_open_session(
        StorageSpec("p2", nodes=1, lifetime=LifetimeClass.PERSISTENT), n_compute=1
    ) is None
    assert len(svc.pool_manager.live_pools) == 1   # no p2 pool created
    hold.release()


def test_pooled_qos_bandwidth_floor_enforced(svc):
    d = DatasetRef("d", GB)
    svc.ensure_pools()
    svc.open_session(StorageSpec("p", nodes=2, lifetime=LifetimeClass.PERSISTENT))
    with pytest.raises(NegotiationError) as ei:
        svc.negotiate(
            StorageSpec("l", lifetime=LifetimeClass.POOLED, datasets=(d,),
                        qos=QoS(min_bandwidth=1e18))
        )
    assert "QoS" in ei.value.reason_for("ephemeralfs")
    # a satisfiable floor still negotiates onto the pool
    offer = svc.negotiate(
        StorageSpec("l2", lifetime=LifetimeClass.POOLED, datasets=(d,),
                    qos=QoS(min_bandwidth=1 * GB))
    )
    assert offer.backend == "ephemeralfs"


def test_workflowspec_rejects_mixed_runtime_and_streams():
    from repro.orchestrator import WorkflowSpec

    with pytest.raises(ValueError, match="storage_spec replaces"):
        WorkflowSpec("j", 1, runtime="docker",
                     storage_spec=StorageSpec("j", nodes=1))
    with pytest.raises(ValueError, match="storage_spec replaces"):
        WorkflowSpec("j", 1, n_streams=16,
                     storage_spec=StorageSpec("j", nodes=1))


def test_negotiations_cached_accumulates_across_cache_swaps(svc):
    """``negotiations_cached`` is a campaign-lifetime counter: it must
    increment per hit, never be assigned from the live cache's own ``hits``
    (a swapped/reset cache would silently rewind the stat)."""
    from repro.provision.negotiation import OfferCache

    spec = StorageSpec("shape", nodes=1, managers=("ephemeralfs",))
    svc.negotiate(spec)                        # miss: scores backends
    svc.negotiate(spec)                        # hit
    assert svc.stats.negotiations_cached == 1
    # swap in a fresh cache mid-campaign (epoch reset, hits == 0)
    svc._offer_cache = OfferCache()
    svc.negotiate(spec)                        # miss in the new cache
    svc.negotiate(spec)                        # hit in the new cache
    assert svc._offer_cache.hits == 1
    assert svc.stats.negotiations_cached == 2  # accumulated, not rewound
    assert svc.stats.negotiations == 4
