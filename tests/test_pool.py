"""Persistent-pool subsystem: ledger, leases, catalog, eviction, teardown
discipline, and the orchestrator's pool-backed fast path.

The hypothesis-driven sweeps live in test_pool_props.py (skipped when
hypothesis is absent); everything here is deterministic, including a
seeded-random invariant soak so the core invariants are exercised even
without hypothesis installed.
"""

import random

import pytest

from repro.core import AllocationError, Scheduler, StorageRequest, dom_cluster
from repro.orchestrator import (
    DataAwarePolicy,
    JobState,
    Orchestrator,
    WorkflowSpec,
    format_report,
    summarize,
)
from repro.pool import (
    DatasetRef,
    PoolManager,
    PoolState,
)
from repro.runtime import FaultInjector, FaultSpec

GB = 1e9
TB = 1e12


def mk_manager(**kw) -> PoolManager:
    return PoolManager(Scheduler(dom_cluster()), **kw)


# -- pools pin nodes through the scheduler ------------------------------------
def test_create_pool_pins_nodes_and_teardown_returns_them():
    mgr = mk_manager()
    pool = mgr.create_pool(nodes=2)
    assert mgr.scheduler.free_counts() == (8, 2)
    assert pool.state is PoolState.ACTIVE
    assert pool.capacity_bytes == pytest.approx(2 * 2 * 5.9 * TB)
    assert mgr.retire(pool, now=1.0) is True          # no leases -> immediate
    assert pool.state is PoolState.RETIRED
    assert mgr.scheduler.free_counts() == (8, 4)


def test_node_never_in_two_live_pools():
    mgr = mk_manager()
    a = mgr.create_pool(nodes=2)
    b = mgr.create_pool(nodes=2)
    assert not a.storage_node_ids & b.storage_node_ids
    with pytest.raises(AllocationError):              # inventory exhausted
        mgr.create_pool(nodes=1)
    mgr.check_invariants()
    mgr.retire(a, now=0.0)
    c = mgr.create_pool(nodes=2)                      # reuses a's nodes
    assert not c.storage_node_ids & b.storage_node_ids
    mgr.check_invariants()


def test_create_pool_by_capacity():
    mgr = mk_manager()
    pool = mgr.create_pool(capacity_bytes=20 * TB)    # 11.8 TB/node -> 2 nodes
    assert len(pool.allocation.storage_nodes) == 2


def test_cap_bytes_caps_ledger_below_hardware():
    mgr = mk_manager()
    pool = mgr.create_pool(nodes=2, cap_bytes=100 * GB)
    assert pool.capacity_bytes == 100 * GB


# -- capacity ledger ------------------------------------------------------------
def test_ledger_never_oversubscribed_and_acquire_fails_when_full():
    mgr = mk_manager()
    pool = mgr.create_pool(nodes=1, cap_bytes=100 * GB)
    d1 = DatasetRef("d1", 60 * GB)
    lease = mgr.try_acquire("a", [d1], scratch_bytes=30 * GB, now=0.0)
    assert lease is not None
    assert pool.used_bytes == pytest.approx(90 * GB)
    # 60 GB more can never fit while d1 is pinned and 30 GB scratch is held
    assert mgr.try_acquire("b", [DatasetRef("d2", 60 * GB)], now=1.0) is None
    mgr.check_invariants()
    mgr.on_stage_in_complete(lease, 2.0)
    mgr.release(lease, 3.0)
    assert pool.scratch_bytes == 0.0
    assert pool.used_bytes == pytest.approx(60 * GB)   # d1 persists, unpinned
    # now d2 fits by evicting LRU d1
    lease2 = mgr.try_acquire("b", [DatasetRef("d2", 60 * GB)], now=4.0)
    assert lease2 is not None
    assert mgr.evictor.evictions == 1
    mgr.check_invariants()


def test_working_set_larger_than_any_pool_is_unleasable():
    mgr = mk_manager()
    mgr.create_pool(nodes=1, cap_bytes=50 * GB)
    big = [DatasetRef("huge", 80 * GB)]
    assert not mgr.feasible(big)
    assert mgr.try_acquire("j", big, now=0.0) is None


# -- hits, misses, and the staleness invariant -----------------------------------
def test_second_reference_is_a_hit_and_saves_bytes():
    mgr = mk_manager()
    mgr.create_pool(nodes=2)
    d = DatasetRef("shared", 40 * GB)
    l1 = mgr.try_acquire("first", [d], now=0.0)
    assert l1.misses == 1 and l1.hits == 0
    mgr.on_stage_in_complete(l1, 1.0)
    l2 = mgr.try_acquire("second", [d], now=2.0)      # while l1 still live
    assert l2.hits == 1 and l2.misses == 0
    assert l2.resident_bytes == 40 * GB
    assert mgr.stats.bytes_saved == 0.0               # not yet: counts at stage-in
    mgr.on_stage_in_complete(l2, 2.5)                 # all-hit stage-in completes
    mgr.release(l1, 3.0)
    mgr.release(l2, 4.0)
    assert mgr.stats.dataset_hits == 1 and mgr.stats.dataset_misses == 1
    assert mgr.stats.bytes_saved == 40 * GB


def test_evicted_dataset_is_restaged_not_served_stale():
    mgr = mk_manager()
    mgr.create_pool(nodes=1, cap_bytes=100 * GB)
    d_old = DatasetRef("old", 60 * GB)
    l1 = mgr.try_acquire("a", [d_old], now=0.0)
    mgr.on_stage_in_complete(l1, 1.0)
    mgr.release(l1, 2.0)
    # pressure evicts d_old
    l2 = mgr.try_acquire("b", [DatasetRef("new", 70 * GB)], now=3.0)
    assert l2 is not None and mgr.evictor.evictions == 1
    assert not mgr.catalog.resident(l2.pool_id, "old")
    # next reference to d_old is a miss: it must re-stage
    mgr.on_stage_in_complete(l2, 4.0)
    mgr.release(l2, 5.0)
    l3 = mgr.try_acquire("c", [d_old], now=6.0)
    assert l3 is not None and l3.misses == 1 and l3.hits == 0
    assert d_old in l3.missing


def test_pinned_and_inflight_datasets_are_not_evictable():
    mgr = mk_manager()
    mgr.create_pool(nodes=1, cap_bytes=100 * GB)
    d = DatasetRef("pinned", 60 * GB)
    l1 = mgr.try_acquire("holder", [d], now=0.0)      # INFLIGHT + pinned
    # 50 GB can't fit: the only evictable candidate set is empty
    assert mgr.try_acquire("b", [DatasetRef("x", 50 * GB)], now=1.0) is None
    mgr.on_stage_in_complete(l1, 2.0)                 # RESIDENT, still pinned
    assert mgr.try_acquire("b", [DatasetRef("x", 50 * GB)], now=3.0) is None
    mgr.release(l1, 4.0)                              # unpinned -> evictable
    assert mgr.try_acquire("b", [DatasetRef("x", 50 * GB)], now=5.0) is not None
    mgr.check_invariants()


def test_faulted_stage_rolls_back_inflight_charge():
    mgr = mk_manager()
    pool = mgr.create_pool(nodes=1, cap_bytes=100 * GB)
    d = DatasetRef("doomed", 60 * GB)
    lease = mgr.try_acquire("a", [d], scratch_bytes=10 * GB, now=0.0)
    assert pool.used_bytes == pytest.approx(70 * GB)
    # stage-in fault: release WITHOUT on_stage_in_complete
    mgr.release(lease, 1.0)
    assert pool.used_bytes == 0.0                      # no ghost bytes
    assert mgr.catalog.lookup(pool.pool_id, "doomed") is None
    mgr.check_invariants()


def test_concurrent_inflight_is_charged_once():
    mgr = mk_manager()
    pool = mgr.create_pool(nodes=1, cap_bytes=200 * GB)
    d = DatasetRef("shared", 60 * GB)
    l1 = mgr.try_acquire("a", [d], now=0.0)
    l2 = mgr.try_acquire("b", [d], now=0.5)            # INFLIGHT: miss, no recharge
    assert l2.misses == 1
    assert pool.used_bytes == pytest.approx(60 * GB)
    mgr.on_stage_in_complete(l1, 1.0)
    mgr.release(l1, 2.0)
    mgr.release(l2, 3.0)
    assert pool.used_bytes == pytest.approx(60 * GB)   # resident survives
    mgr.check_invariants()


# -- teardown discipline ----------------------------------------------------------
def test_teardown_only_on_last_lease_drain():
    mgr = mk_manager()
    pool = mgr.create_pool(nodes=2)
    d = DatasetRef("d", GB)
    l1 = mgr.try_acquire("a", [d], now=0.0)
    l2 = mgr.try_acquire("b", [d], now=0.0)
    assert mgr.retire(pool, now=1.0) is False          # live leases: draining
    assert pool.state is PoolState.DRAINING
    assert mgr.try_acquire("c", [d], now=1.5) is None  # draining grants nothing
    assert mgr.release(l1, 2.0) is False               # not the last lease
    assert pool.state is PoolState.DRAINING
    assert mgr.release(l2, 3.0) is True                # last lease -> teardown
    assert pool.state is PoolState.RETIRED
    assert mgr.scheduler.free_counts() == (8, 4)


def test_ttl_reaps_only_idle_pools():
    mgr = mk_manager(ttl_s=100.0)
    idle = mgr.create_pool(nodes=1, now=0.0)
    busy = mgr.create_pool(nodes=1, now=0.0)
    lease = mgr.try_acquire("j", [DatasetRef("d", GB)], now=10.0)
    assert lease.pool_id in (idle.pool_id, busy.pool_id)
    holder = mgr.get(lease.pool_id)
    other = idle if holder is busy else busy
    assert mgr.reap_idle(now=50.0) == []               # not idle long enough
    reaped = mgr.reap_idle(now=150.0)
    assert reaped == [other]                           # leased pool survives
    assert holder.state is PoolState.ACTIVE
    mgr.release(lease, 200.0)
    assert mgr.reap_idle(now=250.0) == []              # idle 50s < ttl
    assert mgr.reap_idle(now=301.0) == [holder]        # idle >= ttl
    assert mgr.scheduler.free_counts() == (8, 4)


def test_ttl_disabled_never_reaps():
    mgr = mk_manager()                                  # ttl_s=None
    mgr.create_pool(nodes=1, now=0.0)
    assert mgr.reap_idle(now=1e12) == []


# -- seeded-random invariant soak (runs without hypothesis) ------------------------
def test_random_ops_preserve_invariants():
    rng = random.Random(1234)
    mgr = mk_manager(ttl_s=500.0)
    datasets = [DatasetRef(f"d{i}", (5 + 10 * (i % 7)) * GB) for i in range(12)]
    live_leases = []
    staged = set()
    now = 0.0
    for step in range(400):
        now += rng.random() * 10
        op = rng.random()
        if op < 0.15 and len(mgr.active_pools) < 4:
            try:
                mgr.create_pool(nodes=1, cap_bytes=rng.choice([80, 150, 400]) * GB,
                                now=now)
            except AllocationError:
                pass
        elif op < 0.55:
            refs = rng.sample(datasets, rng.randint(1, 3))
            lease = mgr.try_acquire(f"job{step}", refs,
                                    scratch_bytes=rng.random() * 20 * GB, now=now)
            if lease is not None:
                live_leases.append(lease)
        elif op < 0.75 and live_leases:
            lease = live_leases.pop(rng.randrange(len(live_leases)))
            if rng.random() < 0.7:
                mgr.on_stage_in_complete(lease, now)
                staged.add(lease.lease_id)
            mgr.release(lease, now)
        elif op < 0.85 and mgr.active_pools:
            pool = rng.choice(mgr.active_pools)
            mgr.retire(pool, now)
        else:
            mgr.reap_idle(now)
        mgr.check_invariants()
    for lease in live_leases:
        mgr.release(lease, now + 1)
        mgr.check_invariants()
    # every storage node is home (pools either live or cleanly retired)
    free_c, free_s = mgr.scheduler.free_counts()
    held = sum(len(p.allocation.storage_nodes) for p in mgr.live_pools)
    assert free_s + held == 4 and free_c == 8


# -- orchestrator integration --------------------------------------------------------
def _pooled_orch(**pool_kw):
    orch = Orchestrator(dom_cluster())
    mgr = orch.enable_pools(**pool_kw)
    return orch, mgr


def test_pool_backed_job_pays_lease_attach_not_deploy():
    orch, mgr = _pooled_orch(lease_attach_s=0.25)
    mgr.create_pool(nodes=2)
    d = DatasetRef("in", 10 * GB)
    job = orch.submit(WorkflowSpec("j", 2, use_pool=True, datasets=(d,),
                                   stage_in_bytes=GB, stage_out_bytes=GB,
                                   run_time_s=50.0))
    orch.engine.run()
    assert job.state is JobState.DONE
    states = [s for s, _ in job.history]
    assert states == [
        JobState.QUEUED, JobState.ALLOCATED, JobState.PROVISIONING,
        JobState.STAGING_IN, JobState.RUNNING, JobState.STAGING_OUT,
        JobState.TEARDOWN, JobState.DONE,
    ]
    spans = {s0: t1 - t0 for (s0, t0), (_, t1) in zip(job.history, job.history[1:])}
    assert spans[JobState.PROVISIONING] == pytest.approx(0.25)   # no C8 deploy
    assert spans[JobState.TEARDOWN] == pytest.approx(0.0)        # pool survives
    assert job.staged_in_bytes == pytest.approx(11 * GB)         # miss + private
    assert job.pool_id is not None
    assert mgr.get(job.pool_id).state is PoolState.ACTIVE


def test_cache_hit_fast_path_skips_shared_stage_in():
    orch, mgr = _pooled_orch()
    mgr.create_pool(nodes=2)
    d = DatasetRef("shared", 100 * GB)
    spec = lambda name: WorkflowSpec(name, 1, use_pool=True, datasets=(d,),  # noqa: E731
                                     run_time_s=10.0)
    first = orch.submit(spec("first"))
    orch.engine.run()
    second = orch.submit(spec("second"))
    orch.engine.run()
    assert first.dataset_misses == 1 and first.dataset_hits == 0
    assert second.dataset_hits == 1 and second.dataset_misses == 0
    assert second.staged_in_bytes == 0.0                  # full cache hit
    assert second.stage_in_saved_bytes == 100 * GB
    first_in = next(t1 - t0 for (s, t0), (_, t1)
                    in zip(first.history, first.history[1:])
                    if s is JobState.STAGING_IN)
    second_in = next(t1 - t0 for (s, t0), (_, t1)
                     in zip(second.history, second.history[1:])
                     if s is JobState.STAGING_IN)
    assert first_in > 0 and second_in == pytest.approx(0.0)


def test_stage_in_fault_forces_restage_on_retry():
    faults = FaultInjector(FaultSpec(stage_in_fail_p=1.0, seed=9))
    orch, mgr = _pooled_orch()
    mgr.create_pool(nodes=2)
    d = DatasetRef("flaky", 20 * GB)
    job = orch.submit(WorkflowSpec("j", 1, use_pool=True, datasets=(d,),
                                   max_retries=1))
    orch.faults = faults
    orch.engine.run()
    assert job.state is JobState.FAILED
    # both attempts were misses: the faulted stage never became resident
    assert job.dataset_misses == 2 and job.dataset_hits == 0
    assert not mgr.catalog.pools_holding("flaky")
    mgr.check_invariants()


def test_pool_job_infeasible_without_capacity_fails_fast():
    orch, mgr = _pooled_orch()
    mgr.create_pool(nodes=1, cap_bytes=10 * GB)
    job = orch.submit(WorkflowSpec("big", 1, use_pool=True,
                                   datasets=(DatasetRef("d", 50 * GB),)))
    orch.engine.run()
    assert job.state is JobState.FAILED
    assert job.failure_phase == "infeasible"


def test_use_pool_without_manager_raises():
    orch = Orchestrator(dom_cluster())
    with pytest.raises(ValueError):
        orch.submit(WorkflowSpec("j", 1, use_pool=True))


def test_spec_validation_pool_fields():
    with pytest.raises(ValueError):   # pool jobs lease, not allocate
        WorkflowSpec("bad", 1, storage=StorageRequest(nodes=1), use_pool=True)
    with pytest.raises(ValueError):   # datasets need storage or a pool
        WorkflowSpec("bad", 1, datasets=(DatasetRef("d", GB),))
    with pytest.raises(ValueError):   # DatasetRef only
        WorkflowSpec("bad", 1, use_pool=True, datasets=("d",))
    with pytest.raises(ValueError):
        DatasetRef("", GB)
    with pytest.raises(ValueError):
        DatasetRef("d", 0.0)


def test_data_aware_policy_prefers_warm_jobs():
    orch, mgr = _pooled_orch()
    mgr.create_pool(nodes=2, cap_bytes=500 * GB)
    orch.policy = DataAwarePolicy(mgr, aging_s=1e9)
    warm_ds = DatasetRef("warm", 50 * GB)
    cold_ds = DatasetRef("cold", 50 * GB)
    seed = orch.submit(WorkflowSpec("seed", 8, use_pool=True, datasets=(warm_ds,),
                                    run_time_s=10.0))
    # both wait behind seed (it holds all compute); arrival order cold-first
    cold = orch.submit(WorkflowSpec("cold", 8, use_pool=True, datasets=(cold_ds,),
                                    run_time_s=10.0))
    warm = orch.submit(WorkflowSpec("warm", 8, use_pool=True, datasets=(warm_ds,),
                                    run_time_s=10.0))
    orch.engine.run()
    assert all(j.state is JobState.DONE for j in (seed, cold, warm))
    alloc_t = {
        j.spec.name: next(t for s, t in j.history if s is JobState.ALLOCATED)
        for j in (cold, warm)
    }
    assert alloc_t["warm"] < alloc_t["cold"]          # data-aware overtake
    assert warm.dataset_hits == 1


def test_pooled_campaign_report_metrics():
    orch, mgr = _pooled_orch(ttl_s=10_000.0)
    mgr.create_pool(nodes=2)
    mgr.create_pool(nodes=2)
    orch.policy = DataAwarePolicy(mgr)
    ds = [DatasetRef(f"d{k}", (10 + 5 * k) * GB) for k in range(5)]
    specs = [
        WorkflowSpec(f"j{i:02d}", 1 + i % 3, use_pool=True,
                     datasets=(ds[i % 5], ds[(i + 1) % 5]),
                     stage_in_bytes=GB, run_time_s=15.0)
        for i in range(60)
    ]
    jobs = orch.run_campaign(specs)
    assert all(j.state is JobState.DONE for j in jobs)
    rep = summarize(jobs, n_storage_nodes=4, pools=mgr)
    assert rep.pool is not None
    assert rep.pool.hit_rate > 0.5                      # sharing pays off
    assert rep.stage_in_bytes_saved > 0
    assert rep.stage_in_bytes_saved == pytest.approx(rep.pool.stage_in_bytes_saved)
    # staged once per residency, not once per job
    assert rep.staged_in_bytes < sum(s.stage_in_bytes + s.dataset_bytes
                                     for s in specs)
    assert "hit rate" in format_report(rep)
    mgr.check_invariants()


def test_job_arriving_at_draining_pool_fails_fast_not_stranded():
    """feasible() must not count DRAINING pools: they never grant again, so
    a job relying on one would queue forever (run_campaign's terminal-state
    guarantee)."""
    orch, mgr = _pooled_orch()
    pool = mgr.create_pool(nodes=2)
    d = DatasetRef("d", 10 * GB)
    holder = orch.submit(WorkflowSpec("holder", 1, use_pool=True, datasets=(d,),
                                      run_time_s=100.0))
    orch.engine.run(until=50.0)                       # holder mid-run
    mgr.retire(pool)                                  # draining under a live lease
    late = orch.submit(WorkflowSpec("late", 1, use_pool=True, datasets=(d,)))
    orch.engine.run()
    assert holder.state is JobState.DONE
    assert late.state is JobState.FAILED              # terminal, not stranded
    assert late.failure_phase == "infeasible"
    assert pool.state is PoolState.RETIRED


def test_queued_pool_job_fails_fast_when_last_pool_retires():
    orch, mgr = _pooled_orch()
    pool = mgr.create_pool(nodes=2, cap_bytes=50 * GB)
    d = DatasetRef("d", 40 * GB)
    holder = orch.submit(WorkflowSpec("holder", 1, use_pool=True, datasets=(d,),
                                      run_time_s=100.0))
    queued = orch.submit(WorkflowSpec("queued", 1, use_pool=True,
                                      datasets=(DatasetRef("e", 40 * GB),)))
    orch.engine.run(until=50.0)
    assert queued.state is JobState.QUEUED            # no room while holder runs
    mgr.retire(pool)                                  # user retires mid-campaign
    orch.engine.run()
    assert holder.state is JobState.DONE
    assert queued.state is JobState.FAILED
    assert queued.failure_phase == "infeasible"


def test_ttl_reap_waits_for_future_arrivals():
    """A lease release between two widely-spaced arrivals must not reap the
    pool out from under the not-yet-arrived job."""
    orch, mgr = _pooled_orch(ttl_s=50.0)
    mgr.create_pool(nodes=2)
    d = DatasetRef("d", 10 * GB)
    spec = WorkflowSpec("a", 1, use_pool=True, datasets=(d,), run_time_s=10.0)
    spec_b = WorkflowSpec("b", 1, use_pool=True, datasets=(d,), run_time_s=10.0)
    jobs = orch.run_campaign([spec, spec_b], submit_times=[0.0, 500.0])
    assert all(j.state is JobState.DONE for j in jobs)
    assert jobs[1].dataset_hits == 1                  # pool survived the gap
    # with every pool job done the TTL finally applies
    assert orch.engine.now >= 500.0
    orch.engine.run()
    assert len(mgr.live_pools) == 0


def test_pool_created_midcampaign_gets_engine_time():
    orch, mgr = _pooled_orch(ttl_s=1000.0)
    mgr.create_pool(nodes=2)
    made = []
    orch.engine.at(300.0, lambda: made.append(mgr.create_pool(nodes=2)))
    orch.submit(WorkflowSpec("j", 1, use_pool=True,
                             datasets=(DatasetRef("d", GB),), run_time_s=400.0))
    orch.engine.run()
    assert made[0].created_at == 300.0                # engine clock, not 0.0
    assert made[0].idle_since == 300.0


def test_duplicate_dataset_names_rejected_at_spec():
    with pytest.raises(ValueError):
        WorkflowSpec("dup", 1, use_pool=True,
                     datasets=(DatasetRef("a", GB), DatasetRef("a", 2 * GB)))


def test_mixed_campaign_pool_and_jobscoped_coexist():
    orch, mgr = _pooled_orch()
    mgr.create_pool(nodes=2)                            # 2 nodes left for jobs
    d = DatasetRef("d", 20 * GB)
    specs = [
        WorkflowSpec("pooled", 2, use_pool=True, datasets=(d,), run_time_s=20.0),
        WorkflowSpec("scoped", 2, storage=StorageRequest(nodes=2),
                     stage_in_bytes=5 * GB, run_time_s=20.0),
        WorkflowSpec("compute", 1, run_time_s=5.0),
    ]
    jobs = orch.run_campaign(specs)
    assert all(j.state is JobState.DONE for j in jobs)
    assert orch.scheduler.free_counts() == (8, 2)       # pool still holds 2
    mgr.check_invariants()
