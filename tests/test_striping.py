"""Striping math: unit + hypothesis property tests."""

import pytest

pytest.importorskip("hypothesis")
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core.striping import (
    StripeConfig,
    bytes_per_target,
    extents_for_range,
    targets_touched,
)


def test_single_chunk():
    cfg = StripeConfig(stripe_size=1024, n_targets=4)
    exts = list(extents_for_range(cfg, 0, 100))
    assert len(exts) == 1
    assert exts[0].target == 0 and exts[0].length == 100


def test_crosses_chunks_round_robin():
    cfg = StripeConfig(stripe_size=100, n_targets=3)
    exts = list(extents_for_range(cfg, 50, 200))
    assert [e.target for e in exts] == [0, 1, 2]
    assert [e.length for e in exts] == [50, 100, 50]
    assert sum(e.length for e in exts) == 200


def test_shift_rotates_targets():
    cfg = StripeConfig(stripe_size=100, n_targets=4, shift=2)
    exts = list(extents_for_range(cfg, 0, 400))
    assert [e.target for e in exts] == [2, 3, 0, 1]


@settings(max_examples=200, deadline=None)
@given(
    stripe=st.integers(1, 1 << 20),
    n_targets=st.integers(1, 32),
    shift=st.integers(0, 31),
    offset=st.integers(0, 1 << 24),
    length=st.integers(0, 1 << 22),
)
def test_extents_partition_range(stripe, n_targets, shift, offset, length):
    """Extents tile [offset, offset+length) exactly, contiguously, and each
    lies within one chunk on the correct target."""
    cfg = StripeConfig(stripe, n_targets, shift % n_targets)
    pos = offset
    total = 0
    for e in extents_for_range(cfg, offset, length):
        assert e.file_offset == pos
        assert 0 <= e.chunk_offset < stripe
        assert e.chunk_offset + e.length <= stripe
        assert e.chunk_id == e.file_offset // stripe
        assert e.target == cfg.target_of_chunk(e.chunk_id)
        assert e.length > 0
        pos += e.length
        total += e.length
    assert total == length


@settings(max_examples=100, deadline=None)
@given(
    stripe=st.integers(1, 4096),
    n_targets=st.integers(1, 8),
    offset=st.integers(0, 1 << 16),
    length=st.integers(1, 1 << 16),
)
def test_bytes_per_target_balanced(stripe, n_targets, offset, length):
    cfg = StripeConfig(stripe, n_targets)
    per = bytes_per_target(cfg, offset, length)
    assert sum(per.values()) == length
    assert set(per) <= set(range(n_targets))
    # round-robin balance: targets differ by at most one stripe (+ partials)
    if len(per) == n_targets and n_targets > 1:
        assert max(per.values()) - min(per.values()) <= 2 * stripe


def test_targets_touched_subset():
    cfg = StripeConfig(100, 8)
    assert targets_touched(cfg, 0, 100) == {0}
    assert targets_touched(cfg, 0, 800) == set(range(8))
