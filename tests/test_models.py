"""Per-arch smoke tests (reduced configs): forward/train-step on CPU with
shape checks + finiteness; decode-vs-full-forward consistency; kernel-path
equivalence; MoE behaviours."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from tests.conftest import JAX_DRIFT_REASON, jax_api_drifted

pytestmark = pytest.mark.skipif(jax_api_drifted(), reason=JAX_DRIFT_REASON)

from repro.configs import ARCH_IDS, get_config, get_smoke, shapes_for  # noqa: E402
from repro.models import build_model  # noqa: E402
from repro.models.attention import blockwise_sdpa, sdpa  # noqa: E402
from repro.runtime import (  # noqa: E402
    RuntimeConfig,
    make_train_state,
    make_train_step,
)

B, S = 2, 32


def _batch(cfg, seq=S, batch=B, with_labels=True):
    rng = jax.random.PRNGKey(7)
    out = {"tokens": jax.random.randint(rng, (batch, seq), 0, cfg.vocab_size)}
    if with_labels:
        out["labels"] = jax.random.randint(rng, (batch, seq), 0, cfg.vocab_size)
    if cfg.family == "vlm":
        out["patch_embeds"] = 0.1 * jax.random.normal(
            rng, (batch, cfg.n_patches, cfg.d_model))
    if cfg.family == "audio":
        out["frames"] = 0.1 * jax.random.normal(
            rng, (batch, cfg.encoder_seq, cfg.d_model))
    return out


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_loss_finite(arch):
    cfg = get_smoke(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    loss, metrics = model.loss(params, _batch(cfg))
    assert np.isfinite(float(loss))
    assert float(loss) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step_improves(arch):
    """One-layer-of-substance check: a few SGD-ish steps reduce the loss on a
    repeated batch and produce no NaNs anywhere."""
    cfg = get_smoke(arch)
    model = build_model(cfg)
    rt = RuntimeConfig(remat=None, zero1=False)
    state = make_train_state(model, jax.random.PRNGKey(0), rt)
    step = jax.jit(make_train_step(model, rt))
    batch = _batch(cfg)
    losses = []
    for _ in range(5):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert all(np.isfinite(l) for l in losses)
    assert losses[-1] < losses[0]
    for leaf in jax.tree.leaves(state.params):
        assert bool(jnp.all(jnp.isfinite(leaf.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_full_forward(arch):
    cfg = get_smoke(arch)
    if cfg.family == "moe":
        cfg = dataclasses.replace(cfg, moe_capacity_factor=8.0)  # no drops
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n_pref = cfg.n_patches if cfg.family == "vlm" else 0
    S_max = S + 4 + n_pref
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + 4), 0, cfg.vocab_size)
    batch = dict(_batch(cfg, with_labels=False), tokens=toks[:, :S])
    _, cache = model.prefill(params, batch, S_max)
    for t in range(4):
        logits, cache = model.decode_step(params, cache, {"token": toks[:, S + t]})
    full_logits, _ = model.prefill(params, dict(batch, tokens=toks), S_max)
    scale = float(jnp.max(jnp.abs(full_logits))) + 1e-9
    err = float(jnp.max(jnp.abs(logits - full_logits)))
    assert err / scale < 2e-2, (arch, err, scale)


@pytest.mark.parametrize("arch", ["phi4-mini-3.8b", "zamba2-7b", "gemma3-12b"])
def test_kernel_path_matches_reference(arch):
    cfg = get_smoke(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, seq=64)
    l_ref, _ = model.loss(params, batch)
    l_ker, _ = model.loss(params, batch, use_kernels=True)
    assert abs(float(l_ref) - float(l_ker)) < 1e-4


def test_output_logits_shape_padded_vocab():
    cfg = get_smoke("internvl2-2b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, with_labels=False)
    logits, cache = model.prefill(params, batch, S + cfg.n_patches + 2)
    assert logits.shape == (B, cfg.padded_vocab)
    assert cfg.padded_vocab % 256 == 0


def test_gemma3_local_cache_is_windowed():
    cfg = get_smoke("gemma3-12b")
    model = build_model(cfg)
    cache = model.init_cache(B, 128)
    W = cfg.sliding_window
    assert cache["lk"].shape[-3] == W        # ring buffer, not full length
    assert cache["gk"].shape[-3] == 128      # global layers keep full cache


def test_moe_capacity_drops_tokens():
    cfg = dataclasses.replace(get_smoke("granite-moe-1b-a400m"),
                              moe_capacity_factor=0.25)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    loss, _ = model.loss(params, _batch(cfg))
    assert np.isfinite(float(loss))  # drops degrade, never break


def test_moe_aux_loss_positive():
    cfg = get_smoke("qwen3-moe-30b-a3b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    _, metrics = model.loss(params, _batch(cfg))
    assert float(metrics["aux"]) >= 1.0  # >= 1 by Cauchy-Schwarz, = 1 balanced


def test_blockwise_equals_dense_attention():
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (2, 256, 4, 32))
    k = jax.random.normal(ks[1], (2, 256, 2, 32))
    v = jax.random.normal(ks[2], (2, 256, 2, 32))
    for w in (None, 100):
        a = sdpa(q, k, v, causal=True, window=w)
        b = blockwise_sdpa(q, k, v, causal=True, window=w, q_chunk=64, k_chunk=128)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5, rtol=2e-5)


def test_param_count_matches_configs():
    """Analytic param_count (used for roofline MODEL_FLOPS) tracks actual
    init within 12% for dense archs (padding + analytic approximations)."""
    for arch in ("phi4-mini-3.8b", "qwen3-14b"):
        cfg = get_smoke(arch)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        actual = sum(p.size for p in jax.tree.leaves(params))
        assert abs(actual - cfg.param_count()) / actual < 0.12


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_input_specs_cover_all_shapes(arch):
    model = build_model(get_config(arch))
    for shape in shapes_for(arch):
        specs = model.input_specs(shape)
        assert specs, (arch, shape.name)
        for k, v in specs.items():
            assert isinstance(v, jax.ShapeDtypeStruct)
            assert v.shape[0] == shape.global_batch
