"""Multi-device distribution tests. These run in SUBPROCESSES because the
host-platform device count must be set before jax initializes (and the rest
of the suite must see 1 device)."""

import os
import subprocess
import sys
import textwrap

import pytest

from tests.conftest import JAX_DRIFT_REASON, jax_api_drifted

pytestmark = pytest.mark.skipif(jax_api_drifted(), reason=JAX_DRIFT_REASON)

REPO_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str, devices: int = 8, timeout: int = 420) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = REPO_SRC
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=timeout,
    )
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    return out.stdout


def test_sharded_train_step_matches_single_device():
    """The 4x2 sharded train step computes the same loss trajectory as the
    unsharded one (same model, same batch)."""
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_smoke
        from repro.models import build_model
        from repro.runtime import RuntimeConfig, make_train_state, jit_train_step, make_train_step
        from repro.launch.mesh import make_smoke_mesh

        cfg = get_smoke("phi4-mini-3.8b")
        model = build_model(cfg)
        rt = RuntimeConfig(remat=None, zero1=True, accum=2)
        state = make_train_state(model, jax.random.PRNGKey(0), rt)
        batch = {
            "tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, cfg.vocab_size),
            "labels": jax.random.randint(jax.random.PRNGKey(2), (8, 32), 0, cfg.vocab_size),
        }
        # single-device reference
        ref_step = jax.jit(make_train_step(model, rt))
        ref_state, ref_m = ref_step(state, batch)

        mesh = make_smoke_mesh(4, 2)
        state2 = make_train_state(model, jax.random.PRNGKey(0), rt)
        step, st_sh, b_sh = jit_train_step(model, mesh, rt, state2, batch)
        state2 = jax.device_put(state2, st_sh)
        jbatch = jax.device_put(batch, b_sh)
        new_state, m = step(state2, jbatch)
        a, b = float(ref_m["loss"]), float(m["loss"])
        assert abs(a - b) / abs(a) < 2e-3, (a, b)
        print("OK", a, b)
    """)


def test_decode_step_sharded_cache():
    _run("""
        import jax, jax.numpy as jnp
        from repro.configs import get_smoke
        from repro.models import build_model
        from repro.runtime import RuntimeConfig, jit_decode_step
        from repro.launch.mesh import make_smoke_mesh

        cfg = get_smoke("qwen3-14b")
        model = build_model(cfg)
        rt = RuntimeConfig()
        params = model.init(jax.random.PRNGKey(0))
        cache = model.init_cache(8, 64)
        batch = {"token": jnp.ones((8,), jnp.int32)}
        mesh = make_smoke_mesh(2, 4)
        step, p_sh, c_sh, b_sh = jit_decode_step(model, mesh, rt, params, cache, batch)
        params = jax.device_put(params, p_sh)
        cache = jax.device_put(cache, c_sh)
        batch = jax.device_put(batch, b_sh)
        logits, cache = step(params, cache, batch)
        assert logits.shape == (8, cfg.padded_vocab)
        assert int(cache["pos"]) == 1
        # one more step re-uses the donated cache
        logits, cache = step(params, cache, {"token": jnp.zeros((8,), jnp.int32)})
        assert int(cache["pos"]) == 2
        print("OK")
    """)


def test_dryrun_cell_small_mesh_moe():
    """MoE lowering + compile + roofline extraction on a small mesh —
    the dry-run machinery itself, in miniature."""
    _run("""
        import jax, jax.numpy as jnp
        from repro.configs import get_smoke, TRAIN_4K
        import dataclasses
        from repro.models import build_model
        from repro.runtime import RuntimeConfig, make_train_state, jit_train_step
        from repro.runtime.costs import hlo_collective_bytes, jaxpr_costs
        from repro.runtime.parallel import make_train_step
        from repro.launch.mesh import make_smoke_mesh

        cfg = get_smoke("qwen3-moe-30b-a3b")
        model = build_model(cfg)
        rt = RuntimeConfig(accum=2)
        mesh = make_smoke_mesh(2, 4)
        rng_sds = jax.ShapeDtypeStruct((2,), jnp.uint32)
        state_sds = jax.eval_shape(lambda r: make_train_state(model, r, rt), rng_sds)
        specs = {
            "tokens": jax.ShapeDtypeStruct((8, 64), jnp.int32),
            "labels": jax.ShapeDtypeStruct((8, 64), jnp.int32),
        }
        step, *_ = jit_train_step(model, mesh, rt, state_sds, specs)
        lowered = step.lower(state_sds, specs)
        compiled = lowered.compile()
        mem = compiled.memory_analysis()
        coll = hlo_collective_bytes(compiled.as_text())
        alg = jaxpr_costs(jax.make_jaxpr(make_train_step(model, rt))(state_sds, specs))
        assert alg["flops"] > 0
        assert coll["count"] > 0            # EP dispatch produced collectives
        assert mem.temp_size_in_bytes > 0
        print("OK flops", alg["flops"], "coll", coll["count"])
    """)


@pytest.mark.slow
def test_production_mesh_shapes():
    _run("""
        from repro.launch.mesh import make_production_mesh
        m1 = make_production_mesh()
        assert dict(m1.shape) == {"data": 16, "model": 16}
        m2 = make_production_mesh(multi_pod=True)
        assert dict(m2.shape) == {"pod": 2, "data": 16, "model": 16}
        assert m2.devices.size == 512
        print("OK")
    """, devices=512)


def test_int8_allreduce_shard_map():
    """The collective that plain quantize->dequantize cannot buy under GSPMD
    (EXPERIMENTS §Perf A2/B4): int8 wire payloads via shard_map, ~1% error,
    s8 all-to-all/all-gather verified in the compiled HLO."""
    _run("""
        import jax, jax.numpy as jnp, re
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.optim.compression import int8_allreduce

        mesh = jax.make_mesh((8,), ("data",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        x = jax.random.normal(jax.random.PRNGKey(0), (8, 37, 5))

        def f(xl):
            return int8_allreduce(xl[0], "data")[None]

        g = shard_map(f, mesh=mesh, in_specs=P("data"), out_specs=P("data"))
        out = g(x)
        want = jnp.mean(x, axis=0)
        rel = float(jnp.max(jnp.abs(out[0] - want))) / float(jnp.max(jnp.abs(want)))
        assert rel < 0.05, rel
        hlo = jax.jit(g).lower(x).compile().as_text()
        s8 = [l for l in hlo.splitlines()
              if re.search(r"= s8.*(all-to-all|all-gather)", l)]
        assert len(s8) >= 2, "int8 payloads not on the wire"
        print("OK", rel)
    """)
