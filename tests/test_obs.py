"""PR 6 observability suite: recorder wiring, determinism, exporters,
critical path, metrics, and the hot-loop import guard.

The contract under test: tracing is opt-in (every component defaults to
the shared ``NULL_RECORDER`` no-op), strictly read-only (a campaign
replayed with the recorder on produces bit-identical ``JobRecord.history``
and the same engine event count), and complete (spans mirror the history
log exactly; the critical-path buckets tile the makespan).
"""

import importlib.util
import json
import os
import random

import pytest

from repro.core import dom_cluster, synthetic_cluster
from repro.obs import (
    NULL_RECORDER,
    Counter,
    Gauge,
    Histogram,
    MetricsHub,
    NullRecorder,
    TimeSeries,
    TraceRecorder,
    critical_path,
    format_critical_path,
    jsonl_records,
    write_chrome_trace,
    write_jsonl,
)
from repro.obs.profile import PHASES
from repro.orchestrator import (
    BackfillPolicy,
    DataAwarePolicy,
    Orchestrator,
    WorkflowSpec,
    format_report,
    poisson_arrivals,
    summarize,
)
from repro.pool import DatasetRef
from repro.provision import StorageSpec
from repro.runtime import FaultInjector, FaultSpec

from test_campaign_scale import _campaign_fingerprint

GB = 1e9


def _traced_campaign(n_jobs=40, seed=3, *, pools=True, faults=True,
                     sample_every_s=30.0):
    """A small mixed campaign (faults, retries, pools, checkpoints) with a
    full recorder attached; returns (orch, jobs, recorder, hub)."""
    hub = MetricsHub()
    rec = TraceRecorder(metrics=hub, sample_every_s=sample_every_s)
    orch = Orchestrator(
        dom_cluster(),
        policy=BackfillPolicy(),
        faults=FaultInjector(
            FaultSpec(stage_in_fail_p=0.1, run_fail_p=0.08, seed=seed)
        ) if faults else None,
        recorder=rec,
    )
    if pools:
        mgr = orch.enable_pools(ttl_s=800.0)
        mgr.create_pool(nodes=1, cap_bytes=40 * GB)
        orch.policy = DataAwarePolicy(orch.provision)
    rng = random.Random(seed)
    specs = []
    for i in range(n_jobs):
        name = f"job{i:03d}"
        r = rng.random()
        if pools and r < 0.4:
            ds = DatasetRef(f"d{rng.randint(0, 7)}", (10 + 5 * (i % 4)) * GB)
            specs.append(
                WorkflowSpec(name, 1 + i % 2, use_pool=True, datasets=(ds,),
                             stage_in_bytes=1 * GB, run_time_s=20.0 + i % 7,
                             max_retries=2)
            )
        elif r < 0.8:
            specs.append(
                WorkflowSpec(
                    name, 1 + i % 3,
                    storage_spec=StorageSpec(
                        name, nodes=1 + i % 2, managers=("ephemeralfs",),
                        stage_in_bytes=5 * GB, stage_out_bytes=1 * GB,
                    ),
                    run_time_s=30.0 + i % 11, max_retries=2,
                    checkpoint_every_s=10.0, checkpoint_bytes=1 * GB,
                )
            )
        else:
            specs.append(WorkflowSpec(name, 1 + i % 4, run_time_s=15.0 + i % 5))
    jobs = orch.run_campaign(
        specs, submit_times=poisson_arrivals(0.5, n_jobs, seed=seed)
    )
    return orch, jobs, rec, hub


# -- opt-in wiring ------------------------------------------------------------

def test_null_recorder_is_the_default_everywhere():
    orch = Orchestrator(synthetic_cluster(4, 2))
    assert orch.recorder is NULL_RECORDER
    assert orch.engine.recorder is None
    assert orch.provision.recorder is NULL_RECORDER
    assert orch.scheduler.recorder is NULL_RECORDER
    mgr = orch.enable_pools()
    assert mgr.recorder is NULL_RECORDER
    assert mgr.evictor.recorder is NULL_RECORDER
    assert NullRecorder.enabled is False and not NULL_RECORDER.enabled


def test_null_recorder_methods_are_noops():
    rec = NullRecorder()
    assert rec.bind(object()) is rec
    for call in (
        lambda: rec.transition(None, None),
        lambda: rec.grant(None, None),
        lambda: rec.release(None),
        lambda: rec.fault(None, "run", True),
        lambda: rec.negotiation("s", None, cached=True),
        lambda: rec.eviction(0, "d", 1.0),
        lambda: rec.engine_sample(0.0, 0, 0),
    ):
        assert call() is None


def test_bind_propagates_to_every_layer():
    rec = TraceRecorder()
    orch = Orchestrator(synthetic_cluster(4, 2), recorder=rec)
    assert orch.recorder is rec
    assert orch.engine.recorder is rec
    assert orch.provision.recorder is rec
    assert orch.scheduler.recorder is rec
    mgr = orch.enable_pools()     # created after bind: still propagated
    assert mgr.recorder is rec
    assert mgr.evictor.recorder is rec


# -- determinism: tracing must not perturb the campaign -----------------------

@pytest.mark.parametrize("policy_name", ["backfill", "data-aware"])
def test_recorder_on_campaign_is_bit_identical(policy_name):
    """The acceptance regression: a seeded 500-job campaign (faults,
    retries, pools, Poisson arrivals) replayed with a full recorder +
    metrics hub produces identical ``JobRecord.history``, identical
    allocations, and the same engine event count."""
    off_stats, on_stats = {}, {}
    off = _campaign_fingerprint(policy_name, True, 42, 500, dom_cluster,
                                out=off_stats)
    rec = TraceRecorder(metrics=MetricsHub(), sample_every_s=60.0)
    on = _campaign_fingerprint(policy_name, True, 42, 500, dom_cluster,
                               recorder=rec, out=on_stats)
    assert off == on
    assert off_stats["events_processed"] == on_stats["events_processed"]
    assert len(rec.spans) == 500


# -- spans mirror the history log --------------------------------------------

def test_spans_match_job_history_exactly():
    _, jobs, rec, _ = _traced_campaign(30)
    assert len(rec.spans) == len(jobs)
    for job in jobs:
        hist = job.history
        expected = [
            (s0.value, t0, t1) for (s0, t0), (_, t1) in zip(hist, hist[1:])
        ]
        final_state, final_t = hist[-1]
        expected.append((final_state.value, final_t, final_t))
        assert rec.spans[job.job_id] == expected
        meta = rec.job_meta[job.job_id]
        assert meta["name"] == job.spec.name
        assert meta["submit"] == job.submit_time
        if job.done:
            assert meta["backend"] is not None


def test_materialization_is_incremental_mid_campaign():
    rec = TraceRecorder()
    orch = Orchestrator(synthetic_cluster(4, 2), recorder=rec)
    for i in range(6):
        orch.submit(WorkflowSpec(
            f"j{i}", 1,
            storage_spec=StorageSpec(f"j{i}", nodes=1, managers=("ephemeralfs",)),
            run_time_s=50.0,
        ), at=float(i))
    orch.engine.run(until=30.0)
    mid = {j: list(s) for j, s in rec.spans.items()}
    assert mid                                    # something closed already
    orch.engine.run()
    assert all(j.done for j in orch.jobs)
    for jid, spans in mid.items():
        # the mid-campaign read is a prefix of the final materialization
        assert rec.spans[jid][: len(spans)] == spans
    assert all(s[-1][0] == "done" for s in rec.spans.values())


# -- live vs batch reporting with tracing on ----------------------------------

def test_live_report_matches_batch_summarize_with_tracing_on():
    hub = MetricsHub()
    rec = TraceRecorder(metrics=hub)
    orch = Orchestrator(
        dom_cluster(),
        policy=BackfillPolicy(),
        faults=FaultInjector(FaultSpec(run_fail_p=0.1, seed=5)),
        recorder=rec,
    )
    rng = random.Random(5)
    for i in range(40):
        orch.submit(
            WorkflowSpec(
                f"j{i:02d}", rng.randint(1, 4),
                storage_spec=StorageSpec(
                    f"j{i:02d}", nodes=rng.randint(1, 2),
                    managers=("ephemeralfs",),
                    stage_in_bytes=rng.uniform(1, 10) * GB,
                ),
                run_time_s=rng.uniform(10, 60), max_retries=2,
                checkpoint_every_s=15.0, checkpoint_bytes=1 * GB,
            ),
            at=float(i),
        )
    for t in (20.0, 90.0, 250.0):
        orch.engine.run(until=t)
        now = orch.engine.now
        live = orch.live_report(now)
        rep = summarize(orch.jobs, n_storage_nodes=4, now=now, trace=rec)
        assert live.n_jobs == rep.n_jobs
        assert live.n_done == rep.n_done
        assert live.n_failed == rep.n_failed
        assert live.retries + live.preemptions == rep.total_retries
        assert live.staged_in_bytes == pytest.approx(rep.staged_in_bytes)
        assert live.makespan_s == pytest.approx(rep.makespan_s)
    orch.engine.run()
    final = summarize(orch.jobs, n_storage_nodes=4, trace=rec)
    live = orch.live_report(orch.engine.now)
    assert live.n_done == final.n_done == 40 - final.n_failed


# -- exporters ----------------------------------------------------------------

def test_chrome_trace_is_valid_and_complete(tmp_path):
    _, jobs, rec, hub = _traced_campaign(40)
    path = tmp_path / "trace.json"
    doc = write_chrome_trace(path, rec, metrics=hub)
    with open(path) as fh:
        assert json.load(fh) == doc               # round-trips as JSON
    ev = doc["traceEvents"]
    by_ph = {}
    for e in ev:
        assert "ph" in e and "pid" in e
        by_ph.setdefault(e["ph"], []).append(e)
    procs = {e["args"]["name"] for e in by_ph["M"] if e["name"] == "process_name"}
    assert procs == {"jobs", "storage sessions", "storage pools", "metrics"}
    # one X span per non-terminal recorded phase span
    n_spans = sum(
        1 for s in rec.spans.values() for p, _, _ in s
        if p not in ("done", "failed")
    )
    job_x = [e for e in by_ph["X"] if e["cat"] == "phase"]
    assert len(job_x) == n_spans
    assert all(e["dur"] >= 0 and e["ts"] >= 0 for e in by_ph["X"])
    # every requeued fault carries a flow arrow to the next grant
    requeued = [
        (t, a) for k, t, _, a in rec.events if k == "fault" and a["requeued"]
    ]
    assert requeued, "campaign fluked: no faults requeued"
    starts = {e["id"] for e in by_ph.get("s", ())}
    ends = {e["id"] for e in by_ph.get("f", ())}
    assert starts and starts == ends
    # metrics series exported as counter events
    assert {e["name"] for e in by_ph.get("C", ())} >= {"queue_depth"}


def test_jsonl_export_round_trips(tmp_path):
    _, _, rec, _ = _traced_campaign(20)
    path = tmp_path / "trace.jsonl"
    n = write_jsonl(path, rec)
    with open(path) as fh:
        records = [json.loads(line) for line in fh]
    assert len(records) == n == len(list(jsonl_records(rec)))
    kinds = {r["type"] for r in records}
    assert kinds == {"span", "session", "event", "count"}
    n_spans = sum(len(s) for s in rec.spans.values())
    assert sum(r["type"] == "span" for r in records) == n_spans
    assert sum(r["type"] == "session" for r in records) == len(rec.sessions)


# -- critical path ------------------------------------------------------------

def test_critical_path_tiles_the_makespan():
    _, jobs, rec, _ = _traced_campaign(40)
    cp = critical_path(rec)
    assert cp is not None
    assert sum(cp.phase_s.values()) == cp.makespan_s     # exact, not approx
    t0, t1 = rec.t_range()
    assert (cp.t_start, cp.t_end) == (t0, t1)
    assert set(cp.phase_s) <= set(PHASES)
    assert cp.phase_s.get("running", 0.0) > 0
    # segments are contiguous and ordered: they tile [t_start, t_end]
    cursor = cp.t_start
    for seg in cp.segments:
        assert seg.t0 == pytest.approx(cursor, abs=1e-6)
        assert seg.t1 >= seg.t0
        cursor = seg.t1
    assert cursor == pytest.approx(cp.t_end, abs=1e-6)
    text = format_critical_path(cp, max_segments=3)
    assert "critical path:" in text and "running" in text


def test_critical_path_single_job():
    rec = TraceRecorder()
    orch = Orchestrator(synthetic_cluster(2, 1), recorder=rec)
    orch.submit(WorkflowSpec(
        "solo", 1,
        storage_spec=StorageSpec("solo", nodes=1, managers=("ephemeralfs",)),
        run_time_s=100.0,
    ))
    orch.engine.run()
    cp = critical_path(rec)
    assert sum(cp.phase_s.values()) == cp.makespan_s
    assert cp.phase_s["running"] == pytest.approx(100.0)
    jid = orch.jobs[0].job_id
    # every attributed segment belongs to the only job
    assert {seg.job_id for seg in cp.segments} <= {jid, None}
    assert any(seg.job_id == jid for seg in cp.segments)


def test_critical_path_empty_trace_is_none():
    assert critical_path(TraceRecorder()) is None


def test_summarize_attaches_critical_path_to_report():
    _, jobs, rec, _ = _traced_campaign(20)
    rep = summarize(jobs, n_storage_nodes=4, trace=rec)
    assert rep.critical_path is not None
    assert rep.critical_path.makespan_s == pytest.approx(rep.makespan_s)
    assert "critical path:" in format_report(rep)
    assert "critical path:" not in format_report(
        summarize(jobs, n_storage_nodes=4)
    )


# -- trace content: negotiation, pools, engine --------------------------------

def test_negotiation_cache_hits_counted_not_evented():
    _, _, rec, _ = _traced_campaign(40, pools=False, faults=False)
    scored = [e for e in rec.events if e[0] == "negotiation"]
    assert rec.counts["negotiation.scored"] == len(scored)
    assert rec.counts["negotiation.cache_hits"] > 0
    assert rec.counts["scheduler.grants"] == rec.counts["scheduler.releases"]
    opened = sum(
        n for k, n in rec.counts.items() if k.startswith("sessions.opened.")
    )
    assert opened == rec.counts["scheduler.grants"]


def test_pool_lease_and_eviction_events():
    orch, jobs, rec, _ = _traced_campaign(40, faults=False)
    kinds = {e[0] for e in rec.events}
    assert "pool_created" in kinds and "lease_attached" in kinds
    mgr = orch.pools
    n_evictions = sum(1 for e in rec.events if e[0] == "eviction")
    assert n_evictions == mgr.evictor.evictions
    assert rec.counts.get("pool.evictions", 0) == n_evictions
    leases = [e for e in rec.events if e[0] == "lease_attached"]
    assert len(leases) == mgr.stats.leases_granted


def test_engine_sampling_series():
    _, _, rec, hub = _traced_campaign(30)
    assert hub.samples_taken >= 1
    series = hub.series["engine_heap_depth"]
    assert len(series) >= 1
    # the closing sample sees the drained heap
    t_last, depth_last = series.last()
    assert depth_last == 0
    for probe in ("queue_depth", "free_compute_nodes", "pool_occupancy",
                  "catalog_hit_rate", "running_jobs", "jobs_done"):
        assert probe in hub.series


# -- metrics primitives -------------------------------------------------------

def test_metrics_primitives():
    c = Counter("c")
    c.inc()
    c.inc(2.5)
    assert c.value == 3.5
    g = Gauge("g")
    g.set(7.0)
    assert g.value == 7.0
    h = Histogram("h", bounds=(1.0, 10.0))
    for v in (0.5, 5.0, 50.0):
        h.observe(v)
    assert h.counts == [1, 1, 1] and h.total == 3
    assert h.min == 0.5 and h.max == 50.0 and h.mean == pytest.approx(55.5 / 3)
    s = TimeSeries("s", maxlen=3)
    for i in range(5):
        s.append(float(i), float(i * i))
    assert len(s) == 3 and s.items()[0] == (2.0, 4.0)      # ring evicted
    assert s.last() == (4.0, 16.0)


def test_metrics_hub_probes_and_snapshot():
    hub = MetricsHub(maxlen=8)
    x = {"v": 0.0}
    hub.add_probe("x", lambda: x["v"])
    for t in (0.0, 1.0, 2.0):
        x["v"] = t * 10
        hub.sample(t)
    assert hub.samples_taken == 3
    assert hub.series["x"].items() == [(0.0, 0.0), (1.0, 10.0), (2.0, 20.0)]
    assert hub.gauges["x"].value == 20.0
    hub.counter("n").inc()
    hub.histogram("d").observe(3.0)
    snap = hub.snapshot()
    json.dumps(snap)                                       # JSON-serializable
    assert snap["counters"]["n"] == 1.0
    assert snap["histograms"]["d"]["total"] == 1


# -- hot-loop import guard ----------------------------------------------------

def _load_guard():
    path = os.path.join(
        os.path.dirname(__file__), "..", "tools", "check_obs_imports.py"
    )
    spec = importlib.util.spec_from_file_location("check_obs_imports", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_hot_loop_modules_only_import_the_recorder_interface(tmp_path):
    guard = _load_guard()
    root = os.path.join(os.path.dirname(__file__), "..", "src")
    for pkg in guard.HOT_PACKAGES:
        pkg_dir = os.path.join(root, "repro", pkg)
        for dirpath, _, filenames in os.walk(pkg_dir):
            for fn in filenames:
                if fn.endswith(".py"):
                    path = os.path.join(dirpath, fn)
                    assert guard._violations_in(path, root) == [], path


def test_import_guard_flags_violations(tmp_path):
    guard = _load_guard()
    pkg = tmp_path / "repro" / "core"
    pkg.mkdir(parents=True)
    bad = pkg / "bad.py"
    bad.write_text(
        "from ..obs.export import chrome_trace\n"
        "from ..obs.trace import NULL_RECORDER\n"
        "import repro.obs\n"
        "def lazy():\n"
        "    from ..obs.profile import critical_path\n"
        "    return critical_path\n"
    )
    hits = guard._violations_in(str(bad), str(tmp_path))
    assert [line for line, _ in hits] == [1, 3]


# =============================================================================
# PR 7: SLO engine, alerting, campaign doctor, dashboard
# =============================================================================

import types

from repro.obs import (
    FIRING,
    PENDING,
    AlertEngine,
    AlertIncident,
    AlertRule,
    SLOSpec,
    SLOTracker,
    build_dashboard,
    diagnose,
    format_advisories,
    format_alerts,
    format_dashboard,
    format_slo_report,
    write_dashboard,
)


def _hub_with_series(name="v", maxlen=4096):
    hub = MetricsHub(maxlen=maxlen)
    hub.record(name, 0.0, 0.0)
    return hub


def _alerted_campaign(n_jobs=40, seed=3, **kwargs):
    """_traced_campaign with the full active layer riding the recorder."""
    hub = MetricsHub()
    slos = SLOTracker(hub, [
        SLOSpec(name="queue-p95", series="queue_depth", percentile=0.95,
                window_s=600.0, op="<=", target=200.0, objective=0.9),
        SLOSpec(name="progress", series="jobs_done", op=">=", target=0.0,
                objective=0.99),
    ])
    engine = AlertEngine(hub, [
        AlertRule(name="backlog", kind="threshold", series="queue_depth",
                  op=">=", target=1e9, for_s=60.0),
        AlertRule(name="burnout", kind="burn", slo="queue-p95", op=">=",
                  target=100.0, window_s=300.0),
    ], slos=slos)
    rec = TraceRecorder(metrics=hub, sample_every_s=30.0, alerts=engine)
    orch = Orchestrator(
        dom_cluster(),
        policy=BackfillPolicy(),
        faults=FaultInjector(
            FaultSpec(stage_in_fail_p=0.1, run_fail_p=0.08, seed=seed)
        ),
        recorder=rec,
    )
    rng = random.Random(seed)
    specs = [
        WorkflowSpec(
            f"job{i:03d}", 1 + i % 3,
            storage_spec=StorageSpec(
                f"job{i:03d}", nodes=1 + i % 2, managers=("ephemeralfs",),
                stage_in_bytes=rng.uniform(2, 8) * GB, stage_out_bytes=1 * GB,
            ),
            run_time_s=20.0 + i % 11, max_retries=2,
        )
        for i in range(n_jobs)
    ]
    jobs = orch.run_campaign(
        specs, submit_times=poisson_arrivals(0.5, n_jobs, seed=seed)
    )
    return orch, jobs, rec, hub, engine, slos


# -- metrics helpers: percentiles, windows, capped snapshot -------------------

def test_histogram_percentile_exact_cases():
    h = Histogram("h", bounds=(10.0, 20.0, 30.0))
    assert h.percentile(0.5) is None                     # empty
    h.observe(15.0)
    # a one-value histogram answers that value at every quantile
    for q in (0.0, 0.5, 0.99, 1.0):
        assert h.percentile(q) == pytest.approx(15.0)
    h2 = Histogram("h2", bounds=(10.0, 20.0, 30.0))
    for v in (5.0, 12.0, 14.0, 25.0):
        h2.observe(v)
    assert h2.percentile(1.0) == pytest.approx(25.0)     # clamps to max
    assert h2.percentile(0.0) == pytest.approx(5.0)      # clamps to min
    # p50 -> rank 2 of 4, inside the (10, 20] bucket, interpolated
    p50 = h2.percentile(0.5)
    assert 10.0 <= p50 <= 20.0
    # interpolation error is bounded by the bucket width
    exact = 13.0                                         # midpoint of 12, 14
    assert abs(p50 - exact) <= 10.0


def test_histogram_percentile_against_exact_quantiles():
    h = Histogram("u", bounds=tuple(float(b) for b in range(10, 100, 10)))
    vals = [float(v) for v in range(1, 101)]             # uniform 1..100
    for v in vals:
        h.observe(v)
    for q in (0.1, 0.25, 0.5, 0.75, 0.9, 0.99):
        exact = vals[max(0, int(q * len(vals)) - 1)]
        assert abs(h.percentile(q) - exact) <= 10.0      # one bucket width


def test_series_window_agg_and_quantile():
    s = TimeSeries("s")
    for i in range(100):
        s.append(float(i), float(i))
    assert s.window(10.0, 19.0) == [(float(t), float(t)) for t in range(10, 20)]
    assert s.window(None, 4.0) == [(float(t), float(t)) for t in range(5)]
    assert s.window(95.0, None) == [(float(t), float(t)) for t in range(95, 100)]
    assert s.window(200.0, 300.0) == []
    agg = s.agg(10.0, 19.0)
    assert (agg.n, agg.min, agg.max) == (10, 10.0, 19.0)
    assert agg.mean == pytest.approx(14.5)
    assert (agg.t_first, agg.t_last) == (10.0, 19.0)
    assert s.agg(200.0, 300.0) is None
    # exact interpolated quantiles over the full window
    assert s.quantile(0.5) == pytest.approx(49.5)
    assert s.quantile(0.0) == 0.0 and s.quantile(1.0) == 99.0
    assert s.quantile(0.25, t0=0.0, t1=99.0) == pytest.approx(24.75)
    assert s.quantile(0.5, t0=90.0) == pytest.approx(94.5)
    assert s.quantile(0.5, t0=200.0) is None


def test_snapshot_series_are_capped_and_flagged():
    hub = MetricsHub(maxlen=4096)
    for i in range(1000):
        hub.record("big", float(i), float(i))
    hub.record("small", 0.0, 1.0)
    snap = hub.snapshot(max_points=50)
    json.dumps(snap)
    big = snap["series"]["big"]
    assert len(big["points"]) <= 50 and big["n_points"] == len(big["points"])
    assert big["truncated"] is True and big["n_appended"] == 1000
    # deterministic even-stride: endpoints always survive
    assert big["points"][0] == [0.0, 0.0]
    assert big["points"][-1] == [999.0, 999.0]
    assert snap["series"]["small"] == {
        "points": [[0.0, 1.0]], "n_points": 1, "n_appended": 1,
        "truncated": False,
    }
    # ring-buffer truncation is flagged even without down-sampling
    hub2 = MetricsHub(maxlen=8)
    for i in range(20):
        hub2.record("ring", float(i), float(i))
    ring = hub2.snapshot()["series"]["ring"]
    assert len(ring["points"]) == 8 and ring["truncated"] is True
    assert ring["n_appended"] == 20
    # default cap is the hub ring maxlen; histograms export percentiles
    hub2.histogram("d").observe(3.0)
    hd = hub2.snapshot()["histograms"]["d"]
    assert hd["p50"] == hd["p95"] == hd["p99"] == pytest.approx(3.0)


# -- SLO accounting on the virtual clock --------------------------------------

def test_slo_spec_validation():
    with pytest.raises(ValueError):
        SLOSpec(name="both", series="a", histogram="b", target=1.0)
    with pytest.raises(ValueError):
        SLOSpec(name="neither", target=1.0)
    with pytest.raises(ValueError):
        SLOSpec(name="h", histogram="b", target=1.0)      # needs percentile
    with pytest.raises(ValueError):
        SLOSpec(name="obj", series="a", target=1.0, objective=1.0)
    with pytest.raises(ValueError):
        SLOSpec(name="op", series="a", target=1.0, op="<")


def test_slo_burn_rate_windows_exact():
    """100 samples at 10s cadence, the last 10 bad, objective 0.9: the
    100s window burns at 10x sustainable, the 1000s window at exactly 1x."""
    hub = MetricsHub()
    slos = SLOTracker(hub, [SLOSpec(
        name="v-low", series="v", op="<=", target=89.0, objective=0.9,
        burn_windows=(100.0, 1000.0),
    )])
    for i in range(100):
        t = i * 10.0
        hub.record("v", t, float(i))
        slos.observe(t)
    assert slos.samples_taken == 100
    assert slos.burn_rate("v-low", 100.0, 990.0) == pytest.approx(10.0)
    assert slos.burn_rate("v-low", 1000.0, 990.0) == pytest.approx(1.0)
    st = slos.status("v-low", 990.0)
    assert st.n_samples == 100 and st.n_bad == 10
    assert st.attainment == pytest.approx(0.9)
    assert st.budget_consumed == pytest.approx(1.0)       # exactly spent
    assert not st.breached                                # not overspent
    assert st.burn_rates == {
        "100": pytest.approx(10.0), "1000": pytest.approx(1.0)
    }
    report = slos.report(990.0)
    assert report.status("v-low") == st and not report.breached
    assert "v-low" in format_slo_report(report)
    with pytest.raises(KeyError):
        report.status("nope")


def test_slo_breach_and_unmeasurable_samples():
    hub = MetricsHub()
    slos = SLOTracker(hub, [SLOSpec(
        name="floor", series="hit", op=">=", target=0.5, objective=0.75,
    )])
    slos.observe(0.0)                     # no data yet: nothing charged
    assert slos.status("floor").n_samples == 0
    assert slos.status("floor").ok_now is None
    for i, v in enumerate((0.1, 0.2, 0.1, 0.9), start=1):
        hub.record("hit", i * 10.0, v)
        slos.observe(i * 10.0)
    st = slos.status("floor", 40.0)
    assert (st.n_samples, st.n_bad) == (4, 3)
    assert st.breached and st.budget_consumed == pytest.approx(3.0)
    assert st.budget_remaining == pytest.approx(-2.0)
    assert st.ok_now is True and st.current_value == pytest.approx(0.9)


def test_slo_histogram_measurement_materializes_trace():
    _, _, rec, hub = _traced_campaign(20, pools=False, faults=False)
    slos = SLOTracker(hub, [SLOSpec(
        name="queue-p99", histogram="phase_s/queued", percentile=0.99,
        op="<=", target=1e9, objective=0.9,
    )])
    slos.observe(rec.t_range()[1], rec)
    st = slos.status("queue-p99")
    assert st.n_samples == 1 and st.ok_now is True
    assert st.current_value is not None and st.current_value >= 0.0


# -- alert lifecycle: hysteresis, firing, resolution --------------------------

def _threshold_engine(for_s=60.0, target=10.0):
    hub = _hub_with_series()
    engine = AlertEngine(hub, [AlertRule(
        name="hi", kind="threshold", series="v", op=">=", target=target,
        for_s=for_s,
    )])
    return hub, engine


def test_flapping_series_never_fires():
    hub, engine = _threshold_engine(for_s=60.0)
    trace = types.SimpleNamespace(enabled=True, events=[])
    for i in range(40):                       # breach every other sample
        t = i * 30.0
        hub.record("v", t, 100.0 if i % 2 == 0 else 0.0)
        engine.evaluate(t, trace)
    assert engine.incidents == []
    assert engine.state("hi") != FIRING
    assert engine.pending_cancelled >= 19     # every arm was cancelled
    states = [a[3]["state"] for a in trace.events]
    assert FIRING not in states and PENDING in states


def test_sustained_breach_fires_exactly_once_and_resolves():
    hub, engine = _threshold_engine(for_s=60.0)
    trace = types.SimpleNamespace(enabled=True, events=[])
    timeline = []
    for i in range(20):
        t = i * 30.0
        breach = 5 <= i < 15                  # one sustained 300s breach
        hub.record("v", t, 100.0 if breach else 0.0)
        engine.evaluate(t, trace)
        timeline.append((t, engine.state("hi")))
    assert len(engine.incidents) == 1         # exactly one firing
    inc = engine.incidents[0]
    assert inc.t_pending == 150.0             # armed at the first true sample
    assert inc.t_fired == 210.0               # held for_s=60 before firing
    assert inc.t_resolved == 450.0            # first false sample after
    assert not inc.open and inc.value_at_fire == 100.0
    # PENDING while arming, FIRING while held, back to inactive after
    assert (150.0, PENDING) in timeline and (240.0, FIRING) in timeline
    states = [a[3]["state"] for a in trace.events]
    assert states.count(FIRING) == 1 and states.count("resolved") == 1
    assert engine.incidents_for("hi") == [inc]
    text = format_alerts(engine)
    assert "hi" in text and "fired" in text


def test_exact_for_s_boundary_fires_on_the_sample_that_reaches_it():
    hub, engine = _threshold_engine(for_s=60.0)
    for i, v in enumerate((100.0, 100.0, 100.0)):
        t = i * 30.0
        hub.record("v", t, v)
        engine.evaluate(t)
    # armed at t=0, held through t=60 (>= for_s): firing on that sample
    assert engine.state("hi") == FIRING
    assert engine.incidents[0].t_fired == 60.0


def test_zero_for_s_fires_immediately():
    hub, engine = _threshold_engine(for_s=0.0)
    hub.record("v", 10.0, 99.0)
    engine.evaluate(10.0)
    assert engine.state("hi") == FIRING
    assert engine.incidents[0].t_pending == engine.incidents[0].t_fired == 10.0


def test_rate_rule_needs_lookback_coverage():
    hub = _hub_with_series()
    engine = AlertEngine(hub, [AlertRule(
        name="slope", kind="rate", series="v", op=">=", target=1.0,
        window_s=100.0,
    )])
    hub.record("v", 50.0, 500.0)
    engine.evaluate(50.0)                     # lookback not covered yet
    assert engine.state("slope") == "inactive"
    hub.record("v", 200.0, 800.0)
    engine.evaluate(200.0)                    # slope (800-0)/200 = 4 >= 1
    assert engine.state("slope") == FIRING


def test_burn_rule_and_validation():
    hub = MetricsHub()
    slos = SLOTracker(hub, [SLOSpec(
        name="lat", series="v", op="<=", target=10.0, objective=0.9,
    )])
    engine = AlertEngine(hub, [AlertRule(
        name="burn-fast", kind="burn", slo="lat", op=">=", target=5.0,
        window_s=100.0,
    )], slos=slos)
    for i in range(10):                       # all samples bad: burn = 10x
        t = i * 10.0
        hub.record("v", t, 100.0)
        engine.evaluate(t)
    assert engine.state("burn-fast") == FIRING
    assert slos.samples_taken == engine.evaluations == 10
    with pytest.raises(ValueError):
        AlertEngine(hub, [AlertRule(name="b", kind="burn", slo="lat",
                                    target=1.0)])      # no slos= tracker
    with pytest.raises(KeyError):
        AlertEngine(hub, [AlertRule(name="b", kind="burn", slo="nope",
                                    target=1.0)], slos=slos)
    with pytest.raises(ValueError):
        AlertRule(name="r", kind="rate", target=1.0)   # rate needs series
    with pytest.raises(ValueError):
        AlertRule(name="r", kind="nope", series="v", target=1.0)
    with pytest.raises(ValueError):
        AlertEngine(hub, [
            AlertRule(name="dup", series="v", target=1.0),
            AlertRule(name="dup", series="v", target=2.0),
        ])


def test_alerts_require_metrics_on_the_recorder():
    hub = MetricsHub()
    engine = AlertEngine(hub)
    with pytest.raises(ValueError):
        TraceRecorder(alerts=engine)
    rec = TraceRecorder(metrics=hub, alerts=engine)
    assert rec.alerts is engine
    rec2 = TraceRecorder(metrics=hub)
    assert rec2.alerts is None
    assert engine.attach(rec2) is engine and rec2.alerts is engine
    assert NULL_RECORDER.alerts is None


# -- the active layer riding a real campaign ----------------------------------

def test_alert_engine_evaluates_on_the_metronome():
    orch, jobs, rec, hub, engine, slos = _alerted_campaign(30)
    assert engine.evaluations == hub.samples_taken > 0
    assert slos.samples_taken == engine.evaluations
    assert orch.alerts is engine
    rep = summarize(jobs, n_storage_nodes=4, trace=rec)
    assert rep.slo is not None
    assert {s.name for s in rep.slo.statuses} == {"queue-p95", "progress"}
    assert "SLOs at t=" in format_report(rep)
    assert summarize(jobs, n_storage_nodes=4).slo is None


def test_recorder_with_alerts_campaign_is_bit_identical():
    """PR 7 acceptance: the 500-job determinism regression holds with the
    whole active layer (recorder + metrics + SLO tracker + alert engine,
    with rules low enough to actually fire) attached."""
    off_stats, on_stats = {}, {}
    off = _campaign_fingerprint("backfill", True, 42, 500, dom_cluster,
                                out=off_stats)
    hub = MetricsHub()
    slos = SLOTracker(hub, [SLOSpec(
        name="queue", series="queue_depth", op="<=", target=5.0,
        objective=0.9, burn_windows=(120.0, 1200.0),
    )])
    engine = AlertEngine(hub, [
        AlertRule(name="deep", kind="threshold", series="queue_depth",
                  op=">=", target=5.0, for_s=60.0),
        AlertRule(name="burn", kind="burn", slo="queue", op=">=",
                  target=1.0, window_s=600.0),
    ], slos=slos)
    rec = TraceRecorder(metrics=hub, sample_every_s=60.0, alerts=engine)
    on = _campaign_fingerprint("backfill", True, 42, 500, dom_cluster,
                               recorder=rec, out=on_stats)
    assert off == on
    assert off_stats["events_processed"] == on_stats["events_processed"]
    assert engine.evaluations > 0
    assert engine.incidents, "rules were meant to fire on this campaign"
    alert_events = [e for e in rec.events if e[0] == "alert"]
    assert alert_events, "lifecycle transitions should land in the trace"


# -- campaign doctor ----------------------------------------------------------

class _FakeTrace:
    """Minimal duck-typed trace for scripted doctor pathologies."""

    def __init__(self, spans, events=(), job_meta=None, grant_causes=None):
        self.spans = spans
        self.events = list(events)
        self.job_meta = job_meta or {}
        self.grant_causes = grant_causes or {}
        self.metrics = None

    def t_range(self):
        ts = [t for s in self.spans.values() for _, t0, t1 in s for t in (t0, t1)]
        return (min(ts), max(ts))

    def _materialize(self):
        pass


def _stage_bound_spans(t_stage=60.0):
    return {
        1: [("queued", 0.0, 5.0), ("provisioning", 5.0, 10.0),
            ("staging_in", 10.0, 10.0 + t_stage),
            ("running", 10.0 + t_stage, 30.0 + t_stage),
            ("done", 30.0 + t_stage, 30.0 + t_stage)],
    }


def test_doctor_flags_stage_in_bound_campaign():
    trace = _FakeTrace(_stage_bound_spans())
    advisories = diagnose(trace)
    assert advisories and advisories[0].code == "stage_in_bound"
    top = advisories[0]
    assert top.severity == pytest.approx(60.0 / 90.0)
    assert top.evidence["staging_in_fraction"] == pytest.approx(2 / 3, abs=1e-3)
    assert "stage-in bound" in top.summary
    assert "stage_in_bound" in format_advisories(advisories)


def test_doctor_flags_pool_thrash_over_staging():
    events = [("eviction", 20.0 + i, "tile3", {"pool_id": 0, "nbytes": 5 * GB})
              for i in range(9)]
    events.append(("eviction", 50.0, "tile1", {"pool_id": 0, "nbytes": GB}))
    trace = _FakeTrace(_stage_bound_spans(), events=events)
    advisories = diagnose(trace)
    codes = [a.code for a in advisories]
    # churn outranks the (discounted) staging advisory it causes
    assert codes[0] == "pool_thrash" and "stage_in_bound" in codes
    thrash = advisories[0]
    assert thrash.severity == pytest.approx(min(1.0, 0.5 + 0.06 * 9))
    assert thrash.evidence["top_dataset"] == "tile3"
    assert thrash.evidence["top_evictions"] == 9
    assert thrash.evidence["total_evictions"] == 10
    assert "re-staged 10x" in thrash.summary
    staging = next(a for a in advisories if a.code == "stage_in_bound")
    assert staging.severity == pytest.approx((2 / 3) * 0.6)


def test_doctor_flags_head_blocking_and_names_the_blocker():
    spans = {
        1: [("queued", 0.0, 1.0), ("running", 1.0, 100.0),
            ("done", 100.0, 100.0)],
        2: [("queued", 0.0, 100.0), ("running", 100.0, 110.0),
            ("done", 110.0, 110.0)],
        3: [("queued", 0.0, 100.0), ("running", 100.0, 108.0),
            ("done", 108.0, 108.0)],
    }
    events = [
        ("grant", 1.0, "wide", {"job_id": 1, "n_compute": 8, "n_storage": 4}),
        ("grant", 100.0, "nar1", {"job_id": 2, "n_compute": 1, "n_storage": 0}),
        ("grant", 100.0, "nar2", {"job_id": 3, "n_compute": 1, "n_storage": 0}),
    ]
    trace = _FakeTrace(spans, events=events, job_meta={1: {"name": "wide"}})
    advisories = diagnose(trace)
    assert advisories and advisories[0].code == "head_blocking"
    top = advisories[0]
    assert top.evidence["blocker_job_id"] == 1
    assert top.evidence["blocker_name"] == "wide"
    assert top.evidence["blocker_width"] == 12
    # jobs 2 and 3 each overlapped job 1's (1, 100) run while queued
    assert top.evidence["queued_job_s_overlapped"] == pytest.approx(198.0)
    assert "head-blocked" in top.summary and "'wide'" in top.summary


def test_doctor_empty_and_quiet_traces():
    assert diagnose(_FakeTrace({})) == ()
    quiet = _FakeTrace({1: [("queued", 0.0, 1.0), ("running", 1.0, 10.0),
                            ("done", 10.0, 10.0)]})
    assert diagnose(quiet) == ()
    assert "nothing to flag" in format_advisories(())


def test_doctor_reads_slo_breaches_from_the_report():
    _, jobs, rec, hub, engine, slos = _alerted_campaign(20)
    rep = summarize(jobs, n_storage_nodes=4, trace=rec)
    advisories = diagnose(rec, report=rep)
    # the campaign is healthy on these SLOs: no breach advisories expected,
    # but the plumbing must not blow up and ordering must be by severity
    sevs = [a.severity for a in advisories]
    assert sevs == sorted(sevs, reverse=True)


def test_doctor_on_a_real_faulty_campaign():
    _, jobs, rec, hub = _traced_campaign(40)
    rep = summarize(jobs, n_storage_nodes=4, pools=None, trace=rec)
    advisories = diagnose(rec, report=rep)
    for a in advisories:
        assert 0.0 <= a.severity <= 1.01
        assert a.summary and a.recommendation and isinstance(a.evidence, dict)


# -- dashboard ----------------------------------------------------------------

def test_dashboard_is_self_contained(tmp_path):
    _, jobs, rec, hub, engine, slos = _alerted_campaign(30)
    rep = summarize(jobs, n_storage_nodes=4, trace=rec)
    path = tmp_path / "dash.html"
    write_dashboard(path, rec, report=rep, title="test <campaign> & co")
    doc = path.read_text()
    low = doc.lower()
    assert low.startswith("<!doctype html>")
    assert "<script" not in low                  # no JS at all
    assert "http" not in low                     # zero external requests
    assert "src=" not in low and "url(" not in low and "@import" not in low
    assert "test &lt;campaign&gt; &amp; co" in doc      # titles escaped
    for section in ("Campaign doctor", "Critical path", "SLOs",
                    "Alert timeline", "Metric series"):
        assert section in doc
    assert doc.count("<svg") == doc.count("</svg>") > 0
    assert "queue_depth" in doc                  # sparklines for hub series
    assert "prefers-color-scheme" in doc and "data-theme" in doc
    assert "queue-p95" in doc                    # the SLO table rendered


def test_dashboard_autoderives_everything_from_the_recorder():
    _, _, rec, hub, engine, slos = _alerted_campaign(20)
    doc = build_dashboard(rec)
    assert "queue-p95" in doc and "Campaign doctor" in doc
    text = format_dashboard(rec)
    assert "campaign observability report" in text
    assert "campaign doctor" in text and "SLOs at t=" in text


def test_dashboard_handles_a_bare_trace():
    _, _, rec, _ = _traced_campaign(10, pools=False, faults=False)
    doc = build_dashboard(rec)
    assert "no SLOs defined" in doc and "no alert rules registered" in doc


# -- import layering for the new modules --------------------------------------

def test_obs_modules_never_import_the_simulation():
    guard = _load_guard()
    root = os.path.join(os.path.dirname(__file__), "..", "src")
    obs_dir = os.path.join(root, "repro", "obs")
    checked = 0
    for fn in sorted(os.listdir(obs_dir)):
        if fn.endswith(".py"):
            path = os.path.join(obs_dir, fn)
            assert guard._obs_violations_in(path, root) == [], path
            checked += 1
    # the whole PR 7 surface exists and was checked
    names = set(os.listdir(obs_dir))
    assert {"slo.py", "alerts.py", "diagnose.py", "dashboard.py"} <= names
    assert checked >= 8


def test_obs_purity_guard_flags_simulation_imports(tmp_path):
    guard = _load_guard()
    pkg = tmp_path / "repro" / "obs"
    pkg.mkdir(parents=True)
    bad = pkg / "bad.py"
    bad.write_text(
        "from ..orchestrator import Orchestrator\n"
        "from .metrics import MetricsHub\n"
        "import repro.core\n"
        "import bisect\n"
        "def lazy():\n"
        "    from ..orchestrator import summarize\n"
        "    return summarize\n"
    )
    hits = guard._obs_violations_in(str(bad), str(tmp_path))
    assert [line for line, _ in hits] == [1, 3]
