"""PR 6 observability suite: recorder wiring, determinism, exporters,
critical path, metrics, and the hot-loop import guard.

The contract under test: tracing is opt-in (every component defaults to
the shared ``NULL_RECORDER`` no-op), strictly read-only (a campaign
replayed with the recorder on produces bit-identical ``JobRecord.history``
and the same engine event count), and complete (spans mirror the history
log exactly; the critical-path buckets tile the makespan).
"""

import importlib.util
import json
import os
import random

import pytest

from repro.core import dom_cluster, synthetic_cluster
from repro.obs import (
    NULL_RECORDER,
    Counter,
    Gauge,
    Histogram,
    MetricsHub,
    NullRecorder,
    TimeSeries,
    TraceRecorder,
    critical_path,
    format_critical_path,
    jsonl_records,
    write_chrome_trace,
    write_jsonl,
)
from repro.obs.profile import PHASES
from repro.orchestrator import (
    BackfillPolicy,
    DataAwarePolicy,
    Orchestrator,
    WorkflowSpec,
    format_report,
    poisson_arrivals,
    summarize,
)
from repro.pool import DatasetRef
from repro.provision import StorageSpec
from repro.runtime import FaultInjector, FaultSpec

from test_campaign_scale import _campaign_fingerprint

GB = 1e9


def _traced_campaign(n_jobs=40, seed=3, *, pools=True, faults=True,
                     sample_every_s=30.0):
    """A small mixed campaign (faults, retries, pools, checkpoints) with a
    full recorder attached; returns (orch, jobs, recorder, hub)."""
    hub = MetricsHub()
    rec = TraceRecorder(metrics=hub, sample_every_s=sample_every_s)
    orch = Orchestrator(
        dom_cluster(),
        policy=BackfillPolicy(),
        faults=FaultInjector(
            FaultSpec(stage_in_fail_p=0.1, run_fail_p=0.08, seed=seed)
        ) if faults else None,
        recorder=rec,
    )
    if pools:
        mgr = orch.enable_pools(ttl_s=800.0)
        mgr.create_pool(nodes=1, cap_bytes=40 * GB)
        orch.policy = DataAwarePolicy(orch.provision)
    rng = random.Random(seed)
    specs = []
    for i in range(n_jobs):
        name = f"job{i:03d}"
        r = rng.random()
        if pools and r < 0.4:
            ds = DatasetRef(f"d{rng.randint(0, 7)}", (10 + 5 * (i % 4)) * GB)
            specs.append(
                WorkflowSpec(name, 1 + i % 2, use_pool=True, datasets=(ds,),
                             stage_in_bytes=1 * GB, run_time_s=20.0 + i % 7,
                             max_retries=2)
            )
        elif r < 0.8:
            specs.append(
                WorkflowSpec(
                    name, 1 + i % 3,
                    storage_spec=StorageSpec(
                        name, nodes=1 + i % 2, managers=("ephemeralfs",),
                        stage_in_bytes=5 * GB, stage_out_bytes=1 * GB,
                    ),
                    run_time_s=30.0 + i % 11, max_retries=2,
                    checkpoint_every_s=10.0, checkpoint_bytes=1 * GB,
                )
            )
        else:
            specs.append(WorkflowSpec(name, 1 + i % 4, run_time_s=15.0 + i % 5))
    jobs = orch.run_campaign(
        specs, submit_times=poisson_arrivals(0.5, n_jobs, seed=seed)
    )
    return orch, jobs, rec, hub


# -- opt-in wiring ------------------------------------------------------------

def test_null_recorder_is_the_default_everywhere():
    orch = Orchestrator(synthetic_cluster(4, 2))
    assert orch.recorder is NULL_RECORDER
    assert orch.engine.recorder is None
    assert orch.provision.recorder is NULL_RECORDER
    assert orch.scheduler.recorder is NULL_RECORDER
    mgr = orch.enable_pools()
    assert mgr.recorder is NULL_RECORDER
    assert mgr.evictor.recorder is NULL_RECORDER
    assert NullRecorder.enabled is False and not NULL_RECORDER.enabled


def test_null_recorder_methods_are_noops():
    rec = NullRecorder()
    assert rec.bind(object()) is rec
    for call in (
        lambda: rec.transition(None, None),
        lambda: rec.grant(None, None),
        lambda: rec.release(None),
        lambda: rec.fault(None, "run", True),
        lambda: rec.negotiation("s", None, cached=True),
        lambda: rec.eviction(0, "d", 1.0),
        lambda: rec.engine_sample(0.0, 0, 0),
    ):
        assert call() is None


def test_bind_propagates_to_every_layer():
    rec = TraceRecorder()
    orch = Orchestrator(synthetic_cluster(4, 2), recorder=rec)
    assert orch.recorder is rec
    assert orch.engine.recorder is rec
    assert orch.provision.recorder is rec
    assert orch.scheduler.recorder is rec
    mgr = orch.enable_pools()     # created after bind: still propagated
    assert mgr.recorder is rec
    assert mgr.evictor.recorder is rec


# -- determinism: tracing must not perturb the campaign -----------------------

@pytest.mark.parametrize("policy_name", ["backfill", "data-aware"])
def test_recorder_on_campaign_is_bit_identical(policy_name):
    """The acceptance regression: a seeded 500-job campaign (faults,
    retries, pools, Poisson arrivals) replayed with a full recorder +
    metrics hub produces identical ``JobRecord.history``, identical
    allocations, and the same engine event count."""
    off_stats, on_stats = {}, {}
    off = _campaign_fingerprint(policy_name, True, 42, 500, dom_cluster,
                                out=off_stats)
    rec = TraceRecorder(metrics=MetricsHub(), sample_every_s=60.0)
    on = _campaign_fingerprint(policy_name, True, 42, 500, dom_cluster,
                               recorder=rec, out=on_stats)
    assert off == on
    assert off_stats["events_processed"] == on_stats["events_processed"]
    assert len(rec.spans) == 500


# -- spans mirror the history log --------------------------------------------

def test_spans_match_job_history_exactly():
    _, jobs, rec, _ = _traced_campaign(30)
    assert len(rec.spans) == len(jobs)
    for job in jobs:
        hist = job.history
        expected = [
            (s0.value, t0, t1) for (s0, t0), (_, t1) in zip(hist, hist[1:])
        ]
        final_state, final_t = hist[-1]
        expected.append((final_state.value, final_t, final_t))
        assert rec.spans[job.job_id] == expected
        meta = rec.job_meta[job.job_id]
        assert meta["name"] == job.spec.name
        assert meta["submit"] == job.submit_time
        if job.done:
            assert meta["backend"] is not None


def test_materialization_is_incremental_mid_campaign():
    rec = TraceRecorder()
    orch = Orchestrator(synthetic_cluster(4, 2), recorder=rec)
    for i in range(6):
        orch.submit(WorkflowSpec(
            f"j{i}", 1,
            storage_spec=StorageSpec(f"j{i}", nodes=1, managers=("ephemeralfs",)),
            run_time_s=50.0,
        ), at=float(i))
    orch.engine.run(until=30.0)
    mid = {j: list(s) for j, s in rec.spans.items()}
    assert mid                                    # something closed already
    orch.engine.run()
    assert all(j.done for j in orch.jobs)
    for jid, spans in mid.items():
        # the mid-campaign read is a prefix of the final materialization
        assert rec.spans[jid][: len(spans)] == spans
    assert all(s[-1][0] == "done" for s in rec.spans.values())


# -- live vs batch reporting with tracing on ----------------------------------

def test_live_report_matches_batch_summarize_with_tracing_on():
    hub = MetricsHub()
    rec = TraceRecorder(metrics=hub)
    orch = Orchestrator(
        dom_cluster(),
        policy=BackfillPolicy(),
        faults=FaultInjector(FaultSpec(run_fail_p=0.1, seed=5)),
        recorder=rec,
    )
    rng = random.Random(5)
    for i in range(40):
        orch.submit(
            WorkflowSpec(
                f"j{i:02d}", rng.randint(1, 4),
                storage_spec=StorageSpec(
                    f"j{i:02d}", nodes=rng.randint(1, 2),
                    managers=("ephemeralfs",),
                    stage_in_bytes=rng.uniform(1, 10) * GB,
                ),
                run_time_s=rng.uniform(10, 60), max_retries=2,
                checkpoint_every_s=15.0, checkpoint_bytes=1 * GB,
            ),
            at=float(i),
        )
    for t in (20.0, 90.0, 250.0):
        orch.engine.run(until=t)
        now = orch.engine.now
        live = orch.live_report(now)
        rep = summarize(orch.jobs, n_storage_nodes=4, now=now, trace=rec)
        assert live.n_jobs == rep.n_jobs
        assert live.n_done == rep.n_done
        assert live.n_failed == rep.n_failed
        assert live.retries + live.preemptions == rep.total_retries
        assert live.staged_in_bytes == pytest.approx(rep.staged_in_bytes)
        assert live.makespan_s == pytest.approx(rep.makespan_s)
    orch.engine.run()
    final = summarize(orch.jobs, n_storage_nodes=4, trace=rec)
    live = orch.live_report(orch.engine.now)
    assert live.n_done == final.n_done == 40 - final.n_failed


# -- exporters ----------------------------------------------------------------

def test_chrome_trace_is_valid_and_complete(tmp_path):
    _, jobs, rec, hub = _traced_campaign(40)
    path = tmp_path / "trace.json"
    doc = write_chrome_trace(path, rec, metrics=hub)
    with open(path) as fh:
        assert json.load(fh) == doc               # round-trips as JSON
    ev = doc["traceEvents"]
    by_ph = {}
    for e in ev:
        assert "ph" in e and "pid" in e
        by_ph.setdefault(e["ph"], []).append(e)
    procs = {e["args"]["name"] for e in by_ph["M"] if e["name"] == "process_name"}
    assert procs == {"jobs", "storage sessions", "storage pools", "metrics"}
    # one X span per non-terminal recorded phase span
    n_spans = sum(
        1 for s in rec.spans.values() for p, _, _ in s
        if p not in ("done", "failed")
    )
    job_x = [e for e in by_ph["X"] if e["cat"] == "phase"]
    assert len(job_x) == n_spans
    assert all(e["dur"] >= 0 and e["ts"] >= 0 for e in by_ph["X"])
    # every requeued fault carries a flow arrow to the next grant
    requeued = [
        (t, a) for k, t, _, a in rec.events if k == "fault" and a["requeued"]
    ]
    assert requeued, "campaign fluked: no faults requeued"
    starts = {e["id"] for e in by_ph.get("s", ())}
    ends = {e["id"] for e in by_ph.get("f", ())}
    assert starts and starts == ends
    # metrics series exported as counter events
    assert {e["name"] for e in by_ph.get("C", ())} >= {"queue_depth"}


def test_jsonl_export_round_trips(tmp_path):
    _, _, rec, _ = _traced_campaign(20)
    path = tmp_path / "trace.jsonl"
    n = write_jsonl(path, rec)
    with open(path) as fh:
        records = [json.loads(line) for line in fh]
    assert len(records) == n == len(list(jsonl_records(rec)))
    kinds = {r["type"] for r in records}
    assert kinds == {"span", "session", "event", "count"}
    n_spans = sum(len(s) for s in rec.spans.values())
    assert sum(r["type"] == "span" for r in records) == n_spans
    assert sum(r["type"] == "session" for r in records) == len(rec.sessions)


# -- critical path ------------------------------------------------------------

def test_critical_path_tiles_the_makespan():
    _, jobs, rec, _ = _traced_campaign(40)
    cp = critical_path(rec)
    assert cp is not None
    assert sum(cp.phase_s.values()) == cp.makespan_s     # exact, not approx
    t0, t1 = rec.t_range()
    assert (cp.t_start, cp.t_end) == (t0, t1)
    assert set(cp.phase_s) <= set(PHASES)
    assert cp.phase_s.get("running", 0.0) > 0
    # segments are contiguous and ordered: they tile [t_start, t_end]
    cursor = cp.t_start
    for seg in cp.segments:
        assert seg.t0 == pytest.approx(cursor, abs=1e-6)
        assert seg.t1 >= seg.t0
        cursor = seg.t1
    assert cursor == pytest.approx(cp.t_end, abs=1e-6)
    text = format_critical_path(cp, max_segments=3)
    assert "critical path:" in text and "running" in text


def test_critical_path_single_job():
    rec = TraceRecorder()
    orch = Orchestrator(synthetic_cluster(2, 1), recorder=rec)
    orch.submit(WorkflowSpec(
        "solo", 1,
        storage_spec=StorageSpec("solo", nodes=1, managers=("ephemeralfs",)),
        run_time_s=100.0,
    ))
    orch.engine.run()
    cp = critical_path(rec)
    assert sum(cp.phase_s.values()) == cp.makespan_s
    assert cp.phase_s["running"] == pytest.approx(100.0)
    jid = orch.jobs[0].job_id
    # every attributed segment belongs to the only job
    assert {seg.job_id for seg in cp.segments} <= {jid, None}
    assert any(seg.job_id == jid for seg in cp.segments)


def test_critical_path_empty_trace_is_none():
    assert critical_path(TraceRecorder()) is None


def test_summarize_attaches_critical_path_to_report():
    _, jobs, rec, _ = _traced_campaign(20)
    rep = summarize(jobs, n_storage_nodes=4, trace=rec)
    assert rep.critical_path is not None
    assert rep.critical_path.makespan_s == pytest.approx(rep.makespan_s)
    assert "critical path:" in format_report(rep)
    assert "critical path:" not in format_report(
        summarize(jobs, n_storage_nodes=4)
    )


# -- trace content: negotiation, pools, engine --------------------------------

def test_negotiation_cache_hits_counted_not_evented():
    _, _, rec, _ = _traced_campaign(40, pools=False, faults=False)
    scored = [e for e in rec.events if e[0] == "negotiation"]
    assert rec.counts["negotiation.scored"] == len(scored)
    assert rec.counts["negotiation.cache_hits"] > 0
    assert rec.counts["scheduler.grants"] == rec.counts["scheduler.releases"]
    opened = sum(
        n for k, n in rec.counts.items() if k.startswith("sessions.opened.")
    )
    assert opened == rec.counts["scheduler.grants"]


def test_pool_lease_and_eviction_events():
    orch, jobs, rec, _ = _traced_campaign(40, faults=False)
    kinds = {e[0] for e in rec.events}
    assert "pool_created" in kinds and "lease_attached" in kinds
    mgr = orch.pools
    n_evictions = sum(1 for e in rec.events if e[0] == "eviction")
    assert n_evictions == mgr.evictor.evictions
    assert rec.counts.get("pool.evictions", 0) == n_evictions
    leases = [e for e in rec.events if e[0] == "lease_attached"]
    assert len(leases) == mgr.stats.leases_granted


def test_engine_sampling_series():
    _, _, rec, hub = _traced_campaign(30)
    assert hub.samples_taken >= 1
    series = hub.series["engine_heap_depth"]
    assert len(series) >= 1
    # the closing sample sees the drained heap
    t_last, depth_last = series.last()
    assert depth_last == 0
    for probe in ("queue_depth", "free_compute_nodes", "pool_occupancy",
                  "catalog_hit_rate", "running_jobs", "jobs_done"):
        assert probe in hub.series


# -- metrics primitives -------------------------------------------------------

def test_metrics_primitives():
    c = Counter("c")
    c.inc()
    c.inc(2.5)
    assert c.value == 3.5
    g = Gauge("g")
    g.set(7.0)
    assert g.value == 7.0
    h = Histogram("h", bounds=(1.0, 10.0))
    for v in (0.5, 5.0, 50.0):
        h.observe(v)
    assert h.counts == [1, 1, 1] and h.total == 3
    assert h.min == 0.5 and h.max == 50.0 and h.mean == pytest.approx(55.5 / 3)
    s = TimeSeries("s", maxlen=3)
    for i in range(5):
        s.append(float(i), float(i * i))
    assert len(s) == 3 and s.items()[0] == (2.0, 4.0)      # ring evicted
    assert s.last() == (4.0, 16.0)


def test_metrics_hub_probes_and_snapshot():
    hub = MetricsHub(maxlen=8)
    x = {"v": 0.0}
    hub.add_probe("x", lambda: x["v"])
    for t in (0.0, 1.0, 2.0):
        x["v"] = t * 10
        hub.sample(t)
    assert hub.samples_taken == 3
    assert hub.series["x"].items() == [(0.0, 0.0), (1.0, 10.0), (2.0, 20.0)]
    assert hub.gauges["x"].value == 20.0
    hub.counter("n").inc()
    hub.histogram("d").observe(3.0)
    snap = hub.snapshot()
    json.dumps(snap)                                       # JSON-serializable
    assert snap["counters"]["n"] == 1.0
    assert snap["histograms"]["d"]["total"] == 1


# -- hot-loop import guard ----------------------------------------------------

def _load_guard():
    path = os.path.join(
        os.path.dirname(__file__), "..", "tools", "check_obs_imports.py"
    )
    spec = importlib.util.spec_from_file_location("check_obs_imports", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_hot_loop_modules_only_import_the_recorder_interface(tmp_path):
    guard = _load_guard()
    root = os.path.join(os.path.dirname(__file__), "..", "src")
    for pkg in guard.HOT_PACKAGES:
        pkg_dir = os.path.join(root, "repro", pkg)
        for dirpath, _, filenames in os.walk(pkg_dir):
            for fn in filenames:
                if fn.endswith(".py"):
                    path = os.path.join(dirpath, fn)
                    assert guard._violations_in(path, root) == [], path


def test_import_guard_flags_violations(tmp_path):
    guard = _load_guard()
    pkg = tmp_path / "repro" / "core"
    pkg.mkdir(parents=True)
    bad = pkg / "bad.py"
    bad.write_text(
        "from ..obs.export import chrome_trace\n"
        "from ..obs.trace import NULL_RECORDER\n"
        "import repro.obs\n"
        "def lazy():\n"
        "    from ..obs.profile import critical_path\n"
        "    return critical_path\n"
    )
    hits = guard._violations_in(str(bad), str(tmp_path))
    assert [line for line, _ in hits] == [1, 3]
