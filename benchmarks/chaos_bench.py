"""Mirrored deployments + self-healing pools vs no-redundancy under node kills.

The chaos acceptance scenario: one seeded campaign (direct 2-node
ephemeralfs jobs plus POOLED jobs leasing shared datasets from a 2-node
pool), hit by a *scripted* `NodeFaultModel` schedule — three storage-node
kills mid-campaign, each repaired MTTR later — identical for both
configurations, so the comparison isolates the redundancy/healing policy:

* **no-redundancy** (the pre-chaos posture): every deployment touching a
  dead node is destroyed; affected jobs restart through the synthetic-fault
  requeue path, repeating their stage-in and their full run (no checkpoint
  cadence — this is the scenario where redundancy, not PR 5's resume,
  must carry the loss). The pool waits for the node's own repair.
* **mirror + self-heal**: direct jobs request `placement.mirror` (BeeGFS
  buddy-group style), so a single loss degrades the deployment in place —
  halved effective bandwidth, in-flight phase re-priced — instead of
  killing it; the pool backfills a free spare on a deterministic
  `RetryPolicy` backoff instead of waiting out the MTTR.

Asserted here (so ``benchmarks/run.py`` fails loudly on regression):
the resilient configuration completes every job, achieves strictly higher
goodput (jobs per virtual hour ⇔ strictly lower makespan for the fixed
job set) AND strictly lower re-staged bytes, degrades at least one
deployment, and rebuilds the pool at least once. A chaos-off leg replays
the same campaign with an empty fault model and with no model at all —
bit-identical job histories and allocation ids, the PR 4 determinism
contract.

``derived`` reports both modes' makespan, goodput, staged bytes, and the
chaos counters; the JSON trajectory lands in ``benchmarks/out/chaos.json``
and the repo-root ``BENCH_chaos.json`` perf-trajectory point.
"""

from __future__ import annotations

import json
import os
import random
import time

from repro.chaos import NodeFaultModel, RetryPolicy
from repro.core import synthetic_cluster
from repro.orchestrator import (
    BackfillPolicy,
    JobState,
    Orchestrator,
    WorkflowSpec,
    summarize,
)
from repro.pool import DatasetRef
from repro.provision import LifetimeClass, Placement, StorageSpec

from .common import time_us

GB = 1e9
N_JOBS = 32
N_STORAGE = 10
SEED = 11
MTTR_S = 500.0
KILLS = ((240.0, "sn00001"), (420.0, "sn00003"), (560.0, "sn00006"))
OUT_PATH = os.path.join(os.path.dirname(__file__), "out", "chaos.json")
BENCH_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_chaos.json")


def _specs(*, mirror: bool) -> list[WorkflowSpec]:
    rng = random.Random(SEED)
    ds = [DatasetRef(f"ds{k}", (10.0 + 4.0 * k) * GB) for k in range(4)]
    specs = []
    for i in range(N_JOBS):
        name = f"job{i:03d}"
        if i % 4 == 0:
            storage = StorageSpec(
                name,
                lifetime=LifetimeClass.POOLED,
                datasets=(ds[i % 4],),
                stage_in_bytes=1 * GB,
                stage_out_bytes=1 * GB,
            )
        else:
            storage = StorageSpec(
                name,
                nodes=2,
                managers=("ephemeralfs",),
                placement=Placement(mirror=mirror),
                stage_in_bytes=rng.uniform(8, 20) * GB,
                stage_out_bytes=2 * GB,
            )
        specs.append(
            WorkflowSpec(
                name,
                1 + i % 4,
                storage_spec=storage,
                run_time_s=rng.uniform(80, 160),
                max_retries=6,
            )
        )
    return specs


def _campaign(*, mirror: bool, self_heal: bool, chaos: bool = True,
              empty_model: bool = False):
    from repro.obs.trace import TraceRecorder

    cluster = synthetic_cluster(32, N_STORAGE)
    rec = TraceRecorder()
    orch = Orchestrator(cluster, policy=BackfillPolicy(), recorder=rec)
    orch.enable_pools(ttl_s=None)
    pool_session = orch.provision.open_session(
        StorageSpec(
            "pool0",
            nodes=2,
            lifetime=LifetimeClass.PERSISTENT,
            capacity_cap_bytes=100 * GB,
        )
    )
    if chaos or empty_model:
        node_ids = [n.node_id for n in cluster.storage_nodes]
        model = NodeFaultModel(
            node_ids, mttr_s=MTTR_S, schedule=KILLS if chaos else ()
        )
        orch.enable_chaos(
            model,
            retry=RetryPolicy(base_s=15.0, seed=5) if self_heal else None,
        )
    jobs = orch.run_campaign(
        _specs(mirror=mirror), submit_times=[i * 3.0 for i in range(N_JOBS)]
    )
    assert all(j.state is JobState.DONE for j in jobs), "campaign left stragglers"
    rep = summarize(jobs, n_storage_nodes=N_STORAGE, pools=orch.pools)
    fingerprint = [
        (j.spec.name, tuple(j.history), tuple(j.alloc_history), j.attempt)
        for j in jobs
    ]
    return rep, rec, pool_session.pool, fingerprint


def _goodput(rep) -> float:
    """Jobs completed per virtual hour (the job set is fixed, so this is
    the makespan inverted onto an interpretable axis)."""
    return N_JOBS / rep.makespan_s * 3600.0


def rows():
    runs = {}

    def _run(key, **kw):
        runs[key] = _campaign(**kw)

    us_base = time_us(lambda: _run("base", mirror=False, self_heal=False), repeat=2)
    us_res = time_us(lambda: _run("res", mirror=True, self_heal=True), repeat=2)
    us_off = time_us(
        lambda: _run("off", mirror=False, self_heal=False, chaos=False), repeat=2
    )
    _run("off_empty", mirror=False, self_heal=False, chaos=False, empty_model=True)

    base, base_rec, _, _ = runs["base"]
    res, res_rec, res_pool, _ = runs["res"]
    off, _, _, off_fp = runs["off"]
    _, _, _, empty_fp = runs["off_empty"]

    # acceptance: same kill schedule, strictly higher goodput and strictly
    # lower (re-)staged traffic with mirror redundancy + pool self-healing
    assert _goodput(res) > _goodput(base), (
        f"resilient goodput {_goodput(res):.1f} jobs/h not above "
        f"no-redundancy {_goodput(base):.1f} jobs/h"
    )
    assert res.staged_in_bytes < base.staged_in_bytes, (
        f"resilient re-staged {res.staged_in_bytes / GB:.0f}GB, "
        f"no-redundancy {base.staged_in_bytes / GB:.0f}GB"
    )
    # the mechanisms actually fired: deployments degraded, the pool healed
    assert res_rec.counts.get("chaos.degraded", 0) > 0, "nothing degraded"
    assert res_rec.counts.get("chaos.rebuilds", 0) > 0, "pool never rebuilt"
    assert "sn00001" in res_pool.replaced_node_ids, "pool not backfilled"
    assert base_rec.counts.get("chaos.node_downs", 0) == len(KILLS)
    # chaos off == chaos absent: an armed-but-empty model schedules nothing
    # and the campaign replays the no-chaos history bit for bit
    assert off_fp == empty_fp, "empty fault model perturbed the campaign"
    assert off.makespan_s < base.makespan_s, "kills cost nothing?"

    results = {
        "benchmark": "chaos_bench",
        "n_jobs": N_JOBS,
        "kills": [[t, n] for t, n in KILLS],
        "mttr_s": MTTR_S,
        "no_redundancy": {
            "makespan_s": base.makespan_s,
            "goodput_jobs_per_h": _goodput(base),
            "staged_in_bytes": base.staged_in_bytes,
            "retries": base.total_retries,
            "requeued_faults": base_rec.counts.get("fault.requeued", 0),
        },
        "mirror_self_heal": {
            "makespan_s": res.makespan_s,
            "goodput_jobs_per_h": _goodput(res),
            "staged_in_bytes": res.staged_in_bytes,
            "retries": res.total_retries,
            "degraded": res_rec.counts.get("chaos.degraded", 0),
            "rebuilds": res_rec.counts.get("chaos.rebuilds", 0),
        },
        "chaos_off": {"makespan_s": off.makespan_s},
    }
    results["timestamp"] = time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime())
    os.makedirs(os.path.dirname(OUT_PATH), exist_ok=True)
    for path in (OUT_PATH, BENCH_PATH):
        with open(path, "w") as f:
            json.dump(results, f, indent=2, sort_keys=True)
            f.write("\n")

    return [
        (
            f"chaos/no-redundancy-{N_JOBS}jobs",
            us_base,
            f"makespan={base.makespan_s:.0f}s "
            f"goodput={_goodput(base):.1f}jobs/h "
            f"staged_in={base.staged_in_bytes / GB:.0f}GB "
            f"retries={base.total_retries}",
        ),
        (
            f"chaos/mirror-self-heal-{N_JOBS}jobs",
            us_res,
            f"makespan={res.makespan_s:.0f}s "
            f"goodput={_goodput(res):.1f}jobs/h "
            f"staged_in={res.staged_in_bytes / GB:.0f}GB "
            f"degraded={res_rec.counts.get('chaos.degraded', 0)} "
            f"rebuilds={res_rec.counts.get('chaos.rebuilds', 0)}",
        ),
        (
            "chaos/off-replay",
            us_off,
            f"makespan={off.makespan_s:.0f}s bit-identical with/without "
            f"empty model; json={OUT_PATH}",
        ),
    ]
