"""Fig. 3: IOR file-per-process bandwidth vs size-per-process.

Peak write 11.96 GB/s = 93% of the 12.8 GB/s raw aggregate (C3);
~1.7x the shared-file peak (C4).
"""

from __future__ import annotations

from repro.core import Workload, dom_efs, dom_lustre, predict_read, predict_write

from .common import MiB, functional_io_us, mk_efs

SIZES_MB = (4, 16, 32, 64, 128, 256, 512, 1024)


def rows():
    out = []
    efs = mk_efs(2)
    us = functional_io_us(efs)
    efs.teardown()
    d_efs, d_lus = dom_efs(2), dom_lustre()
    for sp in SIZES_MB:
        w = Workload(n_procs=288, size_per_proc=sp * MiB, pattern="fpp")
        for fs_name, d in (("beegfs2dw", d_efs), ("lustre", d_lus)):
            wr = predict_write(w, d)
            rd = predict_read(w, d)
            out.append((f"ior_fpp/write/{fs_name}/{sp}MB", us,
                        f"{wr.bandwidth/1e9:.2f}GBps"))
            out.append((f"ior_fpp/read/{fs_name}/{sp}MB", us,
                        f"{rd.bandwidth/1e9:.2f}GBps"))
    return out
