"""Persistent pools vs per-job provisioning on a shared-dataset campaign.

The acceptance scenario for the pool subsystem: >= 100 jobs sharing <= 10
datasets on an oversubscribed cluster (dom: 4 DataWarp nodes), both modes
expressed through the unified StorageSession API. The baseline campaign
carries EPHEMERAL `StorageSpec`s — negotiation grants a job-scoped file
system per job and re-stages every shared dataset from the global FS each
time (the paper's mechanism). The pooled mode opens two PERSISTENT sessions
(pinning the storage nodes under long-lived pools), gives every job a
POOLED spec so negotiation resolves it to a capacity lease, routes jobs to
their data with ``DataAwarePolicy``, and stages each dataset once per
residency — later references are cache hits. Pool ledgers are capped below
hardware capacity so the LRU eviction engine sees real pressure.

``derived`` reports both modes' virtual makespan, the stage-in bytes saved,
the dataset hit rate, and eviction counts. The pooled mode must beat the
baseline on makespan (including its one-time pool deploys) and save >= 50%
of the baseline's stage-in traffic — asserted here, so `benchmarks/run.py`
fails loudly if the subsystem regresses.
"""

from __future__ import annotations

from repro.core import dom_cluster
from repro.orchestrator import (
    BackfillPolicy,
    DataAwarePolicy,
    JobState,
    Orchestrator,
    summarize,
)
from repro.orchestrator.lifecycle import WorkflowSpec
from repro.pool import DatasetRef
from repro.provision import LifetimeClass, StorageSpec

from .common import time_us

GB = 1e9
N_JOBS = 120
N_DATASETS = 8          # <= 10 shared datasets
POOL_CAP_GB = 110.0     # per-pool ledger cap -> eviction pressure


def _datasets() -> list[DatasetRef]:
    return [
        DatasetRef(f"ds{k}", (15.0 + 5.0 * (k % 4)) * GB) for k in range(N_DATASETS)
    ]


def _refs(i: int, ds: list[DatasetRef]) -> tuple[DatasetRef, ...]:
    """1-3 shared inputs per job, with skewed popularity (low ids hotter)."""
    picks = {i % N_DATASETS, (i * i + 1) % (N_DATASETS // 2)}
    if i % 3 == 0:
        picks.add((i // 3) % N_DATASETS)
    return tuple(ds[k] for k in sorted(picks))


def _specs(ds: list[DatasetRef], *, pooled: bool) -> list[WorkflowSpec]:
    specs = []
    for i in range(N_JOBS):
        name = f"job{i:03d}"
        if pooled:
            storage = StorageSpec(
                name,
                lifetime=LifetimeClass.POOLED,
                datasets=_refs(i, ds),
                stage_in_bytes=2 * GB,
                stage_out_bytes=1 * GB,
            )
        else:
            storage = StorageSpec(
                name,
                nodes=1 + i % 2,
                managers=("ephemeralfs",),
                datasets=_refs(i, ds),
                stage_in_bytes=2 * GB,
                stage_out_bytes=1 * GB,
            )
        specs.append(
            WorkflowSpec(
                name=name,
                n_compute=1 + i % 3,
                storage_spec=storage,
                run_time_s=20.0 + 5.0 * (i % 6),
            )
        )
    return specs


def run_baseline():
    ds = _datasets()
    orch = Orchestrator(dom_cluster(), policy=BackfillPolicy())
    jobs = orch.run_campaign(_specs(ds, pooled=False))
    assert all(j.state is JobState.DONE for j in jobs)
    return summarize(jobs, n_storage_nodes=4)


def run_pooled():
    ds = _datasets()
    orch = Orchestrator(dom_cluster(), policy=BackfillPolicy())
    orch.enable_pools(ttl_s=None)
    sessions = [
        orch.provision.open_session(
            StorageSpec(
                f"pool{k}",
                nodes=2,
                lifetime=LifetimeClass.PERSISTENT,
                capacity_cap_bytes=POOL_CAP_GB * GB,
            )
        )
        for k in range(2)
    ]
    orch.policy = DataAwarePolicy(orch.provision)
    jobs = orch.run_campaign(_specs(ds, pooled=True))
    assert all(j.state is JobState.DONE for j in jobs)
    rep = summarize(jobs, n_storage_nodes=4, pools=orch.pools)
    setup_s = sum(s.provision_time_s for s in sessions)
    return rep, setup_s


def rows():
    base_reports, pool_reports = [], []

    us_base = time_us(lambda: base_reports.append(run_baseline()), repeat=2)
    us_pool = time_us(lambda: pool_reports.append(run_pooled()), repeat=2)

    base = base_reports[-1]
    pooled, setup_s = pool_reports[-1]
    p = pooled.pool

    saved_frac = pooled.stage_in_bytes_saved / base.staged_in_bytes
    # acceptance: >= 50% stage-in bytes saved, strictly lower makespan
    assert saved_frac >= 0.5, f"only {saved_frac:.1%} stage-in bytes saved"
    assert pooled.makespan_s + setup_s < base.makespan_s, (
        f"pooled {pooled.makespan_s + setup_s:.0f}s not under "
        f"baseline {base.makespan_s:.0f}s"
    )
    assert p is not None and p.evictions > 0, "no eviction pressure exercised"

    return [
        (
            f"pool/per-job-{N_JOBS}jobs",
            us_base,
            f"makespan={base.makespan_s:.0f}s "
            f"staged_in={base.staged_in_bytes / GB:.0f}GB",
        ),
        (
            f"pool/pooled-data-aware-{N_JOBS}jobs",
            us_pool,
            f"makespan={pooled.makespan_s:.0f}s(+{setup_s:.1f}s setup) "
            f"staged_in={pooled.staged_in_bytes / GB:.0f}GB "
            f"saved={saved_frac:.0%} hit_rate={p.hit_rate:.0%} "
            f"evictions={p.evictions}",
        ),
    ]
