"""Negotiation overhead + backend sweep for the unified StorageSession API.

Sweeps campaigns of mixed `StorageSpec`s — node-, capacity-, and
bandwidth-sized ephemeral FS requests, QoS-driven globalfs fallbacks,
KV-store grants, and pool leases — through the orchestrator, so every
session passes the `ProvisioningService` negotiation path. For each mix it
reports the virtual makespan, the per-backend session split, and the
cumulative wallclock spent inside ``negotiate()``.

Acceptance (asserted): negotiation overhead stays **under 5% of campaign
makespan** for every mix — the declarative facade must cost noise, not
schedule time. Results are also emitted as JSON
(``benchmarks/out/provision_bench.json``) for the bench trajectory.
"""

from __future__ import annotations

import json
import os

from repro.core import dom_cluster
from repro.orchestrator import BackfillPolicy, JobState, Orchestrator, summarize
from repro.orchestrator.lifecycle import WorkflowSpec
from repro.pool import DatasetRef
from repro.provision import LifetimeClass, QoS, StorageSpec

from .common import time_us

GB = 1e9
N_JOBS = 120
OVERHEAD_BUDGET = 0.05      # negotiation wallclock / virtual makespan
OUT_PATH = os.path.join(os.path.dirname(__file__), "out", "provision_bench.json")


def _ephemeral_mix(i: int) -> StorageSpec:
    """Rotating node / capacity / bandwidth sizing, ephemeralfs only."""
    name = f"efs{i:03d}"
    sizing = i % 3
    if sizing == 0:
        return StorageSpec(name, nodes=1 + i % 2, managers=("ephemeralfs",),
                           stage_in_bytes=8 * GB, stage_out_bytes=2 * GB)
    if sizing == 1:
        return StorageSpec(name, capacity_bytes=12e12, managers=("ephemeralfs",),
                           stage_in_bytes=20 * GB, stage_out_bytes=4 * GB)
    return StorageSpec(name, bandwidth=10 * GB, managers=("ephemeralfs",),
                       qos=QoS(min_bandwidth=10 * GB),
                       stage_in_bytes=30 * GB, stage_out_bytes=8 * GB)


def _negotiated_mix(i: int, ds: list[DatasetRef]) -> StorageSpec:
    """Multi-backend mix: fallback chains, KV access, zero-deploy QoS,
    pool leases — the negotiation-heavy case."""
    name = f"mix{i:03d}"
    kind = i % 5
    if kind == 0:
        return StorageSpec(name, nodes=1, managers=("ephemeralfs", "globalfs"),
                           stage_in_bytes=6 * GB, stage_out_bytes=1 * GB)
    if kind == 1:
        return StorageSpec(name, capacity_bytes=1e12,
                           managers=("globalfs", "ephemeralfs"),
                           qos=QoS(max_provision_s=1.0),
                           stage_in_bytes=2 * GB, stage_out_bytes=1 * GB)
    if kind == 2:
        return StorageSpec(name, nodes=1, access="kv", stage_in_bytes=4 * GB)
    return StorageSpec(name, lifetime=LifetimeClass.POOLED,
                       datasets=(ds[i % len(ds)],),
                       stage_in_bytes=2 * GB, stage_out_bytes=1 * GB)


def _run(mix: str) -> dict:
    ds = [DatasetRef(f"d{k}", (10.0 + 4.0 * k) * GB) for k in range(6)]
    orch = Orchestrator(dom_cluster(), policy=BackfillPolicy())
    if mix == "negotiated":
        orch.enable_pools(ttl_s=None)
        orch.provision.open_session(
            StorageSpec("bench-pool", nodes=2, lifetime=LifetimeClass.PERSISTENT)
        )
        specs = [_negotiated_mix(i, ds) for i in range(N_JOBS)]
    else:
        specs = [_ephemeral_mix(i) for i in range(N_JOBS)]
    jobs = orch.run_campaign(
        [
            WorkflowSpec(name=s.name, n_compute=1 + i % 3, storage_spec=s,
                         run_time_s=15.0 + 5.0 * (i % 4))
            for i, s in enumerate(specs)
        ]
    )
    assert all(j.state is JobState.DONE for j in jobs), f"{mix}: jobs failed"
    rep = summarize(jobs, n_storage_nodes=4, pools=orch.pools)
    stats = orch.provision.stats
    overhead = stats.negotiation_wall_s / rep.makespan_s
    assert overhead < OVERHEAD_BUDGET, (
        f"{mix}: negotiation overhead {overhead:.2%} of makespan "
        f"exceeds the {OVERHEAD_BUDGET:.0%} budget"
    )
    return {
        "mix": mix,
        "n_jobs": N_JOBS,
        "makespan_s": rep.makespan_s,
        "negotiations": stats.negotiations,
        "negotiation_wall_s": stats.negotiation_wall_s,
        "overhead_frac": overhead,
        "sessions_by_backend": dict(sorted(stats.sessions_opened.items())),
        "failed_negotiations": stats.failed_negotiations,
    }


def rows():
    results, out = [], []
    for mix in ("ephemeral", "negotiated"):
        runs = []
        us = time_us(lambda m=mix: runs.append(_run(m)), repeat=2)
        r = runs[-1]           # keep the final run per mix in the JSON
        results.append(r)
        backends = ",".join(f"{k}:{v}" for k, v in r["sessions_by_backend"].items())
        out.append(
            (
                f"provision/{mix}-{N_JOBS}jobs",
                us,
                f"makespan={r['makespan_s']:.0f}s "
                f"negotiations={r['negotiations']} "
                f"overhead={r['overhead_frac']:.4%} "
                f"backends={backends}",
            )
        )
    os.makedirs(os.path.dirname(OUT_PATH), exist_ok=True)
    with open(OUT_PATH, "w") as f:
        json.dump({"benchmark": "provision_bench", "results": results}, f, indent=2)
    out.append(("provision/json", 0.0, f"written={OUT_PATH}"))
    return out
