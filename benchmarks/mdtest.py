"""Tables I & II: mdtest metadata op rates — modeled rates from the
calibrated tables; the functional path counts real metadata ops through the
sharded metadata services.
"""

from __future__ import annotations

import time

from repro.core import ault_efs, dom_efs, dom_lustre, predict_mdtest

from .common import mk_efs


def _functional_md_us(fs, n: int = 200) -> float:
    t0 = time.perf_counter()
    fs.mkdir("/md")
    for i in range(n):
        fs.create(f"/md/f{i}")
    for i in range(n):
        fs.stat(f"/md/f{i}")
    for i in range(n):
        fs.unlink(f"/md/f{i}")
    return (time.perf_counter() - t0) * 1e6 / (3 * n)


def rows():
    out = []
    efs = mk_efs(2)
    us = _functional_md_us(efs)
    ops_total = sum(sum(s.ops.values()) for s in efs.md_services)
    assert ops_total > 0
    efs.teardown()
    for dep_name, dep in (("beegfs2dw", dom_efs(2)),
                          ("lustre", dom_lustre()),
                          ("beegfs-ault", ault_efs())):
        for (target, op), rate in predict_mdtest(dep).items():
            out.append((f"mdtest/{dep_name}/{target}-{op}", us, f"{rate:.0f}ops"))
    return out
