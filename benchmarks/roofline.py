"""§Roofline reader: aggregates dry-run artifacts into the roofline table.

Run the dry-runs first (``python -m repro.launch.dryrun --arch all [--multi-pod]``);
this module only reads artifacts/dryrun/*.json.
"""

from __future__ import annotations

import glob
import json
import os

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def load_records(mesh: str | None = None):
    recs = []
    for path in sorted(glob.glob(os.path.join(ART, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if mesh and r["mesh"] != mesh:
            continue
        recs.append(r)
    return recs


def rows():
    out = []
    for r in load_records():
        if r["variant"] != "baseline":
            continue
        t = r["terms"]
        dom = r["dominant"].replace("_s", "")
        frac = r.get("roofline_fraction")
        out.append((
            f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}",
            t["compute_s"] * 1e6,
            f"dom={dom};frac={frac:.3f};coll={t['collective_s']*1e3:.1f}ms",
        ))
    return out


def table(mesh="16x16"):
    hdr = (f"{'arch':24s} {'shape':12s} {'comp_ms':>9s} {'mem_ms':>9s} "
           f"{'coll_ms':>10s} {'dominant':>11s} {'MFLOPratio':>10s} {'fit16G':>6s}")
    lines = [hdr, "-" * len(hdr)]
    for r in load_records(mesh):
        if r["variant"] != "baseline":
            continue
        t = r["terms"]
        lines.append(
            f"{r['arch']:24s} {r['shape']:12s} {t['compute_s']*1e3:9.2f} "
            f"{t['memory_s']*1e3:9.2f} {t['collective_s']*1e3:10.2f} "
            f"{r['dominant'].replace('_s',''):>11s} "
            f"{(r['useful_flops_ratio'] or 0):10.3f} "
            f"{str(r['memory']['peak_ok_16GiB']):>6s}"
        )
    return "\n".join(lines)


if __name__ == "__main__":
    print(table())


def variants_table():
    """§Perf companion: baseline vs optimized-variant cells side by side."""
    base = {}
    opt = []
    for r in load_records("16x16"):
        key = (r["arch"], r["shape"])
        if r["variant"] == "baseline":
            base[key] = r
        else:
            opt.append(r)
    lines = [f"{'cell':38s} {'variant':28s} {'coll_ms base':>12s} {'coll_ms opt':>12s} {'delta':>7s}"]
    lines.append("-" * len(lines[0]))
    for r in sorted(opt, key=lambda x: (x["arch"], x["shape"], x["variant"])):
        b = base.get((r["arch"], r["shape"]))
        if not b:
            continue
        cb = b["terms"]["collective_s"] * 1e3
        co = r["terms"]["collective_s"] * 1e3
        delta = (co - cb) / cb * 100 if cb else 0.0
        lines.append(
            f"{r['arch'] + '/' + r['shape']:38s} {r['variant']:28s} "
            f"{cb:12.2f} {co:12.2f} {delta:+6.1f}%"
        )
    return "\n".join(lines)
