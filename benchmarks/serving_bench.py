"""Serving benchmark: the autoscaler must beat fixed fleets, cheaply.

One diurnal + burst request trace (deterministic, seeded) is replayed
against three fleet configurations on dom's 8+4 nodes:

* **fixed-min** — one replica, no scaling: the burst buries it, so its
  p99 TTFT is the number an autoscaler must beat;
* **fixed-max** — ``MAX_REPLICAS`` replicas for the whole campaign: great
  latency, but its replica-seconds are the cost ceiling;
* **auto** — start at one replica; a queue-delay SLO burn-rate alert
  (PR 7 ``AlertEngine``) drives scale-up, idle-TTL drives scale-down.

Gates (all on deterministic virtual-clock results, so they are exact):

1. auto p99 TTFT **strictly below** fixed-min p99 TTFT;
2. auto replica-seconds **<=** fixed-max replica-seconds;
3. auto sustained decode throughput >= ``TOKENS_PER_S_FLOOR``;
4. auto p99 TTFT under the diurnal+burst trace <= ``TTFT_P99_CEILING_S``;
5. model weights staged into the pool **exactly once** per campaign —
   asserted from the trace: the loader lease is the only attach with
   misses, every replica attach is a pure catalog hit.

Results land in ``benchmarks/out/serving_bench.json`` and the repo-root
``BENCH_serving.json`` trajectory point.

    PYTHONPATH=src python -m benchmarks.serving_bench
"""

from __future__ import annotations

import json
import os
import time

from repro.core import dom_cluster
from repro.obs import (
    AlertEngine,
    AlertRule,
    MetricsHub,
    SLOSpec,
    SLOTracker,
    TraceRecorder,
)
from repro.orchestrator import burst_arrivals, diurnal_arrivals
from repro.serving import (
    Autoscaler,
    AutoscalerConfig,
    ModelProfile,
    Request,
    ServingCampaign,
    synthesize_requests,
)

OUT_DIR = os.path.join(os.path.dirname(__file__), "out")
OUT_PATH = os.path.join(OUT_DIR, "serving_bench.json")
BENCH_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_serving.json")

GB = 1e9

# -- the workload: a breathing day with a flash crowd -------------------------
N_DIURNAL, N_BURST = 600, 240
BURST_T0, BURST_T1 = 400.0, 520.0
MODEL = ModelProfile("qwen3-14b-sim", weight_bytes=28 * GB, n_slots=8)

# -- fleet + gate constants ---------------------------------------------------
MIN_REPLICAS, MAX_REPLICAS = 1, 4
TOKENS_PER_S_FLOOR = 120.0       # sustained generated tok/s (auto config)
TTFT_P99_CEILING_S = 60.0        # p99 TTFT under the diurnal+burst trace


def make_requests() -> list[Request]:
    times = sorted(
        diurnal_arrivals(
            N_DIURNAL, base_rate=0.5, peak_rate=2.0, period_s=1_200.0, seed=3
        )
        + burst_arrivals(
            N_BURST, base_rate=0.05, burst_rate=6.0,
            burst_t0=BURST_T0, burst_t1=BURST_T1, seed=4,
        )
    )
    return synthesize_requests(times, seed=5)


def make_obs():
    """Hub + queue-delay SLO + burn-rate alert + recorder, freshly wired
    (each campaign needs its own: the hub's series are per-run state)."""
    hub = MetricsHub()
    slos = SLOTracker(
        hub,
        [
            SLOSpec(
                name="queue-delay",
                series="serving/queue_delay_s",
                op="<=",
                target=2.0,
                objective=0.85,
                burn_windows=(120.0, 600.0),
                description="head-of-queue wait stays bounded",
            )
        ],
    )
    alerts = AlertEngine(
        hub,
        [
            AlertRule(
                name="queue-delay-burn",
                kind="burn",
                slo="queue-delay",
                op=">=",
                target=3.0,
                window_s=120.0,
                severity="critical",
            )
        ],
        slos=slos,
    )
    rec = TraceRecorder(metrics=hub, sample_every_s=10.0, alerts=alerts)
    return hub, alerts, rec


def run_config(name: str, *, initial: int, autoscale: bool):
    hub, alerts, rec = make_obs()
    asc = None
    if autoscale:
        asc = Autoscaler(
            alerts,
            AutoscalerConfig(
                rule="queue-delay-burn",
                min_replicas=MIN_REPLICAS,
                max_replicas=MAX_REPLICAS,
                control_every_s=15.0,
                scale_up_cooldown_s=60.0,
                idle_ttl_s=90.0,
            ),
            recorder=rec,
        )
    camp = ServingCampaign(
        dom_cluster(), MODEL, make_requests(),
        initial_replicas=initial, autoscaler=asc, recorder=rec,
    )
    t0 = time.perf_counter()
    report = camp.run()
    wall_s = time.perf_counter() - t0

    attaches = [e for e in rec.events if e[0] == "lease_attached"]
    miss_attaches = [e for e in attaches if e[3]["misses"] > 0]
    pm = camp.service.pool_manager
    return {
        "name": name,
        "wall_s": round(wall_s, 4),
        "completed": report.n_completed,
        "ttft_p50_s": round(report.ttft_p50_s, 4),
        "ttft_p99_s": round(report.ttft_p99_s, 4),
        "tpot_p99_s": round(report.tpot_p99_s, 5),
        "tokens_per_s": round(report.tokens_per_s, 1),
        "replica_seconds": round(report.replica_seconds, 1),
        "peak_replicas": report.peak_replicas,
        "scale_ups": report.scale_ups,
        "scale_downs": report.scale_downs,
        "alert_incidents": len(alerts.incidents),
        "lease_attaches": len(attaches),
        "miss_attaches": len(miss_attaches),
        "bytes_staged": pm.stats.bytes_staged,
        "mean_occupancy": round(report.mean_occupancy, 3),
    }, report, camp


def run_gate(verbose: bool = True) -> dict:
    fixed_min, _, _ = run_config("fixed-min", initial=MIN_REPLICAS, autoscale=False)
    fixed_max, _, _ = run_config("fixed-max", initial=MAX_REPLICAS, autoscale=False)
    auto, _, _ = run_config("auto", initial=MIN_REPLICAS, autoscale=True)

    checks = {
        "auto_beats_fixed_min_p99": auto["ttft_p99_s"] < fixed_min["ttft_p99_s"],
        "auto_within_fixed_max_replica_seconds":
            auto["replica_seconds"] <= fixed_max["replica_seconds"],
        "auto_tokens_per_s_floor": auto["tokens_per_s"] >= TOKENS_PER_S_FLOOR,
        "auto_ttft_p99_ceiling": auto["ttft_p99_s"] <= TTFT_P99_CEILING_S,
        # the staged-once invariant, per campaign: one attach carried
        # misses (the weight loader), and it staged exactly the weights
        "weights_staged_once": all(
            c["miss_attaches"] == 1
            and c["bytes_staged"] == MODEL.weight_bytes
            for c in (fixed_min, fixed_max, auto)
        ),
        "all_requests_completed": all(
            c["completed"] == N_DIURNAL + N_BURST
            for c in (fixed_min, fixed_max, auto)
        ),
        "autoscaler_scaled": auto["scale_ups"] >= 1 and auto["scale_downs"] >= 1,
    }
    payload = {
        "bench": "serving",
        "workload": {
            "n_requests": N_DIURNAL + N_BURST,
            "burst_window_s": [BURST_T0, BURST_T1],
            "model": MODEL.name,
            "weight_bytes": MODEL.weight_bytes,
        },
        "configs": {c["name"]: c for c in (fixed_min, fixed_max, auto)},
        "gate": {
            "tokens_per_s_floor": TOKENS_PER_S_FLOOR,
            "ttft_p99_ceiling_s": TTFT_P99_CEILING_S,
            "checks": checks,
            "ok": all(checks.values()),
        },
    }
    os.makedirs(OUT_DIR, exist_ok=True)
    for path in (OUT_PATH, BENCH_PATH):
        with open(path, "w", encoding="utf-8") as fh:
            json.dump(payload, fh, indent=2, sort_keys=True)
            fh.write("\n")
    if verbose:
        for c in (fixed_min, fixed_max, auto):
            print(
                f"{c['name']:>9}: p99 TTFT {c['ttft_p99_s']:7.2f} s | "
                f"{c['tokens_per_s']:6.1f} tok/s | "
                f"{c['replica_seconds']:7.1f} replica-s | "
                f"peak {c['peak_replicas']} | "
                f"{c['scale_ups']} up / {c['scale_downs']} down"
            )
        for k, ok in checks.items():
            print(f"  {'PASS' if ok else 'FAIL'}  {k}")
    if not payload["gate"]["ok"]:
        failed = [k for k, ok in checks.items() if not ok]
        raise SystemExit(f"serving gate FAILED: {failed}")
    return payload


def rows():
    p = run_gate(verbose=False)
    cfg = p["configs"]
    auto, fmin, fmax = cfg["auto"], cfg["fixed-min"], cfg["fixed-max"]
    n = p["workload"]["n_requests"]
    return [
        (
            "serving_auto",
            auto["wall_s"] * 1e6 / n,
            f"p99 TTFT {auto['ttft_p99_s']:.2f}s vs fixed-min "
            f"{fmin['ttft_p99_s']:.2f}s at {auto['replica_seconds']:.0f} "
            f"replica-s (fixed-max {fmax['replica_seconds']:.0f})",
        ),
        (
            "serving_throughput",
            auto["wall_s"] * 1e6 / n,
            f"{auto['tokens_per_s']:.0f} tok/s sustained, "
            f"occupancy {auto['mean_occupancy']:.2f}, "
            f"weights staged once ({auto['bytes_staged'] / 1e9:.0f} GB)",
        ),
    ]


if __name__ == "__main__":
    run_gate(verbose=True)
