"""Campaign benchmark: the orchestrator under queue pressure.

200 jobs with more aggregate storage demand than the 4 DataWarp nodes can
hold at once, pushed through each queueing policy — every job's demand a
declarative `StorageSpec` negotiated by the orchestrator's
`ProvisioningService`. ``us_per_call`` is the wallclock of simulating the
whole campaign (the event engine's job is to make this milliseconds);
``derived`` reports virtual makespan and storage-node utilization.
"""

from __future__ import annotations

from repro.core import dom_cluster
from repro.orchestrator import (
    BackfillPolicy,
    FIFOPolicy,
    Orchestrator,
    StorageAwarePolicy,
    summarize,
)
from repro.orchestrator.lifecycle import WorkflowSpec
from repro.provision import StorageSpec

from .common import time_us

N_JOBS = 200
GB = 1e9


def _specs() -> list[WorkflowSpec]:
    return [
        WorkflowSpec(
            name=f"job{i:03d}",
            n_compute=1 + i % 4,
            storage_spec=StorageSpec(
                f"job{i:03d}",
                nodes=1 + i % 3,
                managers=("ephemeralfs",),
                stage_in_bytes=(8 + 24 * (i % 5)) * GB,
                stage_out_bytes=(2 + 6 * (i % 3)) * GB,
            ),
            run_time_s=20.0 + 15.0 * (i % 7),
        )
        for i in range(N_JOBS)
    ]


def rows():
    out = []
    for policy in (FIFOPolicy(), BackfillPolicy(), StorageAwarePolicy()):
        reports = []

        def campaign():
            orch = Orchestrator(dom_cluster(), policy=policy)
            jobs = orch.run_campaign(_specs())
            reports.append(
                summarize(jobs, n_storage_nodes=len(orch.scheduler.cluster.storage_nodes))
            )

        us = time_us(campaign, repeat=2)
        rep = reports[-1]
        assert rep.n_done == N_JOBS, f"{policy.name}: {rep.n_failed} jobs failed"
        out.append(
            (
                f"orchestrator/{policy.name}-{N_JOBS}jobs",
                us,
                f"makespan={rep.makespan_s:.0f}s "
                f"util={rep.storage_node_utilization:.2f} "
                f"wait={rep.mean_queue_wait_s:.0f}s",
            )
        )
    return out
