"""Pilot many-task benchmark: 1M tasks through two-level scheduling.

The PR 10 tentpole — `Orchestrator.submit_pilot` + the in-pilot
`TaskScheduler` — exists so a many-task campaign pays the job lifecycle
(negotiation, pooled session, block grant, 7+ engine events) once per
*pilot* instead of once per task. This bench is the proof, in three legs:

* **traced leg** (reduced size) — a `TraceRecorder` campaign asserting
  the amortization is exact: one negotiation and ONE pooled session per
  pilot, however many tasks stream through it, and the engine's
  events-per-task from coalesced completion batches;
* **baseline leg** (reduced size) — the same work shape submitted as
  individual jobs. Events per job is size-independent, so the reduced
  measurement is the honest per-task cost of the one-level path; the
  gate asserts the pilot path sees >= ``EVENTS_RATIO_FLOOR`` (20x) fewer
  engine events per task;
* **perf leg** (full size) — 1,000,000 tasks across 50 pilots, untraced,
  asserting ``TASKS_PER_CPU_S_FLOOR`` tasks per CPU-second scaled by the
  same reference-campaign machine score `campaign_scale_bench` uses.

Results land in ``benchmarks/out/pilot_bench.json``; a full-size run also
seeds/extends the ``tasks_per_s_trajectory`` field of the repo-root
``BENCH_campaign.json`` (the perf-trajectory file).

Run the full 1M-task gate:

    PYTHONPATH=src python -m benchmarks.pilot_bench

CI perf-smoke (reduced size, CPU budget asserted):

    PYTHONPATH=src python -m benchmarks.pilot_bench \
        --tasks 100000 --pilots 10 --compute 200 --storage 50 \
        --budget-cpu-s 60
"""

from __future__ import annotations

import argparse
import gc
import json
import os
import time

from repro.core import synthetic_cluster
from repro.orchestrator import (
    Orchestrator,
    PilotSpec,
    TaskSpec,
    WorkflowSpec,
    summarize,
)
from repro.provision import StorageSpec

from .campaign_scale_bench import REFERENCE_MACHINE_SCORE, machine_score

GB = 1e9

# Full-size configuration: 1,000,000 tasks through 50 pilots on a
# 500-node cluster (each pilot: 4 compute nodes x 8 slots, 20k tasks).
N_TASKS = 1_000_000
N_PILOTS = 50
N_COMPUTE = 400
N_STORAGE = 100

TASKS_PER_CPU_S_FLOOR = 300_000     # full-size config only, machine-scaled
EVENTS_RATIO_FLOOR = 20.0           # per-job events/task over pilot events/task
#: attempts per measured config (shared containers shift speed between runs)
FLOOR_ATTEMPTS = 4

# Reduced sizes for the traced/baseline legs: events-per-task is
# size-independent on both paths, so these stay cheap at any scale.
TRACED_TASKS_PER_PILOT = 2_000
BASELINE_JOBS = 2_000

OUT_PATH = os.path.join(os.path.dirname(__file__), "out", "pilot_bench.json")
BENCH_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_campaign.json")


def _pilot_campaign(n_pilots: int, tasks_per_pilot: int, n_compute: int,
                    n_storage: int, recorder=None) -> Orchestrator:
    orch = Orchestrator(
        synthetic_cluster(n_compute, n_storage),
        recorder=recorder,
        record_allocations=False,
    )
    pool_nodes = max(2, n_storage // 5)
    orch.enable_pools(ttl_s=None).create_pool(nodes=pool_nodes)
    task = TaskSpec("t", run_time_s=30.0, cores=0.125, stage_in_bytes=0.1 * GB)
    per_pilot_nodes = max(1, min(4, n_compute // max(1, n_pilots)))
    for i in range(n_pilots):
        orch.submit_pilot(
            PilotSpec(f"p{i:03d}", n_compute=per_pilot_nodes,
                      slots_per_node=8, completion_quantum_s=5.0),
            tasks=((task, tasks_per_pilot),),
            at=i * 0.5,
        )
    return orch


def traced_leg(n_pilots: int, tasks_per_pilot: int, n_compute: int,
               n_storage: int) -> dict:
    """Reduced-size traced campaign: prove the acquisition amortizes to
    exactly one negotiation + one session per pilot and measure the
    coalesced engine events per task."""
    from repro.obs import TraceRecorder

    rec = TraceRecorder()
    orch = _pilot_campaign(n_pilots, tasks_per_pilot, n_compute, n_storage,
                           recorder=rec)
    orch.engine.run()
    n_tasks = n_pilots * tasks_per_pilot
    c = rec.counts
    assert c.get("pilot.started", 0) == n_pilots, c
    assert c.get("sessions.opened.ephemeralfs", 0) == n_pilots, (
        f"expected ONE session per pilot, got "
        f"{c.get('sessions.opened.ephemeralfs', 0)} for {n_pilots} pilots"
    )
    assert c.get("negotiation.scored", 0) == n_pilots, (
        f"expected ONE negotiation per pilot, got "
        f"{c.get('negotiation.scored', 0)} for {n_pilots} pilots"
    )
    assert c.get("pilot.tasks_done", 0) == n_tasks
    assert orch.counters.tasks_done == n_tasks
    events = orch.engine.events_processed
    return {
        "n_pilots": n_pilots,
        "n_tasks": n_tasks,
        "engine_events": events,
        "events_per_task": round(events / n_tasks, 5),
        "completion_batches": c.get("pilot.batches", 0),
        "negotiations": c.get("negotiation.scored", 0),
        "sessions_opened": c.get("sessions.opened.ephemeralfs", 0),
    }


def baseline_leg(n_jobs: int, n_compute: int, n_storage: int) -> dict:
    """The one-level path: the same task shape submitted as individual
    jobs, each paying its own negotiation/session/lifecycle. Events per
    job is size-independent — this is the honest per-task event cost the
    pilot amortizes away."""
    orch = Orchestrator(
        synthetic_cluster(n_compute, n_storage),
        record_allocations=False,
    )
    specs = [
        WorkflowSpec(
            f"j{i:05d}", n_compute=1,
            storage_spec=StorageSpec(
                f"j{i:05d}", nodes=1, managers=("ephemeralfs",),
                stage_in_bytes=0.1 * GB,
            ),
            run_time_s=30.0,
        )
        for i in range(n_jobs)
    ]
    jobs = orch.run_campaign(specs)
    report = summarize(jobs, n_storage_nodes=n_storage)
    assert report.n_done == n_jobs, f"{report.n_failed} baseline jobs failed"
    events = orch.engine.events_processed
    return {
        "n_jobs": n_jobs,
        "engine_events": events,
        "events_per_job": round(events / n_jobs, 3),
    }


def perf_leg(n_tasks: int, n_pilots: int, n_compute: int,
             n_storage: int) -> dict:
    """Untraced full-scale run: tasks per CPU-second through the whole
    two-level stack (arrivals, negotiation, pooled leases, wave packing,
    coalesced batches, stage-out, teardown)."""
    tasks_per_pilot = max(1, n_tasks // n_pilots)
    orch = _pilot_campaign(n_pilots, tasks_per_pilot, n_compute, n_storage)
    gc_was_enabled = gc.isenabled()
    gc.collect()
    gc.freeze()
    gc.disable()
    try:
        wall0 = time.perf_counter()
        cpu0 = time.process_time()
        orch.engine.run()
        cpu_s = time.process_time() - cpu0
        wall_s = time.perf_counter() - wall0
    finally:
        if gc_was_enabled:
            gc.enable()
        gc.unfreeze()
        gc.collect()
    done = orch.counters.tasks_done
    n_total = n_pilots * tasks_per_pilot
    assert done == n_total, f"{n_total - done} tasks did not complete"
    events = orch.engine.events_processed
    return {
        "n_tasks": n_total,
        "n_pilots": n_pilots,
        "n_compute": n_compute,
        "n_storage": n_storage,
        "wall_s": round(wall_s, 3),
        "cpu_s": round(cpu_s, 3),
        "tasks_per_cpu_s": round(n_total / max(cpu_s, 1e-9)),
        "tasks_per_wall_s": round(n_total / max(wall_s, 1e-9)),
        "engine_events": events,
        "events_per_task": round(events / n_total, 5),
    }


def write_trajectory(payload: dict, *, full_size: bool) -> None:
    """Every run refreshes the (gitignored) benchmarks/out/ copy; only a
    full-size run may touch the committed repo-root trajectory, where it
    seeds/extends the ``tasks_per_s_trajectory`` list alongside the PR 4
    campaign-scale record."""
    os.makedirs(os.path.dirname(OUT_PATH), exist_ok=True)
    with open(OUT_PATH, "w") as fh:
        json.dump(payload, fh, indent=2, sort_keys=True)
        fh.write("\n")
    if not full_size:
        return
    try:
        with open(BENCH_PATH) as fh:
            bench = json.load(fh)
    except (OSError, ValueError):
        bench = {}
    perf = payload["perf"]
    bench.setdefault("tasks_per_s_trajectory", []).append({
        "timestamp": payload["timestamp"],
        "n_tasks": perf["n_tasks"],
        "n_pilots": perf["n_pilots"],
        "tasks_per_cpu_s": perf["tasks_per_cpu_s"],
        "events_per_task": perf["events_per_task"],
        "events_ratio_vs_per_job": payload["events_ratio_vs_per_job"],
    })
    with open(BENCH_PATH, "w") as fh:
        json.dump(bench, fh, indent=2, sort_keys=True)
        fh.write("\n")


def run_gate(
    n_tasks: int,
    n_pilots: int,
    n_compute: int,
    n_storage: int,
    *,
    tasks_floor: float | None = None,
    ratio_floor: float | None = EVENTS_RATIO_FLOOR,
    budget_cpu_s: float | None = None,
) -> dict:
    traced = traced_leg(
        min(n_pilots, 10),
        min(TRACED_TASKS_PER_PILOT, max(1, n_tasks // max(1, n_pilots))),
        n_compute, n_storage,
    )
    baseline = baseline_leg(min(BASELINE_JOBS, n_tasks), n_compute, n_storage)
    ratio = baseline["events_per_job"] / max(traced["events_per_task"], 1e-9)
    if ratio_floor is not None:
        assert ratio >= ratio_floor, (
            f"pilot path sees only {ratio:.1f}x fewer engine events per task "
            f"than the per-job baseline (floor {ratio_floor}x): "
            f"{traced['events_per_task']} vs {baseline['events_per_job']}"
        )
    # perf leg: best of up to FLOOR_ATTEMPTS, each normalized by the
    # machine score sampled around it (campaign_scale_bench convention)
    with_floor = tasks_floor is not None
    attempts = []
    score_prev = machine_score(repeat=1) if with_floor else None
    for _ in range(FLOOR_ATTEMPTS if with_floor else 1):
        row = perf_leg(n_tasks, n_pilots, n_compute, n_storage)
        if with_floor:
            score_next = machine_score(repeat=1)
            row["machine_score"] = round(max(score_prev, score_next))
            row["floor_scale"] = round(
                min(1.0, row["machine_score"] / REFERENCE_MACHINE_SCORE), 3
            )
            score_prev = score_next
        attempts.append(row)
        if with_floor and row["tasks_per_cpu_s"] >= tasks_floor * row["floor_scale"]:
            break
    if with_floor:
        perf = max(
            attempts,
            key=lambda r: r["tasks_per_cpu_s"] / max(r["floor_scale"], 1e-9),
        )
        scaled = tasks_floor * perf["floor_scale"]
        assert perf["tasks_per_cpu_s"] >= scaled, (
            f"{perf['tasks_per_cpu_s']} tasks/cpu-s below the floor "
            f"({tasks_floor} x machine scale {perf['floor_scale']:.2f} "
            f"= {scaled:.0f})"
        )
    else:
        perf = min(attempts, key=lambda r: r["cpu_s"])
    perf["repeats"] = len(attempts)
    if budget_cpu_s is not None:
        assert perf["cpu_s"] <= budget_cpu_s, (
            f"pilot campaign took {perf['cpu_s']} CPU-s, budget {budget_cpu_s}"
        )
    payload = {
        "bench": "pilot_many_task",
        "config": {
            "n_tasks": n_tasks,
            "n_pilots": n_pilots,
            "n_compute": n_compute,
            "n_storage": n_storage,
            "tasks_per_cpu_s_floor": tasks_floor,
            "events_ratio_floor": ratio_floor,
            "reference_machine_score": REFERENCE_MACHINE_SCORE,
        },
        "traced": traced,
        "baseline_per_job": baseline,
        "events_ratio_vs_per_job": round(ratio, 1),
        "perf": perf,
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
    }
    full_size = (
        n_tasks >= N_TASKS
        and n_compute >= N_COMPUTE
        and tasks_floor is not None
    )
    write_trajectory(payload, full_size=full_size)
    return payload


def rows():
    """Registered entry point for ``benchmarks.run`` — a reduced-size gate
    (the full 1M-task config is the module's __main__)."""
    payload = run_gate(100_000, 10, 200, 50)
    traced, perf = payload["traced"], payload["perf"]
    return [
        (
            f"pilot/{perf['n_tasks']}tasks-{perf['n_pilots']}pilots",
            perf["wall_s"] * 1e6,
            f"tasks/cpu-s={perf['tasks_per_cpu_s']} "
            f"ev/task={perf['events_per_task']}",
        ),
        (
            "pilot/amortization",
            0.0,
            f"ratio-vs-per-job={payload['events_ratio_vs_per_job']}x "
            f"negotiations={traced['negotiations']}/"
            f"{traced['n_pilots']}pilots "
            f"batches={traced['completion_batches']}",
        ),
    ]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tasks", type=int, default=N_TASKS)
    ap.add_argument("--pilots", type=int, default=N_PILOTS)
    ap.add_argument("--compute", type=int, default=N_COMPUTE)
    ap.add_argument("--storage", type=int, default=N_STORAGE)
    ap.add_argument(
        "--budget-cpu-s", type=float, default=None,
        help="assert the perf leg stays under this CPU-second budget",
    )
    ap.add_argument(
        "--no-floors", action="store_true",
        help="skip the tasks/sec and events-ratio floor assertions",
    )
    args = ap.parse_args()
    full_size = args.tasks >= N_TASKS and not args.no_floors
    payload = run_gate(
        args.tasks,
        args.pilots,
        args.compute,
        args.storage,
        tasks_floor=TASKS_PER_CPU_S_FLOOR if full_size else None,
        ratio_floor=None if args.no_floors else EVENTS_RATIO_FLOOR,
        budget_cpu_s=args.budget_cpu_s,
    )
    print(json.dumps(payload, indent=2, sort_keys=True))


if __name__ == "__main__":
    main()
