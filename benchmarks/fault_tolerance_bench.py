"""Checkpoint-aware requeue vs restart-from-scratch on a faulty campaign.

The fault-tolerance acceptance scenario: a seeded campaign where a third of
the jobs trip a fault at the ``run`` phase. The baseline replays every
faulted job from zero — full re-provision, full re-stage, full run — which
is what PR 1-4 always did. The checkpointing mode gives every job a commit
cadence (`WorkflowSpec.checkpoint_every_s`, each commit paying a modeled
checkpoint write against the session's bandwidth): faulted jobs requeue as
*resume* attempts that pay only the uncommitted run remainder and re-stage
only data that was actually lost (warm-node landings skip stage-in
entirely; cold landings re-read the checkpoint from the global FS).

Faults are *scripted* per job name (seeded), so both modes fight exactly
the same fault pattern — the comparison isolates the recovery policy.
Asserted here (so ``benchmarks/run.py`` fails loudly on regression):
checkpointing's makespan AND its re-staged bytes are strictly below the
restart-from-scratch baseline. A third scenario exercises preemption: with
a `PreemptionPolicy` installed, late high-priority arrivals
checkpoint-and-release running victims and start strictly sooner than in
the no-preemption replay.

``derived`` reports both modes' virtual makespan, staged bytes, and the
work-saved counters; the JSON trajectory lands in
``benchmarks/out/fault_tolerance.json`` and the repo-root
``BENCH_fault.json`` perf-trajectory point.
"""

from __future__ import annotations

import json
import os
import random
import time

from repro.core import synthetic_cluster
from repro.orchestrator import (
    BackfillPolicy,
    JobState,
    Orchestrator,
    PreemptionPolicy,
    WorkflowSpec,
    summarize,
)
from repro.provision import StorageSpec
from repro.runtime import FaultInjector

from .common import time_us

GB = 1e9
N_JOBS = 60
SEED = 7
FAULT_FRACTION = 0.35
OUT_PATH = os.path.join(os.path.dirname(__file__), "out", "fault_tolerance.json")
BENCH_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_fault.json")


class ScriptedRunFaults(FaultInjector):
    """Trips the run phase once for a fixed, seeded subset of job names —
    identical across both campaign modes by construction."""

    def __init__(self, names):
        super().__init__()
        self._left = {n: 1 for n in names}

    def trip(self, job_name, phase):
        if phase == "run" and self._left.get(job_name, 0) > 0:
            self._left[job_name] -= 1
            self.trips.append((job_name, phase))
            return True
        return False


def _faulty_names():
    rng = random.Random(SEED)
    names = [f"job{i:03d}" for i in range(N_JOBS)]
    return frozenset(rng.sample(names, int(N_JOBS * FAULT_FRACTION)))


def _specs(*, checkpointing: bool, priorities: bool = False):
    rng = random.Random(SEED + 1)
    specs = []
    for i in range(N_JOBS):
        name = f"job{i:03d}"
        specs.append(
            WorkflowSpec(
                name,
                1 + i % 4,
                storage_spec=StorageSpec(
                    name,
                    nodes=1 + i % 2,
                    managers=("ephemeralfs",),
                    stage_in_bytes=rng.uniform(5, 25) * GB,
                    stage_out_bytes=2 * GB,
                ),
                run_time_s=rng.uniform(60, 180),
                max_retries=3,
                checkpoint_every_s=20.0 if checkpointing else None,
                checkpoint_bytes=2 * GB if checkpointing else 0.0,
                priority=(5 if priorities and i % 10 == 9 else 0),
            )
        )
    return specs


def _campaign(*, checkpointing: bool, priorities: bool = False,
              preemption: bool = False):
    orch = Orchestrator(
        synthetic_cluster(24, 8),
        policy=BackfillPolicy(),
        faults=ScriptedRunFaults(_faulty_names()),
        preemption=PreemptionPolicy() if preemption else None,
    )
    specs = _specs(checkpointing=checkpointing, priorities=priorities)
    times = [i * 2.0 for i in range(len(specs))]
    jobs = orch.run_campaign(specs, submit_times=times)
    assert all(j.state is JobState.DONE for j in jobs), "campaign left stragglers"
    rep = summarize(jobs, n_storage_nodes=8)
    hi_waits = [
        b.queue_wait_s
        for b, j in zip(rep.breakdowns, jobs)
        if j.spec.priority > 0
    ]
    return rep, hi_waits


def rows():
    reps = {}

    def _run(key, **kw):
        reps[key] = _campaign(**kw)

    us_base = time_us(lambda: _run("base", checkpointing=False), repeat=2)
    us_ckpt = time_us(lambda: _run("ckpt", checkpointing=True), repeat=2)
    us_pre = time_us(
        lambda: _run("pre", checkpointing=True, priorities=True, preemption=True),
        repeat=2,
    )
    _run("pre_off", checkpointing=True, priorities=True, preemption=False)

    base, _ = reps["base"]
    ckpt, _ = reps["ckpt"]
    pre, pre_waits = reps["pre"]
    _, off_waits = reps["pre_off"]

    # acceptance: same faults, strictly less wall time and re-staged traffic
    assert ckpt.makespan_s < base.makespan_s, (
        f"checkpointing makespan {ckpt.makespan_s:.0f}s not under "
        f"restart-from-scratch {base.makespan_s:.0f}s"
    )
    assert ckpt.staged_in_bytes < base.staged_in_bytes, (
        f"checkpointing re-staged {ckpt.staged_in_bytes / GB:.0f}GB, "
        f"baseline {base.staged_in_bytes / GB:.0f}GB"
    )
    assert ckpt.resumes > 0 and ckpt.run_s_saved > 0
    # preemption: the high-priority arrivals waited strictly less than in
    # the identical campaign without a preemption policy
    assert pre.preemptions > 0, "no preemption exercised"
    assert sum(pre_waits) < sum(off_waits), (
        f"priority waits {sum(pre_waits):.0f}s not under "
        f"no-preemption {sum(off_waits):.0f}s"
    )

    saved_frac = 1.0 - ckpt.staged_in_bytes / base.staged_in_bytes
    results = {
        "benchmark": "fault_tolerance_bench",
        "n_jobs": N_JOBS,
        "fault_fraction": FAULT_FRACTION,
        "baseline": {
            "makespan_s": base.makespan_s,
            "staged_in_bytes": base.staged_in_bytes,
            "retries": base.total_retries,
        },
        "checkpointing": {
            "makespan_s": ckpt.makespan_s,
            "staged_in_bytes": ckpt.staged_in_bytes,
            "retries": ckpt.total_retries,
            "checkpoints_committed": ckpt.checkpoints_committed,
            "resumes": ckpt.resumes,
            "run_s_saved": ckpt.run_s_saved,
            "stage_in_bytes_saved": ckpt.stage_in_bytes_saved,
        },
        "preemption": {
            "preemptions": pre.preemptions,
            "priority_wait_s": sum(pre_waits),
            "priority_wait_s_without": sum(off_waits),
        },
    }
    results["timestamp"] = time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime())
    os.makedirs(os.path.dirname(OUT_PATH), exist_ok=True)
    # this scenario runs at its full (only) size every time, so both the
    # gitignored out/ copy and the committed trajectory point refresh
    for path in (OUT_PATH, BENCH_PATH):
        with open(path, "w") as f:
            json.dump(results, f, indent=2, sort_keys=True)
            f.write("\n")

    return [
        (
            f"fault_tol/restart-{N_JOBS}jobs",
            us_base,
            f"makespan={base.makespan_s:.0f}s "
            f"staged_in={base.staged_in_bytes / GB:.0f}GB "
            f"retries={base.total_retries}",
        ),
        (
            f"fault_tol/checkpointing-{N_JOBS}jobs",
            us_ckpt,
            f"makespan={ckpt.makespan_s:.0f}s "
            f"staged_in={ckpt.staged_in_bytes / GB:.0f}GB (-{saved_frac:.0%}) "
            f"resumes={ckpt.resumes} run_saved={ckpt.run_s_saved:.0f}s "
            f"ckpts={ckpt.checkpoints_committed}",
        ),
        (
            "fault_tol/preemption",
            us_pre,
            f"preemptions={pre.preemptions} "
            f"hi-pri wait {sum(pre_waits):.0f}s vs {sum(off_waits):.0f}s "
            f"without; json={OUT_PATH}",
        ),
    ]
