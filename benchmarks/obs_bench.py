"""Observability overhead benchmark: tracing must be near-free.

The PR 6 tentpole wires a trace recorder through the engine, lifecycle,
provisioning, pool, and scheduler hot paths. This bench is the gate that
the wiring stays opt-in and cheap:

* **tracing off** (the default ``NullRecorder``) — the campaign must hold
  the same machine-scaled events/cpu-s floor as the PR 4 campaign-scale
  smoke (``OFF_EVENTS_FLOOR``, scaled by ``min(1, machine_score /
  REFERENCE_MACHINE_SCORE)``): the instrumented call sites cost one
  attribute check each, within noise of the pre-PR engine;
* **tracing on** (a full ``TraceRecorder`` + ``MetricsHub`` + the PR 7
  active layer: an ``AlertEngine`` with SLO burn-rate accounting riding
  the metronome sample hook) — throughput must stay >=
  ``ON_OFF_RATIO_FLOOR`` (85%) of the tracing-off rate on the same
  machine window. The SLOs here are series-backed on purpose: the bench
  keeps histogram materialization out of the timed window, the same
  configuration a production campaign would run continuously.

Both rates are CPU-time based and best-of-``FLOOR_ATTEMPTS`` paired
attempts (off/on measured back-to-back so a shared container's speed
shifts hit both sides). The traced run is also sanity-checked to have
actually recorded (spans for every job, counter activity) — a gate that
traces nothing proves nothing.

Results land in ``benchmarks/out/obs_bench.json`` and the repo-root
``BENCH_obs.json`` perf-trajectory point.

    PYTHONPATH=src python -m benchmarks.obs_bench
"""

from __future__ import annotations

import argparse
import gc
import json
import os
import time

from repro.core import synthetic_cluster
from repro.obs import (
    AlertEngine,
    AlertRule,
    MetricsHub,
    SLOSpec,
    SLOTracker,
    TraceRecorder,
)
from repro.orchestrator import Orchestrator, summarize

from .campaign_scale_bench import (
    POLICIES,
    REFERENCE_MACHINE_SCORE,
    machine_score,
    serving_specs,
)

# Same shape as the PR 4 perf-smoke rows(): 4k serving jobs, 400/100 nodes.
N_JOBS = 4_000
N_COMPUTE = 400
N_STORAGE = 100
POLICY = "fifo"

#: tracing-off floor — the PR 4 campaign-scale smoke gate, machine-scaled
OFF_EVENTS_FLOOR = 20_000
#: tracing-on throughput >= this fraction of tracing-off (same window)
ON_OFF_RATIO_FLOOR = 0.85
FLOOR_ATTEMPTS = 4
#: virtual-time cadence for metric sampling in the traced run
SAMPLE_EVERY_S = 120.0

OUT_PATH = os.path.join(os.path.dirname(__file__), "out", "obs_bench.json")
BENCH_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_obs.json")


def _alert_engine(hub: MetricsHub) -> AlertEngine:
    """The traced run's active layer: series-backed SLOs plus threshold /
    rate / burn rules, all evaluated at every metronome sample."""
    slos = SLOTracker(
        hub,
        [
            SLOSpec(
                name="queue-depth-p95",
                series="queue_depth",
                percentile=0.95,
                window_s=4 * SAMPLE_EVERY_S,
                op="<=",
                target=N_JOBS,
                objective=0.999,
            ),
            SLOSpec(
                name="completion-progress",
                series="jobs_done",
                op=">=",
                target=0.0,
                objective=0.99,
            ),
        ],
    )
    return AlertEngine(
        hub,
        [
            AlertRule(
                name="queue-depth-high",
                kind="threshold",
                series="queue_depth",
                op=">=",
                target=N_JOBS * 2,       # never trips; the evaluation is the cost
                for_s=2 * SAMPLE_EVERY_S,
            ),
            AlertRule(
                name="queue-growth",
                kind="rate",
                series="queue_depth",
                op=">=",
                target=1e9,
                window_s=4 * SAMPLE_EVERY_S,
            ),
            AlertRule(
                name="queue-slo-burn",
                kind="burn",
                slo="queue-depth-p95",
                op=">=",
                target=100.0,
                window_s=8 * SAMPLE_EVERY_S,
            ),
        ],
        slos=slos,
    )


def _run_once(traced: bool) -> dict:
    specs = serving_specs(N_JOBS)
    recorder = None
    hub = None
    alerts = None
    if traced:
        hub = MetricsHub()
        alerts = _alert_engine(hub)
        recorder = TraceRecorder(
            metrics=hub, sample_every_s=SAMPLE_EVERY_S, alerts=alerts
        )
    orch = Orchestrator(
        synthetic_cluster(N_COMPUTE, N_STORAGE),
        policy=POLICIES[POLICY](),
        incremental=True,
        record_allocations=False,
        recorder=recorder,
    )
    gc_was_enabled = gc.isenabled()
    gc.collect()
    gc.freeze()
    gc.disable()
    try:
        cpu0 = time.process_time()
        jobs = orch.run_campaign(specs)
        cpu_s = time.process_time() - cpu0
    finally:
        if gc_was_enabled:
            gc.enable()
        gc.unfreeze()
        gc.collect()
    report = summarize(jobs, n_storage_nodes=N_STORAGE)
    assert report.n_done == N_JOBS, f"{report.n_failed} of {N_JOBS} jobs failed"
    if traced:
        # the gate must measure a trace that actually happened
        assert len(recorder.spans) == N_JOBS, (
            f"traced run recorded spans for {len(recorder.spans)} of {N_JOBS} jobs"
        )
        assert recorder.counts.get("scheduler.grants", 0) >= N_JOBS
        assert hub.samples_taken > 0, "metrics hub never sampled"
        assert alerts.evaluations > 0, "alert engine never evaluated"
        assert alerts.slos.samples_taken == alerts.evaluations
    events = orch.engine.events_processed
    row = {
        "traced": traced,
        "cpu_s": round(cpu_s, 3),
        "events": events,
        "events_per_cpu_s": round(events / cpu_s),
    }
    if traced:
        row["n_spans"] = recorder.n_spans
        row["n_trace_events"] = len(recorder.events)
        row["metrics_samples"] = hub.samples_taken
        row["alert_evaluations"] = alerts.evaluations
        row["alert_incidents"] = len(alerts.incidents)
    return row


def run_gate(
    *,
    attempts: int = FLOOR_ATTEMPTS,
    off_events_floor: float = OFF_EVENTS_FLOOR,
    ratio_floor: float = ON_OFF_RATIO_FLOOR,
) -> dict:
    """Measure off/on pairs until both floors pass (or attempts run out);
    asserts the floors on the best pair. Returns the JSON payload."""
    pairs = []
    best = None
    for _ in range(max(1, attempts)):
        score0 = machine_score(repeat=1)
        off = _run_once(traced=False)
        on = _run_once(traced=True)
        score1 = machine_score(repeat=1)
        score = max(score0, score1)
        scale = min(1.0, score / REFERENCE_MACHINE_SCORE)
        ratio = on["events_per_cpu_s"] / max(off["events_per_cpu_s"], 1)
        pair = {
            "off": off,
            "on": on,
            "machine_score": round(score),
            "floor_scale": round(scale, 3),
            "on_off_ratio": round(ratio, 4),
        }
        pairs.append(pair)
        if best is None or ratio > best["on_off_ratio"]:
            best = pair
        if (
            off["events_per_cpu_s"] >= off_events_floor * scale
            and ratio >= ratio_floor
        ):
            best = pair
            break
    scaled_floor = off_events_floor * best["floor_scale"]
    assert best["off"]["events_per_cpu_s"] >= scaled_floor, (
        f"tracing-off {best['off']['events_per_cpu_s']} events/cpu-s below "
        f"the PR 4 gate ({off_events_floor} x machine scale "
        f"{best['floor_scale']:.2f} = {scaled_floor:.0f})"
    )
    assert best["on_off_ratio"] >= ratio_floor, (
        f"tracing-on throughput is {best['on_off_ratio']:.1%} of tracing-off, "
        f"below the {ratio_floor:.0%} overhead bound"
    )
    payload = {
        "bench": "obs_overhead",
        "config": {
            "n_jobs": N_JOBS,
            "n_compute": N_COMPUTE,
            "n_storage": N_STORAGE,
            "policy": POLICY,
            "off_events_floor": off_events_floor,
            "on_off_ratio_floor": ratio_floor,
            "reference_machine_score": REFERENCE_MACHINE_SCORE,
        },
        "best": best,
        "attempts": pairs,
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
    }
    write_trajectory(payload)
    return payload


def write_trajectory(payload: dict) -> None:
    os.makedirs(os.path.dirname(OUT_PATH), exist_ok=True)
    for path in (OUT_PATH, BENCH_PATH):
        with open(path, "w") as fh:
            json.dump(payload, fh, indent=2, sort_keys=True)
            fh.write("\n")


def rows():
    """Registered entry point for ``benchmarks.run``."""
    payload = run_gate()
    best = payload["best"]
    return [
        (
            "obs/tracing-off",
            0.0,
            f"ev/cpu-s={best['off']['events_per_cpu_s']} "
            f"floor-scale={best['floor_scale']}",
        ),
        (
            "obs/tracing-on",
            0.0,
            f"ev/cpu-s={best['on']['events_per_cpu_s']} "
            f"ratio={best['on_off_ratio']:.3f} "
            f"spans={best['on']['n_spans']} "
            f"events={best['on']['n_trace_events']} "
            f"alert-evals={best['on']['alert_evaluations']}",
        ),
    ]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--attempts", type=int, default=FLOOR_ATTEMPTS)
    args = ap.parse_args()
    payload = run_gate(attempts=args.attempts)
    print(json.dumps(payload, indent=2, sort_keys=True))


if __name__ == "__main__":
    main()
