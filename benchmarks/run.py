"""Benchmark driver: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (us_per_call = functional in-container
timing at reduced scale; derived = paper-scale modeled metric).
"""

from __future__ import annotations

import sys
import traceback


def main() -> None:
    from . import (
        ault,
        campaign_scale_bench,
        chaos_bench,
        checkpoint_io,
        deployment,
        fault_tolerance_bench,
        haccio,
        ior_fpp,
        ior_shared,
        kernels_bench,
        mdtest,
        obs_bench,
        orchestrator_bench,
        pilot_bench,
        pool_bench,
        provision_bench,
        roofline,
        scalability,
        serving_bench,
    )

    modules = [
        ("ior_shared", ior_shared),        # Fig. 2
        ("ior_fpp", ior_fpp),              # Fig. 3
        ("scalability", scalability),      # Fig. 4
        ("mdtest", mdtest),                # Tables I, II
        ("haccio", haccio),                # Fig. 6
        ("ault", ault),                    # Fig. 7
        ("deployment", deployment),        # §IV-A1/B1
        ("checkpoint_io", checkpoint_io),  # beyond-paper (§III-B use-case)
        ("orchestrator", orchestrator_bench),  # beyond-paper campaign pipeline
        ("pool", pool_bench),              # beyond-paper persistent pools
        ("provision", provision_bench),    # StorageSession API negotiation
        ("campaign_scale", campaign_scale_bench),  # 50k-job engine scaling
        ("fault_tolerance", fault_tolerance_bench),  # checkpoint resume + preemption
        ("chaos", chaos_bench),            # node failure domain + self-healing
        ("pilot", pilot_bench),            # two-level many-task scheduling
        ("obs", obs_bench),                # tracing overhead gate
        ("serving", serving_bench),        # pool-backed serving + autoscaler
        ("kernels", kernels_bench),
        ("roofline", roofline),            # §Roofline (reads dry-run artifacts)
    ]
    print("name,us_per_call,derived")
    failed = []
    for name, mod in modules:
        try:
            for row_name, us, derived in mod.rows():
                print(f"{row_name},{us:.1f},{derived}")
        except Exception as e:  # noqa: BLE001
            traceback.print_exc()
            failed.append((name, repr(e)))
    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
