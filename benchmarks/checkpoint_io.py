"""Beyond-paper: checkpoint burst-buffer economics.

Derived metric: modeled checkpoint stall (write train state to the
provisioned EphemeralFS, file-per-shard) vs writing straight to Lustre, for
paper-hardware deployments and a range of model-state sizes. This is the
§III-B use-case the paper motivates but never measures.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.core import Workload, dom_efs, dom_lustre, predict_write

from .common import mk_efs, time_us

GB = 1e9
# (name, state_bytes): 1B dense fp32+opt ~ 16 GB; 30B bf16+sharded-opt ~ 480GB
STATES = (("1b-fp32-opt", 16 * GB), ("7b", 112 * GB), ("30b", 480 * GB))
SHARDS = 256  # one file per host-shard (C3: FPP is the fast path)


def rows():
    # functional: real sharded save/restore through EphemeralFS
    efs = mk_efs(2)
    mgr = CheckpointManager(efs)
    tree = {"p": {f"l{i}": jnp.ones((64, 64)) for i in range(8)}}

    step_holder = [0]

    def save():
        step_holder[0] += 1
        mgr.save(step_holder[0], tree)

    us = time_us(save, repeat=2)
    restored, _ = mgr.restore(tree)
    assert jax.tree.all(jax.tree.map(lambda a, b: bool((a == b).all()), restored, tree))
    efs.teardown()

    out = []
    for name, nbytes in STATES:
        w = Workload(n_procs=SHARDS, size_per_proc=nbytes / SHARDS, pattern="fpp")
        for fs_name, dep, nodes in (
            ("burst2dw", dom_efs(2), 2),
            ("burst4dw", dom_efs(4), 4),
            ("lustre", dom_lustre(), 2),
        ):
            t = predict_write(w, dep).elapsed_s
            out.append((f"ckpt_stall/{fs_name}/{name}", us, f"{t:.1f}s"))
    return out
