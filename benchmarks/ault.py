"""Fig. 7 + §IV-B: portability — on-demand BeeGFS over 8 local NVMe disks on
Ault (1 mgmt, 2 metadata, 5 storage; client co-located). Peaks: 13.70 GB/s
write / 20.36 GB/s read file-per-process (C9).
"""

from __future__ import annotations

import tempfile

from repro.core import EphemeralFS, Workload, ault_cluster, ault_efs, predict_read, predict_write

from .common import MiB, functional_io_us

SIZES_MB = (4, 32, 128, 512)


def rows():
    node = ault_cluster().storage_nodes[0]
    fs = EphemeralFS((node,), tempfile.mkdtemp(prefix="bench-ault-"),
                     md_disks_per_node=2, storage_disks_per_node=5)
    us = functional_io_us(fs, n_procs=4)
    assert len(fs.storage_services) == 5 and len(fs.md_services) == 2
    fs.teardown()
    d = ault_efs()
    out = []
    for sp in SIZES_MB:
        for pattern in ("shared", "fpp"):
            w = Workload(n_procs=22, size_per_proc=sp * MiB, pattern=pattern)
            out.append((f"ault/write/{pattern}/{sp}MB", us,
                        f"{predict_write(w, d).bandwidth/1e9:.2f}GBps"))
            out.append((f"ault/read/{pattern}/{sp}MB", us,
                        f"{predict_read(w, d).bandwidth/1e9:.2f}GBps"))
    return out
