"""Campaign-scale benchmark: 50k-job campaigns on a 2,000-node cluster.

The PR 4 tentpole — indexed allocation ledgers in the `Scheduler`,
incremental (bucketed) dispatch in the `Orchestrator`, and negotiation
caching in the `ProvisioningService` — exists so that the arbitration
machinery stays cheap at scale. This bench is the proof: it sweeps
(jobs x cluster shape x policy) campaigns through the orchestrator and
holds two floors on the full-size configuration:

* **throughput** — the engine must sustain ``EVENTS_PER_CPU_S_FLOOR``
  events per CPU-second (CPU time, not wallclock, so a noisy CI neighbor
  cannot flake the gate; both rates are reported). Rates are best-of-
  ``repeat`` (the repo's min-timing convention), and the floor is scaled
  by ``min(1, machine_score / REFERENCE_MACHINE_SCORE)``, where
  ``machine_score`` is the throughput of a *miniature reference campaign*
  sampled around every measured run — campaigns are memory-bound, so a
  synthetic spin loop would not track container memory/cache throttling —
  and the reference constant is a nominal full-speed machine. A throttled
  container therefore lowers the gate proportionally; on full-speed
  hardware the floor is the absolute 50k events/s (there, the speedup
  floor and the CI CPU budget are the regression backstops);
* **speedup** — >= ``SPEEDUP_FLOOR`` over the pre-PR engine. The legacy
  sort-everything dispatcher (``Orchestrator(..., incremental=False)``,
  kept precisely as the reference implementation) is quadratic in campaign
  size, so running it at 50k jobs would take tens of minutes; the
  comparison harness measures it at two smaller sizes on the same cluster,
  fits the power law ``t = a * n^b``, and extrapolates to the full size
  (the direct same-size ratio at the largest measured legacy size is also
  reported and asserted > 1).

Results are written as a JSON trajectory point to
``benchmarks/out/campaign_scale.json`` and to the repo-root
``BENCH_campaign.json`` (the perf-trajectory file).

Run the full 50k x 2,000-node sweep:

    PYTHONPATH=src python -m benchmarks.campaign_scale_bench

CI perf-smoke (reduced size, CPU budget asserted):

    PYTHONPATH=src python -m benchmarks.campaign_scale_bench \
        --jobs 2000 --compute 400 --storage 100 --budget-cpu-s 60
"""

from __future__ import annotations

import argparse
import gc
import json
import math
import os
import time

from repro.core import synthetic_cluster
from repro.orchestrator import (
    BackfillPolicy,
    FIFOPolicy,
    Orchestrator,
    StorageAwarePolicy,
    summarize,
)
from repro.orchestrator.lifecycle import WorkflowSpec
from repro.provision import StorageSpec

GB = 1e9
TB = 1e12

# Full-size configuration: 50,000 jobs on a 2,000-node cluster.
N_JOBS = 50_000
N_COMPUTE = 1_600
N_STORAGE = 400

EVENTS_PER_CPU_S_FLOOR = 50_000     # full-size config only
SPEEDUP_FLOOR = 10.0                # vs extrapolated pre-PR engine
# Power-law fit points for the old engine, measured under the *backfill*
# policy — the representative case for the old dispatcher's quadratic cost
# (a full-queue probe per admission; 85 CPU-s at just 4k jobs). FIFO is
# legacy's best case (head-of-line blocking caps each scan at one probe)
# and is still slower than the indexed engine at equal size.
COMPARISON_POLICY = "backfill"
LEGACY_SIZES = (1_000, 2_000)

# Reference-campaign events/cpu-s of a nominal full-speed machine; the
# floor scales down with min(1, measured/REFERENCE) on throttled containers
# (shared VMs measure at ~50-75% of this, bare metal at or above it).
REFERENCE_MACHINE_SCORE = 75_000
#: attempts per measured config — the gate passes on the first attempt that
#: crosses its floor (shared containers shift speed between 6-second runs)
FLOOR_ATTEMPTS = 4

OUT_PATH = os.path.join(os.path.dirname(__file__), "out", "campaign_scale.json")
BENCH_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_campaign.json")

POLICIES = {
    "fifo": FIFOPolicy,
    "backfill": BackfillPolicy,
    "storage-aware": StorageAwarePolicy,
}


def serving_specs(n_jobs: int) -> list[WorkflowSpec]:
    """The serving-scale shape the ROADMAP points at: many small jobs from
    a handful of spec shapes (exactly what negotiation caching and
    admission bucketing exploit — and what a many-users workload looks
    like: thousands of requests, few request *kinds*)."""
    specs = []
    for i in range(n_jobs):
        name = f"job{i:05d}"
        kind = i % 6
        if kind < 3:
            storage = StorageSpec(
                name,
                nodes=1 + (kind & 1),
                managers=("ephemeralfs",),
                stage_in_bytes=8 * GB,
                stage_out_bytes=2 * GB,
            )
        elif kind < 5:
            storage = StorageSpec(
                name,
                capacity_bytes=(8 + 8 * (kind - 3)) * TB,
                managers=("ephemeralfs",),
                stage_in_bytes=8 * GB,
            )
        else:
            storage = StorageSpec(
                name, bandwidth=10 * GB, managers=("ephemeralfs",),
                stage_in_bytes=4 * GB,
            )
        specs.append(
            WorkflowSpec(
                name,
                n_compute=1 + (i % 2),
                storage_spec=storage,
                run_time_s=20.0 + 10.0 * (i % 5),
            )
        )
    return specs


def machine_score(repeat: int = 3) -> float:
    """Events/cpu-s of a miniature (2k-job) reference campaign, best of
    ``repeat`` — the machine-speed reference the throughput floor is
    normalized by. It exercises the exact measured code path, so it tracks
    memory/cache throttling that a synthetic spin loop would miss."""
    return max(
        _run_once(2_000, 400, 100, "fifo", True)["events_per_cpu_s"]
        for _ in range(max(1, repeat))
    )


def _run_once(
    n_jobs: int, n_compute: int, n_storage: int, policy_name: str, incremental: bool
) -> dict:
    specs = serving_specs(n_jobs)
    orch = Orchestrator(
        synthetic_cluster(n_compute, n_storage),
        policy=POLICIES[policy_name](),
        incremental=incremental,
        record_allocations=False,      # measured campaigns: keep memory lean
    )
    gc_was_enabled = gc.isenabled()
    gc.collect()
    gc.freeze()
    gc.disable()
    try:
        wall0 = time.perf_counter()
        cpu0 = time.process_time()
        jobs = orch.run_campaign(specs)
        cpu_s = time.process_time() - cpu0
        wall_s = time.perf_counter() - wall0
    finally:
        if gc_was_enabled:
            gc.enable()
        gc.unfreeze()
        gc.collect()
    report = summarize(jobs, n_storage_nodes=n_storage)
    assert report.n_done == n_jobs, (
        f"{policy_name}: {report.n_failed} of {n_jobs} jobs failed"
    )
    events = orch.engine.events_processed
    stats = orch.provision.stats
    return {
        "policy": policy_name,
        "engine": "indexed" if incremental else "legacy",
        "n_jobs": n_jobs,
        "n_compute": n_compute,
        "n_storage": n_storage,
        "wall_s": round(wall_s, 3),
        "cpu_s": round(cpu_s, 3),
        "events": events,
        "events_per_wall_s": round(events / wall_s),
        "events_per_cpu_s": round(events / cpu_s),
        "virtual_makespan_s": round(report.makespan_s, 1),
        "storage_node_utilization": round(report.storage_node_utilization, 4),
        "negotiations": stats.negotiations,
        "negotiations_cached": stats.negotiations_cached,
        "negotiation_wall_s": round(stats.negotiation_wall_s, 4),
    }


def run_config(
    n_jobs: int,
    n_compute: int,
    n_storage: int,
    policy_name: str,
    *,
    incremental: bool = True,
    repeat: int = 1,
    events_floor: float | None = None,
) -> dict:
    """One measured campaign (best of up to ``repeat`` identical runs —
    the repo's min-timing convention); returns the JSON-ready result row.

    With ``events_floor`` set, a reference-campaign machine score is
    sampled before and after every run (each row carries the max of its
    window — shared containers shift speed between runs, so the floor must
    be normalized by the machine's speed *while that row was measured*),
    and attempts stop early at the first row crossing its scaled floor."""
    with_score = events_floor is not None
    rows = []
    score_prev = machine_score(repeat=1) if with_score else None
    for _ in range(max(1, repeat)):
        row = _run_once(n_jobs, n_compute, n_storage, policy_name, incremental)
        if with_score:
            score_next = machine_score(repeat=1)
            row["machine_score"] = round(max(score_prev, score_next))
            row["floor_scale"] = round(
                min(1.0, row["machine_score"] / REFERENCE_MACHINE_SCORE), 3
            )
            score_prev = score_next
        rows.append(row)
        if (
            with_score
            and row["events_per_cpu_s"] >= events_floor * row["floor_scale"]
        ):
            break
    if with_score:
        best = max(
            rows, key=lambda r: r["events_per_cpu_s"] / max(r["floor_scale"], 1e-9)
        )
    else:
        best = min(rows, key=lambda r: r["cpu_s"])
    best["repeats"] = len(rows)
    return best


def legacy_comparison(
    n_jobs_full: int,
    n_compute: int,
    n_storage: int,
    policy_name: str,
    full_row: dict,
    legacy_sizes: tuple = LEGACY_SIZES,
) -> dict:
    """Measure the pre-PR engine at ``legacy_sizes``, fit ``t = a * n^b``,
    extrapolate its cost at the full size, and compare."""
    rows = [
        run_config(n, n_compute, n_storage, policy_name, incremental=False)
        for n in legacy_sizes
    ]
    (n1, t1), (n2, t2) = [(r["n_jobs"], max(r["cpu_s"], 1e-6)) for r in rows]
    b = math.log(t2 / t1) / math.log(n2 / n1) if n2 != n1 else 1.0
    legacy_full_cpu_s = t2 * (n_jobs_full / n2) ** b
    new_same_size = run_config(n2, n_compute, n_storage, policy_name)
    return {
        "policy": policy_name,
        "legacy_points": rows,
        "fitted_exponent": round(b, 3),
        "legacy_cpu_s_extrapolated_full": round(legacy_full_cpu_s, 1),
        "indexed_cpu_s_full": full_row["cpu_s"],
        "speedup_extrapolated": round(legacy_full_cpu_s / full_row["cpu_s"], 1),
        "same_size_n_jobs": n2,
        "same_size_ratio": round(t2 / max(new_same_size["cpu_s"], 1e-6), 2),
    }


def write_trajectory(payload: dict) -> None:
    """Every run refreshes the (gitignored) benchmarks/out/ copy; only a
    full-size sweep may overwrite the *committed* repo-root trajectory
    point — otherwise a CI smoke or reduced rows() run would silently
    replace the 50k-job record with a 2k-job payload."""
    os.makedirs(os.path.dirname(OUT_PATH), exist_ok=True)
    cfg = payload["config"]
    full_size = (
        cfg["n_jobs"] >= N_JOBS
        and cfg["n_compute"] >= N_COMPUTE
        and cfg["n_storage"] >= N_STORAGE
    )
    paths = (OUT_PATH, BENCH_PATH) if full_size else (OUT_PATH,)
    for path in paths:
        with open(path, "w") as fh:
            json.dump(payload, fh, indent=2, sort_keys=True)
            fh.write("\n")


def run_sweep(
    n_jobs: int,
    n_compute: int,
    n_storage: int,
    *,
    policies: tuple = tuple(POLICIES),
    legacy_sizes: tuple = LEGACY_SIZES,
    events_floor: float | None = None,
    speedup_floor: float | None = None,
    budget_cpu_s: float | None = None,
) -> dict:
    with_floors = events_floor is not None
    results = [
        run_config(
            n_jobs, n_compute, n_storage, p,
            repeat=FLOOR_ATTEMPTS if with_floors else 1,
            events_floor=events_floor,
        )
        for p in policies
    ]
    comparison = None
    if legacy_sizes:
        sizes = tuple(min(s, n_jobs) for s in legacy_sizes)
        cmp_policy = (
            COMPARISON_POLICY if COMPARISON_POLICY in policies else policies[0]
        )
        full_row = results[list(policies).index(cmp_policy)]
        comparison = legacy_comparison(
            n_jobs, n_compute, n_storage, cmp_policy, full_row, sizes
        )
        assert comparison["same_size_ratio"] > 1.0, (
            "indexed engine is not faster than the legacy engine at "
            f"{comparison['same_size_n_jobs']} jobs: {comparison}"
        )
        if speedup_floor is not None:
            assert comparison["speedup_extrapolated"] >= speedup_floor, (
                f"speedup {comparison['speedup_extrapolated']}x below the "
                f"{speedup_floor}x floor over the pre-PR engine"
            )
    for row in results:
        if events_floor is not None:
            scaled_floor = events_floor * row["floor_scale"]
            assert row["events_per_cpu_s"] >= scaled_floor, (
                f"{row['policy']}: {row['events_per_cpu_s']} events/cpu-s "
                f"below the floor ({events_floor} x machine scale "
                f"{row['floor_scale']:.2f} = {scaled_floor:.0f})"
            )
        if budget_cpu_s is not None:
            assert row["cpu_s"] <= budget_cpu_s, (
                f"{row['policy']}: campaign took {row['cpu_s']} CPU-s, "
                f"budget {budget_cpu_s}"
            )
    payload = {
        "bench": "campaign_scale",
        "config": {
            "n_jobs": n_jobs,
            "n_compute": n_compute,
            "n_storage": n_storage,
            "events_per_cpu_s_floor": events_floor,
            "reference_machine_score": REFERENCE_MACHINE_SCORE,
            "speedup_floor": speedup_floor,
        },
        "results": results,
        "legacy_comparison": comparison,
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
    }
    write_trajectory(payload)
    return payload


def rows():
    """Registered entry point for ``benchmarks.run`` — a reduced-size sweep
    (the full 50k config is the module's __main__)."""
    payload = run_sweep(
        4_000,
        400,
        100,
        legacy_sizes=(300, 600),
        events_floor=20_000,
    )
    out = []
    for r in payload["results"]:
        out.append(
            (
                f"campaign_scale/{r['policy']}-{r['n_jobs']}jobs",
                r["wall_s"] * 1e6,
                f"ev/cpu-s={r['events_per_cpu_s']} "
                f"makespan={r['virtual_makespan_s']:.0f}s "
                f"negot-cached={r['negotiations_cached']}/{r['negotiations']}",
            )
        )
    cmp_row = payload["legacy_comparison"]
    out.append(
        (
            "campaign_scale/speedup-vs-legacy",
            0.0,
            f"extrapolated={cmp_row['speedup_extrapolated']}x "
            f"same-size@{cmp_row['same_size_n_jobs']}={cmp_row['same_size_ratio']}x "
            f"exponent={cmp_row['fitted_exponent']}",
        )
    )
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--jobs", type=int, default=N_JOBS)
    ap.add_argument("--compute", type=int, default=N_COMPUTE)
    ap.add_argument("--storage", type=int, default=N_STORAGE)
    ap.add_argument(
        "--legacy-jobs", type=int, nargs="*", default=list(LEGACY_SIZES),
        help="sizes to measure the pre-PR engine at (empty disables)",
    )
    ap.add_argument(
        "--budget-cpu-s", type=float, default=None,
        help="assert each campaign stays under this CPU-second budget",
    )
    ap.add_argument(
        "--no-floors", action="store_true",
        help="skip the events/sec and speedup floor assertions",
    )
    args = ap.parse_args()
    full_size = args.jobs >= N_JOBS and not args.no_floors
    payload = run_sweep(
        args.jobs,
        args.compute,
        args.storage,
        legacy_sizes=tuple(args.legacy_jobs),
        events_floor=EVENTS_PER_CPU_S_FLOOR if full_size else None,
        speedup_floor=(
            SPEEDUP_FLOOR if full_size and args.legacy_jobs else None
        ),
        budget_cpu_s=args.budget_cpu_s,
    )
    print(json.dumps(payload, indent=2, sort_keys=True))


if __name__ == "__main__":
    main()
