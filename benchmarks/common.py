"""Shared benchmark plumbing.

Every benchmark module exposes ``rows() -> list[(name, us_per_call, derived)]``
where ``us_per_call`` is a measured in-container wall time for the functional
path (real bytes through EphemeralFS/GlobalFS at reduced scale) and
``derived`` is the paper-scale modeled metric (GB/s, ops/s, seconds).
"""

from __future__ import annotations

import tempfile
import time
from typing import Callable

from repro.core import EphemeralFS, GlobalFS, dom_cluster

MiB = 1 << 20


def time_us(fn: Callable, *, repeat: int = 3) -> float:
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def mk_efs(n_nodes: int = 2, **kw) -> EphemeralFS:
    nodes = dom_cluster().storage_nodes[:n_nodes]
    return EphemeralFS(nodes, tempfile.mkdtemp(prefix="bench-efs-"), **kw)


def mk_lustre(**kw) -> GlobalFS:
    return GlobalFS(tempfile.mkdtemp(prefix="bench-lfs-"), **kw)


def functional_io_us(fs, n_procs: int = 4, size: int = 256 * 1024) -> float:
    """Timed miniature of the paper's IOR run: n_procs ranks write then read
    a shared file through the real chunk/metadata path."""
    fs.create("/bench-shared")

    def run():
        for rank in range(n_procs):
            fs.write("/bench-shared", rank * size, b"x" * size)
        for rank in range(n_procs):
            fs.read("/bench-shared", rank * size, size)

    return time_us(run, repeat=2)
