"""Kernel microbenchmarks: Pallas (interpret mode on CPU — correctness
harness, not TPU timing) vs the jnp reference, plus algorithmic intensity
derived for the TPU target.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref

from .common import time_us


def rows():
    out = []
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (1, 512, 8, 64), jnp.float32)
    k = jax.random.normal(ks[1], (1, 512, 2, 64), jnp.float32)
    v = jax.random.normal(ks[2], (1, 512, 2, 64), jnp.float32)

    f_ref = jax.jit(lambda: ref.flash_attention_ref(q, k, v))
    f_ker = jax.jit(lambda: ops.flash_attention(q, k, v))
    f_ref()  # compile
    f_ker()
    flops = 4 * 512 * 512 * 8 * 64  # qk + pv
    ai = flops / (3 * q.size * 4 + q.size * 4)
    out.append(("kernel/flash_attn/ref-jnp",
                time_us(lambda: jax.block_until_ready(f_ref())),
                f"AI={ai:.0f}flops/B"))
    out.append(("kernel/flash_attn/pallas-interpret",
                time_us(lambda: jax.block_until_ready(f_ker())),
                f"AI={ai:.0f}flops/B"))

    qd = jax.random.normal(ks[0], (4, 1, 8, 64), jnp.float32)
    d_ref = jax.jit(lambda: ref.decode_attention_ref(qd, k.repeat(4, 0), v.repeat(4, 0), kv_len=500))
    d_ker = jax.jit(lambda: ops.decode_attention(qd, k.repeat(4, 0), v.repeat(4, 0), kv_len=500))
    d_ref(); d_ker()
    out.append(("kernel/decode_attn/ref-jnp",
                time_us(lambda: jax.block_until_ready(d_ref())), "membound"))
    out.append(("kernel/decode_attn/pallas-interpret",
                time_us(lambda: jax.block_until_ready(d_ker())), "membound"))

    la = -jnp.abs(jax.random.normal(ks[0], (1, 4, 128, 8))) * 0.1
    C = jax.random.normal(ks[1], (1, 4, 128, 64))
    Bm = jax.random.normal(ks[2], (1, 4, 128, 64))
    x = jax.random.normal(ks[0], (1, 4, 128, 8, 64))
    s_ref = jax.jit(lambda: ref.ssd_intra_chunk_ref(la, C, Bm, x))
    s_ker = jax.jit(lambda: ops.ssd_intra_chunk(la, C, Bm, x))
    s_ref(); s_ker()
    out.append(("kernel/ssd_chunk/ref-jnp",
                time_us(lambda: jax.block_until_ready(s_ref())), "mxu"))
    out.append(("kernel/ssd_chunk/pallas-interpret",
                time_us(lambda: jax.block_until_ready(s_ker())), "mxu"))

    xx = jax.random.normal(ks[0], (256, 2048), jnp.float32)
    sc = jnp.ones((2048,))
    r_ref = jax.jit(lambda: ref.rmsnorm_ref(xx, sc))
    r_ker = jax.jit(lambda: ops.rmsnorm(xx, sc))
    r_ref(); r_ker()
    out.append(("kernel/rmsnorm/ref-jnp",
                time_us(lambda: jax.block_until_ready(r_ref())), "membound"))
    out.append(("kernel/rmsnorm/pallas-interpret",
                time_us(lambda: jax.block_until_ready(r_ker())), "membound"))
    return out
