"""§IV-A1 / §IV-B1: deployment time of the containerized on-demand FS.

5.37 s over 2 DataWarp nodes (Shifter); 4.6 s fresh / 1.2 s warm over the
8 Ault disks (local docker) — C8. The functional wallclock measured is a
full materialized `StorageSession` open/release cycle (negotiate, allocate,
deploy, tear down) through the unified storage API.
"""

from __future__ import annotations

import tempfile

from repro.core import dom_cluster, predict_deploy_time
from repro.provision import ProvisioningService, StorageSpec

from .common import time_us


def rows():
    svc = ProvisioningService(dom_cluster())
    spec = StorageSpec("bench", nodes=2, managers=("ephemeralfs",))
    base = tempfile.mkdtemp(prefix="bench-deploy-")

    def deploy_cycle():
        # release tears the tree down, so every cycle pays the fresh path
        svc.open_session(spec, materialize=True, base_dir=base).release()

    us = time_us(deploy_cycle, repeat=2)
    return [
        ("deploy/dom-2dw-shifter", us,
         f"{predict_deploy_time(3, runtime='shifter'):.2f}s"),
        ("deploy/ault-8disk-fresh", us,
         f"{predict_deploy_time(8, runtime='docker'):.2f}s"),
        ("deploy/ault-8disk-warm", us,
         f"{predict_deploy_time(8, runtime='docker', fresh=False):.2f}s"),
    ]
