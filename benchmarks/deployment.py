"""§IV-A1 / §IV-B1: deployment time of the containerized on-demand FS.

5.37 s over 2 DataWarp nodes (Shifter); 4.6 s fresh / 1.2 s warm over the
8 Ault disks (local docker) — C8. Functional deploy wallclock measured too.
"""

from __future__ import annotations

import tempfile

from repro.core import (
    JobRequest,
    Provisioner,
    Scheduler,
    StorageRequest,
    dom_cluster,
    predict_deploy_time,
)

from .common import time_us


def rows():
    cluster = dom_cluster()
    sched = Scheduler(cluster)
    alloc = sched.submit(JobRequest("bench", 1, storage=StorageRequest(nodes=2)))
    prov = Provisioner(cluster)
    plan = prov.plan_for(alloc)
    base = tempfile.mkdtemp(prefix="bench-deploy-")

    deps = []

    def deploy():
        deps.append(prov.deploy(plan, base))

    us = time_us(deploy, repeat=2)
    for d in deps:
        d.teardown()
    sched.release(alloc)
    return [
        ("deploy/dom-2dw-shifter", us,
         f"{predict_deploy_time(3, runtime='shifter'):.2f}s"),
        ("deploy/ault-8disk-fresh", us,
         f"{predict_deploy_time(8, runtime='docker'):.2f}s"),
        ("deploy/ault-8disk-warm", us,
         f"{predict_deploy_time(8, runtime='docker', fresh=False):.2f}s"),
    ]
