"""Fig. 4: bandwidth scaling of the on-demand BeeGFS from 1 to 4 DataWarp
nodes (metadata:storage disk ratio fixed at 1:2). Shared-file write scales
logarithmically (~3x from 1->2, +30% from 2->4 — C5); FPP scales linearly.
"""

from __future__ import annotations

from repro.core import Workload, dom_efs, predict_read, predict_write

from .common import MiB, functional_io_us, mk_efs


def rows():
    out = []
    for n in (1, 2, 4):
        efs = mk_efs(n)
        us = functional_io_us(efs)
        efs.teardown()
        d = dom_efs(n)
        for pattern in ("shared", "fpp"):
            w = Workload(n_procs=288, size_per_proc=256 * MiB, pattern=pattern)
            out.append((f"scalability/write/{pattern}/{n}nodes", us,
                        f"{predict_write(w, d).peak_bandwidth/1e9:.2f}GBps"))
            out.append((f"scalability/read/{pattern}/{n}nodes", us,
                        f"{predict_read(w, d).peak_bandwidth/1e9:.2f}GBps"))
    return out
