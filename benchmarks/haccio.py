"""Fig. 6: HACC-IO (38-byte AoS particles, single shared file, 288 procs).

BeeGFS peaks 5.3 GB/s write / 9.1 GB/s read up to 42 GB files; Lustre
collapses below 1 / 0.4 GB/s on the unaligned record stream (C7).
"""

from __future__ import annotations

from repro.core import dom_efs, dom_lustre, hacc_workload, predict_read, predict_write
from repro.core.perfmodel import HACC_PARTICLE_BYTES

from .common import mk_efs, time_us

PARTICLES = (100_000, 500_000, 1_000_000, 2_000_000, 4_000_000)


def _functional_aos_us(fs, particles: int = 2000, n_procs: int = 4) -> float:
    """Real AoS writes: per-proc contiguous particle blocks, 38 B records."""
    fs.create("/hacc")
    rec = b"p" * HACC_PARTICLE_BYTES

    def run():
        for rank in range(n_procs):
            fs.write("/hacc", rank * particles * HACC_PARTICLE_BYTES,
                     rec * particles)
        for rank in range(n_procs):
            fs.read("/hacc", rank * particles * HACC_PARTICLE_BYTES,
                    particles * HACC_PARTICLE_BYTES)

    return time_us(run, repeat=2)


def rows():
    out = []
    efs = mk_efs(2)
    us = _functional_aos_us(efs)
    efs.teardown()
    d_efs, d_lus = dom_efs(2), dom_lustre()
    for np_ in PARTICLES:
        w = hacc_workload(288, np_)
        gb = w.total_bytes / 1e9
        for fs_name, d in (("beegfs2dw", d_efs), ("lustre", d_lus)):
            out.append((f"haccio/write/{fs_name}/{gb:.0f}GB", us,
                        f"{predict_write(w, d).bandwidth/1e9:.2f}GBps"))
            out.append((f"haccio/read/{fs_name}/{gb:.0f}GB", us,
                        f"{predict_read(w, d).bandwidth/1e9:.2f}GBps"))
    return out
