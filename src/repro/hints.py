"""Mesh hints: lets model code place sharding constraints on activations
without depending on the runtime layer (no-op when no mesh hint is set).

Why: under GSPMD, projections whose flattened output dim is model-sharded
(e.g. wk: (d, K*hd) with K*hd % tp == 0 but K % tp != 0) propagate a sharding
that SPLITS THE HEAD DIMENSION after the (B,S,K,hd) reshape — every
subsequent attention contraction then needs a per-block all-reduce (observed:
100 MB x 4096 all-reduces in one train step). Constraining q/k/v to a
head-aligned layout (heads sharded when divisible, replicated otherwise)
keeps attention local at the cost of one well-placed resharding collective.
"""

from __future__ import annotations

import contextvars
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_MESH: contextvars.ContextVar[Optional[Mesh]] = contextvars.ContextVar(
    "repro_mesh_hint", default=None
)
_FLAGS: contextvars.ContextVar[frozenset] = contextvars.ContextVar(
    "repro_flags", default=frozenset()
)


def set_mesh_hint(mesh: Optional[Mesh]):
    return _MESH.set(mesh)


def get_mesh_hint() -> Optional[Mesh]:
    return _MESH.get()


def flag(name: str) -> bool:
    """Trace-time feature flags (perf-variant switches, see dryrun --variant)."""
    return name in _FLAGS.get()


class mesh_hint:
    def __init__(self, mesh: Optional[Mesh], flags: tuple = ()):
        self.mesh = mesh
        self.flags = frozenset(flags)

    def __enter__(self):
        self._tok = _MESH.set(self.mesh)
        self._ftok = _FLAGS.set(self.flags)
        return self.mesh

    def __exit__(self, *exc):
        _MESH.reset(self._tok)
        _FLAGS.reset(self._ftok)
        return False


def _axis_size(mesh: Mesh, axes) -> int:
    if isinstance(axes, str):
        axes = (axes,)
    size = 1
    for a in axes:
        size *= mesh.shape.get(a, 1)
    return size


def constrain(x, *logical):
    """Apply a sharding constraint. ``logical`` entries: None, "dp", "model".
    Axes that don't exist in the mesh or don't divide the dim are dropped.
    No-op without a mesh hint."""
    mesh = _MESH.get()
    if mesh is None:
        return x
    spec = []
    for dim, ax in zip(x.shape, logical):
        if ax is None:
            spec.append(None)
            continue
        if ax == "dp":
            cand = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
        else:
            cand = (ax,) if ax in mesh.axis_names else ()
        if not cand:
            spec.append(None)
            continue
        # largest dividing prefix
        chosen = None
        for end in range(len(cand), 0, -1):
            sub = cand[:end]
            if dim % _axis_size(mesh, sub) == 0:
                chosen = sub if len(sub) > 1 else sub[0]
                break
        spec.append(chosen)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))
