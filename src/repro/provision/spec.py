"""StorageSpec: one declarative request for provisioned storage.

The paper's workflow vision (§VII) is that a job "should be able to select
both its preferred data manager and its required storage capability or
capacity". PR 1-2 left three hand-wired paths to that end (Scheduler.submit +
Provisioner.deploy, PoolManager leases, orchestrator internals); this module
is the single request type they all collapse behind:

* **sizing** — exactly one of ``nodes`` / ``capacity_bytes`` / ``bandwidth``
  (the paper's §V quantity-vs-speed trade-off, now with bandwidth as a
  first-class axis);
* **preferred data managers** — ordered backend names with fallbacks
  (``managers=("kvstore", "ephemeralfs")``), or empty for "any registered";
* **lifetime class** — `EPHEMERAL` (job-scoped deploy + teardown), `POOLED`
  (lease on a live persistent pool), `PERSISTENT` (create a pool);
* **datasets** — shared inputs to stage (`DatasetRef`), plus private
  stage-in/out traffic;
* **placement** — striping / mirroring hints;
* **QoS** — minimum aggregate bandwidth and maximum provisioning latency,
  validated against the perfmodel during negotiation.

A spec never names cluster nodes or pool ids: the `ProvisioningService`
negotiates those (see ``negotiation``), so the same spec is portable across
backends and clusters.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Literal, Optional

from ..core.scheduler import StorageRequest
from ..core.striping import DEFAULT_STRIPE
from ..pool.catalog import DatasetRef, total_bytes


class LifetimeClass(enum.Enum):
    EPHEMERAL = "ephemeral"      # job-scoped: deploy, use, tear down
    POOLED = "pooled"            # lease capacity on a live persistent pool
    PERSISTENT = "persistent"    # create a pool that outlives the session


@dataclasses.dataclass(frozen=True)
class QoS:
    """Service-level floor/ceiling the negotiated backend must honor."""

    min_bandwidth: Optional[float] = None        # aggregate write B/s floor
    max_provision_s: Optional[float] = None      # modeled attach/deploy ceiling

    def __post_init__(self) -> None:
        if self.min_bandwidth is not None and self.min_bandwidth <= 0:
            raise ValueError("min_bandwidth must be positive")
        if self.max_provision_s is not None and self.max_provision_s < 0:
            raise ValueError("max_provision_s must be >= 0")


@dataclasses.dataclass(frozen=True)
class Placement:
    """Striping / redundancy hints, honored when the backend supports them."""

    stripe_size: int = DEFAULT_STRIPE
    mirror: bool = False

    def __post_init__(self) -> None:
        if self.stripe_size <= 0:
            raise ValueError("stripe_size must be positive")


Access = Literal["posix", "kv"]


@dataclasses.dataclass(frozen=True)
class StorageSpec:
    """A declarative storage request; negotiated, never hand-placed."""

    name: str
    nodes: Optional[int] = None
    capacity_bytes: Optional[float] = None
    bandwidth: Optional[float] = None            # aggregate write B/s sizing
    managers: tuple[str, ...] = ()               # ordered preference; () = any
    lifetime: LifetimeClass = LifetimeClass.EPHEMERAL
    access: Access = "posix"
    datasets: tuple[DatasetRef, ...] = ()        # shared inputs to stage
    stage_in_bytes: float = 0.0                  # private stage-in traffic
    stage_out_bytes: float = 0.0                 # private stage-out traffic
    n_streams: int = 8
    placement: Placement = Placement()
    qos: QoS = QoS()
    runtime: Literal["shifter", "docker"] = "shifter"
    capacity_cap_bytes: Optional[float] = None   # PERSISTENT: ledger quota

    def __post_init__(self) -> None:
        if not self.name:
            raise ValueError("spec name must be non-empty")
        object.__setattr__(self, "managers", tuple(self.managers))
        object.__setattr__(self, "datasets", tuple(self.datasets))
        n_sizing = sum(
            x is not None for x in (self.nodes, self.capacity_bytes, self.bandwidth)
        )
        if self.lifetime is LifetimeClass.POOLED:
            if n_sizing:
                raise ValueError(
                    f"{self.name!r}: POOLED specs are sized by datasets + "
                    "stage bytes (the lease), not nodes/capacity/bandwidth"
                )
        elif n_sizing > 1:
            raise ValueError(
                f"{self.name!r}: set at most one of nodes/capacity_bytes/"
                "bandwidth (unsized specs negotiate onto backends that need "
                "no dedicated nodes, e.g. globalfs/null)"
            )
        elif n_sizing == 0 and self.lifetime is LifetimeClass.PERSISTENT:
            raise ValueError(
                f"{self.name!r}: PERSISTENT specs must size the pool "
                "(nodes, capacity_bytes, or bandwidth)"
            )
        if self.nodes is not None and self.nodes <= 0:
            raise ValueError(f"{self.name!r}: nodes must be positive")
        if self.capacity_bytes is not None and self.capacity_bytes <= 0:
            raise ValueError(f"{self.name!r}: capacity_bytes must be positive")
        if self.bandwidth is not None and self.bandwidth <= 0:
            raise ValueError(f"{self.name!r}: bandwidth must be positive")
        if self.stage_in_bytes < 0 or self.stage_out_bytes < 0:
            raise ValueError(f"{self.name!r}: negative stage bytes")
        if self.n_streams <= 0:
            raise ValueError(f"{self.name!r}: n_streams must be positive")
        if self.capacity_cap_bytes is not None and self.capacity_cap_bytes <= 0:
            raise ValueError(f"{self.name!r}: capacity_cap_bytes must be positive")
        if any(not isinstance(d, DatasetRef) for d in self.datasets):
            raise ValueError(f"{self.name!r}: datasets must be DatasetRef instances")
        if len({d.name for d in self.datasets}) != len(self.datasets):
            raise ValueError(f"{self.name!r}: duplicate dataset names")
        if any(not m for m in self.managers):
            raise ValueError(f"{self.name!r}: empty backend name in managers")

    # -- derived views --------------------------------------------------------
    def signature(self) -> tuple:
        """Hashable identity of everything negotiation and admission can
        observe about this spec — every field except the name. Two specs
        with equal signatures receive identical offers from ``negotiate``
        and identical grant/deny answers from every backend at any given
        cluster/pool state, which is what the negotiation cache and the
        dispatch queue's admission buckets key on. (The one name-sensitive
        path, PERSISTENT create-or-reattach, is handled by the callers:
        they append the name for that lifetime.)

        Memoized on the (frozen) instance: negotiation and dispatch consult
        it on every admission attempt."""
        try:
            return self._signature_cache
        except AttributeError:
            pass
        sig = (
            self.nodes,
            self.capacity_bytes,
            self.bandwidth,
            self.managers,
            self.lifetime,
            self.access,
            self.datasets,
            self.stage_in_bytes,
            self.stage_out_bytes,
            self.n_streams,
            self.placement,
            self.qos,
            self.runtime,
            self.capacity_cap_bytes,
        )
        object.__setattr__(self, "_signature_cache", sig)
        return sig

    @property
    def dataset_bytes(self) -> float:
        return total_bytes(self.datasets)

    @property
    def scratch_bytes(self) -> float:
        """Private capacity a lease reserves on top of shared datasets."""
        return self.stage_in_bytes + self.stage_out_bytes

    def to_request(self) -> Optional[StorageRequest]:
        """The scheduler-level sizing request (None for POOLED specs, which
        draw capacity from a lease, and for unsized specs, which negotiate
        onto backends that grant no dedicated nodes). Memoized on the
        (frozen) instance — admission paths build it per attempt."""
        try:
            return self._to_request_cache
        except AttributeError:
            pass
        if self.lifetime is LifetimeClass.POOLED or (
            self.nodes is None
            and self.capacity_bytes is None
            and self.bandwidth is None
        ):
            req = None
        else:
            req = StorageRequest(
                nodes=self.nodes,
                capacity_bytes=self.capacity_bytes,
                capability_bw=self.bandwidth,
            )
        object.__setattr__(self, "_to_request_cache", req)
        return req
