"""ProvisioningService: the single entry point for provisioned storage.

    spec = StorageSpec("job0", capacity_bytes=10e12,
                       managers=("ephemeralfs", "globalfs"))
    with service.open_session(spec) as session:
        ...

The service owns the negotiation loop (spec -> scored backends -> session)
and wires the engine parts underneath — `Scheduler` (node allocation),
`Provisioner` (deployment planning/warm trees), and a lazily-created
`PoolManager` (persistent pools + data-aware catalog). Those remain the
internal engine; callers that used to hand-wire them (examples, benchmarks,
the workflow orchestrator's lifecycle) go through here instead, which is
also the mandated substrate for future scaling/serving PRs (ROADMAP).

Two opening paths:

* :meth:`open_session` — the facade path; raises when the cluster is busy
  (callers that queue should use the orchestrator, which does the retrying).
* :meth:`try_open_session` — the queueing-scheduler path; returns ``None``
  when the spec is feasible but does not fit the free pool *right now*, and
  raises :class:`NegotiationError` only for specs no backend can ever serve.

The service also keeps negotiation telemetry (`ServiceStats`): counts, per-
backend session tallies, and cumulative negotiation wallclock, which
``benchmarks/provision_bench.py`` holds under 5% of campaign makespan.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional, Sequence

from ..core.perfmodel import FSDeployment, dom_lustre
from ..core.provisioner import Provisioner
from ..core.resources import ClusterSpec
from ..core.scheduler import AllocationError, Scheduler
from ..obs.trace import NULL_RECORDER
from ..pool.catalog import DatasetRef
from ..pool.manager import PoolManager
from .backends import BackendRegistry, default_registry
from .negotiation import NegotiationError, Offer, OfferCache, negotiate
from .session import StorageSession
from .spec import LifetimeClass, StorageSpec


@dataclasses.dataclass
class ServiceStats:
    """Negotiation + session telemetry for benchmarks and reports."""

    negotiations: int = 0
    negotiation_wall_s: float = 0.0        # cumulative wallclock inside negotiate()
    negotiations_cached: int = 0           # of which served from the offer cache
    failed_negotiations: int = 0
    sessions_opened: dict = dataclasses.field(default_factory=dict)  # backend -> n
    sessions_released: int = 0

    def record_open(self, backend: str) -> None:
        self.sessions_opened[backend] = self.sessions_opened.get(backend, 0) + 1

    @property
    def total_opened(self) -> int:
        return sum(self.sessions_opened.values())


class ProvisioningService:
    """Declarative request -> negotiated `StorageSession`, one entry point."""

    def __init__(
        self,
        cluster: Optional[ClusterSpec] = None,
        *,
        scheduler: Optional[Scheduler] = None,
        provisioner: Optional[Provisioner] = None,
        registry: Optional[BackendRegistry] = None,
        globalfs_model: Optional[FSDeployment] = None,
        teardown_time_s: float = 0.5,
        clock: Optional[Callable[[], float]] = None,
    ):
        if scheduler is None:
            if cluster is None:
                raise ValueError("pass a ClusterSpec or an existing Scheduler")
            scheduler = Scheduler(cluster)
        self.scheduler = scheduler
        self.cluster = scheduler.cluster
        self.provisioner = provisioner or Provisioner(self.cluster)
        self.registry = registry or default_registry()
        self.globalfs_model = globalfs_model or dom_lustre()
        self.teardown_time_s = teardown_time_s
        self.clock = clock
        self.pool_manager: Optional[PoolManager] = None
        self._pool_kwargs: dict = {}
        self.stats = ServiceStats()
        self._globalfs = None          # lazily materialized functional GlobalFS
        self._offer_cache = OfferCache()
        self._pool_gen = 0             # bumped when the pool subsystem is replaced
        # modeled stage times repeat across same-shape sessions; keyed by
        # (direction, bytes, streams, src-shape, dst-shape) — see session.py
        self._stage_time_cache: dict[tuple, float] = {}
        self._recorder = NULL_RECORDER

    # -- observability ---------------------------------------------------------
    @property
    def recorder(self):
        """The trace recorder negotiation/session events flow into (a
        no-op by default). Assigning propagates to the scheduler and the
        pool subsystem, including managers created later."""
        return self._recorder

    @recorder.setter
    def recorder(self, rec) -> None:
        self._recorder = rec
        self.scheduler.recorder = rec
        if self.pool_manager is not None:
            self.pool_manager.recorder = rec

    def _now(self, now: Optional[float]) -> float:
        if now is not None:
            return now
        return self.clock() if self.clock is not None else 0.0

    # -- pools (lazy engine part) ---------------------------------------------
    def ensure_pools(self, **kwargs) -> PoolManager:
        """The pool subsystem behind POOLED/PERSISTENT specs; created on
        first use (or eagerly, to set TTL/eviction/attach-cost knobs).
        Reconfiguring (passing kwargs) replaces the manager, which is only
        legal while it holds no live pools — replacing it later would orphan
        their node allocations and claimed trees."""
        if self.pool_manager is not None:
            if not kwargs:
                return self.pool_manager
            if self.pool_manager.live_pools:
                raise ValueError(
                    "cannot reconfigure the pool subsystem while "
                    f"{len(self.pool_manager.live_pools)} pools are live; "
                    "retire them first"
                )
        kwargs.setdefault("clock", self.clock)
        self.pool_manager = PoolManager(self.scheduler, self.provisioner, **kwargs)
        self.pool_manager.recorder = self._recorder
        # a fresh manager restarts its epoch at 0; the generation counter
        # keeps POOLED offers cached against the old manager from matching
        self._pool_gen += 1
        return self.pool_manager

    def resident_fraction(self, datasets: Sequence[DatasetRef]) -> float:
        """Best-pool resident-byte fraction (0.0 without pools) — the ranking
        signal `DataAwarePolicy` consumes, now service-level so policies do
        not reach into the PoolManager."""
        if self.pool_manager is None:
            return 0.0
        return self.pool_manager.resident_fraction(datasets)

    # -- negotiation -----------------------------------------------------------
    def _negotiation_epoch(self, spec: StorageSpec) -> tuple:
        """Everything a cached offer for ``spec`` can go stale against.
        EPHEMERAL/PERSISTENT offers are scored against the static inventory,
        so only backend registrations invalidate them; POOLED offers track
        the pool subsystem (manager generation + PoolManager epoch, which
        folds in lease-ledger and catalog changes)."""
        if spec.lifetime is LifetimeClass.POOLED:
            pm = self.pool_manager
            pool_state = (self._pool_gen, pm.epoch if pm is not None else -1)
        else:
            pool_state = ()
        return (self.registry.version, pool_state)

    def negotiate(self, spec: StorageSpec) -> Offer:
        """Score candidate backends, return the best feasible offer, or raise
        :class:`NegotiationError` with per-backend rejection reasons.
        Memoized by spec signature + state epoch (see `OfferCache`), so a
        campaign re-scores a spec shape only when the state it negotiated
        against actually changed. ``negotiation_wall_s`` accounts the real
        scoring work; cache hits cost (and add) effectively nothing."""
        stats = self.stats
        stats.negotiations += 1
        sig = spec.signature()
        epoch = self._negotiation_epoch(spec)
        cache = self._offer_cache
        rec = self._recorder
        result = cache.lookup(sig, epoch)
        if result is not None:
            # increment, never assign from cache.hits: the cache object can
            # be swapped/reset mid-campaign while the stats must keep
            # accumulating (tests/test_provision_api.py pins this)
            stats.negotiations_cached += 1
            if rec.enabled:
                rec.negotiation(spec.name, None, cached=True)
            if isinstance(result, Offer):
                return result
            stats.failed_negotiations += 1
            raise NegotiationError(spec.name, result)
        t0 = time.perf_counter()
        try:
            offer = negotiate(spec, self, self.registry)
        except NegotiationError as e:
            cache.store(sig, epoch, e.rejections)
            stats.failed_negotiations += 1
            if rec.enabled:
                rec.negotiation(spec.name, None, cached=False, rejections=e.rejections)
            raise
        finally:
            stats.negotiation_wall_s += time.perf_counter() - t0
        cache.store(sig, epoch, offer)
        if rec.enabled:
            rec.negotiation(
                spec.name, offer.backend, cached=False, rejections=offer.rejections
            )
        return offer

    def feasible(self, spec: StorageSpec, *, n_compute: int = 0) -> bool:
        """Could some backend ever serve this spec (empty cluster)?"""
        if n_compute > len(self.cluster.compute_nodes):
            return False
        try:
            self.negotiate(spec)
        except NegotiationError:
            return False
        return True

    # -- sessions --------------------------------------------------------------
    def try_open_session(
        self,
        spec: StorageSpec,
        *,
        n_compute: int = 0,
        warm_nodes: frozenset = frozenset(),
        materialize: bool = False,
        base_dir: Optional[str] = None,
        now: Optional[float] = None,
        offer: Optional[Offer] = None,
        staged_nodes: frozenset = frozenset(),
        restore_bytes: float = 0.0,
        restore_pool_id: Optional[int] = None,
    ) -> Optional[StorageSession]:
        """Negotiate and grant, or ``None`` when the cluster is merely busy.

        ``n_compute`` co-allocates compute nodes in the same scheduler grant
        (the paper's two-allocations-one-path mechanism), so a session never
        holds storage while its job's compute can't start. ``warm_nodes``
        lets retrying callers model the §IV-B1 warm redeploy. Queueing
        callers retrying the same spec may pass back a prior ``offer`` to
        skip re-negotiation — safe only while the feasibility landscape is
        static (i.e. never cache offers for POOLED specs, whose candidate
        pools retire and drain mid-campaign).

        Checkpoint-resuming callers size stage-in with ``staged_nodes``
        (storage nodes still holding the fully staged inputs of an earlier
        attempt: a grant landing entirely on them skips stage-in) and
        ``restore_bytes`` (checkpoint state read back from the global FS on
        a cold landing); POOLED resumes additionally pass
        ``restore_pool_id`` so a lease landing back on the checkpoint's own
        pool skips the restore read (residency) — admission answers are
        unchanged, only modeled staging costs move (see
        :meth:`DataManagerBackend.try_open`).
        """
        now = self._now(now)
        if offer is None:
            offer = self.negotiate(spec)    # raises NegotiationError if hopeless
        backend = self.registry.get(offer.backend)
        session = backend.try_open(
            spec,
            offer,
            self,
            n_compute=n_compute,
            warm_nodes=warm_nodes,
            materialize=materialize,
            base_dir=base_dir,
            now=now,
            staged_nodes=staged_nodes,
            restore_bytes=restore_bytes,
            restore_pool_id=restore_pool_id,
        )
        if session is not None:
            self.stats.record_open(offer.backend)
            rec = self._recorder
            if rec.enabled:
                rec.session_opened(offer.backend)
        return session

    def open_session(
        self,
        spec: StorageSpec,
        *,
        n_compute: int = 0,
        materialize: bool = False,
        base_dir: Optional[str] = None,
        now: Optional[float] = None,
    ) -> StorageSession:
        """The facade path: grant now or raise (busy clusters raise too —
        queueing callers should drive :meth:`try_open_session` instead)."""
        session = self.try_open_session(
            spec,
            n_compute=n_compute,
            materialize=materialize,
            base_dir=base_dir,
            now=now,
        )
        if session is None:
            free_c, free_s = self.scheduler.free_counts()
            raise AllocationError(
                f"{spec.name!r}: negotiated backend cannot grant now "
                f"(free: {free_c} compute / {free_s} storage nodes); "
                "use try_open_session / the orchestrator to queue"
            )
        return session

    # -- functional global FS (quickstarts) ------------------------------------
    def materialized_globalfs(self, create: bool = False):
        """The shared functional `GlobalFS` instance for materialized
        globalfs-backed sessions (created on demand, shared by design)."""
        if self._globalfs is None and create:
            from ..core.globalfs import GlobalFS

            self._globalfs = GlobalFS()
        return self._globalfs
