"""Capability negotiation: score registered backends against a StorageSpec.

Following the capability-negotiation framing of *Design Principles of
Dynamic Resource Management for HPC* (2403.17107): the requester states
*what* it needs (`StorageSpec`), every registered `DataManagerBackend`
states what it *can* do, and this module arbitrates — each candidate either
produces a structured rejection reason or a scored `Offer`; the best
feasible offer wins, and a request nobody can serve raises
:class:`NegotiationError` carrying every per-backend rejection so the
caller can see exactly why (and relax the spec deliberately).

Candidate order: the spec's ``managers`` tuple when given (preference with
ordered fallbacks — only those backends are considered), otherwise every
registered backend. Preference rank dominates; among same-rank candidates
(the "any backend" case) the numeric score decides.
"""

from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Optional

from ..core.scheduler import AllocationError

if TYPE_CHECKING:
    from .backends import BackendRegistry, DataManagerBackend
    from .service import ProvisioningService
    from .spec import StorageSpec


@dataclasses.dataclass(frozen=True)
class Rejection:
    """Why one backend declined a spec — the structured negotiation trace."""

    backend: str
    reason: str

    def __str__(self) -> str:
        return f"{self.backend}: {self.reason}"


@dataclasses.dataclass(frozen=True)
class Offer:
    """A feasible (backend, sizing, QoS) match for a spec."""

    backend: str
    score: float
    n_storage_nodes: int            # dedicated nodes the grant would draw
    provision_time_s: float         # modeled attach/deploy latency (fresh)
    bandwidth: float                # aggregate write B/s the grant delivers
    rejections: tuple[Rejection, ...] = ()   # backends that lost or declined


class NegotiationError(AllocationError):
    """No registered backend can serve the spec; carries every reason."""

    def __init__(self, spec_name: str, rejections: tuple[Rejection, ...]):
        self.spec_name = spec_name
        self.rejections = tuple(rejections)
        detail = "; ".join(str(r) for r in self.rejections) or "no backends registered"
        super().__init__(f"{spec_name!r}: no backend can serve this spec ({detail})")

    def reason_for(self, backend: str) -> Optional[str]:
        for r in self.rejections:
            if r.backend == backend:
                return r.reason
        return None


def negotiate(
    spec: "StorageSpec", service: "ProvisioningService", registry: "BackendRegistry"
) -> Offer:
    """Pick the best feasible backend for ``spec`` or raise NegotiationError."""
    if spec.managers:
        ranked: list[tuple[int, "DataManagerBackend"]] = []
        rejections: list[Rejection] = []
        for rank, name in enumerate(spec.managers):
            backend = registry.get(name)
            if backend is None:
                rejections.append(
                    Rejection(name, f"not registered (have: {registry.names()})")
                )
                continue
            ranked.append((rank, backend))
    else:
        ranked = list(enumerate_same_rank(registry))
        rejections = []

    offers: list[tuple[int, Offer]] = []
    for rank, backend in ranked:
        reason = backend.check(spec, service)
        if reason is not None:
            rejections.append(Rejection(backend.name, reason))
            continue
        offers.append((rank, backend.offer(spec, service)))
    if not offers:
        raise NegotiationError(spec.name, tuple(rejections))
    # preference rank first (spec's ordered fallbacks), then highest score
    rank, best = min(offers, key=lambda ro: (ro[0], -ro[1].score))
    return dataclasses.replace(best, rejections=tuple(rejections))


def enumerate_same_rank(registry: "BackendRegistry"):
    """All registered backends at equal preference: score alone decides."""
    for backend in registry:
        yield 0, backend


class OfferCache:
    """Memoized :func:`negotiate`, keyed by spec *signature* + state epoch.

    Negotiation is name-blind (see :meth:`StorageSpec.signature`), so a
    campaign of 50k jobs sharing a handful of spec shapes scores backends a
    handful of times, not 50k. Staleness is epoch-based: the caller passes
    whatever state its spec's offers can depend on — for EPHEMERAL and
    PERSISTENT specs that is static over a campaign (sizing and QoS are
    checked against the whole inventory), for POOLED specs it is the
    PoolManager epoch, so those re-score exactly when a pool, its lease
    ledger, or the catalog actually changed. Failures are cached as their
    rejection tuple and re-raised under the asking spec's name.
    """

    def __init__(self) -> None:
        # signature -> (epoch, Offer | tuple[Rejection, ...])
        self._results: dict[tuple, tuple] = {}
        self.hits = 0
        self.misses = 0

    def lookup(self, sig: tuple, epoch: tuple):
        """The cached result — an :class:`Offer`, or the rejection tuple of
        a cached failure — iff one exists for this signature at this epoch;
        None otherwise."""
        cached = self._results.get(sig)
        if cached is not None and cached[0] == epoch:
            self.hits += 1
            return cached[1]
        return None

    def store(self, sig: tuple, epoch: tuple, result) -> None:
        self.misses += 1
        self._results[sig] = (epoch, result)
