"""StorageSession: one negotiated grant of storage, whatever the backend.

The session is the *only* lifecycle handle callers hold. Whether the
negotiation landed on a job-scoped ephemeral file system (allocation +
deploy + teardown), a lease on a persistent pool (attach + drain), the
always-on global file system (nothing to deploy), or a KV store, the caller
sees the same surface:

    with service.open_session(spec) as sess:
        sess.mount()          # functional client (materialized sessions)
        sess.stage_in_time_s  # modeled staging cost (campaign engines)
        ...
    # exit -> release(): teardown vs lease-drain vs no-op is *policy here*,
    # not caller code; nodes/leases are returned even on exception.

Modeled fields (`provision_time_s`, `teardown_time_s`, `stage_in_bytes`,
`saved_bytes`, `fs_model`) are what the workflow orchestrator advances its
virtual clock by; functional fields (`deployment`, `kv`) exist only for
``materialize=True`` sessions that move real bytes.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import TYPE_CHECKING, Optional

from ..core.perfmodel import FSDeployment
from ..core.scheduler import Allocation
from ..core.staging import modeled_stage_time
from .spec import LifetimeClass, StorageSpec

if TYPE_CHECKING:
    from ..core.kvstore import EphemeralKV
    from ..core.provisioner import Deployment
    from ..pool.pool import Lease, StoragePool
    from .negotiation import Offer
    from .service import ProvisioningService


class SessionState(enum.Enum):
    OPEN = "open"
    RELEASED = "released"


class SessionError(RuntimeError):
    pass


def _model_key(model: FSDeployment) -> tuple:
    """The fields :func:`modeled_stage_time` actually reads — a stable cache
    key even when deployments are distinct (but equal-shaped) instances.
    ``mdtest_table`` is deliberately excluded: staging never consults it
    (and it may be an unhashable dict). Memoized on the (frozen) model —
    deployment models are canonicalized and long-lived."""
    try:
        return model._stage_key_cache
    except AttributeError:
        pass
    key = (
        model.kind,
        model.n_nodes,
        model.storage_targets,
        model.md_targets,
        model.disk,
        model.node_dram,
        model.net,
        model.local_client,
    )
    object.__setattr__(model, "_stage_key_cache", key)
    return key


@dataclasses.dataclass(slots=True)
class StorageSession:
    """A live negotiated grant; mutated only by itself and its service."""

    spec: StorageSpec
    offer: "Offer"
    service: "ProvisioningService"
    opened_at: float
    allocation: Optional[Allocation] = None      # nodes this session pins
    lease: Optional["Lease"] = None              # POOLED capacity grant
    pool: Optional["StoragePool"] = None         # PERSISTENT creation handle
    fs_model: Optional[FSDeployment] = None      # perfmodel view for staging
    provision_time_s: float = 0.0                # modeled attach/deploy
    teardown_time_s: float = 0.0                 # modeled release cost
    stage_in_bytes: float = 0.0                  # bytes stage-in must move
    stage_out_bytes: float = 0.0
    saved_bytes: float = 0.0                     # stage-in avoided (hits etc.)
    deployment: Optional["Deployment"] = None    # materialized ephemeral FS
    kv: Optional["EphemeralKV"] = None           # materialized KV store
    state: SessionState = SessionState.OPEN
    #: effective redundancy of the granted deployment: "mirror" when the
    #: backend honors the spec's mirror hint (BeeGFS buddy groups), else
    #: "none" — the chaos engine's survive-or-die switch on node loss
    redundancy: str = "none"
    #: True once a mirrored deployment lost a node: it keeps serving at
    #: halved effective bandwidth (every modeled staging/checkpoint time
    #: doubles) until the session ends — repairs re-silver offline
    degraded: bool = False

    # -- introspection --------------------------------------------------------
    @property
    def backend(self) -> str:
        return self.offer.backend

    @property
    def lifetime(self) -> LifetimeClass:
        return self.spec.lifetime

    @property
    def storage_nodes(self) -> tuple:
        if self.pool is not None:
            return self.pool.allocation.storage_nodes
        if self.allocation is not None:
            return self.allocation.storage_nodes
        return ()

    @property
    def released(self) -> bool:
        return self.state is SessionState.RELEASED

    # -- failure domain (chaos engine) ----------------------------------------
    @property
    def can_degrade(self) -> bool:
        """Would this session survive a single storage-node loss? Mirrored
        deployments spanning >= 2 nodes degrade; everything else dies."""
        return (
            self.redundancy == "mirror"
            and not self.degraded
            and len(self.storage_nodes) >= 2
        )

    def degrade(self) -> None:
        """Enter DEGRADED mode after a node loss: the surviving mirror half
        serves every read/write, so effective bandwidth halves (modeled as
        doubled staging/checkpoint times). A second loss is fatal — the
        caller checks :attr:`can_degrade` first."""
        self._check_open()
        if not self.can_degrade:
            raise SessionError(
                f"session {self.spec.name!r} has no redundancy left to degrade"
            )
        self.degraded = True

    # -- modeled staging (virtual-clock engines) ------------------------------
    def _staging_time(
        self,
        nbytes: float,
        src: Optional[FSDeployment],
        dst: Optional[FSDeployment],
    ) -> float:
        """Memoized :func:`modeled_stage_time` via the service: a campaign
        stages the same byte counts through the same deployment shapes
        thousands of times. A ``None`` endpoint skips that side of the
        model (e.g. checkpoint bursts, whose source is compute memory)."""
        cache = self.service._stage_time_cache
        key = (
            nbytes,
            self.spec.n_streams,
            None if src is None else _model_key(src),
            None if dst is None else _model_key(dst),
        )
        t = cache.get(key)
        if t is None:
            t = modeled_stage_time(nbytes, src, dst, self.spec.n_streams)
            cache[key] = t
        # degraded mirror: the surviving half serves everything — halved
        # effective bandwidth, applied *after* the cache so healthy sessions
        # of the same shape keep sharing the memoized base time
        if self.degraded:
            return t * 2.0
        return t

    @property
    def stage_in_time_s(self) -> float:
        """Modeled wall time for stage-in: global FS read feeding this
        session's data manager (for a globalfs-backed session both ends are
        the global FS — the data never leaves it)."""
        if self.stage_in_bytes <= 0 or self.fs_model is None:
            return 0.0
        return self._staging_time(
            self.stage_in_bytes, self.service.globalfs_model, self.fs_model
        )

    @property
    def stage_out_time_s(self) -> float:
        if self.stage_out_bytes <= 0 or self.fs_model is None:
            return 0.0
        return self._staging_time(
            self.stage_out_bytes, self.fs_model, self.service.globalfs_model
        )

    def stage_time_s(self, nbytes: float, direction: str = "in") -> float:
        """Modeled wall time to move ``nbytes`` through this session in one
        aggregate transfer (``"in"``: global FS feeding the data manager;
        ``"out"``: the reverse). This is the batch-pricing surface for pilot
        task waves — one call prices a whole wave's coalesced I/O through
        the memoized, degraded-aware perfmodel path instead of one model
        walk per task. Zero for storage-less sessions."""
        if nbytes <= 0 or self.fs_model is None:
            return 0.0
        if direction == "in":
            return self._staging_time(
                nbytes, self.service.globalfs_model, self.fs_model
            )
        if direction == "out":
            return self._staging_time(
                nbytes, self.fs_model, self.service.globalfs_model
            )
        raise ValueError(f"direction must be 'in' or 'out', got {direction!r}")

    def checkpoint_write_s(self, nbytes: float) -> float:
        """Modeled wall time for one checkpoint commit: the compute side
        bursts ``nbytes`` into this session's data manager, so the cost is
        the destination write path alone (charged against the session's
        bandwidth via the perfmodel — `repro.checkpoint`'s burst-then-drain
        story priced for the virtual clock). Zero for storage-less sessions."""
        if nbytes <= 0 or self.fs_model is None:
            return 0.0
        return self._staging_time(nbytes, None, self.fs_model)

    def mark_staged(self, now: Optional[float] = None) -> None:
        """Stage-in finished: publish lease datasets as RESIDENT (cache hits
        for every later session routed to the same pool). No-op otherwise."""
        if self.lease is not None:
            self.service.pool_manager.on_stage_in_complete(self.lease, now)

    # -- functional access (materialized sessions) -----------------------------
    def mount(self, client_id: str = "client0"):
        """An I/O client: `FSClient` for POSIX backends, the KV store itself
        for ``access="kv"``. Requires ``materialize=True`` at open (except
        globalfs, which is always live)."""
        self._check_open()
        if self.kv is not None:
            return self.kv
        if self.deployment is not None:
            return self.deployment.mount(client_id)
        fs = self.service.materialized_globalfs()
        if self.backend == "globalfs" and fs is not None:
            from ..core.client import FSClient

            return FSClient(fs, client_id)
        raise SessionError(
            f"session {self.spec.name!r} is modeled-only; "
            "open with materialize=True for functional I/O"
        )

    # -- lifecycle -------------------------------------------------------------
    def _check_open(self) -> None:
        if self.state is not SessionState.OPEN:
            raise SessionError(f"session {self.spec.name!r} is {self.state.value}")

    def release(self, now: Optional[float] = None) -> None:
        """Return everything this session holds. Idempotent; safe mid-fault.

        Teardown-vs-drain is internal policy: EPHEMERAL sessions tear down
        their data manager and free their nodes; POOLED sessions drop the
        lease (the pool outlives them; a DRAINING pool's last lease tears it
        down via the PoolManager); PERSISTENT sessions release only the
        handle — the pool they created keeps running until :meth:`retire`
        or the manager's idle TTL.
        """
        if self.state is SessionState.RELEASED:
            return
        self.state = SessionState.RELEASED
        if self.lease is not None:
            self.service.pool_manager.release(self.lease, now)
            self.lease = None
        if self.deployment is not None:
            self.deployment.teardown()
            self.deployment = None
        if self.kv is not None:
            self.kv.teardown()
            self.service.provisioner.release_tree(self.kv.base_dir)
            self.kv = None
        if self.allocation is not None:
            self.service.scheduler.release(self.allocation)
            self.allocation = None
        self.service.stats.sessions_released += 1
        rec = self.service.recorder
        if rec.enabled:
            rec.session_released(self.backend)

    def retire(self, now: Optional[float] = None) -> bool:
        """PERSISTENT only: stop granting leases on the created pool and tear
        it down once drained. Returns True if torn down immediately."""
        if self.pool is None:
            raise SessionError(
                f"session {self.spec.name!r} did not create a pool; "
                "only PERSISTENT sessions retire"
            )
        return self.service.pool_manager.retire(self.pool, now)

    def __enter__(self) -> "StorageSession":
        self._check_open()
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.release()
