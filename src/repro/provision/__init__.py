"""Unified StorageSession API: declarative specs, backend negotiation.

The public provisioning surface of the repo (the mandated entry point for
new code — see ROADMAP):

    StorageSpec  ->  ProvisioningService.open_session()  ->  StorageSession

`StorageSpec` declares sizing (capacity | bandwidth | node count), preferred
data managers with ordered fallbacks, a lifetime class (EPHEMERAL per-job /
POOLED lease / PERSISTENT pool-create), datasets to stage, placement hints,
and QoS. The service negotiates capabilities across the `BackendRegistry`
(ephemeralfs, globalfs, kvstore, null by default), grants the best feasible
backend or raises `NegotiationError` with per-backend rejection reasons, and
hands back a `StorageSession` context manager that unifies the lifecycle —
teardown vs lease-drain vs pool persistence is session policy, not caller
code. `Scheduler`/`Provisioner`/`PoolManager` remain the internal engine.
"""

from .backends import (
    BackendCapabilities,
    BackendRegistry,
    DataManagerBackend,
    EphemeralFSBackend,
    GlobalFSBackend,
    KVStoreBackend,
    NullBackend,
    default_registry,
)
from .negotiation import NegotiationError, Offer, Rejection
from .service import ProvisioningService, ServiceStats
from .session import SessionError, SessionState, StorageSession
from .spec import LifetimeClass, Placement, QoS, StorageSpec

__all__ = [
    "BackendCapabilities", "BackendRegistry", "DataManagerBackend",
    "EphemeralFSBackend", "GlobalFSBackend", "KVStoreBackend", "NullBackend",
    "default_registry",
    "NegotiationError", "Offer", "Rejection",
    "ProvisioningService", "ServiceStats",
    "SessionError", "SessionState", "StorageSession",
    "LifetimeClass", "Placement", "QoS", "StorageSpec",
]
