"""DataManager backends: declared capabilities + session construction.

Each backend wraps one of the repo's data managers behind a uniform
negotiation surface (the paper's §VII pitch that the provisioning mechanism
is generic over "parallel file system, object-based storage, database,
key-value store"):

* ``ephemeralfs`` — the BeeGFS-analogue; POSIX, striping + mirroring,
  dedicated storage nodes, supports every lifetime class (job-scoped
  deploy, pool leases, pool creation). Pays the C8 deploy cost.
* ``globalfs``   — the always-on Lustre-analogue; POSIX, zero provisioning
  latency, but no dedicated nodes, fixed aggregate bandwidth shared with
  the rest of the machine, and datasets already live there (nothing to
  stage).
* ``kvstore``    — hash-partitioned KV on dedicated nodes; ``access="kv"``
  only, replication via the mirror placement hint, job-scoped lifetime.
* ``null``       — a dry-run backend that accepts any spec at zero cost;
  must be requested by name, so it never wins a real negotiation. The
  orchestrator uses it for jobs with no storage demand, and tests use it
  to exercise the session lifecycle without touching the cluster.

``check`` answers *could this backend ever serve the spec* (capability,
sizing vs whole-cluster inventory, QoS vs perfmodel) with a structured
rejection reason; ``try_open`` performs the actual grant against the free
pool and returns ``None`` when the cluster is merely busy.
"""

from __future__ import annotations

import abc
import dataclasses
import tempfile
from typing import TYPE_CHECKING, Iterator, Optional

from ..core.perfmodel import predict_deploy_time
from ..core.scheduler import AllocationError, JobRequest
from .negotiation import Offer
from .session import StorageSession
from .spec import LifetimeClass, StorageSpec

if TYPE_CHECKING:
    from .service import ProvisioningService


@dataclasses.dataclass(frozen=True)
class BackendCapabilities:
    """What a data manager can do, declared once at registration."""

    access: tuple[str, ...]                  # ("posix",) / ("kv",) / both
    lifetimes: frozenset                     # supported LifetimeClass values
    striping: bool = False                   # honors stripe_size hints
    mirroring: bool = False                  # honors the mirror hint
    dedicated_nodes: bool = False            # grants allocate storage nodes
    persistent_data: bool = False            # data survives the session
    zero_deploy: bool = False                # no provisioning latency
    #: redundancy classes the manager can deploy: "none" always; "mirror"
    #: (BeeGFS buddy groups / KV replication) lets a multi-node deployment
    #: survive a single storage-node loss in DEGRADED mode instead of dying
    redundancy: tuple[str, ...] = ("none",)


class DataManagerBackend(abc.ABC):
    """One registered data manager the service can negotiate onto."""

    name: str = "abstract"
    capabilities: BackendCapabilities
    #: True when, for EPHEMERAL specs, ``try_open`` returns None *iff* the
    #: scheduler co-allocation (n_compute, plus the resolved storage demand
    #: when ``capabilities.dedicated_nodes``) does not fit the free pool —
    #: i.e. admission is gated by the scheduler alone. Lets dispatchers
    #: pre-filter hopeless probes with two O(1) count checks. Custom
    #: backends with extra admission conditions must leave this False.
    scheduler_gated: bool = False

    # -- negotiation -----------------------------------------------------------
    def check(self, spec: StorageSpec, svc: "ProvisioningService") -> Optional[str]:
        """Rejection reason, or None when the spec is serveable (ever)."""
        caps = self.capabilities
        if spec.access not in caps.access:
            return f"no {spec.access} access (offers {'/'.join(caps.access)})"
        if spec.lifetime not in caps.lifetimes:
            return f"does not support {spec.lifetime.value} lifetime"
        if spec.placement.mirror and not caps.mirroring:
            return "no mirroring support"
        return self._check(spec, svc)

    @abc.abstractmethod
    def _check(self, spec: StorageSpec, svc: "ProvisioningService") -> Optional[str]:
        ...

    @abc.abstractmethod
    def offer(self, spec: StorageSpec, svc: "ProvisioningService") -> Offer:
        """Feasible terms for a spec that passed :meth:`check`. Score favors
        QoS headroom, then low provisioning latency, then few nodes."""

    # -- session construction --------------------------------------------------
    @abc.abstractmethod
    def try_open(
        self,
        spec: StorageSpec,
        offer: Offer,
        svc: "ProvisioningService",
        *,
        n_compute: int = 0,
        warm_nodes: frozenset = frozenset(),
        materialize: bool = False,
        base_dir: Optional[str] = None,
        now: float = 0.0,
        staged_nodes: frozenset = frozenset(),
        restore_bytes: float = 0.0,
        restore_pool_id: Optional[int] = None,
    ) -> Optional[StorageSession]:
        """Grant against the free pool; None when merely busy right now.

        Resume-aware sizing (checkpoint-restarting callers): ``staged_nodes``
        are storage nodes already holding this spec's *fully staged* input
        set from a completed earlier attempt — a grant landing entirely on
        them skips stage-in (the data, checkpoints included, is still in the
        warm tree; the skipped traffic is reported as ``saved_bytes``).
        ``restore_bytes`` is checkpoint state to read back from the global
        FS on a cold landing; it joins the stage-in bill. For POOLED specs,
        ``restore_pool_id`` names the pool the checkpoint was committed into
        — a lease landing on that exact pool (ids are never reused) finds
        the checkpoint still RESIDENT in the warm tree and skips the restore
        read entirely. None of these affect *admission* (grant/deny), only
        the session's modeled staging costs, so same-signature jobs stay
        interchangeable to dispatch buckets."""

    @staticmethod
    def _score(bandwidth: float, spec: StorageSpec, provision_s: float, n_nodes: int) -> float:
        floor = spec.qos.min_bandwidth
        headroom = min(bandwidth / floor, 4.0) if floor else bandwidth / 1e9
        return headroom - 0.1 * provision_s - 0.01 * n_nodes


def _effective_redundancy(
    spec: StorageSpec, caps: BackendCapabilities, n_nodes: int
) -> str:
    """The redundancy class a grant actually deploys with: "mirror" only
    when the spec asked for it, the backend can do it, and there are at
    least two nodes to mirror across — otherwise "none"."""
    if spec.placement.mirror and "mirror" in caps.redundancy and n_nodes >= 2:
        return "mirror"
    return "none"


def _resume_stage_in(
    spec: StorageSpec,
    granted_ids: frozenset,
    staged_nodes: frozenset,
    restore_bytes: float,
) -> tuple[float, float]:
    """(stage_in_bytes, saved_bytes) for a dedicated-node grant under the
    resume model: landing entirely on nodes that still hold the staged data
    (warm trees, §IV-B1 extended to data) skips the whole stage-in; a cold
    landing replays it plus the checkpoint restore read."""
    full = spec.stage_in_bytes + spec.dataset_bytes
    if granted_ids and granted_ids <= staged_nodes:
        return 0.0, full + restore_bytes
    return full + restore_bytes, 0.0


class _NodeBackend(DataManagerBackend):
    """Shared sizing/QoS logic for backends that allocate storage nodes."""

    def _resolve(self, spec: StorageSpec, svc: "ProvisioningService") -> tuple[int, float]:
        """(node count, delivered aggregate write B/s) on an empty cluster."""
        req = spec.to_request()
        n = svc.scheduler.resolve_storage_nodes(req, assume_empty=True)
        policy = svc.scheduler.policy
        per_node = min(
            policy.node_capability_bw(node) for node in svc.cluster.storage_nodes
        )
        return n, n * per_node

    def _provision_s(self, spec: StorageSpec, svc: "ProvisioningService") -> float:
        policy = svc.scheduler.policy
        targets = policy.metadata_disks_per_node + policy.storage_disks_per_node
        return predict_deploy_time(targets, runtime=spec.runtime, fresh=True)

    def _check_sized(self, spec: StorageSpec, svc: "ProvisioningService") -> Optional[str]:
        if spec.to_request() is None:
            return "spec has no sizing; dedicated-node backends need nodes/capacity/bandwidth"
        try:
            n, bw = self._resolve(spec, svc)
        except AllocationError as e:
            return str(e)
        total = len(svc.cluster.storage_nodes)
        if n > total:
            return f"needs {n} storage nodes, cluster has {total}"
        if spec.qos.min_bandwidth is not None and bw < spec.qos.min_bandwidth:
            return (
                f"delivers {bw:.3g} B/s over {n} nodes, "
                f"below QoS floor {spec.qos.min_bandwidth:.3g} B/s"
            )
        t = self._provision_s(spec, svc)
        if spec.qos.max_provision_s is not None and t > spec.qos.max_provision_s:
            return (
                f"modeled deploy {t:.2f} s exceeds QoS ceiling "
                f"{spec.qos.max_provision_s:.2f} s"
            )
        return None


class EphemeralFSBackend(_NodeBackend):
    """BeeGFS-analogue on granted nodes; the paper's own data manager."""

    name = "ephemeralfs"
    scheduler_gated = True
    capabilities = BackendCapabilities(
        access=("posix",),
        lifetimes=frozenset(LifetimeClass),
        striping=True,
        mirroring=True,
        dedicated_nodes=True,
        zero_deploy=False,
        redundancy=("none", "mirror"),
    )

    def _check(self, spec, svc):
        if spec.lifetime is LifetimeClass.POOLED:
            pools = svc.pool_manager
            if pools is None:
                return (
                    "POOLED spec but no pool subsystem attached "
                    "(create a PERSISTENT session first)"
                )
            need = spec.dataset_bytes + spec.scratch_bytes
            if not pools.feasible(spec.datasets, spec.scratch_bytes):
                return (
                    f"no active pool can hold the {need:.3g} B working set "
                    f"({len(pools.active_pools)} active pools)"
                )
            if spec.qos.max_provision_s is not None and (
                pools.lease_attach_s > spec.qos.max_provision_s
            ):
                return "lease attach exceeds QoS provisioning ceiling"
            if spec.qos.min_bandwidth is not None:
                bw = self._pooled_bw(pools)
                if bw < spec.qos.min_bandwidth:
                    return (
                        f"best active pool delivers {bw:.3g} B/s, below QoS "
                        f"floor {spec.qos.min_bandwidth:.3g} B/s"
                    )
            return None
        return self._check_sized(spec, svc)

    @staticmethod
    def _pooled_bw(pools) -> float:
        """Aggregate write B/s of the best active pool (lease QoS basis)."""
        return max(
            (min(p.fs_model.raw_write_bw, p.fs_model.net_bw) for p in pools.active_pools),
            default=0.0,
        )

    def offer(self, spec, svc):
        if spec.lifetime is LifetimeClass.POOLED:
            pools = svc.pool_manager
            bw = self._pooled_bw(pools)
            t = pools.lease_attach_s
            return Offer(self.name, self._score(bw, spec, t, 0), 0, t, bw)
        n, bw = self._resolve(spec, svc)
        t = self._provision_s(spec, svc)
        return Offer(self.name, self._score(bw, spec, t, n), n, t, bw)

    def try_open(self, spec, offer, svc, *, n_compute=0, warm_nodes=frozenset(),
                 materialize=False, base_dir=None, now=0.0,
                 staged_nodes=frozenset(), restore_bytes=0.0,
                 restore_pool_id=None):
        if spec.lifetime is LifetimeClass.POOLED:
            return self._try_lease(spec, offer, svc, n_compute=n_compute, now=now,
                                   restore_bytes=restore_bytes,
                                   restore_pool_id=restore_pool_id)
        if spec.lifetime is LifetimeClass.PERSISTENT:
            return self._try_create_pool(spec, offer, svc, n_compute=n_compute, now=now)
        alloc = svc.scheduler.try_submit(
            JobRequest(spec.name, n_compute, storage=spec.to_request())
        )
        if alloc is None:
            return None
        plan = svc.provisioner.plan_for(
            alloc,
            mirror=spec.placement.mirror,
            stripe_size=spec.placement.stripe_size,
            runtime=spec.runtime,
        )
        ids = frozenset(n.node_id for n in alloc.storage_nodes)
        t_prov = predict_deploy_time(
            plan.targets_per_node, runtime=spec.runtime, fresh=not ids <= warm_nodes
        )
        stage_in, saved = _resume_stage_in(spec, ids, staged_nodes, restore_bytes)
        session = StorageSession(
            spec=spec,
            offer=offer,
            service=svc,
            opened_at=now,
            allocation=alloc,
            fs_model=svc.provisioner.model_for(plan),
            provision_time_s=t_prov,
            teardown_time_s=svc.teardown_time_s,
            stage_in_bytes=stage_in,
            stage_out_bytes=spec.stage_out_bytes,
            saved_bytes=saved,
            redundancy=_effective_redundancy(spec, self.capabilities, len(ids)),
        )
        if materialize:
            try:
                session.deployment = svc.provisioner.deploy(plan, base_dir)
            except Exception:
                # a failed deploy (e.g. base_dir collision) must not leak
                # the already-granted nodes
                session.release(now)
                raise
        return session

    def _try_lease(self, spec, offer, svc, *, n_compute, now, restore_bytes=0.0,
                   restore_pool_id=None):
        creq = JobRequest(spec.name, n_compute)
        # compute first (side-effect free): a failed compute fit must not
        # evict pool datasets for nothing
        if not svc.scheduler.can_allocate(creq):
            return None
        lease = svc.pool_manager.try_acquire(
            spec.name, spec.datasets, spec.scratch_bytes, now=now
        )
        if lease is None:
            return None
        alloc = svc.scheduler.try_submit(creq)
        if alloc is None:
            svc.pool_manager.release(lease, now)
            return None
        from ..pool.catalog import total_bytes

        restore = restore_bytes
        saved = lease.resident_bytes
        if restore and restore_pool_id is not None and lease.pool_id == restore_pool_id:
            # checkpoint residency (the warm-tree story extended to
            # checkpoints): the resume re-leased the very pool its last
            # commit was written into, and that pool has lost no node since
            # (a loss clears the caller's remembered pool id) — the restore
            # is a warm read inside the pool, not global-FS traffic
            saved += restore
            restore = 0.0
        return StorageSession(
            spec=spec,
            offer=offer,
            service=svc,
            opened_at=now,
            allocation=alloc,
            lease=lease,
            fs_model=svc.pool_manager.get(lease.pool_id).fs_model,
            provision_time_s=svc.pool_manager.lease_attach_s,
            teardown_time_s=0.0,   # the pool outlives the session
            # resuming leases re-attach warm: only datasets the catalog says
            # were evicted are in `missing` (re-staged); checkpoint state is
            # read back from the global FS on top unless it is still resident
            stage_in_bytes=spec.stage_in_bytes + total_bytes(lease.missing)
            + restore,
            stage_out_bytes=spec.stage_out_bytes,
            saved_bytes=saved,
        )

    def _try_create_pool(self, spec, offer, svc, *, n_compute=0, now):
        pools = svc.ensure_pools()
        from ..pool.pool import PoolState

        # the session's own compute nodes (the pool's storage allocation is
        # separate and outlives the session): grant them first so a busy
        # compute pool is a clean None, not a half-created pool
        alloc = None
        if n_compute:
            alloc = svc.scheduler.try_submit(JobRequest(spec.name, n_compute))
            if alloc is None:
                return None

        def _release_compute():
            if alloc is not None:
                svc.scheduler.release(alloc)

        for existing in pools.pools:
            if existing.name == spec.name and existing.state is PoolState.ACTIVE:
                # idempotent by name: a retried/replayed PERSISTENT spec
                # reattaches to the pool it already created instead of
                # colliding on the claimed base_dir — but only if the sizing
                # still resolves to the same node count (a silently smaller
                # pool would be a lie)
                want = svc.scheduler.resolve_storage_nodes(
                    spec.to_request(), assume_empty=True
                )
                have = len(existing.allocation.storage_nodes)
                if want != have:
                    _release_compute()
                    raise AllocationError(
                        f"{spec.name!r}: an ACTIVE pool of this name spans "
                        f"{have} nodes but the spec resolves to {want}; "
                        "retire it or pick another name"
                    )
                return StorageSession(
                    spec=spec,
                    offer=offer,
                    service=svc,
                    opened_at=now,
                    allocation=alloc,
                    pool=existing,
                    fs_model=existing.fs_model,
                    provision_time_s=0.0,   # already provisioned
                    teardown_time_s=0.0,
                )
        if not svc.scheduler.can_allocate(JobRequest(spec.name, 0, storage=spec.to_request())):
            _release_compute()
            return None
        try:
            pool = pools.create_pool(
                nodes=spec.nodes,
                capacity_bytes=spec.capacity_bytes,
                capability_bw=spec.bandwidth,
                cap_bytes=spec.capacity_cap_bytes,
                name=spec.name,
                runtime=spec.runtime,
                now=now,
            )
        except Exception:
            _release_compute()
            raise
        return StorageSession(
            spec=spec,
            offer=offer,
            service=svc,
            opened_at=now,
            allocation=alloc,
            pool=pool,
            fs_model=pool.fs_model,
            provision_time_s=pool.deploy_time_s,
            teardown_time_s=0.0,   # retirement/TTL drains it, not the session
        )


class GlobalFSBackend(DataManagerBackend):
    """The always-on Lustre-analogue: zero deploy, shared bandwidth."""

    name = "globalfs"
    scheduler_gated = True
    capabilities = BackendCapabilities(
        access=("posix",),
        lifetimes=frozenset({LifetimeClass.EPHEMERAL}),
        persistent_data=True,
        zero_deploy=True,
    )

    def __init__(self, capacity_bytes: float = 170e12):
        self.capacity_bytes = capacity_bytes

    def _aggregate_bw(self, svc) -> float:
        m = svc.globalfs_model
        return min(m.raw_write_bw, m.net_bw)

    def _check(self, spec, svc):
        if spec.nodes is not None:
            return "cannot grant dedicated storage nodes (always-on shared FS)"
        if spec.capacity_bytes is not None and spec.capacity_bytes > self.capacity_bytes:
            return (
                f"capacity {spec.capacity_bytes:.3g} B exceeds the shared "
                f"file system's {self.capacity_bytes:.3g} B"
            )
        bw = self._aggregate_bw(svc)
        if spec.bandwidth is not None and spec.bandwidth > bw:
            return f"aggregate bandwidth {bw:.3g} B/s below sized {spec.bandwidth:.3g} B/s"
        if spec.qos.min_bandwidth is not None and spec.qos.min_bandwidth > bw:
            return (
                f"aggregate bandwidth {bw:.3g} B/s below QoS floor "
                f"{spec.qos.min_bandwidth:.3g} B/s"
            )
        return None

    def offer(self, spec, svc):
        bw = self._aggregate_bw(svc)
        return Offer(self.name, self._score(bw, spec, 0.0, 0), 0, 0.0, bw)

    def try_open(self, spec, offer, svc, *, n_compute=0, warm_nodes=frozenset(),
                 materialize=False, base_dir=None, now=0.0,
                 staged_nodes=frozenset(), restore_bytes=0.0,
                 restore_pool_id=None):
        alloc = None
        if n_compute:
            alloc = svc.scheduler.try_submit(JobRequest(spec.name, n_compute))
            if alloc is None:
                return None
        if materialize:
            svc.materialized_globalfs(create=True)
        return StorageSession(
            spec=spec,
            offer=offer,
            service=svc,
            opened_at=now,
            allocation=alloc,
            fs_model=svc.globalfs_model,
            provision_time_s=0.0,
            teardown_time_s=0.0,
            # shared datasets already live on the global FS: nothing to move,
            # and the avoided copies are reported as saved traffic; resuming
            # callers re-read their checkpoint (a within-FS copy)
            stage_in_bytes=spec.stage_in_bytes + restore_bytes,
            stage_out_bytes=spec.stage_out_bytes,
            saved_bytes=spec.dataset_bytes,
        )


class KVStoreBackend(_NodeBackend):
    """Hash-partitioned KV store on granted nodes (``access="kv"``)."""

    name = "kvstore"
    scheduler_gated = True
    capabilities = BackendCapabilities(
        access=("kv",),
        lifetimes=frozenset({LifetimeClass.EPHEMERAL}),
        mirroring=True,          # replicate=True mirrors to the next node
        dedicated_nodes=True,
        redundancy=("none", "mirror"),
    )

    def _check(self, spec, svc):
        reason = self._check_sized(spec, svc)
        if reason is not None:
            return reason
        if spec.placement.mirror:
            n = svc.scheduler.resolve_storage_nodes(spec.to_request(), assume_empty=True)
            if n < 2:
                return "replication (mirror) needs >= 2 storage nodes"
        return None

    def offer(self, spec, svc):
        n, bw = self._resolve(spec, svc)
        t = self._provision_s(spec, svc)
        return Offer(self.name, self._score(bw, spec, t, n), n, t, bw)

    def try_open(self, spec, offer, svc, *, n_compute=0, warm_nodes=frozenset(),
                 materialize=False, base_dir=None, now=0.0,
                 staged_nodes=frozenset(), restore_bytes=0.0,
                 restore_pool_id=None):
        alloc = svc.scheduler.try_submit(
            JobRequest(spec.name, n_compute, storage=spec.to_request())
        )
        if alloc is None:
            return None
        plan = svc.provisioner.plan_for(alloc, runtime=spec.runtime)
        ids = frozenset(n.node_id for n in alloc.storage_nodes)
        stage_in, saved = _resume_stage_in(spec, ids, staged_nodes, restore_bytes)
        session = StorageSession(
            spec=spec,
            offer=offer,
            service=svc,
            opened_at=now,
            allocation=alloc,
            fs_model=svc.provisioner.model_for(plan),
            provision_time_s=predict_deploy_time(
                plan.targets_per_node, runtime=spec.runtime, fresh=not ids <= warm_nodes
            ),
            teardown_time_s=svc.teardown_time_s,
            stage_in_bytes=stage_in,
            stage_out_bytes=spec.stage_out_bytes,
            saved_bytes=saved,
            redundancy=_effective_redundancy(spec, self.capabilities, len(ids)),
        )
        if materialize:
            from ..core.kvstore import EphemeralKV

            base_dir = base_dir or tempfile.mkdtemp(prefix="kv-")
            try:
                svc.provisioner.claim_tree(base_dir, owner=spec.name)
                try:
                    session.kv = EphemeralKV(
                        alloc.storage_nodes, base_dir, replicate=spec.placement.mirror
                    )
                except Exception:
                    svc.provisioner.release_tree(base_dir)
                    raise
            except Exception:
                session.release(now)   # failed materialize must not leak nodes
                raise
        return session


class NullBackend(DataManagerBackend):
    """Dry-run backend: accepts anything at zero cost, by explicit request."""

    name = "null"
    scheduler_gated = True
    capabilities = BackendCapabilities(
        access=("posix", "kv"),
        lifetimes=frozenset(LifetimeClass),
        striping=True,
        mirroring=True,
        zero_deploy=True,
    )

    def _check(self, spec, svc):
        if self.name not in spec.managers:
            return "dry-run backend; must be requested by name in managers"
        return None

    def offer(self, spec, svc):
        return Offer(self.name, 0.0, 0, 0.0, float("inf"))

    def try_open(self, spec, offer, svc, *, n_compute=0, warm_nodes=frozenset(),
                 materialize=False, base_dir=None, now=0.0,
                 staged_nodes=frozenset(), restore_bytes=0.0,
                 restore_pool_id=None):
        alloc = None
        if n_compute:
            alloc = svc.scheduler.try_submit(JobRequest(spec.name, n_compute))
            if alloc is None:
                return None
        return StorageSession(
            spec=spec, offer=offer, service=svc, opened_at=now, allocation=alloc
        )


class BackendRegistry:
    """Ordered name -> backend registry the service negotiates over."""

    def __init__(self, backends: Optional[list[DataManagerBackend]] = None):
        self._backends: dict[str, DataManagerBackend] = {}
        #: bumped on registration; offers cached against the old set go stale
        self.version = 0
        for b in backends or []:
            self.register(b)

    def register(self, backend: DataManagerBackend) -> None:
        if backend.name in self._backends:
            raise ValueError(f"backend {backend.name!r} already registered")
        self._backends[backend.name] = backend
        self.version += 1

    def get(self, name: str) -> Optional[DataManagerBackend]:
        return self._backends.get(name)

    def names(self) -> tuple[str, ...]:
        return tuple(self._backends)

    def __iter__(self) -> Iterator[DataManagerBackend]:
        return iter(self._backends.values())

    def __len__(self) -> int:
        return len(self._backends)


def default_registry() -> BackendRegistry:
    """The stock negotiation set: ephemeral FS, global FS, KV, dry-run."""
    return BackendRegistry(
        [EphemeralFSBackend(), GlobalFSBackend(), KVStoreBackend(), NullBackend()]
    )
