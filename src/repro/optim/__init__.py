from .adamw import AdamWConfig, OptState, global_norm, init, update
from .compression import ErrorFeedback, compress_grads
from .compression import init as ef_init
from .schedules import constant, warmup_cosine

__all__ = [
    "AdamWConfig", "OptState", "global_norm", "init", "update",
    "ErrorFeedback", "compress_grads", "ef_init", "constant", "warmup_cosine",
]
