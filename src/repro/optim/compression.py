"""Gradient compression for the DP all-reduce: int8 quantization with error
feedback (1-bit-Adam lineage).

On a real pod the win is collective bytes: reduce-scatter the int8 payload
(4x fewer bytes than fp32, 2x vs bf16) and dequantize after the sum. Here the
quantize -> (collective) -> dequantize numerics are implemented exactly as
they would run per-shard, with the residual (quantization error) fed back
into the next step so the compression bias vanishes over time.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class ErrorFeedback(NamedTuple):
    residual: Any     # same structure as grads, fp32


def init(grads_like) -> ErrorFeedback:
    return ErrorFeedback(
        jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)
    )


def quantize(g: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-tensor int8. Returns (q, scale)."""
    amax = jnp.max(jnp.abs(g)) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def int8_allreduce(x: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """Mean-all-reduce with int8 wire payloads (inside shard_map).

    Ring all-reduce of f32 moves ~2·|x|·4 bytes/device; this moves
    ~2·|x|·1: per-device int8 quantize -> all_to_all chunks -> local f32
    sum of peers' chunks -> int8 re-quantize -> all_gather. This is the
    collective the plain ``compress_grads`` round-trip cannot buy under
    GSPMD (XLA reduces the dequantized values) — §Perf iterations A2/B4.

    x must be the device-local FULL tensor (replicated layout pre-reduce),
    flattened internally; leading size is padded to the axis size.
    """
    n = jax.lax.psum(1, axis_name)
    flat = x.reshape(-1)
    pad = (-flat.size) % n
    flat = jnp.pad(flat, (0, pad))
    q, scale = quantize(flat)
    chunks = q.reshape(n, -1)                              # (n, chunk)
    # every device receives chunk[axis_index] from all peers
    recv = jax.lax.all_to_all(chunks[:, None, :], axis_name, split_axis=0,
                              concat_axis=1)[:, :, :]      # (1, n, chunk)
    recv = recv.reshape(n, -1)
    scales = jax.lax.all_gather(scale, axis_name)          # (n,)
    part = jnp.sum(recv.astype(jnp.float32) * scales[:, None], axis=0) / n
    q2, s2 = quantize(part)
    full_q = jax.lax.all_gather(q2, axis_name)             # (n, chunk) int8
    full_s = jax.lax.all_gather(s2, axis_name)
    out = (full_q.astype(jnp.float32) * full_s[:, None]).reshape(-1)
    out = out[: x.size] if pad else out
    return out.reshape(x.shape).astype(x.dtype)


def compress_grads(grads, ef: ErrorFeedback):
    """Apply error-feedback int8 round-trip to a grad pytree. Returns
    (decompressed_grads, new_error_feedback, bytes_ratio)."""

    def one(g, r):
        gf = g.astype(jnp.float32) + r
        q, s = quantize(gf)
        deq = dequantize(q, s)
        return deq.astype(g.dtype), gf - deq

    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = treedef.flatten_up_to(ef.residual)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    new_g = treedef.unflatten([o[0] for o in outs])
    new_r = treedef.unflatten([o[1] for o in outs])
    in_bytes = sum(g.size * g.dtype.itemsize for g in flat_g)
    out_bytes = sum(g.size for g in flat_g)  # int8 payload
    return new_g, ErrorFeedback(new_r), out_bytes / max(in_bytes, 1)
