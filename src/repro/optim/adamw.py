"""AdamW with fp32 master weights, global-norm clipping, and mixed-precision
params (bf16 compute copies). Functional: state is a pytree."""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


class OptState(NamedTuple):
    step: jnp.ndarray           # scalar int32
    master: Any                 # fp32 params
    m: Any
    v: Any


def init(params) -> OptState:
    # copies force distinct buffers: astype(f32) on fp32 params is a no-op
    # alias, and identical jnp.zeros results may alias — either breaks
    # donation ("attempt to donate the same buffer twice")
    f32 = lambda p: jnp.array(p, dtype=jnp.float32, copy=True)
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    zeros2 = lambda p: jnp.zeros(p.shape, jnp.float32).copy()
    return OptState(
        step=jnp.zeros((), jnp.int32),
        master=jax.tree.map(f32, params),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros2, params),
    )


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree))
    )


def update(grads, state: OptState, params, cfg: AdamWConfig, lr_scale=1.0):
    """Returns (new_params, new_state, stats). ``lr_scale`` multiplies cfg.lr
    (schedules pass the factor)."""
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(g, m, v, mw):
        g = g.astype(jnp.float32) * scale
        m_new = b1 * m + (1 - b1) * g
        v_new = b2 * v + (1 - b2) * g * g
        mhat = m_new / bc1
        vhat = v_new / bc2
        mw_new = mw - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * mw)
        return m_new, v_new, mw_new

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    flat_w = treedef.flatten_up_to(state.master)
    out = [upd(g, m, v, w) for g, m, v, w in zip(flat_g, flat_m, flat_v, flat_w)]
    new_m = treedef.unflatten([o[0] for o in out])
    new_v = treedef.unflatten([o[1] for o in out])
    new_master = treedef.unflatten([o[2] for o in out])
    flat_p = treedef.flatten_up_to(params)
    new_params = treedef.unflatten(
        [mw.astype(p.dtype) for mw, p in zip([o[2] for o in out], flat_p)]
    )
    return new_params, OptState(step, new_master, new_m, new_v), {
        "grad_norm": gnorm,
        "lr": jnp.asarray(lr),
    }
