from .fault import FaultInjector, FaultSpec, HeartbeatMonitor, RestartPlan, plan_restart
from .parallel import (
    RuntimeConfig,
    TrainState,
    jit_decode_step,
    jit_prefill,
    jit_train_step,
    make_decode_step,
    make_prefill,
    make_train_state,
    make_train_step,
    train_state_shardings,
)
from .sharding import (
    batch_shardings,
    cache_shardings,
    dp_axes,
    opt_shardings,
    param_shardings,
    param_spec,
)

__all__ = [
    "FaultInjector", "FaultSpec", "HeartbeatMonitor", "RestartPlan", "plan_restart",
    "RuntimeConfig", "TrainState", "jit_decode_step", "jit_prefill",
    "jit_train_step", "make_decode_step", "make_prefill", "make_train_state",
    "make_train_step", "train_state_shardings",
    "batch_shardings", "cache_shardings", "dp_axes", "opt_shardings",
    "param_shardings", "param_spec",
]
