"""Sharding rules: pytree paths -> PartitionSpecs for the (pod, data, model)
mesh.

Tensor parallelism rides the "model" axis (attention/FFN inner dims, vocab,
MoE experts, SSM inner channels); data parallelism rides ("pod", "data").
Rules are *candidate lists*: the first assignment whose axis sizes divide the
dimension wins, axes that do not divide are dropped (e.g. internvl2's odd
92553 vocab falls back from vocab- to d_model-sharding). Stacked-layer
leading dims are padded with None automatically.
"""

from __future__ import annotations

import math
from typing import Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MODEL = "model"


def dp_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return math.prod(mesh.shape[a] for a in axes)


def _sanitize(mesh: Mesh, spec: Sequence, shape: tuple[int, ...]) -> P:
    """Drop axis assignments that don't divide the dim; composite dp axes
    degrade to their largest dividing prefix."""
    out = []
    for dim, axes in zip(shape, spec):
        if axes is None:
            out.append(None)
            continue
        cand = axes if isinstance(axes, tuple) else (axes,)
        # try full composite, then prefixes, then single axes
        chosen = None
        options = [cand] + [cand[:i] for i in range(len(cand) - 1, 0, -1)] + [
            (a,) for a in cand
        ]
        for opt in options:
            if dim % _axis_size(mesh, opt) == 0:
                chosen = opt if len(opt) > 1 else opt[0]
                break
        out.append(chosen)
    # an axis may appear at most once in the spec
    seen = set()
    final = []
    for axes in out:
        cand = axes if isinstance(axes, tuple) else ((axes,) if axes else ())
        if any(a in seen for a in cand):
            final.append(None)
            continue
        seen.update(cand)
        final.append(axes)
    return P(*final)


# -- parameter rules ----------------------------------------------------------
# (substring match on the '/'-joined path, logical spec for the trailing dims)
_PARAM_RULES: list[tuple[str, tuple]] = [
    ("router/w", (None, None)),
    ("moe/gate", (MODEL, None, None)),       # (E, d, f): expert parallel
    ("moe/up", (MODEL, None, None)),
    ("moe/down", (MODEL, None, None)),
    ("embed/w", (MODEL, None)),
    ("unembed/w", (MODEL, None)),
    ("wq/w", (None, MODEL)),
    ("wk/w", (None, MODEL)),
    ("wv/w", (None, MODEL)),
    ("wq/b", (MODEL,)),
    ("wk/b", (MODEL,)),
    ("wv/b", (MODEL,)),
    ("wo/w", (MODEL, None)),
    ("gate/w", (None, MODEL)),
    ("up/w", (None, MODEL)),
    ("down/w", (MODEL, None)),
    ("in_proj/w", (None, MODEL)),
    ("out_proj/w", (MODEL, None)),
    ("conv_w", (None, MODEL)),
    ("conv_b", (MODEL,)),
    ("gnorm/scale", (MODEL,)),
    ("w_in/w", (None, MODEL)),
    ("w_in/b", (MODEL,)),
    ("w_gates/w", (None, MODEL)),
    ("w_gates/b", (MODEL,)),
    ("skip", (MODEL,)),
    ("R", (None, MODEL, None, None)),        # (4, H, dh, dh)
]

# how many leading stacked-layer dims each top-level group carries
_STACK_DIMS = {
    "layers": 1,
    "local_layers": 2,
    "global_layers": 1,
    "mamba_groups": 2,
    "mamba_tail": 1,
    "shared": 0,
    "slstm": 1,
    "mlstm": 2,
    "enc_layers": 1,
    "dec_layers": 1,
}


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_spec(mesh: Mesh, path_str: str, shape: tuple[int, ...]) -> P:
    top = path_str.split("/")[0]
    n_stack = _STACK_DIMS.get(top, 0)
    logical_shape = shape[n_stack:]
    for pat, spec in _PARAM_RULES:
        if pat in path_str and len(spec) == len(logical_shape):
            full = (None,) * n_stack + tuple(spec)
            return _sanitize(mesh, full, shape)
    return P()  # replicate (norm scales, small vectors, ...)


def param_shardings(mesh: Mesh, params_like):
    def one(path, leaf):
        return NamedSharding(mesh, param_spec(mesh, _path_str(path), leaf.shape))

    return jax.tree_util.tree_map_with_path(one, params_like)


# -- optimizer state ----------------------------------------------------------
def opt_shardings(mesh: Mesh, opt_like, params_sharding, *, zero1: bool = False):
    """m/v/master shadow the param shardings; with zero1, additionally shard
    the largest unsharded dim over "data" (optimizer-state partitioning)."""
    dp = tuple(a for a in mesh.axis_names if a == "data")

    def shadow(ps, leaf):
        spec = list(ps.spec) + [None] * (len(leaf.shape) - len(ps.spec))
        if zero1 and dp:
            used = {a for s in spec if s for a in ((s,) if isinstance(s, str) else s)}
            if "data" not in used:
                # biggest dim not already sharded, divisible by data axis
                order = np.argsort([-d for d in leaf.shape])
                for i in order:
                    if spec[i] is None and leaf.shape[i] % mesh.shape["data"] == 0:
                        spec[i] = "data"
                        break
        return NamedSharding(mesh, P(*spec))

    import jax.tree_util as jtu

    def one(ps_leaf, leaf):
        return shadow(ps_leaf, leaf)

    # opt state = OptState(step, master, m, v) with same tree structure in
    # master/m/v as params
    from ..optim.adamw import OptState

    step_sh = NamedSharding(mesh, P())
    master = jax.tree.map(one, params_sharding, opt_like.master)
    m = jax.tree.map(one, params_sharding, opt_like.m)
    v = jax.tree.map(one, params_sharding, opt_like.v)
    return OptState(step=step_sh, master=master, m=m, v=v)


# -- activations / batches / caches ------------------------------------------
def batch_shardings(mesh: Mesh, batch_like):
    dp = dp_axes(mesh)

    def one(path, leaf):
        spec = [None] * len(leaf.shape)
        if len(leaf.shape) >= 1 and leaf.shape[0] % _axis_size(mesh, dp) == 0:
            spec[0] = dp if len(dp) > 1 else dp[0]
        return NamedSharding(mesh, _sanitize(mesh, spec, leaf.shape))

    return jax.tree_util.tree_map_with_path(one, batch_like)


# cache leaf name -> (batch_dim_index_from_end, seq_dim_index_from_end) hints
def cache_shardings(mesh: Mesh, cache_like, cfg):
    """Decode caches: batch over dp where divisible; KV sequence over "model"
    (flash-decode style context parallelism — head-count agnostic); SSM/mLSTM
    states shard heads or channels over "model"."""
    dp = dp_axes(mesh)

    def one(path, leaf):
        name = _path_str(path)
        shape = leaf.shape
        spec: list = [None] * len(shape)
        if leaf.ndim == 0:
            return NamedSharding(mesh, P())
        # mlstm matrix memory (..., B, H, dh, dh): BATCH-LOCAL (dp only).
        # Any model-axis sharding here loses: GSPMD cannot reshard between
        # the layouts the decode einsums prefer and replicates the whole
        # cache every step ("involuntary full rematerialization") — measured
        # 113 -> 254 ms/step before this rule. Recurrent decode is
        # embarrassingly parallel over batch; keep it that way.
        if leaf.ndim >= 5 and leaf.shape[-1] == leaf.shape[-2]:
            spec[-4] = dp
        # KV-style caches: (..., B, S, K, hd)
        elif any(k in name for k in ("k", "v", "sk", "sv", "gk", "gv", "ck", "cv")) \
                and leaf.ndim >= 4 and "ring" not in name and "conv" not in name:
            spec[-4] = dp
            spec[-3] = MODEL
        elif "conv" in name:                       # (..., B, W-1, Ch)
            spec[-3] = dp
        elif name.endswith("h") and leaf.ndim >= 4:  # ssm state (..., B, H, P, N)
            spec[-4] = dp
            spec[-3] = MODEL
        elif leaf.ndim >= 3:                        # n/m/c/h recurrent states
            spec[-3] = dp
        return NamedSharding(mesh, _sanitize(mesh, spec, shape))

    return jax.tree_util.tree_map_with_path(one, cache_like)


def replicated(mesh: Mesh, tree_like):
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree_like)
