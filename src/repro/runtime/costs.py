"""Analytic cost extraction.

XLA's ``cost_analysis()`` counts ``while``/``scan`` bodies ONCE (loop trip
counts are invisible to it), so for scan-over-layers models it undercounts
flops/bytes by ~n_layers. Two fixes live here:

1. **Jaxpr walker** (``jaxpr_costs``): exact algorithmic flops (2*M*N*K per
   dot, conv-aware) and a post-fusion byte estimate (dot/gather/scatter
   operands + every op's outputs), recursing into scan bodies with the true
   trip count. This is the flops source for §Roofline.
2. **While-aware HLO collective parser** (``hlo_collective_bytes``): walks
   the post-SPMD HLO text, attributes collective result bytes to their
   computation, and multiplies loop bodies by their trip count (recovered
   from the loop condition's comparison constant).
"""

from __future__ import annotations

import math
import re
from collections import defaultdict
from typing import Any

import jax
import numpy as np
from jax import core as jcore

_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
                "u8": 1, "pred": 1, "s64": 8, "f64": 8, "u64": 8, "s16": 2,
                "u16": 2, "c64": 8, "c128": 16, "s4": 1, "u4": 1}


def _nbytes(aval) -> int:
    try:
        return int(np.prod(aval.shape)) * aval.dtype.itemsize
    except Exception:  # noqa: BLE001
        return 0


def _dot_flops(eqn) -> int:
    d = eqn.params["dimension_numbers"]
    (lc, rc), (lb, rb) = d
    a = eqn.invars[0].aval
    batch = math.prod(a.shape[i] for i in lb) if lb else 1
    contract = math.prod(a.shape[i] for i in lc) if lc else 1
    m = math.prod(
        a.shape[i] for i in range(len(a.shape)) if i not in set(lc) | set(lb)
    )
    b = eqn.invars[1].aval
    n = math.prod(
        b.shape[i] for i in range(len(b.shape)) if i not in set(rc) | set(rb)
    )
    return 2 * batch * m * n * contract


def _conv_flops(eqn) -> int:
    out = eqn.outvars[0].aval
    rhs = eqn.invars[1].aval
    groups = eqn.params.get("feature_group_count", 1)
    kernel_elems = math.prod(rhs.shape[:-1])  # spatial * in_per_group
    return 2 * int(np.prod(out.shape)) * kernel_elems // max(groups, 1)


_INNER_JAXPR_KEYS = ("jaxpr", "call_jaxpr", "fun_jaxpr", "cond_jaxpr")


def jaxpr_costs(jaxpr) -> dict:
    """Walk a (closed) jaxpr. Returns {"flops", "bytes", "dot_bytes"}."""
    if hasattr(jaxpr, "jaxpr"):
        jaxpr = jaxpr.jaxpr
    flops = 0
    nbytes = 0
    dot_bytes = 0

    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        if prim == "dot_general":
            f = _dot_flops(eqn)
            flops += f
            io = sum(_nbytes(v.aval) for v in eqn.invars) + sum(
                _nbytes(v.aval) for v in eqn.outvars
            )
            nbytes += io
            dot_bytes += io
        elif prim == "conv_general_dilated":
            flops += _conv_flops(eqn)
            nbytes += sum(_nbytes(v.aval) for v in eqn.invars) + sum(
                _nbytes(v.aval) for v in eqn.outvars
            )
        elif prim == "scan":
            inner = jaxpr_costs(eqn.params["jaxpr"])
            n = eqn.params["length"]
            flops += inner["flops"] * n
            nbytes += inner["bytes"] * n
            dot_bytes += inner["dot_bytes"] * n
        elif prim == "while":
            inner = jaxpr_costs(eqn.params["body_jaxpr"])
            flops += inner["flops"]          # trip count unknown; lower bound
            nbytes += inner["bytes"]
            dot_bytes += inner["dot_bytes"]
        elif prim == "cond":
            branches = [jaxpr_costs(b) for b in eqn.params["branches"]]
            flops += max(b["flops"] for b in branches)
            nbytes += max(b["bytes"] for b in branches)
            dot_bytes += max(b["dot_bytes"] for b in branches)
        elif prim in ("gather", "scatter", "scatter-add", "scatter_add",
                      "dynamic_update_slice", "dynamic_slice"):
            nbytes += sum(_nbytes(v.aval) for v in eqn.outvars)
            # indexed operand traffic: count the smaller of operand/output
            if eqn.invars:
                nbytes += min(
                    _nbytes(eqn.invars[0].aval),
                    4 * sum(_nbytes(v.aval) for v in eqn.outvars) or 1 << 62,
                )
        else:
            inner = None
            for k in _INNER_JAXPR_KEYS:
                if k in getattr(eqn, "params", {}):
                    inner = eqn.params[k]
                    break
            if inner is not None:
                c = jaxpr_costs(inner)
                flops += c["flops"]
                nbytes += c["bytes"]
                dot_bytes += c["dot_bytes"]
            else:
                # assume fused with producers: count outputs only
                nbytes += sum(_nbytes(v.aval) for v in eqn.outvars)

    return {"flops": flops, "bytes": nbytes, "dot_bytes": dot_bytes}


def step_costs(fn, *args) -> dict:
    closed = jax.make_jaxpr(fn)(*args)
    return jaxpr_costs(closed)


# ---------------------------------------------------------------------------
# while-aware collective parsing of post-SPMD HLO text
# ---------------------------------------------------------------------------
_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|s64|s32|s16|s8|u64|u32|u16|u8|pred|c64|c128|s4|u4)\[([\d,]*)\]")
_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
          "collective-permute")
_COLL_RE = re.compile(
    r"=\s.*?\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)(?:-start)?\("
)
_WHILE_RE = re.compile(
    r"\bwhile\(.*?\bbody=%?([\w.\-]+)"
)
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')


def _result_bytes(line: str, kind_pos: int) -> int:
    """Sum shape bytes between '=' and the collective op name (handles tuple
    results)."""
    eq = line.find("=")
    if eq < 0 or eq > kind_pos:
        return 0
    total = 0
    for dt, dims in _SHAPE_RE.findall(line[eq:kind_pos]):
        elems = 1
        for d in dims.split(","):
            if d:
                elems *= int(d)
        total += elems * _DTYPE_BYTES[dt]
    return total


def hlo_collective_bytes(hlo: str) -> dict:
    """Collective result bytes, multiplying while-loop bodies by their
    ``known_trip_count`` (present in post-optimization HLO)."""
    comps: dict[str, dict] = {}
    cur = None
    entry = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        ls = line.strip()
        if ls.endswith("{") and "->" in ls and not ls.startswith("%constant"):
            name = ls.split()[1] if ls.startswith("ENTRY") else ls.split()[0]
            name = name.split("(")[0].lstrip("%")
            cur = name
            comps[cur] = {"coll": defaultdict(int), "count": 0, "whiles": []}
            if ls.startswith("ENTRY"):
                entry = cur
            continue
        if cur is None or not ls:
            continue
        if ls == "}":
            continue
        cm = _COLL_RE.search(ls)
        if cm:
            comps[cur]["coll"][cm.group(1)] += _result_bytes(ls, cm.start(1))
            comps[cur]["count"] += 1
        wm = _WHILE_RE.search(ls)
        if wm:
            tm = _TRIP_RE.search(ls)
            n = int(tm.group(1)) if tm else 1
            comps[cur]["whiles"].append((wm.group(1), n))

    memo: dict[str, dict] = {}

    def eff(name: str, depth=0) -> dict:
        if name in memo:
            return memo[name]
        if depth > 10 or name not in comps:
            return {"coll": {}, "count": 0}
        c = comps[name]
        out = dict(c["coll"])
        cnt = c["count"]
        for body, n in c["whiles"]:
            sub = eff(body, depth + 1)
            for k, v in sub["coll"].items():
                out[k] = out.get(k, 0) + v * n
            cnt += sub["count"] * n
        memo[name] = {"coll": out, "count": cnt}
        return memo[name]

    res = eff(entry) if entry else {"coll": {}, "count": 0}
    out = {k: 0 for k in _KINDS}
    out.update(res["coll"])
    out["count"] = res["count"]
    return out
