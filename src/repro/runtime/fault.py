"""Fault tolerance & elasticity for 1000+-node runs.

Pieces that run in this container (and are tested):
  * **Heartbeat tracking + straggler detection** over node progress reports
    (robust z-score over step latencies);
  * **Restart planning**: given surviving node counts, recompute the mesh
    shape (shrink the data axis, keep "model" intact — TP groups must stay
    whole), pick the checkpoint to restore;
  * **Storage-failure handling**: delivered by the chaos engine
    (`repro.chaos`: `NodeFaultModel` failure domains, mirrored-session
    degradation, pool self-healing on `RetryPolicy` backoff); this module
    supplies the heartbeat/straggler/`revive` primitives its repair path
    builds on.

On a real cluster the heartbeats come from per-host agents; here they are
driven by the training driver / tests.
"""

from __future__ import annotations

import dataclasses
import math
import random
import time
from typing import Callable, Optional

import numpy as np


@dataclasses.dataclass
class NodeHealth:
    node_id: str
    last_beat: float
    step_times: list = dataclasses.field(default_factory=list)
    alive: bool = True


class HeartbeatMonitor:
    """Tracks per-node liveness over an injectable clock.

    ``clock`` is the monitor's time source for everything — the initial
    ``last_beat`` stamps, beats, and deadness checks — and defaults to
    ``time.monotonic()`` for real-cluster agents. Virtual-clock callers
    (the workflow orchestrator above all) MUST inject their own source
    (``clock=lambda: engine.now``): a monitor built on the wall clock but
    queried with virtual ``now`` values silently marks every node dead
    (monotonic stamps dwarf small virtual times) or never dead (the other
    way around). See :meth:`repro.orchestrator.Orchestrator.heartbeat_monitor`.
    """

    def __init__(
        self,
        nodes: list[str],
        *,
        timeout_s: float = 60.0,
        clock: Optional[Callable[[], float]] = None,
    ):
        self.timeout = timeout_s
        self._clock = clock if clock is not None else time.monotonic
        self.nodes = {n: NodeHealth(n, last_beat=self._clock()) for n in nodes}

    def beat(self, node_id: str, step_time_s: Optional[float] = None,
             now: Optional[float] = None) -> None:
        h = self.nodes[node_id]
        h.last_beat = now if now is not None else self._clock()
        if step_time_s is not None:
            h.step_times.append(step_time_s)
            del h.step_times[:-50]

    def dead_nodes(self, now: Optional[float] = None) -> list[str]:
        now = now if now is not None else self._clock()
        out = []
        for h in self.nodes.values():
            if h.alive and now - h.last_beat > self.timeout:
                h.alive = False
            if not h.alive:
                out.append(h.node_id)
        return out

    def revive(self, node_id: str, now: Optional[float] = None) -> None:
        """Bring a repaired node back into the fleet (the chaos repair
        path): fresh heartbeat stamp, stale step-time samples dropped — a
        node returning from repair must not inherit its pre-failure
        latencies into straggler detection."""
        h = self.nodes[node_id]
        h.alive = True
        h.last_beat = now if now is not None else self._clock()
        h.step_times.clear()

    def stragglers(self, *, z: float = 3.0, min_samples: int = 5,
                   now: Optional[float] = None) -> list[str]:
        """Nodes whose median step time is a robust outlier vs the fleet.

        Deadness is refreshed first so timed-out nodes are excluded from
        both the fleet median and the candidate set: a node that stopped
        beating but was never observed through :meth:`dead_nodes` must
        neither drag the median nor be reported as merely "slow" when it
        is in fact gone.
        """
        self.dead_nodes(now)
        meds = {
            n: float(np.median(h.step_times))
            for n, h in self.nodes.items()
            if h.alive and len(h.step_times) >= min_samples
        }
        if len(meds) < 3:
            return []
        vals = np.array(list(meds.values()))
        med = np.median(vals)
        mad = np.median(np.abs(vals - med)) + 1e-9
        return [n for n, v in meds.items() if (v - med) / (1.4826 * mad) > z]


@dataclasses.dataclass(frozen=True)
class RestartPlan:
    mesh_shape: tuple[int, ...]
    mesh_axes: tuple[str, ...]
    restore_step: Optional[int]
    dropped_nodes: tuple[str, ...]


def plan_restart(
    *,
    alive_chips: int,
    model_parallel: int,
    committed_steps: list[int],
    dropped_nodes: tuple[str, ...] = (),
    pods: int = 1,
) -> RestartPlan:
    """Shrink the data axis to what the surviving chips support; "model"
    groups are kept whole (a TP group with a dead member is dropped)."""
    if alive_chips < model_parallel:
        raise RuntimeError("fewer chips than one model-parallel group")
    groups = alive_chips // model_parallel
    if pods > 1 and groups % pods == 0:
        shape = (pods, groups // pods, model_parallel)
        axes = ("pod", "data", "model")
    else:
        shape = (groups, model_parallel)
        axes = ("data", "model")
    return RestartPlan(
        mesh_shape=shape,
        mesh_axes=axes,
        restore_step=committed_steps[-1] if committed_steps else None,
        dropped_nodes=dropped_nodes,
    )


# --------------------------------------------------------------------------
# Per-phase fault injection for the workflow orchestrator
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """Per-phase trip probabilities for a provisioning workflow.

    Each probability is the chance that the named lifecycle phase fails on
    a given attempt (deploy daemon crash, staging transfer error, node loss
    mid-run). Deterministic under ``seed`` so campaigns are reproducible.
    """

    provision_fail_p: float = 0.0
    stage_in_fail_p: float = 0.0
    run_fail_p: float = 0.0
    stage_out_fail_p: float = 0.0
    #: per-attempt trip probability for one *task* inside a pilot (the
    #: in-pilot scheduler consults phase "task" once per completed attempt;
    #: plain jobs never draw from it)
    task_fail_p: float = 0.0
    seed: int = 0

    def __post_init__(self) -> None:
        for f in ("provision_fail_p", "stage_in_fail_p", "run_fail_p",
                  "stage_out_fail_p", "task_fail_p"):
            p = getattr(self, f)
            if not 0.0 <= p <= 1.0:
                raise ValueError(f"{f} must be in [0, 1], got {p}")


class FaultInjector:
    """Seeded coin-flipper the orchestrator consults at each phase boundary."""

    _PHASE_FIELDS = {
        "provision": "provision_fail_p",
        "stage_in": "stage_in_fail_p",
        "run": "run_fail_p",
        "stage_out": "stage_out_fail_p",
        "task": "task_fail_p",
    }

    def __init__(self, spec: FaultSpec | None = None):
        self.spec = spec or FaultSpec()
        self._rng = random.Random(self.spec.seed)
        self.trips: list[tuple[str, str]] = []     # (job_name, phase)

    @property
    def any_faults(self) -> bool:
        """Is there any probability mass at all? Campaign engines skip the
        per-phase coin flip for stock fault-free injectors — a zero
        probability never consumes a random draw, so the skip is
        behavior-identical."""
        s = self.spec
        return (
            s.provision_fail_p > 0.0
            or s.stage_in_fail_p > 0.0
            or s.run_fail_p > 0.0
            or s.stage_out_fail_p > 0.0
            or s.task_fail_p > 0.0
        )

    def trip(self, job_name: str, phase: str) -> bool:
        """Does ``phase`` of ``job_name`` fail on this attempt?"""
        try:
            field = self._PHASE_FIELDS[phase]
        except KeyError:
            valid = ", ".join(sorted(self._PHASE_FIELDS))
            raise ValueError(
                f"unknown phase {phase!r}: valid phases are {valid}"
            ) from None
        p = getattr(self.spec, field)
        tripped = p > 0.0 and self._rng.random() < p
        if tripped:
            self.trips.append((job_name, phase))
        return tripped
