"""Distributed step builders: train_step / prefill / decode_step, jitted with
explicit NamedShardings over the production mesh.

The train step is ZeRO-1-ready (optimizer state shardings extend over the
"data" axis) with optional int8+error-feedback gradient compression and a
remat policy knob. Buffers are donated (params/opt-state update in place).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .. import optim
from ..hints import constrain, mesh_hint
from ..models.common import Model
from . import sharding as sh


@dataclasses.dataclass(frozen=True)
class RuntimeConfig:
    remat: Optional[str] = "full"          # None | "dots" | "full"
    use_kernels: bool = False              # Pallas kernels (TPU) vs jnp ref
    compress_grads: bool = False           # int8 + error feedback
    zero1: bool = True                     # shard opt state over "data"
    donate: bool = True
    accum: int = 1                         # gradient-accumulation microbatches
    flags: tuple = ()                      # trace-time variant switches (hints.flag)
    schedule: str = "warmup_cosine"
    opt: optim.AdamWConfig = dataclasses.field(default_factory=optim.AdamWConfig)


class TrainState(NamedTuple):
    params: Any
    opt: optim.OptState
    ef: Any                                # ErrorFeedback | () when disabled


def make_train_state(model: Model, rng, rt: RuntimeConfig) -> TrainState:
    params = model.init(rng)
    opt = optim.init(params)
    ef = optim.ef_init(params) if rt.compress_grads else ()
    return TrainState(params, opt, ef)


def train_state_shardings(mesh: Mesh, state_like: TrainState, rt: RuntimeConfig):
    ps = sh.param_shardings(mesh, state_like.params)
    os_ = sh.opt_shardings(mesh, state_like.opt, ps, zero1=rt.zero1)
    if rt.compress_grads:
        ef = optim.ErrorFeedback(
            jax.tree.map(lambda s: s, os_.m)  # residuals shadow m's sharding
        )
    else:
        ef = ()
    return TrainState(ps, os_, ef)


def _schedule(rt: RuntimeConfig) -> Callable:
    if rt.schedule == "warmup_cosine":
        return optim.warmup_cosine
    return optim.constant


def make_train_step(model: Model, rt: RuntimeConfig) -> Callable:
    sched = _schedule(rt)

    def grads_of(params, batch):
        def loss_fn(p):
            return model.loss(p, batch, remat=rt.remat, use_kernels=rt.use_kernels)

        return jax.value_and_grad(loss_fn, has_aux=True)(params)

    def train_step(state: TrainState, batch):
        if rt.accum > 1:
            # microbatch over the leading batch dim: activation memory / accum
            def split(x):
                return x.reshape(rt.accum, x.shape[0] // rt.accum, *x.shape[1:])

            micro = jax.tree.map(split, batch)
            micro = jax.tree.map(
                lambda x: constrain(x, None, "dp"), micro
            )
            # fp32 accumulator is 4 bytes/param sharded over "model" only —
            # 2x8.2 GB/device for a 32B model. accbf16 halves it (loss-scale
            # safe at accum<=8; see EXPERIMENTS.md §Perf B).
            acc_dt = jnp.bfloat16 if "accbf16" in rt.flags else jnp.float32
            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, acc_dt), state.params
            )

            def body(carry, mb):
                acc, loss_acc = carry
                (loss, _), g = grads_of(state.params, mb)
                acc = jax.tree.map(
                    lambda a, gg: a + gg.astype(acc_dt), acc, g
                )
                return (acc, loss_acc + loss), None

            (gsum, loss_sum), _ = jax.lax.scan(body, (g0, 0.0), micro)
            grads = jax.tree.map(lambda g: g / rt.accum, gsum)
            loss = loss_sum / rt.accum
            metrics = {"ce": loss, "aux": jnp.zeros(())}
        else:
            (loss, metrics), grads = grads_of(state.params, batch)

        ef = state.ef
        if rt.compress_grads:
            grads, ef, _ = optim.compress_grads(grads, state.ef)
        params, opt, stats = optim.update(
            grads, state.opt, state.params, rt.opt, lr_scale=sched(state.opt.step)
        )
        out_metrics = {"loss": loss, **metrics, **stats}
        return TrainState(params, opt, ef), out_metrics

    return train_step


def jit_train_step(
    model: Model,
    mesh: Mesh,
    rt: RuntimeConfig,
    state_like: TrainState,
    batch_like: dict,
):
    """Returns (jitted_step, state_shardings, batch_shardings)."""
    st_sh = train_state_shardings(mesh, state_like, rt)
    b_sh = sh.batch_shardings(mesh, batch_like)
    metric_sh = NamedSharding(mesh, P())
    raw_step = make_train_step(model, rt)

    def hinted(state, batch):
        with mesh_hint(mesh, rt.flags):
            return raw_step(state, batch)

    step = jax.jit(
        hinted,
        in_shardings=(st_sh, b_sh),
        out_shardings=(st_sh, None),
        donate_argnums=(0,) if rt.donate else (),
    )
    return step, st_sh, b_sh


# -- serving -----------------------------------------------------------------
def make_prefill(model: Model, S_max: int, rt: RuntimeConfig) -> Callable:
    def prefill(params, batch):
        return model.prefill(params, batch, S_max, use_kernels=rt.use_kernels)

    return prefill


def make_decode_step(model: Model, rt: RuntimeConfig) -> Callable:
    def decode(params, cache, batch):
        return model.decode_step(params, cache, batch, use_kernels=rt.use_kernels)

    return decode


def jit_decode_step(
    model: Model,
    mesh: Mesh,
    rt: RuntimeConfig,
    params_like,
    cache_like,
    batch_like,
):
    if "dp_decode" in rt.flags:
        # small-model serving: replicate weights, shard batch only — no
        # model-axis decisions left to GSPMD (see EXPERIMENTS.md §Perf C)
        p_sh = sh.replicated(mesh, params_like)
    else:
        p_sh = sh.param_shardings(mesh, params_like)
    c_sh = sh.cache_shardings(mesh, cache_like, model.cfg)
    b_sh = sh.batch_shardings(mesh, batch_like)
    raw_step = make_decode_step(model, rt)

    def hinted(params, cache, batch):
        with mesh_hint(mesh, rt.flags):
            return raw_step(params, cache, batch)

    step = jax.jit(
        hinted,
        in_shardings=(p_sh, c_sh, b_sh),
        out_shardings=(None, c_sh),
        donate_argnums=(1,) if rt.donate else (),
    )
    return step, p_sh, c_sh, b_sh


def jit_prefill(
    model: Model,
    mesh: Mesh,
    rt: RuntimeConfig,
    S_max: int,
    params_like,
    batch_like,
    cache_like,
):
    p_sh = sh.param_shardings(mesh, params_like)
    b_sh = sh.batch_shardings(mesh, batch_like)
    c_sh = sh.cache_shardings(mesh, cache_like, model.cfg)
    raw_step = make_prefill(model, S_max, rt)

    def hinted(params, batch):
        with mesh_hint(mesh, rt.flags):
            return raw_step(params, batch)

    step = jax.jit(
        hinted,
        in_shardings=(p_sh, b_sh),
        out_shardings=(None, c_sh),
    )
    return step, p_sh, b_sh, c_sh
