import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes, extract memory/cost/collective numbers for §Roofline.

Usage:
  python -m repro.launch.dryrun --arch phi4-mini-3.8b --shape train_4k
  python -m repro.launch.dryrun --arch all                # every cell
  python -m repro.launch.dryrun ... --multi-pod           # (2,16,16) mesh
  python -m repro.launch.dryrun ... --variant zero1=off,remat=full

Artifacts land in artifacts/dryrun/<arch>__<shape>__<mesh>[__<variant>].json.
"""

import argparse
import dataclasses
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from ..configs import ShapeSpec, get_config, shapes_for
from ..core.perfmodel import TPU_V5E
from ..models import build_model
from ..runtime import (
    RuntimeConfig,
    jit_decode_step,
    jit_prefill,
    jit_train_step,
    make_train_state,
)
from ..runtime.costs import hlo_collective_bytes, jaxpr_costs
from ..runtime.parallel import make_decode_step, make_prefill, make_train_step
from .mesh import make_production_mesh


def parse_variant(s: str) -> dict:
    out = {}
    if not s:
        return out
    for kv in s.split(","):
        k, _, v = kv.partition("=")
        out[k.strip()] = v.strip()
    return out


def runtime_from_variant(var: dict, shape_kind: str) -> RuntimeConfig:
    # default for train cells: accum=4 (activation memory / 4)
    rt = RuntimeConfig(accum=4 if shape_kind == "train" else 1)
    if "remat" in var:
        rt = dataclasses.replace(rt, remat=None if var["remat"] == "none" else var["remat"])
    if "accum" in var:
        rt = dataclasses.replace(rt, accum=int(var["accum"]))
    if var.get("zero1") == "off":
        rt = dataclasses.replace(rt, zero1=False)
    if var.get("compress") == "on":
        rt = dataclasses.replace(rt, compress_grads=True)
    flags = tuple(k for k in ("moe2d", "dp_decode", "accbf16", "bf16bwd") if var.get(k) == "on")
    if flags:
        rt = dataclasses.replace(rt, flags=flags)
    return rt


def _pad16(n: int) -> int:
    return ((n + 15) // 16) * 16


def config_from_variant(arch: str, var: dict):
    """Variant-level config transforms (beyond-paper structural changes)."""
    cfg = get_config(arch)
    if var.get("padheads") == "on":
        # pad query heads to a multiple of the model axis so attention stays
        # head-sharded (zero wo rows for pad heads make this exact in prod)
        cfg = dataclasses.replace(cfg, n_heads=_pad16(cfg.n_heads))
    if "capacity" in var:
        cfg = dataclasses.replace(cfg, moe_capacity_factor=float(var["capacity"]))
    return cfg


def lower_cell(arch: str, shape: ShapeSpec, mesh, rt: RuntimeConfig, var=None):
    """Returns (lowered, compiled, algorithmic_costs) for the cell's step."""
    cfg = config_from_variant(arch, var or {})
    model = build_model(cfg)
    specs = model.input_specs(shape)
    rng_sds = jax.ShapeDtypeStruct((2,), jnp.uint32)

    if shape.kind == "train":
        state_sds = jax.eval_shape(
            lambda r: make_train_state(model, r, rt), rng_sds
        )
        step, st_sh, b_sh = jit_train_step(model, mesh, rt, state_sds, specs)
        lowered = step.lower(state_sds, specs)
        alg = jaxpr_costs(jax.make_jaxpr(make_train_step(model, rt))(state_sds, specs))
    elif shape.kind == "prefill":
        params_sds = jax.eval_shape(model.init, rng_sds)
        # VLM prompts carry a patch-embedding prefix on top of seq_len
        s_max = shape.seq_len + (cfg.n_patches if cfg.family == "vlm" else 0)
        cache_sds = jax.eval_shape(
            lambda: model.init_cache(shape.global_batch, s_max)
        )
        step, *_ = jit_prefill(
            model, mesh, rt, s_max, params_sds, specs, cache_sds
        )
        lowered = step.lower(params_sds, specs)
        alg = jaxpr_costs(
            jax.make_jaxpr(make_prefill(model, s_max, rt))(params_sds, specs)
        )
    else:  # decode: one token against a seq_len-deep cache
        params_sds = jax.eval_shape(model.init, rng_sds)
        cache_sds = jax.eval_shape(
            lambda: model.init_cache(shape.global_batch, shape.seq_len)
        )
        step, *_ = jit_decode_step(model, mesh, rt, params_sds, cache_sds, specs)
        lowered = step.lower(params_sds, cache_sds, specs)
        alg = jaxpr_costs(
            jax.make_jaxpr(make_decode_step(model, rt))(params_sds, cache_sds, specs)
        )
    compiled = lowered.compile()
    return lowered, compiled, alg


def run_cell(arch: str, shape: ShapeSpec, *, multi_pod: bool, variant: str,
             out_dir: str) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    var = parse_variant(variant)
    rt = runtime_from_variant(var, shape.kind)
    t0 = time.time()
    lowered, compiled, alg = lower_cell(arch, shape, mesh, rt, var)
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = hlo_collective_bytes(hlo)          # per-device, trip-count aware

    # algorithmic (jaxpr-walk) flops/bytes are GLOBAL; divide by chips.
    # (XLA's cost_analysis counts scan bodies once -> kept as cross-check.)
    flops_dev = alg["flops"] / chips
    # memory term: dot operand/result traffic is the post-fusion floor of HBM
    # bytes; the all-ops estimate is the no-fusion ceiling. See §Roofline.
    dot_bytes_dev = alg["dot_bytes"] / chips
    bytes_dev = alg["bytes"] / chips
    comm = sum(v for k, v in coll.items() if k != "count")

    hw = TPU_V5E
    terms = {
        "compute_s": flops_dev / hw.peak_flops_bf16,
        "memory_s": dot_bytes_dev / hw.hbm_bw,
        "memory_s_upper": bytes_dev / hw.hbm_bw,
        "collective_s": comm / hw.ici_link_bw,
        "collective_bytes_per_dev": comm,
    }

    cfg = get_config(arch)
    n_params = cfg.param_count()
    n_active = cfg.active_param_count()
    tokens = shape.global_batch * (
        shape.seq_len if shape.kind in ("train", "prefill") else 1
    )
    mult = 6 if shape.kind == "train" else 2
    model_flops_global = mult * n_active * tokens
    model_flops_per_dev = model_flops_global / chips

    dominant = max(
        ("compute_s", "memory_s", "collective_s"), key=lambda k: terms[k]
    )
    bound_s = max(terms["compute_s"], terms["memory_s"], terms["collective_s"])
    rec = {
        "arch": arch,
        "shape": shape.name,
        "kind": shape.kind,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips,
        "variant": variant or "baseline",
        "compile_s": round(t_compile, 1),
        "alg_flops_global": alg["flops"],
        "alg_bytes_global": alg["bytes"],
        "alg_dot_bytes_global": alg["dot_bytes"],
        "xla_flops_per_dev_scan_once": float(cost.get("flops", 0.0)),
        "xla_bytes_per_dev_scan_once": float(cost.get("bytes accessed", 0.0)),
        "collectives": coll,
        "terms": terms,
        "dominant": dominant,
        # fraction of roofline if the dominant term were perfectly overlapped
        "roofline_fraction": (terms["compute_s"] / bound_s) if bound_s else None,
        "model_flops_global": model_flops_global,
        "model_flops_per_dev": model_flops_per_dev,
        "useful_flops_ratio": model_flops_global / alg["flops"] if alg["flops"] else None,
        "params": n_params,
        "active_params": n_active,
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_ok_16GiB": (
                (getattr(mem, "argument_size_in_bytes", 0) or 0)
                + (getattr(mem, "temp_size_in_bytes", 0) or 0)
            ) < 16 * (1 << 30),
        },
    }
    os.makedirs(out_dir, exist_ok=True)
    suffix = f"__{variant.replace('=', '-').replace(',', '_')}" if variant else ""
    fname = f"{arch}__{shape.name}__{rec['mesh']}{suffix}.json"
    with open(os.path.join(out_dir, fname), "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, help="arch id or 'all'")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--variant", default="")
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()

    from ..configs import ARCH_IDS

    archs = list(ARCH_IDS) if args.arch == "all" else [args.arch]
    failures = []
    for arch in archs:
        for shape in shapes_for(arch):
            if args.shape != "all" and shape.name != args.shape:
                continue
            try:
                rec = run_cell(arch, shape, multi_pod=args.multi_pod,
                               variant=args.variant, out_dir=args.out)
                t = rec["terms"]
                print(
                    f"OK  {arch:22s} {shape.name:12s} {rec['mesh']:8s} "
                    f"compile={rec['compile_s']}s "
                    f"comp={t['compute_s']*1e3:.2f}ms mem={t['memory_s']*1e3:.2f}ms "
                    f"coll={t['collective_s']*1e3:.2f}ms dom={rec['dominant']} "
                    f"useful={rec['useful_flops_ratio'] and round(rec['useful_flops_ratio'],3)}",
                    flush=True,
                )
            except Exception as e:  # noqa: BLE001 - report and continue
                failures.append((arch, shape.name, repr(e)))
                traceback.print_exc()
                print(f"FAIL {arch} {shape.name}: {e}", flush=True)
    if failures:
        print(f"{len(failures)} failures:", failures)
        sys.exit(1)


if __name__ == "__main__":
    main()
