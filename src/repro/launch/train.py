"""End-to-end training driver: storage provisioning + data staging +
distributed train loop + burst checkpointing + fault-tolerant restart.

This is the paper's workflow as a training job:
  1. request compute + storage allocations (scheduler);
  2. provision the EphemeralFS on the granted storage nodes;
  3. stage the corpus in from the global FS;
  4. train with periodic checkpoints to the burst tier, drained to the
     global FS in the background;
  5. on restart (--resume), restore the newest committed checkpoint.

CPU-friendly by design: defaults are a tiny config on a 1-device mesh;
``--arch`` selects any assigned architecture (smoke variant with --smoke).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import CheckpointManager
from ..configs import get_config, get_smoke
from ..core import (
    GlobalFS,
    JobRequest,
    Provisioner,
    Scheduler,
    StorageRequest,
    dom_cluster,
    size_for_checkpoint,
)
from ..data import DatasetSpec, Loader, stage_in, write_corpus
from ..models import build_model
from ..optim import AdamWConfig
from ..runtime import RuntimeConfig, TrainState, make_train_state, make_train_step


def tree_bytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-moe-1b-a400m")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--storage-nodes", type=int, default=2)
    args = ap.parse_args(argv)

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    rt = RuntimeConfig(remat="dots", zero1=False,
                       opt=AdamWConfig(lr=args.lr), schedule="warmup_cosine")

    # -- storage provisioning (the paper's §III flow) -----------------------
    cluster = dom_cluster()
    sched = Scheduler(cluster)
    state = make_train_state(model, jax.random.PRNGKey(args.seed), rt)
    ckpt_bytes = tree_bytes(state.params) + tree_bytes(state.opt.master) * 3
    storage_req = StorageRequest(nodes=args.storage_nodes)
    alloc = sched.submit(JobRequest("train-lm", n_compute=8, storage=storage_req))
    prov = Provisioner(cluster)
    dep = prov.deploy(prov.plan_for(alloc))
    print(f"[provision] {len(alloc.storage_nodes)} storage nodes, "
          f"modeled deploy {dep.deploy_time_s:.2f}s "
          f"(ckpt size {ckpt_bytes/1e6:.1f} MB)")

    gfs = GlobalFS()
    spec = DatasetSpec(seed=7, vocab=cfg.vocab_size,
                       n_tokens=max(1 << 18, args.batch * (args.seq + 1) * 4))
    write_corpus(gfs, "/datasets/train", spec)
    rep = stage_in(gfs, dep.fs, "/datasets/train", "/data",
                   src_model=gfs.perf_view(), dst_model=dep.model)
    print(f"[stage-in] {rep.files} files, {rep.bytes/1e6:.1f} MB, "
          f"modeled {rep.modeled_time_s:.2f}s")

    loader = Loader(spec, batch=args.batch, seq=args.seq, fs=dep.fs, root="/data")
    mgr = CheckpointManager(dep.fs, global_fs=gfs)

    # -- resume -------------------------------------------------------------
    start_step = 0
    if args.resume and mgr.steps():
        restored, start_step = mgr.restore({"params": state.params, "opt": state.opt})
        state = TrainState(restored["params"], restored["opt"], state.ef)
        print(f"[resume] restored committed step {start_step}")

    step_fn = jax.jit(make_train_step(model, rt), donate_argnums=(0,))
    eval_fn = jax.jit(lambda p, b: model.loss(p, b)[0])

    def to_jax(batch):
        jbatch = {k: jnp.asarray(v) for k, v in batch.items()}
        if cfg.family == "vlm":
            jbatch["patch_embeds"] = jnp.zeros(
                (args.batch, cfg.n_patches, cfg.d_model), jnp.float32)
        if cfg.family == "audio":
            jbatch["frames"] = jnp.zeros(
                (args.batch, cfg.encoder_seq, cfg.d_model), jnp.float32)
        return jbatch

    eval_batch = to_jax(loader.batch_at(0))
    eval_before = float(eval_fn(state.params, eval_batch))

    losses = []
    t0 = time.time()
    for step in range(start_step, args.steps):
        jbatch = to_jax(loader.batch_at(step))
        state, metrics = step_fn(state, jbatch)
        loss = float(metrics["loss"])
        losses.append(loss)
        if (step + 1) % args.ckpt_every == 0 or step + 1 == args.steps:
            man = mgr.save(step + 1, {"params": state.params, "opt": state.opt})
            drain = mgr.drain_to_global(step + 1)
            print(f"[ckpt] step {step+1}: {man['total_bytes']/1e6:.1f} MB to burst; "
                  f"drain modeled {drain['modeled_time_s']:.3f}s")
        if step % 5 == 0 or step + 1 == args.steps:
            print(f"step {step:4d} loss {loss:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f}")

    wall = time.time() - t0
    eval_after = float(eval_fn(state.params, eval_batch))
    print(f"[done] {args.steps - start_step} steps in {wall:.1f}s; "
          f"held-batch loss {eval_before:.4f} -> {eval_after:.4f}")

    result = {
        "losses": losses,
        "eval_before": eval_before,
        "eval_after": eval_after,
        "steps": mgr.steps(),
        "deploy_time_s": dep.deploy_time_s,
        "improved": eval_after < eval_before,
    }
    dep.teardown()
    sched.release(alloc)
    return result


if __name__ == "__main__":
    main()
