"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state. The dry-run entrypoint sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; real launches get devices from the TPU runtime.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = 1
    for s in shape:
        need *= s
    devices = jax.devices()
    if len(devices) < need:
        raise RuntimeError(
            f"mesh {shape} needs {need} devices, have {len(devices)} "
            "(dry-runs must set XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "before importing jax)"
        )
    return jax.make_mesh(shape, axes, devices=devices[:need])


def make_smoke_mesh(data: int = 2, model: int = 2):
    """Small mesh for CPU multi-device tests (subprocess-scoped XLA_FLAGS)."""
    need = data * model
    return jax.make_mesh((data, model), ("data", "model"),
                         devices=jax.devices()[:need])
