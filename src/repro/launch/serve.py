"""Serving driver: model weights staged through dynamically provisioned
storage, then batched prefill + decode.

The serving-side use of the paper's mechanism: at scale, thousands of
serving replicas hammering the global FS for weight loads is the same
burst problem as checkpoint writes — so weights are staged ONCE from the
global FS into a job-scoped EphemeralFS and every local replica loads from
the burst tier (modeled time reported), then requests are decoded with a
KV cache.

Run:  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-14b --requests 8
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..checkpoint import CheckpointManager
from ..configs import get_config, get_smoke
from ..core import (
    GlobalFS,
    JobRequest,
    Provisioner,
    Scheduler,
    StorageRequest,
    Workload,
    dom_cluster,
    predict_read,
)
from ..models import build_model
from ..runtime import RuntimeConfig


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-14b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)

    # -- publish weights to the global FS (the model registry) --------------
    gfs = GlobalFS()
    params = model.init(jax.random.PRNGKey(args.seed))
    pub = CheckpointManager(gfs, root="/registry/models")
    man = pub.save(0, {"params": params})
    print(f"[registry] published {man['total_bytes']/1e6:.1f} MB to global FS")

    # -- provision burst tier, stage weights in, load from burst ------------
    cluster = dom_cluster()
    sched = Scheduler(cluster)
    alloc = sched.submit(JobRequest("serve", 8, storage=StorageRequest(nodes=2)))
    prov = Provisioner(cluster)
    dep = prov.deploy(prov.plan_for(alloc))
    burst = CheckpointManager(dep.fs, root="/weights", global_fs=gfs)
    # stage: global -> burst (one read of the registry feeds all replicas)
    from ..core.staging import stage_tree
    rep = stage_tree(gfs, dep.fs, "/registry/models/step-00000000",
                     "/weights/step-00000000",
                     src_model=gfs.perf_view(), dst_model=dep.model)
    loaded, step = burst.restore({"params": params})
    # modeled: 256 hosts each reading the weights from the burst tier (FPP)
    w = Workload(n_procs=256, size_per_proc=man["total_bytes"], pattern="fpp")
    t_all = predict_read(w, dep.model).elapsed_s
    print(f"[stage-in] {rep.bytes/1e6:.1f} MB staged "
          f"(modeled {rep.modeled_time_s:.2f}s); 256-replica load from burst "
          f"modeled {t_all:.2f}s")
    params = loaded["params"]

    # -- serve ----------------------------------------------------------------
    B, P, G = args.requests, args.prompt_len, args.gen
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, P), 0, cfg.vocab_size)
    batch = {"tokens": prompts}
    if cfg.family == "vlm":
        batch["patch_embeds"] = jnp.zeros((B, cfg.n_patches, cfg.d_model))
    if cfg.family == "audio":
        batch["frames"] = jnp.zeros((B, cfg.encoder_seq, cfg.d_model))
    S_max = P + G + (cfg.n_patches if cfg.family == "vlm" else 0)

    prefill = jax.jit(lambda p, b: model.prefill(p, b, S_max))
    decode = jax.jit(model.decode_step, donate_argnums=(1,))
    t0 = time.perf_counter()
    logits, cache = prefill(params, batch)
    tok = jnp.argmax(logits, axis=-1)
    out = [tok]
    for _ in range(G - 1):
        logits, cache = decode(params, cache, {"token": tok})
        tok = jnp.argmax(logits, axis=-1)
        out.append(tok)
    tok.block_until_ready()
    dt = time.perf_counter() - t0
    gen = jnp.stack(out, axis=1)
    print(f"[serve] {B} requests x {G} tokens in {dt:.2f}s (CPU, incl. compile)")

    dep.teardown()
    sched.release(alloc)
    return {"generated": gen.shape, "stage_bytes": rep.bytes,
            "load_modeled_s": t_all}


if __name__ == "__main__":
    main()
