"""Deterministic exponential backoff shared by every self-healing path.

Pool backfill after a node loss and ``ProvisioningService`` session-open
retries both need the same thing: a bounded, *replayable* sequence of
retry delays. Wallclock-seeded jitter would break the repo's bit-for-bit
campaign determinism, so the jitter stream is seeded from
``f"{seed}:{key}"`` — string seeding hashes through SHA-512, which is
stable across processes and Python versions (unlike ``hash()``-based
object seeding). Same policy + same key -> same delays, forever.
"""

from __future__ import annotations

import dataclasses
import random
from typing import Callable, Optional


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded exponential backoff with seeded jitter.

    ``delays(key)`` yields at most ``max_attempts`` waits: attempt ``i``
    waits ``min(base_s * factor**i, max_delay_s)`` scaled by a jitter
    factor in ``[1, 1 + jitter]`` drawn from the key's stream. A
    ``deadline_s`` truncates the sequence where cumulative waiting would
    exceed it — a retry that could not start before the deadline is not
    offered at all.
    """

    max_attempts: int = 6
    base_s: float = 5.0
    factor: float = 2.0
    max_delay_s: float = 300.0
    deadline_s: Optional[float] = None
    jitter: float = 0.1
    seed: int = 0

    def __post_init__(self) -> None:
        if self.max_attempts < 1:
            raise ValueError(f"max_attempts must be >= 1, got {self.max_attempts}")
        if self.base_s <= 0:
            raise ValueError(f"base_s must be positive, got {self.base_s}")
        if self.factor < 1.0:
            raise ValueError(f"factor must be >= 1, got {self.factor}")
        if self.max_delay_s < self.base_s:
            raise ValueError("max_delay_s must be >= base_s")
        if not 0.0 <= self.jitter <= 1.0:
            raise ValueError(f"jitter must be in [0, 1], got {self.jitter}")
        if self.deadline_s is not None and self.deadline_s <= 0:
            raise ValueError("deadline_s must be positive (or None)")

    def delays(self, key: str) -> tuple[float, ...]:
        """The per-attempt wait sequence for ``key`` (deterministic)."""
        rng = random.Random(f"{self.seed}:{key}")
        out: list[float] = []
        elapsed = 0.0
        for i in range(self.max_attempts):
            d = min(self.base_s * self.factor**i, self.max_delay_s)
            if self.jitter:
                d *= 1.0 + self.jitter * rng.random()
            elapsed += d
            if self.deadline_s is not None and elapsed > self.deadline_s:
                break
            out.append(d)
        return tuple(out)


def drive_retries(
    engine,
    policy: RetryPolicy,
    key: str,
    attempt: Callable[[], bool],
    *,
    give_up: Optional[Callable[[], None]] = None,
) -> None:
    """Run ``attempt`` on ``policy``'s backoff cadence over a ``SimEngine``.

    ``attempt()`` returns True on success (stop) or False to back off and
    retry; after the policy's last delay is exhausted, ``give_up`` (if any)
    fires once. The engine is duck-typed (needs only ``after``), the first
    attempt already waits ``delays[0]`` — a failure was just observed *now*
    — and everything is pre-computed from ``(policy, key)``, so the retry
    trail replays bit-identically.
    """
    delays = policy.delays(key)

    def arm(i: int) -> None:
        if i >= len(delays):
            if give_up is not None:
                give_up()
            return
        engine.after(delays[i], lambda: arm(i + 1) if not attempt() else None)

    arm(0)
