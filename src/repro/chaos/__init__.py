"""Node-level chaos engine: failure domains on the virtual clock.

The paper's BeeGFS-over-DataWarp instance has a failure mode the rest of
this codebase ignored until now: storage *hardware* dies. PR 5 made
job-phase faults cheap (checkpoint-aware resume); this package supplies
the infrastructure fault domain underneath them —

* :class:`NodeFaultModel` — a seeded generator of node failure/repair
  events (exponential MTTF draws per node, repair after MTTR, plus
  optional scripted ``(t, node_id)`` kills). The orchestrator drains it
  through ordinary ``SimEngine`` events, so chaos campaigns stay
  deterministic and chaos-off campaigns schedule *nothing*.
* :class:`RetryPolicy` — deterministic exponential backoff with seeded
  jitter, shared by pool backfill and session-open retries.
* :func:`resolve_blast_radius` — maps a dead node to every live session,
  pool (and its leases), and serving replica touching it.

Everything here is duck-typed against the core/pool/serving objects and
imports none of them, so the chaos layer can never grow an import cycle
with the subsystems it breaks.
"""

from .blast import BlastRadius, resolve_blast_radius
from .faults import NodeEvent, NodeFaultModel
from .retry import RetryPolicy, drive_retries

__all__ = [
    "BlastRadius",
    "NodeEvent",
    "NodeFaultModel",
    "RetryPolicy",
    "drive_retries",
    "resolve_blast_radius",
]
