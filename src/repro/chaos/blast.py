"""Blast-radius resolver: what a dead storage node actually takes out.

One node id fans out along three edges, all resolved duck-typed so this
module imports nothing from the subsystems it inspects:

* **sessions** — live :class:`StorageSession` objects whose dedicated
  allocation (or whose PERSISTENT pool) includes the node. These are the
  deployments that degrade (mirror redundancy) or die (none).
* **pools** — :class:`StoragePool` objects whose allocation pins the
  node, plus every lease currently attached to them: striping puts every
  dataset on every node, so a pool node loss invalidates the pool's
  residency wholesale and its leaseholders with it.
* **replicas** — serving replicas whose lease points into an affected
  pool (or whose own session touches the node): their in-flight requests
  must abort back to the queue.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable


def _session_touches(session, node_id: str) -> bool:
    alloc = getattr(session, "allocation", None)
    if alloc is not None and any(
        n.node_id == node_id for n in alloc.storage_nodes
    ):
        return True
    pool = getattr(session, "pool", None)
    return pool is not None and node_id in pool.storage_node_ids


@dataclasses.dataclass(frozen=True)
class BlastRadius:
    """Everything touching one dead node, resolved at the failure instant."""

    node_id: str
    sessions: tuple                  # live StorageSessions on the node
    pools: tuple                     # StoragePools pinning the node
    leases: tuple                    # leases attached to those pools
    replicas: tuple                  # serving replicas in the fan-out

    @property
    def empty(self) -> bool:
        return not (self.sessions or self.pools or self.replicas)


def resolve_blast_radius(
    node_id: str,
    *,
    sessions: Iterable = (),
    pools: Iterable = (),
    replicas: Iterable = (),
) -> BlastRadius:
    """Resolve the fan-out of ``node_id`` over live objects.

    ``sessions``/``pools``/``replicas`` are whatever the caller has live:
    the orchestrator passes its active jobs' sessions and the pool
    manager's live pools; a serving campaign passes its replica fleet.
    """
    hit_pools = tuple(p for p in pools if node_id in p.storage_node_ids)
    pool_ids = {p.pool_id for p in hit_pools}
    hit_sessions = []
    for s in sessions:
        if _session_touches(s, node_id):
            hit_sessions.append(s)
        else:
            lease = getattr(s, "lease", None)
            if lease is not None and lease.pool_id in pool_ids:
                hit_sessions.append(s)
    hit_replicas = []
    for r in replicas:
        s = getattr(r, "session", None)
        if s is None:
            continue
        lease = getattr(s, "lease", None)
        if (lease is not None and lease.pool_id in pool_ids) or _session_touches(
            s, node_id
        ):
            hit_replicas.append(r)
    leases = tuple(
        lease for p in hit_pools for lease in p.leases.values()
    )
    return BlastRadius(
        node_id=node_id,
        sessions=tuple(hit_sessions),
        pools=hit_pools,
        leases=leases,
        replicas=tuple(hit_replicas),
    )
