"""Seeded node fault model: when storage nodes die and come back.

The model is *generated up front*: :meth:`NodeFaultModel.events` returns a
finite, sorted event list over an explicit horizon, which the orchestrator
bulk-schedules with ``engine.at_many``. Two properties follow directly:

* determinism — the same ``(seed, node set, horizon, schedule)`` always
  yields the same events, byte for byte, independent of campaign load
  (per-node streams are seeded ``random.Random(f"{seed}:{node_id}")``, so
  adding a node never perturbs another node's draws);
* termination — the engine heap always drains: there is no
  self-rescheduling failure loop, just a bounded batch of events.

Failures per node are an alternating renewal process: time-to-failure is
exponential with mean ``mttf_s`` (the memoryless hardware-failure model),
repair follows ``mttr_s`` later, and the next draw starts after the
repair. Scripted kills — the reproducible "pull *this* node at *this*
time" experiments the benchmarks and examples run — merge into the same
stream and get the same repair-after-MTTR treatment. Overlapping windows
(a scripted kill landing inside a drawn outage) are legal; the consumer's
down/repair handlers are idempotent, so a duplicate "down" is a no-op and
the earliest "up" at-or-after both ends the outage.
"""

from __future__ import annotations

import dataclasses
import random
from typing import Iterable, Optional, Sequence


@dataclasses.dataclass(frozen=True)
class NodeEvent:
    """One scheduled state change for one storage node."""

    t: float
    node_id: str
    kind: str                    # "down" | "up"

    def __post_init__(self) -> None:
        if self.kind not in ("down", "up"):
            raise ValueError(f"kind must be 'down' or 'up', got {self.kind!r}")
        if self.t < 0:
            raise ValueError(f"event time must be >= 0, got {self.t}")


class NodeFaultModel:
    """Deterministic storage-node failure/repair schedule.

    Parameters
    ----------
    node_ids:
        The storage nodes in the fault domain (typically every storage
        node id of the cluster). Order does not matter — draws are keyed
        by id, not position.
    mttf_s:
        Mean time to failure for the exponential draws; ``None`` disables
        random failures (scripted kills only).
    mttr_s:
        Repair time: every failure (drawn or scripted) is followed by an
        "up" event ``mttr_s`` later.
    horizon_s:
        Failures are only generated strictly before this time (repairs
        may land after it). Bounds the event batch; with ``mttf_s`` set
        this must be positive.
    seed:
        Base seed; per-node streams derive from ``f"{seed}:{node_id}"``.
    schedule:
        Scripted ``(t, node_id)`` kills merged into the stream.
    """

    def __init__(
        self,
        node_ids: Iterable[str],
        *,
        mttf_s: Optional[float] = None,
        mttr_s: float = 600.0,
        horizon_s: float = 0.0,
        seed: int = 0,
        schedule: Sequence[tuple[float, str]] = (),
    ):
        self.node_ids = tuple(node_ids)
        if mttf_s is not None and mttf_s <= 0:
            raise ValueError(f"mttf_s must be positive, got {mttf_s}")
        if mttr_s <= 0:
            raise ValueError(f"mttr_s must be positive, got {mttr_s}")
        if mttf_s is not None and horizon_s <= 0:
            raise ValueError("random failures (mttf_s) need a positive horizon_s")
        known = set(self.node_ids)
        for t, nid in schedule:
            if nid not in known:
                raise ValueError(f"scripted kill for unknown node {nid!r}")
            if t < 0:
                raise ValueError(f"scripted kill at negative time {t}")
        self.mttf_s = mttf_s
        self.mttr_s = mttr_s
        self.horizon_s = horizon_s
        self.seed = seed
        self.schedule = tuple(schedule)

    @property
    def any_faults(self) -> bool:
        """False iff this model can never emit an event — the orchestrator
        treats such a model exactly like no model at all (chaos off)."""
        return bool(self.schedule) or self.mttf_s is not None

    def events(self) -> list[NodeEvent]:
        """The full failure/repair schedule, sorted by ``(t, node_id)``
        with repairs before failures at equal instants (a node swapping
        down->up at one instant frees before the next kill lands)."""
        out: list[NodeEvent] = []
        mttf, mttr = self.mttf_s, self.mttr_s
        if mttf is not None:
            for nid in sorted(self.node_ids):
                rng = random.Random(f"{self.seed}:{nid}")
                t = rng.expovariate(1.0 / mttf)
                while t < self.horizon_s:
                    out.append(NodeEvent(t, nid, "down"))
                    t += mttr
                    out.append(NodeEvent(t, nid, "up"))
                    t += rng.expovariate(1.0 / mttf)
        for t, nid in self.schedule:
            out.append(NodeEvent(t, nid, "down"))
            out.append(NodeEvent(t + mttr, nid, "up"))
        out.sort(key=lambda e: (e.t, e.node_id, 0 if e.kind == "up" else 1))
        return out
