"""Pilot-style many-task execution (two-level scheduling).

Top level: one :class:`PilotSpec` job acquires a compute block plus one
pooled storage session through the ordinary orchestrator path
(``Orchestrator.submit_pilot``). Bottom level: the in-pilot
:class:`TaskScheduler` packs thousands of sub-node :class:`TaskSpec` s into
the pilot's slots, prices whole waves through the session's performance
model, and coalesces completions so the engine sees O(1) amortized events
per batch instead of a full job lifecycle per task.
"""

from .run import PilotRun, PilotSpec
from .scheduler import TaskScheduler, TaskStats
from .task import (
    STATE_NAMES,
    T_DONE,
    T_FAILED,
    T_PENDING,
    T_RUNNING,
    TaskRecord,
    TaskSpec,
)

__all__ = [
    "PilotRun",
    "PilotSpec",
    "TaskScheduler",
    "TaskStats",
    "TaskRecord",
    "TaskSpec",
    "T_PENDING",
    "T_RUNNING",
    "T_DONE",
    "T_FAILED",
    "STATE_NAMES",
]
