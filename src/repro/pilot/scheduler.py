"""In-pilot task scheduler: wave packing, batch pricing, coalesced ends.

This is the bottom level of the two-level scheduler. The top level (the
orchestrator) pays one full job lifecycle — one negotiation, one pooled
session, one block grant — per *pilot*; this class then runs thousands to
millions of tasks inside that grant for O(1) amortized engine events per
completion *batch* instead of 7+ per task. Three mechanisms make that true:

* **wave packing** — :meth:`pack` starts every queued task that fits the
  free slots in one pass (FIFO with head-blocking, like the global
  scheduler's queue discipline: a task that does not fit blocks the tail,
  so identical-shape streams never starve large tasks);
* **batch pricing** — a wave's stage-in/out bytes are summed and priced
  through the session's performance model ONCE per wave
  (:attr:`price_in`/:attr:`price_out`), not once per task: the session
  memoizes per byte-count, and a wave of 10k identical tasks costs one
  model walk;
* **coalesced completions** — task ends live in a local heap, not the
  engine heap. The pilot arms a single engine event at the earliest end;
  :meth:`advance` then drains *every* end due at that instant in one call.
  ``quantum_s`` optionally rounds ends up to a shared grid so even
  heterogeneous waves complete in batches.

Task-level fault handling stays inside the pilot: a tripped task requeues
with its checkpoint-committed progress (or fails after ``max_retries``)
without the global scheduler ever seeing an event. :meth:`interrupt`
supports the pilot-level fault path — job preemption or node loss requeues
every resident task, keeping progress in ``checkpoint_every_s`` multiples.
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
import math
from collections import deque
from typing import Callable, List, Optional, Tuple

from .task import T_DONE, T_FAILED, T_PENDING, T_RUNNING, TaskRecord, TaskSpec

_EPS = 1e-9


def _zero(_nbytes: float) -> float:
    return 0.0


@dataclasses.dataclass(slots=True)
class TaskStats:
    """Lifetime counters for one pilot's task stream."""

    submitted: int = 0
    done: int = 0
    failed: int = 0
    #: fault requeues (count against each task's ``max_retries``)
    retries: int = 0
    #: tasks re-packed with committed progress after a fault/interruption
    resumes: int = 0
    #: interruption sweeps (pilot preempted / node lost)
    interrupts: int = 0
    #: pack passes that started at least one task
    waves: int = 0
    #: run seconds NOT re-executed thanks to task-level checkpoints
    run_s_saved: float = 0.0

    @property
    def terminal(self) -> int:
        return self.done + self.failed


class TaskScheduler:
    """Packs :class:`TaskSpec` s into a pilot's slot pool (see module doc).

    The pilot owns ``slots = n_compute * slots_per_node`` slots; a task
    occupies ``ceil(cores * slots_per_node)`` of them. ``set_lost_slots``
    models degraded backing (chaos node loss): the effective pool shrinks
    but never below one slot, so a degraded pilot drains slowly rather
    than deadlocking.
    """

    __slots__ = (
        "base_slots", "slots_per_node", "lost_slots", "busy_slots",
        "quantum_s", "trip", "price_in", "price_out", "stats",
        "pending_run_s", "pending_in_bytes", "pending_out_bytes",
        "_queue", "_ends", "_seq", "_ids",
    )

    def __init__(
        self,
        *,
        slots: int,
        slots_per_node: int = 1,
        quantum_s: float = 0.0,
        trip: Optional[Callable[[str], bool]] = None,
    ) -> None:
        if slots <= 0:
            raise ValueError("a pilot needs at least one task slot")
        if quantum_s < 0:
            raise ValueError("quantum_s must be >= 0")
        self.base_slots = int(slots)
        self.slots_per_node = max(1, int(slots_per_node))
        self.lost_slots = 0
        self.busy_slots = 0
        self.quantum_s = float(quantum_s)
        #: fault oracle ``trip(task_name) -> bool``, consulted once per
        #: completed attempt; ``None`` disables task faults entirely (the
        #: hot path skips the call, not just the outcome)
        self.trip = trip
        #: wave I/O pricing, bound to the pilot's session at begin();
        #: each takes aggregate bytes and returns modeled seconds
        self.price_in: Callable[[float], float] = _zero
        self.price_out: Callable[[float], float] = _zero
        self.stats = TaskStats()
        #: advisory projection aggregates over non-terminal tasks (used for
        #: the pilot's EASY release projection; committed progress is
        #: ignored, so these are slight over-estimates after resumes)
        self.pending_run_s = 0.0
        self.pending_in_bytes = 0.0
        self.pending_out_bytes = 0.0
        self._queue: deque = deque()
        #: running tasks as a local min-heap of (end_t, seq, record,
        #: run_start); every running task has exactly one entry (interrupt
        #: clears the whole heap), so no lazy deletion is needed
        self._ends: List[Tuple[float, int, TaskRecord, float]] = []
        self._seq = itertools.count()
        self._ids = itertools.count(1)

    # -- capacity ----------------------------------------------------------
    @property
    def effective_slots(self) -> int:
        return max(1, self.base_slots - self.lost_slots)

    @property
    def free_slots(self) -> int:
        return max(0, self.effective_slots - self.busy_slots)

    @property
    def occupancy(self) -> float:
        return self.busy_slots / self.effective_slots

    @property
    def n_queued(self) -> int:
        return len(self._queue)

    @property
    def n_running(self) -> int:
        return len(self._ends)

    @property
    def drained(self) -> bool:
        return not self._queue and not self._ends

    def set_lost_slots(self, n: int) -> None:
        self.lost_slots = max(0, min(int(n), self.base_slots))

    def slots_for(self, spec: TaskSpec) -> int:
        return max(1, math.ceil(spec.cores * self.slots_per_node - _EPS))

    # -- submission --------------------------------------------------------
    def submit(self, spec: TaskSpec, n: int = 1) -> int:
        """Queue ``n`` instances of ``spec``; returns ``n``. O(1) per task
        — records share the spec, aggregates update once per call."""
        if n <= 0:
            return 0
        need = self.slots_for(spec)
        if need > self.base_slots:
            raise ValueError(
                f"task {spec.name!r} needs {need} slots but the pilot has "
                f"only {self.base_slots}"
            )
        q = self._queue
        ids = self._ids
        for _ in range(n):
            q.append(TaskRecord(spec=spec, task_id=next(ids), slots=need))
        self.stats.submitted += n
        self.pending_run_s += spec.run_time_s * n
        self.pending_in_bytes += spec.stage_in_bytes * n
        self.pending_out_bytes += spec.stage_out_bytes * n
        return n

    # -- wave packing ------------------------------------------------------
    def pack(self, now: float) -> int:
        """Start one wave: pop queued tasks (FIFO, head-blocking) while they
        fit the free slots, price the wave's aggregate I/O once, and push
        every end onto the local heap. Returns tasks started."""
        free = self.effective_slots - self.busy_slots
        q = self._queue
        if free <= 0 or not q:
            return 0
        wave = []
        in_b = 0.0
        out_b = 0.0
        while q:
            rec = q[0]
            if rec.slots > free:
                break
            q.popleft()
            free -= rec.slots
            spec = rec.spec
            in_b += spec.stage_in_bytes
            out_b += spec.stage_out_bytes
            wave.append(rec)
        if not wave:
            return 0
        io_s = 0.0
        if in_b > 0.0:
            io_s += self.price_in(in_b)
        if out_b > 0.0:
            io_s += self.price_out(out_b)
        run_start = now + io_s
        q_s = self.quantum_s
        ends = self._ends
        seq = self._seq
        st = self.stats
        busy = 0
        for rec in wave:
            busy += rec.slots
            rec.state = T_RUNNING
            committed = rec.committed_run_s
            if committed > 0.0:
                st.resumes += 1
                st.run_s_saved += committed
            end = run_start + (rec.spec.run_time_s - committed)
            if q_s > 0.0:
                end = math.ceil(end / q_s - _EPS) * q_s
            heapq.heappush(ends, (end, next(seq), rec, run_start))
        self.busy_slots += busy
        st.waves += 1
        return len(wave)

    def next_wake(self) -> Optional[float]:
        """Earliest task end, or None when nothing is running — the single
        instant the pilot needs on the engine heap."""
        return self._ends[0][0] if self._ends else None

    # -- completion batches ------------------------------------------------
    def advance(self, now: float) -> Tuple[int, int, int]:
        """Complete every task whose end is due, consulting the fault
        oracle once per attempt; returns ``(completed, failed, requeued)``.
        Does NOT pack the freed slots — the caller packs after, so a batch
        is one advance + one pack regardless of its size."""
        ends = self._ends
        trip = self.trip
        st = self.stats
        completed = failed = 0
        retry: List[TaskRecord] = []
        freed = 0
        horizon = now + _EPS
        pop = heapq.heappop
        while ends and ends[0][0] <= horizon:
            end, _seq, rec, _run_start = pop(ends)
            freed += rec.slots
            spec = rec.spec
            if trip is not None and trip(spec.name):
                every = spec.checkpoint_every_s
                if every is not None and spec.run_time_s > every:
                    # the fault hit at attempt end: every full checkpoint
                    # segment before the final one had been committed
                    rec.committed_run_s = max(
                        rec.committed_run_s,
                        every * (math.ceil(spec.run_time_s / every - _EPS) - 1),
                    )
                rec.attempt += 1
                if rec.attempt > spec.max_retries:
                    rec.state = T_FAILED
                    failed += 1
                    self.pending_run_s -= spec.run_time_s
                    self.pending_in_bytes -= spec.stage_in_bytes
                    self.pending_out_bytes -= spec.stage_out_bytes
                else:
                    rec.state = T_PENDING
                    retry.append(rec)
            else:
                rec.state = T_DONE
                rec.finished_at = end
                completed += 1
                self.pending_run_s -= spec.run_time_s
                self.pending_in_bytes -= spec.stage_in_bytes
                self.pending_out_bytes -= spec.stage_out_bytes
        self.busy_slots -= freed
        if retry:
            st.retries += len(retry)
            # retried tasks resume at the queue head, oldest first
            self._queue.extendleft(reversed(retry))
        st.done += completed
        st.failed += failed
        return completed, failed, len(retry)

    # -- pilot-level fault path --------------------------------------------
    def interrupt(self, now: float) -> int:
        """Requeue every resident (running) task — the pilot lost its grant
        (preemption, job-level fault) or shrank (node loss). Progress up to
        the last full ``checkpoint_every_s`` segment survives; interrupted
        attempts do NOT count against ``max_retries`` (matching the
        job-level rule that preemption is not the job's fault)."""
        ends = self._ends
        if not ends:
            return 0
        retry: List[TaskRecord] = []
        # seq order == pack order: requeue preserves FIFO fairness
        for _end, _seq, rec, run_start in sorted(ends, key=lambda e: e[1]):
            spec = rec.spec
            every = spec.checkpoint_every_s
            if every is not None:
                elapsed = max(0.0, now - run_start)
                done_s = min(elapsed, spec.run_time_s - rec.committed_run_s)
                rec.committed_run_s = min(
                    spec.run_time_s,
                    rec.committed_run_s
                    + every * math.floor(done_s / every + _EPS),
                )
            rec.state = T_PENDING
            retry.append(rec)
        ends.clear()
        self.busy_slots = 0
        self._queue.extendleft(reversed(retry))
        self.stats.interrupts += 1
        return len(retry)

    # -- projection --------------------------------------------------------
    def projected_run_s(self) -> float:
        """Advisory remaining-drain estimate: the uncompleted run backlog
        spread over the effective slots, plus the remaining waves' I/O
        priced as one aggregate transfer each way. Used for the pilot's
        EASY release projection — an upper-ish bound, never a promise."""
        run = self.pending_run_s / self.effective_slots
        if self.pending_in_bytes > 0.0:
            run += self.price_in(self.pending_in_bytes)
        if self.pending_out_bytes > 0.0:
            run += self.price_out(self.pending_out_bytes)
        return run
