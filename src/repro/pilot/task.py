"""Sub-node task primitives for pilot (two-level) scheduling.

A pilot acquires a block of compute nodes plus ONE pooled storage session
through the ordinary orchestrator path, then multiplexes many *tasks* —
fractional-node units of work — inside that grant (Merzky et al., "Using
Pilot Systems to Execute Many Task Workloads on Supercomputers"). Tasks
never touch the global scheduler: they are packed, priced, retried, and
resumed entirely inside the pilot by :class:`~repro.pilot.TaskScheduler`.

Two types live here:

* :class:`TaskSpec` — the immutable description of one task kind. Campaigns
  at the million-task scale reuse a handful of spec instances across all
  their :class:`TaskRecord`\\ s (the same few-shapes/many-instances pattern
  the dispatch buckets exploit for jobs), so a spec carries everything
  per-task state does not need to duplicate.
* :class:`TaskRecord` — the per-task mutable runtime record. Deliberately
  tiny (``__slots__``, one spec reference, a few scalars): one million live
  records must fit comfortably in a CI container.

States are plain module-level ints, not an Enum — task state is flipped in
the scheduler's hottest loop and Enum attribute access costs ~10x an int
compare at this volume.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

#: task states (ints on purpose — see module docstring)
T_PENDING = 0
T_RUNNING = 1
T_DONE = 2
T_FAILED = 3

STATE_NAMES = ("PENDING", "RUNNING", "DONE", "FAILED")


@dataclasses.dataclass(frozen=True, slots=True)
class TaskSpec:
    """One kind of sub-node task.

    ``cores`` is the fraction of ONE compute node the task occupies
    (0.125 = an eighth of a node; 2.0 = a two-node task). The scheduler
    converts it to slots with the pilot's ``slots_per_node`` density.
    Stage bytes are the task's *private* I/O through the pilot's shared
    session — pilot-wide datasets are staged once by the session itself.
    """

    name: str
    run_time_s: float
    cores: float = 0.125
    stage_in_bytes: float = 0.0
    stage_out_bytes: float = 0.0
    max_retries: int = 2
    #: commit cadence for task-level checkpointing: on a fault or an
    #: interruption (pilot preempted, node lost) progress survives in
    #: multiples of this; ``None`` restarts the task from scratch
    checkpoint_every_s: Optional[float] = None

    def __post_init__(self) -> None:
        if not self.name:
            raise ValueError("task name must be non-empty")
        if self.run_time_s < 0:
            raise ValueError(f"{self.name}: run_time_s must be >= 0")
        if self.cores <= 0:
            raise ValueError(f"{self.name}: cores must be > 0")
        if self.stage_in_bytes < 0 or self.stage_out_bytes < 0:
            raise ValueError(f"{self.name}: stage bytes must be >= 0")
        if self.max_retries < 0:
            raise ValueError(f"{self.name}: max_retries must be >= 0")
        if self.checkpoint_every_s is not None and self.checkpoint_every_s <= 0:
            raise ValueError(f"{self.name}: checkpoint_every_s must be > 0")


@dataclasses.dataclass(slots=True)
class TaskRecord:
    """Mutable runtime state of one task instance (million-scale: keep it
    small — everything shape-like lives on the shared :class:`TaskSpec`)."""

    spec: TaskSpec
    task_id: int
    #: slots this task occupies in its pilot (ceil(cores * slots_per_node))
    slots: int
    state: int = T_PENDING
    #: fault retries consumed (interruptions/resumes do not count)
    attempt: int = 0
    #: run seconds already committed by task-level checkpoints
    committed_run_s: float = 0.0
    finished_at: float = 0.0

    @property
    def state_name(self) -> str:
        return STATE_NAMES[self.state]
