"""PilotSpec + PilotRun: the glue between the two scheduler levels.

:class:`PilotSpec` describes the top-level acquisition — a block of compute
nodes, a slot density, and the pooled storage the whole task stream shares.
:class:`PilotRun` is the live bottom-level runtime bound to one orchestrator
job record: it owns the :class:`~repro.pilot.TaskScheduler`, arms exactly one
engine event at a time (the earliest task end), and reports completions to
the orchestrator only when the whole stream drains — so the global engine
sees one RUNNING phase per pilot *attempt*, however many tasks ran inside.

This module deliberately imports nothing from ``repro.orchestrator`` or
``repro.provision`` (the orchestrator constructs PilotRun and injects the
engine/recorder/session, all duck-typed): the pilot layer sits below both
and must stay importable from the hot loop without cycles.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Iterable, Optional, Tuple

from .scheduler import TaskScheduler, TaskStats
from .task import TaskSpec


@dataclasses.dataclass(frozen=True)
class PilotSpec:
    """The top-level half of a pilot: what the orchestrator acquires once.

    ``n_compute * slots_per_node`` becomes the pilot's slot pool; a task
    with ``cores=1/slots_per_node`` occupies one slot. ``datasets`` and the
    stage bytes describe the *pilot-wide* storage session (POOLED — leases
    keep the datasets warm across the whole task stream); per-task private
    I/O lives on each :class:`TaskSpec`.

    ``completion_quantum_s`` coalesces heterogeneous task ends onto a
    shared grid (fewer, larger batches). ``open_ended=True`` marks a pilot
    that accepts late task submissions: it makes no EASY release promise,
    so backfill never books holes against it.
    """

    name: str
    n_compute: int
    slots_per_node: int = 8
    datasets: Tuple = ()
    stage_in_bytes: float = 0.0
    stage_out_bytes: float = 0.0
    n_streams: int = 8
    #: job-level retries for the pilot itself (task retries live on TaskSpec)
    max_retries: int = 2
    completion_quantum_s: float = 0.0
    open_ended: bool = False

    def __post_init__(self) -> None:
        object.__setattr__(self, "datasets", tuple(self.datasets))
        if not self.name:
            raise ValueError("pilot name must be non-empty")
        if self.n_compute < 1:
            raise ValueError(f"{self.name}: n_compute must be >= 1")
        if self.slots_per_node < 1:
            raise ValueError(f"{self.name}: slots_per_node must be >= 1")
        if self.stage_in_bytes < 0 or self.stage_out_bytes < 0:
            raise ValueError(f"{self.name}: stage bytes must be >= 0")
        if self.max_retries < 0:
            raise ValueError(f"{self.name}: max_retries must be >= 0")
        if self.completion_quantum_s < 0:
            raise ValueError(f"{self.name}: completion_quantum_s must be >= 0")

    @property
    def n_slots(self) -> int:
        return self.n_compute * self.slots_per_node


class PilotRun:
    """One pilot's bottom-level runtime, attached to its JobRecord.

    Lifecycle (all driven by the orchestrator):

    * ``begin(session, now, ...)`` — the job reached RUNNING: bind wave
      pricing to the session, pack the first wave, arm the wake;
    * ``_wake`` — the armed engine event: drain the due completion batch,
      repack, re-arm; when the stream drains, call ``on_complete`` (the
      orchestrator's ``_run_done``) so staging-out/teardown proceed exactly
      like a plain job;
    * ``suspend(now)`` — the attempt lost its grant (job fault, preemption,
      unsurvivable node loss): requeue resident tasks with committed
      progress; a later attempt re-begins with the backlog intact;
    * ``on_node_down/on_node_repair`` — the PR 9 chaos path: the pilot
      *degrades* (slots shrink in proportion to the lost pool backing,
      resident tasks requeue and repack) instead of dying.

    Stale engine events are neutralized by the wake-token pattern the
    orchestrator uses for phases: every suspend/resize bumps ``_wake_token``
    and an old event finds its token mismatched and returns.
    """

    __slots__ = (
        "spec", "engine", "recorder", "counters", "job_id", "tasks",
        "state", "session", "_wake_token", "_wake_at", "_on_complete",
        "_reproject", "_pool_nodes", "_lost_nodes",
    )

    def __init__(
        self,
        spec: PilotSpec,
        *,
        engine,
        recorder,
        counters=None,
        trip: Optional[Callable[[str], bool]] = None,
        job_id: int = 0,
    ) -> None:
        self.spec = spec
        self.engine = engine
        self.recorder = recorder
        #: orchestrator LiveCounters (duck-typed; None for standalone use)
        self.counters = counters
        self.job_id = job_id
        self.tasks = TaskScheduler(
            slots=spec.n_slots,
            slots_per_node=spec.slots_per_node,
            quantum_s=spec.completion_quantum_s,
            trip=trip,
        )
        self.state = "idle"                 # idle -> running -> drained
        self.session = None
        self._wake_token = 0
        self._wake_at: Optional[float] = None
        self._on_complete: Optional[Callable[[], None]] = None
        self._reproject: Optional[Callable[[], None]] = None
        self._pool_nodes = 0
        self._lost_nodes: set = set()

    @property
    def stats(self) -> TaskStats:
        return self.tasks.stats

    # -- task submission ---------------------------------------------------
    def submit(self, task: TaskSpec, n: int = 1) -> None:
        """Queue ``n`` instances; packs immediately if the pilot is live
        (late submission — see ``PilotSpec.open_ended``)."""
        self.tasks.submit(task, n)
        c = self.counters
        if c is not None:
            c.tasks_submitted += n
        if self.state == "running":
            self.tasks.pack(self.engine.now)
            self._arm()
            if self._reproject is not None:
                self._reproject()

    def submit_many(self, tasks: Iterable[TaskSpec]) -> None:
        for t in tasks:
            self.submit(t)

    # -- attempt lifecycle -------------------------------------------------
    def begin(
        self,
        session,
        now: float,
        *,
        on_complete: Callable[[], None],
        reproject: Optional[Callable[[], None]] = None,
        pool_nodes: int = 0,
    ) -> None:
        """The pilot job reached RUNNING on a fresh session/lease: bind the
        wave pricing, forget any previous attempt's node losses (the new
        lease's pool is priced degraded by the session itself if it is
        still hurt), pack the first wave, and arm the wake."""
        ts = self.tasks
        self.session = session
        self._on_complete = on_complete
        self._reproject = reproject
        self._pool_nodes = int(pool_nodes)
        self._lost_nodes.clear()
        ts.set_lost_slots(0)
        ts.price_in = lambda b: session.stage_time_s(b, "in")
        ts.price_out = lambda b: session.stage_time_s(b, "out")
        self.state = "running"
        packed = ts.pack(now)
        rec = self.recorder
        if rec.enabled:
            rec.pilot_started(
                self.spec.name, self.job_id, now,
                n_tasks=ts.n_queued + ts.n_running,
                n_slots=ts.effective_slots,
                packed=packed,
            )
        if ts.drained:
            # an empty pilot (or one whose backlog already failed out)
            # completes its RUNNING phase immediately
            self._finish()
            return
        self._arm()

    def suspend(self, now: float) -> None:
        """The attempt released its grant (job-level fault/preemption or an
        unsurvivable node loss). Resident tasks requeue with committed
        progress; the engine event, if armed, is invalidated."""
        if self.state != "running":
            return
        self._wake_token += 1
        self._wake_at = None
        self.state = "idle"
        self.session = None
        self.tasks.interrupt(now)

    def projected_run_s(self, session=None) -> float:
        """Remaining-drain estimate for EASY projections; prices the
        backlog's wave I/O through ``session`` when the pilot is not yet
        bound to one (admission-time projection)."""
        ts = self.tasks
        run = ts.pending_run_s / ts.effective_slots
        s = session if session is not None else self.session
        if s is not None:
            if ts.pending_in_bytes > 0.0:
                run += s.stage_time_s(ts.pending_in_bytes, "in")
            if ts.pending_out_bytes > 0.0:
                run += s.stage_time_s(ts.pending_out_bytes, "out")
        return run

    # -- chaos (PR 9 path) -------------------------------------------------
    def on_node_down(self, node_id: str, now: float) -> None:
        """A storage node backing the pilot's pool died: shrink the slot
        pool in proportion to the lost backing (the session's bandwidth
        shrank with it), requeue resident tasks, repack, re-arm."""
        if node_id in self._lost_nodes:
            return
        self._lost_nodes.add(node_id)
        if self.state != "running":
            return
        self._wake_token += 1
        self._wake_at = None
        ts = self.tasks
        ts.interrupt(now)
        self._apply_slot_loss()
        packed = ts.pack(now)
        rec = self.recorder
        if rec.enabled:
            rec.pilot_resized(
                self.spec.name, self.job_id, now,
                n_slots=ts.effective_slots, cause=node_id, packed=packed,
            )
        if self._reproject is not None:
            self._reproject()
        self._arm()

    def on_node_repair(self, node_id: str, now: float) -> None:
        """A lost backing node came back (pool self-healed): restore slots
        and pack the widened pool."""
        if node_id not in self._lost_nodes:
            return
        self._lost_nodes.discard(node_id)
        if self.state != "running":
            return
        ts = self.tasks
        self._apply_slot_loss()
        packed = ts.pack(now)
        rec = self.recorder
        if rec.enabled:
            rec.pilot_resized(
                self.spec.name, self.job_id, now,
                n_slots=ts.effective_slots, cause="repair", packed=packed,
            )
        if self._reproject is not None:
            self._reproject()
        self._arm()

    def _apply_slot_loss(self) -> None:
        ts = self.tasks
        if not self._lost_nodes or self._pool_nodes <= 0:
            ts.set_lost_slots(0)
            return
        frac = min(1.0, len(self._lost_nodes) / self._pool_nodes)
        ts.set_lost_slots(int(round(ts.base_slots * frac)))

    # -- engine wake plumbing ----------------------------------------------
    def _arm(self) -> None:
        """Keep exactly one valid engine event: the earliest task end. If
        an armed wake already fires at or before the new heap minimum it is
        kept (it will re-arm); otherwise the token bump strands it."""
        if self.state != "running":
            return
        nxt = self.tasks.next_wake()
        if nxt is None:
            return
        if self._wake_at is not None and self._wake_at <= nxt:
            return
        self._wake_token += 1
        token = self._wake_token
        self._wake_at = nxt
        self.engine.at(nxt, lambda: self._wake(token))

    def _wake(self, token: int) -> None:
        if token != self._wake_token:
            return
        self._wake_at = None
        ts = self.tasks
        now = self.engine.now
        completed, failed, requeued = ts.advance(now)
        packed = ts.pack(now)
        c = self.counters
        if c is not None:
            c.tasks_done += completed
            c.tasks_failed += failed
            c.task_retries += requeued
        rec = self.recorder
        if rec.enabled:
            rec.task_batch(
                self.spec.name, self.job_id, now,
                completed=completed, failed=failed, requeued=requeued,
                packed=packed, queued=ts.n_queued, running=ts.n_running,
                occupancy=ts.occupancy,
            )
        if ts.drained:
            self._finish()
            return
        self._arm()

    def _finish(self) -> None:
        self.state = "drained"
        self._wake_token += 1
        self._wake_at = None
        cb = self._on_complete
        self._on_complete = None
        if cb is not None:
            cb()
