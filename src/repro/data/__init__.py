from .pipeline import DatasetSpec, Loader, stage_in, write_corpus
from .synthetic import batch_for_step, token_block

__all__ = ["DatasetSpec", "Loader", "stage_in", "write_corpus",
           "batch_for_step", "token_block"]
