"""Data pipeline: corpus files on the global FS, staged into the provisioned
burst tier (the paper's stage-in, §V), then served as training batches.

The loader reads token shards through the FS client API, so the whole
train-input path exercises the provisioned storage exactly like the paper's
IOR runs exercise BeeGFS — plus a fallback pure-generator mode when no
storage deployment is in play (dry-runs, unit tests).
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np

from ..core.client import FSClient
from ..core.datamanager import DataManager
from ..core.staging import StageReport, stage
from .synthetic import batch_for_step, corpus_bytes, token_block

TOKEN_BYTES = 4  # int32


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    seed: int
    vocab: int
    n_tokens: int                    # corpus length
    shard_tokens: int = 1 << 20      # tokens per corpus file


def write_corpus(fs: DataManager, root: str, spec: DatasetSpec) -> list[str]:
    """Materialize the corpus as shard files on a file system (global FS)."""
    client = FSClient(fs, "corpus-writer")
    client.makedirs(root)
    paths = []
    for i, start in enumerate(range(0, spec.n_tokens, spec.shard_tokens)):
        count = min(spec.shard_tokens, spec.n_tokens - start)
        p = f"{root}/shard-{i:05d}.tok"
        client.write_file(p, corpus_bytes(spec.seed, start, count, spec.vocab))
        paths.append(p)
    return paths


def stage_in(
    src_fs: DataManager, dst_fs: DataManager, root: str, dst_root: str,
    **kw,
) -> StageReport:
    client = FSClient(src_fs, "stager")
    names = client.readdir(root)
    pairs = [(f"{root}/{n}", f"{dst_root}/{n}") for n in names]
    return stage(src_fs, dst_fs, pairs, direction="in", **kw)


class Loader:
    """Yields next-token batches; reads token shards via an FS client when a
    deployment is given, else generates directly (identical values either
    way — synthetic corpus is position-deterministic)."""

    def __init__(
        self,
        spec: DatasetSpec,
        batch: int,
        seq: int,
        *,
        fs: Optional[DataManager] = None,
        root: str = "/data",
        shard: int = 0,
        n_shards: int = 1,
    ):
        self.spec = spec
        self.batch = batch
        self.seq = seq
        self.fs = fs
        self.root = root
        self.shard = shard
        self.n_shards = n_shards
        self._client = FSClient(fs, f"loader{shard}") if fs is not None else None

    def _read_tokens(self, start: int, count: int) -> np.ndarray:
        """Read [start, start+count) tokens through the FS."""
        assert self._client is not None
        out = np.empty((count,), np.int32)
        got = 0
        while got < count:
            pos = start + got
            si, off = divmod(pos, self.spec.shard_tokens)
            take = min(count - got, self.spec.shard_tokens - off)
            raw = self._client.pread(
                f"{self.root}/shard-{si:05d}.tok", off * TOKEN_BYTES, take * TOKEN_BYTES
            )
            out[got: got + take] = np.frombuffer(raw, np.int32)
            got += take
        return out

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        if self._client is None:
            return batch_for_step(
                self.spec.seed, step, self.batch, self.seq, self.spec.vocab,
                shard=self.shard, n_shards=self.n_shards,
            )
        per = self.batch // self.n_shards
        base = (step * self.batch + self.shard * per) * (self.seq + 1)
        need = per * (self.seq + 1)
        toks = self._read_tokens(base % self.spec.n_tokens, min(need, self.spec.n_tokens - base % self.spec.n_tokens))
        if toks.size < need:  # wrap around the corpus
            toks = np.concatenate([toks, self._read_tokens(0, need - toks.size)])
        toks = toks.reshape(per, self.seq + 1)
        return {"tokens": toks[:, :-1].copy(), "labels": toks[:, 1:].copy()}

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
