"""Deterministic synthetic token corpus.

Tokens are a seeded counter-mode hash so any (shard, step) batch is
reproducible without materializing a dataset — and the same generator writes
the corpus files used by the stage-in path, so staged bytes equal generated
bytes (tested).
"""

from __future__ import annotations

import numpy as np

_MOD = (1 << 31) - 1


def token_block(seed: int, start: int, count: int, vocab: int) -> np.ndarray:
    """Deterministic pseudo-tokens for positions [start, start+count)."""
    idx = np.arange(start, start + count, dtype=np.uint64)
    # splitmix64-ish (64-bit wraparound is intended)
    mix = (seed * 0x9E3779B97F4A7C15) & 0xFFFFFFFFFFFFFFFF
    with np.errstate(over="ignore"):
        z = idx + np.uint64(mix)
        z = (z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
        z = (z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
        z = z ^ (z >> np.uint64(31))
    return (z % np.uint64(vocab)).astype(np.int32)


def corpus_bytes(seed: int, start: int, count: int, vocab: int) -> bytes:
    return token_block(seed, start, count, vocab).tobytes()


def batch_for_step(
    seed: int, step: int, batch: int, seq: int, vocab: int,
    *, shard: int = 0, n_shards: int = 1,
) -> dict[str, np.ndarray]:
    """Next-token-prediction batch for a (step, data shard)."""
    assert batch % n_shards == 0
    per = batch // n_shards
    base = (step * batch + shard * per) * (seq + 1)
    toks = token_block(seed, base, per * (seq + 1), vocab).reshape(per, seq + 1)
    return {"tokens": toks[:, :-1].copy(), "labels": toks[:, 1:].copy()}
