"""StoragePool: a long-lived deployment plus a capacity ledger and leases.

The paper tears its BeeGFS instance down with the job; DataWarp's own
*persistent instance* mode (and Merzky et al.'s pilot abstraction) instead
keeps one provisioned instance alive across many jobs and sub-allocates it.
A ``StoragePool`` is that persistent instance in this codebase: it pins its
storage nodes through an ordinary scheduler allocation (so the scheduler's
no-double-allocation invariant extends to pools for free), carries the
analytic `FSDeployment` every lease-holder stages against, and accounts every
byte in a ledger:

    used = sum(charged dataset bytes) + sum(lease scratch reservations)

The ledger can never exceed capacity — ``charge_dataset`` / ``reserve_scratch``
raise :class:`PoolCapacityError` instead of oversubscribing, and callers
(the PoolManager) evict to make room *before* charging.

Teardown discipline (property-tested): a pool dies only when its last lease
drains after ``retire()``, or when it sits idle (zero leases) past the
manager's TTL. Nothing else releases its nodes.
"""

from __future__ import annotations

import dataclasses
import enum
import math
from typing import Optional

from ..core.perfmodel import FSDeployment
from ..core.provisioner import DeploymentPlan
from ..core.scheduler import Allocation

from .catalog import DatasetRef


class PoolError(RuntimeError):
    pass


class PoolCapacityError(PoolError):
    """Raised instead of ever letting the ledger exceed capacity."""


class PoolState(enum.Enum):
    ACTIVE = "active"          # granting leases
    DRAINING = "draining"      # retired; existing leases run out, no new ones
    RETIRED = "retired"        # torn down; nodes returned to the scheduler


@dataclasses.dataclass(frozen=True)
class Lease:
    """A job's sub-allocation of a pool: scratch space plus dataset pins."""

    lease_id: int
    pool_id: int
    job_name: str
    scratch_bytes: float
    datasets: tuple[DatasetRef, ...]      # everything the job references
    missing: tuple[DatasetRef, ...]       # misses at acquire time: must stage
    resident_bytes: float                 # hit volume: stage-in bytes saved
    granted_at: float

    @property
    def hits(self) -> int:
        return len(self.datasets) - len(self.missing)

    @property
    def misses(self) -> int:
        return len(self.missing)


@dataclasses.dataclass
class StoragePool:
    """One persistent provisioned instance. Mutated only by the PoolManager."""

    pool_id: int
    name: str
    allocation: Allocation                # pins the storage nodes
    plan: DeploymentPlan
    fs_model: FSDeployment
    capacity_bytes: float
    deploy_time_s: float                  # one-time fresh deploy (C8)
    created_at: float
    state: PoolState = PoolState.ACTIVE
    base_dir: Optional[str] = None        # claimed tree (collision-guarded)
    idle_since: Optional[float] = None    # set while zero leases are live
    retired_at: Optional[float] = None
    leases: dict = dataclasses.field(default_factory=dict)       # id -> Lease
    dataset_bytes: dict = dataclasses.field(default_factory=dict)  # name -> bytes
    scratch_bytes: float = 0.0
    # -- failure domain (chaos engine) ----------------------------------------
    #: dead original nodes awaiting heal: node_id -> capacity share deducted
    #: when the node died (restored exactly on repair or replacement)
    dead_node_capacity: dict = dataclasses.field(default_factory=dict)
    #: original nodes replaced by a backfill node: still pinned by the
    #: pool's allocation (released at teardown) but no longer backing it
    replaced_node_ids: set = dataclasses.field(default_factory=set)
    #: backfill allocations (one spare node each), released at teardown
    extra_allocations: list = dataclasses.field(default_factory=list)

    def __post_init__(self) -> None:
        if self.capacity_bytes <= 0:
            raise ValueError(f"pool {self.name!r}: capacity must be positive")
        if not self.allocation.storage_nodes:
            raise ValueError(f"pool {self.name!r}: allocation has no storage nodes")

    # -- ledger ---------------------------------------------------------------
    @property
    def used_bytes(self) -> float:
        return sum(self.dataset_bytes.values()) + self.scratch_bytes

    @property
    def free_bytes(self) -> float:
        return self.capacity_bytes - self.used_bytes

    @property
    def occupancy(self) -> float:
        # a fully-degraded pool (every node dead, capacity 0) counts as full
        if self.capacity_bytes <= 0:
            return 1.0
        return self.used_bytes / self.capacity_bytes

    def charge_dataset(self, dataset: DatasetRef) -> None:
        """Charge a dataset's bytes once; idempotent for an already-charged
        name (a second lease staging behind an INFLIGHT entry)."""
        if dataset.name in self.dataset_bytes:
            return
        if dataset.nbytes > self.free_bytes:
            raise PoolCapacityError(
                f"pool {self.name!r}: dataset {dataset.name!r} "
                f"({dataset.nbytes:.3g} B) exceeds free {self.free_bytes:.3g} B"
            )
        self.dataset_bytes[dataset.name] = dataset.nbytes

    def uncharge_dataset(self, name: str) -> float:
        return self.dataset_bytes.pop(name, 0.0)

    def reserve_scratch(self, nbytes: float) -> None:
        if nbytes < 0:
            raise ValueError("scratch reservation must be >= 0")
        if nbytes > self.free_bytes:
            raise PoolCapacityError(
                f"pool {self.name!r}: scratch {nbytes:.3g} B "
                f"exceeds free {self.free_bytes:.3g} B"
            )
        self.scratch_bytes += nbytes

    def release_scratch(self, nbytes: float) -> None:
        # float accumulation at GB scale: tolerate relative rounding drift
        if nbytes > self.scratch_bytes and not math.isclose(
            nbytes, self.scratch_bytes, rel_tol=1e-9, abs_tol=1e-6
        ):
            raise PoolError(
                f"pool {self.name!r}: releasing {nbytes:.3g} B scratch, "
                f"only {self.scratch_bytes:.3g} B reserved"
            )
        self.scratch_bytes = max(0.0, self.scratch_bytes - nbytes)

    # -- leases ----------------------------------------------------------------
    @property
    def n_leases(self) -> int:
        return len(self.leases)

    def attach(self, lease: Lease) -> None:
        if self.state is not PoolState.ACTIVE:
            raise PoolError(f"pool {self.name!r} is {self.state.value}, not leasable")
        self.leases[lease.lease_id] = lease
        self.idle_since = None

    def detach(self, lease_id: int, now: float) -> None:
        if lease_id not in self.leases:
            raise PoolError(f"lease {lease_id} is not attached to pool {self.name!r}")
        del self.leases[lease_id]
        if not self.leases:
            self.idle_since = now

    # -- introspection ----------------------------------------------------------
    @property
    def storage_node_ids(self) -> frozenset:
        """Ids of the nodes currently *backing* the pool: the original
        allocation minus dead/replaced nodes, plus backfill spares."""
        ids = {
            n.node_id
            for n in self.allocation.storage_nodes
            if n.node_id not in self.dead_node_capacity
            and n.node_id not in self.replaced_node_ids
        }
        for alloc in self.extra_allocations:
            ids.update(n.node_id for n in alloc.storage_nodes)
        return frozenset(ids)

    @property
    def degraded(self) -> bool:
        """True while any original node is dead and unreplaced."""
        return bool(self.dead_node_capacity)

    def check_invariants(self) -> None:
        """Ledger sanity; tests call this after every operation."""
        assert self.used_bytes <= self.capacity_bytes + 1e-6, (
            f"pool {self.name!r} oversubscribed: "
            f"{self.used_bytes} > {self.capacity_bytes}"
        )
        assert self.scratch_bytes >= -1e-6
        if self.state is PoolState.RETIRED:
            assert not self.leases, f"retired pool {self.name!r} has live leases"
