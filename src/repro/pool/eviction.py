"""Eviction engine: free pool capacity under pressure.

A persistent pool fills up — datasets outlive the leases that staged them
(that is the point) — so admission of a new lease may need to push old
datasets out. Eviction here is *catalog-coupled*: evicting a dataset both
uncharges its bytes from the pool ledger and invalidates its catalog entry,
so the next job referencing it sees a miss and re-stages from the global FS.
Nothing is ever served from an evicted (or half-staged) tree.

Only unpinned RESIDENT entries are candidates: INFLIGHT entries belong to a
staging lease, and pinned entries may be read by a live lease. The default
policy is LRU over the catalog's last-touch stamps; alternative policies
(size-aware, cost-aware GDSF, ...) implement the same two-method interface.
"""

from __future__ import annotations

import abc
from typing import TYPE_CHECKING

from ..obs.trace import NULL_RECORDER
from .catalog import DataCatalog, Residency

if TYPE_CHECKING:  # avoid a cycle: manager imports eviction
    from .pool import StoragePool


class EvictionPolicy(abc.ABC):
    """Chooses victims on one pool until a byte target is met."""

    name: str = "abstract"

    @abc.abstractmethod
    def victims(
        self, pool: "StoragePool", catalog: DataCatalog, need_bytes: float
    ) -> list[Residency]:
        """Entries to evict so that ``pool.free_bytes >= need_bytes`` holds
        afterwards; empty list if the target is unreachable."""


class LRUEviction(EvictionPolicy):
    """Least-recently-touched first — Data Diffusion's baseline cache policy."""

    name = "lru"

    def victims(self, pool, catalog, need_bytes):
        shortfall = need_bytes - pool.free_bytes
        if shortfall <= 0:
            return []
        chosen: list[Residency] = []
        freed = 0.0
        for r in catalog.evictable(pool.pool_id):
            chosen.append(r)
            freed += r.dataset.nbytes
            if freed >= shortfall:
                return chosen
        return []      # even evicting everything evictable is not enough


class Evictor:
    """Applies a policy's choices: ledger uncharge + catalog invalidation."""

    def __init__(self, policy: EvictionPolicy | None = None):
        self.policy = policy or LRUEviction()
        self.evictions = 0
        self.evicted_bytes = 0.0
        # observability sink (no-op by default; PoolManager propagates its
        # recorder here). The recorder stamps virtual time itself.
        self.recorder = NULL_RECORDER

    def make_room(
        self, pool: "StoragePool", catalog: DataCatalog, need_bytes: float
    ) -> bool:
        """Evict until ``need_bytes`` fit in ``pool``; False if impossible
        (then the pool is left untouched — no partial eviction)."""
        if need_bytes <= pool.free_bytes:
            return True
        if need_bytes > pool.capacity_bytes:
            return False
        victims = self.policy.victims(pool, catalog, need_bytes)
        if not victims:
            return False
        rec = self.recorder
        for r in victims:
            catalog.invalidate(pool.pool_id, r.dataset.name)
            pool.uncharge_dataset(r.dataset.name)
            self.evictions += 1
            self.evicted_bytes += r.dataset.nbytes
            if rec.enabled:
                rec.eviction(pool.pool_id, r.dataset.name, r.dataset.nbytes)
        return pool.free_bytes >= need_bytes
