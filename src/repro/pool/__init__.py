"""Persistent storage pools with a data-aware catalog.

The paper provisions a job-scoped BeeGFS instance and tears it down at job
end; DataWarp's persistent-instance mode — and Data Diffusion's data-aware
scheduling over cached provisioned storage — motivate the opposite design:
long-lived pools that outlive single jobs, sub-allocated through leases,
with a catalog tracking which datasets are already resident where so the
orchestrator can route jobs to their data and skip stage-in on cache hits.

Modules: `catalog` (DatasetRef + residency index), `pool` (capacity ledger +
leases), `eviction` (LRU engine under pressure), `manager` (PoolManager, the
only mutator). `DataAwarePolicy` lives with its siblings in
``repro.orchestrator.policies``.
"""

from .catalog import DataCatalog, DatasetRef, Residency, ResidencyState, total_bytes
from .eviction import EvictionPolicy, Evictor, LRUEviction
from .manager import PoolManager, PoolStats
from .pool import (
    Lease,
    PoolCapacityError,
    PoolError,
    PoolState,
    StoragePool,
)

__all__ = [
    "DataCatalog", "DatasetRef", "Residency", "ResidencyState", "total_bytes",
    "EvictionPolicy", "Evictor", "LRUEviction",
    "PoolManager", "PoolStats",
    "Lease", "PoolCapacityError", "PoolError", "PoolState", "StoragePool",
]
