"""PoolManager: provision, lease, evict, retire persistent storage pools.

Sits between the `Scheduler`/`Provisioner` substrate and the workflow
orchestrator. Where PR 1's lifecycle provisions a fresh job-scoped file
system per job (paying the §IV-B1 fresh-deploy cost and re-staging every
shared dataset), the manager keeps long-lived pools and grants **leases**:

* ``create_pool`` pins storage nodes through an ordinary scheduler
  allocation (a node can therefore never be in two live pools — that is the
  scheduler's own no-double-allocation invariant) and plans one persistent
  deployment over them.
* ``try_acquire`` sub-allocates capacity from the best candidate pool:
  datasets already RESIDENT are cache hits (their bytes are *saved* stage-in
  traffic), missing ones are charged to the ledger as INFLIGHT and staged by
  the lease-holder; scratch is reserved on top. Under pressure the eviction
  engine pushes LRU unpinned datasets out first.
* ``release`` drops the lease's pins and scratch; an INFLIGHT dataset whose
  last pin vanishes without a completed stage-in is rolled back (uncharged),
  so a faulted stage never leaves ghost bytes in the ledger.
* Teardown happens on exactly two paths: the last lease of a ``retire()``'d
  (DRAINING) pool draining out, or ``reap_idle`` finding an ACTIVE pool with
  zero leases idle past ``ttl_s``.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Iterable, Optional, Sequence

from ..core.perfmodel import predict_deploy_time
from ..core.provisioner import Provisioner
from ..obs.trace import NULL_RECORDER
from ..core.scheduler import (
    AllocationError,
    JobRequest,
    Scheduler,
    StorageRequest,
)

from .catalog import DataCatalog, DatasetRef, ResidencyState, total_bytes
from .eviction import EvictionPolicy, Evictor
from .pool import Lease, PoolState, StoragePool


@dataclasses.dataclass
class PoolStats:
    """Campaign-lifetime counters (evictions live on the Evictor)."""

    dataset_hits: int = 0
    dataset_misses: int = 0
    bytes_saved: float = 0.0          # stage-in traffic avoided by hits
    bytes_staged: float = 0.0         # dataset bytes actually staged into pools
    leases_granted: int = 0
    pools_created: int = 0
    pools_retired: int = 0

    @property
    def hit_rate(self) -> float:
        total = self.dataset_hits + self.dataset_misses
        return self.dataset_hits / total if total else 0.0


class PoolManager:
    """Owns every pool; the only object that mutates pools and the catalog."""

    def __init__(
        self,
        scheduler: Scheduler,
        provisioner: Optional[Provisioner] = None,
        *,
        catalog: Optional[DataCatalog] = None,
        eviction: Optional[EvictionPolicy] = None,
        ttl_s: Optional[float] = None,
        lease_attach_s: float = 0.1,
        clock: Optional[Callable[[], float]] = None,
    ):
        if ttl_s is not None and ttl_s <= 0:
            raise ValueError("ttl_s must be positive (or None to disable)")
        if lease_attach_s < 0:
            raise ValueError("lease_attach_s must be >= 0")
        self.scheduler = scheduler
        self.provisioner = provisioner or Provisioner(scheduler.cluster)
        self.catalog = catalog or DataCatalog()
        self.evictor = Evictor(eviction)
        self.ttl_s = ttl_s
        self.lease_attach_s = lease_attach_s
        # default time source when callers omit ``now`` — the orchestrator
        # binds its engine clock here so mid-campaign pool operations are
        # stamped with virtual time, not 0.0
        self.clock = clock
        self.stats = PoolStats()
        self._pools: dict[int, StoragePool] = {}
        self._pool_ids = itertools.count(1)
        self._lease_ids = itertools.count(1)
        self._epoch = 0
        self._recorder = NULL_RECORDER

    @property
    def recorder(self):
        """Observability sink for pool/lease/eviction events (no-op by
        default). Assigning propagates to the evictor."""
        return self._recorder

    @recorder.setter
    def recorder(self, rec) -> None:
        self._recorder = rec
        self.evictor.recorder = rec

    @property
    def epoch(self) -> int:
        """Monotone counter over every observable pool-state change: pool
        create/retire/teardown, lease grant/release, ledger charges, and
        catalog residency changes (the catalog's own version folds in).
        Anything cached off pool state — negotiated POOLED offers above all
        — re-validates against this instead of re-scoring every pool on
        every dispatch attempt."""
        return self._epoch + self.catalog.version

    def _now(self, now: Optional[float]) -> float:
        if now is not None:
            return now
        return self.clock() if self.clock is not None else 0.0

    # -- pool lifecycle --------------------------------------------------------
    def create_pool(
        self,
        *,
        nodes: Optional[int] = None,
        capacity_bytes: Optional[float] = None,
        capability_bw: Optional[float] = None,
        cap_bytes: Optional[float] = None,
        name: Optional[str] = None,
        runtime: str = "shifter",
        base_dir: Optional[str] = None,
        now: Optional[float] = None,
    ) -> StoragePool:
        """Provision a persistent pool sized by node count, capacity, or
        aggregate bandwidth.

        ``cap_bytes`` optionally caps the ledger below the hardware capacity
        (useful to model a quota, or to create cache pressure in benchmarks).
        Raises :class:`AllocationError` when the nodes aren't free — pools
        are deliberate, capital allocations, not opportunistic ones — and
        :class:`FSError` when ``base_dir`` is already owned by another live
        deployment or pool (two pools must never share a warm tree).
        """
        now = self._now(now)
        pool_id = next(self._pool_ids)
        name = name or f"pool{pool_id}"
        base_dir = base_dir or f"pool://{name}"
        self.provisioner.claim_tree(base_dir, owner=f"pool:{name}")
        req = StorageRequest(
            nodes=nodes, capacity_bytes=capacity_bytes, capability_bw=capability_bw
        )
        try:
            alloc = self.scheduler.submit(JobRequest(name, 0, storage=req))
        except AllocationError:
            self.provisioner.release_tree(base_dir)
            raise
        plan = self.provisioner.plan_for_nodes(alloc.storage_nodes, runtime=runtime)
        hw_capacity = sum(
            self.scheduler.policy.node_capacity_bytes(n) for n in alloc.storage_nodes
        )
        pool = StoragePool(
            pool_id=pool_id,
            name=name,
            allocation=alloc,
            plan=plan,
            fs_model=self.provisioner.model_for(plan),
            capacity_bytes=min(hw_capacity, cap_bytes) if cap_bytes else hw_capacity,
            deploy_time_s=predict_deploy_time(
                plan.targets_per_node, runtime=plan.runtime, fresh=True
            ),
            created_at=now,
            idle_since=now,        # born idle: TTL applies until the first lease
            base_dir=base_dir,
        )
        self._pools[pool_id] = pool
        self.catalog.register_pool(pool_id)
        self.stats.pools_created += 1
        self._epoch += 1
        rec = self._recorder
        if rec.enabled:
            rec.pool_created(pool, now)
        return pool

    def retire(self, pool: StoragePool, now: Optional[float] = None) -> bool:
        """Stop granting leases; tear down once (or as soon as) drained.
        Returns True if the pool was torn down immediately."""
        now = self._now(now)
        if pool.state is PoolState.RETIRED:
            raise AllocationError(f"pool {pool.name!r} is already retired")
        pool.state = PoolState.DRAINING
        self._epoch += 1
        rec = self._recorder
        if rec.enabled:
            rec.pool_retired(pool, now)
        if pool.n_leases == 0:
            self._teardown(pool, now)
            return True
        return False

    def reap_idle(self, now: Optional[float] = None) -> list[StoragePool]:
        """TTL expiry — the only teardown path besides last-lease drain."""
        now = self._now(now)
        if self.ttl_s is None:
            return []
        reaped = []
        for pool in list(self._pools.values()):
            if (
                pool.state is PoolState.ACTIVE
                and pool.n_leases == 0
                and pool.idle_since is not None
                and now - pool.idle_since >= self.ttl_s
            ):
                self._teardown(pool, now)
                reaped.append(pool)
        return reaped

    def _teardown(self, pool: StoragePool, now: float) -> None:
        assert pool.n_leases == 0, "teardown with live leases"
        self.scheduler.release(pool.allocation)
        for extra in pool.extra_allocations:
            self.scheduler.release(extra)
        pool.extra_allocations.clear()
        if pool.base_dir is not None:
            self.provisioner.release_tree(pool.base_dir)
            self.provisioner.forget_tree(pool.base_dir)
        self.catalog.drop_pool(pool.pool_id)
        pool.dataset_bytes.clear()
        pool.scratch_bytes = 0.0
        pool.state = PoolState.RETIRED
        pool.retired_at = now
        self.stats.pools_retired += 1
        self._epoch += 1
        rec = self._recorder
        if rec.enabled:
            rec.pool_torn_down(pool, now)

    # -- failure domain (chaos engine) -------------------------------------------
    def affected_pools(self, node_id: str) -> tuple[StoragePool, ...]:
        """Live pools whose backing nodes include ``node_id``."""
        return tuple(
            p for p in self.live_pools if node_id in p.storage_node_ids
        )

    def on_node_down(
        self, pool: StoragePool, node_id: str, now: Optional[float] = None
    ) -> None:
        """Absorb the loss of one backing node.

        Striping puts every dataset on every node, so the pool's residency
        is invalidated wholesale: every unpinned catalog entry drops (the
        next reference is a miss that re-stages — evicted data is never
        served stale) and its ledger bytes are uncharged. Callers fail the
        pool's leaseholders *first* (releasing their leases unpins), so by
        the time this runs nothing should still be pinned. Capacity shrinks
        by what the *surviving* backing hardware can no longer cover — a
        ledger quota sitting below hardware may lose nothing at all; a pool
        that loses its last backing node is retired outright. Healing —
        :meth:`backfill` on a retry policy, or the node's own repair via
        :meth:`on_node_repair` — restores exactly the share deducted here.
        """
        now = self._now(now)
        if node_id in pool.dead_node_capacity or node_id in pool.replaced_node_ids:
            return
        node = next(
            (n for n in pool.allocation.storage_nodes if n.node_id == node_id), None
        )
        if node is None:
            # a backfill spare died: drop its allocation back to the
            # scheduler (which parks the dead node) and shed its share
            for extra in list(pool.extra_allocations):
                if any(n.node_id == node_id for n in extra.storage_nodes):
                    share = self._capacity_loss(pool, node_id)
                    pool.extra_allocations.remove(extra)
                    self._invalidate_residency(pool)
                    pool.capacity_bytes -= share
                    self.scheduler.release(extra)
                    break
            self._epoch += 1
            return
        self._invalidate_residency(pool)
        share = self._capacity_loss(pool, node_id)
        pool.capacity_bytes -= share
        pool.dead_node_capacity[node_id] = share
        self._epoch += 1
        if not pool.storage_node_ids and pool.state is PoolState.ACTIVE:
            # nothing left to serve from: stop granting; the last lease
            # drain (or this call, if none are live) tears it down
            self.retire(pool, now)

    def _capacity_loss(self, pool: StoragePool, node_id: str) -> float:
        """Ledger bytes the pool loses with ``node_id`` gone: only what the
        surviving backing hardware cannot absorb (the ledger quota may sit
        well below hardware, in which case a node loss costs nothing)."""
        cap = self.scheduler.policy.node_capacity_bytes
        alive_hw = sum(
            cap(n)
            for n in pool.allocation.storage_nodes
            if n.node_id != node_id
            and n.node_id not in pool.dead_node_capacity
            and n.node_id not in pool.replaced_node_ids
        )
        alive_hw += sum(
            cap(n)
            for extra in pool.extra_allocations
            for n in extra.storage_nodes
            if n.node_id != node_id
        )
        return pool.capacity_bytes - min(pool.capacity_bytes, alive_hw)

    def _invalidate_residency(self, pool: StoragePool) -> None:
        """Drop every unpinned catalog entry (and its ledger charge)."""
        for r in self.catalog.entries(pool.pool_id):
            if r.pins == 0:
                self.catalog.invalidate(pool.pool_id, r.dataset.name)
                pool.uncharge_dataset(r.dataset.name)

    def on_node_repair(self, node_id: str, now: Optional[float] = None) -> None:
        """A dead node came back: pools still waiting on it re-silver it
        (capacity restored); pools that already backfilled past it keep
        their spare and leave the repaired chassis idle in the allocation."""
        now = self._now(now)
        for pool in self.live_pools:
            share = pool.dead_node_capacity.pop(node_id, None)
            if share is not None:
                pool.capacity_bytes += share
                self._epoch += 1
                rec = self._recorder
                if rec.enabled:
                    rec.rebuild(pool, node_id, via="repair", t=now)

    def backfill(self, pool: StoragePool, now: Optional[float] = None) -> bool:
        """One self-heal attempt: claim a free storage node to replace the
        longest-dead unreplaced node. Returns True when a spare was granted
        (capacity restored); False when the cluster has no free node right
        now — callers retry on a :class:`~repro.chaos.RetryPolicy` cadence.
        """
        now = self._now(now)
        if not pool.dead_node_capacity or pool.state is not PoolState.ACTIVE:
            return False
        dead_id = min(pool.dead_node_capacity)
        alloc = self.scheduler.try_submit(
            JobRequest(
                f"{pool.name}-heal-{dead_id}", 0, storage=StorageRequest(nodes=1)
            )
        )
        if alloc is None:
            return False
        share = pool.dead_node_capacity.pop(dead_id)
        pool.replaced_node_ids.add(dead_id)
        pool.extra_allocations.append(alloc)
        pool.capacity_bytes += share
        self._epoch += 1
        rec = self._recorder
        if rec.enabled:
            rec.rebuild(pool, dead_id, via="backfill", t=now)
        return True

    # -- introspection -----------------------------------------------------------
    @property
    def pools(self) -> tuple[StoragePool, ...]:
        return tuple(self._pools.values())

    @property
    def live_pools(self) -> tuple[StoragePool, ...]:
        return tuple(p for p in self._pools.values() if p.state is not PoolState.RETIRED)

    @property
    def active_pools(self) -> tuple[StoragePool, ...]:
        return tuple(p for p in self._pools.values() if p.state is PoolState.ACTIVE)

    def get(self, pool_id: int) -> StoragePool:
        return self._pools[pool_id]

    def occupancy(self) -> float:
        """Mean ledger occupancy over live pools (a campaign-report metric)."""
        live = self.live_pools
        return sum(p.occupancy for p in live) / len(live) if live else 0.0

    def feasible(
        self, datasets: Sequence[DatasetRef], scratch_bytes: float = 0.0
    ) -> bool:
        """Could some pool *ever* hold this working set (full capacity,
        worst case of nothing resident)? The orchestrator's fail-fast check
        for pool-backed jobs. Only ACTIVE pools count: a DRAINING pool never
        grants another lease, so its capacity is a promise that cannot be
        kept."""
        need = total_bytes(datasets) + scratch_bytes
        return any(p.capacity_bytes >= need for p in self.active_pools)

    def resident_fraction(self, datasets: Sequence[DatasetRef]) -> float:
        """Best-pool fraction of these datasets' bytes already resident —
        the ranking signal for ``DataAwarePolicy``."""
        total = total_bytes(datasets)
        if total <= 0 or not self.active_pools:
            return 0.0
        return max(
            self.catalog.resident_bytes(p.pool_id, datasets) / total
            for p in self.active_pools
        )

    # -- leasing -----------------------------------------------------------------
    def try_acquire(
        self,
        job_name: str,
        datasets: Iterable[DatasetRef],
        scratch_bytes: float = 0.0,
        *,
        now: Optional[float] = None,
    ) -> Optional[Lease]:
        """Grant a lease from the best candidate pool, or None if no ACTIVE
        pool can fit the working set right now (callers keep the job queued).

        Candidates are ranked data-aware: most resident bytes for these
        datasets first, then most free space.
        """
        now = self._now(now)
        datasets = tuple(datasets)
        ranked = sorted(
            self.active_pools,
            key=lambda p: (
                -self.catalog.resident_bytes(p.pool_id, datasets),
                -p.free_bytes,
                p.pool_id,
            ),
        )
        for pool in ranked:
            lease = self._acquire_on(pool, job_name, datasets, scratch_bytes, now)
            if lease is not None:
                return lease
        return None

    def _acquire_on(
        self,
        pool: StoragePool,
        job_name: str,
        datasets: tuple[DatasetRef, ...],
        scratch_bytes: float,
        now: float,
    ) -> Optional[Lease]:
        if len({d.name for d in datasets}) != len(datasets):
            raise ValueError(f"{job_name!r}: duplicate dataset names in request")
        tracked = [d for d in datasets if self.catalog.lookup(pool.pool_id, d.name)]
        hits = [d for d in tracked if self.catalog.resident(pool.pool_id, d.name)]
        missing = [d for d in datasets if d not in hits]
        to_charge = [d for d in missing if d not in tracked]   # untracked misses
        need = scratch_bytes + sum(d.nbytes for d in to_charge)

        # Pin what we will read *before* evicting, so the eviction pass can
        # neither victimize this lease's hits nor a sibling's inflight stage.
        for d in tracked:
            self.catalog.pin(pool.pool_id, d.name)
        if not self.evictor.make_room(pool, self.catalog, need):
            for d in tracked:
                self.catalog.unpin(pool.pool_id, d.name)
            return None

        for d in to_charge:
            pool.charge_dataset(d)
            self.catalog.add(pool.pool_id, d, now)   # INFLIGHT until staged
            self.catalog.pin(pool.pool_id, d.name)
        pool.reserve_scratch(scratch_bytes)
        for d in hits:
            self.catalog.touch(pool.pool_id, d.name, now)

        lease = Lease(
            lease_id=next(self._lease_ids),
            pool_id=pool.pool_id,
            job_name=job_name,
            scratch_bytes=scratch_bytes,
            datasets=datasets,
            missing=tuple(missing),
            resident_bytes=sum(d.nbytes for d in hits),
            granted_at=now,
        )
        pool.attach(lease)
        self.stats.leases_granted += 1
        self.stats.dataset_hits += len(hits)
        self.stats.dataset_misses += len(missing)
        self._epoch += 1
        rec = self._recorder
        if rec.enabled:
            rec.lease_attached(lease, pool, len(hits), len(missing), now)
        return lease

    def on_stage_in_complete(self, lease: Lease, now: Optional[float] = None) -> None:
        """The lease-holder finished staging its missing datasets: they are
        now servable (RESIDENT) for every later job routed to this pool.

        Byte counters live here, not at grant time: an attempt that faults
        before its stage-in completes neither staged nor saved anything, so
        ``bytes_saved`` and ``bytes_staged`` stay mutually consistent under
        retries."""
        now = self._now(now)
        self.stats.bytes_saved += lease.resident_bytes
        for d in lease.missing:
            entry = self.catalog.lookup(lease.pool_id, d.name)
            if entry is not None and entry.state is ResidencyState.INFLIGHT:
                self.catalog.mark_resident(lease.pool_id, d.name, now)
            self.stats.bytes_staged += d.nbytes
        for d in lease.datasets:
            self.catalog.touch(lease.pool_id, d.name, now)

    def release(self, lease: Lease, now: Optional[float] = None) -> bool:
        """Drop a lease: unpin datasets, roll back unfinished stages, free
        scratch. Returns True if this was the last lease of a DRAINING pool
        and the pool was torn down."""
        now = self._now(now)
        pool = self._pools[lease.pool_id]
        for d in lease.datasets:
            entry = self.catalog.lookup(pool.pool_id, d.name)
            if entry is None:
                continue
            self.catalog.unpin(pool.pool_id, d.name)
            if entry.pins == 0 and entry.state is ResidencyState.INFLIGHT:
                # the stage never completed (fault mid stage-in): no ghost bytes
                self.catalog.invalidate(pool.pool_id, d.name)
                pool.uncharge_dataset(d.name)
        pool.release_scratch(lease.scratch_bytes)
        pool.detach(lease.lease_id, now)
        self._epoch += 1
        rec = self._recorder
        if rec.enabled:
            rec.lease_released(lease, now)
        if pool.state is PoolState.DRAINING and pool.n_leases == 0:
            self._teardown(pool, now)
            return True
        return False

    # -- invariants (exercised by the property tests) -----------------------------
    def check_invariants(self) -> None:
        seen_nodes: set[str] = set()
        for pool in self.live_pools:
            pool.check_invariants()
            ids = pool.storage_node_ids
            assert not ids & seen_nodes, f"node in two live pools: {ids & seen_nodes}"
            seen_nodes |= ids
            charged = set(pool.dataset_bytes)
            tracked = {r.dataset.name for r in self.catalog.entries(pool.pool_id)}
            assert charged == tracked, (
                f"pool {pool.name!r}: ledger/catalog drift "
                f"{charged ^ tracked}"
            )
