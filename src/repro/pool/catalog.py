"""Data-aware catalog: which datasets are resident on which storage pool.

Data Diffusion (Raicu et al.) schedules work *to the data*: provisioned
storage acts as a cache of the global file system, and the scheduler needs a
catalog mapping logical dataset names to the pools whose trees already hold
them. This module is that catalog. Residency is tracked per (pool, dataset)
with an explicit state machine:

    INFLIGHT  -- a lease is staging the dataset in; its bytes are charged to
                 the pool ledger but the data is not yet servable. A second
                 job referencing it counts as a *miss* (it re-models the
                 stage time) but must not double-charge the ledger.
    RESIDENT  -- staged and servable; a referencing job is a cache *hit*.

Eviction invalidates the entry outright — there is no "stale" state a reader
could be served from; the next reference is a miss and re-stages (the
acceptance invariant: evicted datasets are re-staged, never served stale).
Pins (one per live lease referencing the entry) make an entry ineligible for
eviction while any job may read it.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Iterable, Optional, Sequence


@dataclasses.dataclass(frozen=True)
class DatasetRef:
    """A logical dataset: name -> bytes (optionally a global-FS tree path)."""

    name: str
    nbytes: float
    tree: Optional[str] = None          # source directory on the global FS

    def __post_init__(self) -> None:
        if not self.name:
            raise ValueError("dataset name must be non-empty")
        if self.nbytes <= 0:
            raise ValueError(f"dataset {self.name!r}: nbytes must be positive")


class ResidencyState(enum.Enum):
    INFLIGHT = "inflight"
    RESIDENT = "resident"


@dataclasses.dataclass
class Residency:
    """One dataset's presence on one pool."""

    dataset: DatasetRef
    pool_id: int
    state: ResidencyState
    pins: int = 0
    last_touch: float = 0.0
    staged_at: Optional[float] = None

    @property
    def evictable(self) -> bool:
        return self.pins == 0 and self.state is ResidencyState.RESIDENT


class DataCatalog:
    """Residency index over every live pool; the routing side of the pool
    subsystem (``DataAwarePolicy`` ranks queued jobs by what it answers)."""

    def __init__(self) -> None:
        self._by_pool: dict[int, dict[str, Residency]] = {}
        #: bumped on every mutation that can change what is resident where
        #: (add / mark_resident / invalidate / drop_pool — not touch or
        #: pin, which only steer eviction). Consumers caching anything
        #: derived from residency (negotiated offers, data-aware policy
        #: keys) invalidate against it.
        self.version = 0

    # -- pool lifecycle -------------------------------------------------------
    def register_pool(self, pool_id: int) -> None:
        if pool_id in self._by_pool:
            raise ValueError(f"pool {pool_id} already registered")
        self._by_pool[pool_id] = {}

    def drop_pool(self, pool_id: int) -> list[Residency]:
        """Pool teardown: every entry vanishes with the pool's tree."""
        self.version += 1
        return list(self._by_pool.pop(pool_id, {}).values())

    # -- lookups --------------------------------------------------------------
    def lookup(self, pool_id: int, name: str) -> Optional[Residency]:
        return self._by_pool.get(pool_id, {}).get(name)

    def resident(self, pool_id: int, name: str) -> bool:
        r = self.lookup(pool_id, name)
        return r is not None and r.state is ResidencyState.RESIDENT

    def pools_holding(self, name: str) -> list[int]:
        """Pools on which ``name`` is RESIDENT (servable right now)."""
        return [
            pid
            for pid, entries in self._by_pool.items()
            if (r := entries.get(name)) is not None
            and r.state is ResidencyState.RESIDENT
        ]

    def resident_bytes(self, pool_id: int, datasets: Sequence[DatasetRef]) -> float:
        """Bytes of ``datasets`` servable from ``pool_id`` (the hit volume)."""
        return sum(d.nbytes for d in datasets if self.resident(pool_id, d.name))

    def entries(self, pool_id: int) -> tuple[Residency, ...]:
        return tuple(self._by_pool.get(pool_id, {}).values())

    # -- mutation (driven by the PoolManager) ---------------------------------
    def add(
        self,
        pool_id: int,
        dataset: DatasetRef,
        now: float,
        *,
        state: ResidencyState = ResidencyState.INFLIGHT,
    ) -> Residency:
        entries = self._by_pool[pool_id]
        if dataset.name in entries:
            raise ValueError(f"{dataset.name!r} already tracked on pool {pool_id}")
        r = Residency(dataset=dataset, pool_id=pool_id, state=state, last_touch=now)
        entries[dataset.name] = r
        self.version += 1
        return r

    def mark_resident(self, pool_id: int, name: str, now: float) -> None:
        r = self._require(pool_id, name)
        r.state = ResidencyState.RESIDENT
        r.staged_at = now
        r.last_touch = now
        self.version += 1

    def touch(self, pool_id: int, name: str, now: float) -> None:
        self._require(pool_id, name).last_touch = now

    def pin(self, pool_id: int, name: str) -> None:
        self._require(pool_id, name).pins += 1

    def unpin(self, pool_id: int, name: str) -> None:
        r = self._require(pool_id, name)
        if r.pins <= 0:
            raise ValueError(f"{name!r} on pool {pool_id} is not pinned")
        r.pins -= 1

    def invalidate(self, pool_id: int, name: str) -> Residency:
        """Remove an entry (eviction, or an INFLIGHT stage that failed).

        Pinned entries cannot be invalidated: a live lease may read them.
        """
        r = self._require(pool_id, name)
        if r.pins > 0:
            raise ValueError(f"cannot invalidate pinned {name!r} on pool {pool_id}")
        del self._by_pool[pool_id][name]
        self.version += 1
        return r

    # -- eviction support ------------------------------------------------------
    def evictable(self, pool_id: int) -> list[Residency]:
        """Unpinned RESIDENT entries, least-recently-touched first (LRU)."""
        return sorted(
            (r for r in self._by_pool.get(pool_id, {}).values() if r.evictable),
            key=lambda r: (r.last_touch, r.dataset.name),
        )

    def _require(self, pool_id: int, name: str) -> Residency:
        r = self.lookup(pool_id, name)
        if r is None:
            raise KeyError(f"dataset {name!r} not tracked on pool {pool_id}")
        return r


def total_bytes(datasets: Iterable[DatasetRef]) -> float:
    return sum(d.nbytes for d in datasets)
