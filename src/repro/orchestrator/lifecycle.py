"""Job lifecycle state machine over the provisioning pipeline.

The paper's mechanism is a workflow — allocate compute+storage, deploy the
on-demand file system, stage in, run, stage out, tear down — and this module
wires the repo's pieces (`Scheduler`, `Provisioner`, staging model, fault
injection) into one event-driven pipeline:

    QUEUED -> ALLOCATED -> PROVISIONING -> STAGING_IN -> RUNNING
           -> STAGING_OUT -> TEARDOWN -> DONE
                                 \\-> (fault) -> requeue or FAILED

Every phase duration comes from the calibrated perfmodel: deployment time
is C8 (`predict_deploy_time`, warm on retries over the same tree), staging
time is the slower of the global-FS read and ephemeral-FS write paths
(`modeled_stage_time`), and the run phase is the job's own compute time.
A `FaultInjector` may trip any phase; a tripped job releases its nodes and
requeues (up to ``max_retries``) — the retry pays a *warm* redeploy, the
paper's §IV-B1 1.2 s vs 4.6 s observation.

**Pool-backed jobs** (``WorkflowSpec.use_pool`` with a `PoolManager` attached
via :meth:`Orchestrator.enable_pools`) ride the same state machine but swap
the expensive edges for persistent-pool ones: instead of allocating storage
nodes and deploying a fresh file system, they acquire a *lease* on a
long-lived pool — the PROVISIONING slot costs only the lease attach, the
TEARDOWN slot is free (the pool outlives the job), and STAGING_IN moves only
the dataset bytes *not already resident* on the granted pool (plus the job's
private scratch). Datasets staged by one job are cache hits for the next.
"""

from __future__ import annotations

import dataclasses
import enum
import itertools
from typing import Optional

from ..core.perfmodel import FSDeployment, dom_lustre, predict_deploy_time
from ..core.provisioner import Provisioner
from ..core.scheduler import (
    Allocation,
    AllocationError,
    JobRequest,
    Scheduler,
    StorageRequest,
)
from ..core.staging import modeled_stage_time
from ..pool.catalog import DatasetRef, total_bytes
from ..pool.manager import PoolManager
from ..pool.pool import Lease
from ..runtime.fault import FaultInjector
from .engine import SimEngine
from .policies import FIFOPolicy, QueuePolicy


class JobState(enum.Enum):
    QUEUED = "queued"
    ALLOCATED = "allocated"
    PROVISIONING = "provisioning"
    STAGING_IN = "staging_in"
    RUNNING = "running"
    STAGING_OUT = "staging_out"
    TEARDOWN = "teardown"
    DONE = "done"
    FAILED = "failed"


TERMINAL_STATES = frozenset({JobState.DONE, JobState.FAILED})

# Lifecycle phase -> the FaultInjector phase name consulted at its end.
_FAULT_PHASE = {
    JobState.PROVISIONING: "provision",
    JobState.STAGING_IN: "stage_in",
    JobState.RUNNING: "run",
    JobState.STAGING_OUT: "stage_out",
}


@dataclasses.dataclass(frozen=True)
class WorkflowSpec:
    """One job's demands on the provisioning pipeline.

    ``datasets`` are *shared* inputs by reference (`DatasetRef`): a pool-backed
    job (``use_pool=True``) only stages the ones not already resident on its
    granted pool, while a job-scoped job re-stages all of them every time.
    ``stage_in_bytes``/``stage_out_bytes`` remain the job's private traffic.
    """

    name: str
    n_compute: int
    storage: Optional[StorageRequest] = None
    stage_in_bytes: float = 0.0
    stage_out_bytes: float = 0.0
    run_time_s: float = 60.0
    n_streams: int = 8
    max_retries: int = 2
    runtime: str = "shifter"
    datasets: tuple = ()              # tuple[DatasetRef, ...] shared inputs
    use_pool: bool = False

    def __post_init__(self) -> None:
        object.__setattr__(self, "datasets", tuple(self.datasets))
        if self.run_time_s < 0 or self.stage_in_bytes < 0 or self.stage_out_bytes < 0:
            raise ValueError(f"negative duration/bytes in spec {self.name!r}")
        if self.max_retries < 0:
            raise ValueError("max_retries must be >= 0")
        if any(not isinstance(d, DatasetRef) for d in self.datasets):
            raise ValueError(f"{self.name!r}: datasets must be DatasetRef instances")
        if len({d.name for d in self.datasets}) != len(self.datasets):
            raise ValueError(f"{self.name!r}: duplicate dataset names")
        if self.use_pool and self.storage is not None:
            raise ValueError(
                f"{self.name!r}: use_pool jobs lease pool capacity; "
                "drop the per-job storage request"
            )
        if (
            self.storage is None
            and not self.use_pool
            and (self.stage_in_bytes or self.stage_out_bytes or self.datasets)
        ):
            raise ValueError(f"{self.name!r}: staging bytes without a storage request")

    @property
    def dataset_bytes(self) -> float:
        return total_bytes(self.datasets)

    @property
    def scratch_bytes(self) -> float:
        """Private pool capacity a lease must reserve on top of datasets."""
        return self.stage_in_bytes + self.stage_out_bytes


@dataclasses.dataclass
class JobRecord:
    """Mutable per-job bookkeeping the orchestrator and metrics share."""

    spec: WorkflowSpec
    job_id: int
    submit_time: float
    state: JobState = JobState.QUEUED
    attempt: int = 0
    allocation: Optional[Allocation] = None
    alloc_started: Optional[float] = None
    fs_model: Optional[FSDeployment] = None
    failure_phase: Optional[str] = None
    # storage nodes holding a fully-deployed tree of this job's FS: a retry
    # landing on these nodes redeploys warm (paper §IV-B1)
    warm_nodes: frozenset = frozenset()
    history: list[tuple[JobState, float]] = dataclasses.field(default_factory=list)
    # closed (alloc_time, release_time, n_storage_nodes) intervals per attempt
    storage_intervals: list[tuple[float, float, int]] = dataclasses.field(
        default_factory=list
    )
    staged_in_bytes: float = 0.0
    staged_out_bytes: float = 0.0
    # pool-backed bookkeeping (summed across retries)
    lease: Optional[Lease] = None
    pool_id: Optional[int] = None
    dataset_hits: int = 0
    dataset_misses: int = 0
    stage_in_saved_bytes: float = 0.0

    @property
    def request(self) -> JobRequest:
        # pool-backed jobs draw storage from a lease, not the scheduler
        storage = None if self.spec.use_pool else self.spec.storage
        return JobRequest(self.spec.name, self.spec.n_compute, storage=storage)

    @property
    def done(self) -> bool:
        return self.state in TERMINAL_STATES


class Orchestrator:
    """Runs provisioning campaigns: many jobs through one cluster, queued
    by policy, timed by the perfmodel, perturbed by fault injection."""

    def __init__(
        self,
        cluster,
        *,
        policy: QueuePolicy | None = None,
        faults: FaultInjector | None = None,
        engine: SimEngine | None = None,
        globalfs_model: FSDeployment | None = None,
        teardown_time_s: float = 0.5,
    ):
        self.engine = engine or SimEngine()
        self.scheduler = Scheduler(cluster)
        self.provisioner = Provisioner(cluster)
        self.policy = policy or FIFOPolicy()
        self.faults = faults or FaultInjector()
        self.globalfs_model = globalfs_model or dom_lustre()
        self.teardown_time_s = teardown_time_s
        self.pools: Optional[PoolManager] = None
        self.queue: list[JobRecord] = []
        self.jobs: list[JobRecord] = []
        self._ids = itertools.count(1)

    # -- pools ----------------------------------------------------------------
    def enable_pools(self, **kwargs) -> PoolManager:
        """Attach a persistent-pool subsystem over this orchestrator's own
        scheduler/provisioner. Create pools on the returned manager before
        (or during) the campaign; ``use_pool`` jobs lease from them."""
        kwargs.setdefault("clock", lambda: self.engine.now)
        self.pools = PoolManager(self.scheduler, self.provisioner, **kwargs)
        return self.pools

    # -- submission ----------------------------------------------------------
    def submit(self, spec: WorkflowSpec, at: Optional[float] = None) -> JobRecord:
        """Enqueue a job at virtual time ``at`` (default: now)."""
        if spec.use_pool and self.pools is None:
            raise ValueError(
                f"{spec.name!r}: use_pool requires enable_pools() first"
            )
        t = self.engine.now if at is None else at
        job = JobRecord(spec=spec, job_id=next(self._ids), submit_time=t)
        self.jobs.append(job)
        self.engine.at(t, lambda: self._arrive(job))
        return job

    def _arrive(self, job: JobRecord) -> None:
        try:
            feasible = self.scheduler.feasible(job.request)
        except AllocationError:
            feasible = False
        if feasible and job.spec.use_pool:
            # no pool could ever hold the working set -> fail fast
            feasible = self.pools.feasible(job.spec.datasets, job.spec.scratch_bytes)
        if not feasible:
            # Never satisfiable on this cluster: fail fast instead of letting
            # an AllocationError escape the campaign (or queueing forever).
            job.failure_phase = "infeasible"
            self._transition(job, JobState.QUEUED)
            self._transition(job, JobState.FAILED)
            return
        self._transition(job, JobState.QUEUED)
        self.queue.append(job)
        self._dispatch()

    # -- dispatch loop -------------------------------------------------------
    def _dispatch(self) -> None:
        """Start every queued job the policy admits against the free pool."""
        started = True
        while started and self.queue:
            started = False
            for job in self.policy.order(self.queue, self.scheduler, self.engine.now):
                lease = None
                if job.spec.use_pool:
                    if not self.pools.feasible(
                        job.spec.datasets, job.spec.scratch_bytes
                    ):
                        # every pool that could have held this working set is
                        # gone (retired/reaped): fail fast instead of
                        # stranding the job in the queue forever
                        self.queue.remove(job)
                        job.failure_phase = "infeasible"
                        self._transition(job, JobState.FAILED)
                        started = True
                        break
                    # check compute first (side-effect free), then lease: a
                    # failed compute fit must not evict datasets for nothing
                    if not self.scheduler.can_allocate(job.request):
                        if self.policy.head_blocking:
                            break
                        continue
                    lease = self.pools.try_acquire(
                        job.spec.name,
                        job.spec.datasets,
                        job.spec.scratch_bytes,
                        now=self.engine.now,
                    )
                    if lease is None:
                        if self.policy.head_blocking:
                            break
                        continue
                alloc = self.scheduler.try_submit(job.request)
                if alloc is None:
                    if lease is not None:
                        self.pools.release(lease, self.engine.now)
                    if self.policy.head_blocking:
                        break
                    continue
                self.queue.remove(job)
                self._start(job, alloc, lease)
                started = True
                break                 # re-ask the policy: free pool changed

    def _start(
        self, job: JobRecord, alloc: Allocation, lease: Optional[Lease] = None
    ) -> None:
        job.allocation = alloc
        job.alloc_started = self.engine.now
        self._transition(job, JobState.ALLOCATED)
        if lease is not None:
            # pool-backed: the file system is already running; the
            # PROVISIONING slot costs only the lease attach (no C8 deploy)
            job.lease = lease
            job.pool_id = lease.pool_id
            job.dataset_hits += lease.hits
            job.dataset_misses += lease.misses
            job.fs_model = self.pools.get(lease.pool_id).fs_model
            t_prov = self.pools.lease_attach_s
        elif alloc.storage_nodes:
            plan = self.provisioner.plan_for(alloc, runtime=job.spec.runtime)
            job.fs_model = self.provisioner.model_for(plan)
            # warm only when every granted node already holds this job's
            # fully-deployed tree from an earlier attempt; a retry placed on
            # different nodes (or after a provisioning fault) deploys fresh
            ids = frozenset(n.node_id for n in alloc.storage_nodes)
            t_prov = predict_deploy_time(
                plan.targets_per_node,
                runtime=job.spec.runtime,
                fresh=not ids <= job.warm_nodes,
            )
        else:
            job.fs_model = None
            t_prov = 0.0
        self._enter_phase(job, JobState.PROVISIONING, t_prov)

    # -- phase machinery -----------------------------------------------------
    def _enter_phase(self, job: JobRecord, state: JobState, duration: float) -> None:
        self._transition(job, state)
        self.engine.after(duration, lambda: self._phase_done(job, state))

    def _phase_done(self, job: JobRecord, state: JobState) -> None:
        fault_phase = _FAULT_PHASE.get(state)
        if fault_phase is not None and self.faults.trip(job.spec.name, fault_phase):
            self._fail_attempt(job, fault_phase)
            return
        if state is JobState.PROVISIONING:
            if job.lease is None and job.allocation is not None:
                job.warm_nodes = job.warm_nodes | frozenset(
                    n.node_id for n in job.allocation.storage_nodes
                )
            self._enter_phase(job, JobState.STAGING_IN, self._stage_time(job, "in"))
        elif state is JobState.STAGING_IN:
            job.staged_in_bytes += self._stage_in_bytes(job)
            if job.lease is not None:
                # saved bytes count only when the stage-in actually completed
                # (a faulted attempt neither staged nor saved anything)
                job.stage_in_saved_bytes += job.lease.resident_bytes
                # missing datasets are now resident: hits for every later job
                self.pools.on_stage_in_complete(job.lease, self.engine.now)
            self._enter_phase(job, JobState.RUNNING, job.spec.run_time_s)
        elif state is JobState.RUNNING:
            self._enter_phase(job, JobState.STAGING_OUT, self._stage_time(job, "out"))
        elif state is JobState.STAGING_OUT:
            job.staged_out_bytes += job.spec.stage_out_bytes
            # pool-backed jobs release a lease, not a file system: teardown
            # costs nothing (the pool outlives the job)
            t_down = 0.0 if job.lease is not None else self.teardown_time_s
            self._enter_phase(job, JobState.TEARDOWN, t_down)
        elif state is JobState.TEARDOWN:
            self._release(job)
            self._transition(job, JobState.DONE)
            self._dispatch()

    def _stage_in_bytes(self, job: JobRecord) -> float:
        """Bytes STAGING_IN actually moves: private traffic plus the shared
        datasets this attempt must fetch (all of them for a job-scoped FS;
        only the lease's cache misses for a pool-backed one)."""
        if job.lease is not None:
            return job.spec.stage_in_bytes + total_bytes(job.lease.missing)
        return job.spec.stage_in_bytes + job.spec.dataset_bytes

    def _stage_time(self, job: JobRecord, direction: str) -> float:
        if direction == "in":
            nbytes = self._stage_in_bytes(job)
        else:
            nbytes = job.spec.stage_out_bytes
        if nbytes <= 0 or job.fs_model is None:
            return 0.0
        if direction == "in":       # global FS read feeds ephemeral FS write
            src, dst = self.globalfs_model, job.fs_model
        else:                       # drain back to the global store
            src, dst = job.fs_model, self.globalfs_model
        return modeled_stage_time(nbytes, src, dst, job.spec.n_streams)

    def _fail_attempt(self, job: JobRecord, phase: str) -> None:
        job.failure_phase = phase
        self._release(job)
        job.attempt += 1
        if job.attempt > job.spec.max_retries:
            self._transition(job, JobState.FAILED)
        else:
            self._transition(job, JobState.QUEUED)
            self.queue.append(job)
        self._dispatch()

    def _release(self, job: JobRecord) -> None:
        if job.lease is not None:
            self.pools.release(job.lease, self.engine.now)
            job.lease = None
            if self.pools.ttl_s is not None:
                self.engine.after(self.pools.ttl_s, self._reap_pools)
        if job.allocation is None:
            return
        t0 = job.alloc_started if job.alloc_started is not None else self.engine.now
        job.storage_intervals.append(
            (t0, self.engine.now, len(job.allocation.storage_nodes))
        )
        self.scheduler.release(job.allocation)
        job.allocation = None
        job.alloc_started = None
        job.fs_model = None

    def _reap_pools(self) -> None:
        """TTL check scheduled after each lease release. Never reaps while
        any pool-backed job has yet to run — queued now, requeued after a
        fault, or submitted with a future arrival time — because a reaped
        pool could strand it (or fail it spuriously as infeasible)."""
        if self.pools is None:
            return
        if any(
            j.spec.use_pool and not j.done and j.lease is None
            for j in self.jobs
        ):
            return
        self.pools.reap_idle(self.engine.now)

    def _transition(self, job: JobRecord, state: JobState) -> None:
        job.state = state
        job.history.append((state, self.engine.now))

    # -- campaign driver -----------------------------------------------------
    def run_campaign(
        self,
        specs: Optional[list[WorkflowSpec]] = None,
        *,
        submit_times: Optional[list[float]] = None,
        until: Optional[float] = None,
    ) -> list[JobRecord]:
        """Submit ``specs`` (if given), drain the event loop, return records.

        ``submit_times`` gives each spec its own arrival instant (e.g. from
        :func:`repro.orchestrator.arrivals.poisson_arrivals` or a replayed
        trace) instead of the batch-at-now default; it must match ``specs``
        in length, and no time may predate the engine clock.

        Guarantees every job reaches a terminal state (DONE or FAILED) unless
        ``until`` cut the clock short.
        """
        specs = specs or []
        if submit_times is not None:
            if len(submit_times) != len(specs):
                raise ValueError(
                    f"{len(submit_times)} submit times for {len(specs)} specs"
                )
            for spec, t in zip(specs, submit_times):
                self.submit(spec, at=t)
        else:
            for spec in specs:
                self.submit(spec)
        self.engine.run(until=until)
        return list(self.jobs)
