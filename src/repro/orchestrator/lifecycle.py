"""Job lifecycle state machine over the provisioning pipeline.

The paper's mechanism is a workflow — allocate compute+storage, deploy the
on-demand file system, stage in, run, stage out, tear down — and this module
drives it as one event-driven pipeline:

    QUEUED -> ALLOCATED -> PROVISIONING -> STAGING_IN -> RUNNING
           -> STAGING_OUT -> TEARDOWN -> DONE
                                 \\-> (fault) -> requeue or FAILED

Storage is obtained through exactly one path: every job's demands become a
declarative `StorageSpec`, the orchestrator's `ProvisioningService`
negotiates a backend (ephemeral FS, global FS, KV store, dry-run) and
grants a `StorageSession`, and the lifecycle advances its virtual clock by
the session's modeled costs (`provision_time_s` — C8 deploy, warm on
retries over the same nodes; `stage_in_time_s` — the slower of the
global-FS read and backend write paths; `teardown_time_s`). Releasing a
session returns whatever it held — nodes + file system for a job-scoped
grant, a pool lease for a pooled one — so teardown-vs-lease-drain is
session policy, not lifecycle code. A `FaultInjector` may trip any phase;
a tripped job releases its session and requeues (up to ``max_retries``),
the retry paying a *warm* redeploy when it lands on the same nodes (§IV-B1).

**Pool-backed jobs** (a POOLED `StorageSpec`, or the legacy
``WorkflowSpec(use_pool=True)``) ride the same state machine: negotiation
resolves them to a lease on a live persistent pool, the PROVISIONING slot
costs only the lease attach, TEARDOWN is free (the pool outlives the job),
and STAGING_IN moves only the dataset bytes *not already resident* on the
granted pool. Datasets staged by one job are cache hits for the next.
"""

from __future__ import annotations

import dataclasses
import enum
import itertools
from typing import Optional

from ..core.perfmodel import FSDeployment, dom_lustre
from ..core.scheduler import Allocation, JobRequest, StorageRequest
from ..pool.catalog import DatasetRef, total_bytes
from ..pool.manager import PoolManager
from ..pool.pool import Lease
from ..provision import (
    LifetimeClass,
    NegotiationError,
    Offer,
    ProvisioningService,
    StorageSession,
    StorageSpec,
)
from ..runtime.fault import FaultInjector
from .engine import SimEngine
from .policies import FIFOPolicy, QueuePolicy


class JobState(enum.Enum):
    QUEUED = "queued"
    ALLOCATED = "allocated"
    PROVISIONING = "provisioning"
    STAGING_IN = "staging_in"
    RUNNING = "running"
    STAGING_OUT = "staging_out"
    TEARDOWN = "teardown"
    DONE = "done"
    FAILED = "failed"


TERMINAL_STATES = frozenset({JobState.DONE, JobState.FAILED})

# Lifecycle phase -> the FaultInjector phase name consulted at its end.
_FAULT_PHASE = {
    JobState.PROVISIONING: "provision",
    JobState.STAGING_IN: "stage_in",
    JobState.RUNNING: "run",
    JobState.STAGING_OUT: "stage_out",
}


@dataclasses.dataclass(frozen=True)
class WorkflowSpec:
    """One job's demands on the provisioning pipeline.

    Storage demands are best stated as a declarative ``storage_spec``
    (:class:`~repro.provision.StorageSpec`): preferred data managers with
    fallbacks, lifetime class, datasets, QoS. The legacy fields
    (``storage=StorageRequest(...)``, ``use_pool``, ``datasets``) remain
    supported and are translated into an equivalent spec pinned to the
    ``ephemeralfs`` backend — they cannot be mixed with ``storage_spec``.
    """

    name: str
    n_compute: int
    storage: Optional[StorageRequest] = None
    stage_in_bytes: float = 0.0
    stage_out_bytes: float = 0.0
    run_time_s: float = 60.0
    n_streams: int = 8
    max_retries: int = 2
    runtime: str = "shifter"
    datasets: tuple = ()              # tuple[DatasetRef, ...] shared inputs
    use_pool: bool = False
    storage_spec: Optional[StorageSpec] = None

    def __post_init__(self) -> None:
        object.__setattr__(self, "datasets", tuple(self.datasets))
        if self.run_time_s < 0 or self.stage_in_bytes < 0 or self.stage_out_bytes < 0:
            raise ValueError(f"negative duration/bytes in spec {self.name!r}")
        if self.max_retries < 0:
            raise ValueError("max_retries must be >= 0")
        if self.storage_spec is not None:
            if (
                self.storage is not None
                or self.use_pool
                or self.datasets
                or self.stage_in_bytes
                or self.stage_out_bytes
                or self.n_streams != 8
                or self.runtime != "shifter"
            ):
                raise ValueError(
                    f"{self.name!r}: storage_spec replaces the legacy storage/"
                    "use_pool/datasets/stage_*_bytes/n_streams/runtime fields "
                    "(they all belong on the StorageSpec); set one or the other"
                )
            return
        if any(not isinstance(d, DatasetRef) for d in self.datasets):
            raise ValueError(f"{self.name!r}: datasets must be DatasetRef instances")
        if len({d.name for d in self.datasets}) != len(self.datasets):
            raise ValueError(f"{self.name!r}: duplicate dataset names")
        if self.use_pool and self.storage is not None:
            raise ValueError(
                f"{self.name!r}: use_pool jobs lease pool capacity; "
                "drop the per-job storage request"
            )
        if (
            self.storage is None
            and not self.use_pool
            and (self.stage_in_bytes or self.stage_out_bytes or self.datasets)
        ):
            raise ValueError(f"{self.name!r}: staging bytes without a storage request")

    # -- the one storage path -------------------------------------------------
    def session_spec(self) -> Optional[StorageSpec]:
        """The declarative spec this job presents to the ProvisioningService
        (None for jobs with no storage demand at all). Legacy fields pin the
        ``ephemeralfs`` backend, preserving their original semantics."""
        if self.storage_spec is not None:
            return self.storage_spec
        if self.use_pool:
            return StorageSpec(
                name=self.name,
                lifetime=LifetimeClass.POOLED,
                managers=("ephemeralfs",),
                datasets=self.datasets,
                stage_in_bytes=self.stage_in_bytes,
                stage_out_bytes=self.stage_out_bytes,
                n_streams=self.n_streams,
                runtime=self.runtime,  # type: ignore[arg-type]
            )
        if self.storage is not None:
            return StorageSpec(
                name=self.name,
                nodes=self.storage.nodes,
                capacity_bytes=self.storage.capacity_bytes,
                bandwidth=self.storage.capability_bw,
                managers=("ephemeralfs",),
                datasets=self.datasets,
                stage_in_bytes=self.stage_in_bytes,
                stage_out_bytes=self.stage_out_bytes,
                n_streams=self.n_streams,
                runtime=self.runtime,  # type: ignore[arg-type]
            )
        return None

    @property
    def wants_pool(self) -> bool:
        return self.use_pool or (
            self.storage_spec is not None
            and self.storage_spec.lifetime is LifetimeClass.POOLED
        )

    @property
    def all_datasets(self) -> tuple:
        if self.storage_spec is not None:
            return self.storage_spec.datasets
        return self.datasets

    @property
    def dataset_bytes(self) -> float:
        return total_bytes(self.all_datasets)

    @property
    def scratch_bytes(self) -> float:
        """Private pool capacity a lease must reserve on top of datasets."""
        if self.storage_spec is not None:
            return self.storage_spec.scratch_bytes
        return self.stage_in_bytes + self.stage_out_bytes


@dataclasses.dataclass
class JobRecord:
    """Mutable per-job bookkeeping the orchestrator and metrics share."""

    spec: WorkflowSpec
    job_id: int
    submit_time: float
    state: JobState = JobState.QUEUED
    attempt: int = 0
    sspec: Optional[StorageSpec] = None          # resolved once at submit
    offer: Optional[Offer] = None                # cached non-POOLED negotiation
    session: Optional[StorageSession] = None     # live negotiated grant
    allocation: Optional[Allocation] = None
    alloc_started: Optional[float] = None
    fs_model: Optional[FSDeployment] = None
    failure_phase: Optional[str] = None
    backend: Optional[str] = None                # negotiated data manager
    # storage nodes holding a fully-deployed tree of this job's FS: a retry
    # landing on these nodes redeploys warm (paper §IV-B1)
    warm_nodes: frozenset = frozenset()
    history: list[tuple[JobState, float]] = dataclasses.field(default_factory=list)
    # closed (alloc_time, release_time, n_storage_nodes) intervals per attempt
    storage_intervals: list[tuple[float, float, int]] = dataclasses.field(
        default_factory=list
    )
    staged_in_bytes: float = 0.0
    staged_out_bytes: float = 0.0
    # pool-backed bookkeeping (summed across retries)
    lease: Optional[Lease] = None
    pool_id: Optional[int] = None
    dataset_hits: int = 0
    dataset_misses: int = 0
    stage_in_saved_bytes: float = 0.0

    @property
    def request(self) -> JobRequest:
        """Scheduler-level view of the job's demand (policies rank by it).
        Pool-backed jobs draw storage from a lease, not the allocator."""
        storage = None
        if self.sspec is not None and self.sspec.lifetime is not LifetimeClass.POOLED:
            storage = self.sspec.to_request()
        return JobRequest(self.spec.name, self.spec.n_compute, storage=storage)

    @property
    def done(self) -> bool:
        return self.state in TERMINAL_STATES


class Orchestrator:
    """Runs provisioning campaigns: many jobs through one cluster, queued
    by policy, timed by the perfmodel, perturbed by fault injection. All
    storage flows through one `ProvisioningService` (``self.provision``)."""

    def __init__(
        self,
        cluster,
        *,
        policy: QueuePolicy | None = None,
        faults: FaultInjector | None = None,
        engine: SimEngine | None = None,
        globalfs_model: FSDeployment | None = None,
        teardown_time_s: float | None = None,
        provision: ProvisioningService | None = None,
    ):
        self.engine = engine or SimEngine()
        if provision is None:
            provision = ProvisioningService(
                cluster,
                globalfs_model=globalfs_model or dom_lustre(),
                teardown_time_s=0.5 if teardown_time_s is None else teardown_time_s,
                clock=lambda: self.engine.now,
            )
        elif globalfs_model is not None or teardown_time_s is not None:
            raise ValueError(
                "globalfs_model/teardown_time_s are service knobs: configure "
                "them on the ProvisioningService you pass in"
            )
        self.provision = provision
        # sessions price TEARDOWN and staging from the service; mirror its
        # values so the orchestrator attributes never disagree with behavior
        self.teardown_time_s = self.provision.teardown_time_s
        self.globalfs_model = self.provision.globalfs_model
        self.scheduler = self.provision.scheduler
        self.provisioner = self.provision.provisioner
        self.policy = policy or FIFOPolicy()
        self.faults = faults or FaultInjector()
        self.queue: list[JobRecord] = []
        self.jobs: list[JobRecord] = []
        self._ids = itertools.count(1)

    # -- pools ----------------------------------------------------------------
    @property
    def pools(self) -> Optional[PoolManager]:
        """The service's pool subsystem (None until attached/first use)."""
        return self.provision.pool_manager

    def enable_pools(self, **kwargs) -> PoolManager:
        """Attach a persistent-pool subsystem over this orchestrator's
        provisioning service. Pools themselves are best created through the
        service (a PERSISTENT `StorageSpec`); ``use_pool``/POOLED jobs lease
        from them. A no-argument call returns the existing manager."""
        if self.provision.pool_manager is not None and not kwargs:
            return self.provision.pool_manager
        kwargs.setdefault("clock", lambda: self.engine.now)
        return self.provision.ensure_pools(**kwargs)

    # -- submission ----------------------------------------------------------
    def submit(self, spec: WorkflowSpec, at: Optional[float] = None) -> JobRecord:
        """Enqueue a job at virtual time ``at`` (default: now)."""
        if spec.wants_pool and self.provision.pool_manager is None:
            raise ValueError(
                f"{spec.name!r}: pooled storage requires enable_pools() (or a "
                "PERSISTENT session) first"
            )
        t = self.engine.now if at is None else at
        sspec = spec.session_spec()
        if sspec is None:
            # no storage demand: a dry-run session still co-allocates compute
            sspec = StorageSpec(name=spec.name, managers=("null",))
        job = JobRecord(
            spec=spec,
            job_id=next(self._ids),
            submit_time=t,
            sspec=sspec,
        )
        self.jobs.append(job)
        self.engine.at(t, lambda: self._arrive(job))
        return job

    def _arrive(self, job: JobRecord) -> None:
        feasible = job.spec.n_compute <= len(self.scheduler.cluster.compute_nodes)
        if feasible:
            try:
                offer = self.provision.negotiate(job.sspec)
            except NegotiationError:
                feasible = False
            else:
                if job.sspec.lifetime is not LifetimeClass.POOLED:
                    job.offer = offer   # static over the campaign: reuse at dispatch
        if not feasible:
            # No backend can ever serve this spec on this cluster: fail fast
            # instead of letting an error escape the campaign (or queueing
            # forever).
            job.failure_phase = "infeasible"
            self._transition(job, JobState.QUEUED)
            self._transition(job, JobState.FAILED)
            return
        self._transition(job, JobState.QUEUED)
        self.queue.append(job)
        self._dispatch()

    # -- dispatch loop -------------------------------------------------------
    def _dispatch(self) -> None:
        """Start every queued job the policy admits against the free pool."""
        started = True
        while started and self.queue:
            started = False
            for job in self.policy.order(self.queue, self.scheduler, self.engine.now):
                try:
                    session = self._try_open(job)
                except NegotiationError:
                    # what was feasible at arrival no longer is (e.g. every
                    # pool that could hold the working set was retired):
                    # fail fast instead of stranding the job in the queue
                    self.queue.remove(job)
                    job.failure_phase = "infeasible"
                    self._transition(job, JobState.FAILED)
                    started = True
                    break
                if session is None:
                    if self.policy.head_blocking:
                        break
                    continue
                self.queue.remove(job)
                self._start(job, session)
                started = True
                break                 # re-ask the policy: free pool changed

    def _try_open(self, job: JobRecord) -> Optional[StorageSession]:
        """One declarative call grants everything the job holds: compute
        nodes co-allocated with whatever storage the negotiated backend
        needs (nodes + deploy, a pool lease, or nothing)."""
        sspec = job.sspec
        offer = job.offer
        if offer is None:
            offer = self.provision.negotiate(sspec)   # may raise NegotiationError
            if sspec.lifetime is not LifetimeClass.POOLED:
                # EPHEMERAL/PERSISTENT feasibility is static over a campaign;
                # POOLED offers go stale as pools retire/drain, so those
                # re-negotiate on every dispatch attempt
                job.offer = offer
        return self.provision.try_open_session(
            sspec,
            n_compute=job.spec.n_compute,
            warm_nodes=job.warm_nodes,
            now=self.engine.now,
            offer=offer,
        )

    def _start(self, job: JobRecord, session: StorageSession) -> None:
        job.session = session
        job.allocation = session.allocation
        job.alloc_started = self.engine.now
        job.backend = session.backend
        self._transition(job, JobState.ALLOCATED)
        job.lease = session.lease
        if session.lease is not None:
            job.pool_id = session.lease.pool_id
            job.dataset_hits += session.lease.hits
            job.dataset_misses += session.lease.misses
        job.fs_model = session.fs_model
        self._enter_phase(job, JobState.PROVISIONING, session.provision_time_s)

    # -- phase machinery -----------------------------------------------------
    def _enter_phase(self, job: JobRecord, state: JobState, duration: float) -> None:
        self._transition(job, state)
        self.engine.after(duration, lambda: self._phase_done(job, state))

    def _phase_done(self, job: JobRecord, state: JobState) -> None:
        fault_phase = _FAULT_PHASE.get(state)
        if fault_phase is not None and self.faults.trip(job.spec.name, fault_phase):
            self._fail_attempt(job, fault_phase)
            return
        session = job.session
        if state is JobState.PROVISIONING:
            if session.lease is None and job.allocation is not None:
                job.warm_nodes = job.warm_nodes | frozenset(
                    n.node_id for n in job.allocation.storage_nodes
                )
            self._enter_phase(job, JobState.STAGING_IN, session.stage_in_time_s)
        elif state is JobState.STAGING_IN:
            job.staged_in_bytes += session.stage_in_bytes
            # saved bytes count only when the stage-in actually completed
            # (a faulted attempt neither staged nor saved anything)
            job.stage_in_saved_bytes += session.saved_bytes
            # lease misses are now resident: hits for every later job
            session.mark_staged(self.engine.now)
            self._enter_phase(job, JobState.RUNNING, job.spec.run_time_s)
        elif state is JobState.RUNNING:
            self._enter_phase(job, JobState.STAGING_OUT, session.stage_out_time_s)
        elif state is JobState.STAGING_OUT:
            job.staged_out_bytes += session.stage_out_bytes
            # pool-backed / always-on backends release for free (the data
            # manager outlives the job); only job-scoped deploys pay teardown
            self._enter_phase(job, JobState.TEARDOWN, session.teardown_time_s)
        elif state is JobState.TEARDOWN:
            self._release(job)
            self._transition(job, JobState.DONE)
            self._dispatch()

    def _fail_attempt(self, job: JobRecord, phase: str) -> None:
        job.failure_phase = phase
        self._release(job)
        job.attempt += 1
        if job.attempt > job.spec.max_retries:
            self._transition(job, JobState.FAILED)
        else:
            self._transition(job, JobState.QUEUED)
            self.queue.append(job)
        self._dispatch()

    def _release(self, job: JobRecord) -> None:
        session = job.session
        if session is None:
            return
        if job.allocation is not None:
            t0 = job.alloc_started if job.alloc_started is not None else self.engine.now
            job.storage_intervals.append(
                (t0, self.engine.now, len(job.allocation.storage_nodes))
            )
        pooled = session.lease is not None
        session.release(self.engine.now)
        job.session = None
        job.lease = None
        job.allocation = None
        job.alloc_started = None
        job.fs_model = None
        if pooled and self.pools is not None and self.pools.ttl_s is not None:
            self.engine.after(self.pools.ttl_s, self._reap_pools)

    def _reap_pools(self) -> None:
        """TTL check scheduled after each lease release. Never reaps while
        any pool-backed job has yet to run — queued now, requeued after a
        fault, or submitted with a future arrival time — because a reaped
        pool could strand it (or fail it spuriously as infeasible)."""
        if self.pools is None:
            return
        if any(
            j.spec.wants_pool and not j.done and j.lease is None
            for j in self.jobs
        ):
            return
        self.pools.reap_idle(self.engine.now)

    def _transition(self, job: JobRecord, state: JobState) -> None:
        job.state = state
        job.history.append((state, self.engine.now))

    # -- campaign driver -----------------------------------------------------
    def run_campaign(
        self,
        specs: Optional[list[WorkflowSpec]] = None,
        *,
        submit_times: Optional[list[float]] = None,
        until: Optional[float] = None,
    ) -> list[JobRecord]:
        """Submit ``specs`` (if given), drain the event loop, return records.

        ``submit_times`` gives each spec its own arrival instant (e.g. from
        :func:`repro.orchestrator.arrivals.poisson_arrivals` or a replayed
        trace) instead of the batch-at-now default; it must match ``specs``
        in length, and no time may predate the engine clock.

        Guarantees every job reaches a terminal state (DONE or FAILED) unless
        ``until`` cut the clock short.
        """
        specs = specs or []
        if submit_times is not None:
            if len(submit_times) != len(specs):
                raise ValueError(
                    f"{len(submit_times)} submit times for {len(specs)} specs"
                )
            for spec, t in zip(specs, submit_times):
                self.submit(spec, at=t)
        else:
            for spec in specs:
                self.submit(spec)
        self.engine.run(until=until)
        return list(self.jobs)
