"""Job lifecycle state machine over the provisioning pipeline.

The paper's mechanism is a workflow — allocate compute+storage, deploy the
on-demand file system, stage in, run, stage out, tear down — and this module
drives it as one event-driven pipeline:

    QUEUED -> ALLOCATED -> PROVISIONING -> STAGING_IN -> RUNNING
           -> STAGING_OUT -> TEARDOWN -> DONE
                                 \\-> (fault) -> requeue or FAILED

Storage is obtained through exactly one path: every job's demands become a
declarative `StorageSpec`, the orchestrator's `ProvisioningService`
negotiates a backend (ephemeral FS, global FS, KV store, dry-run) and
grants a `StorageSession`, and the lifecycle advances its virtual clock by
the session's modeled costs (`provision_time_s` — C8 deploy, warm on
retries over the same nodes; `stage_in_time_s` — the slower of the
global-FS read and backend write paths; `teardown_time_s`). Releasing a
session returns whatever it held — nodes + file system for a job-scoped
grant, a pool lease for a pooled one — so teardown-vs-lease-drain is
session policy, not lifecycle code. A `FaultInjector` may trip any phase;
a tripped job releases its session and requeues (up to ``max_retries``),
the retry paying a *warm* redeploy when it lands on the same nodes (§IV-B1).

**Pool-backed jobs** (a POOLED `StorageSpec`, or the legacy
``WorkflowSpec(use_pool=True)``) ride the same state machine: negotiation
resolves them to a lease on a live persistent pool, the PROVISIONING slot
costs only the lease attach, TEARDOWN is free (the pool outlives the job),
and STAGING_IN moves only the dataset bytes *not already resident* on the
granted pool. Datasets staged by one job are cache hits for the next.
"""

from __future__ import annotations

import dataclasses
import enum
import heapq
import itertools
from typing import Optional

from ..core.perfmodel import FSDeployment, dom_lustre
from ..core.scheduler import Allocation, JobRequest, StorageRequest
from ..pool.catalog import DatasetRef, total_bytes
from ..pool.manager import PoolManager
from ..pool.pool import Lease
from ..provision import (
    LifetimeClass,
    NegotiationError,
    Offer,
    ProvisioningService,
    StorageSession,
    StorageSpec,
)
from ..runtime.fault import FaultInjector
from .dispatch import DispatchQueue
from .engine import SimEngine
from .policies import FIFOPolicy, QueuePolicy


class JobState(enum.Enum):
    QUEUED = "queued"
    ALLOCATED = "allocated"
    PROVISIONING = "provisioning"
    STAGING_IN = "staging_in"
    RUNNING = "running"
    STAGING_OUT = "staging_out"
    TEARDOWN = "teardown"
    DONE = "done"
    FAILED = "failed"


TERMINAL_STATES = frozenset({JobState.DONE, JobState.FAILED})

# The FaultInjector phase names, consulted at the end of PROVISIONING /
# STAGING_IN / RUNNING / STAGING_OUT (see the per-phase _*_done handlers).


@dataclasses.dataclass(frozen=True)
class WorkflowSpec:
    """One job's demands on the provisioning pipeline.

    Storage demands are best stated as a declarative ``storage_spec``
    (:class:`~repro.provision.StorageSpec`): preferred data managers with
    fallbacks, lifetime class, datasets, QoS. The legacy fields
    (``storage=StorageRequest(...)``, ``use_pool``, ``datasets``) remain
    supported and are translated into an equivalent spec pinned to the
    ``ephemeralfs`` backend — they cannot be mixed with ``storage_spec``.
    """

    name: str
    n_compute: int
    storage: Optional[StorageRequest] = None
    stage_in_bytes: float = 0.0
    stage_out_bytes: float = 0.0
    run_time_s: float = 60.0
    n_streams: int = 8
    max_retries: int = 2
    runtime: str = "shifter"
    datasets: tuple = ()              # tuple[DatasetRef, ...] shared inputs
    use_pool: bool = False
    storage_spec: Optional[StorageSpec] = None

    def __post_init__(self) -> None:
        object.__setattr__(self, "datasets", tuple(self.datasets))
        if self.run_time_s < 0 or self.stage_in_bytes < 0 or self.stage_out_bytes < 0:
            raise ValueError(f"negative duration/bytes in spec {self.name!r}")
        if self.max_retries < 0:
            raise ValueError("max_retries must be >= 0")
        if self.storage_spec is not None:
            if (
                self.storage is not None
                or self.use_pool
                or self.datasets
                or self.stage_in_bytes
                or self.stage_out_bytes
                or self.n_streams != 8
                or self.runtime != "shifter"
            ):
                raise ValueError(
                    f"{self.name!r}: storage_spec replaces the legacy storage/"
                    "use_pool/datasets/stage_*_bytes/n_streams/runtime fields "
                    "(they all belong on the StorageSpec); set one or the other"
                )
            return
        if any(not isinstance(d, DatasetRef) for d in self.datasets):
            raise ValueError(f"{self.name!r}: datasets must be DatasetRef instances")
        if len({d.name for d in self.datasets}) != len(self.datasets):
            raise ValueError(f"{self.name!r}: duplicate dataset names")
        if self.use_pool and self.storage is not None:
            raise ValueError(
                f"{self.name!r}: use_pool jobs lease pool capacity; "
                "drop the per-job storage request"
            )
        if (
            self.storage is None
            and not self.use_pool
            and (self.stage_in_bytes or self.stage_out_bytes or self.datasets)
        ):
            raise ValueError(f"{self.name!r}: staging bytes without a storage request")

    # -- the one storage path -------------------------------------------------
    def session_spec(self) -> Optional[StorageSpec]:
        """The declarative spec this job presents to the ProvisioningService
        (None for jobs with no storage demand at all). Legacy fields pin the
        ``ephemeralfs`` backend, preserving their original semantics."""
        if self.storage_spec is not None:
            return self.storage_spec
        if self.use_pool:
            return StorageSpec(
                name=self.name,
                lifetime=LifetimeClass.POOLED,
                managers=("ephemeralfs",),
                datasets=self.datasets,
                stage_in_bytes=self.stage_in_bytes,
                stage_out_bytes=self.stage_out_bytes,
                n_streams=self.n_streams,
                runtime=self.runtime,  # type: ignore[arg-type]
            )
        if self.storage is not None:
            return StorageSpec(
                name=self.name,
                nodes=self.storage.nodes,
                capacity_bytes=self.storage.capacity_bytes,
                bandwidth=self.storage.capability_bw,
                managers=("ephemeralfs",),
                datasets=self.datasets,
                stage_in_bytes=self.stage_in_bytes,
                stage_out_bytes=self.stage_out_bytes,
                n_streams=self.n_streams,
                runtime=self.runtime,  # type: ignore[arg-type]
            )
        return None

    @property
    def wants_pool(self) -> bool:
        return self.use_pool or (
            self.storage_spec is not None
            and self.storage_spec.lifetime is LifetimeClass.POOLED
        )

    @property
    def all_datasets(self) -> tuple:
        if self.storage_spec is not None:
            return self.storage_spec.datasets
        return self.datasets

    @property
    def dataset_bytes(self) -> float:
        return total_bytes(self.all_datasets)

    @property
    def scratch_bytes(self) -> float:
        """Private pool capacity a lease must reserve on top of datasets."""
        if self.storage_spec is not None:
            return self.storage_spec.scratch_bytes
        return self.stage_in_bytes + self.stage_out_bytes


@dataclasses.dataclass(slots=True)
class JobRecord:
    """Mutable per-job bookkeeping the orchestrator and metrics share."""

    spec: WorkflowSpec
    job_id: int
    submit_time: float
    state: JobState = JobState.QUEUED
    attempt: int = 0
    sspec: Optional[StorageSpec] = None          # resolved once at submit
    offer: Optional[Offer] = None                # cached non-POOLED negotiation
    session: Optional[StorageSession] = None     # live negotiated grant
    allocation: Optional[Allocation] = None
    alloc_started: Optional[float] = None
    fs_model: Optional[FSDeployment] = None
    failure_phase: Optional[str] = None
    backend: Optional[str] = None                # negotiated data manager
    # storage nodes holding a fully-deployed tree of this job's FS: a retry
    # landing on these nodes redeploys warm (paper §IV-B1)
    warm_nodes: frozenset = frozenset()
    history: list[tuple[JobState, float]] = dataclasses.field(default_factory=list)
    # closed (alloc_time, release_time, n_storage_nodes) intervals per attempt
    storage_intervals: list[tuple[float, float, int]] = dataclasses.field(
        default_factory=list
    )
    staged_in_bytes: float = 0.0
    staged_out_bytes: float = 0.0
    # pool-backed bookkeeping (summed across retries)
    lease: Optional[Lease] = None
    pool_id: Optional[int] = None
    dataset_hits: int = 0
    dataset_misses: int = 0
    stage_in_saved_bytes: float = 0.0
    #: mirrors ``spec.wants_pool`` (checked on every transition; precomputed)
    wants_pool: bool = False
    #: granted (compute ids, storage ids, pool id) per attempt — the
    #: determinism regressions compare these across dispatch paths
    alloc_history: list = dataclasses.field(default_factory=list)
    _request: Optional[JobRequest] = None
    _gating: Optional[tuple] = None              # dispatch pre-filter cache

    @property
    def request(self) -> JobRequest:
        """Scheduler-level view of the job's demand (policies rank by it).
        Pool-backed jobs draw storage from a lease, not the allocator.
        Cached: ``sspec`` is resolved once at submit and never changes."""
        if self._request is None:
            storage = None
            if self.sspec is not None and self.sspec.lifetime is not LifetimeClass.POOLED:
                storage = self.sspec.to_request()
            self._request = JobRequest(
                self.spec.name, self.spec.n_compute, storage=storage
            )
        return self._request

    @property
    def done(self) -> bool:
        return self.state in TERMINAL_STATES


class Orchestrator:
    """Runs provisioning campaigns: many jobs through one cluster, queued
    by policy, timed by the perfmodel, perturbed by fault injection. All
    storage flows through one `ProvisioningService` (``self.provision``)."""

    def __init__(
        self,
        cluster,
        *,
        policy: QueuePolicy | None = None,
        faults: FaultInjector | None = None,
        engine: SimEngine | None = None,
        globalfs_model: FSDeployment | None = None,
        teardown_time_s: float | None = None,
        provision: ProvisioningService | None = None,
        incremental: Optional[bool] = None,
        record_allocations: bool = True,
    ):
        self.engine = engine or SimEngine()
        if provision is None:
            provision = ProvisioningService(
                cluster,
                globalfs_model=globalfs_model or dom_lustre(),
                teardown_time_s=0.5 if teardown_time_s is None else teardown_time_s,
                clock=lambda: self.engine.now,
            )
        elif globalfs_model is not None or teardown_time_s is not None:
            raise ValueError(
                "globalfs_model/teardown_time_s are service knobs: configure "
                "them on the ProvisioningService you pass in"
            )
        self.provision = provision
        # sessions price TEARDOWN and staging from the service; mirror its
        # values so the orchestrator attributes never disagree with behavior
        self.teardown_time_s = self.provision.teardown_time_s
        self.globalfs_model = self.provision.globalfs_model
        self.scheduler = self.provision.scheduler
        self.provisioner = self.provision.provisioner
        self.faults = faults or FaultInjector()
        # Incremental (indexed) dispatch is the default for every policy
        # honoring the sort_key contract; custom policies fall back to the
        # legacy sort-everything loop. ``incremental=False`` forces the
        # legacy path (the determinism regressions replay both).
        # per-attempt granted node ids on JobRecord.alloc_history —
        # determinism evidence; disable for campaigns of very wide jobs
        # where retaining every node id would dominate memory
        self._record_allocations = record_allocations
        self._incremental_requested = incremental
        self._dq: Optional[DispatchQueue] = None
        self._queue: list[JobRecord] = []      # legacy-path wait queue
        self.policy = policy or FIFOPolicy()   # setter builds the index
        self.jobs: list[JobRecord] = []
        self._ids = itertools.count(1)
        # pool-reap bookkeeping: #pool-wanting jobs not yet terminal and not
        # holding a lease (maintained on every transition — replaces the old
        # O(jobs) scan per reap event) + pending-reap coalescing by fire time
        self._pool_wait_n = 0
        self._reap_times: set[float] = set()
        # last full-scan "nothing fits" conclusion: (admission state it was
        # drawn under, and — for head-blocking policies — the blocking
        # head's key). Lets arrival dispatches short-circuit in O(1).
        self._noadmit_state: Optional[tuple] = None
        self._noadmit_head_key: Optional[tuple] = None

    @property
    def faults(self) -> FaultInjector:
        return self._faults

    @faults.setter
    def faults(self, faults: FaultInjector) -> None:
        """To change fault behavior mid-setup, assign a new injector here
        (mutating an installed injector's ``spec`` is not supported). Only
        the *stock* fault-free injector is bypassed on the hot path —
        subclasses overriding :meth:`FaultInjector.trip` always get
        consulted, whatever their spec says."""
        self._faults = faults
        self._faults_passive = (
            type(faults) is FaultInjector and not faults.any_faults
        )

    @property
    def policy(self) -> QueuePolicy:
        return self._policy

    @policy.setter
    def policy(self, policy: QueuePolicy) -> None:
        """Swapping the policy re-indexes any waiting jobs (their policy
        keys, buckets, and aging class all belong to the old policy)."""
        use = self._incremental_requested
        if use is None:
            use = getattr(policy, "incremental", False)
        elif use and not getattr(policy, "incremental", False):
            raise ValueError(
                f"policy {policy.name!r} does not implement the "
                "incremental dispatch contract (QueuePolicy.sort_key)"
            )
        queued = self.queue
        self._policy = policy
        self._noadmit_state = None     # conclusions belong to the old policy
        self._noadmit_head_key = None
        if use:
            self._dq = DispatchQueue(policy, self.scheduler)
            for job in queued:
                self._dq.add(job, self.engine.now)
            self._queue = []
        else:
            self._dq = None
            self._queue = list(queued)

    @property
    def queue(self) -> list[JobRecord]:
        """Waiting jobs in arrival order (a snapshot under indexed dispatch)."""
        if self._dq is not None:
            return self._dq.jobs()
        return self._queue

    def _enqueue(self, job: JobRecord) -> None:
        if self._dq is not None:
            self._dq.add(job, self.engine.now)
        else:
            self._queue.append(job)

    # -- pools ----------------------------------------------------------------
    @property
    def pools(self) -> Optional[PoolManager]:
        """The service's pool subsystem (None until attached/first use)."""
        return self.provision.pool_manager

    def enable_pools(self, **kwargs) -> PoolManager:
        """Attach a persistent-pool subsystem over this orchestrator's
        provisioning service. Pools themselves are best created through the
        service (a PERSISTENT `StorageSpec`); ``use_pool``/POOLED jobs lease
        from them. A no-argument call returns the existing manager."""
        if self.provision.pool_manager is not None and not kwargs:
            return self.provision.pool_manager
        kwargs.setdefault("clock", lambda: self.engine.now)
        return self.provision.ensure_pools(**kwargs)

    # -- submission ----------------------------------------------------------
    def _check_spec(self, spec: WorkflowSpec) -> None:
        if spec.wants_pool and self.provision.pool_manager is None:
            raise ValueError(
                f"{spec.name!r}: pooled storage requires enable_pools() (or a "
                "PERSISTENT session) first"
            )

    def _make_job(self, spec: WorkflowSpec, at: Optional[float]) -> JobRecord:
        t = self.engine.now if at is None else at
        sspec = spec.session_spec()
        if sspec is None:
            # no storage demand: a dry-run session still co-allocates compute
            sspec = StorageSpec(name=spec.name, managers=("null",))
        job = JobRecord(
            spec=spec,
            job_id=next(self._ids),
            submit_time=t,
            sspec=sspec,
            wants_pool=spec.wants_pool,
        )
        self.jobs.append(job)
        self._pool_wait_n += self._pool_waiting(job)
        return job

    def submit(self, spec: WorkflowSpec, at: Optional[float] = None) -> JobRecord:
        """Enqueue a job at virtual time ``at`` (default: now)."""
        self._check_spec(spec)
        job = self._make_job(spec, at)
        self.engine.at(job.submit_time, lambda: self._arrive(job))
        return job

    def _arrive(self, job: JobRecord) -> None:
        feasible = job.spec.n_compute <= len(self.scheduler.cluster.compute_nodes)
        if feasible:
            try:
                offer = self.provision.negotiate(job.sspec)
            except NegotiationError:
                feasible = False
            else:
                if job.sspec.lifetime is not LifetimeClass.POOLED:
                    job.offer = offer   # static over the campaign: reuse at dispatch
        if not feasible:
            # No backend can ever serve this spec on this cluster: fail fast
            # instead of letting an error escape the campaign (or queueing
            # forever).
            job.failure_phase = "infeasible"
            self._transition(job, JobState.QUEUED)
            self._transition(job, JobState.FAILED)
            return
        self._transition(job, JobState.QUEUED)
        self._enqueue(job)
        self._dispatch(new_job=job)

    # -- dispatch loop -------------------------------------------------------
    def _dispatch(self, new_job: Optional[JobRecord] = None) -> None:
        """Start every queued job the policy admits against the free pool.
        ``new_job`` marks an arrival-triggered dispatch, which the indexed
        path can often resolve in O(1) (nothing freed since the last scan
        concluded nothing fits, so only the arrival itself is a candidate)."""
        if self._dq is not None:
            self._dispatch_indexed(new_job)
        else:
            self._dispatch_legacy()

    # admission state = everything a refusal can go stale against: the
    # scheduler free pool (epoch) and the pool subsystem (leases, ledgers,
    # catalog). Aging/promotion changes *order*, never admissibility.
    def _admission_state(self) -> tuple:
        pm = self.provision.pool_manager
        return (self.scheduler.epoch, pm.epoch if pm is not None else -1)

    def _sizing_signature(self) -> tuple:
        """Weakest-free-node contributions: while these are unchanged, every
        capacity/bandwidth request resolves to the same node count, so a
        shrinking free pool can only turn fits into misfits — refusals from
        earlier in the scan stay valid."""
        s = self.scheduler
        return (s.free_min_capacity(), s.free_min_bandwidth())

    _ADMITTED, _REFUSED, _FAILED = "admitted", "refused", "failed"

    def _probe(self, job: JobRecord) -> str:
        """One admission attempt against the live cluster (indexed path)."""
        if not self._admittable_now(job):
            return self._REFUSED
        try:
            session = self._try_open(job)
        except NegotiationError:
            self._dq.remove(job)
            job.failure_phase = "infeasible"
            self._transition(job, JobState.FAILED)
            return self._FAILED
        if session is None:
            return self._REFUSED
        self._dq.remove(job)
        self._start(job, session)
        return self._ADMITTED

    def _dispatch_indexed(self, new_job: Optional[JobRecord] = None) -> None:
        """Incremental dispatch over the indexed queue.

        Observably identical to :meth:`_dispatch_legacy`: same-signature
        jobs receive identical admission answers at any instant, so probing
        one head per bucket probes exactly the jobs whose refusal the legacy
        scan would not have skipped; and a candidate heap merged with each
        admitted bucket's next head reproduces the legacy restart order as
        long as no admission changed the sizing or pool state (when one
        does, the pass restarts from a fresh ranking, as legacy always
        does)."""
        dq = self._dq
        now = self.engine.now
        dq.promote(now)
        state = self._admission_state()
        if new_job is not None and self._noadmit_state == state:
            # Nothing has been freed since a full scan concluded that
            # nothing fits: the arrival is the only new candidate.
            policy = self.policy
            if policy.head_blocking:
                blocked = self._noadmit_head_key
                if blocked is not None:
                    key_new = (
                        policy.sort_key(new_job, self.scheduler, now),
                        dq.seq_of(new_job),
                    )
                    if key_new >= blocked:
                        return          # the blocked head still blocks
            else:
                if not dq.is_bucket_head(new_job):
                    return              # same-signature job already refused
                sizing = self._sizing_signature()
                if self._probe(new_job) is not self._ADMITTED:
                    return              # state unchanged; refusals still hold
                if (
                    self._sizing_signature() == sizing
                    and self._admission_state()[1] == state[1]
                ):
                    # the admission only shrank the free pool: every earlier
                    # refusal still holds, no full scan needed
                    self._noadmit_state = self._admission_state()
                    return
        self._run_dispatch_scan(now)

    def _run_dispatch_scan(self, now: float) -> None:
        """One dispatch pass over the bucket heads, merged in policy order.

        Head-blocking policies must probe their true first head, so they
        skip the admissibility gate and stop at the first refusal; all
        others gate out certain refusals before paying for policy keys and
        keep scanning. Either way, an admitted (or failed) bucket's next
        head re-enters the heap exactly where the departing job ranked —
        the legacy restart order — as long as no admission moved the
        sizing or pool state (then the pass restarts from a fresh ranking,
        as legacy always does)."""
        dq = self._dq
        head_blocking = self.policy.head_blocking
        gate = None if head_blocking else self._admittable_now
        while True:
            candidates = dq.candidate_heads(now, gate)
            if not candidates:
                self._noadmit_state = self._admission_state()
                self._noadmit_head_key = None
                return
            heapq.heapify(candidates)
            sizing = self._sizing_signature()
            pool_epoch = self._admission_state()[1]
            restart = False
            while candidates:
                key, seq, job, bucket = heapq.heappop(candidates)
                outcome = self._probe(job)
                if outcome is self._REFUSED:
                    if head_blocking:
                        self._noadmit_state = self._admission_state()
                        self._noadmit_head_key = (key, seq)
                        return
                    continue            # whole bucket refused until a restart
                if outcome is self._ADMITTED and (
                    self._sizing_signature() != sizing
                    or self._admission_state()[1] != pool_epoch
                ):
                    restart = True      # refusals/ranks may have gone stale
                    break
                item = dq.head_item(bucket, now, gate)
                if item is not None:
                    heapq.heappush(candidates, item)
            if restart:
                continue
            self._noadmit_state = self._admission_state()
            self._noadmit_head_key = None
            return

    def _dispatch_legacy(self) -> None:
        """The pre-index dispatch loop (compatibility fallback for custom
        policies, and the reference the determinism regressions replay)."""
        started = True
        while started and self._queue:
            started = False
            for job in self.policy.order(self._queue, self.scheduler, self.engine.now):
                try:
                    session = self._try_open(job)
                except NegotiationError:
                    # what was feasible at arrival no longer is (e.g. every
                    # pool that could hold the working set was retired):
                    # fail fast instead of stranding the job in the queue
                    self._queue.remove(job)
                    job.failure_phase = "infeasible"
                    self._transition(job, JobState.FAILED)
                    started = True
                    break
                if session is None:
                    if self.policy.head_blocking:
                        break
                    continue
                self._queue.remove(job)
                self._start(job, session)
                started = True
                break                 # re-ask the policy: free pool changed

    def _gating(self, job: JobRecord) -> tuple:
        """Pre-filter terms for a job, computed once: ``()`` when the job
        must always be probed for real (POOLED/PERSISTENT specs, custom
        backends), else ``(n_compute, storage_request_or_None)``."""
        gating = job._gating
        if gating is None:
            offer = job.offer
            if offer is None or job.sspec.lifetime is not LifetimeClass.EPHEMERAL:
                gating = ()
            else:
                backend = self.provision.registry.get(offer.backend)
                if backend is None or not backend.scheduler_gated:
                    gating = ()
                else:
                    storage = (
                        job.request.storage
                        if backend.capabilities.dedicated_nodes
                        else None
                    )
                    if storage is not None and storage.nodes is not None:
                        storage = storage.nodes      # static node count
                    gating = (job.spec.n_compute, storage)
            job._gating = gating
        return gating

    def _admittable_now(self, job: JobRecord) -> bool:
        """Cheap pre-filter for indexed dispatch: False only when
        ``_try_open`` is *certain* to return None right now (two O(1) count
        checks against the indexed free pool). Only ``scheduler_gated``
        backends — whose EPHEMERAL admission is exactly the scheduler
        co-allocation fitting — are filtered; POOLED/PERSISTENT specs and
        custom backends always probe for real."""
        gating = self._gating(job)
        if not gating:
            return True
        n_compute, storage = gating
        sched = self.scheduler
        if n_compute > len(sched._free_compute):
            return False
        if storage is None:
            return True
        if type(storage) is int:
            return storage <= len(sched._free_storage)
        return sched.resolve_storage_nodes(storage) <= len(sched._free_storage)

    def _try_open(self, job: JobRecord) -> Optional[StorageSession]:
        """One declarative call grants everything the job holds: compute
        nodes co-allocated with whatever storage the negotiated backend
        needs (nodes + deploy, a pool lease, or nothing)."""
        sspec = job.sspec
        offer = job.offer
        if offer is None:
            offer = self.provision.negotiate(sspec)   # may raise NegotiationError
            if sspec.lifetime is not LifetimeClass.POOLED:
                # EPHEMERAL/PERSISTENT feasibility is static over a campaign;
                # POOLED offers go stale as pools retire/drain, so those
                # re-negotiate on every dispatch attempt
                job.offer = offer
        return self.provision.try_open_session(
            sspec,
            n_compute=job.spec.n_compute,
            warm_nodes=job.warm_nodes,
            now=self.engine.now,
            offer=offer,
        )

    def _start(self, job: JobRecord, session: StorageSession) -> None:
        job.session = session
        job.allocation = session.allocation
        job.alloc_started = self.engine.now
        job.backend = session.backend
        self._transition(job, JobState.ALLOCATED)
        was_waiting = self._pool_waiting(job)
        job.lease = session.lease
        self._pool_wait_n += self._pool_waiting(job) - was_waiting
        if self._record_allocations:
            alloc = session.allocation
            job.alloc_history.append(
                (
                    tuple(n.node_id for n in alloc.compute_nodes) if alloc else (),
                    tuple(n.node_id for n in alloc.storage_nodes) if alloc else (),
                    session.lease.pool_id if session.lease is not None else None,
                )
            )
        if session.lease is not None:
            job.pool_id = session.lease.pool_id
            job.dataset_hits += session.lease.hits
            job.dataset_misses += session.lease.misses
        job.fs_model = session.fs_model
        self._transition(job, JobState.PROVISIONING)
        eng = self.engine
        eng.at(
            eng.now + session.provision_time_s, lambda: self._provision_done(job)
        )

    # -- phase machinery -----------------------------------------------------
    # Each phase-completion callback schedules its successor directly: no
    # per-event state dispatch on the hot path. A fault trip at any phase
    # boundary routes through _fail_attempt (release + requeue-or-FAIL).
    def _trip(self, job: JobRecord, phase: str) -> bool:
        return not self._faults_passive and self.faults.trip(job.spec.name, phase)

    def _provision_done(self, job: JobRecord) -> None:
        if self._trip(job, "provision"):
            self._fail_attempt(job, "provision")
            return
        session = job.session
        if session.lease is None and job.allocation is not None:
            job.warm_nodes = job.warm_nodes | frozenset(
                n.node_id for n in job.allocation.storage_nodes
            )
        self._transition(job, JobState.STAGING_IN)
        eng = self.engine
        eng.at(eng.now + session.stage_in_time_s, lambda: self._stage_in_done(job))

    def _stage_in_done(self, job: JobRecord) -> None:
        if self._trip(job, "stage_in"):
            self._fail_attempt(job, "stage_in")
            return
        session = job.session
        job.staged_in_bytes += session.stage_in_bytes
        # saved bytes count only when the stage-in actually completed
        # (a faulted attempt neither staged nor saved anything)
        job.stage_in_saved_bytes += session.saved_bytes
        # lease misses are now resident: hits for every later job
        session.mark_staged(self.engine.now)
        self._transition(job, JobState.RUNNING)
        eng = self.engine
        eng.at(eng.now + job.spec.run_time_s, lambda: self._run_done(job))

    def _run_done(self, job: JobRecord) -> None:
        if self._trip(job, "run"):
            self._fail_attempt(job, "run")
            return
        session = job.session
        self._transition(job, JobState.STAGING_OUT)
        eng = self.engine
        eng.at(eng.now + session.stage_out_time_s, lambda: self._stage_out_done(job))

    def _stage_out_done(self, job: JobRecord) -> None:
        if self._trip(job, "stage_out"):
            self._fail_attempt(job, "stage_out")
            return
        session = job.session
        job.staged_out_bytes += session.stage_out_bytes
        # pool-backed / always-on backends release for free (the data
        # manager outlives the job); only job-scoped deploys pay teardown
        self._transition(job, JobState.TEARDOWN)
        eng = self.engine
        eng.at(eng.now + session.teardown_time_s, lambda: self._teardown_done(job))

    def _teardown_done(self, job: JobRecord) -> None:
        self._release(job)
        self._transition(job, JobState.DONE)
        self._dispatch()

    def _fail_attempt(self, job: JobRecord, phase: str) -> None:
        job.failure_phase = phase
        self._release(job)
        job.attempt += 1
        if job.attempt > job.spec.max_retries:
            self._transition(job, JobState.FAILED)
        else:
            self._transition(job, JobState.QUEUED)
            self._enqueue(job)
        self._dispatch()

    def _release(self, job: JobRecord) -> None:
        session = job.session
        if session is None:
            return
        if job.allocation is not None:
            t0 = job.alloc_started if job.alloc_started is not None else self.engine.now
            job.storage_intervals.append(
                (t0, self.engine.now, len(job.allocation.storage_nodes))
            )
        pooled = session.lease is not None
        session.release(self.engine.now)
        job.session = None
        was_waiting = self._pool_waiting(job)
        job.lease = None
        self._pool_wait_n += self._pool_waiting(job) - was_waiting
        job.allocation = None
        job.alloc_started = None
        job.fs_model = None
        if pooled and self.pools is not None and self.pools.ttl_s is not None:
            # coalesce: many leases released at one event time used to fan
            # out into identical reap events; one per fire time suffices
            t = self.engine.now + self.pools.ttl_s
            if t not in self._reap_times:
                self._reap_times.add(t)
                self.engine.at(t, lambda: self._run_reap(t))

    def _run_reap(self, t: float) -> None:
        self._reap_times.discard(t)
        self._reap_pools()

    def _pool_waiting(self, job: JobRecord) -> bool:
        """Is this a pool-wanting job that has yet to run (no lease, not
        terminal)? Counted incrementally in ``_pool_wait_n`` so the TTL
        reaper never scans the whole campaign's job list."""
        return (
            job.wants_pool
            and job.lease is None
            and job.state not in TERMINAL_STATES
        )

    def _reap_pools(self) -> None:
        """TTL check scheduled after lease releases. Never reaps while any
        pool-backed job has yet to run — queued now, requeued after a
        fault, or submitted with a future arrival time — because a reaped
        pool could strand it (or fail it spuriously as infeasible)."""
        if self.pools is None:
            return
        if self._pool_wait_n > 0:
            return
        self.pools.reap_idle(self.engine.now)

    def _transition(self, job: JobRecord, state: JobState) -> None:
        if job.wants_pool:
            was_waiting = self._pool_waiting(job)
            job.state = state
            self._pool_wait_n += self._pool_waiting(job) - was_waiting
        else:
            job.state = state
        job.history.append((state, self.engine.now))

    # -- campaign driver -----------------------------------------------------
    def run_campaign(
        self,
        specs: Optional[list[WorkflowSpec]] = None,
        *,
        submit_times: Optional[list[float]] = None,
        until: Optional[float] = None,
        max_events: Optional[int] = None,
    ) -> list[JobRecord]:
        """Submit ``specs`` (if given), drain the event loop, return records.

        ``submit_times`` gives each spec its own arrival instant (e.g. from
        :func:`repro.orchestrator.arrivals.poisson_arrivals` or a replayed
        trace) instead of the batch-at-now default; it must match ``specs``
        in length, and no time may predate the engine clock.

        ``max_events`` sets the engine's runaway-loop backstop. The default
        scales with campaign size — ``max(1_000_000, 40 * n_jobs)`` — so a
        50k-job campaign no longer trips the engine's fixed 1M guard; pass
        ``None`` explicitly through :meth:`SimEngine.run` to disable it.

        Submissions are bulk-scheduled (:meth:`SimEngine.at_many`): one
        heapify instead of one heap push per job for batch arrivals.

        Guarantees every job reaches a terminal state (DONE or FAILED) unless
        ``until`` cut the clock short.
        """
        specs = specs or []
        if submit_times is not None and len(submit_times) != len(specs):
            raise ValueError(
                f"{len(submit_times)} submit times for {len(specs)} specs"
            )
        for spec in specs:
            self._check_spec(spec)
        events = []
        for i, spec in enumerate(specs):
            job = self._make_job(
                spec, None if submit_times is None else submit_times[i]
            )
            events.append(
                (job.submit_time, (lambda j: lambda: self._arrive(j))(job))
            )
        self.engine.at_many(events)
        if max_events is None:
            max_events = max(1_000_000, 40 * len(self.jobs))
        self.engine.run(until=until, max_events=max_events)
        return list(self.jobs)
