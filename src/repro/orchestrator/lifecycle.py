"""Job lifecycle state machine over the provisioning pipeline.

The paper's mechanism is a workflow — allocate compute+storage, deploy the
on-demand file system, stage in, run, stage out, tear down — and this module
wires the repo's pieces (`Scheduler`, `Provisioner`, staging model, fault
injection) into one event-driven pipeline:

    QUEUED -> ALLOCATED -> PROVISIONING -> STAGING_IN -> RUNNING
           -> STAGING_OUT -> TEARDOWN -> DONE
                                 \\-> (fault) -> requeue or FAILED

Every phase duration comes from the calibrated perfmodel: deployment time
is C8 (`predict_deploy_time`, warm on retries over the same tree), staging
time is the slower of the global-FS read and ephemeral-FS write paths
(`modeled_stage_time`), and the run phase is the job's own compute time.
A `FaultInjector` may trip any phase; a tripped job releases its nodes and
requeues (up to ``max_retries``) — the retry pays a *warm* redeploy, the
paper's §IV-B1 1.2 s vs 4.6 s observation.
"""

from __future__ import annotations

import dataclasses
import enum
import itertools
from typing import Optional

from ..core.perfmodel import FSDeployment, dom_lustre, predict_deploy_time
from ..core.provisioner import Provisioner
from ..core.scheduler import (
    Allocation,
    AllocationError,
    JobRequest,
    Scheduler,
    StorageRequest,
)
from ..core.staging import modeled_stage_time
from ..runtime.fault import FaultInjector
from .engine import SimEngine
from .policies import FIFOPolicy, QueuePolicy


class JobState(enum.Enum):
    QUEUED = "queued"
    ALLOCATED = "allocated"
    PROVISIONING = "provisioning"
    STAGING_IN = "staging_in"
    RUNNING = "running"
    STAGING_OUT = "staging_out"
    TEARDOWN = "teardown"
    DONE = "done"
    FAILED = "failed"


TERMINAL_STATES = frozenset({JobState.DONE, JobState.FAILED})

# Lifecycle phase -> the FaultInjector phase name consulted at its end.
_FAULT_PHASE = {
    JobState.PROVISIONING: "provision",
    JobState.STAGING_IN: "stage_in",
    JobState.RUNNING: "run",
    JobState.STAGING_OUT: "stage_out",
}


@dataclasses.dataclass(frozen=True)
class WorkflowSpec:
    """One job's demands on the provisioning pipeline."""

    name: str
    n_compute: int
    storage: Optional[StorageRequest] = None
    stage_in_bytes: float = 0.0
    stage_out_bytes: float = 0.0
    run_time_s: float = 60.0
    n_streams: int = 8
    max_retries: int = 2
    runtime: str = "shifter"

    def __post_init__(self) -> None:
        if self.run_time_s < 0 or self.stage_in_bytes < 0 or self.stage_out_bytes < 0:
            raise ValueError(f"negative duration/bytes in spec {self.name!r}")
        if self.max_retries < 0:
            raise ValueError("max_retries must be >= 0")
        if self.storage is None and (self.stage_in_bytes or self.stage_out_bytes):
            raise ValueError(f"{self.name!r}: staging bytes without a storage request")


@dataclasses.dataclass
class JobRecord:
    """Mutable per-job bookkeeping the orchestrator and metrics share."""

    spec: WorkflowSpec
    job_id: int
    submit_time: float
    state: JobState = JobState.QUEUED
    attempt: int = 0
    allocation: Optional[Allocation] = None
    alloc_started: Optional[float] = None
    fs_model: Optional[FSDeployment] = None
    failure_phase: Optional[str] = None
    # storage nodes holding a fully-deployed tree of this job's FS: a retry
    # landing on these nodes redeploys warm (paper §IV-B1)
    warm_nodes: frozenset = frozenset()
    history: list[tuple[JobState, float]] = dataclasses.field(default_factory=list)
    # closed (alloc_time, release_time, n_storage_nodes) intervals per attempt
    storage_intervals: list[tuple[float, float, int]] = dataclasses.field(
        default_factory=list
    )
    staged_in_bytes: float = 0.0
    staged_out_bytes: float = 0.0

    @property
    def request(self) -> JobRequest:
        return JobRequest(self.spec.name, self.spec.n_compute, storage=self.spec.storage)

    @property
    def done(self) -> bool:
        return self.state in TERMINAL_STATES


class Orchestrator:
    """Runs provisioning campaigns: many jobs through one cluster, queued
    by policy, timed by the perfmodel, perturbed by fault injection."""

    def __init__(
        self,
        cluster,
        *,
        policy: QueuePolicy | None = None,
        faults: FaultInjector | None = None,
        engine: SimEngine | None = None,
        globalfs_model: FSDeployment | None = None,
        teardown_time_s: float = 0.5,
    ):
        self.engine = engine or SimEngine()
        self.scheduler = Scheduler(cluster)
        self.provisioner = Provisioner(cluster)
        self.policy = policy or FIFOPolicy()
        self.faults = faults or FaultInjector()
        self.globalfs_model = globalfs_model or dom_lustre()
        self.teardown_time_s = teardown_time_s
        self.queue: list[JobRecord] = []
        self.jobs: list[JobRecord] = []
        self._ids = itertools.count(1)

    # -- submission ----------------------------------------------------------
    def submit(self, spec: WorkflowSpec, at: Optional[float] = None) -> JobRecord:
        """Enqueue a job at virtual time ``at`` (default: now)."""
        t = self.engine.now if at is None else at
        job = JobRecord(spec=spec, job_id=next(self._ids), submit_time=t)
        self.jobs.append(job)
        self.engine.at(t, lambda: self._arrive(job))
        return job

    def _arrive(self, job: JobRecord) -> None:
        try:
            feasible = self.scheduler.feasible(job.request)
        except AllocationError:
            feasible = False
        if not feasible:
            # Never satisfiable on this cluster: fail fast instead of letting
            # an AllocationError escape the campaign (or queueing forever).
            job.failure_phase = "infeasible"
            self._transition(job, JobState.QUEUED)
            self._transition(job, JobState.FAILED)
            return
        self._transition(job, JobState.QUEUED)
        self.queue.append(job)
        self._dispatch()

    # -- dispatch loop -------------------------------------------------------
    def _dispatch(self) -> None:
        """Start every queued job the policy admits against the free pool."""
        started = True
        while started and self.queue:
            started = False
            for job in self.policy.order(self.queue, self.scheduler, self.engine.now):
                alloc = self.scheduler.try_submit(job.request)
                if alloc is None:
                    if self.policy.head_blocking:
                        break
                    continue
                self.queue.remove(job)
                self._start(job, alloc)
                started = True
                break                 # re-ask the policy: free pool changed

    def _start(self, job: JobRecord, alloc: Allocation) -> None:
        job.allocation = alloc
        job.alloc_started = self.engine.now
        self._transition(job, JobState.ALLOCATED)
        if alloc.storage_nodes:
            plan = self.provisioner.plan_for(alloc, runtime=job.spec.runtime)
            job.fs_model = self.provisioner.model_for(plan)
            # warm only when every granted node already holds this job's
            # fully-deployed tree from an earlier attempt; a retry placed on
            # different nodes (or after a provisioning fault) deploys fresh
            ids = frozenset(n.node_id for n in alloc.storage_nodes)
            t_prov = predict_deploy_time(
                plan.targets_per_node,
                runtime=job.spec.runtime,
                fresh=not ids <= job.warm_nodes,
            )
        else:
            job.fs_model = None
            t_prov = 0.0
        self._enter_phase(job, JobState.PROVISIONING, t_prov)

    # -- phase machinery -----------------------------------------------------
    def _enter_phase(self, job: JobRecord, state: JobState, duration: float) -> None:
        self._transition(job, state)
        self.engine.after(duration, lambda: self._phase_done(job, state))

    def _phase_done(self, job: JobRecord, state: JobState) -> None:
        fault_phase = _FAULT_PHASE.get(state)
        if fault_phase is not None and self.faults.trip(job.spec.name, fault_phase):
            self._fail_attempt(job, fault_phase)
            return
        if state is JobState.PROVISIONING:
            if job.allocation is not None:
                job.warm_nodes = job.warm_nodes | frozenset(
                    n.node_id for n in job.allocation.storage_nodes
                )
            self._enter_phase(job, JobState.STAGING_IN, self._stage_time(job, "in"))
        elif state is JobState.STAGING_IN:
            job.staged_in_bytes += job.spec.stage_in_bytes
            self._enter_phase(job, JobState.RUNNING, job.spec.run_time_s)
        elif state is JobState.RUNNING:
            self._enter_phase(job, JobState.STAGING_OUT, self._stage_time(job, "out"))
        elif state is JobState.STAGING_OUT:
            job.staged_out_bytes += job.spec.stage_out_bytes
            self._enter_phase(job, JobState.TEARDOWN, self.teardown_time_s)
        elif state is JobState.TEARDOWN:
            self._release(job)
            self._transition(job, JobState.DONE)
            self._dispatch()

    def _stage_time(self, job: JobRecord, direction: str) -> float:
        nbytes = job.spec.stage_in_bytes if direction == "in" else job.spec.stage_out_bytes
        if nbytes <= 0 or job.fs_model is None:
            return 0.0
        if direction == "in":       # global FS read feeds ephemeral FS write
            src, dst = self.globalfs_model, job.fs_model
        else:                       # drain back to the global store
            src, dst = job.fs_model, self.globalfs_model
        return modeled_stage_time(nbytes, src, dst, job.spec.n_streams)

    def _fail_attempt(self, job: JobRecord, phase: str) -> None:
        job.failure_phase = phase
        self._release(job)
        job.attempt += 1
        if job.attempt > job.spec.max_retries:
            self._transition(job, JobState.FAILED)
        else:
            self._transition(job, JobState.QUEUED)
            self.queue.append(job)
        self._dispatch()

    def _release(self, job: JobRecord) -> None:
        if job.allocation is None:
            return
        t0 = job.alloc_started if job.alloc_started is not None else self.engine.now
        job.storage_intervals.append(
            (t0, self.engine.now, len(job.allocation.storage_nodes))
        )
        self.scheduler.release(job.allocation)
        job.allocation = None
        job.alloc_started = None
        job.fs_model = None

    def _transition(self, job: JobRecord, state: JobState) -> None:
        job.state = state
        job.history.append((state, self.engine.now))

    # -- campaign driver -----------------------------------------------------
    def run_campaign(
        self,
        specs: Optional[list[WorkflowSpec]] = None,
        *,
        until: Optional[float] = None,
    ) -> list[JobRecord]:
        """Submit ``specs`` (if given), drain the event loop, return records.

        Guarantees every job reaches a terminal state (DONE or FAILED) unless
        ``until`` cut the clock short.
        """
        for spec in specs or []:
            self.submit(spec)
        self.engine.run(until=until)
        return list(self.jobs)
