"""Job lifecycle state machine over the provisioning pipeline.

The paper's mechanism is a workflow — allocate compute+storage, deploy the
on-demand file system, stage in, run, stage out, tear down — and this module
drives it as one event-driven pipeline:

    QUEUED -> ALLOCATED -> PROVISIONING -> STAGING_IN -> RUNNING
           -> STAGING_OUT -> TEARDOWN -> DONE
                                 \\-> (fault) -> requeue or FAILED

Storage is obtained through exactly one path: every job's demands become a
declarative `StorageSpec`, the orchestrator's `ProvisioningService`
negotiates a backend (ephemeral FS, global FS, KV store, dry-run) and
grants a `StorageSession`, and the lifecycle advances its virtual clock by
the session's modeled costs (`provision_time_s` — C8 deploy, warm on
retries over the same nodes; `stage_in_time_s` — the slower of the
global-FS read and backend write paths; `teardown_time_s`). Releasing a
session returns whatever it held — nodes + file system for a job-scoped
grant, a pool lease for a pooled one — so teardown-vs-lease-drain is
session policy, not lifecycle code. A `FaultInjector` may trip any phase;
a tripped job releases its session and requeues (up to ``max_retries``),
the retry paying a *warm* redeploy when it lands on the same nodes (§IV-B1).

**Pool-backed jobs** (a POOLED `StorageSpec`, or the legacy
``WorkflowSpec(use_pool=True)``) ride the same state machine: negotiation
resolves them to a lease on a live persistent pool, the PROVISIONING slot
costs only the lease attach, TEARDOWN is free (the pool outlives the job),
and STAGING_IN moves only the dataset bytes *not already resident* on the
granted pool. Datasets staged by one job are cache hits for the next.

**Fault tolerance** (all opt-in; with every knob off the engine replays
PR 4 campaigns bit-for-bit):

* *Checkpoint-aware requeue* — a spec with ``checkpoint_every_s`` commits
  run progress on that cadence, each commit paying a modeled checkpoint
  write against the session's bandwidth (``checkpoint_bytes`` through the
  perfmodel; the `repro.checkpoint` burst-then-drain story priced for the
  virtual clock). A fault at the ``run`` phase then requeues a **resume
  attempt**: it pays only the uncommitted remainder of ``run_time_s``,
  re-reads ``checkpoint_bytes`` from the global FS when it lands cold, and
  re-stages only inputs that were actually lost — pool leases re-attach
  warm (the catalog knows what is still resident), and an ephemeral grant
  landing entirely on the nodes that staged it skips stage-in outright.
* *Preemption* — :meth:`Orchestrator.preempt` checkpoint-and-releases a
  RUNNING victim (progress commits through a final checkpoint write) and
  requeues it as a resume attempt that does not count against
  ``max_retries``. With a :class:`~.policies.PreemptionPolicy` installed,
  a blocked higher-``priority`` arrival triggers victim selection
  automatically (lowest priority first, most progress protected).
* *EASY reservations* — `EasyBackfillPolicy` books the blocked
  head-of-queue job a start time from the scheduler's projected-release
  ledger (fed by every started session's modeled span) and backfills only
  jobs that provably cannot delay it.
"""

from __future__ import annotations

import dataclasses
import enum
import heapq
import itertools
import math
from typing import Optional

from ..chaos.blast import resolve_blast_radius
from ..chaos.retry import drive_retries
from ..core.perfmodel import FSDeployment, dom_lustre
from ..core.scheduler import Allocation, AllocationError, JobRequest, StorageRequest
from ..obs.trace import NULL_RECORDER
from ..pilot.run import PilotRun, PilotSpec
from ..pool.catalog import DatasetRef, total_bytes
from ..pool.manager import PoolManager
from ..pool.pool import Lease
from ..provision import (
    LifetimeClass,
    NegotiationError,
    Offer,
    ProvisioningService,
    StorageSession,
    StorageSpec,
)
from ..runtime.fault import FaultInjector, HeartbeatMonitor
from .dispatch import DispatchQueue
from .engine import SimEngine
from .policies import FIFOPolicy, PreemptionPolicy, QueuePolicy, VictimView


class JobState(enum.Enum):
    QUEUED = "queued"
    ALLOCATED = "allocated"
    PROVISIONING = "provisioning"
    STAGING_IN = "staging_in"
    RUNNING = "running"
    STAGING_OUT = "staging_out"
    TEARDOWN = "teardown"
    DONE = "done"
    FAILED = "failed"


TERMINAL_STATES = frozenset({JobState.DONE, JobState.FAILED})

# The FaultInjector phase names, consulted at the end of PROVISIONING /
# STAGING_IN / RUNNING / STAGING_OUT (see the per-phase _*_done handlers).


@dataclasses.dataclass(frozen=True)
class WorkflowSpec:
    """One job's demands on the provisioning pipeline.

    Storage demands are best stated as a declarative ``storage_spec``
    (:class:`~repro.provision.StorageSpec`): preferred data managers with
    fallbacks, lifetime class, datasets, QoS. The legacy fields
    (``storage=StorageRequest(...)``, ``use_pool``, ``datasets``) remain
    supported and are translated into an equivalent spec pinned to the
    ``ephemeralfs`` backend — they cannot be mixed with ``storage_spec``.
    """

    name: str
    n_compute: int
    storage: Optional[StorageRequest] = None
    stage_in_bytes: float = 0.0
    stage_out_bytes: float = 0.0
    run_time_s: float = 60.0
    n_streams: int = 8
    max_retries: int = 2
    runtime: str = "shifter"
    datasets: tuple = ()              # tuple[DatasetRef, ...] shared inputs
    use_pool: bool = False
    storage_spec: Optional[StorageSpec] = None
    #: commit run progress every this many seconds of RUNNING (None: a fault
    #: at `run` replays the whole run — the pre-checkpointing behavior)
    checkpoint_every_s: Optional[float] = None
    #: modeled size of one checkpoint write, charged against the session's
    #: bandwidth at every commit (and re-read on a cold resume)
    checkpoint_bytes: float = 0.0
    #: preemption rank: a blocked arrival with higher priority may
    #: checkpoint-and-release lower-priority RUNNING jobs (see preempt())
    priority: int = 0
    preemptible: bool = True

    def __post_init__(self) -> None:
        object.__setattr__(self, "datasets", tuple(self.datasets))
        if self.run_time_s < 0 or self.stage_in_bytes < 0 or self.stage_out_bytes < 0:
            raise ValueError(f"negative duration/bytes in spec {self.name!r}")
        if self.max_retries < 0:
            raise ValueError("max_retries must be >= 0")
        if self.checkpoint_every_s is not None and self.checkpoint_every_s <= 0:
            raise ValueError(f"{self.name!r}: checkpoint_every_s must be positive")
        if self.checkpoint_bytes < 0:
            raise ValueError(f"{self.name!r}: negative checkpoint_bytes")
        if self.checkpoint_bytes and self.checkpoint_every_s is None:
            raise ValueError(
                f"{self.name!r}: checkpoint_bytes without checkpoint_every_s "
                "would never be written; set a cadence"
            )
        if self.storage_spec is not None:
            if (
                self.storage is not None
                or self.use_pool
                or self.datasets
                or self.stage_in_bytes
                or self.stage_out_bytes
                or self.n_streams != 8
                or self.runtime != "shifter"
            ):
                raise ValueError(
                    f"{self.name!r}: storage_spec replaces the legacy storage/"
                    "use_pool/datasets/stage_*_bytes/n_streams/runtime fields "
                    "(they all belong on the StorageSpec); set one or the other"
                )
            return
        if any(not isinstance(d, DatasetRef) for d in self.datasets):
            raise ValueError(f"{self.name!r}: datasets must be DatasetRef instances")
        if len({d.name for d in self.datasets}) != len(self.datasets):
            raise ValueError(f"{self.name!r}: duplicate dataset names")
        if self.use_pool and self.storage is not None:
            raise ValueError(
                f"{self.name!r}: use_pool jobs lease pool capacity; "
                "drop the per-job storage request"
            )
        if (
            self.storage is None
            and not self.use_pool
            and (self.stage_in_bytes or self.stage_out_bytes or self.datasets)
        ):
            raise ValueError(f"{self.name!r}: staging bytes without a storage request")

    # -- the one storage path -------------------------------------------------
    def session_spec(self) -> Optional[StorageSpec]:
        """The declarative spec this job presents to the ProvisioningService
        (None for jobs with no storage demand at all). Legacy fields pin the
        ``ephemeralfs`` backend, preserving their original semantics."""
        if self.storage_spec is not None:
            return self.storage_spec
        if self.use_pool:
            return StorageSpec(
                name=self.name,
                lifetime=LifetimeClass.POOLED,
                managers=("ephemeralfs",),
                datasets=self.datasets,
                stage_in_bytes=self.stage_in_bytes,
                stage_out_bytes=self.stage_out_bytes,
                n_streams=self.n_streams,
                runtime=self.runtime,  # type: ignore[arg-type]
            )
        if self.storage is not None:
            return StorageSpec(
                name=self.name,
                nodes=self.storage.nodes,
                capacity_bytes=self.storage.capacity_bytes,
                bandwidth=self.storage.capability_bw,
                managers=("ephemeralfs",),
                datasets=self.datasets,
                stage_in_bytes=self.stage_in_bytes,
                stage_out_bytes=self.stage_out_bytes,
                n_streams=self.n_streams,
                runtime=self.runtime,  # type: ignore[arg-type]
            )
        return None

    @property
    def fault_tolerant(self) -> bool:
        """Checkpoint-aware requeue on: RUNNING commits progress on the
        ``checkpoint_every_s`` cadence and faulted/preempted attempts
        resume from the last committed step instead of restarting."""
        return self.checkpoint_every_s is not None

    @property
    def wants_pool(self) -> bool:
        return self.use_pool or (
            self.storage_spec is not None
            and self.storage_spec.lifetime is LifetimeClass.POOLED
        )

    @property
    def all_datasets(self) -> tuple:
        if self.storage_spec is not None:
            return self.storage_spec.datasets
        return self.datasets

    @property
    def dataset_bytes(self) -> float:
        return total_bytes(self.all_datasets)

    @property
    def scratch_bytes(self) -> float:
        """Private pool capacity a lease must reserve on top of datasets."""
        if self.storage_spec is not None:
            return self.storage_spec.scratch_bytes
        return self.stage_in_bytes + self.stage_out_bytes


@dataclasses.dataclass(slots=True)
class JobRecord:
    """Mutable per-job bookkeeping the orchestrator and metrics share."""

    spec: WorkflowSpec
    job_id: int
    submit_time: float
    state: JobState = JobState.QUEUED
    attempt: int = 0
    sspec: Optional[StorageSpec] = None          # resolved once at submit
    offer: Optional[Offer] = None                # cached non-POOLED negotiation
    session: Optional[StorageSession] = None     # live negotiated grant
    allocation: Optional[Allocation] = None
    alloc_started: Optional[float] = None
    fs_model: Optional[FSDeployment] = None
    failure_phase: Optional[str] = None
    backend: Optional[str] = None                # negotiated data manager
    # storage nodes holding a fully-deployed tree of this job's FS: a retry
    # landing on these nodes redeploys warm (paper §IV-B1)
    warm_nodes: frozenset = frozenset()
    history: list[tuple[JobState, float]] = dataclasses.field(default_factory=list)
    # closed (alloc_time, release_time, n_storage_nodes) intervals per attempt
    storage_intervals: list[tuple[float, float, int]] = dataclasses.field(
        default_factory=list
    )
    staged_in_bytes: float = 0.0
    staged_out_bytes: float = 0.0
    # pool-backed bookkeeping (summed across retries)
    lease: Optional[Lease] = None
    pool_id: Optional[int] = None
    dataset_hits: int = 0
    dataset_misses: int = 0
    stage_in_saved_bytes: float = 0.0
    #: mirrors ``spec.wants_pool`` (checked on every transition; precomputed)
    wants_pool: bool = False
    #: granted (compute ids, storage ids, pool id) per attempt — the
    #: determinism regressions compare these across dispatch paths
    alloc_history: list = dataclasses.field(default_factory=list)
    # -- fault tolerance (checkpoint-aware requeue + preemption) -----------
    committed_run_s: float = 0.0      # run progress durable across attempts
    checkpoints_committed: int = 0
    preemptions: int = 0              # checkpoint-and-release requeues
    resume_attempts: int = 0          # attempts that started with committed work
    run_s_saved: float = 0.0          # run seconds resumes did not replay
    #: storage nodes still holding this job's fully staged inputs (and
    #: checkpoints) from a completed stage-in — a resume landing entirely
    #: on them skips stage-in (the data-plane analogue of ``warm_nodes``)
    staged_nodes: frozenset = frozenset()
    #: bottom-level pilot runtime (two-level scheduling) — None for plain
    #: jobs; every pilot-only hot-path branch gates on this being set
    pilot: Optional[PilotRun] = None
    #: pool still holding this job's latest checkpoint commit (pool ids are
    #: never reused): a pooled resume re-leasing this exact pool skips the
    #: global-FS restore read; cleared when a node loss hits the pool
    checkpoint_pool_id: Optional[int] = None
    run_token: int = 0                # invalidates in-flight run events
    #: invalidates in-flight provision/stage/teardown events — bumped on
    #: every release and on a mid-phase re-price (node-loss degradation)
    phase_token: int = 0
    _phase_end: float = 0.0           # scheduled end of the in-flight stage phase
    _run_base: float = 0.0            # progress committed at segment start
    _run_t0: float = 0.0              # virtual time current segment began
    _run_seg_s: float = 0.0           # progress length of current segment
    _preempt_pending: bool = False    # final checkpoint draining pre-release
    _request: Optional[JobRequest] = None
    _gating: Optional[tuple] = None              # dispatch pre-filter cache

    @property
    def request(self) -> JobRequest:
        """Scheduler-level view of the job's demand (policies rank by it).
        Pool-backed jobs draw storage from a lease, not the allocator.
        Cached: ``sspec`` is resolved once at submit and never changes."""
        if self._request is None:
            storage = None
            if self.sspec is not None and self.sspec.lifetime is not LifetimeClass.POOLED:
                storage = self.sspec.to_request()
            self._request = JobRequest(
                self.spec.name, self.spec.n_compute, storage=storage
            )
        return self._request

    @property
    def done(self) -> bool:
        return self.state in TERMINAL_STATES


@dataclasses.dataclass(frozen=True)
class Reservation:
    """EASY guarantee for a blocked head-of-queue job: its resolved node
    demand and the promised start instant. ``start_at`` is None when no
    start can be proven (needed nodes are held by allocations with no
    release projection) — then nothing backfills at all."""

    job_id: int
    n_compute: int
    n_storage: int
    start_at: Optional[float]


@dataclasses.dataclass(slots=True)
class LiveCounters:
    """Campaign rollups maintained incrementally on every transition and
    release, so mid-flight dashboard polls are O(1) instead of the O(jobs)
    re-scan `metrics.summarize` pays (the batch path remains the reference;
    `tests/test_fault_tolerance.py` holds the two equal).

    Open storage allocations are folded as two aggregates — node count and
    node-weighted start-time sums — so busy node-seconds at any instant is
    ``busy_node_s + now * open_nodes - open_node_start_s`` without walking
    live jobs."""

    n_jobs: int = 0
    n_done: int = 0
    n_failed: int = 0
    retries: int = 0              # fault requeues (preemptions counted apart)
    preemptions: int = 0
    resumes: int = 0              # attempts that started with committed work
    checkpoints: int = 0
    run_s_saved: float = 0.0
    staged_in_bytes: float = 0.0
    staged_out_bytes: float = 0.0
    stage_in_saved_bytes: float = 0.0
    # pilot (two-level scheduling) rollups — task batches fold in O(1)
    pilots: int = 0
    tasks_submitted: int = 0
    tasks_done: int = 0
    tasks_failed: int = 0
    task_retries: int = 0
    busy_node_s: float = 0.0      # closed storage-allocation intervals
    open_nodes: int = 0           # sum of n_storage over open allocations
    open_node_start_s: float = 0.0
    t_first_submit: Optional[float] = None
    t_last_event: float = 0.0

    def note_submit(self, t: float) -> None:
        if self.t_first_submit is None or t < self.t_first_submit:
            self.t_first_submit = t

    def busy_node_seconds(self, now: float) -> float:
        return self.busy_node_s + now * self.open_nodes - self.open_node_start_s

    def makespan_s(self, now: float) -> float:
        if self.t_first_submit is None:
            return 0.0
        return max(self.t_last_event, now) - self.t_first_submit

    def utilization(self, n_storage_nodes: int, now: float) -> float:
        span = self.makespan_s(now)
        if n_storage_nodes <= 0 or span <= 0:
            return 0.0
        return self.busy_node_seconds(now) / (n_storage_nodes * span)


class Orchestrator:
    """Runs provisioning campaigns: many jobs through one cluster, queued
    by policy, timed by the perfmodel, perturbed by fault injection. All
    storage flows through one `ProvisioningService` (``self.provision``)."""

    def __init__(
        self,
        cluster,
        *,
        policy: QueuePolicy | None = None,
        faults: FaultInjector | None = None,
        engine: SimEngine | None = None,
        globalfs_model: FSDeployment | None = None,
        teardown_time_s: float | None = None,
        provision: ProvisioningService | None = None,
        incremental: Optional[bool] = None,
        record_allocations: bool = True,
        preemption: Optional[PreemptionPolicy] = None,
        recorder=None,
    ):
        self.engine = engine or SimEngine()
        if provision is None:
            provision = ProvisioningService(
                cluster,
                globalfs_model=globalfs_model or dom_lustre(),
                teardown_time_s=0.5 if teardown_time_s is None else teardown_time_s,
                clock=lambda: self.engine.now,
            )
        elif globalfs_model is not None or teardown_time_s is not None:
            raise ValueError(
                "globalfs_model/teardown_time_s are service knobs: configure "
                "them on the ProvisioningService you pass in"
            )
        self.provision = provision
        # sessions price TEARDOWN and staging from the service; mirror its
        # values so the orchestrator attributes never disagree with behavior
        self.teardown_time_s = self.provision.teardown_time_s
        self.globalfs_model = self.provision.globalfs_model
        self.scheduler = self.provision.scheduler
        self.provisioner = self.provision.provisioner
        self.faults = faults or FaultInjector()
        # Incremental (indexed) dispatch is the default for every policy
        # honoring the sort_key contract; custom policies fall back to the
        # legacy sort-everything loop. ``incremental=False`` forces the
        # legacy path (the determinism regressions replay both).
        # per-attempt granted node ids on JobRecord.alloc_history —
        # determinism evidence; disable for campaigns of very wide jobs
        # where retaining every node id would dominate memory
        self._record_allocations = record_allocations
        self._incremental_requested = incremental
        self._dq: Optional[DispatchQueue] = None
        self._queue: list[JobRecord] = []      # legacy-path wait queue
        self.policy = policy or FIFOPolicy()   # setter builds the index
        self.jobs: list[JobRecord] = []
        self._ids = itertools.count(1)
        # pool-reap bookkeeping: #pool-wanting jobs not yet terminal and not
        # holding a lease (maintained on every transition — replaces the old
        # O(jobs) scan per reap event) + pending-reap coalescing by fire time
        self._pool_wait_n = 0
        self._reap_times: set[float] = set()
        # last full-scan "nothing fits" conclusion: (admission state it was
        # drawn under, and — for head-blocking policies — the blocking
        # head's key). Lets arrival dispatches short-circuit in O(1).
        self._noadmit_state: Optional[tuple] = None
        self._noadmit_head_key: Optional[tuple] = None
        # fault-tolerant scheduling layer: automatic victim selection for
        # blocked high-priority arrivals (None: preempt() is manual-only),
        # live RUNNING index, the EASY reservation last booked by a
        # reserving policy's scan, and the O(1) campaign counters
        self._preemption = preemption
        self._running: dict[int, JobRecord] = {}
        self.reservation: Optional[Reservation] = None
        self.counters = LiveCounters()
        # observability: a repro.obs.trace.TraceRecorder wires itself into
        # the engine, the provisioning service, the scheduler, and the pool
        # subsystem here (bind is read-only: it never schedules events or
        # touches job/session state, so traced campaigns replay
        # bit-identically — see tests/test_obs.py)
        # chaos engine: armed by enable_chaos(); chaos-off campaigns keep
        # these falsy and schedule zero extra events
        self._chaos_model = None
        self._chaos_retry = None
        self._down_nodes: set[str] = set()
        self.recorder = recorder if recorder is not None else NULL_RECORDER
        if self.recorder.enabled:
            self.recorder.bind(self)

    @property
    def alerts(self):
        """The :class:`~repro.obs.alerts.AlertEngine` riding the recorder,
        if one was attached (duck-typed — no obs import on the hot path)."""
        return getattr(self.recorder, "alerts", None)

    @property
    def faults(self) -> FaultInjector:
        return self._faults

    @faults.setter
    def faults(self, faults: FaultInjector) -> None:
        """To change fault behavior mid-setup, assign a new injector here
        (mutating an installed injector's ``spec`` is not supported). Only
        the *stock* fault-free injector is bypassed on the hot path —
        subclasses overriding :meth:`FaultInjector.trip` always get
        consulted, whatever their spec says."""
        self._faults = faults
        self._faults_passive = (
            type(faults) is FaultInjector and not faults.any_faults
        )

    @property
    def policy(self) -> QueuePolicy:
        return self._policy

    @policy.setter
    def policy(self, policy: QueuePolicy) -> None:
        """Swapping the policy re-indexes any waiting jobs (their policy
        keys, buckets, and aging class all belong to the old policy)."""
        use = self._incremental_requested
        if use is None:
            use = getattr(policy, "incremental", False)
        elif use and not getattr(policy, "incremental", False):
            raise ValueError(
                f"policy {policy.name!r} does not implement the "
                "incremental dispatch contract (QueuePolicy.sort_key)"
            )
        queued = self.queue
        self._policy = policy
        self._noadmit_state = None     # conclusions belong to the old policy
        self._noadmit_head_key = None
        if use:
            self._dq = DispatchQueue(policy, self.scheduler)
            for job in queued:
                self._dq.add(job, self.engine.now)
            self._queue = []
        else:
            self._dq = None
            self._queue = list(queued)

    @property
    def queue(self) -> list[JobRecord]:
        """Waiting jobs in arrival order (a snapshot under indexed dispatch)."""
        if self._dq is not None:
            return self._dq.jobs()
        return self._queue

    def _enqueue(self, job: JobRecord) -> None:
        if self._dq is not None:
            self._dq.add(job, self.engine.now)
        else:
            self._queue.append(job)

    # -- pools ----------------------------------------------------------------
    @property
    def pools(self) -> Optional[PoolManager]:
        """The service's pool subsystem (None until attached/first use)."""
        return self.provision.pool_manager

    def enable_pools(self, **kwargs) -> PoolManager:
        """Attach a persistent-pool subsystem over this orchestrator's
        provisioning service. Pools themselves are best created through the
        service (a PERSISTENT `StorageSpec`); ``use_pool``/POOLED jobs lease
        from them. A no-argument call returns the existing manager."""
        if self.provision.pool_manager is not None and not kwargs:
            return self.provision.pool_manager
        kwargs.setdefault("clock", lambda: self.engine.now)
        return self.provision.ensure_pools(**kwargs)

    # -- submission ----------------------------------------------------------
    def _check_spec(self, spec: WorkflowSpec) -> None:
        if spec.wants_pool and self.provision.pool_manager is None:
            raise ValueError(
                f"{spec.name!r}: pooled storage requires enable_pools() (or a "
                "PERSISTENT session) first"
            )

    def _make_job(self, spec: WorkflowSpec, at: Optional[float]) -> JobRecord:
        t = self.engine.now if at is None else at
        sspec = spec.session_spec()
        if sspec is None:
            # no storage demand: a dry-run session still co-allocates compute
            sspec = StorageSpec(name=spec.name, managers=("null",))
        job = JobRecord(
            spec=spec,
            job_id=next(self._ids),
            submit_time=t,
            sspec=sspec,
            wants_pool=spec.wants_pool,
        )
        self.jobs.append(job)
        self._pool_wait_n += self._pool_waiting(job)
        self.counters.n_jobs += 1
        self.counters.note_submit(t)
        return job

    def submit(self, spec: WorkflowSpec, at: Optional[float] = None) -> JobRecord:
        """Enqueue a job at virtual time ``at`` (default: now)."""
        self._check_spec(spec)
        job = self._make_job(spec, at)
        self.engine.at(job.submit_time, lambda: self._arrive(job))
        return job

    def submit_pilot(
        self,
        pspec: PilotSpec,
        tasks: tuple = (),
        at: Optional[float] = None,
    ) -> JobRecord:
        """Submit a pilot: ONE top-level job that acquires a block of
        ``n_compute`` compute nodes plus ONE pooled storage session, then
        multiplexes ``tasks`` (sub-node :class:`~repro.pilot.TaskSpec`
        instances) into its ``n_compute * slots_per_node`` slots with the
        in-pilot :class:`~repro.pilot.TaskScheduler`.

        The pilot flows through the ordinary queue/dispatch/negotiation
        path — exactly one negotiation and one ``open_session`` grant per
        attempt, leases from the `PoolManager` so the pilot-wide datasets
        stay warm across the whole task stream — but its RUNNING phase is
        driven by the task scheduler instead of ``run_time_s``: the engine
        sees one coalesced event per completion *batch*, and task-level
        faults/checkpoints requeue inside the pilot without touching the
        global scheduler. Requires :meth:`enable_pools` (the session is
        POOLED). Pilots are not preemptible; the job-level ``run`` fault
        still applies to the whole attempt. Task-level faults consult the
        injector's ``"task"`` phase — arm them *before* submitting (a pilot
        submitted while the injector is passive skips the per-task oracle
        call entirely, the hot-path fast lane).
        """
        spec = WorkflowSpec(
            name=pspec.name,
            n_compute=pspec.n_compute,
            run_time_s=0.0,
            max_retries=pspec.max_retries,
            preemptible=False,
            storage_spec=StorageSpec(
                name=pspec.name,
                lifetime=LifetimeClass.POOLED,
                managers=("ephemeralfs",),
                datasets=tuple(pspec.datasets),
                stage_in_bytes=pspec.stage_in_bytes,
                stage_out_bytes=pspec.stage_out_bytes,
                n_streams=pspec.n_streams,
            ),
        )
        self._check_spec(spec)
        job = self._make_job(spec, at)
        if self._faults_passive:
            trip = None
        else:
            def trip(name: str) -> bool:
                return self.faults.trip(name, "task")
        pilot = PilotRun(
            pspec,
            engine=self.engine,
            recorder=self.recorder,
            counters=self.counters,
            trip=trip,
            job_id=job.job_id,
        )
        job.pilot = pilot
        self.counters.pilots += 1
        for t in tasks:
            if isinstance(t, tuple):
                tspec, n = t
                pilot.submit(tspec, n)
            else:
                pilot.submit(t)
        self.engine.at(job.submit_time, lambda: self._arrive(job))
        return job

    def _arrive(self, job: JobRecord) -> None:
        feasible = job.spec.n_compute <= len(self.scheduler.cluster.compute_nodes)
        if feasible:
            try:
                offer = self.provision.negotiate(job.sspec)
            except NegotiationError:
                # an arrival mid-outage queues anyway: the verdict may be
                # an artifact of a healing pool, and the post-repair
                # dispatch re-derives it from whole-cluster state
                feasible = bool(self._down_nodes)
            else:
                if job.sspec.lifetime is not LifetimeClass.POOLED:
                    job.offer = offer   # static over the campaign: reuse at dispatch
        if not feasible:
            # No backend can ever serve this spec on this cluster: fail fast
            # instead of letting an error escape the campaign (or queueing
            # forever).
            job.failure_phase = "infeasible"
            self._transition(job, JobState.QUEUED)
            self._transition(job, JobState.FAILED)
            return
        self._transition(job, JobState.QUEUED)
        self._enqueue(job)
        self._dispatch(new_job=job)
        if (
            self._preemption is not None
            and job.state is JobState.QUEUED
            and job.spec.priority > 0
        ):
            self._try_preempt(job)

    # -- dispatch loop -------------------------------------------------------
    def _dispatch(self, new_job: Optional[JobRecord] = None) -> None:
        """Start every queued job the policy admits against the free pool.
        ``new_job`` marks an arrival-triggered dispatch, which the indexed
        path can often resolve in O(1) (nothing freed since the last scan
        concluded nothing fits, so only the arrival itself is a candidate)."""
        if self._dq is not None:
            self._dispatch_indexed(new_job)
        else:
            self._dispatch_legacy()

    # admission state = everything a refusal can go stale against: the
    # scheduler free pool (epoch) and the pool subsystem (leases, ledgers,
    # catalog). Aging/promotion changes *order*, never admissibility.
    def _admission_state(self) -> tuple:
        pm = self.provision.pool_manager
        return (self.scheduler.epoch, pm.epoch if pm is not None else -1)

    def _sizing_signature(self) -> tuple:
        """Weakest-free-node contributions: while these are unchanged, every
        capacity/bandwidth request resolves to the same node count, so a
        shrinking free pool can only turn fits into misfits — refusals from
        earlier in the scan stay valid."""
        s = self.scheduler
        return (s.free_min_capacity(), s.free_min_bandwidth())

    _ADMITTED, _REFUSED, _FAILED = "admitted", "refused", "failed"

    def _probe(self, job: JobRecord, reservation: Optional[Reservation] = None) -> str:
        """One admission attempt against the live cluster (indexed path).
        With a ``reservation``, admission runs under the EASY no-delay
        proof instead of the plain open (and skips the pre-filter: the
        proof does its own fit checks)."""
        if reservation is None and not self._admittable_now(job):
            return self._REFUSED
        try:
            session = (
                self._try_open(job)
                if reservation is None
                else self._reserved_try_open(job, reservation)
            )
        except NegotiationError:
            if self._down_nodes:
                # mid-outage the conclusion is not trustworthy: the pool
                # that could hold this working set may be healing. Wait —
                # the repair/backfill re-dispatch will probe again.
                return self._REFUSED
            self._dq.remove(job)
            job.failure_phase = "infeasible"
            self._transition(job, JobState.FAILED)
            return self._FAILED
        if session is None:
            return self._REFUSED
        self._dq.remove(job)
        self._start(job, session)
        return self._ADMITTED

    # -- EASY reservations ----------------------------------------------------
    def _reserve(self, job: JobRecord) -> Reservation:
        """Book the blocked head its start time: the earliest instant the
        scheduler's projected-release ledger says its node demand fits."""
        try:
            hc, hs = self.scheduler.demand(job.request)
        except AllocationError:
            return Reservation(job.job_id, 0, 0, None)
        t = self.scheduler.earliest_fit(hc, hs, self.engine.now)
        rec = self.recorder
        if rec.enabled:
            rec.reservation(job.job_id, t)
        return Reservation(job.job_id, hc, hs, t)

    def _reserved_try_open(
        self, job: JobRecord, res: Reservation
    ) -> Optional[StorageSession]:
        """Grant a backfill candidate only when it provably cannot delay the
        reserved head start: either the head's node counts survive at
        ``start_at`` even if this candidate never releases, or the
        candidate's own modeled completion lands before the reservation
        (checked against the live session costs — the grant is handed back
        when the proof fails). An unprovable reservation backfills nothing."""
        if res.start_at is None:
            return None
        sched = self.scheduler
        try:
            cc, cs = sched.demand(job.request)
        except AllocationError:
            return None
        fc, fs = sched.free_counts()
        if cc > fc or cs > fs:
            return None                  # does not even fit right now
        dc, ds = sched.projected_free_at(res.start_at)
        if fc - cc + dc >= res.n_compute and fs - cs + ds >= res.n_storage:
            return self._try_open(job)   # leaves the head whole regardless
        if job.sspec.lifetime is not LifetimeClass.EPHEMERAL:
            # proving completion-before-reservation needs a trial grant,
            # and opening a POOLED/PERSISTENT session mutates pool state
            # (pins, evictions, pool creation): refuse instead of probing
            return None
        session = self._try_open(job)
        if session is None:
            return None
        if self.engine.now + self._session_span_s(job, session) <= res.start_at:
            return session
        session.release(self.engine.now)   # would delay the head: hand it back
        # the trial grant never ran: un-count it so session telemetry keeps
        # meaning "sessions that actually carried a job attempt"
        stats = self.provision.stats
        stats.sessions_opened[session.backend] -= 1
        stats.sessions_released -= 1
        return None

    def _dispatch_indexed(self, new_job: Optional[JobRecord] = None) -> None:
        """Incremental dispatch over the indexed queue.

        Observably identical to :meth:`_dispatch_legacy`: same-signature
        jobs receive identical admission answers at any instant, so probing
        one head per bucket probes exactly the jobs whose refusal the legacy
        scan would not have skipped; and a candidate heap merged with each
        admitted bucket's next head reproduces the legacy restart order as
        long as no admission changed the sizing or pool state (when one
        does, the pass restarts from a fresh ranking, as legacy always
        does)."""
        dq = self._dq
        now = self.engine.now
        dq.promote(now)
        state = self._admission_state()
        # reserving policies re-scan on every trigger: a lone-arrival probe
        # would bypass the reservation's no-delay gating, and backfill
        # verdicts also depend on projected completions, which the
        # admission state deliberately does not track
        if (
            new_job is not None
            and self._noadmit_state == state
            and not self.policy.reserving
        ):
            # Nothing has been freed since a full scan concluded that
            # nothing fits: the arrival is the only new candidate.
            policy = self.policy
            if policy.head_blocking:
                blocked = self._noadmit_head_key
                if blocked is not None:
                    key_new = (
                        policy.sort_key(new_job, self.scheduler, now),
                        dq.seq_of(new_job),
                    )
                    if key_new >= blocked:
                        return          # the blocked head still blocks
            else:
                if not dq.is_bucket_head(new_job):
                    return              # same-signature job already refused
                sizing = self._sizing_signature()
                if self._probe(new_job) is not self._ADMITTED:
                    return              # state unchanged; refusals still hold
                if (
                    self._sizing_signature() == sizing
                    and self._admission_state()[1] == state[1]
                ):
                    # the admission only shrank the free pool: every earlier
                    # refusal still holds, no full scan needed
                    self._noadmit_state = self._admission_state()
                    return
        self._run_dispatch_scan(now)

    def _run_dispatch_scan(self, now: float) -> None:
        """One dispatch pass over the bucket heads, merged in policy order.

        Head-blocking policies must probe their true first head, so they
        skip the admissibility gate and stop at the first refusal; all
        others gate out certain refusals before paying for policy keys and
        keep scanning. Either way, an admitted (or failed) bucket's next
        head re-enters the heap exactly where the departing job ranked —
        the legacy restart order — as long as no admission moved the
        sizing or pool state (then the pass restarts from a fresh ranking,
        as legacy always does)."""
        dq = self._dq
        head_blocking = self.policy.head_blocking
        reserving = self.policy.reserving
        # reserving policies must see their true first head (the job the
        # reservation belongs to), so they skip the gate like head-blockers
        gate = None if (head_blocking or reserving) else self._admittable_now
        while True:
            reservation = None
            if reserving:
                self.reservation = None
            candidates = dq.candidate_heads(now, gate)
            if not candidates:
                self._noadmit_state = self._admission_state()
                self._noadmit_head_key = None
                return
            heapq.heapify(candidates)
            sizing = self._sizing_signature()
            pool_epoch = self._admission_state()[1]
            restart = False
            while candidates:
                key, seq, job, bucket = heapq.heappop(candidates)
                outcome = self._probe(job, reservation)
                if outcome is self._REFUSED:
                    if head_blocking:
                        self._noadmit_state = self._admission_state()
                        self._noadmit_head_key = (key, seq)
                        return
                    if reserving and reservation is None:
                        # the first refusal in policy order is the queue
                        # head: book its EASY reservation; later candidates
                        # are admitted only under its no-delay proof
                        reservation = self._reserve(job)
                        self.reservation = reservation
                    continue            # whole bucket refused until a restart
                if outcome is self._ADMITTED and (
                    self._sizing_signature() != sizing
                    or self._admission_state()[1] != pool_epoch
                ):
                    restart = True      # refusals/ranks may have gone stale
                    break
                item = dq.head_item(bucket, now, gate)
                if item is not None:
                    heapq.heappush(candidates, item)
            if restart:
                continue
            self._noadmit_state = self._admission_state()
            self._noadmit_head_key = None
            return

    def _dispatch_legacy(self) -> None:
        """The pre-index dispatch loop (compatibility fallback for custom
        policies, and the reference the determinism regressions replay).
        Reserving policies get the same EASY gating as the indexed path:
        the pass's first refusal books the reservation, and the rest of the
        pass may only backfill around it (each admission restarts the pass,
        so the reservation is re-derived from fresh state)."""
        started = True
        reserving = self.policy.reserving
        while started and self._queue:
            started = False
            reservation = None
            if reserving:
                self.reservation = None
            for job in self.policy.order(self._queue, self.scheduler, self.engine.now):
                try:
                    if reservation is not None:
                        session = self._reserved_try_open(job, reservation)
                    else:
                        session = self._try_open(job)
                except NegotiationError:
                    if self._down_nodes:
                        # mid-outage infeasibility is not trustworthy (the
                        # capable pool may be healing): keep the job queued
                        # and let the repair/backfill re-dispatch re-probe
                        if self.policy.head_blocking:
                            break
                        continue
                    # what was feasible at arrival no longer is (e.g. every
                    # pool that could hold the working set was retired):
                    # fail fast instead of stranding the job in the queue
                    self._queue.remove(job)
                    job.failure_phase = "infeasible"
                    self._transition(job, JobState.FAILED)
                    started = True
                    break
                if session is None:
                    if self.policy.head_blocking:
                        break
                    if reserving and reservation is None:
                        reservation = self._reserve(job)
                        self.reservation = reservation
                    continue
                self._queue.remove(job)
                self._start(job, session)
                started = True
                break                 # re-ask the policy: free pool changed

    def _gating(self, job: JobRecord) -> tuple:
        """Pre-filter terms for a job, computed once: ``()`` when the job
        must always be probed for real (POOLED/PERSISTENT specs, custom
        backends), else ``(n_compute, storage_request_or_None)``."""
        gating = job._gating
        if gating is None:
            offer = job.offer
            if offer is None or job.sspec.lifetime is not LifetimeClass.EPHEMERAL:
                gating = ()
            else:
                backend = self.provision.registry.get(offer.backend)
                if backend is None or not backend.scheduler_gated:
                    gating = ()
                else:
                    storage = (
                        job.request.storage
                        if backend.capabilities.dedicated_nodes
                        else None
                    )
                    if storage is not None and storage.nodes is not None:
                        storage = storage.nodes      # static node count
                    gating = (job.spec.n_compute, storage)
            job._gating = gating
        return gating

    def _admittable_now(self, job: JobRecord) -> bool:
        """Cheap pre-filter for indexed dispatch: False only when
        ``_try_open`` is *certain* to return None right now (two O(1) count
        checks against the indexed free pool). Only ``scheduler_gated``
        backends — whose EPHEMERAL admission is exactly the scheduler
        co-allocation fitting — are filtered; POOLED/PERSISTENT specs and
        custom backends always probe for real."""
        gating = self._gating(job)
        if not gating:
            return True
        n_compute, storage = gating
        sched = self.scheduler
        if n_compute > len(sched._free_compute):
            return False
        if storage is None:
            return True
        if type(storage) is int:
            return storage <= len(sched._free_storage)
        return sched.resolve_storage_nodes(storage) <= len(sched._free_storage)

    def _try_open(self, job: JobRecord) -> Optional[StorageSession]:
        """One declarative call grants everything the job holds: compute
        nodes co-allocated with whatever storage the negotiated backend
        needs (nodes + deploy, a pool lease, or nothing). Fault-tolerant
        specs additionally carry their resume state: which nodes still hold
        the staged inputs, and how many checkpoint bytes a cold landing
        must read back (time-cost-only — admission answers are unchanged,
        so resume attempts keep their admission-signature bucket)."""
        sspec = job.sspec
        offer = job.offer
        if offer is None:
            offer = self.provision.negotiate(sspec)   # may raise NegotiationError
            if sspec.lifetime is not LifetimeClass.POOLED:
                # EPHEMERAL/PERSISTENT feasibility is static over a campaign;
                # POOLED offers go stale as pools retire/drain, so those
                # re-negotiate on every dispatch attempt
                job.offer = offer
        ft = job.spec.fault_tolerant
        return self.provision.try_open_session(
            sspec,
            n_compute=job.spec.n_compute,
            warm_nodes=job.warm_nodes,
            now=self.engine.now,
            offer=offer,
            staged_nodes=job.staged_nodes if ft else frozenset(),
            restore_bytes=(
                job.spec.checkpoint_bytes
                if ft and job.committed_run_s > 0
                else 0.0
            ),
            restore_pool_id=job.checkpoint_pool_id if ft else None,
        )

    def _start(self, job: JobRecord, session: StorageSession) -> None:
        job.session = session
        job.allocation = session.allocation
        job.alloc_started = self.engine.now
        job.backend = session.backend
        self._transition(job, JobState.ALLOCATED)
        was_waiting = self._pool_waiting(job)
        job.lease = session.lease
        self._pool_wait_n += self._pool_waiting(job) - was_waiting
        if self._record_allocations:
            alloc = session.allocation
            job.alloc_history.append(
                (
                    tuple(n.node_id for n in alloc.compute_nodes) if alloc else (),
                    tuple(n.node_id for n in alloc.storage_nodes) if alloc else (),
                    session.lease.pool_id if session.lease is not None else None,
                )
            )
        if session.lease is not None:
            job.pool_id = session.lease.pool_id
            job.dataset_hits += session.lease.hits
            job.dataset_misses += session.lease.misses
        job.fs_model = session.fs_model
        if session.allocation is not None:
            n = len(session.allocation.storage_nodes)
            self.counters.open_nodes += n
            self.counters.open_node_start_s += n * self.engine.now
            # feed the EASY reservation ledger: when this attempt should
            # release, from the session's modeled costs (advisory — faults
            # and preemptions release earlier, and the ledger self-corrects)
            pilot = job.pilot
            if pilot is not None and pilot.spec.open_ended:
                # open-ended pilots accept late tasks: they promise no
                # release, so EASY must not book backfill holes against them
                self.scheduler.note_projected_release(session.allocation, None)
            else:
                self.scheduler.note_projected_release(
                    session.allocation,
                    self.engine.now + self._session_span_s(job, session),
                )
        rec = self.recorder
        if rec.enabled:
            rec.grant(job, session)
        self._transition(job, JobState.PROVISIONING)
        eng = self.engine
        token = job.phase_token
        eng.at(
            eng.now + session.provision_time_s,
            lambda: self._provision_done(job, token),
        )

    # -- phase machinery -----------------------------------------------------
    # Each phase-completion callback schedules its successor directly: no
    # per-event state dispatch on the hot path. A fault trip at any phase
    # boundary routes through _fail_attempt (release + requeue-or-FAIL).
    def _trip(self, job: JobRecord, phase: str) -> bool:
        return not self._faults_passive and self.faults.trip(job.spec.name, phase)

    def _provision_done(self, job: JobRecord, token: int = 0) -> None:
        if token != job.phase_token:
            return                       # attempt released mid-phase (chaos)
        if self._trip(job, "provision"):
            self._fail_attempt(job, "provision")
            return
        session = job.session
        if session.lease is None and job.allocation is not None:
            job.warm_nodes = job.warm_nodes | frozenset(
                n.node_id for n in job.allocation.storage_nodes
            )
        self._transition(job, JobState.STAGING_IN)
        eng = self.engine
        end = eng.now + session.stage_in_time_s
        job._phase_end = end
        eng.at(end, lambda: self._stage_in_done(job, token))

    def _stage_in_done(self, job: JobRecord, token: int = 0) -> None:
        if token != job.phase_token:
            return                       # attempt released or re-priced mid-stage
        if self._trip(job, "stage_in"):
            self._fail_attempt(job, "stage_in")
            return
        session = job.session
        counters = self.counters
        job.staged_in_bytes += session.stage_in_bytes
        counters.staged_in_bytes += session.stage_in_bytes
        # saved bytes count only when the stage-in actually completed
        # (a faulted attempt neither staged nor saved anything)
        job.stage_in_saved_bytes += session.saved_bytes
        counters.stage_in_saved_bytes += session.saved_bytes
        # lease misses are now resident: hits for every later job
        session.mark_staged(self.engine.now)
        if (
            job.spec.fault_tolerant
            and session.lease is None
            and job.allocation is not None
        ):
            # these nodes now hold the full staged input set: a resume
            # attempt landing entirely on them skips stage-in
            job.staged_nodes = job.staged_nodes | frozenset(
                n.node_id for n in job.allocation.storage_nodes
            )
        if job.committed_run_s > 0:
            # a resume attempt: the committed steps are run time not replayed
            job.resume_attempts += 1
            job.run_s_saved += job.committed_run_s
            counters.resumes += 1
            counters.run_s_saved += job.committed_run_s
        self._transition(job, JobState.RUNNING)
        if job.pilot is not None:
            self._begin_pilot(job)
        else:
            self._schedule_run(job)

    # -- pilots (two-level scheduling) -----------------------------------------
    def _begin_pilot(self, job: JobRecord) -> None:
        """Hand the RUNNING phase to the pilot's task scheduler. The pilot
        calls back into :meth:`_run_done` (with this attempt's run token)
        when its task stream drains, so STAGING_OUT/TEARDOWN/DONE — and the
        job-level ``run`` fault check — proceed exactly like a plain job."""
        pilot = job.pilot
        session = job.session
        token = job.run_token
        pm = self.provision.pool_manager
        pool_nodes = 0
        if pm is not None and job.pool_id is not None:
            pool_nodes = len(pm.get(job.pool_id).storage_node_ids)

        def reproject() -> None:
            # the pilot's drain estimate moved (late tasks, resize): refresh
            # the EASY ledger so backfill proofs track the new horizon
            s = job.session
            if s is None or s.allocation is None:
                return
            if pilot.spec.open_ended:
                self.scheduler.note_projected_release(s.allocation, None)
                return
            self.scheduler.note_projected_release(
                s.allocation,
                self.engine.now
                + pilot.projected_run_s(s)
                + s.stage_out_time_s
                + s.teardown_time_s,
            )

        pilot.begin(
            session,
            self.engine.now,
            on_complete=lambda: self._run_done(job, token),
            reproject=reproject,
            pool_nodes=pool_nodes,
        )

    def _degrade_pilot(self, job: JobRecord, node_id: str) -> None:
        """A running pilot's pool lost a backing node: degrade through the
        chaos path instead of killing the attempt. Resident tasks requeue
        inside the pilot with their committed checkpoint progress, the slot
        pool shrinks in proportion to the lost backing, and the EASY
        projection stretches. The lease survives — the pilot-wide datasets
        are re-read by requeued task waves, never re-negotiated."""
        now = self.engine.now
        rec = self.recorder
        if rec.enabled:
            rec.degraded(job, node_id, now)
        job.pilot.on_node_down(node_id, now)

    # -- RUNNING phase (checkpoint segments) ----------------------------------
    def _checkpoint_cost(self, job: JobRecord, session=None) -> float:
        b = job.spec.checkpoint_bytes
        if b <= 0:
            return 0.0
        return (session or job.session).checkpoint_write_s(b)

    def _run_wall_s(self, job: JobRecord, session=None) -> float:
        """Modeled wall time the rest of this job's RUNNING phase occupies:
        the uncommitted remainder plus one checkpoint write per full
        ``checkpoint_every_s`` segment inside it. For pilots: the task
        backlog spread over the slot pool, waves' I/O included."""
        if job.pilot is not None:
            return job.pilot.projected_run_s(session or job.session)
        spec = job.spec
        remaining = max(0.0, spec.run_time_s - job.committed_run_s)
        every = spec.checkpoint_every_s
        if every is None or remaining <= every:
            return remaining
        n_commits = math.ceil(remaining / every) - 1
        return remaining + n_commits * self._checkpoint_cost(job, session)

    def _session_span_s(self, job: JobRecord, session: StorageSession) -> float:
        """Grant-to-release wall time for this attempt under the session's
        models — the projection backing the EASY reservation ledger."""
        return (
            session.provision_time_s
            + session.stage_in_time_s
            + self._run_wall_s(job, session)
            + session.stage_out_time_s
            + session.teardown_time_s
        )

    def _schedule_run(self, job: JobRecord) -> None:
        """Schedule the rest of the RUNNING phase. Without checkpointing
        this is the single end-of-run event (bit-for-bit the pre-existing
        behavior); with a cadence, the remainder is cut into
        ``checkpoint_every_s`` progress segments, each closed by a commit
        event that pays the modeled checkpoint write."""
        eng = self.engine
        spec = job.spec
        remaining = max(0.0, spec.run_time_s - job.committed_run_s)
        every = spec.checkpoint_every_s
        token = job.run_token
        job._run_base = job.committed_run_s
        job._run_t0 = eng.now
        if every is None or remaining <= every:
            job._run_seg_s = remaining
            eng.at(eng.now + remaining, lambda: self._run_done(job, token))
            return
        job._run_seg_s = every
        cost = self._checkpoint_cost(job)
        eng.at(eng.now + every + cost, lambda: self._checkpoint_commit(job, token))

    def _checkpoint_commit(self, job: JobRecord, token: int) -> None:
        """One committed step: ``checkpoint_every_s`` of progress plus its
        write are durable — a later fault resumes from here."""
        if token != job.run_token:
            return                       # preempted mid-segment: stale event
        job.committed_run_s = min(
            job.spec.run_time_s, job._run_base + job._run_seg_s
        )
        job.checkpoints_committed += 1
        if job.pool_id is not None and job.spec.checkpoint_bytes > 0:
            # the write landed in the leased pool's warm tree: a resume
            # re-leasing this exact pool skips the global-FS restore read
            job.checkpoint_pool_id = job.pool_id
        self.counters.checkpoints += 1
        rec = self.recorder
        if rec.enabled:
            rec.checkpoint(job)
        self._schedule_run(job)

    def _run_progress(self, job: JobRecord, now: float) -> float:
        """Run seconds completed by ``now``: the committed base plus the
        current segment's elapsed progress (write stalls excluded)."""
        if job.state is not JobState.RUNNING:
            return job.committed_run_s
        return min(
            job.spec.run_time_s,
            job._run_base + min(max(0.0, now - job._run_t0), job._run_seg_s),
        )

    def _run_done(self, job: JobRecord, token: int = 0) -> None:
        if token != job.run_token:
            return                       # preempted mid-run: stale event
        if self._trip(job, "run"):
            self._fail_attempt(job, "run")
            return
        session = job.session
        self._transition(job, JobState.STAGING_OUT)
        eng = self.engine
        ptoken = job.phase_token
        end = eng.now + session.stage_out_time_s
        job._phase_end = end
        eng.at(end, lambda: self._stage_out_done(job, ptoken))

    def _stage_out_done(self, job: JobRecord, token: int = 0) -> None:
        if token != job.phase_token:
            return                       # attempt released or re-priced mid-stage
        if self._trip(job, "stage_out"):
            self._fail_attempt(job, "stage_out")
            return
        session = job.session
        job.staged_out_bytes += session.stage_out_bytes
        self.counters.staged_out_bytes += session.stage_out_bytes
        # pool-backed / always-on backends release for free (the data
        # manager outlives the job); only job-scoped deploys pay teardown
        self._transition(job, JobState.TEARDOWN)
        eng = self.engine
        eng.at(eng.now + session.teardown_time_s, lambda: self._teardown_done(job, token))

    def _teardown_done(self, job: JobRecord, token: int = 0) -> None:
        if token != job.phase_token:
            return                       # attempt released mid-teardown (chaos)
        self._release(job)
        self._transition(job, JobState.DONE)
        self._dispatch()

    def _fail_attempt(self, job: JobRecord, phase: str, *, dispatch: bool = True) -> None:
        # a job with committed checkpoint steps requeues as a *resume*
        # attempt: committed_run_s survives the release, so the next
        # attempt pays only the remainder (and its restore traffic) — see
        # _try_open / _schedule_run. Nothing to do here beyond not wiping it.
        job.failure_phase = phase
        self._release(job)
        job.attempt += 1
        requeued = job.attempt <= job.spec.max_retries
        rec = self.recorder
        if rec.enabled:
            rec.fault(job, phase, requeued)
        if not requeued:
            self._transition(job, JobState.FAILED)
        else:
            self.counters.retries += 1
            self._transition(job, JobState.QUEUED)
            self._enqueue(job)
        if dispatch:
            # a node-down handler fails many attempts in one event and
            # dispatches once at the end, after the pools took their loss
            self._dispatch()

    def _release(self, job: JobRecord) -> None:
        session = job.session
        if session is None:
            return
        rec = self.recorder
        if rec.enabled:
            rec.release(job)
        job.run_token += 1           # any in-flight run event is now stale
        job.phase_token += 1         # ...and any in-flight phase event too
        job._preempt_pending = False # a draining final write died with the attempt
        if job.pilot is not None:
            # requeue the pilot's resident tasks (committed progress kept);
            # a later attempt re-packs the surviving backlog
            job.pilot.suspend(self.engine.now)
        if job.allocation is not None:
            t0 = job.alloc_started if job.alloc_started is not None else self.engine.now
            job.storage_intervals.append(
                (t0, self.engine.now, len(job.allocation.storage_nodes))
            )
            n = len(job.allocation.storage_nodes)
            counters = self.counters
            counters.open_nodes -= n
            counters.open_node_start_s -= n * t0
            counters.busy_node_s += (self.engine.now - t0) * n
        pooled = session.lease is not None
        session.release(self.engine.now)
        job.session = None
        was_waiting = self._pool_waiting(job)
        job.lease = None
        self._pool_wait_n += self._pool_waiting(job) - was_waiting
        job.allocation = None
        job.alloc_started = None
        job.fs_model = None
        if pooled and self.pools is not None and self.pools.ttl_s is not None:
            # coalesce: many leases released at one event time used to fan
            # out into identical reap events; one per fire time suffices
            t = self.engine.now + self.pools.ttl_s
            if t not in self._reap_times:
                self._reap_times.add(t)
                self.engine.at(t, lambda: self._run_reap(t))

    def _run_reap(self, t: float) -> None:
        self._reap_times.discard(t)
        self._reap_pools()

    def _pool_waiting(self, job: JobRecord) -> bool:
        """Is this a pool-wanting job that has yet to run (no lease, not
        terminal)? Counted incrementally in ``_pool_wait_n`` so the TTL
        reaper never scans the whole campaign's job list."""
        return (
            job.wants_pool
            and job.lease is None
            and job.state not in TERMINAL_STATES
        )

    def _reap_pools(self) -> None:
        """TTL check scheduled after lease releases. Never reaps while any
        pool-backed job has yet to run — queued now, requeued after a
        fault, or submitted with a future arrival time — because a reaped
        pool could strand it (or fail it spuriously as infeasible)."""
        if self.pools is None:
            return
        if self._pool_wait_n > 0:
            return
        self.pools.reap_idle(self.engine.now)

    def _transition(self, job: JobRecord, state: JobState) -> None:
        if job.wants_pool:
            was_waiting = self._pool_waiting(job)
            job.state = state
            self._pool_wait_n += self._pool_waiting(job) - was_waiting
        else:
            job.state = state
        job.history.append((state, self.engine.now))
        rec = self.recorder
        if rec.enabled:
            rec.transition(job, state)
        counters = self.counters
        counters.t_last_event = self.engine.now
        if state is JobState.RUNNING:
            self._running[job.job_id] = job
        else:
            self._running.pop(job.job_id, None)
            if state is JobState.DONE:
                counters.n_done += 1
            elif state is JobState.FAILED:
                counters.n_failed += 1

    # -- preemption -----------------------------------------------------------
    def preempt(self, victim: JobRecord) -> bool:
        """Checkpoint-and-release a RUNNING job for a higher-priority
        arrival (or by hand). With checkpointing on, the victim's progress
        commits through a final checkpoint write — it keeps holding its
        nodes for the write's modeled duration, then releases; without
        checkpointing, uncommitted progress is simply lost. Either way the
        victim requeues as a resume attempt that does **not** count against
        ``max_retries`` (an eviction is not a fault). Returns False when
        the job is not RUNNING or is already draining its final checkpoint."""
        if victim.state is not JobState.RUNNING or victim._preempt_pending:
            return False
        now = self.engine.now
        victim.run_token += 1            # cancel the pending run/commit event
        if victim.spec.checkpoint_every_s is not None:
            victim.committed_run_s = self._run_progress(victim, now)
            victim.checkpoints_committed += 1
            if victim.pool_id is not None and victim.spec.checkpoint_bytes > 0:
                victim.checkpoint_pool_id = victim.pool_id
            self.counters.checkpoints += 1
            cost = self._checkpoint_cost(victim)
            if cost > 0:
                victim._preempt_pending = True
                token = victim.run_token
                self.engine.at(
                    now + cost, lambda: self._preempt_release(victim, token)
                )
                return True
        self._preempt_release(victim)
        return True

    def _preempt_release(self, victim: JobRecord, token: Optional[int] = None) -> None:
        if token is not None and token != victim.run_token:
            return      # the attempt died (chaos) while draining its final write
        victim._preempt_pending = False
        victim.preemptions += 1
        self.counters.preemptions += 1
        rec = self.recorder
        if rec.enabled:
            rec.preemption(victim)
        self._release(victim)
        self._transition(victim, JobState.QUEUED)
        self._enqueue(victim)
        self._dispatch()

    def _try_preempt(self, job: JobRecord) -> bool:
        """A blocked high-priority arrival asks the preemption policy for
        RUNNING victims. Chosen victims are preempted in the policy's
        order; the arrival then competes for the freed nodes at the
        dispatch the releases trigger."""
        try:
            demand = self.scheduler.demand(job.request)
        except AllocationError:
            return False
        now = self.engine.now
        candidates = []
        for victim in self._running.values():
            spec = victim.spec
            if not spec.preemptible or spec.priority >= job.spec.priority:
                continue
            if victim._preempt_pending:
                continue
            alloc = victim.allocation
            candidates.append(
                VictimView(
                    job=victim,
                    priority=spec.priority,
                    progress=(
                        self._run_progress(victim, now) / spec.run_time_s
                        if spec.run_time_s > 0
                        else 1.0
                    ),
                    n_compute=len(alloc.compute_nodes) if alloc else 0,
                    n_storage=len(alloc.storage_nodes) if alloc else 0,
                )
            )
        victims = self._preemption.select(
            job, candidates, demand, self.scheduler.free_counts()
        )
        preempted = False
        for victim in victims:
            preempted |= self.preempt(victim)
        return preempted

    # -- chaos (storage-node failure domain) ----------------------------------
    #: FaultInjector phase name for each interruptible job state — the
    #: synthetic fault a node loss injects lands at the phase the attempt
    #: was actually in (ALLOCATED is transient inside _start; TEARDOWN has
    #: nothing left to lose — outputs are already staged out).
    _PHASE_OF_STATE = {
        JobState.PROVISIONING: "provision",
        JobState.STAGING_IN: "stage_in",
        JobState.RUNNING: "run",
        JobState.STAGING_OUT: "stage_out",
    }

    def enable_chaos(self, model, *, retry=None) -> None:
        """Arm a :class:`~repro.chaos.NodeFaultModel` over this campaign.

        Every failure/repair event is bulk-scheduled now (the model is
        finite by construction), so chaos campaigns replay bit-identically
        and a model that can emit nothing — or ``None`` — schedules
        nothing: chaos-off campaigns run the exact pre-chaos event stream.
        ``retry`` (a :class:`~repro.chaos.RetryPolicy`) additionally arms
        pool self-healing: affected pools backfill from free nodes on the
        policy's backoff cadence.
        """
        if model is None or not model.any_faults:
            return
        unknown = set(model.node_ids) - {
            n.node_id for n in self.scheduler.cluster.storage_nodes
        }
        if unknown:
            raise ValueError(
                f"fault model covers unknown storage nodes: {sorted(unknown)}"
            )
        self._chaos_model = model
        self._chaos_retry = retry
        self.engine.at_many(
            (
                ev.t,
                (
                    (lambda nid: lambda: self._node_down(nid))(ev.node_id)
                    if ev.kind == "down"
                    else (lambda nid: lambda: self._node_repair(nid))(ev.node_id)
                ),
            )
            for ev in model.events()
        )

    def _node_down(self, node_id: str) -> None:
        """One storage node died. Park it in the scheduler, revoke the
        locality credits that named it (warm FS trees and staged inputs on
        other nodes survive), then walk the blast radius: mirrored direct
        deployments degrade in place (half bandwidth, in-flight phase
        re-priced), everything else takes a synthetic fault through the
        ordinary checkpoint-resume requeue path — leaseholders before their
        pools, so residency invalidation never sees a pin."""
        if node_id in self._down_nodes:
            return                       # overlapping outage windows: no-op
        self._down_nodes.add(node_id)
        now = self.engine.now
        self.scheduler.mark_node_down(node_id)
        rec = self.recorder
        if rec.enabled:
            rec.node_down(node_id, now)
        pm = self.provision.pool_manager
        blast = resolve_blast_radius(
            node_id,
            sessions=[j.session for j in self.jobs if j.session is not None],
            pools=pm.live_pools if pm is not None else (),
        )
        hit = {id(s) for s in blast.sessions}
        blast_pool_ids = {p.pool_id for p in blast.pools}
        for job in self.jobs:
            if job.done:
                continue
            if node_id in job.warm_nodes:
                job.warm_nodes = job.warm_nodes - {node_id}
            if node_id in job.staged_nodes:
                job.staged_nodes = job.staged_nodes - {node_id}
            if (
                job.checkpoint_pool_id is not None
                and job.checkpoint_pool_id in blast_pool_ids
            ):
                # the loss took a stripe of the resident checkpoint with it:
                # the next resume must restore from the global FS again
                job.checkpoint_pool_id = None
            session = job.session
            if session is None or id(session) not in hit:
                continue
            if session.lease is None and session.can_degrade:
                self._degrade_job(job, node_id)
            elif (
                job.pilot is not None
                and session.lease is not None
                and job.state is JobState.RUNNING
                and pm is not None
                and len(pm.get(job.pool_id).storage_node_ids) >= 2
            ):
                # a RUNNING pilot on a pool that survives the loss degrades
                # (shrunk slots, requeued resident tasks) instead of dying;
                # a pool left with nothing falls through to _fail_attempt
                self._degrade_pilot(job, node_id)
            else:
                phase = self._PHASE_OF_STATE.get(job.state)
                if phase is not None:
                    self._fail_attempt(job, phase, dispatch=False)
        if pm is not None:
            for pool in blast.pools:
                pm.on_node_down(pool, node_id, now)
                if self._chaos_retry is not None:
                    drive_retries(
                        self.engine,
                        self._chaos_retry,
                        f"pool{pool.pool_id}:{node_id}",
                        lambda p=pool: pm.backfill(p, self.engine.now),
                    )
        self._dispatch()

    def _node_repair(self, node_id: str) -> None:
        """The node came back: un-park it (or un-flag it, if a live
        allocation still holds it), re-silver pools that were waiting on
        it, and re-dispatch — the freed capacity may admit queued jobs."""
        if node_id not in self._down_nodes:
            return
        self._down_nodes.discard(node_id)
        now = self.engine.now
        self.scheduler.mark_node_up(node_id)
        pm = self.provision.pool_manager
        if pm is not None:
            pm.on_node_repair(node_id, now)
        for job in self._running.values():
            if job.pilot is not None:
                # a degraded pilot that lost this node widens back
                job.pilot.on_node_repair(node_id, now)
        rec = self.recorder
        if rec.enabled:
            rec.node_repair(node_id, now)
        self._dispatch()

    def _degrade_job(self, job: JobRecord, node_id: str) -> None:
        """A mirrored deployment lost one replica: the attempt survives
        DEGRADED at half effective bandwidth. Phases not yet scheduled
        re-price through the session's degraded multiplier; the in-flight
        one re-prices here — its *remaining* staging work doubles."""
        session = job.session
        session.degrade()
        eng = self.engine
        now = eng.now
        rec = self.recorder
        if rec.enabled:
            rec.degraded(job, node_id, now)
        state = job.state
        if state is JobState.STAGING_IN or state is JobState.STAGING_OUT:
            remaining = max(0.0, job._phase_end - now) * 2.0
            job.phase_token += 1         # the full-bandwidth end event is stale
            token = job.phase_token
            end = now + remaining
            job._phase_end = end
            cb = (
                self._stage_in_done
                if state is JobState.STAGING_IN
                else self._stage_out_done
            )
            eng.at(end, lambda: cb(job, token))
        elif state is JobState.RUNNING:
            self._reprice_run_segment(job)

    def _reprice_run_segment(self, job: JobRecord) -> None:
        """Degraded mid-RUN: compute progress is unharmed, but a pending
        checkpoint commit priced its write at full bandwidth. Re-issue the
        commit at the degraded cost (the whole write re-prices — a
        conservative model for a mid-write loss); the final run event
        carries no storage traffic and needs nothing."""
        spec = job.spec
        every = spec.checkpoint_every_s
        if every is None or job._preempt_pending:
            return                       # no write pending / final drain stands
        if max(0.0, spec.run_time_s - job._run_base) <= every:
            return                       # pending event is the bare _run_done
        eng = self.engine
        job.run_token += 1
        token = job.run_token
        t = job._run_t0 + every + self._checkpoint_cost(job)
        eng.at(max(t, eng.now), lambda: self._checkpoint_commit(job, token))

    # -- monitoring -----------------------------------------------------------
    def heartbeat_monitor(
        self, nodes: Optional[list] = None, *, timeout_s: float = 60.0
    ) -> HeartbeatMonitor:
        """A `HeartbeatMonitor` bound to this orchestrator's **virtual**
        clock (default node set: the cluster's compute inventory). The
        monitor's own default is ``time.monotonic()`` — correct for real
        per-host agents, but mixed with a virtual clock it silently marks
        every node dead (or never dead), so orchestrator-world callers must
        come through here (or pass ``clock=lambda: engine.now`` themselves)."""
        if nodes is None:
            nodes = [n.node_id for n in self.scheduler.cluster.compute_nodes]
        return HeartbeatMonitor(
            list(nodes), timeout_s=timeout_s, clock=lambda: self.engine.now
        )

    def live_report(self, now: Optional[float] = None):
        """O(1) mid-flight campaign snapshot from the incremental counters
        (`metrics.LiveReport`) — what a dashboard polls instead of the
        O(jobs) `metrics.summarize` scan."""
        from .metrics import live_report

        return live_report(
            self.counters,
            n_storage_nodes=len(self.scheduler.cluster.storage_nodes),
            now=self.engine.now if now is None else now,
        )

    # -- campaign driver -----------------------------------------------------
    def run_campaign(
        self,
        specs: Optional[list[WorkflowSpec]] = None,
        *,
        submit_times: Optional[list[float]] = None,
        until: Optional[float] = None,
        max_events: Optional[int] = None,
    ) -> list[JobRecord]:
        """Submit ``specs`` (if given), drain the event loop, return records.

        ``submit_times`` gives each spec its own arrival instant (e.g. from
        :func:`repro.orchestrator.arrivals.poisson_arrivals` or a replayed
        trace) instead of the batch-at-now default; it must match ``specs``
        in length, and no time may predate the engine clock.

        ``max_events`` sets the engine's runaway-loop backstop. The default
        scales with campaign size — ``max(1_000_000, 40 * n_jobs)`` — so a
        50k-job campaign no longer trips the engine's fixed 1M guard; pass
        ``None`` explicitly through :meth:`SimEngine.run` to disable it.

        Submissions are bulk-scheduled (:meth:`SimEngine.at_many`): one
        heapify instead of one heap push per job for batch arrivals.

        Guarantees every job reaches a terminal state (DONE or FAILED) unless
        ``until`` cut the clock short.
        """
        specs = specs or []
        if submit_times is not None and len(submit_times) != len(specs):
            raise ValueError(
                f"{len(submit_times)} submit times for {len(specs)} specs"
            )
        for spec in specs:
            self._check_spec(spec)
        events = []
        for i, spec in enumerate(specs):
            job = self._make_job(
                spec, None if submit_times is None else submit_times[i]
            )
            events.append(
                (job.submit_time, (lambda j: lambda: self._arrive(j))(job))
            )
        self.engine.at_many(events)
        if max_events is None:
            max_events = max(1_000_000, 40 * len(self.jobs))
        self.engine.run(until=until, max_events=max_events)
        return list(self.jobs)
