"""Arrival processes for provisioning campaigns.

The first ROADMAP orchestrator follow-up: instead of dumping every job on the
queue at t=0 (worst-case burst), campaigns can draw arrivals from a seeded
Poisson process — the standard open-system model for batch submissions — or
replay a recorded trace deterministically. Both produce a ``submit_times``
list for :meth:`Orchestrator.run_campaign`.

Seeding uses a private ``random.Random`` instance, so two campaigns with the
same (rate, n, seed) see byte-identical arrival sequences regardless of any
global RNG state.
"""

from __future__ import annotations

import random
from typing import Iterable, Sequence


def exponential_interarrivals(
    rate_per_s: float, n: int, *, seed: int = 0
) -> list[float]:
    """``n`` i.i.d. Exp(rate) gaps — the memoryless inter-arrival law."""
    if rate_per_s <= 0:
        raise ValueError(f"rate_per_s must be positive, got {rate_per_s}")
    if n < 0:
        raise ValueError(f"n must be >= 0, got {n}")
    rng = random.Random(seed)
    return [rng.expovariate(rate_per_s) for _ in range(n)]


def poisson_arrivals(
    rate_per_s: float, n: int, *, seed: int = 0, start: float = 0.0
) -> list[float]:
    """``n`` absolute arrival times of a Poisson process with the given rate,
    beginning at ``start``. Monotone non-decreasing by construction."""
    if start < 0:
        raise ValueError(f"start must be >= 0, got {start}")
    times = []
    t = start
    for gap in exponential_interarrivals(rate_per_s, n, seed=seed):
        t += gap
        times.append(t)
    return times


def replay_trace(times: Iterable[float], *, start: float = 0.0) -> list[float]:
    """Validate a recorded arrival trace for deterministic replay.

    Returns the times sorted (submission order is by time, whatever order the
    trace file listed them in) and shifted by ``start``. Negative times are
    rejected — the virtual clock cannot schedule into the past.
    """
    out = sorted(float(t) for t in times)
    if out and out[0] < 0:
        raise ValueError(f"trace has negative arrival time {out[0]}")
    return [t + start for t in out]


def mean_interarrival(times: Sequence[float]) -> float:
    """Empirical mean gap of an arrival sequence (trace sanity checks)."""
    if len(times) < 2:
        return 0.0
    return (times[-1] - times[0]) / (len(times) - 1)
