"""Arrival processes for provisioning campaigns.

The first ROADMAP orchestrator follow-up: instead of dumping every job on the
queue at t=0 (worst-case burst), campaigns can draw arrivals from a seeded
Poisson process — the standard open-system model for batch submissions — or
replay a recorded trace deterministically. Both produce a ``submit_times``
list for :meth:`Orchestrator.run_campaign`.

Seeding uses a private ``random.Random`` instance, so two campaigns with the
same (rate, n, seed) see byte-identical arrival sequences regardless of any
global RNG state.
"""

from __future__ import annotations

import math
import random
from typing import Iterable, Sequence


def exponential_interarrivals(
    rate_per_s: float, n: int, *, seed: int = 0
) -> list[float]:
    """``n`` i.i.d. Exp(rate) gaps — the memoryless inter-arrival law."""
    if rate_per_s <= 0:
        raise ValueError(f"rate_per_s must be positive, got {rate_per_s}")
    if n < 0:
        raise ValueError(f"n must be >= 0, got {n}")
    rng = random.Random(seed)
    return [rng.expovariate(rate_per_s) for _ in range(n)]


def poisson_arrivals(
    rate_per_s: float, n: int, *, seed: int = 0, start: float = 0.0
) -> list[float]:
    """``n`` absolute arrival times of a Poisson process with the given rate,
    beginning at ``start``. Monotone non-decreasing by construction."""
    if start < 0:
        raise ValueError(f"start must be >= 0, got {start}")
    times = []
    t = start
    for gap in exponential_interarrivals(rate_per_s, n, seed=seed):
        t += gap
        times.append(t)
    return times


def _thinned_arrivals(
    rate_fn, peak_rate: float, n: int, *, seed: int, start: float
) -> list[float]:
    """``n`` arrivals of a non-homogeneous Poisson process by Lewis-Shedler
    thinning: candidate points arrive at ``peak_rate`` and survive with
    probability ``rate_fn(t) / peak_rate``. Exact for any rate function
    bounded by ``peak_rate``; deterministic for a fixed seed because the
    private RNG draws exactly two variates per candidate."""
    if peak_rate <= 0:
        raise ValueError(f"peak rate must be positive, got {peak_rate}")
    if n < 0:
        raise ValueError(f"n must be >= 0, got {n}")
    if start < 0:
        raise ValueError(f"start must be >= 0, got {start}")
    rng = random.Random(seed)
    out: list[float] = []
    t = start
    while len(out) < n:
        t += rng.expovariate(peak_rate)
        if rng.random() * peak_rate <= rate_fn(t):
            out.append(t)
    return out


def diurnal_arrivals(
    n: int,
    *,
    base_rate: float,
    peak_rate: float,
    period_s: float = 86_400.0,
    seed: int = 0,
    start: float = 0.0,
) -> list[float]:
    """``n`` arrival times following a sinusoidal day/night profile:

        rate(t) = base + (peak - base) * (1 - cos(2*pi*(t - start)/period)) / 2

    The process starts at the trough (``base_rate`` at ``t = start``), climbs
    to ``peak_rate`` half a period in, and returns — the serving subsystem's
    "queue that breathes". Mean rate over whole periods is
    ``(base_rate + peak_rate) / 2``.
    """
    if base_rate < 0:
        raise ValueError(f"base_rate must be >= 0, got {base_rate}")
    if peak_rate < base_rate or peak_rate <= 0:
        raise ValueError(
            f"peak_rate must be positive and >= base_rate, got {peak_rate}"
        )
    if period_s <= 0:
        raise ValueError(f"period_s must be positive, got {period_s}")

    def rate(t: float) -> float:
        swing = 0.5 * (1.0 - math.cos(2.0 * math.pi * (t - start) / period_s))
        return base_rate + (peak_rate - base_rate) * swing

    return _thinned_arrivals(rate, peak_rate, n, seed=seed, start=start)


def burst_arrivals(
    n: int,
    *,
    base_rate: float,
    burst_rate: float,
    burst_t0: float,
    burst_t1: float,
    seed: int = 0,
    start: float = 0.0,
) -> list[float]:
    """``n`` arrival times at ``base_rate`` with a piecewise-constant burst:
    the rate jumps to ``burst_rate`` on ``[burst_t0, burst_t1)`` and falls
    back after. The flash crowd that trips a queue-delay alert."""
    if base_rate <= 0:
        raise ValueError(f"base_rate must be positive, got {base_rate}")
    if burst_rate <= 0:
        raise ValueError(f"burst_rate must be positive, got {burst_rate}")
    if burst_t1 <= burst_t0:
        raise ValueError(
            f"burst window is empty: [{burst_t0}, {burst_t1})"
        )

    def rate(t: float) -> float:
        return burst_rate if burst_t0 <= t < burst_t1 else base_rate

    peak = max(base_rate, burst_rate)
    return _thinned_arrivals(rate, peak, n, seed=seed, start=start)


def replay_trace(times: Iterable[float], *, start: float = 0.0) -> list[float]:
    """Validate a recorded arrival trace for deterministic replay.

    Returns the times sorted (submission order is by time, whatever order the
    trace file listed them in) and shifted by ``start``. Negative times are
    rejected — the virtual clock cannot schedule into the past.
    """
    out = sorted(float(t) for t in times)
    if out and out[0] < 0:
        raise ValueError(f"trace has negative arrival time {out[0]}")
    return [t + start for t in out]


def mean_interarrival(times: Sequence[float]) -> float:
    """Empirical mean gap of an arrival sequence (trace sanity checks)."""
    if len(times) < 2:
        return 0.0
    return (times[-1] - times[0]) / (len(times) - 1)
