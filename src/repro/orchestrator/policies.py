"""Queueing policies: which waiting job starts when nodes free up.

The seed repo's `Scheduler.submit` hard-fails when the free pool is short;
the orchestrator instead holds a queue and consults a policy every time
capacity changes. Three policies, in increasing awareness:

* **FIFO** — strict arrival order with head-of-line blocking: if the oldest
  job doesn't fit, nothing starts (the classic batch-queue baseline).
* **Backfill** — arrival order, but jobs that fit may jump a blocked head
  (EASY-style backfill without reservations; small jobs drain around a
  large one).
* **Storage-aware** — orders by resolved *storage-node* demand, smallest
  first, so scarce DataWarp nodes turn over quickly; an aging threshold
  promotes long-waiting jobs back to arrival order to prevent starvation.
  This is the data-aware scheduling direction of Raicu et al.'s Data
  Diffusion applied to the paper's schedulable-storage model.
* **Data-aware** — the full Data Diffusion move, over the persistent-pool
  subsystem (``repro.pool``): jobs whose input datasets are already resident
  on some pool run first (their stage-in is partly or wholly a cache hit),
  ranked by resident-byte fraction; ties and pool-less jobs fall back to
  storage-aware ordering, and the same aging threshold prevents starvation
  of jobs whose data is nowhere warm.
"""

from __future__ import annotations

import abc
from typing import TYPE_CHECKING, Sequence

if TYPE_CHECKING:  # circular: lifecycle imports policies
    from ..core.scheduler import Scheduler
    from .lifecycle import JobRecord


class QueuePolicy(abc.ABC):
    """Orders the wait queue for dispatch; the orchestrator starts jobs in
    the returned order, stopping at the first misfit iff ``head_blocking``."""

    name: str = "abstract"
    head_blocking: bool = False

    @abc.abstractmethod
    def order(
        self, queue: Sequence["JobRecord"], scheduler: "Scheduler", now: float
    ) -> list["JobRecord"]:
        ...


class FIFOPolicy(QueuePolicy):
    name = "fifo"
    head_blocking = True

    def order(self, queue, scheduler, now):
        return list(queue)          # queue is maintained in arrival order


class BackfillPolicy(QueuePolicy):
    name = "backfill"
    head_blocking = False

    def order(self, queue, scheduler, now):
        return list(queue)


class StorageAwarePolicy(QueuePolicy):
    """Smallest storage demand first, with aging anti-starvation."""

    name = "storage-aware"
    head_blocking = False

    def __init__(self, aging_s: float = 3600.0):
        if aging_s <= 0:
            raise ValueError("aging_s must be positive")
        self.aging_s = aging_s

    def order(self, queue, scheduler, now):
        def key(job):
            aged = (now - job.submit_time) >= self.aging_s
            if aged:
                return (0, job.submit_time, job.submit_time)
            _, n_storage = scheduler.demand(job.request)
            return (1, n_storage, job.submit_time)

        return sorted(queue, key=key)


class DataAwarePolicy(QueuePolicy):
    """Route jobs to their data: highest resident-byte fraction first.

    Takes anything exposing ``resident_fraction(datasets)`` — a
    :class:`~repro.provision.ProvisioningService` (the preferred handle;
    its pool catalog knows what is warm where) or a bare
    :class:`~repro.pool.PoolManager`. A job with 100% of its datasets
    resident skips all shared stage-in; starting it now both finishes it
    sooner and *keeps* those datasets pinned-warm against eviction, which
    is the Data Diffusion feedback loop (hits beget hits). Jobs with
    nothing warm are ordered by storage demand (small first), and aging
    promotes starved jobs to strict arrival order.
    """

    name = "data-aware"
    head_blocking = False

    def __init__(self, pools, aging_s: float = 3600.0):
        if aging_s <= 0:
            raise ValueError("aging_s must be positive")
        if not hasattr(pools, "resident_fraction"):
            raise TypeError(
                "DataAwarePolicy needs a ProvisioningService or PoolManager "
                "(anything with resident_fraction)"
            )
        self.pools = pools
        self.aging_s = aging_s

    def order(self, queue, scheduler, now):
        def key(job):
            if (now - job.submit_time) >= self.aging_s:
                return (0, job.submit_time, 0.0, job.submit_time)
            spec = job.spec
            frac = 0.0
            if spec.wants_pool and spec.all_datasets:
                frac = self.pools.resident_fraction(spec.all_datasets)
            _, n_storage = scheduler.demand(job.request)
            return (1, -frac, n_storage, job.submit_time)

        return sorted(queue, key=key)
