"""Queueing policies: which waiting job starts when nodes free up.

The seed repo's `Scheduler.submit` hard-fails when the free pool is short;
the orchestrator instead holds a queue and consults a policy every time
capacity changes. Three policies, in increasing awareness:

* **FIFO** — strict arrival order with head-of-line blocking: if the oldest
  job doesn't fit, nothing starts (the classic batch-queue baseline).
* **Backfill** — arrival order, but jobs that fit may jump a blocked head
  (EASY-style backfill without reservations; small jobs drain around a
  large one).
* **Storage-aware** — orders by resolved *storage-node* demand, smallest
  first, so scarce DataWarp nodes turn over quickly; an aging threshold
  promotes long-waiting jobs back to arrival order to prevent starvation.
  This is the data-aware scheduling direction of Raicu et al.'s Data
  Diffusion applied to the paper's schedulable-storage model.
* **Data-aware** — the full Data Diffusion move, over the persistent-pool
  subsystem (``repro.pool``): jobs whose input datasets are already resident
  on some pool run first (their stage-in is partly or wholly a cache hit),
  ranked by resident-byte fraction; ties and pool-less jobs fall back to
  storage-aware ordering, and the same aging threshold prevents starvation
  of jobs whose data is nowhere warm.

Two dispatch protocols share these policies. The legacy protocol calls
:meth:`QueuePolicy.order` — sort the whole queue, every time — and remains
the compatibility fallback for custom policies. The incremental protocol
(``orchestrator.dispatch``) never sorts the queue: it keys jobs once with
:meth:`QueuePolicy.sort_key` and re-evaluates only bucket heads, which is
valid for any policy honoring the contract documented on ``sort_key``.
"""

from __future__ import annotations

import abc
from typing import TYPE_CHECKING, Optional, Sequence

if TYPE_CHECKING:  # circular: lifecycle imports policies
    from ..core.scheduler import Scheduler
    from .lifecycle import JobRecord


class QueuePolicy(abc.ABC):
    """Orders the wait queue for dispatch; the orchestrator starts jobs in
    the returned order, stopping at the first misfit iff ``head_blocking``."""

    name: str = "abstract"
    head_blocking: bool = False
    #: aging horizon in seconds; None when keys never change as jobs wait
    aging_s: Optional[float] = None
    #: True when ``sort_key`` honors the incremental-dispatch contract
    incremental: bool = False

    @abc.abstractmethod
    def order(
        self, queue: Sequence["JobRecord"], scheduler: "Scheduler", now: float
    ) -> list["JobRecord"]:
        ...

    def sort_key(
        self, job: "JobRecord", scheduler: "Scheduler", now: float
    ) -> tuple:
        """Key reproducing :meth:`order`: a stable sort of the queue on
        ``sort_key`` must equal ``order(queue)``.

        Incremental-dispatch contract (``orchestrator.dispatch`` relies on
        it): the key may depend on the job only through (a) its *admission
        signature* — the resolved `StorageSpec` minus the name, plus the
        compute-node count — (b) its ``submit_time``, and (c) whether it has
        waited past ``aging_s``; and aged jobs must order before all fresh
        ones. Same-signature jobs then always order by
        ``(aged, bucket_subkey, arrival)``, which is what lets the dispatch
        queue maintain per-bucket order without re-sorting.
        """
        raise NotImplementedError

    def bucket_subkey(self, job: "JobRecord") -> tuple:
        """In-bucket ordering prefix (ahead of arrival order) for the
        incremental protocol: ``()`` for pure arrival order; policies whose
        ``sort_key`` orders same-signature jobs by submit time return
        ``(job.submit_time,)``."""
        return ()


class FIFOPolicy(QueuePolicy):
    name = "fifo"
    head_blocking = True
    incremental = True

    def order(self, queue, scheduler, now):
        return list(queue)          # queue is maintained in arrival order

    def sort_key(self, job, scheduler, now):
        return ()                   # arrival order alone


class BackfillPolicy(QueuePolicy):
    name = "backfill"
    head_blocking = False
    incremental = True

    def order(self, queue, scheduler, now):
        return list(queue)

    def sort_key(self, job, scheduler, now):
        return ()


class StorageAwarePolicy(QueuePolicy):
    """Smallest storage demand first, with aging anti-starvation."""

    name = "storage-aware"
    head_blocking = False
    incremental = True

    def __init__(self, aging_s: float = 3600.0):
        if aging_s <= 0:
            raise ValueError("aging_s must be positive")
        self.aging_s = aging_s

    def sort_key(self, job, scheduler, now):
        if (now - job.submit_time) >= self.aging_s:
            return (0, job.submit_time, job.submit_time)
        storage = job.request.storage
        n_storage = 0 if storage is None else scheduler.resolve_storage_nodes(storage)
        return (1, n_storage, job.submit_time)

    def bucket_subkey(self, job):
        return (job.submit_time,)

    def order(self, queue, scheduler, now):
        return sorted(queue, key=lambda job: self.sort_key(job, scheduler, now))


class DataAwarePolicy(QueuePolicy):
    """Route jobs to their data: highest resident-byte fraction first.

    Takes anything exposing ``resident_fraction(datasets)`` — a
    :class:`~repro.provision.ProvisioningService` (the preferred handle;
    its pool catalog knows what is warm where) or a bare
    :class:`~repro.pool.PoolManager`. A job with 100% of its datasets
    resident skips all shared stage-in; starting it now both finishes it
    sooner and *keeps* those datasets pinned-warm against eviction, which
    is the Data Diffusion feedback loop (hits beget hits). Jobs with
    nothing warm are ordered by storage demand (small first), and aging
    promotes starved jobs to strict arrival order.
    """

    name = "data-aware"
    head_blocking = False
    incremental = True

    def __init__(self, pools, aging_s: float = 3600.0):
        if aging_s <= 0:
            raise ValueError("aging_s must be positive")
        if not hasattr(pools, "resident_fraction"):
            raise TypeError(
                "DataAwarePolicy needs a ProvisioningService or PoolManager "
                "(anything with resident_fraction)"
            )
        self.pools = pools
        self.aging_s = aging_s

    def sort_key(self, job, scheduler, now):
        if (now - job.submit_time) >= self.aging_s:
            return (0, job.submit_time, 0.0, job.submit_time)
        spec = job.spec
        frac = 0.0
        if spec.wants_pool and spec.all_datasets:
            frac = self.pools.resident_fraction(spec.all_datasets)
        storage = job.request.storage
        n_storage = 0 if storage is None else scheduler.resolve_storage_nodes(storage)
        return (1, -frac, n_storage, job.submit_time)

    def bucket_subkey(self, job):
        return (job.submit_time,)

    def order(self, queue, scheduler, now):
        return sorted(queue, key=lambda job: self.sort_key(job, scheduler, now))
