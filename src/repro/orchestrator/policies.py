"""Queueing policies: which waiting job starts when nodes free up.

The seed repo's `Scheduler.submit` hard-fails when the free pool is short;
the orchestrator instead holds a queue and consults a policy every time
capacity changes. Three policies, in increasing awareness:

* **FIFO** — strict arrival order with head-of-line blocking: if the oldest
  job doesn't fit, nothing starts (the classic batch-queue baseline).
* **Backfill** — arrival order, but jobs that fit may jump a blocked head
  (EASY-style backfill without reservations; small jobs drain around a
  large one).
* **Storage-aware** — orders by resolved *storage-node* demand, smallest
  first, so scarce DataWarp nodes turn over quickly; an aging threshold
  promotes long-waiting jobs back to arrival order to prevent starvation.
  This is the data-aware scheduling direction of Raicu et al.'s Data
  Diffusion applied to the paper's schedulable-storage model.
* **Data-aware** — the full Data Diffusion move, over the persistent-pool
  subsystem (``repro.pool``): jobs whose input datasets are already resident
  on some pool run first (their stage-in is partly or wholly a cache hit),
  ranked by resident-byte fraction; ties and pool-less jobs fall back to
  storage-aware ordering, and the same aging threshold prevents starvation
  of jobs whose data is nowhere warm.
* **EASY backfill** — arrival order, but the blocked head-of-queue job is
  given a *reservation* (the earliest instant its node demand fits, from
  the scheduler's projected-release ledger) and later jobs backfill only
  when they provably cannot delay that start. Plain backfill can starve a
  wide job indefinitely; EASY bounds its wait by the running jobs' modeled
  completions.

Preemption is a separate axis: a :class:`PreemptionPolicy` picks RUNNING
victims to checkpoint-and-release when a higher-priority arrival cannot
start (see ``Orchestrator.preempt``).

Two dispatch protocols share these policies. The legacy protocol calls
:meth:`QueuePolicy.order` — sort the whole queue, every time — and remains
the compatibility fallback for custom policies. The incremental protocol
(``orchestrator.dispatch``) never sorts the queue: it keys jobs once with
:meth:`QueuePolicy.sort_key` and re-evaluates only bucket heads, which is
valid for any policy honoring the contract documented on ``sort_key``.
"""

from __future__ import annotations

import abc
import dataclasses
from typing import TYPE_CHECKING, Optional, Sequence

if TYPE_CHECKING:  # circular: lifecycle imports policies
    from ..core.scheduler import Scheduler
    from .lifecycle import JobRecord


class QueuePolicy(abc.ABC):
    """Orders the wait queue for dispatch; the orchestrator starts jobs in
    the returned order, stopping at the first misfit iff ``head_blocking``."""

    name: str = "abstract"
    head_blocking: bool = False
    #: aging horizon in seconds; None when keys never change as jobs wait
    aging_s: Optional[float] = None
    #: True when ``sort_key`` honors the incremental-dispatch contract
    incremental: bool = False
    #: True when the blocked head-of-queue job must receive an EASY
    #: reservation and later jobs are admitted only under its no-delay
    #: proof (the orchestrator implements the gating; the flag only asks
    #: for it)
    reserving: bool = False

    @abc.abstractmethod
    def order(
        self, queue: Sequence["JobRecord"], scheduler: "Scheduler", now: float
    ) -> list["JobRecord"]:
        ...

    def sort_key(
        self, job: "JobRecord", scheduler: "Scheduler", now: float
    ) -> tuple:
        """Key reproducing :meth:`order`: a stable sort of the queue on
        ``sort_key`` must equal ``order(queue)``.

        Incremental-dispatch contract (``orchestrator.dispatch`` relies on
        it): the key may depend on the job only through (a) its *admission
        signature* — the resolved `StorageSpec` minus the name, plus the
        compute-node count and the spec ``priority`` — (b) its
        ``submit_time``, and (c) whether it has waited past ``aging_s``;
        and, within one priority level, aged jobs must order before all
        fresh ones. Same-signature jobs then always order by
        ``(aged, bucket_subkey, arrival)``, which is what lets the dispatch
        queue maintain per-bucket order without re-sorting.

        Every stock policy ranks ``-priority`` ahead of all its own terms,
        so a preempting high-priority arrival actually receives the nodes
        its victims release (with every priority at the default 0 the
        prefix is constant and the pre-priority orderings are reproduced
        exactly).
        """
        raise NotImplementedError

    def bucket_subkey(self, job: "JobRecord") -> tuple:
        """In-bucket ordering prefix (ahead of arrival order) for the
        incremental protocol: ``()`` for pure arrival order; policies whose
        ``sort_key`` orders same-signature jobs by submit time return
        ``(job.submit_time,)``."""
        return ()


class FIFOPolicy(QueuePolicy):
    name = "fifo"
    head_blocking = True
    incremental = True

    def order(self, queue, scheduler, now):
        # arrival order within a priority level (stable sort; with every
        # priority at 0 this is exactly the arrival-ordered queue)
        return sorted(queue, key=lambda job: -job.spec.priority)

    def sort_key(self, job, scheduler, now):
        return (-job.spec.priority,)


class BackfillPolicy(QueuePolicy):
    name = "backfill"
    head_blocking = False
    incremental = True

    def order(self, queue, scheduler, now):
        return sorted(queue, key=lambda job: -job.spec.priority)

    def sort_key(self, job, scheduler, now):
        return (-job.spec.priority,)


class EasyBackfillPolicy(BackfillPolicy):
    """EASY backfill: reservations bound the head-of-queue job's wait.

    Arrival order like :class:`BackfillPolicy`, but when the head job does
    not fit, the orchestrator books it a reservation at the earliest instant
    the scheduler's projected-release ledger says its demand fits, and a
    later job may start only when it *provably* does not delay that start —
    either it leaves the head's node counts intact at the reserved instant
    even if it never finishes, or its own modeled completion lands before
    the reservation. When no reservation can be proven (the head's nodes
    are held by allocations with no release projection, e.g. persistent
    pools), nothing backfills — the guarantee degrades to head-of-line
    blocking, never to starvation. The guarantee covers *node* availability;
    pool-capacity contention is outside the ledger's vocabulary.
    """

    name = "easy-backfill"
    reserving = True


class StorageAwarePolicy(QueuePolicy):
    """Smallest storage demand first, with aging anti-starvation."""

    name = "storage-aware"
    head_blocking = False
    incremental = True

    def __init__(self, aging_s: float = 3600.0):
        if aging_s <= 0:
            raise ValueError("aging_s must be positive")
        self.aging_s = aging_s

    def sort_key(self, job, scheduler, now):
        if (now - job.submit_time) >= self.aging_s:
            return (-job.spec.priority, 0, job.submit_time, job.submit_time)
        storage = job.request.storage
        n_storage = 0 if storage is None else scheduler.resolve_storage_nodes(storage)
        return (-job.spec.priority, 1, n_storage, job.submit_time)

    def bucket_subkey(self, job):
        return (job.submit_time,)

    def order(self, queue, scheduler, now):
        return sorted(queue, key=lambda job: self.sort_key(job, scheduler, now))


class PreemptionPolicy:
    """Selects RUNNING victims to checkpoint-and-release for a blocked
    higher-priority arrival.

    The stock ranking is the classic pair: lowest priority first, and among
    equals the job with the *least* run progress — most progress protected,
    because preempting a nearly-done job wastes the most committed work
    (checkpointing bounds the loss but re-staging and redeploying are never
    free). Victims are taken greedily until their released allocations
    cover the arrival's node demand; if even every eligible victim cannot
    cover it, nothing is preempted (no pointless evictions).
    """

    def select(
        self,
        job: "JobRecord",
        candidates: Sequence["VictimView"],
        demand: tuple[int, int],
        free: tuple[int, int],
    ) -> list["JobRecord"]:
        """``candidates`` are (record, priority, progress_fraction,
        n_compute, n_storage) views of preemptible RUNNING jobs with lower
        priority than ``job``; ``demand``/``free`` are (compute, storage)
        node counts. Returns the victims to release, possibly empty."""
        need_c = demand[0] - free[0]
        need_s = demand[1] - free[1]
        victims: list = []
        for v in sorted(candidates, key=lambda v: (v.priority, v.progress, v.job.job_id)):
            if need_c <= 0 and need_s <= 0:
                break
            victims.append(v.job)
            need_c -= v.n_compute
            need_s -= v.n_storage
        if need_c > 0 or need_s > 0:
            return []
        return victims


@dataclasses.dataclass(frozen=True)
class VictimView:
    """What a :class:`PreemptionPolicy` may observe about a candidate."""

    job: "JobRecord"
    priority: int
    progress: float          # fraction of run_time_s completed so far
    n_compute: int           # nodes its release would free
    n_storage: int


class DataAwarePolicy(QueuePolicy):
    """Route jobs to their data: highest resident-byte fraction first.

    Takes anything exposing ``resident_fraction(datasets)`` — a
    :class:`~repro.provision.ProvisioningService` (the preferred handle;
    its pool catalog knows what is warm where) or a bare
    :class:`~repro.pool.PoolManager`. A job with 100% of its datasets
    resident skips all shared stage-in; starting it now both finishes it
    sooner and *keeps* those datasets pinned-warm against eviction, which
    is the Data Diffusion feedback loop (hits beget hits). Jobs with
    nothing warm are ordered by storage demand (small first), and aging
    promotes starved jobs to strict arrival order.

    Resident fractions are cached per ``(datasets, PoolManager.epoch)``:
    a dispatch round ranks every bucket head, and large campaigns share a
    handful of dataset working sets, so without the cache each round pays
    O(pools x datasets) per head. The epoch folds in the catalog version,
    so any residency change (stage-in completion, eviction, pool retire)
    invalidates exactly the stale entries.
    """

    name = "data-aware"
    head_blocking = False
    incremental = True

    def __init__(self, pools, aging_s: float = 3600.0):
        if aging_s <= 0:
            raise ValueError("aging_s must be positive")
        if not hasattr(pools, "resident_fraction"):
            raise TypeError(
                "DataAwarePolicy needs a ProvisioningService or PoolManager "
                "(anything with resident_fraction)"
            )
        self.pools = pools
        self.aging_s = aging_s
        # datasets tuple -> (pool-state token, fraction)
        self._frac_cache: dict = {}

    def _pool_state(self) -> tuple:
        """Everything a cached fraction can go stale against: the manager
        identity (services can replace theirs) and its epoch (pool set,
        lease ledgers, catalog residency all fold in)."""
        pm = getattr(self.pools, "pool_manager", self.pools)
        return (id(pm), -1 if pm is None else pm.epoch)

    def resident_fraction(self, datasets) -> float:
        state = self._pool_state()
        hit = self._frac_cache.get(datasets)
        if hit is not None and hit[0] == state:
            return hit[1]
        frac = self.pools.resident_fraction(datasets)
        self._frac_cache[datasets] = (state, frac)
        return frac

    def sort_key(self, job, scheduler, now):
        if (now - job.submit_time) >= self.aging_s:
            return (-job.spec.priority, 0, job.submit_time, 0.0, job.submit_time)
        spec = job.spec
        frac = 0.0
        if spec.wants_pool and spec.all_datasets:
            frac = self.resident_fraction(spec.all_datasets)
        storage = job.request.storage
        n_storage = 0 if storage is None else scheduler.resolve_storage_nodes(storage)
        return (-job.spec.priority, 1, -frac, n_storage, job.submit_time)

    def bucket_subkey(self, job):
        return (job.submit_time,)

    def order(self, queue, scheduler, now):
        return sorted(queue, key=lambda job: self.sort_key(job, scheduler, now))
