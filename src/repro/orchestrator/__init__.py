"""Event-driven workflow orchestrator over the provisioning substrate.

Turns the paper's hand-driven allocate/provision/stage/run/teardown sequence
into a pipeline: jobs queue instead of failing when nodes are busy, phase
durations come from the calibrated perfmodel, faults trigger requeue, and a
campaign of hundreds of jobs simulates in milliseconds of wallclock.
"""

from .engine import SimEngine
from .lifecycle import (
    TERMINAL_STATES,
    JobRecord,
    JobState,
    Orchestrator,
    WorkflowSpec,
)
from .metrics import (
    BREAKDOWN_STATES,
    CampaignReport,
    JobBreakdown,
    format_report,
    job_breakdown,
    storage_node_utilization,
    summarize,
)
from .policies import BackfillPolicy, FIFOPolicy, QueuePolicy, StorageAwarePolicy

__all__ = [
    "SimEngine",
    "TERMINAL_STATES", "JobRecord", "JobState", "Orchestrator", "WorkflowSpec",
    "BREAKDOWN_STATES", "CampaignReport", "JobBreakdown", "format_report",
    "job_breakdown", "storage_node_utilization", "summarize",
    "BackfillPolicy", "FIFOPolicy", "QueuePolicy", "StorageAwarePolicy",
]
