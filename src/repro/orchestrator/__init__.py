"""Event-driven workflow orchestrator over the provisioning substrate.

Turns the paper's hand-driven allocate/provision/stage/run/teardown sequence
into a pipeline: jobs queue instead of failing when nodes are busy, phase
durations come from the calibrated perfmodel, faults trigger requeue, and a
campaign of hundreds of jobs simulates in milliseconds. Campaigns can draw
arrivals from a Poisson process (`arrivals`) and, with a persistent-pool
subsystem attached (`Orchestrator.enable_pools`, see ``repro.pool``), route
jobs to pools already holding their input datasets via `DataAwarePolicy`.
"""

from .arrivals import (
    exponential_interarrivals,
    mean_interarrival,
    poisson_arrivals,
    replay_trace,
)
from .engine import SimEngine
from .lifecycle import (
    TERMINAL_STATES,
    JobRecord,
    JobState,
    Orchestrator,
    WorkflowSpec,
)
from .metrics import (
    BREAKDOWN_STATES,
    CampaignReport,
    JobBreakdown,
    PoolReport,
    format_report,
    job_breakdown,
    pool_report,
    storage_node_utilization,
    summarize,
)
from .policies import (
    BackfillPolicy,
    DataAwarePolicy,
    FIFOPolicy,
    QueuePolicy,
    StorageAwarePolicy,
)

__all__ = [
    "SimEngine",
    "TERMINAL_STATES", "JobRecord", "JobState", "Orchestrator", "WorkflowSpec",
    "BREAKDOWN_STATES", "CampaignReport", "JobBreakdown", "PoolReport",
    "format_report", "job_breakdown", "pool_report",
    "storage_node_utilization", "summarize",
    "BackfillPolicy", "DataAwarePolicy", "FIFOPolicy", "QueuePolicy",
    "StorageAwarePolicy",
    "exponential_interarrivals", "mean_interarrival", "poisson_arrivals",
    "replay_trace",
]
