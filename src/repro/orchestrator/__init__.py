"""Event-driven workflow orchestrator over the provisioning substrate.

Turns the paper's hand-driven allocate/provision/stage/run/teardown sequence
into a pipeline: jobs queue instead of failing when nodes are busy, phase
durations come from the calibrated perfmodel, faults trigger requeue, and a
campaign of hundreds of jobs simulates in milliseconds. Campaigns can draw
arrivals from a Poisson process (`arrivals`) and, with a persistent-pool
subsystem attached (`Orchestrator.enable_pools`, see ``repro.pool``), route
jobs to pools already holding their input datasets via `DataAwarePolicy`.

Fault tolerance is a first-class layer (README "Fault tolerance and
reservations"): checkpointing specs resume from their last committed step
instead of restarting, `Orchestrator.preempt` checkpoint-and-releases
RUNNING jobs for higher-priority arrivals (`PreemptionPolicy`), and
`EasyBackfillPolicy` guarantees the blocked head-of-queue job a reserved
start no backfill may delay. `Orchestrator.live_report` serves O(1)
mid-flight campaign snapshots.
"""

from ..pilot import PilotSpec, TaskSpec
from .arrivals import (
    burst_arrivals,
    diurnal_arrivals,
    exponential_interarrivals,
    mean_interarrival,
    poisson_arrivals,
    replay_trace,
)
from .engine import SimEngine
from .lifecycle import (
    TERMINAL_STATES,
    JobRecord,
    JobState,
    LiveCounters,
    Orchestrator,
    Reservation,
    WorkflowSpec,
)
from .metrics import (
    BREAKDOWN_STATES,
    CampaignReport,
    JobBreakdown,
    LiveReport,
    PoolReport,
    format_report,
    job_breakdown,
    live_report,
    pool_report,
    storage_node_utilization,
    summarize,
)
from .policies import (
    BackfillPolicy,
    DataAwarePolicy,
    EasyBackfillPolicy,
    FIFOPolicy,
    PreemptionPolicy,
    QueuePolicy,
    StorageAwarePolicy,
    VictimView,
)

__all__ = [
    "SimEngine",
    "TERMINAL_STATES", "JobRecord", "JobState", "Orchestrator", "WorkflowSpec",
    "LiveCounters", "Reservation",
    "PilotSpec", "TaskSpec",      # pilot (two-level scheduling) entry points
    "BREAKDOWN_STATES", "CampaignReport", "JobBreakdown", "LiveReport",
    "PoolReport", "format_report", "job_breakdown", "live_report",
    "pool_report", "storage_node_utilization", "summarize",
    "BackfillPolicy", "DataAwarePolicy", "EasyBackfillPolicy", "FIFOPolicy",
    "PreemptionPolicy", "QueuePolicy", "StorageAwarePolicy", "VictimView",
    "burst_arrivals", "diurnal_arrivals", "exponential_interarrivals",
    "mean_interarrival", "poisson_arrivals", "replay_trace",
]
