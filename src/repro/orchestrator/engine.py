"""Event-driven simulation engine with a virtual clock.

The orchestrator advances time by *model predictions* (deploy time C8,
staging bandwidth, run time), not wallclock: a campaign of hundreds of jobs
— each spending modeled minutes in provisioning, staging, and compute —
executes in milliseconds of real time. Classic discrete-event simulation:
a min-heap of timestamped callbacks, popped in (time, insertion) order so
simultaneous events fire FIFO.
"""

from __future__ import annotations

import heapq
import itertools
from typing import Callable, Optional


class SimEngine:
    """A discrete-event loop over a virtual clock."""

    def __init__(self, start: float = 0.0):
        self._now = start
        self._heap: list[tuple[float, int, Callable[[], None]]] = []
        self._seq = itertools.count()
        self._events_processed = 0

    @property
    def now(self) -> float:
        """Current virtual time (seconds)."""
        return self._now

    @property
    def events_processed(self) -> int:
        return self._events_processed

    def __len__(self) -> int:
        return len(self._heap)

    def at(self, t: float, fn: Callable[[], None]) -> None:
        """Schedule ``fn`` to fire at virtual time ``t``."""
        if t < self._now:
            raise ValueError(f"cannot schedule at {t} < now {self._now}")
        heapq.heappush(self._heap, (t, next(self._seq), fn))

    def after(self, delay: float, fn: Callable[[], None]) -> None:
        """Schedule ``fn`` to fire ``delay`` seconds from now."""
        if delay < 0:
            raise ValueError(f"negative delay {delay}")
        self.at(self._now + delay, fn)

    def run(self, until: Optional[float] = None, max_events: int = 1_000_000) -> float:
        """Drain the event heap; returns the final virtual time.

        ``until`` stops the clock at that time, leaving later events queued.
        ``max_events`` guards against a pathological self-rescheduling loop.
        """
        processed = 0
        while self._heap:
            t, _, fn = self._heap[0]
            if until is not None and t > until:
                self._now = until
                return self._now
            heapq.heappop(self._heap)
            self._now = t
            fn()
            processed += 1
            self._events_processed += 1
            if processed >= max_events:
                raise RuntimeError(
                    f"engine processed {max_events} events without draining; "
                    f"likely an event loop (now={self._now})"
                )
        if until is not None and until > self._now:
            self._now = until
        return self._now
