"""Event-driven simulation engine with a virtual clock.

The orchestrator advances time by *model predictions* (deploy time C8,
staging bandwidth, run time), not wallclock: a campaign of hundreds of jobs
— each spending modeled minutes in provisioning, staging, and compute —
executes in milliseconds of real time. Classic discrete-event simulation:
a min-heap of timestamped callbacks, popped in (time, insertion) order so
simultaneous events fire FIFO.
"""

from __future__ import annotations

import heapq
import itertools
from typing import Callable, Iterable, Optional


class SimEngine:
    """A discrete-event loop over a virtual clock."""

    #: How many processed events between recorder heap-depth samples.
    SAMPLE_EVERY = 512

    def __init__(self, start: float = 0.0):
        self._now = start
        self._heap: list[tuple[float, int, Callable[[], None]]] = []
        self._seq = itertools.count()
        self._events_processed = 0
        # observability: an enabled repro.obs.trace recorder gets periodic
        # heap-depth samples from run(); None / NullRecorder cost one local
        # truthiness check per event
        self.recorder = None

    @property
    def now(self) -> float:
        """Current virtual time (seconds)."""
        return self._now

    @property
    def events_processed(self) -> int:
        return self._events_processed

    def __len__(self) -> int:
        return len(self._heap)

    def at(self, t: float, fn: Callable[[], None]) -> None:
        """Schedule ``fn`` to fire at virtual time ``t``."""
        if t < self._now:
            raise ValueError(f"cannot schedule at {t} < now {self._now}")
        heapq.heappush(self._heap, (t, next(self._seq), fn))

    def after(self, delay: float, fn: Callable[[], None]) -> None:
        """Schedule ``fn`` to fire ``delay`` seconds from now."""
        if delay < 0:
            raise ValueError(f"negative delay {delay}")
        self.at(self._now + delay, fn)

    def at_many(self, events: Iterable[tuple[float, Callable[[], None]]]) -> None:
        """Bulk-schedule ``(time, fn)`` pairs: one heapify instead of a push
        per event, for campaign submission bursts. Sequence numbers are
        assigned in iteration order, so simultaneous events still fire FIFO
        exactly as the equivalent sequence of :meth:`at` calls would (the
        pop order of a heap is determined by its entries alone)."""
        batch = []
        for t, fn in events:
            if t < self._now:
                raise ValueError(f"cannot schedule at {t} < now {self._now}")
            batch.append((t, next(self._seq), fn))
        if not batch:
            return
        if len(batch) > 8 and len(batch) * 4 > len(self._heap):
            self._heap.extend(batch)
            heapq.heapify(self._heap)
        else:
            for entry in batch:
                heapq.heappush(self._heap, entry)

    def run(
        self,
        until: Optional[float] = None,
        max_events: Optional[int] = 1_000_000,
    ) -> float:
        """Drain the event heap; returns the final virtual time.

        ``until`` stops the clock at that time, leaving later events queued.
        ``max_events`` guards against a pathological self-rescheduling loop;
        pass ``None`` to disable the backstop (large campaigns legitimately
        process many millions of events).
        """
        processed = 0
        heap = self._heap
        pop = heapq.heappop
        rec = self.recorder
        if rec is not None and not rec.enabled:
            rec = None
        sample_mask = self.SAMPLE_EVERY - 1
        while heap:
            if until is not None and heap[0][0] > until:
                self._now = until
                return self._now
            t, _, fn = pop(heap)
            self._now = t
            fn()
            processed += 1
            self._events_processed += 1
            if rec is not None and not (self._events_processed & sample_mask):
                rec.engine_sample(self._now, len(heap), self._events_processed)
            if max_events is not None and processed >= max_events:
                raise RuntimeError(
                    f"engine processed {max_events} events without draining; "
                    f"likely an event loop (now={self._now})"
                )
        if until is not None and until > self._now:
            self._now = until
        if rec is not None and processed:
            # closing sample: short runs (< SAMPLE_EVERY events) still get
            # at least one, and every trace ends with a drained-heap point
            rec.engine_sample(self._now, len(heap), self._events_processed)
        return self._now
