"""Campaign telemetry: per-job latency breakdowns and cluster-level rollups.

Everything here is derived from the `JobRecord.history` transition logs the
lifecycle machine writes — no live instrumentation, so a report can be
computed for any subset of jobs at any point of the campaign.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

from .lifecycle import TERMINAL_STATES, JobRecord, JobState

# Phases reported in per-job latency breakdowns, pipeline order.
BREAKDOWN_STATES = (
    JobState.QUEUED,
    JobState.PROVISIONING,
    JobState.STAGING_IN,
    JobState.RUNNING,
    JobState.STAGING_OUT,
    JobState.TEARDOWN,
)


@dataclasses.dataclass(frozen=True)
class JobBreakdown:
    """Seconds spent per lifecycle phase, summed across retries."""

    name: str
    job_id: int
    final_state: JobState
    attempts: int
    phase_s: dict
    total_s: float

    @property
    def queue_wait_s(self) -> float:
        return self.phase_s.get(JobState.QUEUED, 0.0)


@dataclasses.dataclass(frozen=True)
class CampaignReport:
    n_jobs: int
    n_done: int
    n_failed: int
    makespan_s: float
    storage_node_utilization: float      # busy node-seconds / capacity
    total_retries: int
    staged_in_bytes: float
    staged_out_bytes: float
    mean_queue_wait_s: float
    max_queue_wait_s: float
    mean_phase_s: dict
    breakdowns: tuple


def job_breakdown(job: JobRecord, now: Optional[float] = None) -> JobBreakdown:
    """Fold a job's transition history into per-phase durations."""
    phase_s: dict = {s: 0.0 for s in BREAKDOWN_STATES}
    hist = job.history
    for (state, t0), (_, t1) in zip(hist, hist[1:]):
        if state in phase_s:
            phase_s[state] += t1 - t0
    if hist and hist[-1][0] not in TERMINAL_STATES and now is not None:
        state, t0 = hist[-1]
        if state in phase_s:
            phase_s[state] += now - t0
    end = hist[-1][1] if hist else job.submit_time
    if now is not None and job.state not in TERMINAL_STATES:
        end = now
    # each attempt (initial or requeue) opens with a QUEUED transition, so
    # the count is exact for DONE, FAILED-exhausted, and still-running jobs
    attempts = max(1, sum(s is JobState.QUEUED for s, _ in hist))
    return JobBreakdown(
        name=job.spec.name,
        job_id=job.job_id,
        final_state=job.state,
        attempts=attempts,
        phase_s=phase_s,
        total_s=end - job.submit_time,
    )


def storage_node_utilization(
    jobs: Sequence[JobRecord],
    n_storage_nodes: int,
    makespan_s: float,
    now: Optional[float] = None,
) -> float:
    """Busy storage-node-seconds over the campaign's node-second capacity.

    Pass ``now`` for a mid-campaign snapshot: allocations still open at
    ``now`` count as busy from their start time."""
    if n_storage_nodes <= 0 or makespan_s <= 0:
        return 0.0
    busy = sum(
        (t1 - t0) * n for job in jobs for (t0, t1, n) in job.storage_intervals
    )
    if now is not None:
        busy += sum(
            (now - job.alloc_started) * len(job.allocation.storage_nodes)
            for job in jobs
            if job.allocation is not None and job.alloc_started is not None
        )
    return busy / (n_storage_nodes * makespan_s)


def summarize(
    jobs: Sequence[JobRecord],
    *,
    n_storage_nodes: int,
    now: Optional[float] = None,
) -> CampaignReport:
    if not jobs:
        raise ValueError("no jobs to summarize")
    breakdowns = tuple(job_breakdown(j, now) for j in jobs)
    t_start = min(j.submit_time for j in jobs)
    t_end = max(
        (h[-1][1] for j in jobs if (h := j.history)), default=t_start
    )
    if now is not None:
        t_end = max(t_end, now)
    makespan = t_end - t_start
    waits = [b.queue_wait_s for b in breakdowns]
    mean_phase = {
        s: sum(b.phase_s[s] for b in breakdowns) / len(breakdowns)
        for s in BREAKDOWN_STATES
    }
    return CampaignReport(
        n_jobs=len(jobs),
        n_done=sum(j.state is JobState.DONE for j in jobs),
        n_failed=sum(j.state is JobState.FAILED for j in jobs),
        makespan_s=makespan,
        storage_node_utilization=storage_node_utilization(
            jobs, n_storage_nodes, makespan, now
        ),
        total_retries=sum(b.attempts - 1 for b in breakdowns),
        staged_in_bytes=sum(j.staged_in_bytes for j in jobs),
        staged_out_bytes=sum(j.staged_out_bytes for j in jobs),
        mean_queue_wait_s=sum(waits) / len(waits),
        max_queue_wait_s=max(waits),
        mean_phase_s=mean_phase,
        breakdowns=breakdowns,
    )


def format_report(report: CampaignReport, *, top_n: int = 10) -> str:
    """Human-readable campaign summary + the ``top_n`` slowest jobs."""
    lines = [
        f"jobs: {report.n_jobs} ({report.n_done} done, {report.n_failed} failed, "
        f"{report.total_retries} retries)",
        f"makespan: {report.makespan_s:,.1f} s (virtual)",
        f"storage-node utilization: {report.storage_node_utilization:.1%}",
        f"staged: {report.staged_in_bytes / 1e9:,.1f} GB in, "
        f"{report.staged_out_bytes / 1e9:,.1f} GB out",
        f"queue wait: mean {report.mean_queue_wait_s:,.1f} s, "
        f"max {report.max_queue_wait_s:,.1f} s",
        "mean phase breakdown (s): "
        + "  ".join(
            f"{s.value}={report.mean_phase_s[s]:,.1f}" for s in BREAKDOWN_STATES
        ),
        f"slowest {min(top_n, report.n_jobs)} jobs:",
    ]
    slowest = sorted(report.breakdowns, key=lambda b: -b.total_s)[:top_n]
    for b in slowest:
        phases = "  ".join(
            f"{s.value}={b.phase_s[s]:,.1f}" for s in BREAKDOWN_STATES
        )
        lines.append(
            f"  {b.name:<20s} {b.final_state.value:<7s} x{b.attempts} "
            f"total={b.total_s:,.1f}s  {phases}"
        )
    return "\n".join(lines)
