"""Campaign telemetry: per-job latency breakdowns and cluster-level rollups.

Everything here is derived from the `JobRecord.history` transition logs the
lifecycle machine writes — no live instrumentation, so a report can be
computed for any subset of jobs at any point of the campaign.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

from .lifecycle import TERMINAL_STATES, JobRecord, JobState, LiveCounters

# Phases reported in per-job latency breakdowns, pipeline order.
BREAKDOWN_STATES = (
    JobState.QUEUED,
    JobState.PROVISIONING,
    JobState.STAGING_IN,
    JobState.RUNNING,
    JobState.STAGING_OUT,
    JobState.TEARDOWN,
)


@dataclasses.dataclass(frozen=True)
class JobBreakdown:
    """Seconds spent per lifecycle phase, summed across retries."""

    name: str
    job_id: int
    final_state: JobState
    attempts: int
    phase_s: dict
    total_s: float

    @property
    def queue_wait_s(self) -> float:
        return self.phase_s.get(JobState.QUEUED, 0.0)


@dataclasses.dataclass(frozen=True)
class PoolReport:
    """Persistent-pool telemetry attached to a campaign report."""

    n_pools: int                 # ever created
    n_live: int                  # not yet retired at summarize time
    dataset_hits: int
    dataset_misses: int
    hit_rate: float              # hits / (hits + misses), dataset-granular
    stage_in_bytes_saved: float  # traffic avoided by cache hits
    bytes_staged: float          # dataset bytes pulled into pools
    evictions: int
    evicted_bytes: float
    occupancy: float             # mean used/capacity over live pools
    leases_granted: int
    pools_retired: int


@dataclasses.dataclass(frozen=True)
class CampaignReport:
    n_jobs: int
    n_done: int
    n_failed: int
    makespan_s: float
    storage_node_utilization: float      # busy node-seconds / capacity
    total_retries: int
    staged_in_bytes: float
    staged_out_bytes: float
    mean_queue_wait_s: float
    max_queue_wait_s: float
    mean_phase_s: dict
    breakdowns: tuple
    stage_in_bytes_saved: float = 0.0    # summed over jobs (pool cache hits)
    pool: Optional[PoolReport] = None
    # fault-tolerance rollups (checkpoint-aware requeue + preemption)
    checkpoints_committed: int = 0
    preemptions: int = 0                 # checkpoint-and-release requeues
    resumes: int = 0                     # attempts started with committed work
    run_s_saved: float = 0.0             # run seconds resumes did not replay
    # pilot (two-level scheduling) rollups — folded from each pilot job's
    # in-pilot TaskScheduler stats
    n_pilots: int = 0
    tasks_submitted: int = 0
    tasks_done: int = 0
    tasks_failed: int = 0
    task_retries: int = 0
    #: makespan attribution from the span DAG (a
    #: :class:`repro.obs.profile.CriticalPath`); populated when
    #: :func:`summarize` is handed the campaign's trace recorder
    critical_path: Optional[object] = None
    #: SLO / error-budget accounting (a :class:`repro.obs.slo.SLOReport`);
    #: populated when the trace handed to :func:`summarize` carries an
    #: :class:`~repro.obs.alerts.AlertEngine` with an SLO tracker attached
    slo: Optional[object] = None


@dataclasses.dataclass(frozen=True)
class LiveReport:
    """O(1) mid-flight snapshot built from the orchestrator's incremental
    `LiveCounters` — no per-job scan, no history folds. The batch
    :func:`summarize` remains the reference; the regression tests hold the
    shared fields equal at arbitrary poll instants."""

    t: float
    n_jobs: int
    n_done: int
    n_failed: int
    retries: int
    preemptions: int
    resumes: int
    checkpoints_committed: int
    run_s_saved: float
    staged_in_bytes: float
    staged_out_bytes: float
    stage_in_bytes_saved: float
    makespan_s: float
    storage_node_utilization: float
    # pilot (two-level scheduling) rollups
    n_pilots: int = 0
    tasks_submitted: int = 0
    tasks_done: int = 0
    tasks_failed: int = 0
    task_retries: int = 0


def live_report(
    counters: LiveCounters, *, n_storage_nodes: int, now: float
) -> LiveReport:
    """Fold `LiveCounters` into a `LiveReport` at instant ``now``."""
    return LiveReport(
        t=now,
        n_jobs=counters.n_jobs,
        n_done=counters.n_done,
        n_failed=counters.n_failed,
        retries=counters.retries,
        preemptions=counters.preemptions,
        resumes=counters.resumes,
        checkpoints_committed=counters.checkpoints,
        run_s_saved=counters.run_s_saved,
        staged_in_bytes=counters.staged_in_bytes,
        staged_out_bytes=counters.staged_out_bytes,
        stage_in_bytes_saved=counters.stage_in_saved_bytes,
        makespan_s=counters.makespan_s(now),
        storage_node_utilization=counters.utilization(n_storage_nodes, now),
        n_pilots=counters.pilots,
        tasks_submitted=counters.tasks_submitted,
        tasks_done=counters.tasks_done,
        tasks_failed=counters.tasks_failed,
        task_retries=counters.task_retries,
    )


def job_breakdown(job: JobRecord, now: Optional[float] = None) -> JobBreakdown:
    """Fold a job's transition history into per-phase durations."""
    phase_s: dict = {s: 0.0 for s in BREAKDOWN_STATES}
    hist = job.history
    for (state, t0), (_, t1) in zip(hist, hist[1:]):
        if state in phase_s:
            phase_s[state] += t1 - t0
    if hist and hist[-1][0] not in TERMINAL_STATES and now is not None:
        state, t0 = hist[-1]
        if state in phase_s:
            phase_s[state] += now - t0
    end = hist[-1][1] if hist else job.submit_time
    if now is not None and job.state not in TERMINAL_STATES:
        end = now
    # each attempt (initial or requeue) opens with a QUEUED transition, so
    # the count is exact for DONE, FAILED-exhausted, and still-running jobs
    attempts = max(1, sum(s is JobState.QUEUED for s, _ in hist))
    return JobBreakdown(
        name=job.spec.name,
        job_id=job.job_id,
        final_state=job.state,
        attempts=attempts,
        phase_s=phase_s,
        total_s=end - job.submit_time,
    )


def storage_node_utilization(
    jobs: Sequence[JobRecord],
    n_storage_nodes: int,
    makespan_s: float,
    now: Optional[float] = None,
) -> float:
    """Busy storage-node-seconds over the campaign's node-second capacity.

    Pass ``now`` for a mid-campaign snapshot: allocations still open at
    ``now`` count as busy from their start time."""
    if n_storage_nodes <= 0 or makespan_s <= 0:
        return 0.0
    busy = sum(
        (t1 - t0) * n for job in jobs for (t0, t1, n) in job.storage_intervals
    )
    if now is not None:
        busy += sum(
            (now - job.alloc_started) * len(job.allocation.storage_nodes)
            for job in jobs
            if job.allocation is not None and job.alloc_started is not None
        )
    return busy / (n_storage_nodes * makespan_s)


def pool_report(pools) -> PoolReport:
    """Snapshot a :class:`~repro.pool.PoolManager` for a campaign report."""
    stats = pools.stats
    return PoolReport(
        n_pools=stats.pools_created,
        n_live=len(pools.live_pools),
        dataset_hits=stats.dataset_hits,
        dataset_misses=stats.dataset_misses,
        hit_rate=stats.hit_rate,
        stage_in_bytes_saved=stats.bytes_saved,
        bytes_staged=stats.bytes_staged,
        evictions=pools.evictor.evictions,
        evicted_bytes=pools.evictor.evicted_bytes,
        occupancy=pools.occupancy(),
        leases_granted=stats.leases_granted,
        pools_retired=stats.pools_retired,
    )


def summarize(
    jobs: Sequence[JobRecord],
    *,
    n_storage_nodes: int,
    now: Optional[float] = None,
    pools=None,
    trace=None,
) -> CampaignReport:
    """Fold job records into a :class:`CampaignReport`. Pass the campaign's
    :class:`~repro.obs.trace.TraceRecorder` as ``trace`` to also attach the
    critical-path makespan attribution (see :mod:`repro.obs.profile`)."""
    if not jobs:
        raise ValueError("no jobs to summarize")
    breakdowns = tuple(job_breakdown(j, now) for j in jobs)
    t_start = min(j.submit_time for j in jobs)
    t_end = max(
        (h[-1][1] for j in jobs if (h := j.history)), default=t_start
    )
    if now is not None:
        t_end = max(t_end, now)
    makespan = t_end - t_start
    utilization = storage_node_utilization(jobs, n_storage_nodes, makespan, now)
    if pools is not None and makespan > 0 and n_storage_nodes > 0:
        # pool-held nodes are busy from creation to retirement (or still),
        # clipped to the campaign window — jobs' own intervals don't see them
        busy = 0.0
        for p in pools.pools:
            end = p.retired_at if p.retired_at is not None else t_end
            span = min(end, t_end) - max(p.created_at, t_start)
            busy += len(p.allocation.storage_nodes) * max(0.0, span)
        utilization += busy / (n_storage_nodes * makespan)
    n_pilots = tasks_submitted = tasks_done = tasks_failed = task_retries = 0
    for j in jobs:
        if j.pilot is None:
            continue
        n_pilots += 1
        st = j.pilot.stats
        tasks_submitted += st.submitted
        tasks_done += st.done
        tasks_failed += st.failed
        task_retries += st.retries
    waits = [b.queue_wait_s for b in breakdowns]
    mean_phase = {
        s: sum(b.phase_s[s] for b in breakdowns) / len(breakdowns)
        for s in BREAKDOWN_STATES
    }
    return CampaignReport(
        n_jobs=len(jobs),
        n_done=sum(j.state is JobState.DONE for j in jobs),
        n_failed=sum(j.state is JobState.FAILED for j in jobs),
        makespan_s=makespan,
        storage_node_utilization=utilization,
        total_retries=sum(b.attempts - 1 for b in breakdowns),
        staged_in_bytes=sum(j.staged_in_bytes for j in jobs),
        staged_out_bytes=sum(j.staged_out_bytes for j in jobs),
        mean_queue_wait_s=sum(waits) / len(waits),
        max_queue_wait_s=max(waits),
        mean_phase_s=mean_phase,
        breakdowns=breakdowns,
        stage_in_bytes_saved=sum(j.stage_in_saved_bytes for j in jobs),
        pool=pool_report(pools) if pools is not None else None,
        checkpoints_committed=sum(j.checkpoints_committed for j in jobs),
        preemptions=sum(j.preemptions for j in jobs),
        resumes=sum(j.resume_attempts for j in jobs),
        run_s_saved=sum(j.run_s_saved for j in jobs),
        n_pilots=n_pilots,
        tasks_submitted=tasks_submitted,
        tasks_done=tasks_done,
        tasks_failed=tasks_failed,
        task_retries=task_retries,
        critical_path=_critical_path(trace),
        slo=_slo_report(trace),
    )


def _critical_path(trace):
    """Offline reporting step — imported lazily so the hot lifecycle path
    never loads the profiler (tools/check_obs_imports.py allows hot modules
    only module-level imports of the recorder interface)."""
    if trace is None:
        return None
    from ..obs.profile import critical_path

    return critical_path(trace)


def _slo_report(trace):
    """Fold the trace's SLO accounting into the report, when an alert
    engine with a tracker rides the recorder (duck-typed off the recorder's
    ``alerts`` attribute — no obs import needed at all)."""
    alerts = getattr(trace, "alerts", None)
    if alerts is None or alerts.slos is None:
        return None
    return alerts.slos.report()


def format_report(report: CampaignReport, *, top_n: int = 10) -> str:
    """Human-readable campaign summary + the ``top_n`` slowest jobs."""
    lines = [
        f"jobs: {report.n_jobs} ({report.n_done} done, {report.n_failed} failed, "
        f"{report.total_retries} retries)",
        f"makespan: {report.makespan_s:,.1f} s (virtual)",
        f"storage-node utilization: {report.storage_node_utilization:.1%}",
        f"staged: {report.staged_in_bytes / 1e9:,.1f} GB in, "
        f"{report.staged_out_bytes / 1e9:,.1f} GB out",
        f"queue wait: mean {report.mean_queue_wait_s:,.1f} s, "
        f"max {report.max_queue_wait_s:,.1f} s",
        "mean phase breakdown (s): "
        + "  ".join(
            f"{s.value}={report.mean_phase_s[s]:,.1f}" for s in BREAKDOWN_STATES
        ),
    ]
    if report.checkpoints_committed or report.preemptions or report.resumes:
        lines.append(
            f"fault tolerance: {report.checkpoints_committed} checkpoints, "
            f"{report.resumes} resumes ({report.run_s_saved:,.1f} s of run "
            f"time not replayed), {report.preemptions} preemptions"
        )
    if report.n_pilots:
        lines.append(
            f"pilots: {report.n_pilots} ({report.tasks_done:,} of "
            f"{report.tasks_submitted:,} tasks done, {report.tasks_failed} "
            f"failed, {report.task_retries} in-pilot task retries)"
        )
    if report.pool is not None:
        p = report.pool
        lines += [
            f"pools: {p.n_pools} created ({p.n_live} live, {p.pools_retired} "
            f"retired), {p.leases_granted} leases",
            f"dataset cache: {p.dataset_hits} hits / {p.dataset_misses} misses "
            f"(hit rate {p.hit_rate:.1%}), "
            f"{p.stage_in_bytes_saved / 1e9:,.1f} GB stage-in saved, "
            f"{p.bytes_staged / 1e9:,.1f} GB staged into pools",
            f"evictions: {p.evictions} ({p.evicted_bytes / 1e9:,.1f} GB), "
            f"pool occupancy {p.occupancy:.1%}",
        ]
    if report.critical_path is not None:
        from ..obs.profile import format_critical_path

        lines.append(format_critical_path(report.critical_path))
    if report.slo is not None:
        from ..obs.slo import format_slo_report

        lines.append(format_slo_report(report.slo))
    lines.append(f"slowest {min(top_n, report.n_jobs)} jobs:")
    slowest = sorted(report.breakdowns, key=lambda b: -b.total_s)[:top_n]
    for b in slowest:
        phases = "  ".join(
            f"{s.value}={b.phase_s[s]:,.1f}" for s in BREAKDOWN_STATES
        )
        lines.append(
            f"  {b.name:<20s} {b.final_state.value:<7s} x{b.attempts} "
            f"total={b.total_s:,.1f}s  {phases}"
        )
    return "\n".join(lines)
