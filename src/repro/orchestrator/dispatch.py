"""Indexed dispatch queue: incremental job admission for large campaigns.

The legacy dispatch loop re-sorts the whole wait queue (``policy.order``),
re-resolves every queued job's demand, and removes an admitted job with an
O(Q) list scan — once per admitted job, so a campaign of N jobs pays
O(N²·log N) in the dispatcher alone. This module replaces that with an
indexed structure without changing any observable scheduling decision:

* Jobs are grouped into **buckets** by *admission signature* — everything
  the provisioning path can observe about a job except its name: the
  resolved `StorageSpec`'s fields plus the compute-node count (PERSISTENT
  specs also carry their name, because pool creation is
  idempotent-by-name). Same-signature jobs are interchangeable to every
  admission check: negotiation sees the same spec, the scheduler resolves
  the same demand, a pool sees the same working set. If the first of them
  in policy order cannot start right now, neither can the rest — so one
  probe per *bucket* replaces one probe per *job*.
* Within a bucket, the built-in policies order jobs by
  ``(aged, bucket_subkey, arrival seq)`` — the incremental contract
  documented on :meth:`QueuePolicy.sort_key` — which is invariant under
  free-pool and catalog changes. In-bucket order is therefore maintained
  once, in two lazy-deletion heaps (aged / fresh) per bucket.
* Across buckets only the bucket *heads* are compared, with the policy's
  full ``sort_key`` (storage demand against the live free pool, resident
  fraction against the live catalog, ...) computed fresh per dispatch
  round: O(buckets · log buckets), not O(queue · log queue).
* Aging promotions are driven by a global min-heap on each job's promotion
  instant, so a job moves to the aged class exactly when the legacy sort
  would have reclassified it.
"""

from __future__ import annotations

import heapq
import itertools
from typing import TYPE_CHECKING, Optional

from ..provision.spec import LifetimeClass

if TYPE_CHECKING:
    from ..core.scheduler import Scheduler
    from .lifecycle import JobRecord
    from .policies import QueuePolicy


def admission_signature(job: "JobRecord") -> tuple:
    """Everything admission can observe about a queued job except its name
    (plus the name for PERSISTENT specs — pool creation is idempotent by
    name, so two PERSISTENT jobs with different names are *not*
    interchangeable: one may reattach to a live pool the other cannot).

    Resume state (``committed_run_s``, ``staged_nodes``, the restore bytes
    a cold landing re-reads) is deliberately **excluded**: it moves a
    session's modeled *time* costs but never its grant/deny answer, so a
    checkpoint-resuming requeue keeps the same admission-signature bucket
    rank as a fresh attempt of the same spec — which is what keeps
    one-probe-per-bucket dispatch sound with fault tolerance on.

    ``priority`` *is* included — not because admission sees it, but because
    every stock policy ranks it ahead of its own terms, and in-bucket order
    is maintained priority-blind; same-priority jobs are still one bucket."""
    sspec = job.sspec
    sig = sspec.signature()
    if sspec.lifetime is LifetimeClass.PERSISTENT:
        sig = sig + (sspec.name,)
    return (job.spec.n_compute, job.spec.priority, sig)


class _Entry:
    """One enqueued attempt of a job (a requeue creates a fresh entry)."""

    __slots__ = ("job", "seq", "aged", "alive", "bucket")

    def __init__(self, job: "JobRecord", seq: int, aged: bool, bucket: "_Bucket"):
        self.job = job
        self.seq = seq
        self.aged = aged
        self.alive = True
        self.bucket = bucket


class _Bucket:
    """Jobs sharing one admission signature, in policy order.

    Heap items are ``(subkey..., seq, entry)``; the aged heap orders before
    the fresh heap (every built-in policy ranks aged jobs first)."""

    __slots__ = ("signature", "aged", "fresh", "n_live")

    def __init__(self, signature: tuple):
        self.signature = signature
        self.aged: list = []
        self.fresh: list = []
        self.n_live = 0

    def push(self, entry: _Entry, subkey: tuple) -> None:
        heap = self.aged if entry.aged else self.fresh
        heapq.heappush(heap, (*subkey, entry.seq, entry))
        self.n_live += 1

    def head(self) -> Optional[_Entry]:
        """Live entry first in in-bucket order (lazy-dropping removed and
        promoted-away entries from the heap heads)."""
        aged = self.aged
        while aged and not aged[0][-1].alive:
            heapq.heappop(aged)
        if aged:
            return aged[0][-1]
        fresh = self.fresh
        while fresh and (not fresh[0][-1].alive or fresh[0][-1].aged):
            heapq.heappop(fresh)
        return fresh[0][-1] if fresh else None


class DispatchQueue:
    """The orchestrator's wait queue, indexed for O(buckets) dispatch."""

    def __init__(self, policy: "QueuePolicy", scheduler: "Scheduler"):
        self.policy = policy
        self.scheduler = scheduler
        self._buckets: dict[tuple, _Bucket] = {}
        self._entries: dict[int, _Entry] = {}        # job_id -> live entry
        self._seq = itertools.count()
        # (promotion instant, seq, entry) for not-yet-aged jobs
        self._promotions: list = []

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, job: "JobRecord") -> bool:
        return job.job_id in self._entries

    def add(self, job: "JobRecord", now: float) -> None:
        if job.job_id in self._entries:
            raise ValueError(f"{job.spec.name!r} is already queued")
        sig = admission_signature(job)
        bucket = self._buckets.get(sig)
        if bucket is None:
            bucket = self._buckets[sig] = _Bucket(sig)
        aging = self.policy.aging_s
        aged = aging is not None and (now - job.submit_time) >= aging
        entry = _Entry(job, next(self._seq), aged, bucket)
        self._entries[job.job_id] = entry
        bucket.push(entry, self.policy.bucket_subkey(job))
        if aging is not None and not aged:
            heapq.heappush(
                self._promotions, (job.submit_time + aging, entry.seq, entry)
            )

    def remove(self, job: "JobRecord") -> None:
        entry = self._entries.pop(job.job_id)
        entry.alive = False
        bucket = entry.bucket
        bucket.n_live -= 1
        if bucket.n_live == 0:
            # dropping the bucket also drops its dead heap entries
            del self._buckets[bucket.signature]

    def promote(self, now: float) -> None:
        """Move every job whose wait crossed ``aging_s`` to the aged class —
        exactly the reclassification the legacy full sort would apply."""
        promos = self._promotions
        while promos and promos[0][0] <= now:
            _, _, entry = heapq.heappop(promos)
            if entry.alive and not entry.aged:
                entry.aged = True
                bucket = entry.bucket
                heapq.heappush(
                    bucket.aged,
                    (*self.policy.bucket_subkey(entry.job), entry.seq, entry),
                )

    def candidate_heads(self, now: float, gate=None) -> list:
        """``(key, seq, job, bucket)`` for every bucket head. Heapified by
        the caller, this is the legacy policy order restricted to heads
        (seq is unique, so job/bucket never enter the comparison).

        ``gate`` (e.g. the orchestrator's O(1) admissibility pre-filter)
        drops heads that would certainly be refused, before paying for
        their policy keys — sound because a gated-out probe is
        side-effect-free in the legacy scan too."""
        policy, scheduler = self.policy, self.scheduler
        out = []
        for bucket in self._buckets.values():
            entry = bucket.head()
            if entry is None or (gate is not None and not gate(entry.job)):
                continue
            out.append(
                (policy.sort_key(entry.job, scheduler, now), entry.seq, entry.job, bucket)
            )
        return out

    def head_item(self, bucket: _Bucket, now: float, gate=None) -> Optional[tuple]:
        """Fresh candidate tuple for one bucket (after its head changed)."""
        entry = bucket.head()
        if entry is None or (gate is not None and not gate(entry.job)):
            return None
        key = self.policy.sort_key(entry.job, self.scheduler, now)
        return (key, entry.seq, entry.job, bucket)

    def is_bucket_head(self, job: "JobRecord") -> bool:
        entry = self._entries[job.job_id]
        return entry.bucket.head() is entry

    def seq_of(self, job: "JobRecord") -> int:
        return self._entries[job.job_id].seq

    def jobs(self) -> list:
        """Snapshot of queued jobs in arrival order (``Orchestrator.queue``)."""
        return [
            e.job for e in sorted(self._entries.values(), key=lambda e: e.seq)
        ]
