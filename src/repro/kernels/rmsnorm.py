"""Fused RMSNorm Pallas kernel: one pass over rows, fp32 statistics in-tile.

Grid: rows / BR. Tile (BR, d) stays in VMEM; d up to ~8k rows fit easily
(BR * d * 4B << 16 MiB VMEM for BR=256, d=8192 -> 8 MiB).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    scale = s_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps) * scale).astype(o_ref.dtype)


def rmsnorm(x: jnp.ndarray, scale: jnp.ndarray, *, eps: float = 1e-5,
            block_rows: int = 256, interpret: bool = True) -> jnp.ndarray:
    """x: (..., d); scale: (d,)."""
    orig_shape = x.shape
    d = x.shape[-1]
    rows = int(x.size // d)
    xr = x.reshape(rows, d)
    BR = min(block_rows, rows)
    if rows % BR:
        BR = 1
    kernel = functools.partial(_kernel, eps=eps)
    out = pl.pallas_call(
        kernel,
        grid=(rows // BR,),
        in_specs=[
            pl.BlockSpec((BR, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((BR, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel",),
        ),
        interpret=interpret,
    )(xr, scale)
    return out.reshape(orig_shape)
