"""Mamba2 SSD intra-chunk kernel (Pallas TPU).

The chunked SSD algorithm splits into (a) per-chunk quadratic work --
build the decay-masked (Q x Q) score matrix, apply it to the inputs, and
reduce the chunk's contribution to the running state -- and (b) a cheap
inter-chunk linear scan. (a) is the MXU-heavy part and lives here; (b)
stays a ``lax.scan`` on the host graph (see ``models/mamba2.ssd_chunked``).

Grid: (B * nc, H). Per step the kernel holds the chunk's C/B (Q, N),
x (Q, P) and log-decay (Q,) tiles in VMEM; emits y_intra (Q, P), the chunk
state contribution (P, N) and the chunk's total decay (scalar).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(la_ref, c_ref, b_ref, x_ref, y_ref, st_ref, tot_ref, *, Q: int):
    la = la_ref[0, 0, 0].astype(jnp.float32)         # (Q,)
    C = c_ref[0].astype(jnp.float32)                 # (Q, N)
    Bm = b_ref[0].astype(jnp.float32)                # (Q, N)
    x = x_ref[0, 0, 0].astype(jnp.float32)           # (Q, P)

    L = jnp.cumsum(la)                               # (Q,)
    # intra-chunk: M[t,s] = exp(L_t - L_s) * (C_t . B_s)  for s <= t
    CB = jax.lax.dot_general(
        C, Bm, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                                # (Q, Q)
    seg = L[:, None] - L[None, :]
    rows = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    M = jnp.where(cols <= rows, jnp.exp(seg) * CB, 0.0)
    y_ref[0, 0, 0] = jax.lax.dot_general(
        M, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    ).astype(y_ref.dtype)

    # chunk state contribution: sum_s exp(L_end - L_s) x_s ⊗ B_s -> (P, N)
    w_end = jnp.exp(L[-1] - L)                       # (Q,)
    xw = x * w_end[:, None]                          # (Q, P)
    st_ref[0, 0, 0] = jax.lax.dot_general(
        xw, Bm, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    ).astype(st_ref.dtype)
    tot_ref[0, 0] = L[-1]


def ssd_intra_chunk(la, C, B_in, x, *, interpret: bool = True):
    """la: (B, nc, Q, H) log-decay; C/B_in: (B, nc, Q, N); x: (B, nc, Q, H, P).

    Returns (y_intra (B,nc,Q,H,P) f32, states (B,nc,H,P,N) f32,
    tot (B,nc,H) f32 total log-decay per chunk).
    """
    Bs, nc, Q, H = la.shape
    N = C.shape[-1]
    P = x.shape[-1]

    la_r = la.transpose(0, 1, 3, 2).reshape(Bs * nc, 1, H, Q)
    c_r = C.reshape(Bs * nc, Q, N)
    b_r = B_in.reshape(Bs * nc, Q, N)
    x_r = x.transpose(0, 1, 3, 2, 4).reshape(Bs * nc, 1, H, Q, P)

    kernel = functools.partial(_kernel, Q=Q)
    y, st, tot = pl.pallas_call(
        kernel,
        grid=(Bs * nc, H),
        in_specs=[
            pl.BlockSpec((1, 1, 1, Q), lambda i, h: (i, 0, h, 0)),
            pl.BlockSpec((1, Q, N), lambda i, h: (i, 0, 0)),
            pl.BlockSpec((1, Q, N), lambda i, h: (i, 0, 0)),
            pl.BlockSpec((1, 1, 1, Q, P), lambda i, h: (i, 0, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, Q, P), lambda i, h: (i, 0, h, 0, 0)),
            pl.BlockSpec((1, 1, 1, P, N), lambda i, h: (i, 0, h, 0, 0)),
            pl.BlockSpec((1, 1), lambda i, h: (i, h)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bs * nc, 1, H, Q, P), jnp.float32),
            jax.ShapeDtypeStruct((Bs * nc, 1, H, P, N), jnp.float32),
            jax.ShapeDtypeStruct((Bs * nc, H), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel"),
        ),
        interpret=interpret,
    )(la_r, c_r, b_r, x_r)

    y = y.reshape(Bs, nc, H, Q, P).transpose(0, 1, 3, 2, 4)
    st = st.reshape(Bs, nc, H, P, N)
    tot = tot.reshape(Bs, nc, H)
    return y, st, tot
