"""Pure-jnp oracles for every Pallas kernel (the source of truth in tests)."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

_NEG = -2.0e38


def flash_attention_ref(
    q, k, v, *, causal: bool = True, window: Optional[int] = None
) -> jnp.ndarray:
    """q: (B,S,H,hd); k/v: (B,T,K,hd) GQA full-softmax reference."""
    B, S, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    qg = q.reshape(B, S, K, G, hd)
    s = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32) * (hd ** -0.5)
    rows = jnp.arange(S)[:, None]
    cols = jnp.arange(T)[None, :]
    ok = cols <= rows if causal else jnp.ones((S, T), bool)
    if window is not None:
        ok = ok & (cols > rows - window)
    s = jnp.where(ok, s, _NEG)
    w = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    return jnp.einsum("bkgst,btkd->bskgd", w, v).reshape(B, S, H, hd)


def decode_attention_ref(q, k, v, *, kv_len, window: Optional[int] = None):
    """q: (B,1,H,hd); k/v: (B,T,K,hd); attend to [0, kv_len)."""
    B, S, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    qg = q.reshape(B, S, K, G, hd)
    s = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32) * (hd ** -0.5)
    cols = jnp.arange(T)[None, :]
    ok = cols < kv_len
    if window is not None:
        ok = ok & (cols > kv_len - 1 - window)
    s = jnp.where(ok[None, None, None], s, _NEG)
    w = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    return jnp.einsum("bkgst,btkd->bskgd", w, v).reshape(B, S, H, hd)


def ssd_intra_chunk_ref(la, C, B_in, x):
    """la: (B,nc,Q,H); C/B_in: (B,nc,Q,N); x: (B,nc,Q,H,P).
    Returns (y_intra, states (B,nc,H,P,N), tot (B,nc,H))."""
    f32 = jnp.float32
    la, C, B_in, x = (t.astype(f32) for t in (la, C, B_in, x))
    Q = la.shape[2]
    L = jnp.cumsum(la, axis=2)
    CB = jnp.einsum("bcqn,bcsn->bcqs", C, B_in)
    seg = L[:, :, :, None, :] - L[:, :, None, :, :]          # (B,nc,Q,Q,H)
    tri = jnp.tril(jnp.ones((Q, Q), bool))[None, None, :, :, None]
    M = jnp.where(tri, jnp.exp(seg), 0.0) * CB[..., None]
    y = jnp.einsum("bcqsh,bcshp->bcqhp", M, x)
    tot = L[:, :, -1, :]
    w_end = jnp.exp(tot[:, :, None, :] - L)
    st = jnp.einsum("bcqh,bcqhp,bcqn->bchpn", w_end, x, B_in)
    return y, st, tot


def rmsnorm_ref(x, scale, *, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)
