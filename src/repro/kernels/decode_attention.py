"""Single-token decode attention (flash-decode style) as a Pallas TPU kernel.

One query row per (batch, head); the KV cache is streamed in BK-sized tiles
with online softmax; only the valid prefix (``kv_len``) contributes. The
``kv_len`` scalar rides in SMEM (runtime value, no retrace per step).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG = -2.0e38


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, window: Optional[int], BK: int, nk: int):
    ik = pl.program_id(1)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    kv_len = len_ref[0]
    q = q_ref[0].astype(jnp.float32)           # (1, hd)
    k = k_ref[0].astype(jnp.float32)           # (BK, hd)
    v = v_ref[0].astype(jnp.float32)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale                                   # (1, BK)
    cols = ik * BK + jax.lax.broadcasted_iota(jnp.int32, (1, BK), 1)
    ok = cols < kv_len
    if window is not None:
        ok &= cols > kv_len - 1 - window
    s = jnp.where(ok, s, _NEG)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = alpha * l_ref[...] + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = alpha * acc_ref[...] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _done():
        l = l_ref[...]
        o_ref[0] = (acc_ref[...] / jnp.where(l == 0.0, 1.0, l)).astype(o_ref.dtype)


def decode_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    kv_len,
    window: Optional[int] = None,
    block_k: int = 512,
    interpret: bool = True,
) -> jnp.ndarray:
    """q: (B,1,H,hd); k/v: (B,T,K,hd); kv_len: scalar int (# valid entries,
    including the token just written). Returns (B,1,H,hd)."""
    B, S, H, hd = q.shape
    assert S == 1, "decode kernel is single-token"
    T, K = k.shape[1], k.shape[2]
    G = H // K
    BK = min(block_k, T)
    if T % BK:
        raise ValueError(f"T={T} % {BK} != 0")
    nk = T // BK

    qh = q.transpose(0, 2, 1, 3).reshape(B * H, 1, hd)
    kh = k.transpose(0, 2, 1, 3).reshape(B * K, T, hd)
    vh = v.transpose(0, 2, 1, 3).reshape(B * K, T, hd)
    len_arr = jnp.asarray(kv_len, jnp.int32).reshape(1)

    kernel = functools.partial(
        _kernel, scale=hd ** -0.5, window=window, BK=BK, nk=nk
    )
    out = pl.pallas_call(
        kernel,
        grid=(B * H, nk),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, hd), lambda bh, ik: (bh, 0, 0)),
            pl.BlockSpec((1, BK, hd), lambda bh, ik, G=G, K=K, H=H:
                         ((bh // H) * K + (bh % H) // G, ik, 0)),
            pl.BlockSpec((1, BK, hd), lambda bh, ik, G=G, K=K, H=H:
                         ((bh // H) * K + (bh % H) // G, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, hd), lambda bh, ik: (bh, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, 1, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, hd), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(len_arr, qh, kh, vh)
    return out.reshape(B, H, 1, hd).transpose(0, 2, 1, 3)
