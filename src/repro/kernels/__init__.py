"""Pallas TPU kernels for the framework's compute hot spots.

The paper (storage provisioning) has no kernel-level contribution; these
kernels serve the training/serving stack built around it. See DESIGN.md §2.
"""
