"""Flash attention (causal / sliding-window, GQA) as a Pallas TPU kernel.

Tiling: grid = (B*H, S/BQ, T/BK) with the KV axis innermost ("arbitrary"
semantics); online-softmax state (m, l, acc) lives in VMEM scratch. Query
tiles are (BQ, hd) and KV tiles (BK, hd); hd and the tile sizes should be
multiples of 128 on real TPU (the MXU contraction dims), while interpret
mode (CPU validation) accepts any size.

GQA is handled in the index maps: query head h reads kv head h // (H/K).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG = -2.0e38


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, window: Optional[int],
            BQ: int, BK: int, nk: int):
    ik = pl.program_id(2)
    iq = pl.program_id(1)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)          # (BQ, hd)
    k = k_ref[0].astype(jnp.float32)          # (BK, hd)
    v = v_ref[0].astype(jnp.float32)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale                                  # (BQ, BK)

    rows = iq * BQ + jax.lax.broadcasted_iota(jnp.int32, (BQ, BK), 0)
    cols = ik * BK + jax.lax.broadcasted_iota(jnp.int32, (BQ, BK), 1)
    ok = jnp.ones((BQ, BK), dtype=bool)
    if causal:
        ok &= cols <= rows
    if window is not None:
        ok &= cols > rows - window
    s = jnp.where(ok, s, _NEG)

    m_prev = m_ref[...]                        # (BQ, 1)
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = alpha * l_ref[...] + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = alpha * acc_ref[...] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _done():
        l = l_ref[...]
        o_ref[0] = (acc_ref[...] / jnp.where(l == 0.0, 1.0, l)).astype(o_ref.dtype)


def flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    """q: (B,S,H,hd); k/v: (B,T,K,hd) -> (B,S,H,hd)."""
    B, S, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    BQ = min(block_q, S)
    BK = min(block_k, T)
    if S % BQ or T % BK:
        raise ValueError(f"S={S} % {BQ} or T={T} % {BK} != 0")
    nq, nk = S // BQ, T // BK

    qh = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kh = k.transpose(0, 2, 1, 3).reshape(B * K, T, hd)
    vh = v.transpose(0, 2, 1, 3).reshape(B * K, T, hd)

    kernel = functools.partial(
        _kernel, scale=hd ** -0.5, causal=causal, window=window,
        BQ=BQ, BK=BK, nk=nk,
    )
    out = pl.pallas_call(
        kernel,
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, BQ, hd), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, BK, hd), lambda bh, iq, ik, G=G, K=K, H=H:
                         ((bh // H) * K + (bh % H) // G, ik, 0)),
            pl.BlockSpec((1, BK, hd), lambda bh, iq, ik, G=G, K=K, H=H:
                         ((bh // H) * K + (bh % H) // G, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, BQ, hd), lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((BQ, 1), jnp.float32),
            pltpu.VMEM((BQ, 1), jnp.float32),
            pltpu.VMEM((BQ, hd), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(qh, kh, vh)
    return out.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
