"""jit'd public wrappers for the Pallas kernels.

On a real TPU backend the kernels compile natively; everywhere else they run
in interpret mode (Python evaluation of the kernel body — the validation mode
for this repo). ``REPRO_KERNEL_INTERPRET=0`` forces native lowering.
"""

from __future__ import annotations

import functools
import os
from typing import Optional

import jax
import jax.numpy as jnp

from . import decode_attention as _dec
from . import flash_attention as _fa
from . import rmsnorm as _rn
from . import ssd_scan as _ssd


def _interpret() -> bool:
    env = os.environ.get("REPRO_KERNEL_INTERPRET")
    if env is not None:
        return env not in ("0", "false")
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k"))
def flash_attention(q, k, v, *, causal: bool = True, window: Optional[int] = None,
                    block_q: int = 128, block_k: int = 128):
    return _fa.flash_attention(
        q, k, v, causal=causal, window=window,
        block_q=block_q, block_k=block_k, interpret=_interpret(),
    )


@functools.partial(jax.jit, static_argnames=("window", "block_k"))
def decode_attention(q, k, v, *, kv_len, window: Optional[int] = None,
                     block_k: int = 512):
    return _dec.decode_attention(
        q, k, v, kv_len=kv_len, window=window, block_k=block_k,
        interpret=_interpret(),
    )


@jax.jit
def ssd_intra_chunk(la, C, B_in, x):
    return _ssd.ssd_intra_chunk(la, C, B_in, x, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("eps", "block_rows"))
def rmsnorm(x, scale, *, eps: float = 1e-5, block_rows: int = 256):
    return _rn.rmsnorm(x, scale, eps=eps, block_rows=block_rows,
                       interpret=_interpret())
