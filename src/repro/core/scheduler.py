"""Batch scheduler with storage as an allocatable resource (paper §III-A/B).

The paper's key move: instead of the rigid SLURM Burst-Buffer plugin, the
re-purposed DataWarp nodes are exposed through a plain SLURM *constraint*
(``--constraint=storage``), so a job requests two allocations -- compute nodes
and storage nodes -- through the ordinary scheduler path.

This module reproduces that model and adds the paper's §V sizing trade-off as
a first-class request: a job may ask for storage by **node count**, by
**capacity** (bytes), or by **capability** (bandwidth); the scheduler resolves
capacity/capability to a node count using the deployment policy (how many
disks per node take the storage role).
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
import math
from typing import Optional

from ..obs.trace import NULL_RECORDER
from .resources import ClusterSpec, ComputeNode, StorageNode


class AllocationError(RuntimeError):
    pass


@dataclasses.dataclass(frozen=True, slots=True)
class StorageRequest:
    """Exactly one of ``nodes`` / ``capacity_bytes`` / ``capability_bw`` must
    be set (the paper's §V: users target either quantity of bytes or speed)."""

    nodes: Optional[int] = None
    capacity_bytes: Optional[float] = None
    capability_bw: Optional[float] = None      # aggregate write B/s target

    def __post_init__(self) -> None:
        n_set = sum(x is not None for x in (self.nodes, self.capacity_bytes, self.capability_bw))
        if n_set != 1:
            raise ValueError("set exactly one of nodes/capacity_bytes/capability_bw")
        if self.nodes is not None and self.nodes <= 0:
            raise ValueError(f"storage node count must be positive, got {self.nodes}")
        if self.capacity_bytes is not None and self.capacity_bytes <= 0:
            raise ValueError(f"capacity_bytes must be positive, got {self.capacity_bytes}")
        if self.capability_bw is not None and self.capability_bw <= 0:
            raise ValueError(f"capability_bw must be positive, got {self.capability_bw}")


@dataclasses.dataclass(frozen=True, slots=True)
class JobRequest:
    job_name: str
    n_compute: int
    storage: Optional[StorageRequest] = None
    constraint: str = "storage"

    def __post_init__(self) -> None:
        if self.n_compute < 0:
            raise ValueError(f"n_compute must be >= 0, got {self.n_compute}")


@dataclasses.dataclass(frozen=True, slots=True)
class Allocation:
    job_id: int
    job_name: str
    compute_nodes: tuple[ComputeNode, ...]
    storage_nodes: tuple[StorageNode, ...]


@dataclasses.dataclass(frozen=True)
class SizingPolicy:
    """How storage requests map to nodes. The paper's default layout is one
    metadata disk + two storage disks per DataWarp node (§IV-A)."""

    storage_disks_per_node: int = 2
    metadata_disks_per_node: int = 1

    def node_capacity_bytes(self, node: StorageNode) -> float:
        """Usable bytes one node contributes (its storage-role disks)."""
        return sum(
            d.spec.capacity_bytes for d in node.disks[: self.storage_disks_per_node]
        )

    def node_capability_bw(self, node: StorageNode) -> float:
        """Aggregate write bandwidth one node contributes (storage-role disks)."""
        return sum(
            d.spec.write_bw for d in node.disks[: self.storage_disks_per_node]
        )

    def nodes_for_capacity(self, node: StorageNode, capacity: float) -> int:
        return max(1, math.ceil(capacity / self.node_capacity_bytes(node)))

    def nodes_for_capability(self, node: StorageNode, bw: float) -> int:
        return max(1, math.ceil(bw / self.node_capability_bw(node)))


class Scheduler:
    """FIFO allocator over a static cluster inventory.

    Invariants (property-tested):
      * a node is never in two live allocations;
      * ``release`` returns every node of the allocation to the free pool;
      * storage nodes are only granted to requests carrying the storage
        constraint (the paper's access-control mechanism).

    The free pools are *indexed*: a min-heap of node ids carries exactly one
    entry per free node (grants pop, releases push), so handing out the
    lowest-id nodes is O(log M) per node and bit-for-bit the order of the
    old full-sort path. Two lazy-deletion heaps keyed by each node's static
    capacity / bandwidth contribution answer the weakest-free-node question
    that capacity- and bandwidth-sized requests resolve against, making
    ``resolve_storage_nodes`` / ``demand`` / ``can_allocate`` O(1) amortized
    instead of O(M) scans per admission. ``epoch`` counts grant/release
    batches; anything cached off the free pool upstream (queue-policy keys,
    negotiated offers) invalidates against it.
    """

    def __init__(self, cluster: ClusterSpec, policy: SizingPolicy | None = None):
        self.cluster = cluster
        self.policy = policy or SizingPolicy()
        self._free_compute = {n.node_id: n for n in cluster.compute_nodes}
        self._free_storage = {n.node_id: n for n in cluster.storage_nodes}
        self._live: dict[int, Allocation] = {}
        # reservation ledger: live allocation id -> projected release time,
        # reported by callers with a duration model (the orchestrator's
        # session costs). Feeds earliest_fit/projected_free_at — the EASY
        # backfill reservation questions. Purely advisory: never consulted
        # by grants or releases themselves.
        self._projected: dict[int, float] = {}
        self._next_id = itertools.count(1)
        #: bumped on every grant/release batch (cache-invalidation signal)
        self.epoch = 0
        #: observability sink for grant/release events (no-op by default;
        #: the recorder stamps virtual time itself — the scheduler is clockless)
        self.recorder = NULL_RECORDER
        # -- indexed ledger ---------------------------------------------------
        # a sorted list is a valid min-heap; one entry per free node
        self._compute_ids = sorted(self._free_compute)
        self._storage_ids = sorted(self._free_storage)
        # per-node contributions are static under the (frozen) sizing policy
        self._node_cap = {
            n.node_id: self.policy.node_capacity_bytes(n)
            for n in cluster.storage_nodes
        }
        self._node_bw = {
            n.node_id: self.policy.node_capability_bw(n)
            for n in cluster.storage_nodes
        }
        self._free_cap_heap = [(c, nid) for nid, c in self._node_cap.items()]
        self._free_bw_heap = [(b, nid) for nid, b in self._node_bw.items()]
        heapq.heapify(self._free_cap_heap)
        heapq.heapify(self._free_bw_heap)
        # -- failure domain (chaos engine) ------------------------------------
        # dead nodes are *parked*: a free node moves straight into
        # ``_down_storage``; a node inside a live allocation is flagged in
        # ``_down_pending`` and parked by ``release`` instead of freed. Both
        # dicts are empty in chaos-off campaigns, and ``release`` only takes
        # the slow path while ``_down_pending`` is non-empty — so the hot
        # path is one falsy check and replay stays bit-for-bit.
        self._down_storage: dict = {}
        self._down_pending: set = set()
        self._total_storage_cap = sum(self._node_cap.values())
        # weakest node over the whole inventory (the assume_empty candidates)
        if cluster.storage_nodes:
            self._empty_weakest_cap = min(
                cluster.storage_nodes, key=self.policy.node_capacity_bytes
            )
            self._empty_weakest_bw = min(
                cluster.storage_nodes, key=self.policy.node_capability_bw
            )
            self._empty_cap_min = min(self._node_cap.values())
            self._empty_bw_min = min(self._node_bw.values())
        # sizing with the stock SizingPolicy arithmetic is pure
        # ceil(request / weakest-contribution): resolve it from the cached
        # per-node values instead of re-summing disk specs per call.
        # Subclasses overriding the nodes_for_* hooks keep the node-object
        # path.
        self._stock_sizing = (
            type(self.policy).nodes_for_capacity is SizingPolicy.nodes_for_capacity
            and type(self.policy).nodes_for_capability is SizingPolicy.nodes_for_capability
        )

    def _weakest_free(self, heap: list) -> StorageNode:
        """Lazy-deletion min: drop stale heads (granted nodes, or duplicate
        entries left by earlier release/grant cycles of a now-busy node)."""
        free = self._free_storage
        while heap and heap[0][1] not in free:
            heapq.heappop(heap)
        assert heap, "weakest-free query on an empty free pool"
        return free[heap[0][1]]

    def _free_min(self, heap: list) -> float:
        """Weakest free node's cached contribution (value, not node)."""
        free = self._free_storage
        while heap and heap[0][1] not in free:
            heapq.heappop(heap)
        return heap[0][0]

    def free_min_capacity(self) -> Optional[float]:
        """Weakest free node's capacity contribution (None: free pool empty).
        With the whole-inventory min, this is the full sizing state: two
        capacity/bandwidth requests resolve identically whenever these are
        unchanged — what dispatchers key refusal caches on."""
        return self._free_min(self._free_cap_heap) if self._free_storage else None

    def free_min_bandwidth(self) -> Optional[float]:
        return self._free_min(self._free_bw_heap) if self._free_storage else None

    # -- introspection -------------------------------------------------------
    @property
    def live_allocations(self) -> tuple[Allocation, ...]:
        return tuple(self._live.values())

    def free_counts(self) -> tuple[int, int]:
        return len(self._free_compute), len(self._free_storage)

    # -- failure domain (chaos engine) ---------------------------------------
    @property
    def down_storage_nodes(self) -> frozenset:
        """Ids of storage nodes currently marked down (parked free nodes
        plus dead nodes still inside live allocations)."""
        return frozenset(self._down_storage) | frozenset(self._down_pending)

    @property
    def healthy_capacity_fraction(self) -> float:
        """Fraction of nominal storage capacity on healthy nodes — the
        availability gauge chaos campaigns chart. 1.0 with no storage."""
        total = self._total_storage_cap
        if not total:
            return 1.0
        down = sum(self._node_cap[nid] for nid in self._down_storage)
        down += sum(self._node_cap[nid] for nid in self._down_pending)
        return 1.0 - down / total

    def mark_node_down(self, node_id: str) -> bool:
        """Take a storage node out of service.

        A free node leaves the free pool immediately; a node held by a live
        allocation is flagged and parked when that allocation releases (the
        blast-radius handling upstream decides what happens to the holder).
        Returns True when the node was free. Idempotent for an already-down
        node; raises :class:`AllocationError` for unknown node ids.
        """
        if node_id in self._down_storage or node_id in self._down_pending:
            return node_id in self._down_storage
        if node_id not in self._node_cap:
            raise AllocationError(f"unknown storage node {node_id!r}")
        node = self._free_storage.pop(node_id, None)
        if node is not None:
            # node death is rare: the O(M) list fix-up is fine, and keeps
            # the one-entry-per-free-node id-heap invariant _grant pops by
            self._storage_ids.remove(node_id)
            heapq.heapify(self._storage_ids)
            self._down_storage[node_id] = node
            self.epoch += 1
            return True
        self._down_pending.add(node_id)
        self.epoch += 1
        return False

    def mark_node_up(self, node_id: str) -> bool:
        """Return a repaired storage node to service.

        A parked node rejoins the free pool; a dead-flagged node still held
        by a live allocation is simply unflagged (it frees normally on
        release). Returns True when the node rejoined the free pool now.
        Idempotent for a node that is not down.
        """
        node = self._down_storage.pop(node_id, None)
        if node is not None:
            self._free_storage[node_id] = node
            heapq.heappush(self._storage_ids, node_id)
            heapq.heappush(self._free_cap_heap, (self._node_cap[node_id], node_id))
            heapq.heappush(self._free_bw_heap, (self._node_bw[node_id], node_id))
            self.epoch += 1
            return True
        if node_id in self._down_pending:
            self._down_pending.discard(node_id)
            self.epoch += 1
        return False

    # -- sizing (paper §V trade-off) ----------------------------------------
    def resolve_storage_nodes(
        self, req: StorageRequest, *, assume_empty: bool = False
    ) -> int:
        """Resolve a capacity/capability request to a node count.

        Sizing is against the **minimum** per-node contribution across the
        candidate nodes, so any subset the allocator picks delivers at least
        the requested bytes/bandwidth — on heterogeneous storage nodes a
        single-prototype sizing (the old ``storage_nodes[0]``) over- or
        under-sizes whenever node 0 isn't the weakest.

        Candidates are the currently free storage nodes (what a grant would
        actually draw from); with ``assume_empty`` (the feasibility question
        "could this ever fit?") or an exhausted free pool, the whole
        inventory. Min over the free subset >= min over all nodes, so the
        empty-cluster count is the largest and feasibility stays conservative.
        """
        if not self.cluster.storage_nodes:
            raise AllocationError("cluster has no storage nodes")
        if req.nodes is not None:
            return req.nodes
        whole_inventory = assume_empty or not self._free_storage
        if req.capacity_bytes is not None:
            if self._stock_sizing:
                cap = (
                    self._empty_cap_min
                    if whole_inventory
                    else self._free_min(self._free_cap_heap)
                )
                return max(1, math.ceil(req.capacity_bytes / cap))
            weakest = (
                self._empty_weakest_cap
                if whole_inventory
                else self._weakest_free(self._free_cap_heap)
            )
            return self.policy.nodes_for_capacity(weakest, req.capacity_bytes)
        assert req.capability_bw is not None
        if self._stock_sizing:
            bw = (
                self._empty_bw_min
                if whole_inventory
                else self._free_min(self._free_bw_heap)
            )
            return max(1, math.ceil(req.capability_bw / bw))
        weakest = (
            self._empty_weakest_bw
            if whole_inventory
            else self._weakest_free(self._free_bw_heap)
        )
        return self.policy.nodes_for_capability(weakest, req.capability_bw)

    # -- feasibility (orchestrator queueing path) ----------------------------
    def demand(self, req: JobRequest, *, assume_empty: bool = False) -> tuple[int, int]:
        """Resolve a request to ``(n_compute, n_storage)`` node counts.

        Raises :class:`AllocationError` for requests that are malformed
        (storage without the storage constraint) -- these can never be
        granted, no matter how the cluster drains.
        """
        n_storage = 0
        if req.storage is not None:
            if req.constraint != "storage":
                raise AllocationError(
                    f"{req.job_name}: storage request without storage constraint"
                )
            n_storage = self.resolve_storage_nodes(req.storage, assume_empty=assume_empty)
        return req.n_compute, n_storage

    def feasible(self, req: JobRequest) -> bool:
        """Could this request ever be granted on an *empty* cluster?"""
        n_compute, n_storage = self.demand(req, assume_empty=True)
        return n_compute <= len(self.cluster.compute_nodes) and n_storage <= len(
            self.cluster.storage_nodes
        )

    def can_allocate(self, req: JobRequest) -> bool:
        """Does the request fit the free pool *right now*?"""
        n_compute, n_storage = self.demand(req)
        return n_compute <= len(self._free_compute) and n_storage <= len(
            self._free_storage
        )

    def try_submit(self, req: JobRequest) -> Optional[Allocation]:
        """Non-raising allocation path for queueing schedulers.

        Returns ``None`` when the cluster is merely *busy* (the request fits
        an empty cluster but not the current free pool) so callers can queue
        and retry; still raises :class:`AllocationError` for requests that
        can never be satisfied.

        Sizing is resolved exactly once per outcome: one empty-cluster
        resolution for the feasibility gate and one free-pool resolution that
        both the fit check and the grant reuse (the old path re-resolved in
        ``feasible``, ``can_allocate``, *and* ``submit``).
        """
        storage = req.storage
        n_compute = req.n_compute
        if storage is None:
            n_storage_empty = n_storage = 0
        else:
            if req.constraint != "storage":
                raise AllocationError(
                    f"{req.job_name}: storage request without storage constraint"
                )
            if storage.nodes is not None:
                n_storage_empty = n_storage = storage.nodes
            else:
                n_storage_empty = self.resolve_storage_nodes(storage, assume_empty=True)
                n_storage = -1          # resolved against the free pool below
        if n_compute > len(self.cluster.compute_nodes) or n_storage_empty > len(
            self.cluster.storage_nodes
        ):
            n_compute, n_storage = self.demand(req)
            raise AllocationError(
                f"{req.job_name}: wants {n_compute} compute / {n_storage} storage "
                "nodes but the cluster only has "
                f"{len(self.cluster.compute_nodes)} / {len(self.cluster.storage_nodes)}"
            )
        if n_storage < 0:
            n_storage = self.resolve_storage_nodes(storage)
        if n_compute > len(self._free_compute) or n_storage > len(self._free_storage):
            return None
        return self._grant(req, n_storage)

    # -- reservation ledger (EASY backfill substrate) ------------------------
    def note_projected_release(self, alloc: Allocation, t: Optional[float]) -> None:
        """Record when ``alloc`` is expected to release (from the caller's
        duration model). Overwrites any earlier projection; dropped
        automatically on :meth:`release`. ``t=None`` clears the projection:
        open-ended allocations (pilots accepting late task submissions)
        promise no release, so EASY proofs must not book holes against
        them, same as persistent pools. No-op for unknown allocations."""
        if alloc.job_id in self._live:
            if t is None:
                self._projected.pop(alloc.job_id, None)
            else:
                self._projected[alloc.job_id] = t

    def projected_release_of(self, alloc: Allocation) -> Optional[float]:
        return self._projected.get(alloc.job_id)

    def projected_free_at(self, t: float) -> tuple[int, int]:
        """(compute, storage) node counts of live allocations projected to
        have released by ``t``. Allocations with no projection (persistent
        pools above all) contribute nothing — they may never release."""
        dc = ds = 0
        for jid, tr in self._projected.items():
            if tr <= t:
                a = self._live[jid]
                dc += len(a.compute_nodes)
                ds += len(a.storage_nodes)
        return dc, ds

    def earliest_fit(
        self, n_compute: int, n_storage: int, now: float
    ) -> Optional[float]:
        """Earliest instant the demand could fit: the current free pool plus
        live allocations returned in projected-release order. ``None`` when
        the demand cannot fit even after every *projected* release — some
        needed nodes are held by allocations with no release projection, so
        no start time can be promised."""
        fc, fs = len(self._free_compute), len(self._free_storage)
        if fc >= n_compute and fs >= n_storage:
            return now
        for jid, t in sorted(self._projected.items(), key=lambda kv: (kv[1], kv[0])):
            a = self._live[jid]
            fc += len(a.compute_nodes)
            fs += len(a.storage_nodes)
            if fc >= n_compute and fs >= n_storage:
                return max(t, now)
        return None

    # -- allocation ----------------------------------------------------------
    def submit(self, req: JobRequest) -> Allocation:
        if req.n_compute > len(self._free_compute):
            raise AllocationError(
                f"{req.job_name}: wants {req.n_compute} compute nodes, "
                f"{len(self._free_compute)} free"
            )
        n_storage = 0
        if req.storage is not None:
            if req.constraint != "storage":
                raise AllocationError(
                    f"{req.job_name}: storage request without storage constraint"
                )
            n_storage = self.resolve_storage_nodes(req.storage)
            if n_storage > len(self._free_storage):
                raise AllocationError(
                    f"{req.job_name}: wants {n_storage} storage nodes, "
                    f"{len(self._free_storage)} free"
                )
        return self._grant(req, n_storage)

    def _grant(self, req: JobRequest, n_storage: int) -> Allocation:
        """Pop the lowest-id free nodes — the indexed equivalent of the old
        ``sorted(free)[:k]`` scan — and register the allocation."""
        pop = heapq.heappop
        compute = [
            self._free_compute.pop(pop(self._compute_ids))
            for _ in range(req.n_compute)
        ]
        storage = [
            self._free_storage.pop(pop(self._storage_ids))
            for _ in range(n_storage)
        ]
        alloc = Allocation(next(self._next_id), req.job_name, tuple(compute), tuple(storage))
        self._live[alloc.job_id] = alloc
        self.epoch += 1
        rec = self.recorder
        if rec.enabled:
            rec.sched_grant(alloc)
        return alloc

    def release(self, alloc: Allocation) -> None:
        if alloc.job_id not in self._live:
            raise AllocationError(f"allocation {alloc.job_id} is not live")
        del self._live[alloc.job_id]
        self._projected.pop(alloc.job_id, None)
        for n in alloc.compute_nodes:
            self._free_compute[n.node_id] = n
            heapq.heappush(self._compute_ids, n.node_id)
        pending = self._down_pending
        for n in alloc.storage_nodes:
            nid = n.node_id
            if pending and nid in pending:
                # died while allocated: park instead of freeing
                pending.discard(nid)
                self._down_storage[nid] = n
                continue
            self._free_storage[nid] = n
            heapq.heappush(self._storage_ids, nid)
            heapq.heappush(self._free_cap_heap, (self._node_cap[nid], nid))
            heapq.heappush(self._free_bw_heap, (self._node_bw[nid], nid))
        self.epoch += 1
        rec = self.recorder
        if rec.enabled:
            rec.sched_release(alloc)


def size_for_checkpoint(
    state_bytes: float,
    stall_budget_s: float,
    cluster: ClusterSpec,
    policy: SizingPolicy | None = None,
) -> StorageRequest:
    """Beyond-paper helper: derive a capability request from a training job's
    checkpoint size and the stall the job will tolerate per checkpoint.

    ``bw >= state_bytes / stall_budget`` -- the scheduler then converts the
    bandwidth target into a storage-node count via the sizing policy.
    """
    if stall_budget_s <= 0:
        raise ValueError("stall budget must be positive")
    return StorageRequest(capability_bw=state_bytes / stall_budget_s)
