"""Provisioner: turns a storage allocation into a running data manager
(paper §III-C: container started with Shifter on each storage node; an
entry-point script renders per-service config files and starts daemons).

The functional deployment instantiates :class:`EphemeralFS`; the deployment
*time* is modeled (C8: 5.37 s over 2 DataWarp nodes on Dom; 4.6 s fresh /
1.2 s warm over 8 local disks on Ault).
"""

from __future__ import annotations

import dataclasses
import os
import tempfile
import time
from typing import Literal, Optional

from .client import FSClient
from .datamanager import FSError
from .ephemeralfs import EphemeralFS
from .perfmodel import FSDeployment, predict_deploy_time
from .resources import ClusterSpec, StorageNode
from .scheduler import Allocation, SizingPolicy
from .striping import DEFAULT_STRIPE


@dataclasses.dataclass(frozen=True)
class DeploymentPlan:
    """Rendered 'container + entrypoint config' for one job's storage."""

    storage_nodes: tuple[StorageNode, ...]
    md_disks_per_node: int = 1
    storage_disks_per_node: int = 2
    stripe_size: int = DEFAULT_STRIPE
    mirror: bool = False
    runtime: Literal["shifter", "docker"] = "shifter"
    image: str = "cscs/beegfs-ondemand:7.1"

    @property
    def targets_per_node(self) -> int:
        return self.md_disks_per_node + self.storage_disks_per_node

    @property
    def n_storage_targets(self) -> int:
        return self.storage_disks_per_node * len(self.storage_nodes)

    def render_service_config(self) -> dict:
        """The paper's entrypoint python script writes beegfs-{mgmtd,meta,
        storage,mon}.conf per node; we render the equivalent dict."""
        mgmt_node = self.storage_nodes[0].node_id
        cfg: dict = {
            "mgmtd": {"node": mgmt_node, "port": 8008},
            "mon": {"node": mgmt_node, "port": 8009},
            "meta": [],
            "storage": [],
        }
        for node in self.storage_nodes:
            for d in range(self.md_disks_per_node):
                cfg["meta"].append(
                    {
                        "node": node.node_id,
                        "store": f"/mnt/nvme{d}n1/meta",
                        "mgmtd": mgmt_node,
                        "xattr": True,
                    }
                )
            for d in range(self.md_disks_per_node, self.targets_per_node):
                cfg["storage"].append(
                    {
                        "node": node.node_id,
                        "store": f"/mnt/nvme{d}n1/storage",
                        "mgmtd": mgmt_node,
                    }
                )
        return cfg


@dataclasses.dataclass
class Deployment:
    """A live, job-scoped data manager."""

    plan: DeploymentPlan
    fs: EphemeralFS
    model: FSDeployment              # analytic view for the perfmodel
    deploy_time_s: float             # modeled (C8)
    wallclock_deploy_s: float        # actual in-container time (functional)
    base_dir: str
    provisioner: Optional["Provisioner"] = None   # owner of the tree registry

    def mount(self, client_id: str = "client0") -> FSClient:
        return FSClient(self.fs, client_id)

    def teardown(self) -> None:
        """Kill services and delete the tree; the base_dir becomes claimable
        (and cold) again."""
        self.fs.teardown()
        if self.provisioner is not None:
            self.provisioner.release_tree(self.base_dir)

    def release(self, *, keep_tree: bool = False) -> None:
        """Stop the data manager; with ``keep_tree`` the on-disk tree stays,
        so the next deploy into the same base_dir takes the warm (§IV-B1
        1.2 s) path instead of the fresh one."""
        self.fs.teardown(keep_data=keep_tree)
        if self.provisioner is not None:
            self.provisioner.release_tree(self.base_dir)


class Provisioner:
    """Deploys a data manager on the storage nodes of an allocation."""

    def __init__(self, cluster: ClusterSpec, policy: SizingPolicy | None = None):
        self.cluster = cluster
        self.policy = policy or SizingPolicy()
        # warm-tree cache: base dirs we have deployed into before (paper
        # §IV-B1: re-deploying over an existing tree takes 1.2 s vs 4.6 s).
        self._seen_trees: set[str] = set()
        # collision guard: base dirs currently owned by a live deployment or
        # pool. Two live sessions must never share a tree (they would
        # silently serve each other's data as a "warm" cache).
        self._live_dirs: dict[str, str] = {}
        # analytic models are pure functions of a plan's shape; campaigns
        # re-plan the same shapes thousands of times, so canonicalize
        self._model_cache: dict[tuple, FSDeployment] = {}

    # -- base_dir ownership ---------------------------------------------------
    def claim_tree(self, base_dir: str, owner: str = "deployment") -> None:
        """Register ``base_dir`` as owned by a live deployment/pool; raises
        :class:`FSError` on collision instead of silently sharing the tree."""
        holder = self._live_dirs.get(base_dir)
        if holder is not None:
            raise FSError(
                f"base_dir {base_dir!r} is already in use by live "
                f"deployment {holder!r}; release it before redeploying"
            )
        self._live_dirs[base_dir] = owner

    def release_tree(self, base_dir: str) -> None:
        """Drop live ownership of ``base_dir`` (teardown/retire path)."""
        self._live_dirs.pop(base_dir, None)

    def tree_owner(self, base_dir: str) -> Optional[str]:
        return self._live_dirs.get(base_dir)

    def plan_for(
        self,
        alloc: Allocation,
        *,
        mirror: bool = False,
        stripe_size: int = DEFAULT_STRIPE,
        md_disks_per_node: Optional[int] = None,
        storage_disks_per_node: Optional[int] = None,
        runtime: Literal["shifter", "docker"] = "shifter",
    ) -> DeploymentPlan:
        if not alloc.storage_nodes:
            raise FSError("allocation has no storage nodes")
        return self.plan_for_nodes(
            alloc.storage_nodes,
            mirror=mirror,
            stripe_size=stripe_size,
            md_disks_per_node=md_disks_per_node,
            storage_disks_per_node=storage_disks_per_node,
            runtime=runtime,
        )

    def plan_for_nodes(
        self,
        storage_nodes: tuple[StorageNode, ...],
        *,
        mirror: bool = False,
        stripe_size: int = DEFAULT_STRIPE,
        md_disks_per_node: Optional[int] = None,
        storage_disks_per_node: Optional[int] = None,
        runtime: Literal["shifter", "docker"] = "shifter",
    ) -> DeploymentPlan:
        """Plan a deployment over an explicit node set (no Allocation needed).

        The persistent-pool subsystem plans its long-lived file systems this
        way: the pool holds the nodes through its own scheduler allocation
        and re-plans (warm) deployments over the same set across leases.
        """
        if not storage_nodes:
            raise FSError("no storage nodes to plan over")
        return DeploymentPlan(
            storage_nodes=tuple(storage_nodes),
            md_disks_per_node=(
                md_disks_per_node
                if md_disks_per_node is not None
                else self.policy.metadata_disks_per_node
            ),
            storage_disks_per_node=(
                storage_disks_per_node
                if storage_disks_per_node is not None
                else self.policy.storage_disks_per_node
            ),
            stripe_size=stripe_size,
            mirror=mirror,
            runtime=runtime,
        )

    def is_warm(self, base_dir: str) -> bool:
        """Would a deploy into ``base_dir`` take the warm (1.2 s) path?"""
        return base_dir in self._seen_trees and os.path.isdir(base_dir)

    def forget_tree(self, base_dir: str) -> None:
        """Drop a tree from the warm cache (pool retirement / eviction of a
        pool-resident tree): the next deploy over it pays the fresh cost."""
        self._seen_trees.discard(base_dir)

    def model_for(self, plan: DeploymentPlan) -> FSDeployment:
        """The analytic (perfmodel) view of a plan -- no disk I/O.

        Used by the workflow orchestrator's event-driven engine, which runs
        whole provisioning campaigns against modeled time only. Models are
        canonicalized (one shared frozen instance per plan shape), so
        same-shape deployments across a campaign hit one cache entry.
        """
        node0 = plan.storage_nodes[0]
        key = (
            len(plan.storage_nodes),
            plan.n_storage_targets,
            plan.md_disks_per_node,
            node0.disks[plan.md_disks_per_node].spec,
            node0.dram_bytes,
        )
        cached = self._model_cache.get(key)
        if cached is not None:
            return cached
        self._model_cache[key] = model = FSDeployment(
            kind="ephemeral",
            n_nodes=len(plan.storage_nodes),
            storage_targets=plan.n_storage_targets,
            md_targets=plan.md_disks_per_node * len(plan.storage_nodes),
            disk=node0.disks[plan.md_disks_per_node].spec,
            node_dram=node0.dram_bytes,
            net=self.cluster.interconnect,
            local_client=self.cluster.name == "ault",
        )
        return model

    def deploy(self, plan: DeploymentPlan, base_dir: Optional[str] = None) -> Deployment:
        base_dir = base_dir or tempfile.mkdtemp(prefix="efs-")
        self.claim_tree(base_dir)
        fresh = base_dir not in self._seen_trees or not os.path.isdir(base_dir)
        t0 = time.perf_counter()
        try:
            plan.render_service_config()      # the entrypoint work
            fs = EphemeralFS(
                plan.storage_nodes,
                base_dir,
                md_disks_per_node=plan.md_disks_per_node,
                storage_disks_per_node=plan.storage_disks_per_node,
                stripe_size=plan.stripe_size,
                mirror=plan.mirror,
            )
        except Exception:
            # a failed deploy never produced a Deployment whose teardown
            # could release the claim — drop it here or the dir is
            # undeployable forever
            self.release_tree(base_dir)
            raise
        wall = time.perf_counter() - t0
        self._seen_trees.add(base_dir)
        model = self.model_for(plan)
        t_model = predict_deploy_time(
            plan.targets_per_node, runtime=plan.runtime, fresh=fresh
        )
        return Deployment(
            plan=plan,
            fs=fs,
            model=model,
            deploy_time_s=t_model,
            wallclock_deploy_s=wall,
            base_dir=base_dir,
            provisioner=self,
        )
