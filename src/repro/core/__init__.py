"""Core: the paper's contribution — dynamically provisioned, job-scoped
data managers on schedulable storage resources (Tessier et al., 2019)."""

from .client import FSClient
from .datamanager import DataManager, FSError, FileStat, ServiceInfo
from .ephemeralfs import CacheSim, EphemeralFS
from .globalfs import GlobalFS
from .kvstore import EphemeralKV
from .perfmodel import (
    BWResult,
    FSDeployment,
    TPU_V5E,
    TPUProfile,
    Workload,
    ault_efs,
    dom_efs,
    dom_lustre,
    hacc_workload,
    predict,
    predict_deploy_time,
    predict_mdtest,
    predict_read,
    predict_write,
)
from .provisioner import Deployment, DeploymentPlan, Provisioner
from .resources import (
    ClusterSpec,
    ComputeNode,
    Disk,
    DiskSpec,
    InterconnectSpec,
    StorageNode,
    ault_cluster,
    dom_cluster,
    synthetic_cluster,
    tpu_pod_cluster,
)
from .scheduler import (
    Allocation,
    AllocationError,
    JobRequest,
    Scheduler,
    SizingPolicy,
    StorageRequest,
    size_for_checkpoint,
)
from .staging import StageReport, modeled_stage_time, stage, stage_tree
from .striping import Extent, StripeConfig, bytes_per_target, extents_for_range

__all__ = [
    "FSClient", "DataManager", "FSError", "FileStat", "ServiceInfo",
    "CacheSim", "EphemeralFS", "EphemeralKV", "GlobalFS",
    "BWResult", "FSDeployment", "TPU_V5E", "TPUProfile", "Workload",
    "ault_efs", "dom_efs", "dom_lustre", "hacc_workload",
    "predict", "predict_deploy_time", "predict_mdtest", "predict_read", "predict_write",
    "Deployment", "DeploymentPlan", "Provisioner",
    "ClusterSpec", "ComputeNode", "Disk", "DiskSpec", "InterconnectSpec",
    "StorageNode", "ault_cluster", "dom_cluster", "synthetic_cluster",
    "tpu_pod_cluster",
    "Allocation", "AllocationError", "JobRequest", "Scheduler", "SizingPolicy",
    "StorageRequest", "size_for_checkpoint",
    "StageReport", "modeled_stage_time", "stage", "stage_tree",
    "Extent", "StripeConfig", "bytes_per_target", "extents_for_range",
]
