"""EphemeralFS: the BeeGFS-analogue deployed on demand over storage nodes.

Functionally faithful to the paper's BeeGFS deployment (§III-C):

* four service roles -- **management** (orchestration/registry), **metadata**
  (namespace, striping info; one per metadata disk, namespace spread by
  parent-directory hash, like BeeGFS dirent distribution), **storage** (one
  per storage disk, owns raw chunks), **monitor** (counter aggregation);
* round-robin 1 MiB striping across all storage targets;
* job-scoped: ``teardown()`` kills services and deletes every byte
  (the paper: "services on storage nodes are killed and data on disks is
  deleted");
* optional chunk mirroring (beyond-paper: survives a storage-node loss).

This layer moves *real bytes* (chunk files under a backing directory per
disk) so correctness is testable end-to-end; timing at paper scale is the
job of ``perfmodel``. A per-node ``CacheSim`` reproduces the server-side
DRAM cache *mechanism* behind the paper's read-collapse observation (C2).
"""

from __future__ import annotations

import dataclasses
import hashlib
import os
import shutil
from collections import OrderedDict
from typing import Optional

from .datamanager import (
    DataManager,
    FSError,
    FileStat,
    ServiceInfo,
    normpath,
    parent_of,
)
from .resources import Disk, StorageNode
from .striping import DEFAULT_STRIPE, StripeConfig, extents_for_range


class CacheSim:
    """Per-node server-side DRAM cache (LRU over chunk keys).

    Models the mechanism behind the paper's Fig. 2 read collapse: once the
    per-node working set exceeds node DRAM (64 GB on Dom), reads fall off the
    cache to disk. Tracks hits/misses/evictions; capacity is bytes.
    """

    def __init__(self, capacity_bytes: float):
        self.capacity = float(capacity_bytes)
        self._lru: OrderedDict[str, int] = OrderedDict()
        self.resident = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def touch(self, key: str, nbytes: int, *, is_read: bool) -> bool:
        """Record an access; returns True on hit (for reads)."""
        hit = key in self._lru
        if hit:
            self._lru.move_to_end(key)
            if is_read:
                self.hits += 1
        else:
            if is_read:
                self.misses += 1
            self._lru[key] = nbytes
            self.resident += nbytes
            while self.resident > self.capacity and self._lru:
                _, evicted = self._lru.popitem(last=False)
                self.resident -= evicted
                self.evictions += 1
        return hit

    def hit_rate(self) -> float:
        tot = self.hits + self.misses
        return self.hits / tot if tot else 0.0


@dataclasses.dataclass
class Inode:
    path: str
    is_dir: bool
    size: int = 0
    file_id: int = 0
    stripe: Optional[StripeConfig] = None
    xattrs: dict = dataclasses.field(default_factory=dict)


class MetadataService:
    """Owns a shard of the namespace. BeeGFS spreads directory entries over
    metadata servers; we shard by parent-directory hash."""

    def __init__(self, service_id: int, node_id: str, disk: Disk):
        self.service_id = service_id
        self.node_id = node_id
        self.disk = disk
        self.alive = True
        self.inodes: dict[str, Inode] = {}
        self.children: dict[str, set[str]] = {}
        self.ops: dict[str, int] = {}

    def _count(self, op: str) -> None:
        self.ops[op] = self.ops.get(op, 0) + 1

    def _check(self) -> None:
        if not self.alive:
            raise FSError(f"metadata service {self.service_id} is down")

    def insert(self, inode: Inode) -> None:
        self._check()
        self._count("create")
        if inode.path in self.inodes:
            raise FSError(f"exists: {inode.path}")
        self.inodes[inode.path] = inode
        self.children.setdefault(inode.path, set()) if inode.is_dir else None

    def register_child(self, parent: str, name: str) -> None:
        self._check()
        self.children.setdefault(parent, set()).add(name)

    def drop_child(self, parent: str, name: str) -> None:
        self._check()
        self.children.get(parent, set()).discard(name)

    def lookup(self, path: str) -> Inode:
        self._check()
        self._count("stat")
        ino = self.inodes.get(path)
        if ino is None:
            raise FSError(f"no such file: {path}")
        return ino

    def remove(self, path: str) -> Inode:
        self._check()
        self._count("remove")
        ino = self.inodes.pop(path, None)
        if ino is None:
            raise FSError(f"no such file: {path}")
        self.children.pop(path, None)
        return ino

    def listdir(self, path: str) -> list[str]:
        self._check()
        self._count("readdir")
        return sorted(self.children.get(path, set()))


class StorageService:
    """Owns one storage target (= one disk). Chunks are real files under
    ``target_dir``; a shared per-node CacheSim accounts DRAM residency."""

    def __init__(
        self,
        service_id: int,
        node_id: str,
        disk: Disk,
        target_dir: str,
        cache: CacheSim,
    ):
        self.service_id = service_id
        self.node_id = node_id
        self.disk = disk
        self.target_dir = target_dir
        self.cache = cache
        self.alive = True
        self.bytes_written = 0
        self.bytes_read = 0
        self.chunks = 0
        os.makedirs(target_dir, exist_ok=True)

    def _chunk_path(self, file_id: int, chunk_id: int) -> str:
        return os.path.join(self.target_dir, f"{file_id:08x}.{chunk_id:08d}")

    def _check(self) -> None:
        if not self.alive:
            raise FSError(f"storage service {self.service_id} is down")

    def write_chunk(self, file_id: int, chunk_id: int, offset: int, data: bytes) -> None:
        self._check()
        p = self._chunk_path(file_id, chunk_id)
        new = not os.path.exists(p)
        mode = "r+b" if not new else "wb"
        with open(p, mode) as f:
            f.seek(offset)
            f.write(data)
        if new:
            self.chunks += 1
        self.bytes_written += len(data)
        self.cache.touch(f"{self.service_id}:{file_id}:{chunk_id}", len(data), is_read=False)

    def read_chunk(self, file_id: int, chunk_id: int, offset: int, length: int) -> bytes:
        self._check()
        self.cache.touch(f"{self.service_id}:{file_id}:{chunk_id}", length, is_read=True)
        p = self._chunk_path(file_id, chunk_id)
        if not os.path.exists(p):
            return b"\x00" * length            # sparse region
        with open(p, "rb") as f:
            f.seek(offset)
            buf = f.read(length)
        self.bytes_read += len(buf)
        if len(buf) < length:                   # short chunk file -> zero fill
            buf += b"\x00" * (length - len(buf))
        return buf

    def drop_file(self, file_id: int) -> None:
        if not self.alive:
            return
        prefix = f"{file_id:08x}."
        for name in os.listdir(self.target_dir):
            if name.startswith(prefix):
                os.unlink(os.path.join(self.target_dir, name))
                self.chunks -= 1


class ManagementService:
    """BeeGFS management daemon analogue: service registry + heartbeats."""

    def __init__(self, node_id: str, disk: Disk):
        self.node_id = node_id
        self.disk = disk
        self.alive = True
        self.registry: list[ServiceInfo] = []

    def register(self, info: ServiceInfo) -> None:
        self.registry.append(info)


class MonitorService:
    def __init__(self, node_id: str, disk: Disk):
        self.node_id = node_id
        self.disk = disk
        self.alive = True

    def collect(self, fs: "EphemeralFS") -> dict:
        return {
            "md_ops": {s.service_id: dict(s.ops) for s in fs.md_services},
            "storage": {
                s.service_id: {
                    "bytes_written": s.bytes_written,
                    "bytes_read": s.bytes_read,
                    "chunks": s.chunks,
                }
                for s in fs.storage_services
            },
            "cache": {
                nid: {
                    "resident": c.resident,
                    "hit_rate": c.hit_rate(),
                    "evictions": c.evictions,
                }
                for nid, c in fs.caches.items()
            },
        }


def _md_shard(path: str, n: int) -> int:
    parent = parent_of(path)
    return int.from_bytes(hashlib.blake2s(parent.encode()).digest()[:4], "little") % n


class EphemeralFS(DataManager):
    """The dynamically-provisioned, job-scoped parallel FS (paper §III)."""

    def __init__(
        self,
        storage_nodes: tuple[StorageNode, ...],
        base_dir: str,
        *,
        md_disks_per_node: int = 1,
        storage_disks_per_node: int = 2,
        stripe_size: int = DEFAULT_STRIPE,
        mirror: bool = False,
        cache_capacity_override: Optional[float] = None,
    ):
        if not storage_nodes:
            raise FSError("need at least one storage node")
        self.storage_nodes = storage_nodes
        self.base_dir = base_dir
        self.stripe_size = stripe_size
        self.mirror = mirror
        self.md_disks_per_node = md_disks_per_node
        self.storage_disks_per_node = storage_disks_per_node
        self._torn_down = False
        self._next_file_id = 1
        self._degraded_targets: set[int] = set()

        self.caches: dict[str, CacheSim] = {}
        self.md_services: list[MetadataService] = []
        self.storage_services: list[StorageService] = []

        # Paper layout (§IV-A): per node, disk 0 -> metadata; next
        # ``storage_disks_per_node`` disks -> storage. mgmt + monitor share
        # the first node's metadata disk.
        for ni, node in enumerate(storage_nodes):
            need = md_disks_per_node + storage_disks_per_node
            if node.n_disks < need:
                raise FSError(
                    f"{node.node_id}: {node.n_disks} disks < {need} required by layout"
                )
            cap = cache_capacity_override if cache_capacity_override is not None else node.dram_bytes
            self.caches[node.node_id] = CacheSim(cap)
            for d in range(md_disks_per_node):
                disk = node.disks[d]
                self.md_services.append(MetadataService(len(self.md_services), node.node_id, disk))
            for d in range(md_disks_per_node, need):
                disk = node.disks[d]
                tdir = os.path.join(base_dir, node.node_id, f"nvme{d}")
                self.storage_services.append(
                    StorageService(
                        len(self.storage_services),
                        node.node_id,
                        disk,
                        tdir,
                        self.caches[node.node_id],
                    )
                )

        first = storage_nodes[0]
        self.mgmt = ManagementService(first.node_id, first.disks[0])
        self.monitor = MonitorService(first.node_id, first.disks[0])
        for s in self.md_services:
            self.mgmt.register(ServiceInfo("metadata", s.node_id, s.disk.name))
        for s in self.storage_services:
            self.mgmt.register(ServiceInfo("storage", s.node_id, s.disk.name))
        self.mgmt.register(ServiceInfo("management", self.mgmt.node_id, self.mgmt.disk.name))
        self.mgmt.register(ServiceInfo("monitor", self.monitor.node_id, self.monitor.disk.name))

        if mirror and len(self.storage_services) < 2:
            raise FSError("mirror mode needs >= 2 storage targets")

        # root directory lives on shard of "/" (replicated in mirror mode)
        root = Inode("/", is_dir=True)
        for svc in self._md_writers("/"):
            svc.insert(root)

    # -- routing ---------------------------------------------------------
    @property
    def n_targets(self) -> int:
        return len(self.storage_services)

    def _md_for(self, path: str) -> MetadataService:
        """Service to READ path metadata from. Mirror mode replicates the
        namespace (shared Inode objects) so any alive service works."""
        svc = self.md_services[_md_shard(path, len(self.md_services))]
        if not svc.alive and self.mirror:
            for s in self.md_services:
                if s.alive:
                    return s
        return svc

    def _md_writers(self, path: str) -> list[MetadataService]:
        """Services to apply a namespace MUTATION to."""
        if self.mirror:
            out = [s for s in self.md_services if s.alive]
            if not out:
                raise FSError("all metadata services are down")
            return out
        return [self.md_services[_md_shard(path, len(self.md_services))]]

    def _check_live(self) -> None:
        if self._torn_down:
            raise FSError("filesystem has been torn down")

    def _mirror_of(self, target: int) -> int:
        """Next target on a DIFFERENT node (chunk replicas must not share a
        failure domain); falls back to next target on single-node deploys."""
        n = self.n_targets
        nid = self.storage_services[target].node_id
        for step in range(1, n):
            cand = (target + step) % n
            if self.storage_services[cand].node_id != nid:
                return cand
        return (target + 1) % n

    # -- DataManager: lifecycle -------------------------------------------
    def services(self) -> list[ServiceInfo]:
        infos = list(self.mgmt.registry)
        for info in infos:
            if info.kind == "metadata":
                svc = next(s for s in self.md_services if s.disk.name == info.disk_name)
                info.alive = svc.alive
            elif info.kind == "storage":
                svc = next(s for s in self.storage_services if s.disk.name == info.disk_name)
                info.alive = svc.alive
        return infos

    def teardown(self, *, keep_data: bool = False) -> None:
        """Kill all services; delete every byte unless ``keep_data`` (the
        warm-redeploy scenario: services stop but the tree survives, so the
        next deploy over the same base_dir pays the §IV-B1 warm cost)."""
        self._torn_down = True
        for s in self.md_services:
            s.alive = False
            s.inodes.clear()
            s.children.clear()
        for s in self.storage_services:
            s.alive = False
        self.mgmt.alive = False
        self.monitor.alive = False
        if not keep_data:
            shutil.rmtree(self.base_dir, ignore_errors=True)

    # -- DataManager: namespace --------------------------------------------
    def _require_parent(self, path: str) -> None:
        parent = parent_of(path)
        ino = self._md_for(parent).inodes.get(parent)
        if ino is None or not ino.is_dir:
            raise FSError(f"parent directory missing: {parent}")

    def create(self, path: str) -> None:
        self._check_live()
        path = normpath(path)
        self._require_parent(path)
        fid = self._next_file_id
        self._next_file_id += 1
        stripe = StripeConfig(self.stripe_size, self.n_targets, shift=fid % self.n_targets)
        ino = Inode(path, is_dir=False, file_id=fid, stripe=stripe)
        for svc in self._md_writers(path):
            svc.insert(ino)             # shared object: replicas stay in sync
        for svc in self._md_writers(parent_of(path)):
            svc.register_child(parent_of(path), path.rsplit("/", 1)[1])

    def mkdir(self, path: str) -> None:
        self._check_live()
        path = normpath(path)
        self._require_parent(path)
        ino = Inode(path, is_dir=True)
        for svc in self._md_writers(path):
            svc.insert(ino)
        for svc in self._md_writers(parent_of(path)):
            svc.register_child(parent_of(path), path.rsplit("/", 1)[1])

    def stat(self, path: str) -> FileStat:
        self._check_live()
        path = normpath(path)
        ino = self._md_for(path).lookup(path)
        return FileStat(
            path=path,
            size=ino.size,
            is_dir=ino.is_dir,
            stripe_size=self.stripe_size,
            n_targets=self.n_targets,
        )

    def readdir(self, path: str) -> list[str]:
        self._check_live()
        path = normpath(path)
        ino = self._md_for(path).lookup(path)
        if not ino.is_dir:
            raise FSError(f"not a directory: {path}")
        return self._md_for(path).listdir(path)

    def unlink(self, path: str) -> None:
        self._check_live()
        path = normpath(path)
        ino = self._md_for(path).lookup(path)
        if ino.is_dir:
            raise FSError(f"is a directory: {path}")
        for svc in self._md_writers(path):
            svc.remove(path)
        for svc in self._md_writers(parent_of(path)):
            svc.drop_child(parent_of(path), path.rsplit("/", 1)[1])
        for s in self.storage_services:
            s.drop_file(ino.file_id)

    def rmdir(self, path: str) -> None:
        self._check_live()
        path = normpath(path)
        if path == "/":
            raise FSError("cannot remove root")
        ino = self._md_for(path).lookup(path)
        if not ino.is_dir:
            raise FSError(f"not a directory: {path}")
        if self._md_for(path).listdir(path):
            raise FSError(f"directory not empty: {path}")
        for svc in self._md_writers(path):
            svc.remove(path)
        for svc in self._md_writers(parent_of(path)):
            svc.drop_child(parent_of(path), path.rsplit("/", 1)[1])

    # -- DataManager: data ----------------------------------------------------
    def write(self, path: str, offset: int, data: bytes) -> int:
        self._check_live()
        path = normpath(path)
        md = self._md_for(path)
        ino = md.lookup(path)
        if ino.is_dir:
            raise FSError(f"is a directory: {path}")
        assert ino.stripe is not None
        view = memoryview(data)
        pos = 0
        for ext in extents_for_range(ino.stripe, offset, len(data)):
            piece = view[pos : pos + ext.length]
            self._write_extent(ino.file_id, ext.target, ext.chunk_id, ext.chunk_offset, piece)
            pos += ext.length
        ino.size = max(ino.size, offset + len(data))
        return len(data)

    def _write_extent(self, fid: int, target: int, chunk: int, off: int, piece) -> None:
        primary = self.storage_services[target]
        wrote_primary = False
        if primary.alive:
            primary.write_chunk(fid, chunk, off, bytes(piece))
            wrote_primary = True
        elif not self.mirror:
            raise FSError(f"storage target {target} is down (no mirror)")
        else:
            self._degraded_targets.add(target)
        if self.mirror:
            m = self.storage_services[self._mirror_of(target)]
            if m.alive:
                m.write_chunk(fid, chunk + (1 << 40), off, bytes(piece))
            elif not wrote_primary:
                raise FSError(f"both replicas of target {target} are down")

    def read(self, path: str, offset: int, length: int) -> bytes:
        self._check_live()
        path = normpath(path)
        ino = self._md_for(path).lookup(path)
        if ino.is_dir:
            raise FSError(f"is a directory: {path}")
        assert ino.stripe is not None
        out = bytearray()
        for ext in extents_for_range(ino.stripe, offset, length):
            primary = self.storage_services[ext.target]
            if primary.alive:
                out += primary.read_chunk(ino.file_id, ext.chunk_id, ext.chunk_offset, ext.length)
            elif self.mirror:
                m = self.storage_services[self._mirror_of(ext.target)]
                if not m.alive:
                    raise FSError(f"both replicas of target {ext.target} are down")
                out += m.read_chunk(ino.file_id, ext.chunk_id + (1 << 40), ext.chunk_offset, ext.length)
            else:
                raise FSError(f"storage target {ext.target} is down (no mirror)")
        return bytes(out)

    # -- failure injection ------------------------------------------------
    def kill_node(self, node_id: str) -> None:
        found = False
        for s in self.storage_services:
            if s.node_id == node_id:
                s.alive = False
                found = True
        for s in self.md_services:
            if s.node_id == node_id:
                s.alive = False
                found = True
        if not found:
            raise FSError(f"no services on node {node_id}")

    def healthy(self) -> bool:
        services_ok = all(s.alive for s in self.storage_services + self.md_services)
        return services_ok and not self._degraded_targets and not self._torn_down

    def degraded(self) -> bool:
        return bool(self._degraded_targets) or not all(
            s.alive for s in self.storage_services
        )
