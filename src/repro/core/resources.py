"""Cluster resource inventory: disks, storage nodes, compute nodes.

Mirrors the paper's two testbeds:

* **Dom** (Cray XC50): 8 compute nodes (2x18-core Broadwell, 64 GB DRAM) +
  4 DataWarp nodes, each with 3x 5.9 TB Samsung PM1725a PCIe SSDs
  (empirical 6.34 GB/s seq read, 3.2 GB/s seq write, measured with ``dd``
  and concurrent streams -- paper §IV-A). Global FS: Lustre, 2 OSTs, 170 TB.
* **Ault** (non-Cray): 1 node, 22-core Xeon Gold 6152, 16x Intel P4500 NVMe
  (vendor 3.2 GB/s read / 1.9 GB/s write; empirical-with-streams values are
  lower and captured in ``perfmodel``).

The same abstractions describe a TPU-pod hosting cluster: ``StorageNode`` is a
burst-buffer host on the pod's data-center network, ``ComputeNode`` a TPU host.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Iterable, Sequence

GB = 1e9
TB = 1e12
MiB = 1 << 20
GiB = 1 << 30


@dataclasses.dataclass(frozen=True)
class DiskSpec:
    """A block-device model. Bandwidths are *empirical* multi-stream values."""

    model: str
    capacity_bytes: float
    read_bw: float           # B/s, sequential, concurrent streams
    write_bw: float          # B/s, sequential, concurrent streams
    iops_4k: float = 200e3   # small-IO ops/s, used for metadata targets
    latency_s: float = 80e-6

    def __post_init__(self) -> None:
        if self.capacity_bytes <= 0 or self.read_bw <= 0 or self.write_bw <= 0:
            raise ValueError(f"invalid DiskSpec: {self}")


# Paper-empirical devices (§IV-A, §IV-B).
PM1725A = DiskSpec("samsung-pm1725a", 5.9 * TB, read_bw=6.34 * GB, write_bw=3.2 * GB)
# Vendor numbers for the P4500 are 3.2/1.9 GB/s; with many concurrent streams
# the paper reached 20.36/13.70 GB/s aggregate over 5 storage + 2 md disks,
# i.e. ~2.9 GB/s read and ~2.9 GB/s write effective per storage disk once
# client-side effects are included; we keep vendor seq numbers and let the
# perfmodel's concurrency term handle the rest.
P4500 = DiskSpec("intel-p4500", 4.0 * TB, read_bw=3.2 * GB, write_bw=1.9 * GB)
# A contemporary profile for TPU-cluster burst-buffer hosts.
NVME_GEN4 = DiskSpec("nvme-gen4", 7.68 * TB, read_bw=7.0 * GB, write_bw=5.0 * GB)


@dataclasses.dataclass(frozen=True)
class Disk:
    """A concrete disk instance inside a node."""

    node_id: str
    index: int
    spec: DiskSpec

    @property
    def name(self) -> str:
        return f"{self.node_id}/nvme{self.index}n1"


@dataclasses.dataclass(frozen=True)
class InterconnectSpec:
    """Node-to-node network. Aries on Dom; DCN for TPU-cluster profile."""

    name: str
    node_bw: float            # B/s injection bandwidth per node
    latency_s: float = 1.5e-6


ARIES = InterconnectSpec("cray-aries", node_bw=10.0 * GB)
LOCAL_PCIE = InterconnectSpec("local-pcie", node_bw=64.0 * GB, latency_s=0.3e-6)
DCN_100G = InterconnectSpec("dcn-100g", node_bw=12.5 * GB, latency_s=5e-6)


@dataclasses.dataclass(frozen=True)
class StorageNode:
    """A node with local block storage (DataWarp node / burst-buffer host)."""

    node_id: str
    disks: tuple[Disk, ...]
    dram_bytes: float = 64 * GiB      # server-side cache ceiling (paper §IV-A2)
    constraint: str = "storage"       # the paper's SLURM constraint

    @property
    def n_disks(self) -> int:
        return len(self.disks)


@dataclasses.dataclass(frozen=True)
class ComputeNode:
    node_id: str
    cores: int = 36
    dram_bytes: float = 64 * GiB
    constraint: str = "mc"


@dataclasses.dataclass(frozen=True)
class ClusterSpec:
    """Inventory handed to the scheduler."""

    name: str
    compute_nodes: tuple[ComputeNode, ...]
    storage_nodes: tuple[StorageNode, ...]
    interconnect: InterconnectSpec

    def storage_node(self, node_id: str) -> StorageNode:
        for n in self.storage_nodes:
            if n.node_id == node_id:
                return n
        raise KeyError(node_id)


def _mk_storage_nodes(
    prefix: str, count: int, disks_per_node: int, spec: DiskSpec, dram: float
) -> tuple[StorageNode, ...]:
    nodes = []
    for i in range(count):
        nid = f"{prefix}{i:03d}"
        disks = tuple(Disk(nid, d, spec) for d in range(disks_per_node))
        nodes.append(StorageNode(nid, disks, dram_bytes=dram))
    return tuple(nodes)


def dom_cluster() -> ClusterSpec:
    """The paper's Cray XC50 testbed (§IV-A)."""
    return ClusterSpec(
        name="dom",
        compute_nodes=tuple(ComputeNode(f"nid{i:05d}", cores=36) for i in range(8)),
        storage_nodes=_mk_storage_nodes("datawarp", 4, 3, PM1725A, 64 * GiB),
        interconnect=ARIES,
    )


def ault_cluster() -> ClusterSpec:
    """The paper's non-Cray portability testbed (§IV-B): storage is node-local,
    so the single node appears in both sets (the sets may overlap -- §III)."""
    return ClusterSpec(
        name="ault",
        compute_nodes=(ComputeNode("ault11", cores=22),),
        storage_nodes=_mk_storage_nodes("ault11-disks", 1, 16, P4500, 376 * GiB),
        interconnect=LOCAL_PCIE,
    )


def tpu_pod_cluster(n_hosts: int = 64, n_storage: int = 16) -> ClusterSpec:
    """A v5e-pod-scale profile: 64 TPU hosts + burst-buffer storage hosts."""
    return ClusterSpec(
        name="tpu-pod",
        compute_nodes=tuple(ComputeNode(f"host{i:04d}", cores=112) for i in range(n_hosts)),
        storage_nodes=_mk_storage_nodes("bb", n_storage, 4, NVME_GEN4, 512 * GiB),
        interconnect=DCN_100G,
    )


def synthetic_cluster(
    n_compute: int,
    n_storage: int,
    *,
    disks_per_node: int = 3,
    disk: DiskSpec = NVME_GEN4,
    name: str = "synthetic",
) -> ClusterSpec:
    """A parametric homogeneous inventory for scale benchmarks — e.g. the
    2,000-node cluster the 50k-job campaign bench sweeps. Node ids are
    zero-padded to five digits so lexicographic order (what the allocator
    grants by) equals numeric order at any size."""
    if n_compute <= 0 or n_storage <= 0:
        raise ValueError("synthetic_cluster needs positive node counts")
    storage = []
    for i in range(n_storage):
        nid = f"sn{i:05d}"
        disks = tuple(Disk(nid, d, disk) for d in range(disks_per_node))
        storage.append(StorageNode(nid, disks, dram_bytes=64 * GiB))
    return ClusterSpec(
        name=name,
        compute_nodes=tuple(ComputeNode(f"cn{i:05d}") for i in range(n_compute)),
        storage_nodes=tuple(storage),
        interconnect=DCN_100G,
    )


def aggregate_write_bw(nodes: Sequence[StorageNode], storage_disks_per_node: int) -> float:
    """Raw aggregate write bandwidth of the *storage-role* disks (paper's
    12.8 GB/s = 4 disks x 3.2 on two DataWarp nodes)."""
    return sum(
        sum(d.spec.write_bw for d in n.disks[:storage_disks_per_node])
        for n in nodes
    )


def flatten_disks(nodes: Iterable[StorageNode]) -> list[Disk]:
    return list(itertools.chain.from_iterable(n.disks for n in nodes))
