"""Compute-node client for a deployed data manager (paper §III-C).

BeeGFS needs a kernel module and a privileged mount; the paper lists this as
its main limitation (§V) and sketches prolog/epilog workarounds. Our client is
pure user-space (the abstraction the paper wishes it had), bound to a
``DataManager`` instance; it adds per-client op/byte accounting used by the
benchmarks and the monitoring service.
"""

from __future__ import annotations

import dataclasses

from .datamanager import DataManager, FSError, FileStat


@dataclasses.dataclass
class ClientStats:
    bytes_written: int = 0
    bytes_read: int = 0
    ops: int = 0


class FSClient:
    """One logical client (one compute-node process in the paper's runs)."""

    def __init__(self, fs: DataManager, client_id: str = "client0"):
        self._fs = fs
        self.client_id = client_id
        self.stats = ClientStats()
        self._mounted = True

    # -- lifecycle ---------------------------------------------------------
    def unmount(self) -> None:
        """Paper: 'on compute nodes, clients are properly stopped'."""
        self._mounted = False

    def _check(self) -> None:
        if not self._mounted:
            raise FSError(f"client {self.client_id} is unmounted")

    # -- namespace -----------------------------------------------------------
    def create(self, path: str) -> None:
        self._check()
        self.stats.ops += 1
        self._fs.create(path)

    def mkdir(self, path: str) -> None:
        self._check()
        self.stats.ops += 1
        self._fs.mkdir(path)

    def makedirs(self, path: str) -> None:
        self._check()
        parts = [p for p in path.split("/") if p]
        cur = ""
        for p in parts:
            cur += "/" + p
            if not self._fs.exists(cur):
                self.mkdir(cur)

    def stat(self, path: str) -> FileStat:
        self._check()
        self.stats.ops += 1
        return self._fs.stat(path)

    def exists(self, path: str) -> bool:
        self._check()
        self.stats.ops += 1
        return self._fs.exists(path)

    def readdir(self, path: str) -> list[str]:
        self._check()
        self.stats.ops += 1
        return self._fs.readdir(path)

    def unlink(self, path: str) -> None:
        self._check()
        self.stats.ops += 1
        self._fs.unlink(path)

    def rmdir(self, path: str) -> None:
        self._check()
        self.stats.ops += 1
        self._fs.rmdir(path)

    # -- data ----------------------------------------------------------------
    def pwrite(self, path: str, offset: int, data: bytes) -> int:
        self._check()
        n = self._fs.write(path, offset, data)
        self.stats.bytes_written += n
        self.stats.ops += 1
        return n

    def pread(self, path: str, offset: int, length: int) -> bytes:
        self._check()
        buf = self._fs.read(path, offset, length)
        self.stats.bytes_read += len(buf)
        self.stats.ops += 1
        return buf

    def write_file(self, path: str, data: bytes) -> int:
        if not self._fs.exists(path):
            self.create(path)
        return self.pwrite(path, 0, data)

    def read_file(self, path: str) -> bytes:
        st = self.stat(path)
        return self.pread(path, 0, st.size)
