"""Chunk/stripe layout math (BeeGFS-style round-robin striping).

A file is split into ``stripe_size`` chunks; chunk *i* lives on storage target
``(i + shift) % n_targets`` where ``shift`` is derived from the file id so that
different files start on different targets (load spreading). The paper uses a
1 MiB stripe size on both file systems (§IV-A).
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

MiB = 1 << 20
DEFAULT_STRIPE = 1 * MiB


@dataclasses.dataclass(frozen=True)
class StripeConfig:
    stripe_size: int
    n_targets: int
    shift: int = 0

    def __post_init__(self) -> None:
        if self.stripe_size <= 0:
            raise ValueError("stripe_size must be positive")
        if self.n_targets <= 0:
            raise ValueError("n_targets must be positive")

    def target_of_chunk(self, chunk_id: int) -> int:
        return (chunk_id + self.shift) % self.n_targets


@dataclasses.dataclass(frozen=True)
class Extent:
    """One contiguous piece of a logical byte range, landed on one chunk."""

    target: int          # storage-target index
    chunk_id: int        # global chunk index within the file
    chunk_offset: int    # offset within the chunk
    length: int
    file_offset: int     # where this piece starts in the logical file


def extents_for_range(cfg: StripeConfig, offset: int, length: int) -> Iterator[Extent]:
    """Split [offset, offset+length) into per-chunk extents."""
    if offset < 0 or length < 0:
        raise ValueError("negative offset/length")
    pos = offset
    end = offset + length
    while pos < end:
        chunk_id = pos // cfg.stripe_size
        chunk_off = pos % cfg.stripe_size
        take = min(cfg.stripe_size - chunk_off, end - pos)
        yield Extent(
            target=cfg.target_of_chunk(chunk_id),
            chunk_id=chunk_id,
            chunk_offset=chunk_off,
            length=take,
            file_offset=pos,
        )
        pos += take


def targets_touched(cfg: StripeConfig, offset: int, length: int) -> set[int]:
    return {e.target for e in extents_for_range(cfg, offset, length)}


def bytes_per_target(cfg: StripeConfig, offset: int, length: int) -> dict[int, int]:
    out: dict[int, int] = {}
    for e in extents_for_range(cfg, offset, length):
        out[e.target] = out.get(e.target, 0) + e.length
    return out
