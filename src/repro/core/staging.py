"""Stage-in / stage-out between the global FS and a provisioned EphemeralFS.

Paper §V: "a stage in and stage out of data might be required for the
scientific application to run or to retrieve its results". In the training
framework this is how datasets reach the burst tier before step 0 and how
checkpoints drain back to the global store (see ``repro.checkpoint``).
"""

from __future__ import annotations

import dataclasses

from .client import FSClient
from .datamanager import DataManager, FSError
from .perfmodel import FSDeployment, Workload, predict_read, predict_write

_CHUNK = 8 << 20  # 8 MiB copy granularity


@dataclasses.dataclass(frozen=True)
class StageReport:
    files: int
    bytes: int
    modeled_time_s: float      # max(src read, dst write) + per-file overhead
    direction: str             # "in" | "out"


def _copy_file(src: DataManager, dst: DataManager, src_path: str, dst_path: str) -> int:
    st = src.stat(src_path)
    if st.is_dir:
        raise FSError(f"not a file: {src_path}")
    if not dst.exists(dst_path):
        dst.create(dst_path)
    moved = 0
    while moved < st.size:
        take = min(_CHUNK, st.size - moved)
        dst.write(dst_path, moved, src.read(src_path, moved, take))
        moved += take
    return moved


def modeled_stage_time(
    nbytes: float,
    src_model: FSDeployment | None,
    dst_model: FSDeployment | None,
    n_streams: int = 8,
) -> float:
    """Modeled wall time to move ``nbytes`` from ``src`` to ``dst``: the
    slower of the source read and destination write paths at paper scale.
    Shared with the workflow orchestrator, which advances its virtual clock
    by this prediction for every stage-in/stage-out phase (and by the pool
    subsystem, which charges only the *missing* dataset bytes on a cache hit).

    Zero (or negative) byte counts are a no-op — an empty stage must not pay
    the perfmodel's fixed setup ramp — and ``n_streams`` is clamped to >= 1.
    """
    if nbytes <= 0:
        return 0.0
    w = Workload(n_procs=max(1, n_streams), size_per_proc=nbytes / max(1, n_streams),
                 pattern="fpp")
    t = 0.0
    if src_model is not None:
        t = max(t, predict_read(w, src_model).elapsed_s)
    if dst_model is not None:
        t = max(t, predict_write(w, dst_model).elapsed_s)
    return t


def stage(
    src: DataManager,
    dst: DataManager,
    paths: list[tuple[str, str]],
    *,
    src_model: FSDeployment | None = None,
    dst_model: FSDeployment | None = None,
    n_streams: int = 8,
    direction: str = "in",
) -> StageReport:
    """Copy ``[(src_path, dst_path), ...]``; returns bytes + modeled time."""
    total = 0
    for sp, dp in paths:
        parent = dp.rsplit("/", 1)[0]
        if parent and parent != "":
            FSClient(dst, "stager").makedirs(parent)
        total += _copy_file(src, dst, sp, dp)
    t = modeled_stage_time(total, src_model, dst_model, n_streams)
    return StageReport(files=len(paths), bytes=total, modeled_time_s=t, direction=direction)


def stage_tree(
    src: DataManager,
    dst: DataManager,
    src_dir: str,
    dst_dir: str,
    **kw,
) -> StageReport:
    """Recursively stage a directory."""
    pairs: list[tuple[str, str]] = []

    def walk(d: str) -> None:
        for name in src.readdir(d):
            p = f"{d.rstrip('/')}/{name}"
            if src.stat(p).is_dir:
                walk(p)
            else:
                rel = p[len(src_dir):].lstrip("/")
                pairs.append((p, f"{dst_dir.rstrip('/')}/{rel}"))

    walk(src_dir)
    return stage(src, dst, pairs, **kw)
