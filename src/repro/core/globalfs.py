"""GlobalFS: the Lustre-analogue shared parallel file system (the baseline).

On Dom the global store is Lustre with 2 OSTs and a dedicated MDS (§IV-A).
Functionally we reuse the striped-FS machinery (MDS = 1 metadata service,
OSTs = storage services, stripe_count configurable like ``lfs setstripe -c``);
the analytic view is ``perfmodel.dom_lustre()``. Unlike EphemeralFS it is
*not* job-scoped: it pre-exists jobs and survives them.
"""

from __future__ import annotations

import tempfile

from .datamanager import ServiceInfo
from .ephemeralfs import EphemeralFS
from .perfmodel import FSDeployment, dom_lustre
from .resources import GiB, TB, Disk, DiskSpec, StorageNode
from .striping import DEFAULT_STRIPE

# An OST on Dom: 170 TB usable over 2 OSTs.
LUSTRE_OST = DiskSpec("lustre-ost", 85 * TB, read_bw=2.3e9, write_bw=3.0e9, iops_4k=50e3)
LUSTRE_MDT = DiskSpec("lustre-mdt", 2 * TB, read_bw=2.0e9, write_bw=2.0e9, iops_4k=500e3)


class GlobalFS(EphemeralFS):
    """Shared parallel FS with ``stripe_count`` OSTs (paper sets -c 2)."""

    def __init__(
        self,
        base_dir: str | None = None,
        *,
        n_osts: int = 2,
        stripe_size: int = DEFAULT_STRIPE,
    ):
        base_dir = base_dir or tempfile.mkdtemp(prefix="lustre-")
        mds = StorageNode(
            "lustre-mds0",
            disks=(Disk("lustre-mds0", 0, LUSTRE_MDT),) + tuple(
                Disk("lustre-mds0", 1 + i, LUSTRE_OST) for i in range(n_osts)
            ),
            dram_bytes=256 * GiB,
        )
        super().__init__(
            (mds,),
            base_dir,
            md_disks_per_node=1,
            storage_disks_per_node=n_osts,
            stripe_size=stripe_size,
        )
        self.n_osts = n_osts

    def services(self) -> list[ServiceInfo]:
        infos = super().services()
        for info in infos:
            if info.kind == "metadata":
                info.kind = "mds"
            elif info.kind == "storage":
                info.kind = "ost"
        return infos

    def perf_view(self) -> FSDeployment:
        return dom_lustre()
